#!/usr/bin/env bash
# Tier-1 verification: rust build+tests, python tests.
# Usage: scripts/check.sh [--rust-only|--python-only]
set -euo pipefail
cd "$(dirname "$0")/.."

want_rust=1
want_python=1
case "${1:-}" in
  --rust-only) want_python=0 ;;
  --python-only) want_rust=0 ;;
  "") ;;
  *) echo "usage: scripts/check.sh [--rust-only|--python-only]" >&2; exit 2 ;;
esac

status=0

if [ "$want_rust" = 1 ]; then
  if command -v cargo >/dev/null 2>&1; then
    echo "== cargo build --release =="
    cargo build --release
    echo "== cargo test -q =="
    cargo test -q
  else
    echo "!! cargo not found: skipping rust tier (install a rust toolchain)" >&2
    status=0 # informational skip; CI images provide the toolchain
  fi
fi

if [ "$want_python" = 1 ]; then
  if command -v python3 >/dev/null 2>&1; then
    echo "== python -m pytest python/tests -q =="
    python3 -m pytest python/tests -q
  else
    echo "!! python3 not found: skipping python tier" >&2
  fi
fi

exit "$status"
