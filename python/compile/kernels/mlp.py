"""MXU-tiled matmul(+bias) Pallas kernel for the bottom/top-MLP layers.

TPU adaptation of the paper's GPU MLP path: tiles are sized for the
128x128 MXU systolic array and a VMEM working set of
bm*bk + bk*bn + bm*bn floats (<= ~192 KiB at the default 128 tiles, far
under the ~16 MiB VMEM budget, leaving room for double-buffering). The
K-reduction is the innermost grid axis so the output tile stays resident
in VMEM across partial products (revolving accumulator).

Lowered with interpret=True; odd DLRM widths (13, 8192, ...) are padded to
tile multiples by the wrapper and sliced back.

A jax.custom_vjp makes the kernel differentiable: both backward matmuls
(dx = g @ w^T, dw = x^T @ g) reuse the same kernel, so the entire MLP
fwd+bwd lowers onto one tiled-primitive.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid (M/bm, N/bn, K/bk); K innermost, accumulate into the out tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    rem = x.shape[axis] % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jnp.ndarray, w: jnp.ndarray, bm: int = 128, bn: int = 128, bk: int = 128
) -> jnp.ndarray:
    """Tiled x @ w for f32 operands; pads to tile multiples and slices back."""
    M, K = x.shape
    _, N = w.shape
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    Mp, Kp = xp.shape
    Np = wp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:M, :N]


@jax.custom_vjp
def matmul_bias(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x @ w + b through the tiled kernel, differentiable via custom VJP."""
    return matmul(x, w) + b


def _mb_fwd(x, w, b):
    return matmul(x, w) + b, (x, w)


def _mb_bwd(res, g):
    x, w = res
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    return dx, dw, g.sum(axis=0)


matmul_bias.defvjp(_mb_fwd, _mb_bwd)
