//! Energy model (paper Fig 13): dynamic energy per byte moved on each
//! medium + link, active-power x busy-time for the compute engines, and
//! static (refresh/leakage) power for the provisioned capacity.
//!
//! The paper's key energy effects all fall out of this accounting:
//! * DRAM provisions many more modules for the same table capacity, so
//!   its static term dominates (DRAM > PMEM for embedding-heavy RMs);
//! * PMEM pays heavy dynamic write energy for MLP logging (PMEM > DRAM
//!   for MLP-heavy RMs, which log big MLPs every batch);
//! * CXL wins everywhere mainly by *finishing sooner* (static and active
//!   power integrate over a 5x shorter run) and by writing fewer log
//!   bytes (undo + relaxed logging).

use crate::config::device::{DeviceParams, EnergyParams};
use crate::config::sysconfig::SystemConfig;
use crate::config::ModelConfig;
use crate::sched::RunResult;

/// Energy breakdown in joules.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyReport {
    pub dynamic_media_j: f64,
    pub link_j: f64,
    pub gpu_j: f64,
    pub host_j: f64,
    pub logic_j: f64,
    pub static_j: f64,
}

impl EnergyReport {
    pub fn total(&self) -> f64 {
        self.dynamic_media_j
            + self.link_j
            + self.gpu_j
            + self.host_j
            + self.logic_j
            + self.static_j
    }
}

/// Provisioned capacity (bytes) per tier for a (model, config) pair.
fn provisioned(cfg: &ModelConfig, sys: SystemConfig) -> (f64, f64, bool) {
    let table_gb = cfg.logical_table_bytes() as f64 / 1e9;
    // (dram_gb, pmem_gb, ssd_present)
    match sys {
        SystemConfig::Dram => (table_gb + 4.0, 0.0, false),
        SystemConfig::Ssd => (4.0 + table_gb * 0.02, 0.0, true), // host DRAM + cache
        SystemConfig::Pmem => (4.0, table_gb * 1.25, false),     // +25% log region
        SystemConfig::Pcie | SystemConfig::CxlD | SystemConfig::CxlB | SystemConfig::Cxl => {
            (4.0, table_gb * 1.25, false)
        }
    }
}

/// Integrate a finished run into joules.
pub fn energy_of_run(cfg: &ModelConfig, params: &DeviceParams, run: &RunResult) -> EnergyReport {
    let e: &EnergyParams = &params.energy;
    let secs = run.total_time as f64 / 1e9;

    let mut dynamic = 0.0;
    for (medium, (rd, wr)) in &run.traffic.by_medium {
        let (pj_rd, pj_wr) = match *medium {
            "dram" => (e.dram_pj_per_byte, e.dram_pj_per_byte),
            "pmem" => (e.pmem_read_pj_per_byte, e.pmem_write_pj_per_byte),
            "ssd" => (e.ssd_pj_per_byte, e.ssd_pj_per_byte),
            _ => (0.0, 0.0),
        };
        dynamic += (*rd as f64 * pj_rd + *wr as f64 * pj_wr) * 1e-12;
    }
    let link_j = run.traffic.link_bytes as f64 * e.link_pj_per_byte * 1e-12;
    let gpu_j = params.gpu.power_w * run.gpu_busy as f64 / 1e9
        + params.gpu.idle_w * run.total_time.saturating_sub(run.gpu_busy) as f64 / 1e9;
    let host_j = e.host_cpu_power_w * run.host_busy as f64 / 1e9;
    let logic_j =
        (params.comp_logic.power_w + params.ckpt_logic.power_w) * run.logic_busy as f64 / 1e9;

    let (dram_gb, pmem_gb, ssd) = provisioned(cfg, run.config);
    let static_w = dram_gb * e.dram_static_w_per_gb
        + pmem_gb * e.pmem_static_w_per_gb
        + if ssd { e.ssd_static_w } else { 0.0 };
    let static_j = static_w * secs;

    EnergyReport {
        dynamic_media_j: dynamic,
        link_j,
        gpu_j,
        host_j,
        logic_j,
        static_j,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::TrafficCounters;

    fn fake_run(config: SystemConfig, total_ns: u64) -> RunResult {
        let mut traffic = TrafficCounters::default();
        traffic.record("pmem", 1 << 30, 1 << 28);
        RunResult {
            config,
            topology: config.name().to_string(),
            model: "rm1".into(),
            spans: Default::default(),
            breakdowns: vec![],
            batch_times: vec![total_ns],
            traffic,
            total_time: total_ns,
            raw_hits: 0,
            max_mlp_gap: 0,
            gpu_busy: total_ns / 2,
            host_busy: 0,
            logic_busy: total_ns / 4,
            trace: Default::default(),
        }
    }

    #[test]
    fn energy_scales_with_runtime() {
        let root = crate::repo_root();
        let cfg = crate::config::ModelConfig::load(&root, "rm1").unwrap();
        let p = DeviceParams::builtin_default();
        let fast = energy_of_run(&cfg, &p, &fake_run(SystemConfig::Cxl, 1_000_000_000));
        let slow = energy_of_run(&cfg, &p, &fake_run(SystemConfig::Cxl, 5_000_000_000));
        assert!(slow.total() > fast.total());
        assert!(slow.static_j > 4.9 * fast.static_j);
    }

    #[test]
    fn dram_static_dominates_pmem_static_for_same_capacity() {
        let root = crate::repo_root();
        let cfg = crate::config::ModelConfig::load(&root, "rm1").unwrap();
        let p = DeviceParams::builtin_default();
        let t = 10_000_000_000;
        let dram = energy_of_run(&cfg, &p, &fake_run(SystemConfig::Dram, t));
        let pmem = energy_of_run(&cfg, &p, &fake_run(SystemConfig::Pmem, t));
        assert!(dram.static_j > 2.0 * pmem.static_j);
    }

    #[test]
    fn pmem_writes_cost_more_than_reads() {
        let root = crate::repo_root();
        let cfg = crate::config::ModelConfig::load(&root, "rm1").unwrap();
        let p = DeviceParams::builtin_default();
        let mut rd_run = fake_run(SystemConfig::Pmem, 1_000_000_000);
        rd_run.traffic = TrafficCounters::default();
        rd_run.traffic.record("pmem", 1 << 30, 0);
        let mut wr_run = fake_run(SystemConfig::Pmem, 1_000_000_000);
        wr_run.traffic = TrafficCounters::default();
        wr_run.traffic.record("pmem", 0, 1 << 30);
        let er = energy_of_run(&cfg, &p, &rd_run);
        let ew = energy_of_run(&cfg, &p, &wr_run);
        assert!(ew.dynamic_media_j > 3.0 * er.dynamic_media_j);
    }
}
