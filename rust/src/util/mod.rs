//! In-tree substrates the offline build cannot pull from crates.io:
//! deterministic PRNG + Zipf sampling, minimal JSON/TOML readers, and
//! summary statistics.

pub mod json;
pub mod rng;
pub mod stats;
pub mod tomlmini;

pub use rng::{Rng, Zipf};
