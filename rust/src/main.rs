//! `trainingcxl` — the launcher.
//!
//! ```text
//! trainingcxl train    --model rm_e2e --steps 300 [--topology NAME]
//! trainingcxl simulate --model rm1 --config CXL --batches 50 [--timeline]
//! trainingcxl bench    <fig11|fig12|fig13|fig9a|headline|ablate-movement|ablate-raw|pooling|shard-scaling|tier-sweep|tenant-interference|serve-latency|engine-throughput|fault-sweep|all>
//! trainingcxl trace    <topology|world> [--out FILE] [--summary]
//! trainingcxl calibrate [--model NAME ...]
//! trainingcxl recover-demo
//! trainingcxl list
//! ```
//!
//! Hand-rolled argument parsing (offline build: no clap); every subcommand
//! maps onto a library entry point, so everything here is also reachable
//! from tests and examples. Name resolution (`--topology`, tenant sets)
//! goes through [`trainingcxl::world::World`], the unified entry point.

#![forbid(unsafe_code)]

use std::collections::VecDeque;
use std::process::ExitCode;

use trainingcxl::analysis;
use trainingcxl::bench::experiments::{self, Experiment, RunOpts};
use trainingcxl::config::{DeviceParams, ModelConfig, SystemConfig};
use trainingcxl::sched::PipelineSim;
use trainingcxl::sim::fabric::LinkStats;
use trainingcxl::sim::topology::Topology;
use trainingcxl::telemetry::{MetricsRegistry, SpanLog, TraceLog};
use trainingcxl::tenancy::MultiTenantSim;
use trainingcxl::train::{calibrate, failure, Trainer};
use trainingcxl::world::World;

fn usage() -> &'static str {
    "trainingcxl — TrainingCXL reproduction (IEEE Micro 2023)

USAGE:
  trainingcxl train     --model NAME [--steps N] [--topology NAME] [--seed S]
                        --topology: a system config or configs/topologies/ file;
                        its CkptMode drives checkpointing (default: DRAM = off)
  trainingcxl simulate  --model NAME --config CFG [--batches N] [--timeline]
                        CFG: a system config (SSD|PMEM|PCIe|CXL-D|CXL-B|CXL|DRAM)
                        or --topology NAME from configs/topologies/
  trainingcxl bench     EXP [--json]     fig11|fig12|fig13|fig9a|headline|
                                         ablate-movement|ablate-raw|pooling|
                                         shard-scaling|tier-sweep|
                                         tenant-interference|serve-latency|
                                         engine-throughput|fault-sweep|all
  trainingcxl analyze   [--topology NAME] [--verbose]
                        static crash-consistency + resource-order check over
                        every configs/topologies/*.toml (solo or [[tenants]]),
                        the exhaustive builder-family enumeration, and mixed
                        tenant worlds; exits non-zero on any violation (the
                        CI gate)
  trainingcxl trace     WORLD [--out FILE] [--summary] [--batches N]
                        [--model NAME] [--workers N]
                        run a world and export its causal trace as Chrome
                        trace-event JSON (load in Perfetto / about:tracing);
                        --summary prints critical-path attribution and
                        lane/link utilization instead of staying silent
  trainingcxl calibrate [--model NAME]...   measure MLP times -> artifacts/calibration.json
  trainingcxl recover-demo                  crash + recover walk-through (rm_mini)
  trainingcxl list                          models, system configs, topologies
"
}

struct Args {
    flags: std::collections::BTreeMap<String, String>,
    positional: Vec<String>,
}

fn parse_args(mut argv: VecDeque<String>) -> Args {
    let mut flags = std::collections::BTreeMap::new();
    let mut positional = Vec::new();
    while let Some(a) = argv.pop_front() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if argv.front().map(|v| !v.starts_with("--")).unwrap_or(false) {
                argv.pop_front().unwrap()
            } else {
                "true".to_string()
            };
            // repeatable flags accumulate comma-separated
            flags
                .entry(name.to_string())
                .and_modify(|v: &mut String| {
                    v.push(',');
                    v.push_str(&val);
                })
                .or_insert(val);
        } else {
            positional.push(a);
        }
    }
    Args { flags, positional }
}

impl Args {
    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn get_u64(&self, k: &str, default: u64) -> u64 {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
}

fn cmd_train(root: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let model = args.get("model").unwrap_or("rm_mini");
    let steps = args.get_u64("steps", 100);
    let seed = args.get_u64("seed", 7);
    let cfg = ModelConfig::load(root, model)?;
    for removed in ["ckpt", "mlp-every"] {
        anyhow::ensure!(
            !args.has(removed),
            "--{removed} was replaced by --topology: checkpointing now derives \
             from the fabric's CkptMode (try --topology cxl-b, or cxl for the \
             relaxed schedule)"
        );
    }
    // Checkpointing derives from the fabric: DRAM-ideal (the default)
    // has CkptMode::None, the CXL stages checkpoint batch-aware. A
    // `[[tenants]]` world is a typed error here — training drives ONE
    // model (World::into_solo says so instead of simulating a fallback).
    let topo = World::resolve(root, args.get("topology").unwrap_or("dram"))?.into_solo()?;
    eprintln!(
        "[train] {model}: {} params, batch {}, topology {} (ckpt {:?})",
        cfg.param_count(),
        cfg.batch_size,
        topo.name,
        topo.ckpt
    );
    let mut t = Trainer::with_topology(root, &cfg, seed, &topo)?;
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let out = t.step()?;
        if s < 5 || s % 10 == 9 || s + 1 == steps {
            println!("step {:>5}  loss {:.5}", out.batch, out.loss);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let (eval_loss, acc) = t.evaluate(8, seed ^ 0xE7A1)?;
    println!(
        "[train] {steps} steps in {dt:.1}s ({:.1} ms/step) | eval loss {eval_loss:.4} acc {acc:.4}",
        1e3 * dt / steps as f64
    );
    Ok(())
}

fn cmd_simulate(root: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let model = args.get("model").unwrap_or("rm1");
    let batches = args.get_u64("batches", 20);
    // An explicitly requested --topology resolves strictly through the
    // World API: a typo'd name errors with the available list, a
    // malformed file errors with the parse failure, and a `[[tenants]]`
    // set errors typed (this command simulates ONE pipeline; tenant sets
    // run through `bench tenant-interference`). --config parses a paper
    // system config; unknown values print the valid list.
    let topo = match args.get("topology") {
        Some(name) => World::resolve(root, name)?.into_solo()?,
        None => {
            let sys: SystemConfig = args.get("config").unwrap_or("cxl").parse()?;
            Topology::from_system(sys)
        }
    };
    let name = topo.name.clone();
    let r = experiments::simulate_topology(root, model, topo, batches)?;
    let bd = r.mean_breakdown();
    println!(
        "[simulate] {model}/{name}: {:.3} ms/batch over {batches} batches",
        r.mean_batch_ns() / 1e6
    );
    println!(
        "  B-MLP {:.3}ms  T-MLP {:.3}ms  Transfer {:.3}ms  Embedding {:.3}ms  Checkpoint {:.3}ms",
        bd.bmlp / 1e6,
        bd.tmlp / 1e6,
        bd.transfer / 1e6,
        bd.embedding / 1e6,
        bd.checkpoint / 1e6
    );
    println!("  raw-hits {}  max MLP-log gap {}", r.raw_hits, r.max_mlp_gap);
    if args.has("timeline") {
        let t0 = r.batch_times[..2.min(r.batch_times.len())]
            .iter()
            .sum::<u64>();
        let t1 = r.spans.end_time();
        print!("{}", r.spans.render_timeline(t0, t1, 96));
    }
    Ok(())
}

fn cmd_bench(root: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let what = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = RunOpts {
        batches: args.get_u64("batches", 30),
        model: args.get("model").map(str::to_string),
    };
    let json = args.has("json");
    let experiments: Vec<Experiment> = if what == "all" {
        Experiment::ALL.to_vec()
    } else {
        vec![what.parse()?] // unknown names list the valid experiments
    };
    for e in experiments {
        let report = e.run(root, &opts)?;
        if json {
            println!("{}", report.to_json());
        } else {
            println!("{report}");
        }
    }
    Ok(())
}

fn cmd_analyze(root: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let reports = match args.get("topology") {
        // One named world: a solo fabric analyzes both its chains, a
        // tenant set analyzes every member lane plus the mixed world.
        Some(name) => match World::resolve(root, name)? {
            World::Solo(t) => vec![
                analysis::analyze_topology(&t)?,
                analysis::analyze_serving_topology(&t)?,
            ],
            World::Tenants(set) => vec![analysis::analyze_tenant_set(&set)?],
        },
        // The gate: every shipped TOML + the family enumeration + worlds.
        None => analysis::analyze_repo(root)?,
    };
    let mut violations = 0usize;
    let mut warnings = 0usize;
    for r in &reports {
        violations += r.violations.len();
        warnings += r.warnings.len();
        if r.is_clean() && r.warnings.is_empty() {
            if args.has("verbose") {
                println!("{r}");
            }
        } else {
            print!("{r}");
        }
    }
    println!(
        "analyze: {} subjects checked, {violations} violation(s), {warnings} warning(s)",
        reports.len()
    );
    anyhow::ensure!(
        violations == 0,
        "static analysis found {violations} violation(s)"
    );
    Ok(())
}

fn cmd_trace(root: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let name = args.positional.get(1).map(|s| s.as_str()).ok_or_else(|| {
        anyhow::anyhow!("trace needs a world name (see `trainingcxl list` for what ships)")
    })?;
    let batches = args.get_u64("batches", 8);
    // Both world classes produce the same artifact: a validated TraceLog
    // plus per-tenant SpanLogs for the hardware-lane tracks. Solo worlds
    // run the deterministic pipeline (seed 42, same as the bench path);
    // tenant sets run the full engine, optionally with --workers (the
    // trace is byte-identical at any worker count — that is the pin).
    match World::resolve(root, name)? {
        World::Solo(topo) => {
            let model = args.get("model").unwrap_or("rm_mini");
            let tenant = topo.name.clone();
            let r = PipelineSim::for_model(root, model, topo, 42)?.run(batches);
            export_trace(args, &r.trace, &[tenant], &[&r.spans], &[])
        }
        World::Tenants(set) => {
            let mut sim = MultiTenantSim::new(root, &set)?;
            if let Some(w) = args.get("workers") {
                sim = sim.with_workers(w.parse()?);
            }
            let run = sim.run(batches);
            let tenants: Vec<String> = run.tenants.iter().map(|t| t.name.clone()).collect();
            let spans: Vec<&SpanLog> = run.tenants.iter().map(|t| &t.result.spans).collect();
            export_trace(args, &run.trace, &tenants, &spans, &run.links)
        }
    }
}

/// The shared tail of `trainingcxl trace`: schema-validate the log (the
/// CI legs lean on this — a malformed trace fails the command, not just
/// the viewer), export Chrome trace-event JSON, and optionally print the
/// critical-path attribution + utilization summary.
fn export_trace(
    args: &Args,
    trace: &TraceLog,
    tenants: &[String],
    spans: &[&SpanLog],
    links: &[(String, LinkStats)],
) -> anyhow::Result<()> {
    trace
        .validate()
        .map_err(|e| anyhow::anyhow!("trace failed validation: {e}"))?;
    let json = trace.chrome_trace(tenants, spans);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, format!("{json}\n"))?;
            eprintln!("[trace] wrote {path} ({} events)", trace.len());
        }
        None => println!("{json}"),
    }
    if args.has("summary") {
        let a = trace.attribution();
        print!("{}", a.render());
        let wall = a.total_ns.max(1);
        let mut m = MetricsRegistry::new();
        for (name, s) in tenants.iter().zip(spans) {
            m.register_lanes(name, s, 0, wall);
        }
        if !links.is_empty() {
            m.register_links("fabric", links, wall);
        }
        if !m.is_empty() {
            print!("{}", m.render());
        }
    }
    Ok(())
}

fn cmd_calibrate(root: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let params = DeviceParams::load(root)?;
    let models: Vec<String> = args
        .get("model")
        .map(|m| m.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["rm1".into(), "rm2".into(), "rm3".into(), "rm4".into()]);
    let refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
    calibrate::calibrate_all(root, &refs, &params)?;
    println!("wrote {}", root.join("artifacts/calibration.json").display());
    Ok(())
}

fn cmd_recover_demo(root: &std::path::Path) -> anyhow::Result<()> {
    let cfg = ModelConfig::load(root, "rm_mini")?;
    println!("[demo] training rm_mini 40 batches with batch-aware checkpointing...");
    let r = failure::run_gap_experiment(root, &cfg, 7, 40, 40, 10, 8)?;
    println!(
        "[demo] crash injected; recovered tables@batch {} with MLP {} batches stale",
        r.recovered_from, r.mlp_gap_observed
    );
    println!(
        "[demo] resumed 40 batches: loss {:.4}, accuracy {:.4}",
        r.loss, r.accuracy
    );
    Ok(())
}

fn cmd_list(root: &std::path::Path) -> anyhow::Result<()> {
    println!("models ({}):", root.join("configs/models").display());
    for m in ModelConfig::available(root) {
        let cfg = ModelConfig::load(root, &m)?;
        println!(
            "  {:<8} {:>12} params  T={:<3} L={:<3} batch={}",
            m,
            cfg.param_count(),
            cfg.num_tables,
            cfg.lookups_per_table,
            cfg.batch_size
        );
    }
    println!("\nsystem configs: SSD PMEM PCIe CXL-D CXL-B CXL DRAM(energy-only)");
    let topologies = Topology::available(root);
    if !topologies.is_empty() {
        println!(
            "topologies ({}): {}",
            root.join("configs/topologies").display(),
            topologies.join(" ")
        );
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: VecDeque<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{}", usage());
        return ExitCode::FAILURE;
    }
    let args = parse_args(argv);
    let root = trainingcxl::repo_root();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "train" => cmd_train(&root, &args),
        "simulate" => cmd_simulate(&root, &args),
        "bench" => cmd_bench(&root, &args),
        "analyze" => cmd_analyze(&root, &args),
        "trace" => cmd_trace(&root, &args),
        "calibrate" => cmd_calibrate(&root, &args),
        "recover-demo" => cmd_recover_demo(&root),
        "list" => cmd_list(&root),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
