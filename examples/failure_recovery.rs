//! Failure-tolerance walk-through (paper §Failure Tolerance Management):
//! train with batch-aware checkpointing, kill the machine mid-update,
//! recover from the CXL-MEM log region, resume, and compare accuracy to a
//! never-crashed twin — including the relaxed case where the MLP log is
//! many batches stale (Fig 9a's x-axis).
//!
//! Run: `cargo run --release --example failure_recovery`

use trainingcxl::config::ModelConfig;
use trainingcxl::train::failure;

fn main() -> anyhow::Result<()> {
    let root = trainingcxl::repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini")?;

    println!("== no-crash twin: 400 batches ==");
    let (base_loss, base_acc) = failure::run_no_crash_baseline(&root, &cfg, 7, 400, 16)?;
    println!("baseline: loss {base_loss:.4} acc {base_acc:.4}\n");

    for gap in [1u64, 25, 100] {
        println!("== crash at batch 200, MLP log every {gap} batch(es) ==");
        let r = failure::run_gap_experiment(&root, &cfg, 7, 200, 200, gap, 16)?;
        println!(
            "recovered: tables@batch {}, MLP {} batches stale",
            r.recovered_from, r.mlp_gap_observed
        );
        println!(
            "after resume: loss {:.4} acc {:.4} (delta vs baseline {:+.4})\n",
            r.loss,
            r.accuracy,
            r.accuracy - base_acc
        );
        anyhow::ensure!(
            (r.accuracy - base_acc).abs() < 0.08,
            "recovery diverged beyond tolerance"
        );
    }
    println!("failure_recovery OK: stale-MLP recovery stays within tolerance (Fig 9a)");
    Ok(())
}
