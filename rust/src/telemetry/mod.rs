//! Telemetry: span logs, per-lane utilization (Fig 12), per-batch
//! breakdowns (Fig 11), and plain-text renderers for the bench harness.

use crate::sim::fabric::LinkStats;
use crate::sim::{Lane, OpKind, SimTime, Span};
use std::collections::BTreeMap;

pub mod latency;
pub mod metrics;
pub mod trace;

pub use latency::{LatencyHistogram, StalenessGauge};
pub use metrics::{MetricEntry, MetricValue, MetricsRegistry};
pub use trace::{Attribution, TraceEvent, TraceKind, TraceLog};

/// Append-only span log for one simulation run.
#[derive(Clone, Debug, Default)]
pub struct SpanLog {
    pub spans: Vec<Span>,
}

impl SpanLog {
    pub fn add(&mut self, lane: Lane, kind: OpKind, batch: u64, start: SimTime, end: SimTime) {
        debug_assert!(end >= start, "{kind:?} span ends before it starts");
        if end > start {
            self.spans.push(Span {
                lane,
                kind,
                batch,
                start,
                end,
            });
        }
    }

    /// Busy time per lane within [from, to), overlap-merged.
    pub fn busy(&self, lane: Lane, from: SimTime, to: SimTime) -> SimTime {
        let mut iv: Vec<(SimTime, SimTime)> = self
            .spans
            .iter()
            .filter(|s| s.lane == lane && s.end > from && s.start < to)
            .map(|s| (s.start.max(from), s.end.min(to)))
            .collect();
        iv.sort_unstable();
        let mut busy = 0;
        let mut cur: Option<(SimTime, SimTime)> = None;
        for (s, e) in iv {
            match cur {
                Some((cs, ce)) if s <= ce => cur = Some((cs, ce.max(e))),
                Some((cs, ce)) => {
                    busy += ce - cs;
                    cur = Some((s, e));
                    let _ = cs;
                }
                None => cur = Some((s, e)),
            }
        }
        if let Some((cs, ce)) = cur {
            busy += ce - cs;
        }
        busy
    }

    /// Utilization of `lane` over [from, to).
    pub fn utilization(&self, lane: Lane, from: SimTime, to: SimTime) -> f64 {
        if to <= from {
            return 0.0;
        }
        self.busy(lane, from, to) as f64 / (to - from) as f64
    }

    pub fn end_time(&self) -> SimTime {
        self.spans.iter().map(|s| s.end).max().unwrap_or(0)
    }

    /// Render a Fig-12-style ASCII timeline: one row per lane, `width`
    /// columns over [from, to), each cell the op occupying that instant.
    pub fn render_timeline(&self, from: SimTime, to: SimTime, width: usize) -> String {
        let lanes = [
            Lane::Gpu,
            Lane::CompLogic,
            Lane::CkptLogic,
            Lane::Pmem,
            Lane::HostCpu,
            Lane::Link,
        ];
        let glyph = |k: OpKind| match k {
            OpKind::BottomMlp => 'B',
            OpKind::TopMlp => 'T',
            OpKind::Transfer => 'x',
            OpKind::EmbLookup => 'L',
            OpKind::EmbUpdate => 'U',
            OpKind::CkptEmb => 'e',
            OpKind::CkptMlp => 'm',
            OpKind::Idle => '.',
        };
        let mut out = String::new();
        let dur = (to - from).max(1);
        for lane in lanes {
            let mut row: Vec<char> = vec!['.'; width];
            for s in self.spans.iter().filter(|s| s.lane == lane) {
                if s.end <= from || s.start >= to {
                    continue;
                }
                let c0 =
                    ((s.start.max(from) - from) as u128 * width as u128 / dur as u128) as usize;
                let c1 = ((s.end.min(to) - from) as u128 * width as u128 / dur as u128) as usize;
                for c in row.iter_mut().take(c1.max(c0 + 1).min(width)).skip(c0) {
                    *c = glyph(s.kind);
                }
            }
            out.push_str(&format!("{:>9} |", lane.name()));
            out.extend(row);
            out.push('\n');
        }
        out.push_str(&format!(
            "          +{} {:.2} ms total\n",
            "-".repeat(width),
            (to - from) as f64 / 1e6
        ));
        out.push_str(
            "          B=bottom-MLP T=top-MLP L=lookup U=update e=emb-log m=mlp-log x=transfer\n",
        );
        out
    }
}

/// Per-batch critical-path attribution — Fig 11's stacked-bar segments.
/// Components sum to the batch latency.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub bmlp: f64,
    pub tmlp: f64,
    pub transfer: f64,
    pub embedding: f64,
    pub checkpoint: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.bmlp + self.tmlp + self.transfer + self.embedding + self.checkpoint
    }

    pub fn add(&mut self, o: &Breakdown) {
        self.bmlp += o.bmlp;
        self.tmlp += o.tmlp;
        self.transfer += o.transfer;
        self.embedding += o.embedding;
        self.checkpoint += o.checkpoint;
    }

    pub fn scale(&self, k: f64) -> Breakdown {
        Breakdown {
            bmlp: self.bmlp * k,
            tmlp: self.tmlp * k,
            transfer: self.transfer * k,
            embedding: self.embedding * k,
            checkpoint: self.checkpoint * k,
        }
    }

    /// The paper's training time excludes Checkpoint in some comparisons
    /// ("including T-MLP, B-MLP, Transfer, and Embedding, except for
    /// Checkpoint").
    pub fn sans_checkpoint(&self) -> f64 {
        self.total() - self.checkpoint
    }
}

/// A labelled table of breakdown rows (config -> Breakdown), rendered like
/// the paper's figures.
#[derive(Clone, Debug, Default)]
pub struct BreakdownTable {
    pub rows: Vec<(String, Breakdown)>,
}

impl BreakdownTable {
    pub fn push(&mut self, label: &str, b: Breakdown) {
        self.rows.push((label.to_string(), b));
    }

    pub fn render(&self, unit_ns: f64, unit: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<10} {:>9} {:>9} {:>9} {:>9} {:>10} {:>10}\n",
            "config", "B-MLP", "T-MLP", "Transfer", "Embed", "Checkpoint", "TOTAL"
        ));
        for (label, b) in &self.rows {
            out.push_str(&format!(
                "{:<10} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>10.2} {:>10.2}  {unit}\n",
                label,
                b.bmlp / unit_ns,
                b.tmlp / unit_ns,
                b.transfer / unit_ns,
                b.embedding / unit_ns,
                b.checkpoint / unit_ns,
                b.total() / unit_ns,
            ));
        }
        out
    }
}

/// Render a fabric's per-link counters as a table — bytes, occupancy,
/// the utilization of the run wall that occupancy represents (matching
/// the `util_pct` scalars the serve/tenant reports carry), and the
/// degraded-mode share of that occupancy (the ns an edge spent running
/// on surviving lanes after a `LinkDown`). `wall_ns` is the run's wall
/// clock. Drives the `bench fault-sweep` body and the multi-tenant
/// link summaries.
pub fn render_links(links: &[(String, LinkStats)], wall_ns: SimTime) -> String {
    let wall = wall_ns.max(1) as f64;
    let mut out = String::new();
    out.push_str(&format!(
        "{:<18} {:>10} {:>12} {:>8} {:>13} {:>10}\n",
        "link", "GB", "busy ms", "util %", "degraded ms", "transfers"
    ));
    for (name, l) in links {
        out.push_str(&format!(
            "{:<18} {:>10.3} {:>12.3} {:>8.2} {:>13.3} {:>10}\n",
            name,
            l.bytes as f64 / (1u64 << 30) as f64,
            l.busy_ns as f64 / 1e6,
            100.0 * l.busy_ns as f64 / wall,
            l.degraded_ns as f64 / 1e6,
            l.transfers,
        ));
    }
    out
}

/// Byte counters per medium, fed to the energy model.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TrafficCounters {
    pub by_medium: BTreeMap<&'static str, (u64, u64)>, // (read, written)
    pub link_bytes: u64,
}

impl TrafficCounters {
    pub fn record(&mut self, medium: &'static str, read: u64, written: u64) {
        let e = self.by_medium.entry(medium).or_insert((0, 0));
        e.0 += read;
        e.1 += written;
    }

    pub fn record_link(&mut self, bytes: u64) {
        self.link_bytes += bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_merges_overlaps() {
        let mut log = SpanLog::default();
        log.add(Lane::Gpu, OpKind::BottomMlp, 0, 0, 100);
        log.add(Lane::Gpu, OpKind::TopMlp, 0, 50, 150);
        log.add(Lane::Gpu, OpKind::TopMlp, 0, 200, 300);
        assert_eq!(log.busy(Lane::Gpu, 0, 300), 150 + 100);
        assert!((log.utilization(Lane::Gpu, 0, 300) - 250.0 / 300.0).abs() < 1e-12);
        // clipped window
        assert_eq!(log.busy(Lane::Gpu, 100, 250), 50 + 50);
    }

    #[test]
    fn zero_length_spans_dropped() {
        let mut log = SpanLog::default();
        log.add(Lane::Pmem, OpKind::EmbLookup, 0, 5, 5);
        assert!(log.spans.is_empty());
    }

    #[test]
    fn breakdown_sums() {
        let b = Breakdown {
            bmlp: 1.0,
            tmlp: 2.0,
            transfer: 0.5,
            embedding: 3.0,
            checkpoint: 1.5,
        };
        assert!((b.total() - 8.0).abs() < 1e-12);
        assert!((b.sans_checkpoint() - 6.5).abs() < 1e-12);
        let mut acc = Breakdown::default();
        acc.add(&b);
        acc.add(&b);
        assert!((acc.scale(0.5).total() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn link_table_renders_degraded_share() {
        let links = vec![
            (
                "tenant-a-l1".to_string(),
                LinkStats {
                    bytes: 3 << 30,
                    busy_ns: 8_000_000,
                    degraded_ns: 2_000_000,
                    transfers: 12,
                },
            ),
            ("tenant-b-l1".to_string(), LinkStats::default()),
        ];
        let s = render_links(&links, 16_000_000);
        assert!(s.contains("degraded ms"), "{s}");
        assert!(s.contains("util %"), "{s}");
        assert!(s.contains("tenant-a-l1"), "{s}");
        assert!(s.contains("2.000"), "{s}: degraded share missing");
        assert!(s.contains("8.000"), "{s}: busy share missing");
        // 8 ms busy over a 16 ms wall
        assert!(s.contains("50.00"), "{s}: util % missing");
        assert_eq!(s.lines().count(), 3);

        // a zero wall clamps instead of dividing by zero
        let z = render_links(&links, 0);
        assert!(!z.contains("NaN") && !z.contains("inf"), "{z}");
    }

    #[test]
    fn timeline_renders_all_lanes() {
        let mut log = SpanLog::default();
        log.add(Lane::Gpu, OpKind::BottomMlp, 0, 0, 500);
        log.add(Lane::Pmem, OpKind::EmbLookup, 0, 0, 1000);
        let s = log.render_timeline(0, 1000, 40);
        assert!(s.contains("CXL-GPU"));
        assert!(s.contains('B'));
        assert!(s.contains('L'));
        assert!(s.lines().count() >= 7);
    }
}
