"""L2 model correctness: shapes, loss behaviour, kernel-vs-ref forward,
and the export surface the AOT path lowers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, modelcfg
from compile.kernels import ref

CFG = modelcfg.load("rm_mini")


def batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    dense = jnp.asarray(rng.normal(size=(cfg.batch_size, cfg.num_dense)), jnp.float32)
    idx = jnp.asarray(
        rng.integers(
            0,
            cfg.rows_per_table,
            size=(cfg.num_tables, cfg.batch_size, cfg.lookups_per_table),
        ),
        jnp.int32,
    )
    labels = jnp.asarray(rng.integers(0, 2, size=(cfg.batch_size,)), jnp.float32)
    return dense, idx, labels


def test_param_specs_layout():
    specs = model.param_specs(CFG)
    # bottom pairs + top pairs + table
    assert len(specs) == 2 * len(CFG.bottom_layers) + 2 * len(CFG.top_layers) + 1
    assert specs[-1][0] == "table"
    assert specs[0] == ("bot_w0", (13, 32))
    n = sum(int(np.prod(s)) for _, s in specs)
    assert n == CFG.param_count()


def test_forward_shapes_and_finite():
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    dense, idx, _ = batch(CFG)
    logits = model.forward(CFG, params, dense, idx)
    assert logits.shape == (CFG.batch_size,)
    assert bool(jnp.isfinite(logits).all())


def test_forward_matches_ref_pipeline():
    """Kernel-composed forward == oracle-composed forward."""
    params = model.init_params(CFG, jax.random.PRNGKey(1))
    dense, idx, _ = batch(CFG, 1)
    bot, top, table = model.split_params(CFG, params)

    x = dense
    for w, b in bot:
        x = jax.nn.relu(ref.matmul_bias(x, w, b))
    reduced = ref.embedding_bag(table, idx)
    z = jnp.concatenate([x, reduced.reshape(CFG.batch_size, -1)], axis=1)
    for i, (w, b) in enumerate(top):
        z = ref.matmul_bias(z, w, b)
        if i + 1 < len(top):
            z = jax.nn.relu(z)
    want = z[:, 0]

    got = model.forward(CFG, params, dense, idx)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_train_step_decreases_loss():
    params = model.init_params(CFG, jax.random.PRNGKey(2))
    dense, idx, labels = batch(CFG, 2)
    step = jax.jit(lambda p, d, i, l: model.train_step(CFG, p, d, i, l))
    losses = []
    for _ in range(20):
        *params, loss = step(params, dense, idx, labels)
        params = list(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.02, losses
    assert all(np.isfinite(losses))


def test_train_step_only_touched_rows_change():
    params = model.init_params(CFG, jax.random.PRNGKey(3))
    dense, idx, labels = batch(CFG, 3)
    out = model.train_step(CFG, params, dense, idx, labels)
    new_table = out[-2]
    old_table = params[-1]
    touched = np.zeros((CFG.num_tables, CFG.rows_per_table), bool)
    idx_np = np.asarray(idx)
    for t in range(CFG.num_tables):
        touched[t, np.unique(idx_np[t])] = True
    changed = np.any(np.asarray(new_table) != np.asarray(old_table), axis=-1)
    assert not np.any(changed & ~touched), "untouched rows must be bit-identical"


def test_bce_loss_reference_values():
    logits = jnp.asarray([0.0, 100.0, -100.0])
    labels = jnp.asarray([1.0, 1.0, 0.0])
    # log(2), ~0, ~0
    got = model.bce_loss(logits, labels)
    np.testing.assert_allclose(got, np.log(2.0) / 3, rtol=1e-5)


@pytest.mark.parametrize("what", model.EXPORTS)
def test_exports_trace(what):
    """Every AOT export must abstractly evaluate with its example inputs."""
    fn = model.export_fn(CFG, what)
    ins = model.example_inputs(CFG, what)
    outs = jax.eval_shape(fn, *ins)
    assert isinstance(outs, tuple) and outs
    if what == "train_step":
        n = len(model.param_specs(CFG))
        assert len(outs) == n + 1  # new params + loss
        for o, (_, s) in zip(outs, model.param_specs(CFG)):
            assert o.shape == s
        assert outs[-1].shape == ()


def test_export_forward_consistent_with_train_step_params():
    """forward() after k train steps must run on exactly the param list
    train_step emits (layout compatibility relied on by rust)."""
    params = model.init_params(CFG, jax.random.PRNGKey(4))
    dense, idx, labels = batch(CFG, 4)
    out = model.train_step(CFG, params, dense, idx, labels)
    new_params = list(out[:-1])
    logits = model.forward(CFG, new_params, dense, idx)
    assert logits.shape == (CFG.batch_size,)
