#!/usr/bin/env bash
# Regenerate the golden report fixtures (rust/tests/golden/*.json) and
# list what to commit. Run on a machine with a rust toolchain; see
# rust/tests/golden/README.md for when re-blessing is appropriate.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "!! cargo not found: the fixtures must be blessed where a rust toolchain exists" >&2
  exit 1
fi

GOLDEN_BLESS=1 cargo test --test golden_reports
echo
echo "== blessed fixtures (commit these to arm the GOLDEN_STRICT gate) =="
ls -l rust/tests/golden/*.json
echo
echo "  git add rust/tests/golden/*.json"
