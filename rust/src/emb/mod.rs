//! Embedding engine: the CXL-MEM *data region* — the authoritative host
//! image of the embedding tables used by the byte-accurate checkpointing
//! path ([`crate::checkpoint`]) and the failure-injection experiments.
//!
//! During real training the tables also live as PJRT device buffers; the
//! trainer keeps this store in sync (cheap at the artifact scales used for
//! recovery experiments) so that undo logs can capture pre-update row
//! values exactly as the paper's checkpointing logic does from PMEM.

use crate::config::ModelConfig;

/// Row-addressable embedding tables: `num_tables x rows x dim` f32.
#[derive(Clone, Debug, PartialEq)]
pub struct EmbeddingStore {
    pub num_tables: usize,
    pub rows: usize,
    pub dim: usize,
    data: Vec<f32>,
}

impl EmbeddingStore {
    pub fn zeros(cfg: &ModelConfig) -> EmbeddingStore {
        EmbeddingStore {
            num_tables: cfg.num_tables,
            rows: cfg.rows_per_table,
            dim: cfg.feature_dim,
            data: vec![0.0; cfg.num_tables * cfg.rows_per_table * cfg.feature_dim],
        }
    }

    pub fn from_flat(cfg: &ModelConfig, data: Vec<f32>) -> EmbeddingStore {
        assert_eq!(
            data.len(),
            cfg.num_tables * cfg.rows_per_table * cfg.feature_dim,
            "flat table size mismatch"
        );
        EmbeddingStore {
            num_tables: cfg.num_tables,
            rows: cfg.rows_per_table,
            dim: cfg.feature_dim,
            data,
        }
    }

    #[inline]
    fn offset(&self, table: usize, row: usize) -> usize {
        debug_assert!(table < self.num_tables && row < self.rows);
        (table * self.rows + row) * self.dim
    }

    pub fn row(&self, table: usize, row: usize) -> &[f32] {
        let o = self.offset(table, row);
        &self.data[o..o + self.dim]
    }

    pub fn row_mut(&mut self, table: usize, row: usize) -> &mut [f32] {
        let o = self.offset(table, row);
        &mut self.data[o..o + self.dim]
    }

    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Overwrite one row with device-fresh values — the incremental
    /// mirror-maintenance primitive: after an update, the trainer applies
    /// just the rows the batch touched instead of rebuilding the whole
    /// image from a full-table download.
    pub fn apply_row(&mut self, table: usize, row: usize, vals: &[f32]) {
        assert_eq!(vals.len(), self.dim, "row width mismatch");
        self.row_mut(table, row).copy_from_slice(vals);
    }

    /// Apply a batch of rows: `rows[i]`'s new values are
    /// `values[i*dim .. (i+1)*dim]` (concatenated row-major payload, e.g.
    /// a `gather_rows` download or an undo-log generation).
    pub fn apply_rows(&mut self, rows: &[(usize, usize)], values: &[f32]) {
        assert_eq!(
            values.len(),
            rows.len() * self.dim,
            "row payload size mismatch"
        );
        for (i, &(t, r)) in rows.iter().enumerate() {
            self.apply_row(t, r, &values[i * self.dim..(i + 1) * self.dim]);
        }
    }

    /// Distinct (table, row) pairs named by a `(T, B, L)` indices tensor.
    pub fn touched_rows(&self, indices: &[i32]) -> Vec<(usize, usize)> {
        let per_table = indices.len() / self.num_tables;
        let mut out: Vec<(usize, usize)> = Vec::new();
        for t in 0..self.num_tables {
            let mut rows: Vec<usize> = indices[t * per_table..(t + 1) * per_table]
                .iter()
                .map(|&r| r as usize)
                .collect();
            rows.sort_unstable();
            rows.dedup();
            out.extend(rows.into_iter().map(|r| (t, r)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    fn mini() -> ModelConfig {
        ModelConfig::load(&repo_root(), "rm_mini").unwrap()
    }

    #[test]
    fn row_addressing_round_trips() {
        let cfg = mini();
        let mut s = EmbeddingStore::zeros(&cfg);
        s.row_mut(2, 5).copy_from_slice(&[1.0; 8]);
        assert_eq!(s.row(2, 5), &[1.0; 8]);
        assert_eq!(s.row(2, 4), &[0.0; 8]);
        assert_eq!(s.row(3, 5), &[0.0; 8]);
        // flat layout is (table, row, dim)
        let o = (2 * cfg.rows_per_table + 5) * cfg.feature_dim;
        assert_eq!(&s.flat()[o..o + 8], &[1.0; 8]);
    }

    #[test]
    fn touched_rows_dedups_per_table() {
        let cfg = mini();
        let s = EmbeddingStore::zeros(&cfg);
        // T=4, B*L entries per table = batch*lookups = 128
        let mut idx = vec![0i32; cfg.num_tables * cfg.batch_size * cfg.lookups_per_table];
        idx[0] = 3;
        idx[1] = 3;
        idx[2] = 7;
        let touched = s.touched_rows(&idx);
        // table 0: {0, 3, 7}; tables 1-3: {0}
        assert_eq!(
            touched,
            vec![(0, 0), (0, 3), (0, 7), (1, 0), (2, 0), (3, 0)]
        );
    }

    #[test]
    fn apply_rows_overwrites_only_named_rows() {
        let cfg = mini();
        let mut s = EmbeddingStore::zeros(&cfg);
        let mut vals = vec![0.0; 2 * cfg.feature_dim];
        vals[..cfg.feature_dim].fill(2.0);
        vals[cfg.feature_dim..].fill(9.0);
        s.apply_rows(&[(1, 4), (3, 0)], &vals);
        assert_eq!(s.row(1, 4), &[2.0; 8]);
        assert_eq!(s.row(3, 0), &[9.0; 8]);
        assert_eq!(s.row(1, 5), &[0.0; 8]);
        assert_eq!(s.row(0, 4), &[0.0; 8]);
    }

    #[test]
    #[should_panic(expected = "row payload size mismatch")]
    fn apply_rows_checks_payload_size() {
        let cfg = mini();
        let mut s = EmbeddingStore::zeros(&cfg);
        s.apply_rows(&[(0, 0)], &[1.0]);
    }

    #[test]
    #[should_panic(expected = "flat table size mismatch")]
    fn from_flat_checks_size() {
        let cfg = mini();
        let _ = EmbeddingStore::from_flat(&cfg, vec![0.0; 3]);
    }
}
