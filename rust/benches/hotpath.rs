//! Bench: L3 hot paths — event queue, DCOH, Zipf workload generation,
//! the batch pipeline step, and (if artifacts exist) the real PJRT
//! training step. This is the §Perf profiling entry point.
//!
//! Run: `cargo bench --bench hotpath`

use trainingcxl::bench::bench_fn;
use trainingcxl::config::{DeviceParams, ModelConfig, SystemConfig};
use trainingcxl::devices::CxlGpu;
use trainingcxl::sched::PipelineSim;
use trainingcxl::sim::cxl::dcoh::AgentId;
use trainingcxl::sim::cxl::Dcoh;
use trainingcxl::sim::engine::EventQueue;
use trainingcxl::sim::topology::Topology;
use trainingcxl::train::Trainer;
use trainingcxl::workload::Generator;

fn main() -> anyhow::Result<()> {
    let root = trainingcxl::repo_root();
    let params = DeviceParams::load(&root)?;

    // ---- event queue: schedule+pop 10k events
    let r = bench_fn("event_queue 10k schedule+pop", 3, 50, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule((i * 7919) % 100_000, i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", r.render());
    println!(
        "  -> {:.1}M events/s (target >=1M/s)",
        2.0 * 10_000.0 / (r.mean_ns / 1e9) / 1e6
    );

    // ---- DCOH: produce+flush 64KB ranges
    let r = bench_fn("dcoh produce_and_flush 64KiB", 3, 100, || {
        let mut d = Dcoh::new();
        std::hint::black_box(d.produce_and_flush(AgentId(1), 0x1000, 65536));
    });
    println!("{}", r.render());

    // ---- workload generation (rm1 batch: 51k zipf draws)
    let cfg = ModelConfig::load(&root, "rm1")?;
    let mut gen = Generator::new(&cfg, 42);
    let r = bench_fn("workload rm1 batch (51k draws)", 2, 20, || {
        std::hint::black_box(gen.next_batch());
    });
    println!("{}", r.render());

    // ---- pipeline: one full simulated run
    let stats = Generator::average_stats(&cfg, 42, 4, 0.0);
    let gpu = CxlGpu::from_params(&cfg, &params, &root);
    let r = bench_fn("pipeline rm1/CXL 30 batches", 2, 20, || {
        let sim = PipelineSim::new(&cfg, SystemConfig::Cxl, &params, gpu, stats);
        std::hint::black_box(sim.run(30));
    });
    println!("{}", r.render());

    // ---- real training step (needs artifacts)
    if root.join("artifacts/rm_mini/manifest.json").exists() {
        let mini = ModelConfig::load(&root, "rm_mini")?;
        // DRAM-ideal topology: no checkpointing, pure step latency
        let mut t = Trainer::with_topology(
            &root,
            &mini,
            7,
            &Topology::from_system(SystemConfig::Dram),
        )?;
        let r = bench_fn("real train step rm_mini (PJRT)", 3, 30, || {
            t.step().unwrap();
        });
        println!("{}", r.render());

        // with the CXL topology: + undo log + incremental row-wise mirror
        let mut t = Trainer::with_topology(
            &root,
            &mini,
            7,
            &Topology::from_system(SystemConfig::Cxl),
        )?;
        let r = bench_fn("real train step rm_mini + batch-aware ckpt", 3, 30, || {
            t.step().unwrap();
        });
        println!("{}", r.render());
    } else {
        println!("(skipping PJRT step bench: run `make artifacts`)");
    }
    Ok(())
}
