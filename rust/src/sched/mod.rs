//! Batch pipeline scheduling — the paper's system contribution.
//!
//! [`stage`] holds the composable [`stage::Stage`] slices of a training
//! batch and [`stage::compose`], which selects a chain of them for a
//! [`crate::sim::topology::Topology`]. [`pipeline::PipelineSim`] runs a
//! composed chain for `n` batches, producing a [`pipeline::RunResult`]
//! with spans (Fig 12), critical-path breakdowns (Fig 11), and traffic
//! counters (Fig 13). The six paper configurations (SSD/PMEM/PCIe/CXL-D/
//! CXL-B/CXL) are just prebuilt topologies routed through the same
//! composition.

pub mod pipeline;
pub mod stage;

pub use pipeline::{PipelineSim, RunResult};
pub use stage::{BatchCtx, PipelineEnv, Stage};
