//! Workload generation: synthetic DLRM batches with Criteo-Kaggle-like
//! table-access skew.
//!
//! The paper generates random sparse features whose per-table access
//! distribution follows Criteo Kaggle ("we consider Criteo Kaggle's
//! embedding table access distribution when randomly generating sparse
//! feature input for RM1~3 to evaluate the RAW impact"), plus a
//! consecutive-batch overlap knob: Kwon & Rhu (2022) report ~80% of
//! embedding vectors are re-trained across adjacent batches. We reproduce
//! both: Zipf-ranked rows with a per-batch re-touch probability.
//!
//! Two consumers:
//! * the **timing simulator** uses [`BatchStats`] (unique rows, overlap
//!   fraction, cache-hit fraction) over the *logical* table size;
//! * the **real trainer** uses the concrete `indices` tensor over the
//!   *artifact* table size.

use crate::config::ModelConfig;
use crate::util::{Rng, Zipf};

/// One generated batch.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Sparse features: `(T, B, L)` flattened row ids, local per table.
    pub indices: Vec<i32>,
    /// Dense features: `(B, num_dense)` standard-normal values.
    pub dense: Vec<f32>,
    /// Binary labels `(B,)` correlated with the features (learnable).
    pub labels: Vec<f32>,
    pub stats: BatchStats,
    /// Per-table access counts (multi-GPU sharding stripes tables across
    /// GPU lanes; each lane's timing input sums its stripe's counts).
    pub table_stats: Vec<TableStats>,
}

/// Raw access counts of one embedding table in one batch. Counts (not
/// fractions) so striped aggregation over any table subset stays exact.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TableStats {
    /// Row accesses into this table (B*L).
    pub accesses: u64,
    /// Distinct rows touched.
    pub unique_rows: u64,
    /// Accesses touching rows the previous batch updated (RAW-exposed).
    pub overlap_hits: u64,
    /// Fresh Zipf draws landing in the host-DRAM cache's hot ranks.
    pub cache_hits: u64,
    /// Accesses the host-DRAM cache serves, counted at most ONCE per
    /// access: a fresh hot-rank draw that also re-touches a previous
    /// row is one resident hit, not two (`cache_hits + overlap_hits`
    /// double-counts exactly those accesses).
    pub cache_resident_hits: u64,
    /// Accesses landing on hot-media-tier rows (the hottest `hot_frac`
    /// Zipf ranks of a tiered topology).
    pub hot_tier_hits: u64,
    /// Hot-tier accesses that are also RAW-exposed to the previous
    /// batch (the cold tail keeps the remaining overlap).
    pub hot_tier_overlap_hits: u64,
    /// Distinct hot-tier rows touched (the hot-tier flush footprint).
    pub hot_tier_unique: u64,
}

/// Access statistics the timing model needs (computed on logical rows).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BatchStats {
    /// Total row accesses (T*B*L).
    pub accesses: u64,
    /// Distinct (table, row) pairs touched — the undo-log footprint.
    pub unique_rows: u64,
    /// Fraction of this batch's accesses touching rows also updated by the
    /// previous batch (RAW-exposed accesses).
    pub prev_overlap: f64,
    /// Fraction of accesses that would hit a host-DRAM cache holding the
    /// hottest `cache_rows` rows (SSD config).
    pub hot_hit_frac: f64,
    /// Accesses served by the volatile hot media tier (tiered-media
    /// topologies; 0 when untiered).
    pub hot_accesses: u64,
    /// Distinct hot-tier rows touched — the rows the hot-tier flush must
    /// capture durably each batch.
    pub hot_unique_rows: u64,
    /// Hot-tier accesses that were RAW-exposed; the cold tail carries
    /// `prev_overlap * accesses - hot_overlap_hits` of the exposure.
    pub hot_overlap_hits: u64,
}

impl BatchStats {
    /// Rescale the count fields by `num/den` (ceiling, at least 1 when the
    /// source count is nonzero), keeping the fractions untouched. The
    /// serving lanes use this to size a dynamic batch of `num` requests
    /// against stats generated at the training batch size `den`.
    pub fn scaled(&self, num: u64, den: u64) -> BatchStats {
        let den = den.max(1);
        let scale = |c: u64| {
            if c == 0 {
                0
            } else {
                (c * num).div_ceil(den).max(1)
            }
        };
        BatchStats {
            accesses: scale(self.accesses),
            unique_rows: scale(self.unique_rows),
            prev_overlap: self.prev_overlap,
            hot_hit_frac: self.hot_hit_frac,
            hot_accesses: scale(self.hot_accesses),
            hot_unique_rows: scale(self.hot_unique_rows),
            hot_overlap_hits: scale(self.hot_overlap_hits),
        }
    }
}

/// Deterministic batch stream for one model.
pub struct Generator {
    cfg: ModelConfig,
    rng: Rng,
    zipf: Zipf,
    logical_rows: u64,
    /// Rows (per table) counted as host-DRAM-cache resident (hottest ranks).
    cache_rows: u64,
    /// Rows (per table) held by the hot media tier (hottest Zipf ranks of
    /// a tiered topology); 0 when untiered.
    tier_rows: u64,
    /// Previous batch's touched logical rows, per table (sorted).
    prev_touched: Vec<Vec<u64>>,
    /// The hot-tier subset of `prev_touched` (sorted) — re-touched rows
    /// carry the previous batch's tier classification.
    prev_hot: Vec<Vec<u64>>,
    batch_no: u64,
}

impl Generator {
    pub fn new(cfg: &ModelConfig, seed: u64) -> Generator {
        let logical_rows = cfg.sim.logical_rows_per_table as u64;
        Generator {
            zipf: Zipf::new(logical_rows, cfg.sim.zipf_alpha),
            rng: Rng::new(seed ^ 0xC0DE_D00D),
            cache_rows: 0,
            tier_rows: 0,
            prev_touched: vec![Vec::new(); cfg.num_tables],
            prev_hot: vec![Vec::new(); cfg.num_tables],
            logical_rows,
            batch_no: 0,
            cfg: cfg.clone(),
        }
    }

    /// Configure the SSD config's host-DRAM vector cache size (fraction of
    /// logical rows).
    pub fn with_cache_frac(mut self, frac: f64) -> Self {
        self.cache_rows = (self.logical_rows as f64 * frac) as u64;
        self
    }

    /// Configure the hot media tier's size: the hottest `frac` of each
    /// table's Zipf ranks are classified hot. `0.0` (the default) leaves
    /// every statistic identical to an untiered generator.
    pub fn with_hot_tier_frac(mut self, frac: f64) -> Self {
        self.tier_rows = (self.logical_rows as f64 * frac) as u64;
        self
    }

    /// Map a Zipf rank to a logical row id (multiplicative-hash scatter, so
    /// hot rows are spread over the index space like real hashed features).
    #[inline]
    fn rank_to_row(&self, rank: u64) -> u64 {
        rank.wrapping_mul(0x9E37_79B9_7F4A_7C15) % self.logical_rows
    }

    pub fn batches_generated(&self) -> u64 {
        self.batch_no
    }

    /// Generate the next batch. Row ids in `indices` are folded onto the
    /// artifact's physical `rows_per_table`; statistics are computed on
    /// logical rows.
    pub fn next_batch(&mut self) -> Batch {
        let cfg = self.cfg.clone();
        let (t_n, b_n, l_n) = (cfg.num_tables, cfg.batch_size, cfg.lookups_per_table);
        let mut indices = Vec::with_capacity(t_n * b_n * l_n);
        let mut touched: Vec<Vec<u64>> = vec![Vec::new(); t_n];
        let mut hot_touched: Vec<Vec<u64>> = vec![Vec::new(); t_n];
        let mut overlap_hits = 0u64;
        let mut resident_hits = 0u64;
        let accesses = (t_n * b_n * l_n) as u64;

        let mut table_stats: Vec<TableStats> = vec![TableStats::default(); t_n];
        for t in 0..t_n {
            let prev = std::mem::take(&mut self.prev_touched[t]);
            let prev_hot = std::mem::take(&mut self.prev_hot[t]);
            table_stats[t].accesses = (b_n * l_n) as u64;
            for _ in 0..b_n {
                for _ in 0..l_n {
                    // With probability `consecutive_batch_overlap`, re-touch a
                    // row from the previous batch (temporal locality across
                    // batches); otherwise draw fresh from the Zipf.
                    let (row, fresh_rank) = if !prev.is_empty()
                        && self.rng.next_f64() < cfg.sim.consecutive_batch_overlap
                    {
                        (prev[self.rng.gen_range(prev.len() as u64) as usize], None)
                    } else {
                        let rank = self.zipf.sample(&mut self.rng);
                        (self.rank_to_row(rank), Some(rank))
                    };
                    let overlap = prev.binary_search(&row).is_ok();
                    let fresh_cache_hit = fresh_rank.is_some_and(|r| r < self.cache_rows);
                    // Hot-tier membership: by rank for fresh draws;
                    // re-touched rows carry last batch's classification.
                    let hot = match fresh_rank {
                        Some(rank) => rank < self.tier_rows,
                        None => prev_hot.binary_search(&row).is_ok(),
                    };
                    if fresh_cache_hit {
                        table_stats[t].cache_hits += 1;
                    }
                    if overlap {
                        overlap_hits += 1;
                        table_stats[t].overlap_hits += 1;
                    }
                    // Cache residency: fresh hot-rank draws and re-touched
                    // rows (resident after their first access) — each
                    // access is at most ONE hit, even when it is both.
                    if fresh_cache_hit || overlap {
                        resident_hits += 1;
                        table_stats[t].cache_resident_hits += 1;
                    }
                    if hot {
                        table_stats[t].hot_tier_hits += 1;
                        if overlap {
                            table_stats[t].hot_tier_overlap_hits += 1;
                        }
                        hot_touched[t].push(row);
                    }
                    touched[t].push(row);
                    indices.push((row % cfg.rows_per_table as u64) as i32);
                }
            }
        }

        let mut unique_rows = 0u64;
        let mut hot_unique_rows = 0u64;
        for (t, rows) in touched.iter_mut().enumerate() {
            rows.sort_unstable();
            rows.dedup();
            unique_rows += rows.len() as u64;
            table_stats[t].unique_rows = rows.len() as u64;
            let hot = &mut hot_touched[t];
            hot.sort_unstable();
            hot.dedup();
            hot_unique_rows += hot.len() as u64;
            table_stats[t].hot_tier_unique = hot.len() as u64;
        }
        let hot_hit_frac = if self.cache_rows > 0 {
            resident_hits as f64 / accesses as f64
        } else {
            0.0
        };
        self.prev_touched = touched;
        self.prev_hot = hot_touched;
        self.batch_no += 1;

        let dense: Vec<f32> = (0..b_n * cfg.num_dense)
            .map(|_| self.rng.next_normal() as f32)
            .collect();
        // Learnable labels: logistic of a fixed random projection of the
        // dense features (so the e2e example's loss actually falls).
        let mut wrng = Rng::new(0xFEED_FACE);
        let w: Vec<f32> = (0..cfg.num_dense)
            .map(|_| wrng.next_normal() as f32)
            .collect();
        let labels: Vec<f32> = (0..b_n)
            .map(|b| {
                let z: f32 = (0..cfg.num_dense)
                    .map(|j| dense[b * cfg.num_dense + j] * w[j])
                    .sum();
                let p = 1.0 / (1.0 + (-z).exp());
                if self.rng.next_f32() < p {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();

        Batch {
            indices,
            dense,
            labels,
            stats: BatchStats {
                accesses,
                unique_rows,
                prev_overlap: overlap_hits as f64 / accesses as f64,
                hot_hit_frac,
                hot_accesses: table_stats.iter().map(|t| t.hot_tier_hits).sum(),
                hot_unique_rows,
                hot_overlap_hits: table_stats.iter().map(|t| t.hot_tier_overlap_hits).sum(),
            },
            table_stats,
        }
    }

    /// Average [`BatchStats`] over `n` warm batches (timing-model input).
    pub fn average_stats(cfg: &ModelConfig, seed: u64, n: u64, cache_frac: f64) -> BatchStats {
        Generator::average_stats_tiered(cfg, seed, n, cache_frac, 0.0)
    }

    /// [`Generator::average_stats`] with a hot media tier holding the
    /// hottest `hot_tier_frac` Zipf ranks. `hot_tier_frac == 0.0` is
    /// bit-identical to the untiered statistics.
    pub fn average_stats_tiered(
        cfg: &ModelConfig,
        seed: u64,
        n: u64,
        cache_frac: f64,
        hot_tier_frac: f64,
    ) -> BatchStats {
        let mut g = Generator::new(cfg, seed)
            .with_cache_frac(cache_frac)
            .with_hot_tier_frac(hot_tier_frac);
        // warm one batch so overlap statistics are steady-state
        let _ = g.next_batch();
        let mut acc = BatchStats::default();
        for _ in 0..n {
            let s = g.next_batch().stats;
            acc.accesses += s.accesses;
            acc.unique_rows += s.unique_rows;
            acc.prev_overlap += s.prev_overlap;
            acc.hot_hit_frac += s.hot_hit_frac;
            acc.hot_accesses += s.hot_accesses;
            acc.hot_unique_rows += s.hot_unique_rows;
            acc.hot_overlap_hits += s.hot_overlap_hits;
        }
        BatchStats {
            accesses: acc.accesses / n,
            unique_rows: acc.unique_rows / n,
            prev_overlap: acc.prev_overlap / n as f64,
            hot_hit_frac: acc.hot_hit_frac / n as f64,
            hot_accesses: acc.hot_accesses / n,
            hot_unique_rows: acc.hot_unique_rows / n,
            hot_overlap_hits: acc.hot_overlap_hits / n,
        }
    }

    /// Stripe one batch's per-table counts round-robin over `shards` GPU
    /// lanes (table `t` belongs to lane `t % shards`) and fold each
    /// lane's stripe into a [`BatchStats`]. With `shards == 1` this is
    /// exactly `[batch.stats]`.
    pub fn shard_stats(&self, batch: &Batch, shards: usize) -> Vec<BatchStats> {
        stripe_stats(&batch.table_stats, shards, self.cache_rows > 0)
    }

    /// Per-shard average [`BatchStats`] over `n` warm batches — the
    /// timing input of each GPU lane of a sharded topology. The element
    /// for shard `s` covers the tables with `t % shards == s`;
    /// `sharded_average_stats(.., 1)` equals `[average_stats(..)]`.
    pub fn sharded_average_stats(
        cfg: &ModelConfig,
        seed: u64,
        n: u64,
        cache_frac: f64,
        shards: usize,
    ) -> Vec<BatchStats> {
        Generator::sharded_average_stats_tiered(cfg, seed, n, cache_frac, 0.0, shards)
    }

    /// [`Generator::sharded_average_stats`] with a hot media tier holding
    /// the hottest `hot_tier_frac` Zipf ranks of every table.
    pub fn sharded_average_stats_tiered(
        cfg: &ModelConfig,
        seed: u64,
        n: u64,
        cache_frac: f64,
        hot_tier_frac: f64,
        shards: usize,
    ) -> Vec<BatchStats> {
        let mut g = Generator::new(cfg, seed)
            .with_cache_frac(cache_frac)
            .with_hot_tier_frac(hot_tier_frac);
        // warm one batch so overlap statistics are steady-state
        let _ = g.next_batch();
        let mut acc = vec![BatchStats::default(); shards];
        for _ in 0..n {
            let b = g.next_batch();
            for (a, s) in acc.iter_mut().zip(g.shard_stats(&b, shards)) {
                a.accesses += s.accesses;
                a.unique_rows += s.unique_rows;
                a.prev_overlap += s.prev_overlap;
                a.hot_hit_frac += s.hot_hit_frac;
                a.hot_accesses += s.hot_accesses;
                a.hot_unique_rows += s.hot_unique_rows;
                a.hot_overlap_hits += s.hot_overlap_hits;
            }
        }
        acc.into_iter()
            .map(|a| BatchStats {
                accesses: a.accesses / n,
                unique_rows: a.unique_rows / n,
                prev_overlap: a.prev_overlap / n as f64,
                hot_hit_frac: a.hot_hit_frac / n as f64,
                hot_accesses: a.hot_accesses / n,
                hot_unique_rows: a.hot_unique_rows / n,
                hot_overlap_hits: a.hot_overlap_hits / n,
            })
            .collect()
    }
}

/// Fold per-table counts into per-shard [`BatchStats`] (round-robin table
/// striping, the same derivation `Generator::next_batch` applies globally).
fn stripe_stats(table_stats: &[TableStats], shards: usize, cached: bool) -> Vec<BatchStats> {
    let mut counts = vec![TableStats::default(); shards];
    for (t, ts) in table_stats.iter().enumerate() {
        let c = &mut counts[t % shards];
        c.accesses += ts.accesses;
        c.unique_rows += ts.unique_rows;
        c.overlap_hits += ts.overlap_hits;
        c.cache_hits += ts.cache_hits;
        c.cache_resident_hits += ts.cache_resident_hits;
        c.hot_tier_hits += ts.hot_tier_hits;
        c.hot_tier_overlap_hits += ts.hot_tier_overlap_hits;
        c.hot_tier_unique += ts.hot_tier_unique;
    }
    counts
        .into_iter()
        .map(|c| BatchStats {
            accesses: c.accesses,
            unique_rows: c.unique_rows,
            prev_overlap: if c.accesses > 0 {
                c.overlap_hits as f64 / c.accesses as f64
            } else {
                0.0
            },
            // distinct resident hits per access: no double count, no clamp
            hot_hit_frac: if cached && c.accesses > 0 {
                c.cache_resident_hits as f64 / c.accesses as f64
            } else {
                0.0
            },
            hot_accesses: c.hot_tier_hits,
            hot_unique_rows: c.hot_tier_unique,
            hot_overlap_hits: c.hot_tier_overlap_hits,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    fn mini() -> ModelConfig {
        ModelConfig::load(&repo_root(), "rm_mini").unwrap()
    }

    #[test]
    fn shapes_and_bounds() {
        let cfg = mini();
        let mut g = Generator::new(&cfg, 1);
        let b = g.next_batch();
        assert_eq!(
            b.indices.len(),
            cfg.num_tables * cfg.batch_size * cfg.lookups_per_table
        );
        assert_eq!(b.dense.len(), cfg.batch_size * cfg.num_dense);
        assert_eq!(b.labels.len(), cfg.batch_size);
        assert!(b
            .indices
            .iter()
            .all(|&i| (0..cfg.rows_per_table as i32).contains(&i)));
        assert!(b.labels.iter().all(|&l| l == 0.0 || l == 1.0));
        assert_eq!(b.stats.accesses, cfg.lookups_per_batch());
        assert!(b.stats.unique_rows <= b.stats.accesses);
        assert!(b.stats.unique_rows > 0);
    }

    #[test]
    fn determinism() {
        let cfg = mini();
        let a = Generator::new(&cfg, 7).next_batch();
        let b = Generator::new(&cfg, 7).next_batch();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.dense, b.dense);
        let c = Generator::new(&cfg, 8).next_batch();
        assert_ne!(a.indices, c.indices);
    }

    #[test]
    fn consecutive_overlap_tracks_config() {
        let cfg = mini();
        let mut g = Generator::new(&cfg, 3);
        let _ = g.next_batch(); // warm
        let mut overlap = 0.0;
        for _ in 0..20 {
            overlap += g.next_batch().stats.prev_overlap;
        }
        overlap /= 20.0;
        // configured 0.8: re-touched rows are definitionally overlapping,
        // fresh zipf draws add a little more
        assert!(
            (0.7..=0.95).contains(&overlap),
            "overlap {overlap} vs cfg {}",
            cfg.sim.consecutive_batch_overlap
        );
    }

    #[test]
    fn zipf_cache_hits_meaningful() {
        let cfg = mini();
        // 2% of rows cached should catch far more than 2% of accesses
        let s = Generator::average_stats(&cfg, 5, 10, 0.02);
        assert!(s.hot_hit_frac > 0.1, "hit frac {}", s.hot_hit_frac);
    }

    #[test]
    fn table_stats_counts_sum_to_batch_stats() {
        let cfg = mini();
        let mut g = Generator::new(&cfg, 9).with_cache_frac(0.05);
        let _ = g.next_batch(); // warm so overlap counts are non-trivial
        let b = g.next_batch();
        assert_eq!(b.table_stats.len(), cfg.num_tables);
        let accesses: u64 = b.table_stats.iter().map(|t| t.accesses).sum();
        let unique: u64 = b.table_stats.iter().map(|t| t.unique_rows).sum();
        let overlap: u64 = b.table_stats.iter().map(|t| t.overlap_hits).sum();
        assert_eq!(accesses, b.stats.accesses);
        assert_eq!(unique, b.stats.unique_rows);
        assert_eq!(overlap as f64 / accesses as f64, b.stats.prev_overlap);
        assert!(overlap > 0, "warm batch must observe overlap");
    }

    #[test]
    fn shard_striping_partitions_the_batch() {
        let cfg = mini(); // 4 tables
        let mut g = Generator::new(&cfg, 5);
        let _ = g.next_batch();
        let b = g.next_batch();
        let shards = g.shard_stats(&b, 2);
        assert_eq!(shards.len(), 2);
        // round-robin over 4 equal-sized tables: each lane sees half
        assert_eq!(shards[0].accesses, b.stats.accesses / 2);
        assert_eq!(shards[1].accesses, b.stats.accesses / 2);
        assert_eq!(
            shards[0].unique_rows + shards[1].unique_rows,
            b.stats.unique_rows
        );
        // more shards than tables: the tail lanes are legitimately empty
        let wide = g.shard_stats(&b, 8);
        assert_eq!(wide.iter().map(|s| s.accesses).sum::<u64>(), b.stats.accesses);
        assert_eq!(wide[5].accesses, 0);
        assert_eq!(wide[5].prev_overlap, 0.0);
    }

    #[test]
    fn one_shard_equals_global_average_stats() {
        let cfg = mini();
        for cache in [0.0, 0.05] {
            let global = Generator::average_stats(&cfg, 42, 8, cache);
            let sharded = Generator::sharded_average_stats(&cfg, 42, 8, cache, 1);
            assert_eq!(sharded.len(), 1);
            assert_eq!(sharded[0], global, "cache {cache}");
        }
    }

    #[test]
    fn cache_resident_hits_are_distinct_not_double_counted() {
        // Regression for the hot-set overlap clamp: a fresh hot-rank draw
        // whose row was also touched by the previous batch used to count
        // as BOTH a zipf cache hit and an overlap hit before the clamp.
        // With a warm 50% cache such accesses are common, so the distinct
        // count must come out strictly below the naive sum.
        let cfg = mini();
        let mut double_counted = 0u64;
        for seed in 0..20 {
            let mut g = Generator::new(&cfg, seed).with_cache_frac(0.5);
            let _ = g.next_batch(); // warm
            let b = g.next_batch();
            let mut resident = 0u64;
            for ts in &b.table_stats {
                assert!(ts.cache_resident_hits <= ts.accesses, "seed {seed}");
                assert!(ts.cache_resident_hits >= ts.overlap_hits, "seed {seed}");
                assert!(
                    ts.cache_resident_hits <= ts.cache_hits + ts.overlap_hits,
                    "seed {seed}"
                );
                double_counted += ts.cache_hits + ts.overlap_hits - ts.cache_resident_hits;
                resident += ts.cache_resident_hits;
            }
            // the batch fraction is the distinct count, in [0, 1] exactly
            let want = resident as f64 / b.stats.accesses as f64;
            assert!((b.stats.hot_hit_frac - want).abs() < 1e-12, "seed {seed}");
            assert!((0.0..=1.0).contains(&b.stats.hot_hit_frac), "seed {seed}");
        }
        assert!(
            double_counted > 0,
            "no fresh-hot-and-overlap access observed: regression scenario lost"
        );
    }

    #[test]
    fn hot_tier_classification_tracks_zipf_head() {
        let cfg = mini();
        let mut g = Generator::new(&cfg, 13).with_hot_tier_frac(0.25);
        let _ = g.next_batch(); // warm: re-touches carry classification
        let b = g.next_batch();
        let s = b.stats;
        // Zipf skew concentrates accesses in the head: the hottest 25% of
        // ranks must serve well over 25% of the accesses
        assert!(s.hot_accesses > s.accesses / 4, "{s:?}");
        assert!(s.hot_accesses <= s.accesses);
        assert!(s.hot_unique_rows <= s.unique_rows);
        assert!(s.hot_overlap_hits <= s.hot_accesses);
        // per-table counts sum to the batch aggregates
        assert_eq!(
            s.hot_accesses,
            b.table_stats.iter().map(|t| t.hot_tier_hits).sum::<u64>()
        );
        assert_eq!(
            s.hot_unique_rows,
            b.table_stats.iter().map(|t| t.hot_tier_unique).sum::<u64>()
        );
    }

    #[test]
    fn zero_hot_tier_frac_changes_nothing() {
        let cfg = mini();
        let mut plain = Generator::new(&cfg, 21);
        let mut tiered = Generator::new(&cfg, 21).with_hot_tier_frac(0.0);
        for _ in 0..3 {
            let a = plain.next_batch();
            let b = tiered.next_batch();
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.stats, b.stats);
            assert_eq!(b.stats.hot_accesses, 0);
            assert_eq!(b.stats.hot_unique_rows, 0);
        }
        // full tier: everything is hot
        let mut all = Generator::new(&cfg, 21).with_hot_tier_frac(1.0);
        let _ = all.next_batch();
        let b = all.next_batch();
        assert_eq!(b.stats.hot_accesses, b.stats.accesses);
        assert_eq!(b.stats.hot_unique_rows, b.stats.unique_rows);
    }

    #[test]
    fn labels_are_learnable_signal() {
        // labels correlate with dense features through the fixed projection
        let cfg = mini();
        let mut g = Generator::new(&cfg, 11);
        let mut w = Rng::new(0xFEED_FACE);
        let proj: Vec<f32> = (0..cfg.num_dense).map(|_| w.next_normal() as f32).collect();
        let (mut pos, mut n_pos, mut neg, mut n_neg) = (0.0f64, 0u32, 0.0f64, 0u32);
        for _ in 0..10 {
            let b = g.next_batch();
            for s in 0..cfg.batch_size {
                let z: f32 = (0..cfg.num_dense)
                    .map(|j| b.dense[s * cfg.num_dense + j] * proj[j])
                    .sum();
                if b.labels[s] > 0.5 {
                    pos += z as f64;
                    n_pos += 1;
                } else {
                    neg += z as f64;
                    n_neg += 1;
                }
            }
        }
        assert!(pos / n_pos as f64 > neg / n_neg as f64 + 0.3);
    }
}
