//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client — the only place compute numerics happen at run time.
//! Python is never on this path (paper: the host only runs coordination
//! software; all tensor math is in compiled executables).
//!
//! Interchange is HLO *text*: jax >= 0.5 emits serialized protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Training state lives in device-side [`xla::PjRtBuffer`]s between steps
//! (`execute_b`), so the ~400 MB rm_e2e table is never copied through the
//! host on the hot path.

pub mod manifest;

pub use manifest::{ExportSpec, Manifest, TensorSpec};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A loaded model: PJRT client + compiled executables by export name.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

/// Host-side tensor handed to / received from the runtime.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(v, _) => v,
            _ => panic!("expected f32 tensor"),
        }
    }
}

impl ModelRuntime {
    /// Load `<root>/artifacts/<model>` and compile the given exports
    /// (compiling up front keeps the request path compilation-free).
    pub fn load(root: &Path, model: &str, exports: &[&str]) -> anyhow::Result<ModelRuntime> {
        let dir = Self::model_dir(root, model);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = BTreeMap::new();
        for &name in exports {
            let spec = manifest
                .exports
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("model {model} has no export '{name}'"))?;
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            exes.insert(name.to_string(), client.compile(&comp)?);
        }
        Ok(ModelRuntime {
            manifest,
            client,
            exes,
        })
    }

    pub fn model_dir(root: &Path, model: &str) -> PathBuf {
        root.join("artifacts").join(model)
    }

    /// Upload a host tensor to a device buffer.
    pub fn to_device(&self, t: &HostTensor) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(match t {
            HostTensor::F32(v, s) => self.client.buffer_from_host_buffer(v, s, None)?,
            HostTensor::I32(v, s) => self.client.buffer_from_host_buffer(v, s, None)?,
        })
    }

    /// Execute export `name` on device buffers; outputs stay on device.
    /// The lowered functions return one tuple (return_tuple=True), which
    /// PJRT untuples into per-output buffers.
    pub fn run_b(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<xla::PjRtBuffer>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("export '{name}' not compiled"))?;
        let spec = &self.manifest.exports[name];
        anyhow::ensure!(
            args.len() == spec.inputs.len(),
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            args.len()
        );
        let mut out = exe.execute_b(args)?;
        let replica = out.swap_remove(0);
        anyhow::ensure!(
            replica.len() == spec.outputs.len() || (replica.len() == 1 && spec.outputs.len() > 1),
            "{name}: expected {} outputs (or 1 tuple), got {}",
            spec.outputs.len(),
            replica.len()
        );
        Ok(replica)
    }

    /// Execute a *multi-output* export and bring every output to the host.
    ///
    /// Multi-output exports lower to a tuple root, which PJRT returns as a
    /// single tuple buffer; it is downloaded once and decomposed here. By
    /// design only the small MLP-side exports are multi-output (the table
    /// never crosses the host boundary — the paper's CXL-MEM/CXL-GPU split).
    pub fn run_to_host(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let outs = self.run_b(name, args)?;
        let spec = &self.manifest.exports[name];
        if outs.len() == 1 && spec.outputs.len() > 1 {
            // one tuple buffer: download + decompose
            let mut lit = outs[0].to_literal_sync()?;
            let parts = lit.decompose_tuple()?;
            anyhow::ensure!(parts.len() == spec.outputs.len(), "tuple arity mismatch");
            return parts.iter().map(|l| Ok(l.to_vec::<f32>()?)).collect();
        }
        outs.iter().map(|b| self.to_host_f32(b)).collect()
    }

    /// Download a device buffer to the host as f32.
    pub fn to_host_f32(&self, buf: &xla::PjRtBuffer) -> anyhow::Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Scalar convenience (loss values).
    pub fn to_host_scalar(&self, buf: &xla::PjRtBuffer) -> anyhow::Result<f32> {
        let lit = buf.to_literal_sync()?;
        Ok(lit.get_first_element::<f32>()?)
    }

    pub fn export_spec(&self, name: &str) -> &ExportSpec {
        &self.manifest.exports[name]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    fn have_artifacts() -> bool {
        repo_root().join("artifacts/rm_mini/manifest.json").exists()
    }

    #[test]
    fn untuple_smoke_embedding_bag() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let root = repo_root();
        let rt = ModelRuntime::load(&root, "rm_mini", &["embedding_bag"]).unwrap();
        let spec = rt.export_spec("embedding_bag").clone();
        let tdims = spec.inputs[0].shape.clone(); // (T, R, D)
        let idims = spec.inputs[1].shape.clone(); // (T, B, L)
        let (t_n, r_n, d_n) = (tdims[0], tdims[1], tdims[2]);
        let mut table = vec![0f32; t_n * r_n * d_n];
        for t in 0..t_n {
            for r in 0..r_n {
                for d in 0..d_n {
                    table[(t * r_n + r) * d_n + d] = r as f32;
                }
            }
        }
        let idx = vec![3i32; idims.iter().product()];
        let tb = rt.to_device(&HostTensor::F32(table, tdims)).unwrap();
        let ib = rt.to_device(&HostTensor::I32(idx, idims.clone())).unwrap();
        let out = rt.run_b("embedding_bag", &[&tb, &ib]).unwrap();
        assert_eq!(out.len(), 1);
        let host = rt.to_host_f32(&out[0]).unwrap();
        // every reduced vector element = L * 3
        let l_n = idims[2];
        assert!(
            host.iter().all(|&v| v == (l_n * 3) as f32),
            "{:?}",
            &host[..4]
        );
    }
}
