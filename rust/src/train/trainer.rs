//! The trainer: owns device-resident state, drives batches through the
//! AOT executables, and (optionally) maintains the byte-accurate
//! batch-aware checkpoint of the paper.
//!
//! Checkpointing behaviour is not free-floating configuration: it derives
//! from the fabric's [`CkptMode`] via [`CkptOptions::from_topology`], so
//! the real trainer and the simulated schedules
//! ([`crate::sched::stage`]) describe the same machine. The host mirror
//! of the embedding table is maintained *incrementally* — after each
//! update only the batch's touched rows are downloaded (`gather_rows`);
//! the full table never crosses the host boundary on the per-step path.

use crate::checkpoint::LogRegion;
use crate::config::sysconfig::CkptMode;
use crate::config::ModelConfig;
use crate::emb::EmbeddingStore;
use crate::runtime::{HostTensor, ModelRuntime};
use crate::sim::topology::Topology;
use crate::util::Rng;
use crate::workload::{Batch, Generator};
use std::path::Path;

/// Checkpointing behaviour of the trainer, derived from a fabric
/// [`Topology`] by [`CkptOptions::from_topology`] (construct values
/// directly only in tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CkptOptions {
    /// Take an embedding undo-log every batch (the paper's invariant).
    pub emb_every_batch: bool,
    /// MLP snapshot cadence in batches (1 = every batch; Fig 9a sweeps
    /// this gap).
    pub mlp_every: u64,
    /// Batches an MLP snapshot streams across before sealing (the relaxed
    /// per-batch byte budget is `total / mlp_stream_batches`). 1 = the
    /// snapshot is begun and sealed synchronously in its own batch.
    pub mlp_stream_batches: u64,
}

impl Default for CkptOptions {
    fn default() -> Self {
        CkptOptions {
            emb_every_batch: true,
            mlp_every: 1,
            mlp_stream_batches: 1,
        }
    }
}

impl CkptOptions {
    /// THE `Topology -> CkptOptions` derivation (ROADMAP "real-training
    /// parity"): logging behaviour comes from the fabric's [`CkptMode`]
    /// and `max_mlp_log_gap`, mirroring the simulator's checkpoint tails:
    ///
    /// | `CkptMode`   | emb log     | MLP snapshot          | streaming          |
    /// |--------------|-------------|-----------------------|--------------------|
    /// | `None`       | off — no mirror, no log region                           |
    /// | `Redo`       | every batch | every batch           | synchronous        |
    /// | `BatchAware` | every batch | every batch           | synchronous        |
    /// | `Relaxed`    | every batch | every `max_mlp_log_gap` | across the window |
    pub fn from_topology(t: &Topology) -> Option<CkptOptions> {
        match t.ckpt {
            CkptMode::None => None,
            CkptMode::Redo | CkptMode::BatchAware => Some(CkptOptions::default()),
            CkptMode::Relaxed => {
                let window = t.max_mlp_log_gap.max(1);
                Some(CkptOptions {
                    emb_every_batch: true,
                    mlp_every: window,
                    mlp_stream_batches: window,
                })
            }
        }
    }
}

/// Per-step outputs.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    pub batch: u64,
    pub loss: f32,
}

/// Real trainer over the AOT artifacts.
pub struct Trainer {
    pub cfg: ModelConfig,
    rt: ModelRuntime,
    gen: Generator,
    /// Device-resident embedding table (T, R, D) — never downloaded on the
    /// hot path.
    table: xla::PjRtBuffer,
    /// Small MLP parameters: host copy + device buffers (re-uploaded per
    /// step after SGD).
    mlp_host: Vec<Vec<f32>>,
    mlp_shapes: Vec<Vec<usize>>,
    mlp_bufs: Vec<xla::PjRtBuffer>,
    /// Host mirror of the table, maintained row-wise from each batch's
    /// touched rows when checkpointing is on.
    pub store: Option<EmbeddingStore>,
    pub log: Option<LogRegion>,
    pub ckpt: CkptOptions,
    step_no: u64,
}

impl Trainer {
    /// Exports the trainer needs compiled. `gather_rows` (the incremental
    /// mirror readout) is only loaded when checkpointing is on, so
    /// artifact sets built before it existed keep working for
    /// non-checkpointed runs.
    pub const EXPORTS: [&'static str; 5] = [
        "embedding_bag",
        "mlp_step",
        "embedding_update",
        "gather_rows",
        "forward",
    ];
    const BASE_EXPORTS: [&'static str; 4] =
        ["embedding_bag", "mlp_step", "embedding_update", "forward"];

    /// Construct the trainer from a fabric [`Topology`]: checkpointing
    /// derives from its `CkptMode` + `max_mlp_log_gap` — the production
    /// entry point (prefer this over passing [`CkptOptions`] by hand).
    pub fn with_topology(
        root: &Path,
        cfg: &ModelConfig,
        seed: u64,
        topo: &Topology,
    ) -> anyhow::Result<Trainer> {
        Trainer::new(root, cfg, seed, CkptOptions::from_topology(topo))
    }

    pub fn new(
        root: &Path,
        cfg: &ModelConfig,
        seed: u64,
        ckpt: Option<CkptOptions>,
    ) -> anyhow::Result<Trainer> {
        let exports: &[&str] = if ckpt.is_some() {
            &Self::EXPORTS
        } else {
            &Self::BASE_EXPORTS
        };
        let rt = ModelRuntime::load(root, &cfg.name, exports)?;
        let mut rng = Rng::new(seed);

        // Xavier-uniform init, same layout as the manifest's param list.
        let mut mlp_host = Vec::new();
        let mut mlp_shapes = Vec::new();
        let mut table_host: Vec<f32> = Vec::new();
        for (name, shape) in &rt.manifest.params {
            let n: usize = shape.iter().product();
            if name == "table" {
                table_host = (0..n).map(|_| (rng.next_f32() - 0.5) * 0.1).collect();
            } else if name.contains("_w") {
                let limit = (6.0 / (shape[0] + shape[1]) as f32).sqrt();
                mlp_host.push((0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * limit).collect());
                mlp_shapes.push(shape.clone());
            } else {
                mlp_host.push(vec![0.0; n]);
                mlp_shapes.push(shape.clone());
            }
        }
        let table_shape = rt.manifest.param_shape("table")?.to_vec();
        let table = rt.to_device(&HostTensor::F32(table_host.clone(), table_shape))?;
        let mlp_bufs = mlp_host
            .iter()
            .zip(&mlp_shapes)
            .map(|(v, s)| rt.to_device(&HostTensor::F32(v.clone(), s.clone())))
            .collect::<anyhow::Result<Vec<_>>>()?;

        let (store, log) = if ckpt.is_some() {
            (
                Some(EmbeddingStore::from_flat(cfg, table_host)),
                Some(LogRegion::new()),
            )
        } else {
            (None, None)
        };

        Ok(Trainer {
            cfg: cfg.clone(),
            rt,
            gen: Generator::new(cfg, seed ^ 0xBA7C4),
            table,
            mlp_host,
            mlp_shapes,
            mlp_bufs,
            store,
            log,
            ckpt: ckpt.unwrap_or_default(),
            step_no: 0,
        })
    }

    pub fn step_no(&self) -> u64 {
        self.step_no
    }

    pub fn mlp_params(&self) -> &[Vec<f32>] {
        &self.mlp_host
    }

    /// Full device->host table download — verification and recovery
    /// tooling ONLY. The per-step path never does this: the mirror is
    /// maintained row-wise from the batch's touched rows.
    pub fn download_table(&self) -> anyhow::Result<Vec<f32>> {
        self.rt.to_host_f32(&self.table)
    }

    fn idx_shape(&self) -> Vec<usize> {
        vec![
            self.cfg.num_tables,
            self.cfg.batch_size,
            self.cfg.lookups_per_table,
        ]
    }

    fn mlp_bytes_total(&self) -> u64 {
        self.mlp_host.iter().map(|p| (p.len() * 4) as u64).sum()
    }

    /// Run one training batch; returns the loss.
    pub fn step(&mut self) -> anyhow::Result<StepOutcome> {
        let batch = self.gen.next_batch();
        self.step_with_batch(&batch)
    }

    /// Run one training batch with caller-provided data (replay/recovery).
    pub fn step_with_batch(&mut self, batch: &Batch) -> anyhow::Result<StepOutcome> {
        let b = self.step_no;

        // ---- batch-aware checkpoint: undo-log BEFORE the update lands
        // (the sparse features tell us which rows will change — Fig 6).
        let mlp_total = self.mlp_bytes_total();
        if let (Some(store), Some(log)) = (self.store.as_ref(), self.log.as_mut()) {
            if self.ckpt.emb_every_batch {
                let touched = store.touched_rows(&batch.indices);
                log.begin_emb_log(b, store, &touched);
                log.seal_emb_log(b);
            }
            // MLP snapshot cadence: begin at each window boundary. The
            // relaxed modes stream the snapshot across the window via
            // advance_mlp_log (Fig 9b) instead of begin/seal in one step.
            if b % self.ckpt.mlp_every == 0 {
                if log.mlp_cur.as_ref().is_some_and(|l| !l.persistent) {
                    // predecessor ran out of window: finish synchronously
                    // (the trainer-side analogue of the simulator's
                    // max_mlp_log_gap bound in RelaxedMlpLog)
                    log.advance_mlp_log(u64::MAX);
                    log.seal_mlp_log();
                }
                log.begin_mlp_log(b, &self.mlp_host);
            }
            if log.mlp_cur.as_ref().is_some_and(|l| !l.persistent) {
                // Bootstrap: until ONE generation is persistent somewhere,
                // a crash would be unrecoverable (NoMlpLog) — the very
                // first snapshot seals synchronously; only later ones
                // stream. A crash mid-stream recovers from the previous
                // generation (observed gap up to 2x the window — honest
                // relaxed semantics, reported via mlp_gap_observed).
                let budget = if log.persistent_mlp().is_none() {
                    u64::MAX
                } else {
                    mlp_total.div_ceil(self.ckpt.mlp_stream_batches.max(1)).max(1)
                };
                if log.advance_mlp_log(budget) == 0 {
                    log.seal_mlp_log();
                }
            }
        }

        // ---- FWP embedding path (CXL-MEM computing logic)
        let idx = self
            .rt
            .to_device(&HostTensor::I32(batch.indices.clone(), self.idx_shape()))?;
        let reduced = self
            .rt
            .run_b("embedding_bag", &[&self.table, &idx])?
            .remove(0);

        // ---- MLP fwd+bwd+SGD (CXL-GPU)
        let dense = self.rt.to_device(&HostTensor::F32(
            batch.dense.clone(),
            vec![self.cfg.batch_size, self.cfg.num_dense],
        ))?;
        let labels = self.rt.to_device(&HostTensor::F32(
            batch.labels.clone(),
            vec![self.cfg.batch_size],
        ))?;
        let mut args: Vec<&xla::PjRtBuffer> = self.mlp_bufs.iter().collect();
        args.push(&reduced);
        args.push(&dense);
        args.push(&labels);
        let mut outs = self.rt.run_to_host("mlp_step", &args)?;
        let loss = outs.pop().unwrap()[0];
        let grad_reduced = outs.pop().unwrap();
        // new MLP params
        for (dst, src) in self.mlp_host.iter_mut().zip(outs) {
            *dst = src;
        }
        self.mlp_bufs = self
            .mlp_host
            .iter()
            .zip(&self.mlp_shapes)
            .map(|(v, s)| self.rt.to_device(&HostTensor::F32(v.clone(), s.clone())))
            .collect::<anyhow::Result<Vec<_>>>()?;

        // ---- BWP embedding path: near-data scatter update
        let grad = self.rt.to_device(&HostTensor::F32(
            grad_reduced.clone(),
            vec![
                self.cfg.batch_size,
                self.cfg.num_tables,
                self.cfg.feature_dim,
            ],
        ))?;
        self.table = self
            .rt
            .run_b("embedding_update", &[&self.table, &idx, &grad])?
            .remove(0);

        // ---- keep the host mirror (data region image) in sync — row-wise.
        // `gather_rows` reads back exactly the positions this batch looked
        // up (the undo-log's touched-row set, duplicates carrying identical
        // post-update values), so the full table never crosses the host
        // boundary on the step path.
        if self.store.is_some() {
            let gathered = self
                .rt
                .run_b("gather_rows", &[&self.table, &idx])?
                .remove(0);
            let rows = self.rt.to_host_f32(&gathered)?;
            let store = self.store.as_mut().unwrap();
            let per_table = batch.indices.len() / store.num_tables;
            let positions: Vec<(usize, usize)> = batch
                .indices
                .iter()
                .enumerate()
                .map(|(p, &r)| (p / per_table, r as usize))
                .collect();
            store.apply_rows(&positions, &rows);
        }

        self.step_no += 1;
        Ok(StepOutcome { batch: b, loss })
    }

    /// Mean loss + binary accuracy over `n` held-out batches (seeded apart
    /// from the training stream).
    pub fn evaluate(&self, n: u64, seed: u64) -> anyhow::Result<(f32, f32)> {
        let mut gen = Generator::new(&self.cfg, seed);
        let mut loss_sum = 0.0f64;
        let mut correct = 0u64;
        let mut total = 0u64;
        for _ in 0..n {
            let batch = gen.next_batch();
            let idx = self
                .rt
                .to_device(&HostTensor::I32(batch.indices.clone(), self.idx_shape()))?;
            let dense = self.rt.to_device(&HostTensor::F32(
                batch.dense.clone(),
                vec![self.cfg.batch_size, self.cfg.num_dense],
            ))?;
            let mut args: Vec<&xla::PjRtBuffer> = self.mlp_bufs.iter().collect();
            args.push(&self.table);
            args.push(&dense);
            args.push(&idx);
            let logits = self.rt.to_host_f32(&self.rt.run_b("forward", &args)?[0])?;
            for (lo, la) in logits.iter().zip(&batch.labels) {
                let p = 1.0 / (1.0 + (-lo).exp());
                loss_sum += -(la * p.max(1e-7).ln() + (1.0 - la) * (1.0 - p).max(1e-7).ln()) as f64;
                if (p > 0.5) == (*la > 0.5) {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok((
            (loss_sum / total as f64) as f32,
            correct as f32 / total as f32,
        ))
    }

    /// Simulate a power failure mid-update: the device state is lost AND
    /// the touched rows of the in-flight batch are torn in the host image
    /// (the DMA died mid-row — NaN fill). Recovery must roll those rows
    /// back from the undo log; nothing else was in flight, so every other
    /// row is valid. Returns the post-crash (store, log, mlp_shapes).
    pub fn crash(mut self) -> (EmbeddingStore, LogRegion, Vec<Vec<usize>>) {
        let mut store = self.store.take().expect("crash() requires checkpointing");
        let log = self.log.take().expect("crash() requires checkpointing");
        if let Some(emb) = log.emb_cur.as_ref().or(log.emb_prev.as_ref()) {
            for e in &emb.entries {
                store.row_mut(e.table, e.row).fill(f32::NAN);
            }
        }
        let shapes = self.mlp_shapes.clone();
        (store, log, shapes)
    }

    /// Rebuild a trainer from recovered state (tables rolled back to the
    /// logged batch, MLP params possibly `gap` batches stale).
    pub fn from_recovered(
        root: &Path,
        cfg: &ModelConfig,
        seed: u64,
        store: EmbeddingStore,
        mlp_params: Vec<Vec<f32>>,
        mlp_shapes: Vec<Vec<usize>>,
        resume_batch: u64,
        ckpt: CkptOptions,
    ) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::load(root, &cfg.name, &Self::EXPORTS)?;
        let table_shape = rt.manifest.param_shape("table")?.to_vec();
        let table = rt.to_device(&HostTensor::F32(store.flat().to_vec(), table_shape))?;
        let mlp_bufs = mlp_params
            .iter()
            .zip(&mlp_shapes)
            .map(|(v, s)| rt.to_device(&HostTensor::F32(v.clone(), s.clone())))
            .collect::<anyhow::Result<Vec<_>>>()?;
        // Re-play the generator to the resume point so the data stream
        // continues exactly where the crash happened.
        let mut gen = Generator::new(cfg, seed ^ 0xBA7C4);
        for _ in 0..resume_batch {
            let _ = gen.next_batch();
        }
        Ok(Trainer {
            cfg: cfg.clone(),
            rt,
            gen,
            table,
            mlp_host: mlp_params,
            mlp_shapes,
            mlp_bufs,
            store: Some(store),
            log: Some(LogRegion::new()),
            ckpt,
            step_no: resume_batch,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn ckpt_options_derive_from_ckpt_mode() {
        // DRAM ideal: no checkpointing, so no mirror and no log region
        assert!(
            CkptOptions::from_topology(&Topology::from_system(SystemConfig::Dram)).is_none()
        );
        // redo and batch-aware modes: emb log + MLP snapshot every batch,
        // sealed synchronously
        for sys in [
            SystemConfig::Ssd,
            SystemConfig::Pmem,
            SystemConfig::Pcie,
            SystemConfig::CxlD,
            SystemConfig::CxlB,
        ] {
            let o = CkptOptions::from_topology(&Topology::from_system(sys))
                .unwrap_or_else(|| panic!("{sys} must checkpoint"));
            assert!(o.emb_every_batch, "{sys}");
            assert_eq!((o.mlp_every, o.mlp_stream_batches), (1, 1), "{sys}");
        }
        // relaxed mode: MLP snapshot every max_mlp_log_gap batches,
        // streamed across that window
        let cxl = Topology::from_system(SystemConfig::Cxl);
        let o = CkptOptions::from_topology(&cxl).unwrap();
        assert!(o.emb_every_batch);
        assert_eq!(o.mlp_every, cxl.max_mlp_log_gap);
        assert_eq!(o.mlp_stream_batches, cxl.max_mlp_log_gap);
    }

    #[test]
    fn relaxed_zero_gap_clamps_to_synchronous() {
        let t = Topology::builder("tight")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .max_mlp_log_gap(0)
            .build()
            .unwrap();
        assert_eq!(
            CkptOptions::from_topology(&t),
            Some(CkptOptions::default())
        );
    }
}
