//! Minimal timing harness: warmup, fixed-count repetition, summary stats.

use crate::util::stats::{percentile, Summary};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn render(&self) -> String {
        format!(
            "{:<44} {:>10.2} us/iter (sd {:>8.2}, p50 {:>9.2}, p99 {:>9.2}, n={})",
            self.name,
            self.mean_ns / 1e3,
            self.stddev_ns / 1e3,
            self.p50_ns / 1e3,
            self.p99_ns / 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::default();
    let mut xs = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        s.add(dt);
        xs.push(dt);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: s.mean(),
        stddev_ns: s.stddev(),
        p50_ns: percentile(&xs, 50.0),
        p99_ns: percentile(&xs, 99.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sanity() {
        let r = bench_fn("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }
}
