//! Failure injection + recovery experiments (paper Fig 9a).
//!
//! Protocol: train for `pre` batches with batch-aware checkpointing where
//! the MLP snapshot lags by `gap` batches; inject a power failure (device
//! state lost, in-flight rows corrupted); recover from the log region
//! (tables at batch N, MLP at batch N-gap); resume for `post` batches;
//! report the final held-out accuracy. The paper's claim: the accuracy
//! degradation stays within the 0.01% business tolerance even when the
//! gap reaches hundreds of batches.

use super::trainer::{CkptOptions, Trainer};
use crate::checkpoint;
use crate::config::ModelConfig;
use std::path::Path;

/// One Fig-9a measurement.
#[derive(Clone, Copy, Debug)]
pub struct GapResult {
    pub gap: u64,
    pub recovered_from: u64,
    pub mlp_gap_observed: u64,
    pub loss: f32,
    pub accuracy: f32,
}

/// Train, crash, recover with an MLP log `gap` batches stale, resume, and
/// evaluate. `gap == 0` means MLP logged every batch (no staleness).
pub fn run_gap_experiment(
    root: &Path,
    cfg: &ModelConfig,
    seed: u64,
    pre: u64,
    post: u64,
    gap: u64,
    eval_batches: u64,
) -> anyhow::Result<GapResult> {
    let ckpt = CkptOptions {
        emb_every_batch: true,
        mlp_every: gap.max(1),
    };
    let mut t = Trainer::new(root, cfg, seed, Some(ckpt))?;
    for _ in 0..pre {
        t.step()?;
    }

    // ---- power failure: device state gone; roll back from the log region
    let (mut store, log, mlp_shapes) = t.crash();
    let rec = checkpoint::recover(&mut store, &log)
        .map_err(|e| anyhow::anyhow!("recovery failed: {e}"))?;

    let mut t = Trainer::from_recovered(
        root,
        cfg,
        seed,
        store,
        rec.mlp_params.clone(),
        mlp_shapes,
        rec.resume_batch,
        ckpt,
    )?;
    for _ in 0..post {
        t.step()?;
    }
    let (loss, accuracy) = t.evaluate(eval_batches, seed ^ 0xE7A1)?;
    Ok(GapResult {
        gap,
        recovered_from: rec.resume_batch,
        mlp_gap_observed: rec.mlp_gap,
        loss,
        accuracy,
    })
}

/// Baseline: same schedule with no crash.
pub fn run_no_crash_baseline(
    root: &Path,
    cfg: &ModelConfig,
    seed: u64,
    batches: u64,
    eval_batches: u64,
) -> anyhow::Result<(f32, f32)> {
    let mut t = Trainer::new(root, cfg, seed, None)?;
    for _ in 0..batches {
        t.step()?;
    }
    t.evaluate(eval_batches, seed ^ 0xE7A1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    fn ready() -> Option<(std::path::PathBuf, ModelConfig)> {
        let root = repo_root();
        if !root.join("artifacts/rm_mini/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
        Some((root, cfg))
    }

    #[test]
    fn crash_recovery_resumes_and_learns() {
        let Some((root, cfg)) = ready() else { return };
        let r = run_gap_experiment(&root, &cfg, 11, 12, 12, 1, 4).unwrap();
        assert_eq!(r.recovered_from, 11); // emb log of the last batch
        assert!(r.mlp_gap_observed <= 1);
        assert!(r.accuracy > 0.5, "acc {}", r.accuracy);
    }

    #[test]
    fn stale_mlp_recovery_close_to_fresh() {
        let Some((root, cfg)) = ready() else { return };
        // longer resume phase lets recovery re-converge (Fig 9a's regime
        // is thousands of batches; rm_mini keeps CI fast)
        let fresh = run_gap_experiment(&root, &cfg, 11, 20, 60, 1, 10).unwrap();
        let stale = run_gap_experiment(&root, &cfg, 11, 20, 60, 10, 10).unwrap();
        assert!(stale.mlp_gap_observed > 0, "gap not exercised");
        // Fig 9a: accuracy degradation is tiny even at large gaps
        assert!(
            (fresh.accuracy - stale.accuracy).abs() < 0.04,
            "fresh {} vs stale {}",
            fresh.accuracy,
            stale.accuracy
        );
    }
}
