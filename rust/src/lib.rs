//! # TrainingCXL — failure-tolerant DLRM training over disaggregated PMEM/CXL
//!
//! Reproduction of *"Failure Tolerant Training with Persistent Memory
//! Disaggregation over CXL"* (Kwon, Jang, Choi, Lee, Jung — IEEE Micro
//! 2023). The crate is the L3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (embedding bag / scatter update / MXU matmul)
//!   authored in `python/compile/kernels/`, the compute the paper places in
//!   CXL-MEM's *computing logic* and the GPU.
//! * **L2** — a JAX DLRM (fwd+bwd+SGD) in `python/compile/model.py`,
//!   AOT-lowered once to HLO text under `artifacts/`.
//! * **L3** — this crate: loads the artifacts through PJRT ([`runtime`]),
//!   drives real training ([`train`]), and reproduces the paper's system
//!   behaviour on a discrete-event CXL fabric ([`sim`], [`devices`],
//!   [`sched`], [`checkpoint`], [`energy`]).
//!
//! Python never runs on the request path; after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## Layout
//!
//! | module | paper artefact |
//! |---|---|
//! | [`sim`] | discrete-event engine (typed events, resource queues, worker pool), CXL protocol (switch/DCOH/link), media models (Table 2) |
//! | [`world`] | unified entry point: one TOML resolves to a solo [`sim::Topology`] or a multi-tenant [`tenancy::TenantSet`] |
//! | [`sim::topology`] | declarative fabric builder: media, movement, checkpoint schedule, pooled expanders; TOML-loadable (`configs/topologies/`) |
//! | [`sim::fabric`] | CXL 3.0 multi-level switch tree: hop-aware range routing, per-link byte/occupancy counters |
//! | [`tenancy`] | multi-tenant pooled fabric: QoS pool arbiter (fair-share/weighted/strict-priority), per-tenant log-region slices, crash isolation |
//! | [`devices`] | CXL-MEM (Fig 3b/10), CXL-GPU, host CPU |
//! | [`emb`] | embedding engine: data/log regions, lookup/update accounting |
//! | [`checkpoint`] | redo log, batch-aware undo log (Fig 6/7), relaxed (Fig 9b), recovery |
//! | [`sched`] | composable batch-pipeline stages + runner (Fig 4/8/12); the six paper configs are prebuilt stage compositions |
//! | [`serve`] | online inference serving: open-loop arrivals, dynamic batching, read-only lookup lanes, tail-latency telemetry |
//! | [`workload`] | RM1–RM4 sparse/dense feature generation, Zipf skew |
//! | [`energy`] | Fig 13 energy accounting |
//! | [`train`] | real training/recovery through the PJRT runtime |
//! | [`telemetry`] | Fig 11 breakdowns, Fig 12 timelines |
//! | [`bench`] | typed `Experiment -> Report` drivers for every table/figure |
//! | [`analysis`] | static crash-consistency analyzer: stage-effect graphs proving persistency ordering for every composable chain |
//!
//! Custom scenarios compose through [`sim::topology::Topology::builder`]
//! (or a TOML file under `configs/topologies/`) and run through
//! [`sched::PipelineSim::from_topology`]; see `docs/topology.md` for a
//! worked example.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod devices;
pub mod emb;
pub mod energy;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod telemetry;
pub mod tenancy;
pub mod train;
pub mod util;
pub mod workload;
pub mod world;

/// Repo root discovery: honours `TRAININGCXL_ROOT`, else walks up from the
/// current dir looking for `configs/models`.
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("TRAININGCXL_ROOT") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        if dir.join("configs/models").is_dir() {
            return dir;
        }
        if !dir.pop() {
            return ".".into();
        }
    }
}
