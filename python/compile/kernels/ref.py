"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: python/tests asserts every Pallas
kernel allclose against these on swept shapes/dtypes (hypothesis), and the
L2 model is itself testable against a ref-only forward.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """Sum-reduce embedding lookup.

    table:   (T, R, D) stacked per-table embeddings
    indices: (T, B, L) local row ids in [0, R)
    returns: (B, T, D) per-(sample, table) reduced vectors
    """
    # rows[t, b, l] = table[t, indices[t, b, l]]
    rows = jax.vmap(lambda tbl_t, idx_t: jnp.take(tbl_t, idx_t, axis=0))(
        table, indices
    )  # (T, B, L, D)
    return rows.sum(axis=2).transpose(1, 0, 2)


def embedding_update(
    table: jnp.ndarray, indices: jnp.ndarray, grad: jnp.ndarray, lr
) -> jnp.ndarray:
    """SGD scatter update of the rows touched by `indices`.

    Each looked-up row receives the gradient of its bag's reduced vector
    (d reduced / d row = identity for a sum-bag). Duplicate indices
    accumulate.

    table:   (T, R, D); indices: (T, B, L); grad: (B, T, D)
    returns: updated (T, R, D)
    """
    T, B, L = indices.shape
    D = table.shape[-1]
    g = grad.transpose(1, 0, 2)  # (T, B, D)
    g = jnp.broadcast_to(g[:, :, None, :], (T, B, L, D)).reshape(T, B * L, D)
    idx = indices.reshape(T, B * L)

    def upd(tbl_t, idx_t, g_t):
        return tbl_t.at[idx_t].add(-lr * g_t)

    return jax.vmap(upd)(table, idx, g)


def matmul_bias(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x @ w + b with f32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32) + b
