//! Tenant-isolation pins: one tenant's crash/recovery must be invisible
//! to its co-tenants, in BOTH domains the tenancy subsystem models.
//!
//! * **Timing** — `MultiTenantSim::run_with_crash` recovers the crashed
//!   tenant by replaying its own log slice over its own leaf link inside
//!   the same arbiter slot, so every co-tenant's `RunResult` is
//!   bit-identical to the crash-free run.
//! * **Data plane** — each tenant checkpoints into its own `LogRegion`
//!   slice of the shared pool (`PoolPartition`): recovery restores the
//!   crashed tenant's tables bit-identically to an uncrashed twin while
//!   the co-tenant's store AND log region stay byte-for-byte untouched.

use trainingcxl::checkpoint::{self, LogRegion};
use trainingcxl::config::{ModelConfig, SystemConfig};
use trainingcxl::emb::EmbeddingStore;
use trainingcxl::repo_root;
use trainingcxl::sched::RunResult;
use trainingcxl::sim::topology::Topology;
use trainingcxl::tenancy::{
    CrashPlan, MultiTenantSim, PoolPartition, QosPolicy, TENANT_SLICE_BYTES, TenantSet, TenantSpec,
};
use trainingcxl::workload::Generator;

const BATCHES: u64 = 8;

fn assert_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.batch_times, b.batch_times, "{what}: batch times differ");
    assert_eq!(a.total_time, b.total_time, "{what}: total time differs");
    assert_eq!(a.raw_hits, b.raw_hits, "{what}: raw hits differ");
    assert_eq!(a.max_mlp_gap, b.max_mlp_gap, "{what}: mlp gap differs");
    assert_eq!(a.traffic, b.traffic, "{what}: traffic differs");
    assert_eq!(a.gpu_busy, b.gpu_busy, "{what}: gpu busy differs");
    assert_eq!(a.host_busy, b.host_busy, "{what}: host busy differs");
    assert_eq!(a.logic_busy, b.logic_busy, "{what}: logic busy differs");
    assert_eq!(a.breakdowns.len(), b.breakdowns.len(), "{what}: breakdown count");
    for (i, (x, y)) in a.breakdowns.iter().zip(&b.breakdowns).enumerate() {
        assert_eq!(x, y, "{what}: breakdown {i} differs");
    }
}

fn pair(policy: QosPolicy) -> TenantSet {
    let flagship = Topology::from_system(SystemConfig::Cxl);
    TenantSet {
        name: "pair".into(),
        fabric_levels: 2,
        redundancy: 0,
        policy,
        tenants: vec![
            TenantSpec {
                name: "victim".into(),
                model: "rm_mini".into(),
                topology: flagship.clone(),
                seed: 42,
                weight: 1,
                serve: None,
            },
            TenantSpec {
                name: "bystander".into(),
                model: "rm_mini".into(),
                topology: flagship,
                seed: 43,
                weight: 2,
                serve: None,
            },
        ],
        faults: Vec::new(),
    }
}

#[test]
fn co_tenant_run_result_untouched_by_a_crash() {
    let root = repo_root();
    for policy in [
        QosPolicy::FairShare,
        QosPolicy::Weighted,
        QosPolicy::StrictPriority,
    ] {
        let clean = MultiTenantSim::new(&root, &pair(policy)).unwrap().run(BATCHES);
        let crashed = MultiTenantSim::new(&root, &pair(policy))
            .unwrap()
            .run_with_crash(BATCHES, Some(CrashPlan { tenant: 0, batch: 3 }));
        let what = policy.name();
        // the bystander never observes the victim's failure
        assert_identical(
            &crashed.tenants[1].result,
            &clean.tenants[1].result,
            &format!("{what}/bystander"),
        );
        assert_eq!(
            crashed.tenants[1].stalls, clean.tenants[1].stalls,
            "{what}: bystander's charged stalls changed"
        );
        assert_eq!(crashed.tenants[1].recoveries, 0, "{what}");
        // the victim paid for its own recovery: the crashed batch's wall
        // time carries the whole torn + undo-replay + re-execute cycle
        assert_eq!(crashed.tenants[0].recoveries, 1, "{what}");
        let v_crash = &crashed.tenants[0].result.batch_times;
        let v_clean = &clean.tenants[0].result.batch_times;
        assert_eq!(v_crash.len() as u64, BATCHES, "{what}");
        assert_eq!(v_crash[..3], v_clean[..3], "{what}: pre-crash batches perturbed");
        assert!(
            v_crash[3] > v_clean[3],
            "{what}: the crash cycle must cost the victim time ({} vs {})",
            v_crash[3],
            v_clean[3]
        );
        assert!(
            crashed.tenants[0].result.total_time >= clean.tenants[0].result.total_time,
            "{what}: recovery can never shorten the victim's timeline"
        );
        // ...and the victim still completed its full scheduled quota
        assert_eq!(crashed.tenants[0].batches, BATCHES, "{what}");
    }
}

#[test]
fn crash_in_an_unscheduled_batch_is_a_no_op() {
    let root = repo_root();
    let clean = MultiTenantSim::new(&root, &pair(QosPolicy::FairShare))
        .unwrap()
        .run(4);
    let miss = MultiTenantSim::new(&root, &pair(QosPolicy::FairShare))
        .unwrap()
        .run_with_crash(4, Some(CrashPlan { tenant: 1, batch: 99 }));
    for (a, b) in miss.tenants.iter().zip(&clean.tenants) {
        assert_identical(&a.result, &b.result, &a.name);
        assert_eq!(a.recoveries, 0);
    }
}

// ------------------------------------------------------------ data plane

/// Deterministic per-tenant update delta.
fn delta(tenant: usize, batch: u64, table: usize, row: usize) -> f32 {
    (tenant as f32 + 1.0) * 0.5 + (batch as f32 + 1.0) * 0.125 + (table * 31 + row) as f32 * 0.001
}

fn initial_store(cfg: &ModelConfig, tenant: usize) -> EmbeddingStore {
    let mut s = EmbeddingStore::zeros(cfg);
    for t in 0..cfg.num_tables {
        for r in 0..cfg.rows_per_table {
            s.row_mut(t, r).fill((tenant * 100_000 + t * 1000 + r) as f32 * 0.03125);
        }
    }
    s
}

fn tenant_params(tenant: usize) -> Vec<Vec<f32>> {
    vec![vec![tenant as f32 + 0.5; 6], vec![-(tenant as f32) - 0.25; 3]]
}

/// One tenant's data-plane batch: undo-log its touched rows into ITS
/// partition slice, snapshot its MLP params, apply the update.
fn run_data_batch(
    region: &mut LogRegion,
    store: &mut EmbeddingStore,
    params: &mut [Vec<f32>],
    tenant: usize,
    batch: u64,
    touched: &[(usize, usize)],
    crash_mid_update: bool,
) {
    region.begin_emb_log(batch, store, touched);
    region.seal_emb_log(batch);
    region.begin_mlp_log(batch, params);
    region.advance_mlp_log(u64::MAX);
    region.seal_mlp_log();
    if crash_mid_update {
        // the DMA died mid-row: the batch's touched rows are torn
        for &(t, r) in touched {
            store.row_mut(t, r).fill(f32::NAN);
        }
        return;
    }
    for &(t, r) in touched {
        let d = delta(tenant, batch, t, r);
        for v in store.row_mut(t, r) {
            *v += d;
        }
    }
    for p in params.iter_mut() {
        for v in p.iter_mut() {
            *v += (batch as f32 + 1.0) * 0.25;
        }
    }
}

#[test]
fn partitioned_log_regions_isolate_crash_recovery() {
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    let touched_per_batch = |seed: u64| -> Vec<Vec<(usize, usize)>> {
        let probe = EmbeddingStore::zeros(&cfg);
        let mut g = Generator::new(&cfg, seed);
        (0..BATCHES).map(|_| probe.touched_rows(&g.next_batch().indices)).collect()
    };
    let rows = [touched_per_batch(42), touched_per_batch(43)];
    let crash_batch = 4u64;

    // interference-free reference: both tenants run all batches, no crash
    let mut clean = PoolPartition::new(2, TENANT_SLICE_BYTES);
    let mut clean_stores = [initial_store(&cfg, 0), initial_store(&cfg, 1)];
    let mut clean_params = [tenant_params(0), tenant_params(1)];
    for b in 0..BATCHES {
        for i in 0..2 {
            run_data_batch(
                clean.region_mut(i),
                &mut clean_stores[i],
                &mut clean_params[i],
                i,
                b,
                &rows[i][b as usize],
                false,
            );
        }
    }

    // crashed run: tenant 0 tears mid-update at crash_batch, tenant 1
    // keeps going to the end
    let mut part = PoolPartition::new(2, TENANT_SLICE_BYTES);
    let mut stores = [initial_store(&cfg, 0), initial_store(&cfg, 1)];
    let mut params = [tenant_params(0), tenant_params(1)];
    for b in 0..BATCHES {
        if b <= crash_batch {
            run_data_batch(
                part.region_mut(0),
                &mut stores[0],
                &mut params[0],
                0,
                b,
                &rows[0][b as usize],
                b == crash_batch,
            );
        }
        run_data_batch(
            part.region_mut(1),
            &mut stores[1],
            &mut params[1],
            1,
            b,
            &rows[1][b as usize],
            false,
        );
    }

    // recover tenant 0 from ITS slice only
    let rec = checkpoint::recover(&mut stores[0], part.region(0)).unwrap();
    assert_eq!(rec.resume_batch, crash_batch);
    assert!(stores[0].flat().iter().all(|v| v.is_finite()), "torn rows not healed");
    // bit-identical to an uncrashed twin resumed at the same batch
    let mut twin = initial_store(&cfg, 0);
    let mut twin_region = LogRegion::new();
    let mut twin_params = tenant_params(0);
    for b in 0..crash_batch {
        run_data_batch(
            &mut twin_region,
            &mut twin,
            &mut twin_params,
            0,
            b,
            &rows[0][b as usize],
            false,
        );
    }
    assert_eq!(stores[0], twin, "recovered tables diverge from the twin");
    assert_eq!(rec.mlp_params, twin_params, "recovered MLP params diverge");

    // the co-tenant's WHOLE failure domain is byte-identical to the
    // interference-free run: its tables, its log slice, its params
    assert_eq!(stores[1], clean_stores[1], "co-tenant tables perturbed");
    assert_eq!(part.region(1), clean.region(1), "co-tenant log region perturbed");
    assert_eq!(params[1], clean_params[1], "co-tenant params perturbed");
    // and the partition windows can never alias
    let (s0, l0) = part.window(0);
    let (s1, _) = part.window(1);
    assert!(s0 + l0 <= s1);
}

#[test]
fn pool_cycle_accounting_is_conserved_across_tenants() {
    // Sim-level conservation: what a tenant is charged can only be pool
    // cycles a co-tenant actually consumed, and the schedule serves every
    // tenant its full batch quota under every policy.
    let root = repo_root();
    for policy in [
        QosPolicy::FairShare,
        QosPolicy::Weighted,
        QosPolicy::StrictPriority,
    ] {
        let run = MultiTenantSim::new(&root, &pair(policy)).unwrap().run(BATCHES);
        let busy: Vec<u64> = run.tenants.iter().map(|t| t.pool_busy_ns).collect();
        for (i, t) in run.tenants.iter().enumerate() {
            assert_eq!(t.batches, BATCHES, "{}: short-served", t.name);
            assert_eq!(t.stalls.len() as u64, BATCHES, "{}", t.name);
            let others: u64 = busy
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != i)
                .map(|(_, &b)| b)
                .sum();
            assert!(
                t.total_stall_ns() <= others,
                "{} ({}): charged {} > co-tenant busy {}",
                t.name,
                policy.name(),
                t.total_stall_ns(),
                others
            );
        }
    }
}
