//! Declarative fabric topology: which medium holds the tables, who moves
//! data, who computes, how checkpoints are taken, and how many pooled
//! CXL-MEM expanders sit behind the switch.
//!
//! A [`Topology`] is the single input the stage pipeline
//! ([`crate::sched::stage`]) is composed from. The six paper
//! configurations are prebuilt ([`Topology::from_system`]); arbitrary
//! scenarios are assembled with [`Topology::builder`] or loaded from
//! `configs/topologies/*.toml` ([`Topology::load`]). Invalid compositions
//! (e.g. hardware data movement without near-data processing — the old
//! `unreachable!` arm of the pipeline monolith) are rejected at
//! *construction* time by [`TopologyBuilder::build`], so a constructed
//! `Topology` always composes into a runnable pipeline.

use crate::config::sysconfig::{CkptMode, SystemConfig};
use crate::sim::mem::MediaKind;
use crate::util::tomlmini::Doc;
use std::path::Path;

/// Pooled CXL-MEM expanders behind the switch (CXL 3.0 multi-level
/// switching, paper §Related Work). Tables are striped across all pooled
/// backends, multiplying PMEM channel parallelism; each extra switch
/// level adds hop latency to the link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpanderPool {
    /// Number of CXL-MEM devices the tables are striped over (>= 1).
    pub expanders: usize,
    /// Extra switch hops on the path to the pool.
    pub extra_hops: usize,
}

impl Default for ExpanderPool {
    fn default() -> Self {
        ExpanderPool {
            expanders: 1,
            extra_hops: 0,
        }
    }
}

/// Hot/cold media tiering: the hottest `hot_frac` Zipf ranks of every
/// table are served from a fast volatile tier (`hot`, DRAM) while the
/// durable pool keeps the cold tail, stays authoritative for every row
/// (inclusive tiering), and holds the undo log. The hot tier's touched
/// rows are captured durably each batch by the `hot-tier-flush` stage;
/// a promotion/demotion leg crosses the switch every `migrate_every`
/// batches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierSpec {
    /// Medium of the hot tier (must be volatile-fast: DRAM).
    pub hot: MediaKind,
    /// Fraction of each table's hottest Zipf ranks held hot, in [0, 1].
    /// `0.0` degenerates to the untouched single-media composition.
    pub hot_frac: f64,
    /// Batches between tier promotion/demotion legs (>= 1).
    pub migrate_every: u64,
}

/// Default migration cadence when `[tiers]` omits `migrate_every`.
pub const DEFAULT_MIGRATE_EVERY: u64 = 8;

/// A validated fabric + schedule description. Construct via
/// [`Topology::from_system`], [`Topology::builder`], or [`Topology::load`].
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    /// Display name ("CXL", "pooled-cxl-4x", ...).
    pub name: String,
    /// Medium holding the embedding tables.
    pub table_media: MediaKind,
    /// Embedding ops run near data (computing logic) instead of host CPU.
    pub near_data_processing: bool,
    /// Data movement by CXL hardware (DCOH flushes) instead of
    /// sync+memcpy software.
    pub hw_data_movement: bool,
    /// Checkpointing scheme (Fig 4/6/9b).
    pub ckpt: CkptMode,
    /// Relaxed embedding lookup (RAW elimination, Fig 8).
    pub relaxed_lookup: bool,
    /// Host-DRAM vector cache in front of the table medium (SSD config).
    pub dram_vector_cache: bool,
    /// Max embedding/MLP-log batch gap tolerated by relaxed checkpointing
    /// (Fig 9a: hundreds of batches stay within the 0.01% accuracy budget).
    pub max_mlp_log_gap: u64,
    /// Pooled expanders behind the switch.
    pub pool: ExpanderPool,
    /// GPU lanes the embedding tables are striped over (>= 1). With more
    /// than one lane the pipeline composes per-shard lookup/flush lanes,
    /// an all-to-all embedding exchange over the switch, and a gradient
    /// reduce; `1` is the paper's single-GPU schedule, bit-identical to
    /// the unsharded composition.
    pub gpu_shards: usize,
    /// Hot/cold media tiering of the tables (None = single medium).
    pub tiers: Option<TierSpec>,
}

/// Why a composition cannot be built (the old runtime `unreachable!`s,
/// surfaced as constructor errors).
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum TopologyError {
    #[error("hardware data movement requires near-data processing (no computing logic to produce the reduced vectors the DCOH would flush)")]
    HwMovementWithoutNdp,
    #[error("relaxed embedding lookup requires hardware data movement (the early lookup runs on the expander's computing logic)")]
    RelaxedLookupWithoutHwMovement,
    #[error("{0:?} checkpointing requires hardware data movement (the undo log runs on the expander's checkpointing logic)")]
    BackgroundCkptWithoutHwMovement(CkptMode),
    #[error("expander pool must contain at least one device")]
    EmptyPool,
    #[error("gpu shard set must contain at least one lane")]
    EmptyShardSet,
    #[error("multi-GPU sharding requires hardware data movement (the all-to-all embedding exchange rides the CXL switch's DCOH)")]
    ShardingWithoutHwMovement,
    #[error("tiers.hot_frac must be a finite fraction in [0, 1], got {0}")]
    HotFracOutOfRange(String),
    #[error("tiered media requires hardware data movement (the hot-tier flush and tier-migrate legs ride the switch DCOH)")]
    TieredWithoutHwMovement,
    #[error("the hot tier must be the fast volatile medium (dram), got {0:?}")]
    TieredHotMediaNotVolatile(MediaKind),
    #[error("tiered tables need a durable cold tier (pmem) holding the tail and the undo log, got {0:?}")]
    TieredColdMediaNotDurable(MediaKind),
    #[error("topology key '{0}': {1}")]
    BadField(String, String),
    #[error(
        "this document declares [[tenants]] — it is a multi-tenant world, \
         not one topology; load it through `World::load` (or \
         `tenancy::TenantSet` directly)"
    )]
    TenantWorld,
}

/// Step-by-step assembly of a [`Topology`]; `build()` validates the
/// composition.
#[derive(Clone, Debug)]
pub struct TopologyBuilder {
    t: Topology,
    /// Migration cadence requested before/without `tiered_media`;
    /// resolved (and validated) at `build()` so call order is free.
    migrate_every: Option<u64>,
}

impl TopologyBuilder {
    fn new(name: &str) -> TopologyBuilder {
        TopologyBuilder {
            migrate_every: None,
            t: Topology {
                name: name.to_string(),
                table_media: MediaKind::Pmem,
                near_data_processing: false,
                hw_data_movement: false,
                ckpt: CkptMode::Redo,
                relaxed_lookup: false,
                dram_vector_cache: false,
                max_mlp_log_gap: 1,
                pool: ExpanderPool::default(),
                gpu_shards: 1,
                tiers: None,
            },
        }
    }

    /// Medium holding the embedding tables (default: PMEM).
    pub fn table_media(mut self, media: MediaKind) -> Self {
        self.t.table_media = media;
        self
    }

    /// Run embedding ops on the expander's computing logic.
    pub fn near_data(mut self) -> Self {
        self.t.near_data_processing = true;
        self
    }

    /// Move data with DCOH flushes instead of sync+memcpy software.
    pub fn hw_movement(mut self) -> Self {
        self.t.hw_data_movement = true;
        self
    }

    /// Checkpointing scheme (default: synchronous redo log).
    pub fn checkpoint(mut self, mode: CkptMode) -> Self {
        self.t.ckpt = mode;
        self
    }

    /// Enable the relaxed (early, RAW-free) embedding lookup.
    pub fn relaxed_lookup(mut self) -> Self {
        self.t.relaxed_lookup = true;
        self
    }

    /// Put a host-DRAM vector cache in front of the table medium.
    pub fn vector_cache(mut self) -> Self {
        self.t.dram_vector_cache = true;
        self
    }

    /// Bound the embedding/MLP-log gap of relaxed checkpointing.
    pub fn max_mlp_log_gap(mut self, batches: u64) -> Self {
        self.t.max_mlp_log_gap = batches;
        self
    }

    /// Stripe the tables over `expanders` pooled CXL-MEM devices reached
    /// through `extra_hops` additional switch levels.
    pub fn expander_pool(mut self, expanders: usize, extra_hops: usize) -> Self {
        self.t.pool = ExpanderPool {
            expanders,
            extra_hops,
        };
        self
    }

    /// Stripe the embedding tables over `n` GPU lanes (one shard stage per
    /// lane). `1` (the default) keeps the single-GPU schedule.
    pub fn gpu_shards(mut self, n: usize) -> Self {
        self.t.gpu_shards = n;
        self
    }

    /// Serve the hottest `hot_frac` Zipf ranks of every table from a fast
    /// volatile `hot` tier; the durable pool keeps the cold tail and the
    /// undo log. `hot_frac == 0.0` keeps the untouched single-media
    /// composition, bit-identical to not calling this at all.
    pub fn tiered_media(mut self, hot: MediaKind, hot_frac: f64) -> Self {
        self.t.tiers = Some(TierSpec {
            hot,
            hot_frac,
            migrate_every: DEFAULT_MIGRATE_EVERY,
        });
        self
    }

    /// Batches between tier promotion/demotion legs. Order-independent
    /// with [`TopologyBuilder::tiered_media`]; a cadence without any hot
    /// tier is rejected by `build()`, not here.
    pub fn migrate_every(mut self, batches: u64) -> Self {
        self.migrate_every = Some(batches);
        self
    }

    /// Validate the composition. Every combination a [`Topology`] value
    /// can express is runnable; the invalid ones are rejected here.
    pub fn build(mut self) -> Result<Topology, TopologyError> {
        if let Some(m) = self.migrate_every {
            match self.t.tiers.as_mut() {
                Some(ts) => ts.migrate_every = m,
                None => {
                    return Err(TopologyError::BadField(
                        "tiers.migrate_every".into(),
                        "requires tiered_media (no hot tier configured)".into(),
                    ))
                }
            }
        }
        self.t.validate()?;
        Ok(self.t)
    }
}

impl Topology {
    /// Start assembling a custom topology.
    pub fn builder(name: &str) -> TopologyBuilder {
        TopologyBuilder::new(name)
    }

    /// The single source of the composition invariants, shared by
    /// [`TopologyBuilder::build`] and [`crate::sched::stage::compose`]
    /// (the latter re-checks so hand-constructed `Topology` values cannot
    /// revive the old `unreachable!` path).
    pub fn validate(&self) -> Result<(), TopologyError> {
        if self.hw_data_movement && !self.near_data_processing {
            return Err(TopologyError::HwMovementWithoutNdp);
        }
        if self.relaxed_lookup && !self.hw_data_movement {
            return Err(TopologyError::RelaxedLookupWithoutHwMovement);
        }
        if matches!(self.ckpt, CkptMode::BatchAware | CkptMode::Relaxed) && !self.hw_data_movement
        {
            return Err(TopologyError::BackgroundCkptWithoutHwMovement(self.ckpt));
        }
        if self.pool.expanders == 0 {
            return Err(TopologyError::EmptyPool);
        }
        if self.gpu_shards == 0 {
            return Err(TopologyError::EmptyShardSet);
        }
        if self.gpu_shards > 1 && !self.hw_data_movement {
            return Err(TopologyError::ShardingWithoutHwMovement);
        }
        if let Some(ts) = self.tiers {
            if !(ts.hot_frac.is_finite() && (0.0..=1.0).contains(&ts.hot_frac)) {
                return Err(TopologyError::HotFracOutOfRange(ts.hot_frac.to_string()));
            }
            if !self.hw_data_movement {
                return Err(TopologyError::TieredWithoutHwMovement);
            }
            if ts.hot != MediaKind::Dram {
                return Err(TopologyError::TieredHotMediaNotVolatile(ts.hot));
            }
            if self.table_media != MediaKind::Pmem {
                return Err(TopologyError::TieredColdMediaNotDurable(self.table_media));
            }
            if ts.migrate_every == 0 {
                return Err(TopologyError::BadField(
                    "tiers.migrate_every".into(),
                    "must be at least 1".into(),
                ));
            }
        }
        Ok(())
    }

    /// The effective tier split: `Some` only when a hot tier is configured
    /// AND actually holds rows. `hot_frac == 0.0` (and no `[tiers]` at
    /// all) routes through the untouched single-media composition.
    pub fn tier_split(&self) -> Option<TierSpec> {
        self.tiers.filter(|t| t.hot_frac > 0.0)
    }

    /// The prebuilt topology for one of the paper's test configurations.
    pub fn from_system(sys: SystemConfig) -> Topology {
        let b = Topology::builder(sys.name());
        let b = match sys {
            SystemConfig::Ssd => b.table_media(MediaKind::Ssd).vector_cache(),
            SystemConfig::Pmem => b,
            SystemConfig::Pcie => b.near_data(),
            SystemConfig::CxlD => b.near_data().hw_movement(),
            SystemConfig::CxlB => b.near_data().hw_movement().checkpoint(CkptMode::BatchAware),
            SystemConfig::Cxl => b
                .near_data()
                .hw_movement()
                .checkpoint(CkptMode::Relaxed)
                .relaxed_lookup()
                .max_mlp_log_gap(200),
            SystemConfig::Dram => b.table_media(MediaKind::Dram).checkpoint(CkptMode::None),
        };
        b.build()
            .expect("prebuilt system topologies are always valid")
    }

    /// The legacy [`SystemConfig`] this topology is accounted as (energy
    /// provisioning, `RunResult::config`): the nearest paper config by
    /// capability flags.
    pub fn system_label(&self) -> SystemConfig {
        if self.hw_data_movement {
            match self.ckpt {
                CkptMode::Relaxed => SystemConfig::Cxl,
                CkptMode::BatchAware => SystemConfig::CxlB,
                CkptMode::Redo | CkptMode::None => SystemConfig::CxlD,
            }
        } else if self.near_data_processing {
            SystemConfig::Pcie
        } else {
            match self.table_media {
                MediaKind::Ssd => SystemConfig::Ssd,
                MediaKind::Dram => SystemConfig::Dram,
                MediaKind::Pmem => SystemConfig::Pmem,
            }
        }
    }

    // ------------------------------------------------------------- TOML

    /// Parse a topology from a `tomlmini` document. Unknown keys are
    /// ignored; missing keys take the builder defaults; the assembled
    /// composition is validated by [`TopologyBuilder::build`].
    pub fn from_doc(name: &str, doc: &Doc) -> Result<Topology, TopologyError> {
        // A `[[tenants]]` file is a multi-tenant SET, not one topology:
        // loading it here would silently simulate a default fabric. The
        // typed redirect points at the API that sniffs both classes.
        if doc.array_len("tenants") > 0 {
            return Err(TopologyError::TenantWorld);
        }
        let mut b = Topology::builder(doc.get("name").and_then(|v| v.as_str()).unwrap_or(name));
        if let Some(v) = doc.get("table_media") {
            let s = v.as_str().ok_or_else(|| {
                TopologyError::BadField("table_media".into(), "expected string".into())
            })?;
            b = b.table_media(parse_media(s).ok_or_else(|| {
                TopologyError::BadField(
                    "table_media".into(),
                    format!("unknown medium '{s}' (expected dram|pmem|ssd)"),
                )
            })?);
        }
        if flag(doc, "near_data_processing")? {
            b = b.near_data();
        }
        if flag(doc, "hw_data_movement")? {
            b = b.hw_movement();
        }
        if let Some(v) = doc.get("checkpoint") {
            let s = v.as_str().ok_or_else(|| {
                TopologyError::BadField("checkpoint".into(), "expected string".into())
            })?;
            b = b.checkpoint(parse_ckpt(s).ok_or_else(|| {
                TopologyError::BadField(
                    "checkpoint".into(),
                    format!("unknown mode '{s}' (expected redo|batch-aware|relaxed|none)"),
                )
            })?);
        }
        if flag(doc, "relaxed_lookup")? {
            b = b.relaxed_lookup();
        }
        if flag(doc, "dram_vector_cache")? {
            b = b.vector_cache();
        }
        if let Some(v) = doc.get("max_mlp_log_gap") {
            let n = v.as_i64().filter(|&n| n >= 0).ok_or_else(|| {
                TopologyError::BadField(
                    "max_mlp_log_gap".into(),
                    "expected non-negative integer".into(),
                )
            })?;
            b = b.max_mlp_log_gap(n as u64);
        }
        let expanders = count(doc, "pool.expanders")?;
        let extra_hops = count(doc, "pool.extra_hops")?;
        if expanders.is_some() || extra_hops.is_some() {
            b = b.expander_pool(expanders.unwrap_or(1), extra_hops.unwrap_or(0));
        }
        if let Some(n) = count(doc, "gpu.shards")? {
            b = b.gpu_shards(n);
        }
        let hot_media = match doc.get("tiers.hot_media") {
            None => None,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    TopologyError::BadField("tiers.hot_media".into(), "expected string".into())
                })?;
                Some(parse_media(s).ok_or_else(|| {
                    TopologyError::BadField(
                        "tiers.hot_media".into(),
                        format!("unknown medium '{s}' (expected dram|pmem|ssd)"),
                    )
                })?)
            }
        };
        let hot_frac = match doc.get("tiers.hot_frac") {
            None => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| {
                TopologyError::BadField("tiers.hot_frac".into(), "expected number".into())
            })?),
        };
        let migrate_every = count(doc, "tiers.migrate_every")?;
        if hot_media.is_some() || hot_frac.is_some() || migrate_every.is_some() {
            let frac = hot_frac.ok_or_else(|| {
                TopologyError::BadField(
                    "tiers.hot_frac".into(),
                    "required when [tiers] is present".into(),
                )
            })?;
            b = b.tiered_media(hot_media.unwrap_or(MediaKind::Dram), frac);
            if let Some(m) = migrate_every {
                b = b.migrate_every(m as u64);
            }
        }
        b.build()
    }

    /// Load `configs/topologies/<name>.toml` strictly: any I/O, parse, or
    /// composition error is returned to the caller.
    pub fn load_strict(root: &Path, name: &str) -> anyhow::Result<Topology> {
        let path = root.join("configs/topologies").join(format!("{name}.toml"));
        let doc = Doc::load(&path)?;
        Ok(Topology::from_doc(name, &doc)?)
    }

    /// Load a topology by name with the documented fallback chain:
    ///
    /// 1. `configs/topologies/<name>.toml` if present and well-formed;
    /// 2. else, if `name` is one of the paper configs, that prebuilt
    ///    topology;
    /// 3. else the CXL flagship topology.
    ///
    /// A malformed or missing file never panics: the fallback is logged
    /// to stderr once at load time so default usage is visible at startup.
    pub fn load(root: &Path, name: &str) -> Topology {
        let path = root.join("configs/topologies").join(format!("{name}.toml"));
        match Doc::load_lenient(&path) {
            Some(doc) => match Topology::from_doc(name, &doc) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!(
                        "[topology] {}: invalid composition ({e}); using built-in default for '{name}'",
                        path.display()
                    );
                    Topology::fallback(name)
                }
            },
            None => {
                eprintln!(
                    "[topology] {} missing or malformed; using built-in default for '{name}'",
                    path.display()
                );
                Topology::fallback(name)
            }
        }
    }

    fn fallback(name: &str) -> Topology {
        match name.parse::<SystemConfig>() {
            Ok(sys) => Topology::from_system(sys),
            Err(_) => Topology::from_system(SystemConfig::Cxl),
        }
    }

    /// Names of the topology files shipped under `configs/topologies`.
    pub fn available(root: &Path) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(root.join("configs/topologies"))
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        let p = e.path();
                        (p.extension()? == "toml")
                            .then(|| p.file_stem().unwrap().to_string_lossy().into_owned())
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }
}

/// A non-negative integer key, or a [`TopologyError::BadField`] if present
/// with any other shape (strings, floats, negatives). A negative must not
/// sneak through `as usize` into a gigantic channel/shard multiplier.
fn count(doc: &Doc, key: &str) -> Result<Option<usize>, TopologyError> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_i64()
            .filter(|&n| n >= 0)
            .map(|n| Some(n as usize))
            .ok_or_else(|| {
                TopologyError::BadField(key.into(), "expected non-negative integer".into())
            }),
    }
}

fn flag(doc: &Doc, key: &str) -> Result<bool, TopologyError> {
    match doc.get(key) {
        None => Ok(false),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| TopologyError::BadField(key.into(), "expected true/false".into())),
    }
}

fn parse_media(s: &str) -> Option<MediaKind> {
    Some(match s.to_ascii_lowercase().as_str() {
        "dram" => MediaKind::Dram,
        "pmem" => MediaKind::Pmem,
        "ssd" => MediaKind::Ssd,
        _ => return None,
    })
}

fn parse_ckpt(s: &str) -> Option<CkptMode> {
    Some(match s.to_ascii_lowercase().as_str() {
        "redo" => CkptMode::Redo,
        "batch-aware" | "batchaware" | "undo" => CkptMode::BatchAware,
        "relaxed" => CkptMode::Relaxed,
        "none" => CkptMode::None,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    #[test]
    fn paper_progression_matches_fig4() {
        // each TrainingCXL step adds exactly one capability
        let d = Topology::from_system(SystemConfig::CxlD);
        let b = Topology::from_system(SystemConfig::CxlB);
        let c = Topology::from_system(SystemConfig::Cxl);
        assert!(d.near_data_processing && d.hw_data_movement);
        assert_eq!(d.ckpt, CkptMode::Redo);
        assert_eq!(b.ckpt, CkptMode::BatchAware);
        assert!(!b.relaxed_lookup);
        assert_eq!(c.ckpt, CkptMode::Relaxed);
        assert!(c.relaxed_lookup);
        assert!(c.max_mlp_log_gap > 100); // Fig 9a: hundreds of batches
    }

    #[test]
    fn baselines_use_software_paths() {
        for sys in [SystemConfig::Ssd, SystemConfig::Pmem] {
            let t = Topology::from_system(sys);
            assert!(!t.near_data_processing && !t.hw_data_movement);
            assert_eq!(t.ckpt, CkptMode::Redo);
        }
        let pcie = Topology::from_system(SystemConfig::Pcie);
        assert!(pcie.near_data_processing && !pcie.hw_data_movement);
        assert_eq!(
            Topology::from_system(SystemConfig::Ssd).table_media,
            MediaKind::Ssd
        );
    }

    #[test]
    fn invalid_compositions_fail_at_build_time() {
        // the old `(false, true)` unreachable!: hw movement without NDP
        assert_eq!(
            Topology::builder("bad").hw_movement().build().unwrap_err(),
            TopologyError::HwMovementWithoutNdp
        );
        assert_eq!(
            Topology::builder("bad").near_data().relaxed_lookup().build().unwrap_err(),
            TopologyError::RelaxedLookupWithoutHwMovement
        );
        assert!(matches!(
            Topology::builder("bad")
                .checkpoint(CkptMode::BatchAware)
                .build()
                .unwrap_err(),
            TopologyError::BackgroundCkptWithoutHwMovement(CkptMode::BatchAware)
        ));
        assert_eq!(
            Topology::builder("bad").expander_pool(0, 0).build().unwrap_err(),
            TopologyError::EmptyPool
        );
    }

    #[test]
    fn system_labels_round_trip() {
        for sys in SystemConfig::ALL {
            assert_eq!(Topology::from_system(sys).system_label(), sys);
        }
        assert_eq!(
            Topology::from_system(SystemConfig::Dram).system_label(),
            SystemConfig::Dram
        );
    }

    #[test]
    fn toml_topologies_match_prebuilt() {
        let root = repo_root();
        for sys in SystemConfig::ALL {
            let name = sys.name().to_ascii_lowercase();
            let loaded = Topology::load_strict(&root, &name)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(loaded, Topology::from_system(sys), "{name}");
        }
    }

    #[test]
    fn pooled_toml_loads() {
        let root = repo_root();
        let t = Topology::load_strict(&root, "pooled-cxl-4x").unwrap();
        assert_eq!(t.pool.expanders, 4);
        assert_eq!(t.pool.extra_hops, 2);
        assert_eq!(t.ckpt, CkptMode::Relaxed);
    }

    #[test]
    fn malformed_or_missing_toml_falls_back() {
        let root = repo_root();
        // no file shipped for the DRAM ideal: falls back to the named
        // paper config
        let t = Topology::load(&root, "dram");
        assert_eq!(t.ckpt, CkptMode::None);
        // unknown name entirely: falls back to the CXL flagship
        let t = Topology::load(&root, "no-such-topology");
        assert_eq!(t.ckpt, CkptMode::Relaxed);
        // malformed document: parse error surfaces as fallback, not panic
        let dir = std::env::temp_dir().join("trainingcxl-topo-test");
        std::fs::create_dir_all(dir.join("configs/topologies")).unwrap();
        std::fs::write(
            dir.join("configs/topologies/cxl.toml"),
            "this is not toml at all",
        )
        .unwrap();
        let t = Topology::load(&dir, "cxl");
        assert_eq!(t, Topology::from_system(SystemConfig::Cxl));
    }

    #[test]
    fn shard_compositions_validated_at_build_time() {
        assert_eq!(
            Topology::builder("bad")
                .near_data()
                .hw_movement()
                .gpu_shards(0)
                .build()
                .unwrap_err(),
            TopologyError::EmptyShardSet
        );
        // the exchange/reduce stages ride the switch DCOH: software
        // movement cannot express them
        assert_eq!(
            Topology::builder("bad").near_data().gpu_shards(2).build().unwrap_err(),
            TopologyError::ShardingWithoutHwMovement
        );
        let t = Topology::builder("ok")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .gpu_shards(4)
            .build()
            .unwrap();
        assert_eq!(t.gpu_shards, 4);
        // the default is the paper's single-GPU schedule
        assert_eq!(Topology::from_system(SystemConfig::Cxl).gpu_shards, 1);
    }

    #[test]
    fn sharded_tomls_load() {
        let root = repo_root();
        for (name, shards, expanders, hops) in
            [("sharded-cxl-2x", 2, 2, 1), ("sharded-cxl-4x", 4, 4, 2)]
        {
            let t = Topology::load_strict(&root, name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(t.gpu_shards, shards, "{name}");
            assert_eq!(t.pool.expanders, expanders, "{name}");
            assert_eq!(t.pool.extra_hops, hops, "{name}");
            assert_eq!(t.ckpt, CkptMode::Relaxed, "{name}");
            assert!(t.relaxed_lookup, "{name}");
        }
    }

    #[test]
    fn unknown_keys_are_ignored_not_fatal() {
        let doc = Doc::parse(
            "near_data_processing = true\nhw_data_movement = true\nwibble = 3\n[frobnicator]\nlevel = 9\n",
        )
        .unwrap();
        let t = Topology::from_doc("x", &doc).unwrap();
        assert!(t.hw_data_movement);
        assert_eq!(t.gpu_shards, 1);
    }

    #[test]
    fn malformed_shard_and_pool_values_fall_back_not_panic() {
        // every malformed value must surface as a BadField from the doc
        // parser and a logged fallback from `Topology::load`
        for bad in [
            "gpu.shards = \"two\"",
            "gpu.shards = -2",
            "gpu.shards = 1.5",
            "[gpu]\nshards = 0", // rejected by validate(), same fallback
            "pool.expanders = \"four\"",
            "pool.expanders = -1",
            "[pool]\nextra_hops = -3",
            "pool.extra_hops = 0.25",
        ] {
            let doc = Doc::parse(bad).unwrap_or_else(|e| panic!("{bad}: {e}"));
            assert!(
                Topology::from_doc("x", &doc).is_err(),
                "expected rejection for {bad:?}"
            );

            let dir = std::env::temp_dir().join(format!(
                "trainingcxl-shard-fallback-{:x}",
                bad.as_bytes().iter().fold(0u64, |h, &b| h.wrapping_mul(31).wrapping_add(b as u64))
            ));
            std::fs::create_dir_all(dir.join("configs/topologies")).unwrap();
            std::fs::write(dir.join("configs/topologies/cxl.toml"), bad).unwrap();
            // lenient load: logs and falls back to the named paper config
            let t = Topology::load(&dir, "cxl");
            assert_eq!(t, Topology::from_system(SystemConfig::Cxl), "{bad}");
        }
    }

    #[test]
    fn tiered_media_validated_at_build_time() {
        let cxl = |b: TopologyBuilder| b.near_data().hw_movement();
        for bad in [-0.1, 1.5, f64::NAN] {
            assert!(
                matches!(
                    cxl(Topology::builder("bad"))
                        .tiered_media(MediaKind::Dram, bad)
                        .build()
                        .unwrap_err(),
                    TopologyError::HotFracOutOfRange(_)
                ),
                "hot_frac {bad} must be rejected"
            );
        }
        // the flush/migrate legs ride the switch DCOH: software movement
        // cannot express them
        assert_eq!(
            Topology::builder("bad")
                .near_data()
                .tiered_media(MediaKind::Dram, 0.1)
                .build()
                .unwrap_err(),
            TopologyError::TieredWithoutHwMovement
        );
        // the hot tier must be the fast volatile medium...
        assert_eq!(
            cxl(Topology::builder("bad"))
                .tiered_media(MediaKind::Pmem, 0.1)
                .build()
                .unwrap_err(),
            TopologyError::TieredHotMediaNotVolatile(MediaKind::Pmem)
        );
        // ...and the cold tier the durable one (it keeps the undo log)
        assert_eq!(
            cxl(Topology::builder("bad"))
                .table_media(MediaKind::Dram)
                .tiered_media(MediaKind::Dram, 0.1)
                .build()
                .unwrap_err(),
            TopologyError::TieredColdMediaNotDurable(MediaKind::Dram)
        );
        // a zero migration cadence cannot schedule the periodic leg
        assert!(matches!(
            cxl(Topology::builder("bad"))
                .tiered_media(MediaKind::Dram, 0.1)
                .migrate_every(0)
                .build()
                .unwrap_err(),
            TopologyError::BadField(_, _)
        ));
        // ...and a cadence without any hot tier is an Err, not a panic
        assert!(matches!(
            cxl(Topology::builder("bad")).migrate_every(4).build().unwrap_err(),
            TopologyError::BadField(_, _)
        ));
        // builder call order is free: cadence before tiered_media sticks
        let early = cxl(Topology::builder("ok"))
            .migrate_every(6)
            .tiered_media(MediaKind::Dram, 0.2)
            .build()
            .unwrap();
        assert_eq!(early.tier_split().unwrap().migrate_every, 6);
        // valid: DRAM head over the PMEM pool, composing with shards
        let t = cxl(Topology::builder("ok"))
            .tiered_media(MediaKind::Dram, 0.25)
            .gpu_shards(2)
            .build()
            .unwrap();
        let ts = t.tier_split().unwrap();
        assert_eq!(ts.hot, MediaKind::Dram);
        assert!((ts.hot_frac - 0.25).abs() < 1e-12);
        assert_eq!(ts.migrate_every, DEFAULT_MIGRATE_EVERY);
        // hot_frac == 0 builds fine but degenerates to the untiered path
        let zero = cxl(Topology::builder("zero"))
            .tiered_media(MediaKind::Dram, 0.0)
            .build()
            .unwrap();
        assert!(zero.tiers.is_some() && zero.tier_split().is_none());
        assert!(Topology::from_system(SystemConfig::Cxl).tier_split().is_none());
    }

    #[test]
    fn tiered_tomls_load() {
        let root = repo_root();
        for (name, frac) in [("tiered-cxl-10", 0.10), ("tiered-cxl-30", 0.30)] {
            let t = Topology::load_strict(&root, name).unwrap_or_else(|e| panic!("{name}: {e}"));
            let ts = t.tier_split().unwrap_or_else(|| panic!("{name}: no tier split"));
            assert_eq!(ts.hot, MediaKind::Dram, "{name}");
            assert!((ts.hot_frac - frac).abs() < 1e-12, "{name}");
            assert_eq!(ts.migrate_every, 4, "{name}");
            assert_eq!(t.table_media, MediaKind::Pmem, "{name}");
            assert_eq!(t.ckpt, CkptMode::Relaxed, "{name}");
            assert!(t.relaxed_lookup, "{name}");
        }
    }

    #[test]
    fn malformed_tier_values_rejected() {
        for bad in [
            "tiers.hot_frac = \"lots\"",
            "[tiers]\nhot_frac = 2.0",
            "tiers.hot_frac = -0.5",
            "[tiers]\nhot_media = \"tape\"\nhot_frac = 0.1",
            "[tiers]\nhot_media = \"dram\"", // hot_frac is required
            "[tiers]\nhot_frac = 0.2\nmigrate_every = 0",
            "tiers.migrate_every = -1",
            "[tiers]\nhot_media = \"pmem\"\nhot_frac = 0.2", // hot must be volatile
        ] {
            let text = format!("near_data_processing = true\nhw_data_movement = true\n{bad}\n");
            let doc = Doc::parse(&text).unwrap_or_else(|e| panic!("{bad}: {e}"));
            assert!(Topology::from_doc("x", &doc).is_err(), "expected rejection for {bad:?}");
        }
    }

    #[test]
    fn multi_tenant_docs_are_not_topologies() {
        // `trainingcxl simulate --topology multi-tenant-2` must error with
        // a pointer to the tenancy loader instead of silently simulating
        // the builder-default fabric
        let doc = Doc::parse("[[tenants]]\nmodel = \"rm2\"\n").unwrap();
        match Topology::from_doc("x", &doc) {
            Err(TopologyError::TenantWorld) => {
                let msg = TopologyError::TenantWorld.to_string();
                // the redirect must name both the sniffing entry point and
                // the direct loader (and the [[tenants]] trigger itself)
                assert!(msg.contains("World::load"), "{msg}");
                assert!(msg.contains("TenantSet"), "{msg}");
                assert!(msg.contains("tenants"), "{msg}");
            }
            other => panic!("expected TenantWorld, got {other:?}"),
        }
        // and the lenient loader falls back instead of panicking
        let dir = std::env::temp_dir().join("trainingcxl-tenant-doc-test");
        std::fs::create_dir_all(dir.join("configs/topologies")).unwrap();
        std::fs::write(
            dir.join("configs/topologies/cxl.toml"),
            "[[tenants]]\nmodel = \"rm2\"\n",
        )
        .unwrap();
        let t = Topology::load(&dir, "cxl");
        assert_eq!(t, Topology::from_system(SystemConfig::Cxl));
    }

    #[test]
    fn doc_rejects_bad_fields() {
        let doc = Doc::parse("table_media = \"tape\"").unwrap();
        assert!(matches!(
            Topology::from_doc("x", &doc),
            Err(TopologyError::BadField(_, _))
        ));
        let doc = Doc::parse("checkpoint = \"sometimes\"").unwrap();
        assert!(Topology::from_doc("x", &doc).is_err());
    }
}
