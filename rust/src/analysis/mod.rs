//! Static crash-consistency and resource-ordering analyzer.
//!
//! The recovery matrix (`rust/tests/recovery_matrix.rs`) proves crash
//! consistency *dynamically* — by crashing a data-state rig during every
//! stage and diffing the recovery against an uncrashed twin. This module
//! proves the same ordering invariants *statically*, for every chain the
//! builders can compose, without running anything: each stage declares a
//! [`StageEffects`] summary, [`EffectGraph`] lifts a `compose(...)` output
//! into a happens-before graph, and the checks in [`checks`] verify:
//!
//! 1. undo-before-update under batch-aware/relaxed checkpointing,
//! 2. MLP-log lag stays within `max_mlp_log_gap` and the bootstrap
//!    snapshot seals synchronously,
//! 3. every crash point has a reachable recovery path,
//! 4. resource acquisition order (`pmem_free`, fabric links, GPU lanes)
//!    is globally consistent across co-resident chains, and
//! 5. serving chains are write-free.
//!
//! Entry points: [`analyze_topology`] / [`analyze_serving_topology`] for
//! one fabric, [`analyze_tenant_set`] for a multi-tenant world, and
//! [`analyze_repo`] for the CI gate (all shipped
//! `configs/topologies/*.toml` plus [`enumerate_families`] /
//! [`enumerate_worlds`], the exhaustive builder-family sweep). The
//! `trainingcxl analyze` subcommand drives [`analyze_repo`].

pub mod checks;
pub mod effects;
pub mod graph;

pub use checks::{AnalysisReport, ChainSpec, Violation, Warning, MAX_SAFE_MLP_GAP};
pub use effects::{MlpPersist, Region, Resource, Rows, StageEffects, UndoCapture};
pub use graph::{EffectGraph, EffectNode};

use std::path::Path;

use crate::config::{CkptMode, SystemConfig};
use crate::sched::stage::{self, Stage};
use crate::serve::{compose_serving, ServeStage};
use crate::sim::mem::MediaKind;
use crate::sim::topology::{Topology, TopologyError};
use crate::tenancy::TenantSet;

/// Run the training-chain checks (everything except the cross-chain
/// resource union) and return the report with the lifted graph.
fn training_report(
    spec: &ChainSpec,
    subject: &str,
    chain: &[Box<dyn Stage>],
) -> (AnalysisReport, EffectGraph) {
    let g = EffectGraph::lift_training(chain);
    let mut r = AnalysisReport::new(subject);
    checks::check_declared(&g, &mut r);
    checks::check_undo_ordering(spec, &g, &mut r);
    checks::check_mlp(spec, &g, &mut r);
    checks::check_crash_coverage(spec, &g, &mut r);
    checks::check_dataflow(&g, &mut r);
    (r, g)
}

/// Run the serving-chain checks (everything except the cross-chain
/// resource union) and return the report with the lifted graph.
fn serving_report(subject: &str, chain: &[Box<dyn ServeStage>]) -> (AnalysisReport, EffectGraph) {
    let g = EffectGraph::lift_serving(chain);
    let mut r = AnalysisReport::new(subject);
    checks::check_declared(&g, &mut r);
    checks::check_serving_read_only(&g, &mut r);
    checks::check_dataflow(&g, &mut r);
    (r, g)
}

/// Analyze an already-composed training chain. This is the raw entry
/// point the mutant tests use: hand-built (deliberately broken) chains
/// go straight in without passing `compose`'s validation.
pub fn analyze_training_chain(
    spec: &ChainSpec,
    subject: &str,
    chain: &[Box<dyn Stage>],
) -> AnalysisReport {
    let (mut r, g) = training_report(spec, subject, chain);
    checks::check_resource_order([&g], &mut r);
    r
}

/// Analyze an already-composed serving chain (see
/// [`analyze_training_chain`] for why chains come pre-composed).
pub fn analyze_serving_chain(subject: &str, chain: &[Box<dyn ServeStage>]) -> AnalysisReport {
    let (mut r, g) = serving_report(subject, chain);
    checks::check_resource_order([&g], &mut r);
    r
}

/// Compose and analyze a topology's training chain.
pub fn analyze_topology(t: &Topology) -> Result<AnalysisReport, TopologyError> {
    let chain = stage::compose(t)?;
    Ok(analyze_training_chain(
        &ChainSpec::of(t),
        &format!("train/{}", t.name),
        &chain,
    ))
}

/// Compose and analyze a topology's serving chain.
pub fn analyze_serving_topology(t: &Topology) -> Result<AnalysisReport, TopologyError> {
    let chain = compose_serving(t)?;
    Ok(analyze_serving_chain(&format!("serve/{}", t.name), &chain))
}

/// The resource footprint of a fabric-fault recovery: after an expander
/// loss, the victim tenant replays its undo slice (read the log, rewrite
/// the torn table rows) holding the pool and streaming over its CXL leaf
/// link. Declared pool-before-link — the SAME nested acquisition order
/// every checkpoint/recovery stage uses — so fault recovery composes
/// with any co-resident chain without introducing a resource-order
/// cycle.
pub fn fault_recovery_effects() -> StageEffects {
    StageEffects::declared()
        .read(Region::UndoLog, Rows::All)
        .write(Region::EmbTable, Rows::All)
        .section(&[Resource::PmemPool, Resource::CxlLink])
}

/// Analyze a world of co-resident chains: per-chain checks for each
/// member, then one resource-order check over the union (co-tenants
/// contend on the same pool and links, so a cycle only visible across
/// two tenants' chains is still a deadlock). `serving == true` members
/// run the serving chain. The union always includes the fabric-fault
/// recovery pseudo-chain: a `FabricRepair` can fire between any two
/// rounds of any world, so its lock order must be consistent with every
/// member even when no fault is scheduled.
pub fn analyze_world(
    subject: &str,
    members: &[(Topology, bool)],
) -> Result<AnalysisReport, TopologyError> {
    let mut out = AnalysisReport::new(subject);
    let mut graphs = Vec::new();
    for (t, serving) in members {
        let member_subject = format!("{subject}/{}", t.name);
        let (r, g) = if *serving {
            serving_report(&member_subject, &compose_serving(t)?)
        } else {
            training_report(&ChainSpec::of(t), &member_subject, &stage::compose(t)?)
        };
        out.absorb(r);
        graphs.push(g);
    }
    graphs.push(EffectGraph::from_effects(
        &[("fabric-fault-recovery", fault_recovery_effects())],
        1,
    ));
    checks::check_resource_order(graphs.iter(), &mut out);
    Ok(out)
}

/// Analyze a loaded tenant set: each tenant's chain in its declared role,
/// plus the cross-tenant resource-order union.
pub fn analyze_tenant_set(set: &TenantSet) -> Result<AnalysisReport, TopologyError> {
    let members: Vec<(Topology, bool)> = set
        .tenants
        .iter()
        .map(|t| (t.topology.clone(), t.serve.is_some()))
        .collect();
    analyze_world(&format!("tenants/{}", set.name), &members)
}

/// Exhaustively enumerate the builder families: the seven paper presets,
/// the software family (table media x checkpoint), the PCIe-NDP family,
/// and the CXL family (ckpt mode x shards x tiers x pool). Every
/// returned topology passed `build()` validation; the analyzer must find
/// all of them clean.
pub fn enumerate_families() -> Vec<Topology> {
    let mut out = Vec::new();
    for sys in SystemConfig::ALL {
        out.push(Topology::from_system(sys));
    }
    out.push(Topology::from_system(SystemConfig::Dram));

    // Software family: host CPU embedding ops, sync/memcpy movement.
    // Background checkpointing needs hardware movement, so only the
    // synchronous modes compose here.
    let sw_media = [
        ("pmem", MediaKind::Pmem),
        ("ssd", MediaKind::Ssd),
        ("dram", MediaKind::Dram),
    ];
    let sync_ckpts = [("redo", CkptMode::Redo), ("none", CkptMode::None)];
    for (media_label, media) in sw_media {
        for (ckpt_label, ckpt) in sync_ckpts {
            let t = Topology::builder(&format!("fam-sw-{media_label}-{ckpt_label}"))
                .table_media(media)
                .checkpoint(ckpt)
                .build()
                .expect("software family composition must validate");
            out.push(t);
        }
    }

    // PCIe-NDP family: near-data ops, software movement.
    for (ckpt_label, ckpt) in sync_ckpts {
        let t = Topology::builder(&format!("fam-pcie-{ckpt_label}"))
            .near_data()
            .checkpoint(ckpt)
            .build()
            .expect("pcie family composition must validate");
        out.push(t);
    }

    // CXL family: ckpt mode x shard count x tiering x pool shape.
    let cxl_ckpts = [
        ("redo", CkptMode::Redo),
        ("batch-aware", CkptMode::BatchAware),
        ("relaxed", CkptMode::Relaxed),
        ("none", CkptMode::None),
    ];
    for (ckpt_label, ckpt) in cxl_ckpts {
        for shards in [1usize, 2, 4] {
            for tiered in [false, true] {
                for (expanders, hops) in [(1usize, 0usize), (4, 2)] {
                    let name = format!(
                        "fam-cxl-{ckpt_label}-s{shards}-t{}-p{expanders}",
                        u8::from(tiered)
                    );
                    let mut b = Topology::builder(&name)
                        .near_data()
                        .hw_movement()
                        .checkpoint(ckpt)
                        .expander_pool(expanders, hops)
                        .gpu_shards(shards);
                    if tiered {
                        b = b.tiered_media(MediaKind::Dram, 0.3);
                    }
                    if ckpt == CkptMode::Relaxed {
                        b = b.relaxed_lookup().max_mlp_log_gap(200);
                    }
                    out.push(b.build().expect("cxl family composition must validate"));
                }
            }
        }
    }
    out
}

/// Mixed tenant worlds for the cross-chain resource-order check: roles
/// and families combined so every pair of link types co-resides with the
/// pool somewhere in the sweep.
pub fn enumerate_worlds() -> Vec<(String, Vec<(Topology, bool)>)> {
    let cxl = Topology::from_system(SystemConfig::Cxl);
    let tiered = Topology::builder("world-tiered")
        .near_data()
        .hw_movement()
        .checkpoint(CkptMode::BatchAware)
        .tiered_media(MediaKind::Dram, 0.3)
        .build()
        .expect("tiered world member must validate");
    let sharded = Topology::builder("world-sharded")
        .near_data()
        .hw_movement()
        .checkpoint(CkptMode::Relaxed)
        .relaxed_lookup()
        .max_mlp_log_gap(200)
        .gpu_shards(2)
        .build()
        .expect("sharded world member must validate");
    let software = Topology::from_system(SystemConfig::Pmem);
    let pcie = Topology::from_system(SystemConfig::Pcie);
    vec![
        (
            "world/train-serve-cxl".into(),
            vec![(cxl.clone(), false), (cxl.clone(), true)],
        ),
        (
            "world/tiered-sharded-serve".into(),
            vec![
                (tiered.clone(), false),
                (sharded.clone(), false),
                (cxl.clone(), true),
            ],
        ),
        (
            "world/all-link-types".into(),
            vec![
                (software, false),
                (pcie, false),
                (cxl, false),
                (tiered, true),
                (sharded, true),
            ],
        ),
    ]
}

/// The CI gate: analyze every shipped `configs/topologies/*.toml`
/// (training + serving for single fabrics, the full world for tenant
/// sets) plus the exhaustive family enumeration and the mixed worlds.
pub fn analyze_repo(root: &Path) -> anyhow::Result<Vec<AnalysisReport>> {
    let mut reports = Vec::new();
    let dir = root.join("configs/topologies");
    for name in Topology::available(root) {
        match crate::world::World::load(root, &dir.join(format!("{name}.toml")))? {
            crate::world::World::Tenants(set) => reports.push(analyze_tenant_set(&set)?),
            crate::world::World::Solo(t) => {
                reports.push(analyze_topology(&t)?);
                reports.push(analyze_serving_topology(&t)?);
            }
        }
    }
    for t in enumerate_families() {
        reports.push(analyze_topology(&t)?);
        reports.push(analyze_serving_topology(&t)?);
    }
    for (subject, members) in enumerate_worlds() {
        reports.push(analyze_world(&subject, &members)?);
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_enumerated_family_is_clean() {
        for t in enumerate_families() {
            let train = analyze_topology(&t).expect("family must compose");
            assert!(
                train.is_clean(),
                "train/{} expected clean, got:\n{train}",
                t.name
            );
            let serve = analyze_serving_topology(&t).expect("family must compose serving");
            assert!(
                serve.is_clean(),
                "serve/{} expected clean, got:\n{serve}",
                t.name
            );
        }
    }

    #[test]
    fn every_mixed_world_is_clean() {
        for (subject, members) in enumerate_worlds() {
            let r = analyze_world(&subject, &members).expect("world must compose");
            assert!(r.is_clean(), "{subject} expected clean, got:\n{r}");
        }
    }

    #[test]
    fn fault_recovery_lock_order_composes_with_every_world() {
        // every_mixed_world_is_clean already exercises analyze_world
        // (which now folds the fabric-fault recovery pseudo-chain into
        // the union); here pin that the declared pool->link order is
        // load-bearing: the REVERSED order forms a cross-chain cycle
        // the checker must flag.
        let sane = EffectGraph::from_effects(
            &[("fabric-fault-recovery", fault_recovery_effects())],
            1,
        );
        let reversed = EffectGraph::from_effects(
            &[(
                "mutant-fault-recovery",
                StageEffects::declared().section(&[Resource::CxlLink, Resource::PmemPool]),
            )],
            1,
        );
        let mut clean = AnalysisReport::new("sane");
        checks::check_resource_order([&sane], &mut clean);
        assert!(clean.is_clean(), "{clean}");
        let mut broken = AnalysisReport::new("mutant");
        checks::check_resource_order([&sane, &reversed], &mut broken);
        assert!(
            broken
                .violations
                .iter()
                .any(|v| matches!(v, Violation::CyclicResourceOrder { .. })),
            "reversed fault-recovery lock order must cycle:\n{broken}"
        );
    }

    #[test]
    fn unprotected_durable_writes_warn_but_pass() {
        // CkptMode::None over durable media is legitimately
        // unrecoverable (the recovery matrix treats it the same way):
        // a warning, not a violation.
        let t = Topology::builder("none-durable")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::None)
            .build()
            .unwrap();
        let r = analyze_topology(&t).unwrap();
        assert!(r.is_clean(), "{r}");
        assert!(
            r.warnings
                .iter()
                .any(|w| matches!(w, Warning::UnprotectedDurableWrite { .. })),
            "expected an unprotected-write warning, got:\n{r}"
        );
    }

    #[test]
    fn relaxed_gap_beyond_budget_is_flagged() {
        let t = Topology::builder("oversized-gap")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(MAX_SAFE_MLP_GAP + 1)
            .build()
            .unwrap();
        let r = analyze_topology(&t).unwrap();
        assert!(
            r.violations
                .iter()
                .any(|v| matches!(v, Violation::MlpGapOverrun { gap, bound }
                    if *gap == MAX_SAFE_MLP_GAP + 1 && *bound == MAX_SAFE_MLP_GAP)),
            "expected MlpGapOverrun, got:\n{r}"
        );
    }

    #[test]
    fn analyze_repo_passes_all_shipped_topologies() {
        let root = crate::repo_root();
        if !root.join("configs/topologies").is_dir() {
            return; // out-of-tree test run
        }
        let reports = analyze_repo(&root).expect("shipped configs must load");
        assert!(!reports.is_empty());
        for r in &reports {
            assert!(r.is_clean(), "{r}");
        }
    }
}
