//! Multi-tenant pooled fabric: N concurrent models sharing one persistent
//! PMEM pool behind a CXL 3.0 multi-level switch tree.
//!
//! TrainingCXL's pooled expanders pay off at datacenter scale when the
//! pool is *shared*: each tenant is its own model + [`Topology`]-derived
//! stage chain + workload generator seed + partitioned log-region slice
//! of the pool, and a [`PoolArbiter`] interleaves the tenants' batches
//! over the shared pool clock with a pluggable QoS policy:
//!
//! * **fair-share** — round-robin, one batch per tenant per round;
//! * **weighted** — weighted round-robin, `weight` consecutive batches
//!   per round;
//! * **strict-priority** — tenant 0 drains completely before tenant 1
//!   starts, and so on.
//!
//! The pool is a single serialised resource (the paper's Fig-12b PMEM
//! contention, across tenants): the policy never creates or destroys pool
//! cycles, it only reorders WHO waits — every co-tenant pool occupancy a
//! tenant has not yet absorbed is charged to its `pmem_free` horizon
//! before its next batch. With one tenant nothing is ever charged, so the
//! single-tenant arbiter path is bit-identical to the plain
//! [`PipelineSim`](crate::sched::PipelineSim) chain (pinned in
//! `tests/topology_equiv.rs`).
//!
//! Execution rides the discrete-event engine
//! ([`crate::sim::engine`]): the arbiter's service order is partitioned
//! into **rounds** ([`PoolArbiter::rounds`]), each round opens as a
//! [`RoundOpen`](crate::sim::engine::Event::RoundOpen) event, and every
//! (lane, quantum) pair in the round runs against the same round-entry
//! snapshot of the pool ledger — which makes the lanes of a round
//! *independent*, so they fan out over a worker pool
//! ([`run_tasks`](crate::sim::engine::run_tasks), thread count via
//! [`MultiTenantSim::with_workers`]) and merge back in lane-slot order.
//! The merge is deterministic by construction: results are keyed by
//! round position, the fabric and the
//! [`ResourceLedger`](crate::sim::engine::ResourceLedger) are only
//! touched in that order, and nothing reads wall-clock or thread
//! identity — the same seed yields byte-identical reports at ANY worker
//! count (pinned in `tests/engine_determinism.rs`). Crash plans enter
//! the run as [`CrashInject`](crate::sim::engine::Event::CrashInject)
//! events and resolve to a tenant-local recovery inside the victim's
//! quantum.
//!
//! Failure domains are per-tenant: each tenant checkpoints into its own
//! [`LogRegion`] slice ([`PoolPartition`]), and a crash recovers by
//! replaying that slice over the tenant's own leaf link — the arbiter
//! never re-admits the slot, so co-tenants observe an identical service
//! schedule (pinned in `tests/tenancy_isolation.rs` and the
//! `recovery_matrix` multi-tenant rows).
//!
//! Tenants come in two classes: **trainers** (the default) run the full
//! training pipeline, **servers** (`role = "server"` in `[[tenants]]`)
//! run the read-only inference chain of [`crate::serve`] against the same
//! pool — open-loop arrivals, dynamic batching, per-request latency into
//! a histogram, plus a staleness gauge counting how many trainer batches
//! committed since the server last read the pool.
//!
//! # Fabric failure domains
//!
//! Beyond media crashes ([`CrashPlan`]), the fabric itself can break:
//! `[[faults]]` tables in the set TOML schedule [`FaultPlan`]s — a
//! [`FaultKind`] striking a component on one tenant's leaf path at
//! `inject_round`, repaired at `repair_round`. Faults enter the event
//! pump as first-class
//! [`FabricFault`](crate::sim::engine::Event::FabricFault) /
//! [`FabricRepair`](crate::sim::engine::Event::FabricRepair) events,
//! applied on the single merge thread before the same-time round opens,
//! so degraded-mode behaviour is byte-identical at any worker count.
//! Semantics:
//!
//! * a degraded edge (`[fabric] redundancy` spare lanes absorbing a
//!   LinkDown) keeps its tenants running: the fabric's per-transfer
//!   degradation penalty is attributed to the lane as a fault stall at
//!   its next quantum entry (`fault_stall_ns`);
//! * an unreachable window (severed edge, downed switch, lost expander)
//!   defers the owning lane's quanta — FIFO, merged per lane — until a
//!   repair re-admits them in a catch-up round (`stalled_rounds`, with
//!   the re-entry pool stall attributed to the fault);
//! * the **blast radius** of a fault is exactly the set of tenants whose
//!   [`PoolPartition`] windows stopped routing when it was applied
//!   ([`FaultRecord::blast`]) — bystanders keep their full service
//!   schedule, batch count, and total co-tenant charge, and their data
//!   plane is byte-identical (pinned in the recovery matrix); only the
//!   round at which a co-tenant charge lands can shift, because a
//!   stalled victim really does free the pool;
//! * only [`FaultKind::ExpanderLost`] tears data: blast tenants replay
//!   their own undo slice at re-entry (priced like a crash recovery,
//!   `fault_recovery_ns`), because the expander lost the rows in flight.
//!   LinkDown/SwitchDown are pure stalls — PMEM contents survive.

use crate::analysis::effects::Resource;
use crate::checkpoint::LogRegion;
use crate::config::sysconfig::SystemConfig;
use crate::sched::{PipelineEnv, PipelineSim, RunResult};
use crate::serve::{ServeConfig, ServeStats, ServingSim, TraceShape};
use crate::sim::cxl::Proto;
use crate::sim::cxl::switch::PortId;
use crate::sim::engine::{run_tasks, Event, EventQueue, ResourceLedger};
use crate::sim::fabric::{FabricTree, FaultKind, LinkStats, NodeId, ROOT};
use crate::sim::topology::Topology;
use crate::sim::{Lane, SimTime};
use crate::telemetry::trace::{TraceEvent, TraceKind, TraceLog};
use crate::telemetry::Breakdown;
use crate::util::tomlmini::Doc;
use std::path::Path;

/// HPA bytes of the shared pool each tenant's partition claims (the
/// window its log-region slice and fabric leaf port are addressed by).
pub const TENANT_SLICE_BYTES: u64 = 16 << 30;

/// Pool service policy of the [`PoolArbiter`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QosPolicy {
    FairShare,
    Weighted,
    StrictPriority,
}

impl QosPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            QosPolicy::FairShare => "fair-share",
            QosPolicy::Weighted => "weighted",
            QosPolicy::StrictPriority => "strict-priority",
        }
    }

    pub fn parse(s: &str) -> Option<QosPolicy> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fair-share" | "fairshare" | "fair" => QosPolicy::FairShare,
            "weighted" => QosPolicy::Weighted,
            "strict-priority" | "strict" | "priority" => QosPolicy::StrictPriority,
            _ => return None,
        })
    }
}

/// One tenant of the shared pool.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    /// Model config the tenant trains (`configs/models/`).
    pub model: String,
    /// The tenant's fabric schedule; its `pool.extra_hops` is deepened by
    /// the shared fabric's extra levels at simulation time.
    pub topology: Topology,
    /// Workload generator seed (feeds the tenant's batch statistics).
    pub seed: u64,
    /// Weighted-round-robin share (>= 1; ignored by the other policies).
    pub weight: u64,
    /// `Some` makes this an inference-serving tenant (`role = "server"`):
    /// read-only lookups under the given arrival/batching knobs. `None`
    /// is the default trainer role.
    pub serve: Option<ServeConfig>,
}

/// A named set of tenants + the fabric depth and arbitration policy they
/// share. Loaded from `configs/topologies/multi-tenant-*.toml`.
#[derive(Clone, Debug)]
pub struct TenantSet {
    pub name: String,
    /// Switch-tree depth (1 = the paper's single switch).
    pub fabric_levels: usize,
    /// Spare physical lanes per fabric edge (`[fabric] redundancy`): a
    /// LinkDown degrades instead of severing while spares survive.
    pub redundancy: u32,
    pub policy: QosPolicy,
    pub tenants: Vec<TenantSpec>,
    /// Scheduled fabric faults (`[[faults]]` tables), applied as engine
    /// events during [`MultiTenantSim::run`].
    pub faults: Vec<FaultPlan>,
}

/// One scheduled fabric fault: `kind` strikes a component on `tenant`'s
/// leaf path when arbiter round `inject_round` is about to open, and is
/// repaired just before round `repair_round` (deferred lanes re-enter in
/// a catch-up round first; a repair scheduled past the last round still
/// fires before the run ends, so every admitted batch is served).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    pub kind: FaultKind,
    /// The tenant whose leaf path hosts the faulted component (named in
    /// TOML, resolved to the tenant index).
    pub tenant: usize,
    /// Which path component, for LinkDown/SwitchDown. `Some(k)` is the
    /// switch `k` levels below the root (so `Some(0)` downs the root
    /// switch itself — only valid for SwitchDown). `None` picks the
    /// deepest component: the leaf switch / its uplink, or on a depth-1
    /// fabric the root switch / the tenant's device-port link.
    /// ExpanderLost always targets the tenant's device port.
    pub level: Option<usize>,
    pub inject_round: u64,
    pub repair_round: u64,
}

/// What a fault actually did when it was applied: the plan plus its
/// measured blast radius — the tenants whose pool windows stopped
/// routing. A LinkDown absorbed by redundant lanes has an empty blast.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultRecord {
    pub plan: FaultPlan,
    pub blast: Vec<usize>,
}

#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum TenancyError {
    #[error("a tenant set needs at least one [[tenants]] table")]
    NoTenants,
    #[error("tenant set key '{0}': {1}")]
    BadField(String, String),
}

impl TenantSet {
    /// Parse a tenant set from a `tomlmini` document. `[[tenants]]`
    /// tables carry `name`/`model`/`topology`/`seed`/`weight`/`role`,
    /// plus the serving knobs `rate_per_s`/`max_batch`/`max_wait_us`/
    /// `trace` when `role = "server"`; unknown keys are ignored (the same
    /// tolerance [`Topology::from_doc`] has), malformed ones are
    /// [`TenancyError::BadField`].
    pub fn from_doc(root: &Path, name: &str, doc: &Doc) -> anyhow::Result<TenantSet> {
        let set_name = doc.get("name").and_then(|v| v.as_str()).unwrap_or(name);
        let fabric_levels = match doc.get("fabric.levels") {
            None => 1,
            Some(v) => v.as_i64().filter(|&n| n >= 1).ok_or_else(|| {
                TenancyError::BadField("fabric.levels".into(), "expected integer >= 1".into())
            })? as usize,
        };
        let redundancy = match doc.get("fabric.redundancy") {
            None => 0,
            Some(v) => v.as_i64().filter(|r| (0..=8).contains(r)).ok_or_else(|| {
                TenancyError::BadField(
                    "fabric.redundancy".into(),
                    "expected integer in 0..=8 (spare lanes per fabric edge)".into(),
                )
            })? as u32,
        };
        let policy = match doc.get("arbiter.policy") {
            None => QosPolicy::FairShare,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    TenancyError::BadField("arbiter.policy".into(), "expected string".into())
                })?;
                QosPolicy::parse(s).ok_or_else(|| {
                    TenancyError::BadField(
                        "arbiter.policy".into(),
                        format!("unknown policy '{s}' (expected fair-share|weighted|strict-priority)"),
                    )
                })?
            }
        };
        let n = doc.array_len("tenants");
        if n == 0 {
            return Err(TenancyError::NoTenants.into());
        }
        let mut tenants = Vec::with_capacity(n);
        for i in 0..n {
            let t = doc.sub(&format!("tenants.{i}"));
            let key = |k: &str| format!("tenants.{i}.{k}");
            let tname = match t.get("name") {
                None => format!("tenant-{i}"),
                Some(v) => v
                    .as_str()
                    .ok_or_else(|| {
                        TenancyError::BadField(key("name"), "expected string".into())
                    })?
                    .to_string(),
            };
            let model = t
                .get("model")
                .ok_or_else(|| TenancyError::BadField(key("model"), "required".into()))?
                .as_str()
                .ok_or_else(|| TenancyError::BadField(key("model"), "expected string".into()))?
                .to_string();
            let topo_name = match t.get("topology") {
                None => "cxl",
                Some(v) => v.as_str().ok_or_else(|| {
                    TenancyError::BadField(key("topology"), "expected string".into())
                })?,
            };
            let topology = resolve_topology(root, topo_name)?;
            let seed = match t.get("seed") {
                None => 42 + i as u64,
                Some(v) => v.as_i64().filter(|&s| s >= 0).ok_or_else(|| {
                    TenancyError::BadField(key("seed"), "expected non-negative integer".into())
                })? as u64,
            };
            let weight = match t.get("weight") {
                None => 1,
                Some(v) => v.as_i64().filter(|&w| w >= 1).ok_or_else(|| {
                    TenancyError::BadField(key("weight"), "expected integer >= 1".into())
                })? as u64,
            };
            let role = match t.get("role") {
                None => "trainer",
                Some(v) => v.as_str().ok_or_else(|| {
                    TenancyError::BadField(key("role"), "expected string".into())
                })?,
            };
            let serve = match role {
                "server" => Some(parse_serve(&t, &key)?),
                "trainer" => {
                    for k in ["rate_per_s", "max_batch", "max_wait_us", "trace"] {
                        if t.get(k).is_some() {
                            return Err(TenancyError::BadField(
                                key(k),
                                "serving knob requires role = \"server\"".into(),
                            )
                            .into());
                        }
                    }
                    None
                }
                other => {
                    return Err(TenancyError::BadField(
                        key("role"),
                        format!("unknown role '{other}' (expected trainer|server)"),
                    )
                    .into())
                }
            };
            tenants.push(TenantSpec {
                name: tname,
                model,
                topology,
                seed,
                weight,
                serve,
            });
        }
        // `[[faults]]` tables are parsed AFTER the tenants so `tenant`
        // can resolve by name.
        let mut faults = Vec::new();
        for i in 0..doc.array_len("faults") {
            let f = doc.sub(&format!("faults.{i}"));
            let key = |k: &str| format!("faults.{i}.{k}");
            let kind_s = f
                .get("kind")
                .ok_or_else(|| TenancyError::BadField(key("kind"), "required".into()))?
                .as_str()
                .ok_or_else(|| TenancyError::BadField(key("kind"), "expected string".into()))?;
            let kind = FaultKind::parse(kind_s).ok_or_else(|| {
                TenancyError::BadField(
                    key("kind"),
                    format!(
                        "unknown fault kind '{kind_s}' (expected link-down|switch-down|expander-lost)"
                    ),
                )
            })?;
            let tname = f
                .get("tenant")
                .ok_or_else(|| TenancyError::BadField(key("tenant"), "required".into()))?
                .as_str()
                .ok_or_else(|| TenancyError::BadField(key("tenant"), "expected string".into()))?;
            let tenant = tenants.iter().position(|t| t.name == tname).ok_or_else(|| {
                TenancyError::BadField(key("tenant"), format!("no tenant named '{tname}'"))
            })?;
            let level = match f.get("level") {
                None => None,
                Some(v) => {
                    let l = v.as_i64().filter(|&l| l >= 0).ok_or_else(|| {
                        TenancyError::BadField(key("level"), "expected non-negative integer".into())
                    })? as usize;
                    match kind {
                        FaultKind::ExpanderLost => {
                            return Err(TenancyError::BadField(
                                key("level"),
                                "level only applies to link-down/switch-down".into(),
                            )
                            .into())
                        }
                        FaultKind::LinkDown if !(1..fabric_levels).contains(&l) => {
                            return Err(TenancyError::BadField(
                                key("level"),
                                format!(
                                    "link level must be in 1..={} for a {fabric_levels}-level fabric",
                                    fabric_levels - 1
                                ),
                            )
                            .into())
                        }
                        FaultKind::SwitchDown if l >= fabric_levels => {
                            return Err(TenancyError::BadField(
                                key("level"),
                                format!(
                                    "switch level must be in 0..={} for a {fabric_levels}-level fabric",
                                    fabric_levels - 1
                                ),
                            )
                            .into())
                        }
                        _ => {}
                    }
                    Some(l)
                }
            };
            let round_of = |k: &'static str| -> Result<u64, TenancyError> {
                f.get(k)
                    .ok_or_else(|| TenancyError::BadField(key(k), "required".into()))?
                    .as_i64()
                    .filter(|&r| r >= 0)
                    .map(|r| r as u64)
                    .ok_or_else(|| {
                        TenancyError::BadField(key(k), "expected non-negative integer".into())
                    })
            };
            let inject_round = round_of("inject_round")?;
            let repair_round = round_of("repair_round")?;
            if repair_round <= inject_round {
                return Err(TenancyError::BadField(
                    key("repair_round"),
                    format!(
                        "repair round {repair_round} must come after inject round {inject_round}"
                    ),
                )
                .into());
            }
            faults.push(FaultPlan {
                kind,
                tenant,
                level,
                inject_round,
                repair_round,
            });
        }
        Ok(TenantSet {
            name: set_name.to_string(),
            fabric_levels,
            redundancy,
            policy,
            tenants,
            faults,
        })
    }

    /// Load `configs/topologies/<name>.toml` strictly: any I/O, parse, or
    /// field error is returned to the caller.
    pub fn load_strict(root: &Path, name: &str) -> anyhow::Result<TenantSet> {
        let path = root.join("configs/topologies").join(format!("{name}.toml"));
        let doc = Doc::load(&path)?;
        TenantSet::from_doc(root, name, &doc)
    }
}

/// Resolve a tenant's `topology` key: paper system-config names take the
/// prebuilt topology, anything else loads strictly from
/// `configs/topologies/` (same rule as the CLI's `--topology`).
fn resolve_topology(root: &Path, name: &str) -> anyhow::Result<Topology> {
    match name.parse::<SystemConfig>() {
        Ok(sys) => Ok(Topology::from_system(sys)),
        Err(_) => Topology::load_strict(root, name),
    }
}

/// Parse the serving knobs of a `role = "server"` tenant table into a
/// [`ServeConfig`]; absent knobs take the serving defaults.
fn parse_serve(t: &Doc, key: &impl Fn(&str) -> String) -> Result<ServeConfig, TenancyError> {
    let defaults = ServeConfig::default();
    let rate_per_s = match t.get("rate_per_s") {
        None => defaults.rate_per_s,
        Some(v) => v
            .as_f64()
            .filter(|r| r.is_finite() && *r > 0.0)
            .ok_or_else(|| {
                TenancyError::BadField(key("rate_per_s"), "expected finite rate > 0".into())
            })?,
    };
    let mut policy = defaults.policy;
    if let Some(v) = t.get("max_batch") {
        policy.max_batch = v.as_i64().filter(|&b| b >= 1).ok_or_else(|| {
            TenancyError::BadField(key("max_batch"), "expected integer >= 1".into())
        })? as usize;
    }
    if let Some(v) = t.get("max_wait_us") {
        policy.max_wait_us = v.as_i64().filter(|&w| w >= 0).ok_or_else(|| {
            TenancyError::BadField(key("max_wait_us"), "expected integer >= 0".into())
        })? as u64;
    }
    let trace = match t.get("trace") {
        None => defaults.trace,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| TenancyError::BadField(key("trace"), "expected string".into()))?;
            TraceShape::parse(s).ok_or_else(|| {
                TenancyError::BadField(
                    key("trace"),
                    format!("unknown trace '{s}' (expected steady|diurnal|spike)"),
                )
            })?
        }
    };
    Ok(ServeConfig {
        rate_per_s,
        policy,
        trace,
    })
}

// ============================================================== arbiter

/// QoS scheduler of the shared pool: turns a policy + per-tenant weights
/// into the global service order of (tenant, batch) slots.
#[derive(Clone, Debug)]
pub struct PoolArbiter {
    policy: QosPolicy,
    weights: Vec<u64>,
}

impl PoolArbiter {
    pub fn new(policy: QosPolicy, weights: Vec<u64>) -> Result<PoolArbiter, TenancyError> {
        if weights.is_empty() {
            return Err(TenancyError::NoTenants);
        }
        if weights.contains(&0) {
            return Err(TenancyError::BadField(
                "weight".into(),
                "every tenant weight must be >= 1".into(),
            ));
        }
        Ok(PoolArbiter { policy, weights })
    }

    pub fn policy(&self) -> QosPolicy {
        self.policy
    }

    /// The service order as **rounds**: each round is a list of
    /// `(tenant, quantum)` pairs, every tenant appearing at most once per
    /// round. A round is the engine's barrier unit — its lanes share one
    /// round-entry pool snapshot and run concurrently; consecutive slots
    /// of one quantum stay back-to-back on the tenant's lane clock,
    /// exactly as the flat schedule served them.
    ///
    /// * fair-share: `batches` rounds of `(i, 1)` for every tenant;
    /// * weighted: weighted-round-robin cycles of `(i, min(weight_i,
    ///   remaining_i))` until every tenant has its `batches`;
    /// * strict-priority: one round per tenant, `(i, batches)` — a full
    ///   drain, which is why the top tenant never waits.
    pub fn rounds(&self, batches: u64) -> Vec<Vec<(usize, u64)>> {
        let n = self.weights.len();
        let mut rounds = Vec::new();
        match self.policy {
            QosPolicy::StrictPriority => {
                if batches > 0 {
                    for i in 0..n {
                        rounds.push(vec![(i, batches)]);
                    }
                }
            }
            QosPolicy::FairShare => {
                for _ in 0..batches {
                    rounds.push((0..n).map(|i| (i, 1)).collect());
                }
            }
            QosPolicy::Weighted => {
                let mut remaining = vec![batches; n];
                while remaining.iter().any(|&r| r > 0) {
                    let mut round = Vec::new();
                    for (i, rem) in remaining.iter_mut().enumerate() {
                        let quantum = self.weights[i].min(*rem);
                        if quantum > 0 {
                            round.push((i, quantum));
                        }
                        *rem -= quantum;
                    }
                    rounds.push(round);
                }
            }
        }
        rounds
    }

    /// The flat global service order for `batches` batches per tenant: a
    /// sequence of tenant indices in which every tenant appears exactly
    /// `batches` times — the policy reorders pool service, it never
    /// creates or destroys slots (pinned by `prop_arbiter_schedules_
    /// conserve_pool_slots`). Defined as the flattening of
    /// [`PoolArbiter::rounds`], so the two views cannot diverge.
    pub fn schedule(&self, batches: u64) -> Vec<usize> {
        self.rounds(batches)
            .iter()
            .flat_map(|round| {
                round
                    .iter()
                    .flat_map(|&(i, q)| std::iter::repeat(i).take(q as usize))
            })
            .collect()
    }
}

// ==================================================== pool partitioning

/// The shared pool's persistent log space, partitioned into per-tenant
/// slices: tenant `i` owns HPA window `[i * slice, i * slice + slice)`
/// and its own [`LogRegion`] — one tenant's undo generations can never
/// alias another's, which is what makes per-tenant crash recovery a
/// purely local replay.
#[derive(Clone, Debug, Default)]
pub struct PoolPartition {
    pub slice_bytes: u64,
    pub regions: Vec<LogRegion>,
}

impl PoolPartition {
    pub fn new(tenants: usize, slice_bytes: u64) -> PoolPartition {
        PoolPartition {
            slice_bytes,
            regions: vec![LogRegion::new(); tenants],
        }
    }

    /// The partition layout: `(start, len)` of window `i` for a given
    /// slice size — shared by [`PoolPartition::window`] and the fabric
    /// attachment in [`MultiTenantSim::new`] so the two cannot diverge.
    pub fn window_of(i: usize, slice_bytes: u64) -> (u64, u64) {
        (i as u64 * slice_bytes, slice_bytes)
    }

    /// `(start, len)` of tenant `i`'s HPA window in the pool.
    pub fn window(&self, i: usize) -> (u64, u64) {
        Self::window_of(i, self.slice_bytes)
    }

    pub fn region(&self, i: usize) -> &LogRegion {
        &self.regions[i]
    }

    pub fn region_mut(&mut self, i: usize) -> &mut LogRegion {
        &mut self.regions[i]
    }
}

// ========================================================== simulation

/// Crash injection for [`MultiTenantSim::run_with_crash`]: power fails on
/// `tenant` while it commits batch `batch`. The torn batch is recovered
/// from the tenant's own log slice and replayed inside the same arbiter
/// slot, so co-tenants never observe the failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashPlan {
    pub tenant: usize,
    pub batch: u64,
}

/// One tenant's finished run.
#[derive(Clone, Debug)]
pub struct TenantRunResult {
    pub name: String,
    /// The same record a solo [`PipelineSim`](crate::sched::PipelineSim)
    /// run returns. A recovered tenant's crashed batch carries the whole
    /// crash cycle in its `batch_times` entry (torn run + undo replay +
    /// re-execution).
    pub result: RunResult,
    /// Co-tenant pool occupancy (ns) charged before each batch.
    pub stalls: Vec<u64>,
    /// This tenant's own cumulative pool-busy ns.
    pub pool_busy_ns: u64,
    /// Batches scheduled (and completed) by the arbiter.
    pub batches: u64,
    /// Crash/recovery cycles this tenant went through.
    pub recoveries: u64,
    /// Arbiter rounds whose quantum was deferred because a fabric fault
    /// made this tenant's pool window unreachable.
    pub stalled_rounds: u64,
    /// Wall-clock ns this tenant lost to fabric faults: degraded-edge
    /// inflation penalties plus the pool stall absorbed at re-entry
    /// after an outage.
    pub fault_stall_ns: u64,
    /// Ns spent replaying the undo slice after an expander loss tore
    /// this tenant's in-flight rows (0 unless an ExpanderLost hit it).
    pub fault_recovery_ns: u64,
    /// Serving-side counters (latency histogram, staleness gauge,
    /// request count) — `Some` exactly for `role = "server"` tenants.
    pub serve: Option<ServeStats>,
}

impl TenantRunResult {
    pub fn total_stall_ns(&self) -> u64 {
        self.stalls.iter().sum()
    }

    /// p99 of the per-batch charged stalls (ns).
    pub fn p99_stall_ns(&self) -> f64 {
        if self.stalls.is_empty() {
            return 0.0;
        }
        let mut s = self.stalls.clone();
        s.sort_unstable();
        let rank = ((s.len() as f64) * 0.99).ceil() as usize;
        s[rank.clamp(1, s.len()) - 1] as f64
    }

    /// Completed batches per wall-clock second of the tenant's timeline.
    pub fn throughput_batches_per_s(&self) -> f64 {
        if self.result.total_time == 0 {
            return 0.0;
        }
        self.batches as f64 * 1e9 / self.result.total_time as f64
    }
}

/// Jain's fairness index over per-tenant throughputs: 1.0 = perfectly
/// fair, 1/n = one tenant got everything.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

/// Everything a multi-tenant run produced.
#[derive(Clone, Debug)]
pub struct MultiTenantRun {
    pub tenants: Vec<TenantRunResult>,
    /// Per-link byte/occupancy counters of the switch tree (empty for the
    /// depth-1 fabric, which has no internal links).
    pub links: Vec<(String, LinkStats)>,
    pub levels: usize,
    /// Every fabric fault applied during the run, with its measured
    /// blast radius, in injection order.
    pub faults: Vec<FaultRecord>,
    /// The run's causal trace: every round, slot, recovery, resource
    /// grant, fabric transfer, and fault/crash instant, recorded on the
    /// merge thread — byte-identical at any worker count.
    pub trace: TraceLog,
}

/// A tenant lane's simulator: the full training pipeline or the
/// read-only serving chain, both advancing over the shared pool clock.
enum LaneSim {
    Trainer(PipelineSim),
    Server(ServingSim),
}

impl LaneSim {
    fn env(&self) -> &PipelineEnv {
        match self {
            LaneSim::Trainer(s) => s.env(),
            LaneSim::Server(s) => s.env(),
        }
    }

    fn env_mut(&mut self) -> &mut PipelineEnv {
        match self {
            LaneSim::Trainer(s) => s.env_mut(),
            LaneSim::Server(s) => s.env_mut(),
        }
    }
}

/// One tenant's live lane: its solo simulator + local clock and
/// accumulators.
struct TenantLane {
    name: String,
    sim: LaneSim,
    t: SimTime,
    next_batch: u64,
    breakdowns: Vec<Breakdown>,
    batch_times: Vec<SimTime>,
    stalls: Vec<u64>,
    /// Own cumulative pool-busy ns — what co-tenants absorb as
    /// interference.
    pool_busy_total: u64,
    /// Co-tenant pool-busy ns already charged to this lane.
    foreign_charged: u64,
    /// Spans already folded into `pool_busy_total` (incremental scan).
    spans_seen: usize,
    /// Link bytes already forwarded through the fabric tree.
    link_seen: u64,
    /// Trainer-head value at this (server) lane's last pool read —
    /// feeds the staleness gauge.
    head_seen: u64,
    recoveries: u64,
    /// Degraded-edge penalty ns accumulated at merge time, consumed
    /// (charged to `pmem_free` as a fault stall) at next quantum entry.
    pending_fault_stall_ns: u64,
    /// The lane's next quantum is its first after a fabric outage: the
    /// pool stall it absorbs on entry is attributed to the fault.
    pending_reentry: bool,
    /// An expander loss tore this lane's in-flight rows: replay the undo
    /// slice at the next quantum entry (trainers only).
    pending_recovery: bool,
    stalled_rounds: u64,
    fault_stall_ns: u64,
    fault_recovery_ns: u64,
}

impl TenantLane {
    /// Run one batch on the lane's local clock, through the exact
    /// [`PipelineSim::step_batch`] (trainer) or
    /// [`ServingSim::step_batch`] (server) loop a solo run uses. Trainer
    /// batch times span from the lane clock; server batch times are the
    /// service time only (flush-to-completion), matching the standalone
    /// [`ServingSim::run`] accounting bit-for-bit.
    fn run_batch(&mut self, batch: u64) {
        match &mut self.sim {
            LaneSim::Trainer(sim) => {
                let ctx = sim.step_batch(batch, self.t);
                self.breakdowns.push(ctx.bd);
                self.batch_times.push(ctx.end - self.t);
                self.t = ctx.end;
            }
            LaneSim::Server(sim) => {
                let out = sim.step_batch(batch, self.t);
                self.breakdowns.push(out.bd);
                self.batch_times.push(out.end - out.start);
                self.t = out.end;
            }
        }
        // Incremental pool-occupancy accounting: fold in only the spans
        // this batch appended. Every pool op serialises through
        // `pmem_free`, so `Lane::Pmem` spans never overlap and the plain
        // sum IS the merged busy time.
        let spans = &self.sim.env().spans.spans;
        let new: u64 = spans[self.spans_seen..]
            .iter()
            .filter(|s| s.lane == Lane::Pmem)
            .map(|s| s.end - s.start)
            .sum();
        self.spans_seen = spans.len();
        self.pool_busy_total += new;
    }

    /// Run one arbiter quantum (`quantum` consecutive batches) against the
    /// round-entry snapshots: `global` is the pool ledger's busy total and
    /// `head` the trainer head when the round opened. Entirely lane-local —
    /// no shared state is touched, which is what lets a round's quanta run
    /// on the worker pool — and returns the deltas the deterministic merge
    /// folds back into the fabric and ledger.
    ///
    /// The co-tenant stall is charged ONCE at quantum entry (the
    /// remaining batches of the quantum run back-to-back, so no new
    /// foreign occupancy can appear between them — the same zero the flat
    /// interleaver produced for consecutive slots of one tenant), and a
    /// stall entry is still recorded per batch so `stalls.len()` stays
    /// equal to the batch count.
    fn run_quantum(
        &mut self,
        lane_idx: usize,
        quantum: u64,
        global: u64,
        head: u64,
        crash: Option<CrashPlan>,
    ) -> QuantumOutcome {
        let pool_before = self.pool_busy_total;
        let gpu_before = self.sim.env().gpu_busy;
        let foreign = global - self.pool_busy_total;
        let stall = foreign - self.foreign_charged;
        self.foreign_charged = foreign;
        self.sim.env_mut().pmem_free += stall;

        // Fabric-fault accounting, all at quantum entry. Degraded-edge
        // penalties accumulated at merge time push the pool horizon out
        // exactly like a co-tenant stall; a re-entry after an outage
        // attributes the foreign stall that built up during it to the
        // fault; a torn expander replays the lane's own undo slice
        // (trainers only — servers are stateless and simply re-read).
        let fault_stall = self.pending_fault_stall_ns;
        self.pending_fault_stall_ns = 0;
        self.sim.env_mut().pmem_free += fault_stall;
        self.fault_stall_ns += fault_stall;
        if self.pending_reentry {
            self.pending_reentry = false;
            self.fault_stall_ns += stall;
        }
        let mut entry_recovery = None;
        if self.pending_recovery {
            self.pending_recovery = false;
            if matches!(self.sim, LaneSim::Trainer(_)) {
                let env = self.sim.env();
                let replay_bytes = env.stats.unique_rows * env.cfg.row_bytes();
                let pause = env.cxl.transfer(2 * replay_bytes, Proto::Mem).duration.max(1);
                entry_recovery = Some((self.t, self.t + pause));
                self.t += pause;
                self.fault_recovery_ns += pause;
                self.recoveries += 1;
            }
        }

        let mut links = Vec::with_capacity(quantum as usize);
        let mut slots = Vec::with_capacity(quantum as usize);
        let mut trainer_batches = 0;
        for k in 0..quantum {
            self.stalls.push(if k == 0 { stall + fault_stall } else { 0 });
            let b = self.next_batch;
            if let LaneSim::Server(sim) = &mut self.sim {
                // the embeddings this serving batch reads were last
                // refreshed at the server's previous pool access; every
                // trainer batch committed since then aged them by one
                sim.note_staleness(head - self.head_seen);
                self.head_seen = head;
            }
            self.run_batch(b);
            let mut recovery_ns = 0;
            let is_trainer = matches!(self.sim, LaneSim::Trainer(_));
            if is_trainer
                && crash
                    == Some(CrashPlan {
                        tenant: lane_idx,
                        batch: b,
                    })
            {
                // Power failed as batch `b` committed. Recovery is purely
                // tenant-local: the torn rows are rolled back from the
                // tenant's own undo slice (read the log + rewrite the
                // rows over its leaf link) and the batch is re-executed,
                // priced at the torn batch's duration. Both are charged
                // to the victim's WALL CLOCK only — its pool image after
                // replay is what the single clean execution produced, so
                // the pipeline state, pool occupancy, and the arbiter
                // schedule all stay exactly as in a crash-free run and
                // co-tenants cannot observe the failure.
                let torn = *self.batch_times.last().expect("just ran");
                let env = self.sim.env();
                let replay_bytes = env.stats.unique_rows * env.cfg.row_bytes();
                let pause = env.cxl.transfer(2 * replay_bytes, Proto::Mem).duration;
                let cost = pause.max(1) + torn;
                self.t += cost;
                *self.batch_times.last_mut().expect("just ran") += cost;
                self.recoveries += 1;
                recovery_ns = cost;
            }
            self.next_batch = b + 1;
            if is_trainer {
                trainer_batches += 1;
            }
            let link_total = self.sim.env().traffic.link_bytes;
            let delta = link_total - self.link_seen;
            self.link_seen = link_total;
            let busy = *self.batch_times.last().expect("run_batch pushed a time");
            links.push((delta, busy));
            // The slot's trace record, on the lane's own clock: trainers
            // span [clock-before, clock-after]; servers span the service
            // window (their batch time is flush-to-completion). Both are
            // exactly `busy` wide, ending at the lane clock.
            slots.push(SlotTrace {
                batch: b,
                start: self.t - busy,
                end: self.t,
                stall_ns: if k == 0 { stall } else { 0 },
                fault_stall_ns: if k == 0 { fault_stall } else { 0 },
                recovery_ns,
                bd: *self.breakdowns.last().expect("run_batch pushed a breakdown"),
            });
        }
        let env = self.sim.env();
        QuantumOutcome {
            pool_busy_delta: self.pool_busy_total - pool_before,
            gpu_busy_delta: env.gpu_busy - gpu_before,
            link_resource: if env.topo.hw_data_movement {
                Resource::CxlLink
            } else {
                Resource::PcieLink
            },
            links,
            trainer_batches,
            trace: QuantumTrace {
                entry_recovery,
                slots,
            },
        }
    }
}

/// What one lane quantum hands back to the deterministic merge: the busy
/// deltas for the resource ledger and the per-batch fabric transfers,
/// replayed against the switch tree in round order.
struct QuantumOutcome {
    pool_busy_delta: u64,
    gpu_busy_delta: u64,
    /// Which analyzer resource this lane's movement traffic occupies
    /// (DCOH hardware movement rides `CxlLink`, software staging
    /// `PcieLink`).
    link_resource: Resource,
    /// Per batch: (fabric bytes appended, batch busy ns).
    links: Vec<(u64, u64)>,
    trainer_batches: u64,
    /// Lane-local trace records, handed back so the merge thread — and
    /// only the merge thread — appends to the run's [`TraceLog`].
    trace: QuantumTrace,
}

/// What a quantum contributes to the trace, recorded lane-locally in
/// deterministic per-lane order and folded in on the merge thread.
struct QuantumTrace {
    /// Undo-slice replay at quantum entry (torn expander), as a
    /// `(start, end)` window on the lane clock.
    entry_recovery: Option<(SimTime, SimTime)>,
    /// One record per batch slot, aligned with `QuantumOutcome::links`.
    slots: Vec<SlotTrace>,
}

/// One batch slot's trace record on the lane clock.
struct SlotTrace {
    batch: u64,
    start: SimTime,
    end: SimTime,
    /// Co-tenant pool stall absorbed at this slot (first of a quantum).
    stall_ns: u64,
    /// Fabric-fault stall absorbed at this slot (first of a quantum).
    fault_stall_ns: u64,
    /// Crash-recovery cost charged inside this slot.
    recovery_ns: u64,
    bd: Breakdown,
}

/// N tenants interleaved by a [`PoolArbiter`] over a shared PMEM pool
/// mounted on a [`FabricTree`], executed round-by-round on the
/// discrete-event engine with the round's lanes fanned out over a worker
/// pool (see the module docs for the determinism contract).
pub struct MultiTenantSim {
    lanes: Vec<TenantLane>,
    arbiter: PoolArbiter,
    fabric: FabricTree,
    windows: Vec<(u64, u64)>,
    levels: usize,
    /// Trainer batches committed to the pool so far, across all trainer
    /// lanes — the "training head" server staleness is measured against.
    /// Lanes read the round-entry snapshot; the merge advances it.
    trainer_head: u64,
    /// Worker threads per round ([`MultiTenantSim::with_workers`]).
    workers: usize,
    /// Busy totals per analyzer [`Resource`], charged at merge time. The
    /// `PmemPool` entry is load-bearing: it IS the global pool-pressure
    /// snapshot each round's stall accounting starts from.
    ledger: ResourceLedger,
    /// The set's scheduled fabric faults; `FabricFault`/`FabricRepair`
    /// events index into this table.
    faults: Vec<FaultPlan>,
    /// Per tenant: the internal switches of its leaf path, root-side
    /// first (empty on a depth-1 fabric).
    tenant_paths: Vec<Vec<NodeId>>,
    /// Per tenant: (leaf node, device port) its pool window attaches at.
    dev_ports: Vec<(NodeId, PortId)>,
    /// The run's causal trace; appended to on the merge thread only.
    trace: TraceLog,
    /// Id of the root `Run` span in `trace` (closed when the run ends).
    trace_root: u32,
}

impl MultiTenantSim {
    /// Build the fabric tree (one leaf path per tenant) and every
    /// tenant's simulator through [`PipelineSim::for_model`] — the SAME
    /// construction point the solo bench drivers use, so the
    /// single-tenant depth-1 case is structurally bit-identical to them
    /// (and pinned so in `tests/topology_equiv.rs`). Each extra fabric
    /// level deepens every tenant's `pool.extra_hops` by one.
    pub fn new(root: &Path, set: &TenantSet) -> anyhow::Result<MultiTenantSim> {
        anyhow::ensure!(!set.tenants.is_empty(), "tenant set '{}' is empty", set.name);
        anyhow::ensure!(
            set.fabric_levels >= 1,
            "tenant set '{}': fabric needs at least one switch level",
            set.name
        );
        let arbiter = PoolArbiter::new(
            set.policy,
            set.tenants.iter().map(|t| t.weight).collect(),
        )?;
        for (fi, f) in set.faults.iter().enumerate() {
            anyhow::ensure!(
                f.tenant < set.tenants.len(),
                "tenant set '{}': faults.{fi} targets tenant {} of {}",
                set.name,
                f.tenant,
                set.tenants.len()
            );
            anyhow::ensure!(
                f.repair_round > f.inject_round,
                "tenant set '{}': faults.{fi} repairs at round {} before its injection at {}",
                set.name,
                f.repair_round,
                f.inject_round
            );
            if let Some(l) = f.level {
                let ok = match f.kind {
                    FaultKind::LinkDown => (1..set.fabric_levels).contains(&l),
                    FaultKind::SwitchDown => l < set.fabric_levels,
                    FaultKind::ExpanderLost => false,
                };
                anyhow::ensure!(
                    ok,
                    "tenant set '{}': faults.{fi} level {l} is invalid for {} on a {}-level fabric",
                    set.name,
                    f.kind.name(),
                    set.fabric_levels
                );
            }
        }
        let mut fabric = FabricTree::new("pool-root");
        fabric.set_redundancy(set.redundancy);
        let mut windows = Vec::with_capacity(set.tenants.len());
        let mut lanes = Vec::with_capacity(set.tenants.len());
        let mut tenant_paths = Vec::with_capacity(set.tenants.len());
        let mut dev_ports = Vec::with_capacity(set.tenants.len());
        for (i, spec) in set.tenants.iter().enumerate() {
            // the tenant's leaf path: one switch per extra fabric level
            let mut at: NodeId = ROOT;
            let mut path = Vec::with_capacity(set.fabric_levels - 1);
            for lvl in 1..set.fabric_levels {
                at = fabric.add_switch(at, &format!("{}-l{lvl}", spec.name))?;
                path.push(at);
            }
            let (start, len) = PoolPartition::window_of(i, TENANT_SLICE_BYTES);
            let port = fabric.attach_device(at, &spec.name, start, len)?;
            windows.push((start, len));
            tenant_paths.push(path);
            dev_ports.push((at, port));

            let mut topo = spec.topology.clone();
            topo.pool.extra_hops += set.fabric_levels - 1;
            let sim = match &spec.serve {
                None => {
                    LaneSim::Trainer(PipelineSim::for_model(root, &spec.model, topo, spec.seed)?)
                }
                Some(sc) => {
                    LaneSim::Server(ServingSim::for_model(root, &spec.model, topo, spec.seed, sc)?)
                }
            };
            lanes.push(TenantLane {
                name: spec.name.clone(),
                sim,
                t: 0,
                next_batch: 0,
                breakdowns: Vec::new(),
                batch_times: Vec::new(),
                stalls: Vec::new(),
                pool_busy_total: 0,
                foreign_charged: 0,
                spans_seen: 0,
                link_seen: 0,
                head_seen: 0,
                recoveries: 0,
                pending_fault_stall_ns: 0,
                pending_reentry: false,
                pending_recovery: false,
                stalled_rounds: 0,
                fault_stall_ns: 0,
                fault_recovery_ns: 0,
            });
        }
        let mut trace = TraceLog::new();
        let trace_root = trace.record(TraceEvent::span(None, None, TraceKind::Run, 0, 0));
        Ok(MultiTenantSim {
            lanes,
            arbiter,
            fabric,
            windows,
            levels: set.fabric_levels,
            trainer_head: 0,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            ledger: ResourceLedger::new(),
            faults: set.faults.clone(),
            tenant_paths,
            dev_ports,
            trace,
            trace_root,
        })
    }

    /// Pin the worker-pool width for round execution. Any value produces
    /// byte-identical results (pinned in `tests/engine_determinism.rs`);
    /// `1` runs rounds inline with no threads. The default is the
    /// machine's available parallelism.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Run `batches` batches per tenant in the arbiter's service order.
    pub fn run(self, batches: u64) -> MultiTenantRun {
        self.run_with_crash(batches, None)
    }

    /// [`MultiTenantSim::run`] with an injected power failure: the
    /// crashed tenant pays a tenant-local recovery cycle (its undo slice
    /// streamed back over its own leaf link, then the torn batch
    /// re-executed) on its own wall clock, inside the same arbiter slot.
    /// Its pool image after replay is what the clean execution produced,
    /// so co-tenants observe an identical schedule and identical pool
    /// occupancy — their `RunResult`s are bit-identical to the
    /// crash-free run. Server lanes are stateless (read-only, no undo
    /// log): a crash plan targeting one is a no-op — the restarted
    /// server simply re-reads the pool.
    ///
    /// The run is an event pump: the crash plan is injected as a
    /// [`CrashInject`](Event::CrashInject) event at t=0 (armed before any
    /// round opens, by the queue's stable tie-break), then every arbiter
    /// round opens on the round clock, fans its quanta out over the
    /// worker pool, and merges deterministically before the next round
    /// fires.
    pub fn run_with_crash(mut self, batches: u64, crash: Option<CrashPlan>) -> MultiTenantRun {
        let rounds = self.arbiter.rounds(batches);
        let mut q: EventQueue<Event> = EventQueue::new();
        if let Some(c) = crash {
            q.schedule(
                0,
                Event::CrashInject {
                    lane: c.tenant,
                    batch: c.batch,
                },
            );
        }
        // Fault/repair events are scheduled BEFORE the rounds, so the
        // queue's stable tie-break applies a fault ahead of the
        // same-time RoundOpen. Repairs past the last round still fire
        // (the queue drains fully), so every deferred quantum completes
        // and `batches` keeps its meaning in a faulted run.
        for fi in 0..self.faults.len() {
            let f = self.faults[fi];
            q.schedule(f.inject_round as SimTime, Event::FabricFault { fault: fi });
            q.schedule(f.repair_round as SimTime, Event::FabricRepair { fault: fi });
        }
        for r in 0..rounds.len() {
            q.schedule(r as SimTime, Event::RoundOpen { round: r });
        }
        let mut armed: Option<CrashPlan> = None;
        // Quanta deferred while their lane's pool window cannot route,
        // FIFO, coalesced per lane.
        let mut deferred: Vec<(usize, u64)> = Vec::new();
        let mut records: Vec<FaultRecord> = Vec::new();
        while let Some((at, ev)) = q.pop() {
            match ev {
                Event::CrashInject { lane, batch } => {
                    armed = Some(CrashPlan {
                        tenant: lane,
                        batch,
                    });
                    self.trace.record(TraceEvent::instant(
                        Some(self.trace_root),
                        Some(lane as u32),
                        TraceKind::CrashArm { batch },
                        0,
                    ));
                }
                Event::FabricFault { fault } => {
                    let plan = self.faults[fault];
                    let before = self.reachability();
                    self.apply_fault(&plan);
                    let after = self.reachability();
                    let blast: Vec<usize> =
                        (0..after.len()).filter(|&i| before[i] && !after[i]).collect();
                    if plan.kind.tears_data() {
                        // the expander lost the rows in flight: its
                        // tenants replay their undo slices at re-entry
                        for &i in &blast {
                            self.lanes[i].pending_recovery = true;
                        }
                    }
                    records.push(FaultRecord { plan, blast });
                    // the round clock counts rounds, not ns: stamp the
                    // instant on the merged lane horizon instead
                    let t = self.lane_horizon();
                    self.trace.record(TraceEvent::instant(
                        Some(self.trace_root),
                        Some(plan.tenant as u32),
                        TraceKind::FabricFault { fault },
                        t,
                    ));
                }
                Event::FabricRepair { fault } => {
                    let plan = self.faults[fault];
                    self.repair_fault(&plan);
                    let t = self.lane_horizon();
                    self.trace.record(TraceEvent::instant(
                        Some(self.trace_root),
                        Some(plan.tenant as u32),
                        TraceKind::FabricRepair { fault },
                        t,
                    ));
                    // catch-up round: deferred quanta whose windows
                    // route again re-enter before the next round opens
                    let ready = self.take_runnable(&mut deferred);
                    if !ready.is_empty() {
                        self.run_round(
                            &ready,
                            armed,
                            TraceKind::Round {
                                round: fault,
                                catch_up: true,
                            },
                        );
                    }
                }
                Event::RoundOpen { round } => {
                    let mut ready = self.take_runnable(&mut deferred);
                    for &(i, quantum) in &rounds[round] {
                        if self.fabric.route(self.windows[i].0).is_ok() {
                            merge_quantum(&mut ready, i, quantum);
                        } else {
                            self.lanes[i].stalled_rounds += 1;
                            self.lanes[i].pending_reentry = true;
                            merge_quantum(&mut deferred, i, quantum);
                        }
                    }
                    if !ready.is_empty() {
                        self.run_round(
                            &ready,
                            armed,
                            TraceKind::Round {
                                round,
                                catch_up: false,
                            },
                        );
                    }
                    q.schedule(at, Event::RoundClose { round });
                }
                Event::RoundClose { .. } => {}
                Event::SlotStart { .. } | Event::SlotDone { .. } => {
                    unreachable!("slot events are pumped inside the lanes")
                }
            }
        }
        debug_assert!(
            deferred.is_empty(),
            "every fault repairs, so no quantum stays deferred"
        );
        let end = self.lane_horizon();
        self.trace.close(self.trace_root, 0, end);
        let trace = self.trace;
        debug_assert!(trace.validate().is_ok(), "{:?}", trace.validate());
        let links = self.fabric.links();
        let levels = self.levels;
        let tenants = self
            .lanes
            .into_iter()
            .map(|lane| {
                let (result, serve) = match lane.sim {
                    LaneSim::Trainer(sim) => {
                        (sim.finish(lane.breakdowns, lane.batch_times, lane.t), None)
                    }
                    LaneSim::Server(sim) => {
                        let (result, stats) =
                            sim.finish(lane.breakdowns, lane.batch_times, lane.t);
                        (result, Some(stats))
                    }
                };
                TenantRunResult {
                    name: lane.name,
                    result,
                    stalls: lane.stalls,
                    pool_busy_ns: lane.pool_busy_total,
                    batches,
                    recoveries: lane.recoveries,
                    stalled_rounds: lane.stalled_rounds,
                    fault_stall_ns: lane.fault_stall_ns,
                    fault_recovery_ns: lane.fault_recovery_ns,
                    serve,
                }
            })
            .collect();
        MultiTenantRun {
            tenants,
            links,
            levels,
            faults: records,
            trace,
        }
    }

    /// The merged lane-clock horizon: the furthest any lane has run.
    /// Fault/crash instants are stamped here (the event queue's round
    /// clock counts rounds, not ns), and the root `Run` span closes at
    /// the final horizon — deterministic, merge-thread-only state.
    fn lane_horizon(&self) -> SimTime {
        self.lanes.iter().map(|l| l.t).max().unwrap_or(0)
    }

    /// Whether each tenant's pool window currently routes.
    fn reachability(&self) -> Vec<bool> {
        self.windows.iter().map(|&(s, _)| self.fabric.route(s).is_ok()).collect()
    }

    /// Pull the deferred quanta whose windows route again, coalescing a
    /// lane's FIFO backlog into one quantum (a round visits each lane at
    /// most once); the rest stay deferred in order.
    fn take_runnable(&mut self, deferred: &mut Vec<(usize, u64)>) -> Vec<(usize, u64)> {
        let mut ready: Vec<(usize, u64)> = Vec::new();
        let mut still: Vec<(usize, u64)> = Vec::new();
        for (i, quantum) in deferred.drain(..) {
            if self.fabric.route(self.windows[i].0).is_ok() {
                merge_quantum(&mut ready, i, quantum);
            } else {
                merge_quantum(&mut still, i, quantum);
            }
        }
        *deferred = still;
        ready
    }

    /// Where a plan lands on the fabric (see [`FaultPlan::level`]): a
    /// switch, an uplink edge, or the victim tenant's device port.
    fn fault_site(&self, plan: &FaultPlan) -> FaultSite {
        let path = &self.tenant_paths[plan.tenant];
        let (leaf, port) = self.dev_ports[plan.tenant];
        match plan.kind {
            FaultKind::LinkDown => match plan.level {
                Some(l) => FaultSite::Uplink(path[l - 1]),
                None if path.is_empty() => FaultSite::DevicePort(leaf, port),
                None => FaultSite::Uplink(*path.last().expect("checked non-empty")),
            },
            FaultKind::SwitchDown => FaultSite::Switch(match plan.level {
                Some(0) => ROOT,
                Some(l) => path[l - 1],
                None => path.last().copied().unwrap_or(ROOT),
            }),
            FaultKind::ExpanderLost => FaultSite::Expander(leaf, port),
        }
    }

    fn apply_fault(&mut self, plan: &FaultPlan) {
        match self.fault_site(plan) {
            FaultSite::Uplink(n) => self.fabric.fail_uplink(n),
            FaultSite::Switch(n) => self.fabric.fail_switch(n),
            FaultSite::DevicePort(n, p) => self.fabric.fail_device_port(n, p),
            FaultSite::Expander(n, p) => self.fabric.lose_expander(n, p),
        }
        .expect("fault plans are validated at construction");
    }

    fn repair_fault(&mut self, plan: &FaultPlan) {
        match self.fault_site(plan) {
            FaultSite::Uplink(n) => self.fabric.repair_uplink(n),
            FaultSite::Switch(n) => self.fabric.repair_switch(n),
            FaultSite::DevicePort(n, p) => self.fabric.repair_device_port(n, p),
            FaultSite::Expander(n, p) => self.fabric.restore_expander(n, p),
        }
        .expect("fault plans are validated at construction");
    }

    /// One arbiter round: snapshot the shared state (pool ledger, trainer
    /// head), fan the round's (lane, quantum) pairs out over the worker
    /// pool, then merge the outcomes back **in round order** — fabric
    /// forwarding, ledger charges, the trainer head, and the trace only
    /// ever mutate here, on one thread, in a thread-count-independent
    /// order. `kind` is the `Round` record this round appends (catch-up
    /// rounds carry their fault index); its span closes over its
    /// children's extent on the lane clocks.
    fn run_round(&mut self, round: &[(usize, u64)], crash: Option<CrashPlan>, kind: TraceKind) {
        let global = self.ledger.busy(Resource::PmemPool);
        let head = self.trainer_head;
        let mut slots: Vec<Option<TenantLane>> =
            std::mem::take(&mut self.lanes).into_iter().map(Some).collect();
        let tasks: Vec<(usize, u64, TenantLane)> = round
            .iter()
            .map(|&(i, quantum)| {
                let lane = slots[i]
                    .take()
                    .expect("arbiter rounds visit each lane at most once");
                (i, quantum, lane)
            })
            .collect();
        let done = run_tasks(tasks, self.workers, move |_, (i, quantum, mut lane)| {
            let outcome = lane.run_quantum(i, quantum, global, head, crash);
            (i, lane, outcome)
        });
        let round_id = self
            .trace
            .record(TraceEvent::span(Some(self.trace_root), None, kind, 0, 0));
        let (mut lo, mut hi) = (SimTime::MAX, 0);
        for (i, mut lane, out) in done {
            let tenant = Some(i as u32);
            self.trainer_head += out.trainer_batches;
            self.ledger.charge_traced(
                Resource::PmemPool,
                out.pool_busy_delta,
                &mut self.trace,
                Some(round_id),
                tenant,
            );
            if out.gpu_busy_delta > 0 {
                self.ledger.charge_traced(
                    Resource::GpuLane,
                    out.gpu_busy_delta,
                    &mut self.trace,
                    Some(round_id),
                    tenant,
                );
            }
            if let Some((rs, re)) = out.trace.entry_recovery {
                lo = lo.min(rs);
                hi = hi.max(re);
                self.trace.record(TraceEvent::span(
                    Some(round_id),
                    tenant,
                    TraceKind::Recovery,
                    rs,
                    re,
                ));
            }
            for (s, &(delta, busy)) in out.trace.slots.iter().zip(&out.links) {
                lo = lo.min(s.start);
                hi = hi.max(s.end);
                let slot_kind = TraceKind::slot(
                    s.batch,
                    s.end - s.start,
                    s.stall_ns,
                    s.fault_stall_ns,
                    s.recovery_ns,
                    &s.bd,
                );
                let mut ev = TraceEvent::span(Some(round_id), tenant, slot_kind, s.start, s.end);
                ev.resource = Some(out.link_resource);
                let slot_id = self.trace.record(ev);
                if delta > 0 {
                    // a degraded path stretches the transfer; the
                    // inflation comes back as a penalty the lane absorbs
                    // as a fault stall at its next quantum entry
                    let (_, penalty) = self
                        .fabric
                        .forward_counted(self.windows[i].0, delta, busy)
                        .expect("lanes only run while their window routes");
                    lane.pending_fault_stall_ns += penalty;
                    self.ledger.charge_traced(
                        out.link_resource,
                        busy,
                        &mut self.trace,
                        Some(slot_id),
                        tenant,
                    );
                    let mut tr = TraceEvent::span(
                        Some(slot_id),
                        tenant,
                        TraceKind::Transfer { bytes: delta },
                        s.start,
                        s.end,
                    );
                    tr.lane = Some(Lane::Link);
                    self.trace.record(tr);
                }
            }
            slots[i] = Some(lane);
        }
        if lo <= hi {
            self.trace.close(round_id, lo, hi);
        }
        self.lanes = slots
            .into_iter()
            .map(|s| s.expect("every lane returns from the round"))
            .collect();
    }
}

/// A resolved fault target on the fabric tree.
enum FaultSite {
    Uplink(NodeId),
    Switch(NodeId),
    DevicePort(NodeId, PortId),
    Expander(NodeId, PortId),
}

/// Fold a quantum into a round body, coalescing per lane (the engine's
/// round contract: each lane appears at most once per round, and a
/// lane's coalesced quanta run back-to-back on its clock — exactly what
/// the flat schedule would have done).
fn merge_quantum(round: &mut Vec<(usize, u64)>, lane: usize, quantum: u64) {
    match round.iter_mut().find(|(i, _)| *i == lane) {
        Some((_, q)) => *q += quantum,
        None => round.push((lane, quantum)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    fn flagship(name: &str) -> Topology {
        let mut t = Topology::from_system(SystemConfig::Cxl);
        t.name = name.to_string();
        t
    }

    fn two_tenants_of(model: &str, policy: QosPolicy, levels: usize) -> TenantSet {
        TenantSet {
            name: "test-2".into(),
            fabric_levels: levels,
            redundancy: 0,
            policy,
            faults: Vec::new(),
            tenants: vec![
                TenantSpec {
                    name: "a".into(),
                    model: model.into(),
                    topology: flagship("a"),
                    seed: 42,
                    weight: 1,
                    serve: None,
                },
                TenantSpec {
                    name: "b".into(),
                    model: model.into(),
                    topology: flagship("b"),
                    seed: 43,
                    weight: 2,
                    serve: None,
                },
            ],
        }
    }

    fn two_tenants(policy: QosPolicy, levels: usize) -> TenantSet {
        two_tenants_of("rm_mini", policy, levels)
    }

    #[test]
    fn schedules_match_their_policies() {
        let fair = PoolArbiter::new(QosPolicy::FairShare, vec![1, 1, 1]).unwrap();
        assert_eq!(fair.schedule(2), vec![0, 1, 2, 0, 1, 2]);
        let strict = PoolArbiter::new(QosPolicy::StrictPriority, vec![1, 1]).unwrap();
        assert_eq!(strict.schedule(3), vec![0, 0, 0, 1, 1, 1]);
        let weighted = PoolArbiter::new(QosPolicy::Weighted, vec![2, 1]).unwrap();
        // rounds: [0,0,1] [0,0,1] ... until each has its 4 batches
        assert_eq!(weighted.schedule(4), vec![0, 0, 1, 0, 0, 1, 1, 1]);
        // weights are validated
        assert!(PoolArbiter::new(QosPolicy::Weighted, vec![1, 0]).is_err());
        assert_eq!(
            PoolArbiter::new(QosPolicy::FairShare, vec![]).unwrap_err(),
            TenancyError::NoTenants
        );
    }

    #[test]
    fn rounds_visit_each_lane_at_most_once_and_conserve_slots() {
        for (policy, weights) in [
            (QosPolicy::FairShare, vec![1, 1, 1]),
            (QosPolicy::Weighted, vec![2, 1, 3]),
            (QosPolicy::StrictPriority, vec![1, 1]),
        ] {
            let arb = PoolArbiter::new(policy, weights.clone()).unwrap();
            for batches in [0u64, 1, 4, 7] {
                let rounds = arb.rounds(batches);
                // the barrier model needs each lane at most once per
                // round (one snapshot, one quantum), quanta non-empty
                let mut served = vec![0u64; weights.len()];
                for round in &rounds {
                    let mut seen = std::collections::HashSet::new();
                    for &(i, q) in round {
                        assert!(q > 0, "empty quantum for lane {i}");
                        assert!(seen.insert(i), "lane {i} twice in one round");
                        served[i] += q;
                    }
                }
                assert!(
                    served.iter().all(|&s| s == batches),
                    "{policy:?}/{batches}: rounds must serve exactly `batches` per lane, got {served:?}"
                );
                assert_eq!(arb.schedule(batches).len() as u64, batches * weights.len() as u64);
            }
        }
    }

    #[test]
    fn partition_windows_are_disjoint() {
        let p = PoolPartition::new(4, TENANT_SLICE_BYTES);
        for i in 0..4 {
            let (s, l) = p.window(i);
            assert_eq!(s, i as u64 * TENANT_SLICE_BYTES);
            assert_eq!(l, TENANT_SLICE_BYTES);
            for j in 0..i {
                let (s2, l2) = p.window(j);
                assert!(s2 + l2 <= s, "windows {j} and {i} overlap");
            }
        }
        assert_eq!(p.regions.len(), 4);
    }

    #[test]
    fn co_tenants_contend_for_the_pool() {
        let root = repo_root();
        // one tenant alone vs the same tenant sharing the pool: the
        // shared run must charge real stalls and stretch the timeline
        // (rm2 is embedding-bound, so the pool IS the bottleneck and a
        // charged stall cannot hide in GPU slack)
        let pair = || two_tenants_of("rm2", QosPolicy::FairShare, 1);
        let solo = TenantSet {
            tenants: pair().tenants[..1].to_vec(),
            ..pair()
        };
        let solo_run = MultiTenantSim::new(&root, &solo).unwrap().run(6);
        assert_eq!(solo_run.tenants[0].total_stall_ns(), 0, "no co-tenant, no stall");
        let shared = MultiTenantSim::new(&root, &pair()).unwrap();
        let shared_run = shared.run(6);
        for t in &shared_run.tenants {
            assert!(t.pool_busy_ns > 0, "{}: no pool traffic", t.name);
        }
        assert!(
            shared_run.tenants[0].total_stall_ns() > 0
                && shared_run.tenants[1].total_stall_ns() > 0,
            "sharing the pool must charge stalls"
        );
        assert!(
            shared_run.tenants[0].result.total_time > solo_run.tenants[0].result.total_time,
            "contention must stretch the tenant's timeline"
        );
        // conservation: a tenant can never be charged more than the
        // co-tenants actually consumed
        for (i, t) in shared_run.tenants.iter().enumerate() {
            let others: u64 = shared_run
                .tenants
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != i)
                .map(|(_, o)| o.pool_busy_ns)
                .sum();
            assert!(
                t.total_stall_ns() <= others,
                "{}: charged {} > co-tenant busy {}",
                t.name,
                t.total_stall_ns(),
                others
            );
        }
    }

    #[test]
    fn strict_priority_shields_the_top_tenant() {
        let root = repo_root();
        let run = MultiTenantSim::new(&root, &two_tenants(QosPolicy::StrictPriority, 1))
            .unwrap()
            .run(6);
        assert_eq!(run.tenants[0].total_stall_ns(), 0, "priority 0 never waits");
        assert!(run.tenants[1].total_stall_ns() > 0, "the background tenant absorbs it all");
        // fair-share spreads what strict-priority concentrates
        let fair = MultiTenantSim::new(&root, &two_tenants(QosPolicy::FairShare, 1))
            .unwrap()
            .run(6);
        let thr = |r: &MultiTenantRun| -> Vec<f64> {
            r.tenants.iter().map(|t| t.throughput_batches_per_s()).collect()
        };
        assert!(jain_fairness(&thr(&fair)) >= jain_fairness(&thr(&run)) - 1e-9);
    }

    #[test]
    fn deeper_fabrics_add_hops_and_count_link_traffic() {
        let root = repo_root();
        let flat = MultiTenantSim::new(&root, &two_tenants(QosPolicy::FairShare, 1))
            .unwrap()
            .run(4);
        assert!(flat.links.is_empty(), "depth-1 fabric has no internal links");
        let deep = MultiTenantSim::new(&root, &two_tenants(QosPolicy::FairShare, 3))
            .unwrap()
            .run(4);
        assert_eq!(deep.levels, 3);
        // two tenants x two extra levels = four internal links
        assert_eq!(deep.links.len(), 4);
        // only the leaf end of each path carries the device window, but
        // every link on a tenant's path forwards its bytes
        for (name, l) in &deep.links {
            assert!(l.bytes > 0, "{name}: no bytes forwarded");
            assert!(l.transfers > 0, "{name}");
        }
        // extra switch levels add hop latency to every link transfer
        // (whether the batch critical path absorbs it is model-dependent,
        // so pin the link occupancy, which cannot be absorbed)
        let link_busy = |r: &MultiTenantRun| {
            r.tenants[0].result.spans.busy(Lane::Link, 0, u64::MAX)
        };
        assert!(
            link_busy(&deep) > link_busy(&flat),
            "hops must lengthen link occupancy: deep {} vs flat {}",
            link_busy(&deep),
            link_busy(&flat)
        );
        // ...and can never make anyone faster
        assert!(deep.tenants[0].result.total_time >= flat.tenants[0].result.total_time);
    }

    #[test]
    fn tenant_set_toml_parses_and_validates() {
        let root = repo_root();
        let doc = Doc::parse(
            "name = \"pair\"\n[fabric]\nlevels = 2\n[arbiter]\npolicy = \"weighted\"\n\
             [[tenants]]\nmodel = \"rm_mini\"\nweight = 2\n\
             [[tenants]]\nname = \"bg\"\nmodel = \"rm_mini\"\nseed = 7\n",
        )
        .unwrap();
        let set = TenantSet::from_doc(&root, "pair", &doc).unwrap();
        assert_eq!(set.name, "pair");
        assert_eq!(set.fabric_levels, 2);
        assert_eq!(set.policy, QosPolicy::Weighted);
        assert_eq!(set.tenants.len(), 2);
        assert_eq!(set.tenants[0].name, "tenant-0");
        assert_eq!(set.tenants[0].weight, 2);
        assert_eq!(set.tenants[0].seed, 42);
        assert_eq!(set.tenants[1].name, "bg");
        assert_eq!(set.tenants[1].seed, 7);
        assert_eq!(set.tenants[1].weight, 1);
        // the default tenant topology is the CXL flagship
        assert_eq!(set.tenants[0].topology.ckpt, crate::config::CkptMode::Relaxed);
        // neither tenant declared a role, so both default to trainer
        assert!(set.tenants.iter().all(|t| t.serve.is_none()));

        // a server tenant parses its knobs into a ServeConfig
        let doc = Doc::parse(
            "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\n\
             rate_per_s = 8000\nmax_batch = 16\nmax_wait_us = 150\ntrace = \"spike\"\n",
        )
        .unwrap();
        let set = TenantSet::from_doc(&root, "serve", &doc).unwrap();
        let sc = set.tenants[0].serve.expect("server role yields a ServeConfig");
        assert_eq!(sc.rate_per_s, 8000.0);
        assert_eq!(sc.policy.max_batch, 16);
        assert_eq!(sc.policy.max_wait_us, 150);
        assert!(matches!(sc.trace, TraceShape::Spike { .. }));

        // fabric redundancy + a fault schedule parse into typed plans
        let doc = Doc::parse(
            "[fabric]\nlevels = 2\nredundancy = 1\n\
             [[tenants]]\nname = \"hot\"\nmodel = \"rm_mini\"\n\
             [[tenants]]\nname = \"cold\"\nmodel = \"rm_mini\"\n\
             [[faults]]\nkind = \"link-down\"\ntenant = \"cold\"\n\
             inject_round = 2\nrepair_round = 5\n\
             [[faults]]\nkind = \"switch-down\"\ntenant = \"hot\"\nlevel = 0\n\
             inject_round = 1\nrepair_round = 2\n",
        )
        .unwrap();
        let set = TenantSet::from_doc(&root, "faulted", &doc).unwrap();
        assert_eq!(set.redundancy, 1);
        assert_eq!(
            set.faults,
            vec![
                FaultPlan {
                    kind: FaultKind::LinkDown,
                    tenant: 1,
                    level: None,
                    inject_round: 2,
                    repair_round: 5,
                },
                FaultPlan {
                    kind: FaultKind::SwitchDown,
                    tenant: 0,
                    level: Some(0),
                    inject_round: 1,
                    repair_round: 2,
                },
            ]
        );

        for (bad, needle) in [
            ("[fabric]\nlevels = 0\n[[tenants]]\nmodel = \"rm_mini\"", "fabric.levels"),
            ("[arbiter]\npolicy = \"round-robin\"\n[[tenants]]\nmodel = \"rm_mini\"", "policy"),
            ("[[tenants]]\nmodel = \"rm_mini\"\nweight = 0", "weight"),
            ("[[tenants]]\nmodel = \"rm_mini\"\nseed = -4", "seed"),
            ("[[tenants]]\nseed = 1", "model"),
            ("name = \"empty\"", "at least one"),
            ("[[tenants]]\nmodel = \"rm_mini\"\nrole = \"observer\"", "role"),
            (
                "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\nrate_per_s = -5",
                "rate_per_s",
            ),
            (
                "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\nmax_batch = 0",
                "max_batch",
            ),
            (
                "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\nmax_wait_us = -1",
                "max_wait_us",
            ),
            (
                "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\ntrace = \"bursty\"",
                "trace",
            ),
            // serving knobs without the server role are a conflict, not
            // silently ignored
            ("[[tenants]]\nmodel = \"rm_mini\"\nmax_batch = 8", "max_batch"),
            // fault-schedule validation (the exhaustive adversarial rows
            // live in tests/config_adversarial.rs)
            ("[fabric]\nredundancy = -1\n[[tenants]]\nmodel = \"rm_mini\"", "redundancy"),
            (
                "[[tenants]]\nname = \"t\"\nmodel = \"rm_mini\"\n\
                 [[faults]]\nkind = \"gamma-ray\"\ntenant = \"t\"\n\
                 inject_round = 0\nrepair_round = 1",
                "unknown fault kind",
            ),
            (
                "[[tenants]]\nname = \"t\"\nmodel = \"rm_mini\"\n\
                 [[faults]]\nkind = \"link-down\"\ntenant = \"t\"\n\
                 inject_round = 3\nrepair_round = 3",
                "repair round",
            ),
        ] {
            let doc = Doc::parse(bad).unwrap();
            let err = TenantSet::from_doc(&root, "x", &doc).unwrap_err().to_string();
            assert!(err.contains(needle), "{bad:?}: {err}");
        }
    }

    /// A two-tenant depth-2 set with one scheduled fault on tenant 0.
    fn faulted_pair(kind: FaultKind, redundancy: u32) -> TenantSet {
        let mut set = two_tenants(QosPolicy::FairShare, 2);
        set.redundancy = redundancy;
        set.faults = vec![FaultPlan {
            kind,
            tenant: 0,
            level: None,
            inject_round: 1,
            repair_round: 3,
        }];
        set
    }

    #[test]
    fn link_down_without_redundancy_stalls_the_victim_until_repair() {
        let root = repo_root();
        let clean = MultiTenantSim::new(&root, &two_tenants(QosPolicy::FairShare, 2))
            .unwrap()
            .run(6);
        let run = MultiTenantSim::new(&root, &faulted_pair(FaultKind::LinkDown, 0))
            .unwrap()
            .run(6);
        // the severed uplink blacks out exactly tenant 0's window
        assert_eq!(run.faults.len(), 1);
        assert_eq!(run.faults[0].blast, vec![0]);
        let victim = &run.tenants[0];
        assert_eq!(victim.stalled_rounds, 2, "rounds 1 and 2 are deferred");
        assert_eq!(
            victim.result.batch_times.len(),
            6,
            "every deferred batch is served after repair"
        );
        assert!(victim.recoveries == 0, "a link fault tears no data");
        assert_eq!(victim.fault_recovery_ns, 0);
        // the bystander never stalls on the fault and keeps its full
        // schedule and total co-tenant charge
        let bystander = &run.tenants[1];
        assert_eq!(bystander.stalled_rounds, 0);
        assert_eq!(bystander.fault_stall_ns, 0);
        assert_eq!(bystander.result.batch_times.len(), 6);
        assert_eq!(
            bystander.total_stall_ns(),
            clean.tenants[1].total_stall_ns(),
            "deferral shifts co-tenant charges between rounds, never their total"
        );
        // the victim's own pool work is unchanged — it only waited
        assert_eq!(victim.pool_busy_ns, clean.tenants[0].pool_busy_ns);
    }

    #[test]
    fn redundant_uplinks_keep_the_victim_running_degraded() {
        let root = repo_root();
        let run = MultiTenantSim::new(&root, &faulted_pair(FaultKind::LinkDown, 1))
            .unwrap()
            .run(6);
        // a spare lane absorbs the hit: nothing becomes unreachable
        assert_eq!(run.faults[0].blast, Vec::<usize>::new());
        let victim = &run.tenants[0];
        assert_eq!(victim.stalled_rounds, 0, "degraded, not stalled");
        assert!(
            victim.fault_stall_ns > 0,
            "running on the surviving lane must cost degradation penalty"
        );
        // degraded occupancy surfaces on the victim's leaf uplink only
        let degraded: Vec<&str> = run
            .links
            .iter()
            .filter(|(_, l)| l.degraded_ns > 0)
            .map(|(n, _)| n.as_str())
            .collect();
        assert_eq!(degraded, vec!["a-l1"]);
        assert_eq!(run.tenants[1].fault_stall_ns, 0, "bystander edge is healthy");
    }

    #[test]
    fn expander_loss_tears_only_its_tenant() {
        let root = repo_root();
        let run = MultiTenantSim::new(&root, &faulted_pair(FaultKind::ExpanderLost, 1))
            .unwrap()
            .run(6);
        // redundancy cannot save a lost expander
        assert_eq!(run.faults[0].blast, vec![0]);
        let victim = &run.tenants[0];
        assert_eq!(victim.stalled_rounds, 2);
        assert_eq!(victim.recoveries, 1, "torn rows force one undo-slice replay");
        assert!(victim.fault_recovery_ns > 0);
        assert_eq!(victim.result.batch_times.len(), 6);
        let bystander = &run.tenants[1];
        assert_eq!(bystander.recoveries, 0);
        assert_eq!(bystander.fault_recovery_ns, 0);
        assert_eq!(bystander.stalled_rounds, 0);
    }

    #[test]
    fn root_switch_down_stalls_every_tenant() {
        let root = repo_root();
        let mut set = faulted_pair(FaultKind::SwitchDown, 0);
        set.faults[0].level = Some(0); // the root switch itself
        let run = MultiTenantSim::new(&root, &set).unwrap().run(6);
        assert_eq!(run.faults[0].blast, vec![0, 1], "everyone routes through the root");
        for t in &run.tenants {
            assert_eq!(t.stalled_rounds, 2, "{}", t.name);
            assert_eq!(t.result.batch_times.len(), 6, "{}", t.name);
            assert_eq!(t.recoveries, 0, "{}: a switch fault tears no data", t.name);
        }
    }

    #[test]
    fn clean_runs_carry_no_fault_artifacts() {
        let root = repo_root();
        let run = MultiTenantSim::new(&root, &two_tenants(QosPolicy::Weighted, 2))
            .unwrap()
            .run(4);
        assert!(run.faults.is_empty());
        for t in &run.tenants {
            assert_eq!(
                (t.stalled_rounds, t.fault_stall_ns, t.fault_recovery_ns),
                (0, 0, 0),
                "{}",
                t.name
            );
        }
        for (name, l) in &run.links {
            assert_eq!(l.degraded_ns, 0, "{name}");
        }
    }

    #[test]
    fn shipped_tenant_sets_load() {
        let root = repo_root();
        let two = TenantSet::load_strict(&root, "multi-tenant-2").unwrap();
        assert_eq!(two.tenants.len(), 2);
        assert_eq!(two.fabric_levels, 2);
        assert_eq!(two.redundancy, 1, "the shipped pair declares a spare lane per edge");
        assert_eq!(two.policy, QosPolicy::FairShare);
        let four = TenantSet::load_strict(&root, "multi-tenant-4").unwrap();
        assert_eq!(four.tenants.len(), 4);
        assert_eq!(four.fabric_levels, 3);
        assert_eq!(four.policy, QosPolicy::Weighted);
        assert!(four.tenants[0].weight > four.tenants[3].weight);
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[5.0, 5.0, 5.0]), 1.0);
        let skew = jain_fairness(&[10.0, 0.0, 0.0]);
        assert!((skew - 1.0 / 3.0).abs() < 1e-12, "{skew}");
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0, "degenerate: no throughput at all");
    }
}
