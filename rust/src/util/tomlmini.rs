//! Minimal TOML reader for `configs/**/*.toml`.
//!
//! Supports the subset our configs use: `[table]` / `[a.b]` headers,
//! `[[array-of-tables]]` headers, `key = value` with strings, integers,
//! floats, booleans, and flat arrays, plus `#` comments. Keys are
//! flattened to `table.key` paths; the i-th `[[name]]` table flattens to
//! `name.<i>.key` ([`Doc::array_len`] counts the tables, [`Doc::sub`]
//! extracts one as its own document).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().map(|i| i as usize)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_arr(&self) -> Option<Vec<usize>> {
        match self {
            Value::Arr(a) => a.iter().map(|v| v.as_usize()).collect(),
            _ => None,
        }
    }
}

/// Flat `table.key -> value` document.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
    /// `[[name]]` header counts — kept separately from `entries` so an
    /// array table with no keys (everything commented out) still counts.
    arrays: BTreeMap<String, usize>,
}

#[derive(Debug, thiserror::Error)]
#[error("toml parse error at line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, TomlError> {
        let mut doc = Doc::default();
        let mut prefix = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            // `[[name]]` must be checked before `[name]`
            if let Some(h) = line.strip_prefix("[[") {
                let h = h.strip_suffix("]]").ok_or(TomlError {
                    line: ln + 1,
                    msg: "unterminated array-of-tables header".into(),
                })?;
                let name = h.trim();
                if name.is_empty() {
                    return Err(TomlError {
                        line: ln + 1,
                        msg: "empty array-of-tables name".into(),
                    });
                }
                let idx = doc.arrays.entry(name.to_string()).or_insert(0);
                prefix = format!("{name}.{idx}");
                *idx += 1;
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let h = h.strip_suffix(']').ok_or(TomlError {
                    line: ln + 1,
                    msg: "unterminated table header".into(),
                })?;
                prefix = h.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(TomlError {
                line: ln + 1,
                msg: "expected key = value".into(),
            })?;
            let key = if prefix.is_empty() {
                k.trim().to_string()
            } else {
                format!("{prefix}.{}", k.trim())
            };
            let val = parse_value(v.trim()).map_err(|msg| TomlError { line: ln + 1, msg })?;
            doc.entries.insert(key, val);
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Doc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Doc::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    /// Lenient load for optional config files: `None` when the file is
    /// missing or malformed, so callers can log once and fall back to
    /// built-in defaults instead of aborting startup.
    pub fn load_lenient(path: &std::path::Path) -> Option<Doc> {
        let text = std::fs::read_to_string(path).ok()?;
        match Doc::parse(&text) {
            Ok(doc) => Some(doc),
            Err(e) => {
                eprintln!("[tomlmini] {}: {e}", path.display());
                None
            }
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Number of `[[name]]` tables in the document (0 when absent).
    /// Counted from the headers, so a table whose keys are all commented
    /// out still counts (its consumer then sees missing required keys
    /// instead of the table silently vanishing).
    pub fn array_len(&self, name: &str) -> usize {
        self.arrays.get(name).copied().unwrap_or(0)
    }

    /// The sub-document under `prefix.`, with the prefix stripped —
    /// `doc.sub("tenants.0")` yields the first `[[tenants]]` table as its
    /// own flat document. Empty when no such keys exist.
    pub fn sub(&self, prefix: &str) -> Doc {
        let p = format!("{prefix}.");
        Doc {
            entries: self
                .entries
                .iter()
                .filter_map(|(k, v)| k.strip_prefix(&p).map(|r| (r.to_string(), v.clone())))
                .collect(),
            arrays: BTreeMap::new(),
        }
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer key '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid float key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string key '{key}'"))
    }

    pub fn req_usize_arr(&self, key: &str) -> anyhow::Result<Vec<usize>> {
        self.get(key)
            .and_then(|v| v.as_usize_arr())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array key '{key}'"))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_model_config_shape() {
        let doc = Doc::parse(
            r#"
# comment
name = "rm1"
feature_dim = 32
lr = 0.01
bottom_mlp = [8192, 2048, 32]

[sim]
zipf_alpha = 1.05
logical_rows_per_table = 8_388_608
"#,
        )
        .unwrap();
        assert_eq!(doc.req_str("name").unwrap(), "rm1");
        assert_eq!(doc.req_usize("feature_dim").unwrap(), 32);
        assert_eq!(doc.req_f64("lr").unwrap(), 0.01);
        assert_eq!(doc.req_usize_arr("bottom_mlp").unwrap(), vec![8192, 2048, 32]);
        assert_eq!(doc.req_f64("sim.zipf_alpha").unwrap(), 1.05);
        assert_eq!(doc.req_usize("sim.logical_rows_per_table").unwrap(), 8_388_608);
    }

    #[test]
    fn comments_inside_strings_kept() {
        let doc = Doc::parse("s = \"a#b\"  # real comment").unwrap();
        assert_eq!(doc.req_str("s").unwrap(), "a#b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Doc::parse("just words").is_err());
        assert!(Doc::parse("[unterminated").is_err());
        assert!(Doc::parse("x = @").is_err());
        assert!(Doc::parse("[[unterminated]").is_err());
        assert!(Doc::parse("[[]]").is_err());
    }

    #[test]
    fn parses_array_of_tables() {
        let doc = Doc::parse(
            r#"
name = "multi"

[fabric]
levels = 2

[[tenants]]
name = "ranker"
weight = 2

[[tenants]]
name = "retrieval"
seed = 43

[arbiter]
policy = "fair-share"
"#,
        )
        .unwrap();
        assert_eq!(doc.array_len("tenants"), 2);
        assert_eq!(doc.array_len("fabric"), 0, "plain tables are not arrays");
        assert_eq!(doc.array_len("nope"), 0);
        assert_eq!(doc.req_str("tenants.0.name").unwrap(), "ranker");
        assert_eq!(doc.req_usize("tenants.0.weight").unwrap(), 2);
        assert_eq!(doc.req_str("tenants.1.name").unwrap(), "retrieval");
        assert_eq!(doc.req_usize("tenants.1.seed").unwrap(), 43);
        // headers after the array close the array table
        assert_eq!(doc.req_str("arbiter.policy").unwrap(), "fair-share");
        assert_eq!(doc.req_usize("fabric.levels").unwrap(), 2);
        // sub() extracts one table as its own flat document
        let t1 = doc.sub("tenants.1");
        assert_eq!(t1.req_str("name").unwrap(), "retrieval");
        assert_eq!(t1.req_usize("seed").unwrap(), 43);
        assert!(t1.get("weight").is_none());
        assert!(doc.sub("tenants.7").entries.is_empty());
    }

    #[test]
    fn array_tables_keep_value_shapes_and_tolerate_unknown_keys() {
        // malformed values inside a [[table]] surface exactly like the
        // scalar-key shapes Topology::load pins (wrong-typed values are
        // still typed Values here; rejection is the consumer's BadField)
        let doc = Doc::parse("[[tenants]]\nweight = \"heavy\"\nwibble = 3\n").unwrap();
        assert_eq!(doc.array_len("tenants"), 1);
        assert!(doc.get("tenants.0.weight").unwrap().as_i64().is_none());
        assert_eq!(doc.get("tenants.0.wibble").unwrap().as_i64(), Some(3));
        // a second array with the same name elsewhere keeps counting
        let doc = Doc::parse("[[t]]\na = 1\n[x]\nb = 2\n[[t]]\na = 3\n").unwrap();
        assert_eq!(doc.array_len("t"), 2);
        assert_eq!(doc.req_usize("t.1.a").unwrap(), 3);
    }

    #[test]
    fn empty_array_tables_still_count() {
        // a table whose keys are all commented out must not silently
        // vanish — its consumer should see missing required keys instead
        let doc = Doc::parse("[[t]]\na = 1\n[[t]]\n# a = 2\n").unwrap();
        assert_eq!(doc.array_len("t"), 2);
        assert!(doc.sub("t.1").entries.is_empty());
        // header-only documents count too
        let doc = Doc::parse("[[tenants]]\n").unwrap();
        assert_eq!(doc.array_len("tenants"), 1);
    }

    #[test]
    fn lenient_load_never_errors() {
        let dir = std::env::temp_dir().join("trainingcxl-tomlmini-test");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Doc::load_lenient(&dir.join("missing.toml")).is_none());
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "not = toml = at all").unwrap();
        assert!(Doc::load_lenient(&bad).is_none());
        let good = dir.join("good.toml");
        std::fs::write(&good, "k = 1").unwrap();
        assert_eq!(Doc::load_lenient(&good).unwrap().req_usize("k").unwrap(), 1);
    }
}
