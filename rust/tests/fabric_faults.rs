//! Fabric failure-domain pins (docs/fabric-faults.md), property-style:
//! randomized trees, fault schedules, and repair orders, each checked
//! against an invariant the fault model promises.
//!
//! * **Byte conservation** — for ANY schedule of lane faults/repairs,
//!   every deferred transfer is eventually delivered and the per-link
//!   byte/transfer counters match a fault-free twin exactly; only the
//!   occupancy carries the fault (split into busy vs degraded shares).
//! * **Blast radius** — a dead component unroutes exactly the windows
//!   whose root-down path crosses it; everything else keeps routing.
//! * **Repair identity** — routing is a pure function of windows and
//!   health, so undoing every fault (in any order) restores routes
//!   bit-identical to pre-fault, with zero degradation penalty.

use trainingcxl::repo_root;
use trainingcxl::config::SystemConfig;
use trainingcxl::sim::cxl::switch::PortId;
use trainingcxl::sim::fabric::{FabricTree, FaultKind, NodeId, ROOT};
use trainingcxl::sim::topology::Topology;
use trainingcxl::tenancy::{FaultPlan, MultiTenantSim, QosPolicy, TenantSet, TenantSpec};
use trainingcxl::util::Rng;

const GB: u64 = 1 << 30;

/// `n` leaf switches under the root, one 16 GB window per leaf — the
/// shape the tenancy layer builds for an n-tenant depth-2 fabric.
fn star(n: usize) -> (FabricTree, Vec<NodeId>) {
    let mut tree = FabricTree::new("root");
    let mut leaves = Vec::new();
    for i in 0..n {
        let leaf = tree.add_switch(ROOT, &format!("leaf-{i}")).unwrap();
        tree.attach_device(leaf, &format!("mem-{i}"), i as u64 * 16 * GB, 16 * GB).unwrap();
        leaves.push(leaf);
    }
    (tree, leaves)
}

#[test]
fn rerouting_conserves_total_bytes_for_any_surviving_path_schedule() {
    for seed in 0..24u64 {
        let mut rng = Rng::new(0xFAB0_0000 + seed);
        let n = 2 + rng.gen_range(3) as usize; // 2..=4 leaves
        let spares = 1 + rng.gen_range(2) as u32; // 1..=2 spare lanes
        let (mut faulty, leaves) = star(n);
        let (mut clean, _) = star(n);
        faulty.set_redundancy(spares);
        clean.set_redundancy(spares);

        // a random transfer stream interleaved with random lane churn;
        // transfers whose edge is severed are deferred FIFO and retried
        // as soon as any repair lands — exactly the sim's discipline
        let mut deferred: Vec<(u64, u64)> = Vec::new();
        for _ in 0..200 {
            let leaf = leaves[rng.gen_range(n as u64) as usize];
            match rng.gen_range(4) {
                0 => {
                    let _ = faulty.fail_uplink(leaf);
                }
                1 => {
                    let _ = faulty.repair_uplink(leaf);
                    deferred.retain(|&(a, b)| faulty.forward(a, b, 100).is_err());
                }
                _ => {}
            }
            let dst = rng.gen_range(n as u64);
            let addr = dst * 16 * GB + rng.gen_range(16 * GB);
            let bytes = 256 + rng.gen_range(4096);
            clean.forward(addr, bytes, 100).unwrap();
            if faulty.forward(addr, bytes, 100).is_err() {
                deferred.push((addr, bytes));
            }
        }
        // repair everything and drain: no transfer may be lost
        for &leaf in &leaves {
            for _ in 0..=spares {
                let _ = faulty.repair_uplink(leaf);
            }
        }
        deferred.retain(|&(a, b)| faulty.forward(a, b, 100).is_err());
        assert!(deferred.is_empty(), "seed {seed}: transfers lost after full repair");

        // bytes and transfer counts are conserved per link; the fault
        // shows up only as occupancy, split busy vs degraded without
        // double counting
        let (fl, cl) = (faulty.links(), clean.links());
        assert_eq!(fl.len(), cl.len());
        for ((fname, f), (cname, c)) in fl.iter().zip(&cl) {
            assert_eq!(fname, cname);
            assert_eq!(f.bytes, c.bytes, "seed {seed}: {fname} lost bytes");
            assert_eq!(f.transfers, c.transfers, "seed {seed}: {fname} lost transfers");
            assert_eq!(
                f.busy_ns - f.degraded_ns,
                c.busy_ns,
                "seed {seed}: {fname} healthy occupancy drifted"
            );
        }
        // the root's per-port byte map agrees with the twin exactly
        assert_eq!(
            faulty.switch(ROOT).unwrap().bytes_by_port,
            clean.switch(ROOT).unwrap().bytes_by_port,
            "seed {seed}"
        );
    }
}

#[test]
fn blast_radius_is_exactly_the_windows_routed_through_the_dead_node() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(0xB1A5_7000 + seed);
        let mut tree = FabricTree::new("root");
        // random chains: window i hangs `depth` switches below the root
        let n = 2 + rng.gen_range(4) as usize; // 2..=5 windows
        let mut windows: Vec<(Vec<NodeId>, u64, NodeId, _)> = Vec::new();
        for i in 0..n {
            let mut path = vec![ROOT];
            for d in 0..rng.gen_range(3) {
                let sw = tree.add_switch(*path.last().unwrap(), &format!("sw-{i}-{d}")).unwrap();
                path.push(sw);
            }
            let at = *path.last().unwrap();
            let base = i as u64 * 32 * GB;
            let port = tree.attach_device(at, &format!("mem-{i}"), base, 16 * GB).unwrap();
            windows.push((path, base + GB, at, port));
        }

        // downing any switch unroutes exactly the windows whose path
        // crosses it — and repair brings exactly them back
        for victim in 0..tree.node_count() {
            tree.fail_switch(victim).unwrap();
            for (path, addr, _, _) in &windows {
                assert_eq!(
                    tree.route(*addr).is_err(),
                    path.contains(&victim),
                    "seed {seed}: switch {victim} vs window at {addr:#x}"
                );
            }
            tree.repair_switch(victim).unwrap();
        }
        // losing an expander unroutes exactly its own window
        for i in 0..n {
            let (at, port) = (windows[i].2, windows[i].3);
            tree.lose_expander(at, port).unwrap();
            for (j, (_, addr, _, _)) in windows.iter().enumerate() {
                assert_eq!(tree.route(*addr).is_err(), i == j, "seed {seed}: expander {i}");
            }
            tree.restore_expander(at, port).unwrap();
        }
        for (_, addr, _, _) in &windows {
            assert!(tree.route(*addr).is_ok(), "seed {seed}: repair left debris");
        }
    }
}

#[test]
fn repairing_every_fault_restores_routes_bit_identical() {
    for seed in 0..16u64 {
        let mut rng = Rng::new(0x4E9A_1200 + seed);
        let n = 2 + rng.gen_range(3) as usize;
        let (mut tree, leaves) = star(n);
        tree.set_redundancy(rng.gen_range(3) as u32);
        let probes: Vec<u64> = (0..n as u64).map(|i| i * 16 * GB + 3 * GB).collect();
        let before: Vec<_> = probes.iter().map(|&a| tree.route(a).unwrap()).collect();

        // a random pile of faults of every kind, each recorded so it can
        // be undone exactly once, in a shuffled order
        let mut undo: Vec<(u8, NodeId)> = Vec::new();
        for _ in 0..12 {
            let leaf = leaves[rng.gen_range(n as u64) as usize];
            match rng.gen_range(3) {
                0 => {
                    tree.fail_uplink(leaf).unwrap();
                    undo.push((0, leaf));
                }
                1 => {
                    tree.fail_switch(leaf).unwrap();
                    undo.push((1, leaf));
                }
                _ => {
                    // star() attaches exactly one device per leaf, so its
                    // port is always the leaf's first-allocated PortId(0)
                    tree.lose_expander(leaf, PortId(0)).unwrap();
                    undo.push((2, leaf));
                }
            }
        }
        while !undo.is_empty() {
            let (kind, leaf) = undo.swap_remove(rng.gen_range(undo.len() as u64) as usize);
            match kind {
                0 => tree.repair_uplink(leaf).unwrap(),
                1 => tree.repair_switch(leaf).unwrap(),
                _ => tree.restore_expander(leaf, PortId(0)).unwrap(),
            }
        }

        // health is the only routing input that changed, so the restored
        // routes are the exact pre-fault structs and carry no penalty
        for (i, &addr) in probes.iter().enumerate() {
            assert_eq!(tree.route(addr).unwrap(), before[i], "seed {seed}");
            let (_, penalty) = tree.forward_counted(addr, 512, 100).unwrap();
            assert_eq!(penalty, 0, "seed {seed}: repaired fabric still degraded");
        }
    }
}

// ------------------------------------------------------------- sim level

fn trio(faults: Vec<FaultPlan>) -> TenantSet {
    let tenants = (0..3)
        .map(|i| TenantSpec {
            name: format!("t{i}"),
            model: "rm_mini".into(),
            topology: Topology::from_system(SystemConfig::Cxl),
            seed: 42 + i as u64,
            weight: 1,
            serve: None,
        })
        .collect();
    TenantSet {
        name: "fault-trio".into(),
        fabric_levels: 2,
        redundancy: 0,
        policy: QosPolicy::FairShare,
        tenants,
        faults,
    }
}

#[test]
fn sim_blast_radius_follows_the_pool_windows() {
    let root = repo_root();
    let leaf = MultiTenantSim::new(&root, &trio(vec![FaultPlan {
        kind: FaultKind::SwitchDown,
        tenant: 1,
        level: None,
        inject_round: 1,
        repair_round: 2,
    }]))
    .unwrap()
    .run(4);
    assert_eq!(leaf.faults.len(), 1);
    // tenant 1's leaf switch backs exactly tenant 1's HPA window
    assert_eq!(leaf.faults[0].blast, vec![1]);
    for (i, t) in leaf.tenants.iter().enumerate() {
        assert_eq!(t.batches, 4, "{}: short-served under a fault", t.name);
        assert_eq!(t.stalled_rounds, u64::from(i == 1), "{}", t.name);
    }

    // the root switch backs every window: the blast is the whole set
    let all = MultiTenantSim::new(&root, &trio(vec![FaultPlan {
        kind: FaultKind::SwitchDown,
        tenant: 0,
        level: Some(0),
        inject_round: 1,
        repair_round: 2,
    }]))
    .unwrap()
    .run(4);
    assert_eq!(all.faults[0].blast, vec![0, 1, 2]);
    for t in &all.tenants {
        assert_eq!(t.batches, 4);
        assert_eq!(t.stalled_rounds, 1);
    }

    // a tearing fault marks only its blast for undo-slice recovery
    let torn = MultiTenantSim::new(&root, &trio(vec![FaultPlan {
        kind: FaultKind::ExpanderLost,
        tenant: 2,
        level: None,
        inject_round: 1,
        repair_round: 2,
    }]))
    .unwrap()
    .run(4);
    assert_eq!(torn.faults[0].blast, vec![2]);
    assert!(torn.tenants[2].fault_recovery_ns > 0, "victim never replayed");
    for t in &torn.tenants[..2] {
        assert_eq!(t.fault_recovery_ns, 0, "{}: bystander paid a replay", t.name);
    }
}
