//! The effect vocabulary: what a stage reads, writes, holds, and logs.
//!
//! Every [`crate::sched::stage::Stage`] (and serving stage) declares a
//! [`StageEffects`] summary. The declarations are *static* — one value per
//! stage type, independent of the topology it composes into; durability is
//! resolved by the analyzer from the topology's media (the same region is
//! durable under a PMEM pool and volatile under the DRAM-ideal config).
//!
//! The vocabulary is deliberately small: regions name the recoverable
//! state and the per-batch dataflow buffers of the TrainingCXL pipeline,
//! resources name the serialization points (`pmem_free`, the fabric
//! links, the GPU lanes) whose acquisition order the analyzer proves
//! acyclic.

/// A named state region touched by a stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Region {
    /// The authoritative embedding tables in the pooled table media.
    EmbTable,
    /// The volatile DRAM hot-tier head fronting the pool (inclusive
    /// tiering: the pool stays authoritative for every row).
    HotTier,
    /// Batch-aware undo-log generations (and redo images) in the pool.
    UndoLog,
    /// MLP parameter snapshot log in the pool.
    MlpLog,
    /// Dense MLP weights resident in GPU HBM.
    GpuWeights,
    /// Host-DRAM mirror / vector cache of embedding rows.
    HostMirror,
    /// Reduced embedding vectors staged outside the GPU (pool buffer or
    /// host memory) — per-batch scratch, never recovered.
    ReducedVectors,
    /// Reduced vectors after delivery into GPU HBM — per-batch scratch.
    GpuVectors,
}

impl Region {
    /// Regions whose contents must survive a crash or be reconstructible
    /// afterwards — writes here are what the recovery matrix calls
    /// "stateful". The remaining regions are per-batch scratch.
    pub fn is_recoverable_state(self) -> bool {
        matches!(
            self,
            Region::EmbTable
                | Region::HotTier
                | Region::UndoLog
                | Region::MlpLog
                | Region::GpuWeights
        )
    }

    /// Per-batch dataflow buffers: a read must be preceded by a producer
    /// in the same batch (a chain composed without its movement stage is
    /// caught here).
    pub fn is_dataflow(self) -> bool {
        matches!(self, Region::ReducedVectors | Region::GpuVectors)
    }
}

/// Which slice of a region's rows an access touches. `All` covers both
/// tier classes; the tiered chains split their accesses per class.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rows {
    All,
    Cold,
    Hot,
}

impl Rows {
    /// Bitmask over the two tier classes (`All` = both) for coverage
    /// arithmetic in the checks.
    pub fn mask(self) -> u8 {
        match self {
            Rows::Cold => 0b01,
            Rows::Hot => 0b10,
            Rows::All => 0b11,
        }
    }
}

/// A serialization point a stage occupies while it runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Resource {
    /// The shared pool backend (`PipelineEnv::pmem_free`).
    PmemPool,
    /// The CXL switch / DCOH transfer window.
    CxlLink,
    /// The host PCIe link (software movement, staged checkpoints).
    PcieLink,
    /// A per-lane GPU compute slot.
    GpuLane,
}

impl Resource {
    pub const COUNT: usize = 4;

    pub fn index(self) -> usize {
        match self {
            Resource::PmemPool => 0,
            Resource::CxlLink => 1,
            Resource::PcieLink => 2,
            Resource::GpuLane => 3,
        }
    }

    pub fn from_index(i: usize) -> Resource {
        match i {
            0 => Resource::PmemPool,
            1 => Resource::CxlLink,
            2 => Resource::PcieLink,
            _ => Resource::GpuLane,
        }
    }

    /// Stable display name (trace tracks, attribution buckets).
    pub fn name(self) -> &'static str {
        match self {
            Resource::PmemPool => "pmem-pool",
            Resource::CxlLink => "cxl-link",
            Resource::PcieLink => "pcie-link",
            Resource::GpuLane => "gpu-lane",
        }
    }
}

/// How a stage persists the dense MLP parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MlpPersist {
    /// A complete durable snapshot every batch (redo tails, the
    /// batch-aware MLP log).
    PerBatch,
    /// Streamed across a `max_mlp_log_gap` window of batches; the
    /// recovered MLP may lag by up to the window. `seals_bootstrap`
    /// records whether the *first* snapshot seals synchronously — without
    /// that, recovery before the first seal has no MLP image at all.
    WindowBounded { seals_bootstrap: bool },
    /// No bound on snapshot lag. Never produced by `compose`; exists so
    /// mutant chains (and future stages) have something to get caught
    /// declaring.
    Unbounded,
}

/// A stage's contribution to the undo/redo coverage window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UndoCapture {
    /// Row classes the capture covers.
    pub rows: Rows,
    /// `false`: the capture covers the *current* batch's update
    /// (undo-before-update legs). `true`: it covers the *next* batch's
    /// update — redo tails persist the post-update image that batch
    /// `b + 1` rolls back to.
    pub for_next_batch: bool,
}

/// The declarative effect summary of one stage. Built fluently:
///
/// ```
/// use trainingcxl::analysis::effects::{Region, Resource, Rows, StageEffects};
/// let fx = StageEffects::declared()
///     .read(Region::EmbTable, Rows::All)
///     .write(Region::UndoLog, Rows::All)
///     .undo_capture(Rows::All, false)
///     .section(&[Resource::PmemPool]);
/// assert!(fx.is_stateful());
/// ```
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct StageEffects {
    /// `false` only for the trait default: the stage never stated its
    /// effects. The analyzer and the recovery-matrix coverage pin both
    /// fail on an undeclared stage, so the effect table cannot drift from
    /// the stage universe.
    pub declared: bool,
    pub reads: Vec<(Region, Rows)>,
    /// Mutations. Writes to recoverable regions are what crash
    /// consistency is about; writes to scratch regions feed the dataflow
    /// check only.
    pub writes: Vec<(Region, Rows)>,
    /// Resource acquisition: each inner vector is one critical section
    /// listing resources in nested acquisition order (consecutive
    /// entries mean "held while acquiring the next"). Separate inner
    /// vectors are sequential sections and contribute no ordering edge.
    pub acquires: Vec<Vec<Resource>>,
    pub undo: Option<UndoCapture>,
    pub mlp: Option<MlpPersist>,
}

impl StageEffects {
    /// The trait-default marker value; see [`StageEffects::declared`].
    pub fn undeclared() -> Self {
        StageEffects::default()
    }

    /// An empty but *declared* effect set (pure compute / accounting).
    pub fn declared() -> Self {
        StageEffects {
            declared: true,
            ..StageEffects::default()
        }
    }

    pub fn read(mut self, region: Region, rows: Rows) -> Self {
        self.reads.push((region, rows));
        self
    }

    pub fn write(mut self, region: Region, rows: Rows) -> Self {
        self.writes.push((region, rows));
        self
    }

    /// One critical section; `resources` in nested acquisition order.
    pub fn section(mut self, resources: &[Resource]) -> Self {
        self.acquires.push(resources.to_vec());
        self
    }

    pub fn undo_capture(mut self, rows: Rows, for_next_batch: bool) -> Self {
        self.undo = Some(UndoCapture {
            rows,
            for_next_batch,
        });
        self
    }

    pub fn mlp(mut self, m: MlpPersist) -> Self {
        self.mlp = Some(m);
        self
    }

    /// Whether the recovery matrix would call this stage stateful: it
    /// mutates recoverable state or contributes to a coverage window.
    pub fn is_stateful(&self) -> bool {
        self.undo.is_some()
            || self.mlp.is_some()
            || self.writes.iter().any(|(r, _)| r.is_recoverable_state())
    }
}
