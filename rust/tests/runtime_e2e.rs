//! End-to-end tests over the PJRT runtime: the three layers must compose
//! (Pallas kernels -> JAX DLRM -> rust coordinator) with real numerics.
//! All tests skip gracefully when `make artifacts` has not run.

use trainingcxl::config::ModelConfig;
use trainingcxl::repo_root;
use trainingcxl::runtime::{HostTensor, ModelRuntime};
use trainingcxl::train::{CkptOptions, Trainer};
use trainingcxl::workload::Generator;

fn ready() -> Option<(std::path::PathBuf, ModelConfig)> {
    let root = repo_root();
    if !root.join("artifacts/rm_mini/manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some((root.clone(), ModelConfig::load(&root, "rm_mini").unwrap()))
}

#[test]
fn training_reduces_loss() {
    let Some((root, cfg)) = ready() else { return };
    let mut t = Trainer::new(&root, &cfg, 3, None).unwrap();
    let mut first10 = 0.0;
    let mut last10 = 0.0;
    for s in 0..60 {
        let out = t.step().unwrap();
        if s < 10 {
            first10 += out.loss / 10.0;
        }
        if s >= 50 {
            last10 += out.loss / 10.0;
        }
    }
    assert!(
        last10 < first10 - 0.005,
        "no learning: {first10:.4} -> {last10:.4}"
    );
}

#[test]
fn split_path_matches_monolithic_train_step() {
    // The device-split hot path (embedding_bag -> mlp_step ->
    // embedding_update) must produce the SAME loss and parameters as the
    // monolithic train_step artifact: the decomposition is an
    // implementation detail, not a semantic change.
    let Some((root, cfg)) = ready() else { return };
    let rt = ModelRuntime::load(&root, "rm_mini", &["train_step"]).unwrap();

    // identical init on both paths
    let mut split = Trainer::new(&root, &cfg, 5, None).unwrap();
    let mlp0: Vec<Vec<f32>> = split.mlp_params().to_vec();

    // build monolithic inputs with the same init: trainer's table is
    // device-side; rebuild it from the same seed by reading the store of
    // a checkpointing twin
    let twin = Trainer::new(&root, &cfg, 5, Some(CkptOptions::default())).unwrap();
    let table0 = twin.store.as_ref().unwrap().flat().to_vec();

    let mut gen = Generator::new(&cfg, 5 ^ 0xBA7C4);
    let batch = gen.next_batch();

    // split path: one step
    let split_out = split.step_with_batch(&batch).unwrap();

    // monolithic path
    let spec = rt.export_spec("train_step").clone();
    let mut bufs = Vec::new();
    let nmlp = mlp0.len();
    for (i, p) in mlp0.iter().enumerate() {
        bufs.push(
            rt.to_device(&HostTensor::F32(p.clone(), spec.inputs[i].shape.clone()))
                .unwrap(),
        );
    }
    bufs.push(
        rt.to_device(&HostTensor::F32(table0, spec.inputs[nmlp].shape.clone()))
            .unwrap(),
    );
    bufs.push(
        rt.to_device(&HostTensor::F32(
            batch.dense.clone(),
            spec.inputs[nmlp + 1].shape.clone(),
        ))
        .unwrap(),
    );
    bufs.push(
        rt.to_device(&HostTensor::I32(
            batch.indices.clone(),
            spec.inputs[nmlp + 2].shape.clone(),
        ))
        .unwrap(),
    );
    bufs.push(
        rt.to_device(&HostTensor::F32(
            batch.labels.clone(),
            spec.inputs[nmlp + 3].shape.clone(),
        ))
        .unwrap(),
    );
    let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let outs = rt.run_to_host("train_step", &args).unwrap();
    let mono_loss = outs.last().unwrap()[0];

    assert!(
        (mono_loss - split_out.loss).abs() < 1e-5,
        "split {} vs monolithic {}",
        split_out.loss,
        mono_loss
    );
    // and the updated MLP params agree
    for (i, (a, b)) in outs[..nmlp].iter().zip(split.mlp_params()).enumerate() {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5, "param {i} diverged: {x} vs {y}");
        }
    }
}

#[test]
fn forward_shapes_and_determinism() {
    let Some((root, cfg)) = ready() else { return };
    let t1 = Trainer::new(&root, &cfg, 9, None).unwrap();
    let t2 = Trainer::new(&root, &cfg, 9, None).unwrap();
    let (l1, a1) = t1.evaluate(3, 123).unwrap();
    let (l2, a2) = t2.evaluate(3, 123).unwrap();
    assert_eq!(l1, l2, "same seed must give identical eval");
    assert_eq!(a1, a2);
    let (l3, _) = t1.evaluate(3, 456).unwrap();
    assert_ne!(l1, l3, "different eval seed must differ");
}

#[test]
fn checkpointed_training_keeps_host_mirror_in_sync() {
    let Some((root, cfg)) = ready() else { return };
    let mut t = Trainer::new(&root, &cfg, 13, Some(CkptOptions::default())).unwrap();
    for _ in 0..5 {
        t.step().unwrap();
    }
    // the undo log of the NEXT batch must capture current values: verify
    // by crashing now and recovering — rollback must equal the mirror
    // state at the last completed batch boundary.
    let (mut store, log, _) = t.crash();
    let pre = store.clone();
    let rec = trainingcxl::checkpoint::recover(&mut store, &log).unwrap();
    assert_eq!(rec.resume_batch, 4);
    // rows not in the last batch's touched set are identical
    let touched: std::collections::HashSet<(usize, usize)> = log
        .persistent_emb()
        .unwrap()
        .entries
        .iter()
        .map(|e| (e.table, e.row))
        .collect();
    for t_i in 0..cfg.num_tables {
        for r_i in 0..cfg.rows_per_table {
            if !touched.contains(&(t_i, r_i)) {
                assert_eq!(store.row(t_i, r_i), pre.row(t_i, r_i));
            }
        }
    }
}

#[test]
fn rm1_artifacts_load_and_execute() {
    // one of the real paper models end-to-end at artifact scale
    let root = repo_root();
    if !root.join("artifacts/rm1/manifest.json").exists() {
        eprintln!("skipping: rm1 artifacts not built");
        return;
    }
    let cfg = ModelConfig::load(&root, "rm1").unwrap();
    let mut t = Trainer::new(&root, &cfg, 1, None).unwrap();
    let out = t.step().unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
}
