//! Discrete-event simulation core: a time-ordered event queue with stable
//! FIFO ordering for simultaneous events.
//!
//! The engine is deliberately minimal — `schedule` posts a payload at an
//! absolute time, `pop` drains in (time, insertion) order. Components
//! (memory controllers, CXL ports) are driven by an owner that holds the
//! state and pumps typed events; see [`super::mem::controller`].

use super::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest (at, seq) first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Min-heap event queue over payload type `E`.
///
/// Determinism: ties in `at` are broken by insertion order (`seq`), so a
/// simulation is a pure function of its inputs.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Post `payload` to fire at absolute time `at` (must be >= now).
    pub fn schedule(&mut self, at: SimTime, payload: E) {
        debug_assert!(
            at >= self.now,
            "scheduling into the past: {at} < {}",
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, payload });
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = self.heap.pop()?;
        self.now = ev.at;
        Some((ev.at, ev.payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_for_ties_and_time_order_overall() {
        let mut q: EventQueue<&'static str> = EventQueue::new();
        q.schedule(10, "b");
        q.schedule(5, "a");
        q.schedule(10, "c");
        q.schedule(20, "d");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(5, "a"), (10, "b"), (10, "c"), (20, "d")]);
    }

    #[test]
    fn now_advances_monotonically() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(3, 1);
        q.schedule(7, 2);
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 3);
        q.pop();
        assert_eq!(q.now(), 7);
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_schedule_pop() {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.schedule(1, 1);
        let (t, v) = q.pop().unwrap();
        assert_eq!((t, v), (1, 1));
        // rescheduling relative to now
        q.schedule(q.now() + 4, 2);
        q.schedule(q.now() + 2, 3);
        assert_eq!(q.pop().unwrap(), (3, 3));
        assert_eq!(q.pop().unwrap(), (5, 2));
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore)]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_scheduling() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(10, 1);
        q.pop();
        q.schedule(5, 2);
    }
}
