//! Lifting composed chains into a happens-before effect graph.
//!
//! A composed chain is a *sequence*: within a batch, stage `i` completes
//! before stage `i + 1` starts, and batch `b`'s chain completes before
//! batch `b + 1` begins (the simulator's per-stage gating only moves
//! completion times, never reorders effects). Happens-before over the
//! lifted nodes is therefore just index order — which keeps the graph
//! honest and the checks readable.
//!
//! Training chains are unrolled across **two** batch instances so
//! cross-batch coverage is visible: a redo tail in batch `b` captures the
//! post-update image that covers batch `b + 1`'s update, and the check
//! for "every crash point has a reachable recovery path" needs both ends
//! of that edge in one graph. Batch 0 doubles as the bootstrap window
//! (where e.g. redo chains legitimately have no prior coverage — the
//! recovery matrix exempts a batch-0 crash the same way), so the
//! steady-state checks run against the last unrolled batch.

use super::effects::StageEffects;
use crate::sched::stage::Stage;
use crate::serve::ServeStage;

/// One stage instance in the unrolled chain.
#[derive(Clone, Debug)]
pub struct EffectNode {
    /// Which unrolled batch instance this node belongs to.
    pub batch: usize,
    /// Position within the batch's chain.
    pub index: usize,
    pub name: &'static str,
    pub fx: StageEffects,
}

/// The unrolled happens-before graph of a composed chain.
#[derive(Clone, Debug)]
pub struct EffectGraph {
    /// Nodes in happens-before (program) order: node `i` happens-before
    /// node `j` iff `i < j`.
    pub nodes: Vec<EffectNode>,
    /// Stages per batch instance.
    pub chain_len: usize,
}

impl EffectGraph {
    /// Build from `(name, effects)` pairs, unrolled `batches` times.
    /// This is the raw entry point the mutant tests use to assemble
    /// deliberately broken chains.
    pub fn from_effects(stages: &[(&'static str, StageEffects)], batches: usize) -> EffectGraph {
        let mut nodes = Vec::with_capacity(stages.len() * batches);
        for b in 0..batches {
            for (i, (name, fx)) in stages.iter().enumerate() {
                nodes.push(EffectNode {
                    batch: b,
                    index: i,
                    name,
                    fx: fx.clone(),
                });
            }
        }
        EffectGraph {
            nodes,
            chain_len: stages.len(),
        }
    }

    /// Lift a training chain (any `compose(...)` output), unrolled across
    /// two batches so cross-batch redo coverage type-checks.
    pub fn lift_training(chain: &[Box<dyn Stage>]) -> EffectGraph {
        let fx: Vec<_> = chain.iter().map(|s| (s.name(), s.effects())).collect();
        EffectGraph::from_effects(&fx, 2)
    }

    /// Lift a serving chain. Serving is stateless per request, so one
    /// batch instance suffices.
    pub fn lift_serving(chain: &[Box<dyn ServeStage>]) -> EffectGraph {
        let fx: Vec<_> = chain.iter().map(|s| (s.name(), s.effects())).collect();
        EffectGraph::from_effects(&fx, 1)
    }

    /// The last (steady-state) unrolled batch index.
    pub fn last_batch(&self) -> usize {
        self.nodes.last().map(|n| n.batch).unwrap_or(0)
    }

    /// Nodes of one batch instance, in program order.
    pub fn batch(&self, b: usize) -> Vec<&EffectNode> {
        self.nodes.iter().filter(|n| n.batch == b).collect()
    }
}
