//! Seeded mutant chains the static analyzer must flag.
//!
//! The analyzer is only trustworthy if it catches the bugs it claims to:
//! each test here assembles a deliberately broken chain — an ordering
//! inversion, a dropped stage, an oversized window, a cyclic lock order,
//! a write-bearing serving stage — and asserts the *specific* typed
//! [`Violation`] it must produce. A control test pins that the
//! un-mutated chains stay clean, so the mutants fail because of the
//! seeded defect and not analyzer over-approximation.

use trainingcxl::analysis::{
    self, AnalysisReport, ChainSpec, MlpPersist, Region, Resource, Rows, StageEffects, Violation,
};
use trainingcxl::config::{CkptMode, SystemConfig};
use trainingcxl::sched::stage::{
    self, BatchAwareMlpLog, BatchCtx, CxlAttribution, CxlFrontLookup, CxlGradFlush, DcohFlush,
    EmbUndoLog, GpuBottomBwd, GpuBottomFwd, GpuTopMlp, NdpEmbUpdate, PipelineEnv, RedoTailCkpt,
    Stage, TierMigrate, TieredEmbLookup, TieredEmbUndoLog, TieredEmbUpdate,
};
use trainingcxl::serve::{ServeCtx, ServeStage};
use trainingcxl::sim::topology::Topology;

fn spec(ckpt: CkptMode) -> ChainSpec {
    ChainSpec {
        ckpt,
        max_mlp_log_gap: 1,
        durable_table: true,
    }
}

fn assert_flags(report: &AnalysisReport, what: &str, pred: impl Fn(&Violation) -> bool) {
    assert!(
        report.violations.iter().any(pred),
        "expected {what}, got:\n{report}"
    );
}

// ------------------------------------------------------------- controls

#[test]
fn control_unmutated_chains_are_clean() {
    // The same chains the mutants below are derived from, as `compose`
    // actually builds them: all clean. (The full family sweep lives in
    // the analysis unit tests; this is the paired control.)
    for sys in [SystemConfig::CxlB, SystemConfig::CxlD, SystemConfig::Cxl] {
        let t = Topology::from_system(sys);
        let r = analysis::analyze_topology(&t).unwrap();
        assert!(r.is_clean(), "control {}:\n{r}", t.name);
    }
}

// -------------------------------------------------------------- mutants

#[test]
fn mutant_update_before_undo_log_is_flagged() {
    // CXL-B chain with the update hoisted above the undo leg: the write
    // lands before the capture that covers it.
    let chain: Vec<Box<dyn Stage>> = vec![
        Box::new(CxlFrontLookup { relaxed: false }),
        Box::new(NdpEmbUpdate { correction: false }),
        Box::new(EmbUndoLog),
        Box::new(DcohFlush),
        Box::new(GpuBottomFwd { launch_gated: false }),
        Box::new(GpuTopMlp),
        Box::new(GpuBottomBwd),
        Box::new(CxlGradFlush),
        Box::new(BatchAwareMlpLog),
        Box::new(CxlAttribution),
    ];
    let r = analysis::analyze_training_chain(&spec(CkptMode::BatchAware), "mutant", &chain);
    assert_flags(&r, "UpdateBeforeUndoLog", |v| {
        matches!(v, Violation::UpdateBeforeUndoLog { stage, region }
            if *stage == "ndp-emb-update" && *region == Region::EmbTable)
    });
}

#[test]
fn mutant_dropped_hot_tier_flush_is_flagged() {
    // Tiered batch-aware chain without its hot-tier-flush leg: the hot
    // rows' mutation has no covering capture anywhere — a crash during
    // the update loses them.
    let chain: Vec<Box<dyn Stage>> = vec![
        Box::new(TieredEmbLookup { relaxed: false }),
        Box::new(TieredEmbUndoLog),
        Box::new(DcohFlush),
        Box::new(GpuBottomFwd { launch_gated: false }),
        Box::new(GpuTopMlp),
        Box::new(GpuBottomBwd),
        Box::new(CxlGradFlush),
        Box::new(TieredEmbUpdate { correction: false }),
        Box::new(BatchAwareMlpLog),
        Box::new(TierMigrate),
        Box::new(CxlAttribution),
    ];
    let r = analysis::analyze_training_chain(&spec(CkptMode::BatchAware), "mutant", &chain);
    assert_flags(&r, "WriteOutsideLogCoverage on the hot tier", |v| {
        matches!(v, Violation::WriteOutsideLogCoverage { stage, region }
            if *stage == "tiered-emb-update" && *region == Region::HotTier)
    });
    // The cold rows ARE covered (tiered-emb-undo-log survives), so the
    // finding is specific to the dropped leg.
    assert!(
        !r.violations.iter().any(|v| matches!(
            v,
            Violation::WriteOutsideLogCoverage {
                region: Region::EmbTable,
                ..
            }
        )),
        "cold coverage should survive:\n{r}"
    );
}

#[test]
fn mutant_missing_dcoh_flush_is_flagged() {
    // CXL-D chain without its movement stage: the reduced vectors never
    // reach the GPU.
    let chain: Vec<Box<dyn Stage>> = vec![
        Box::new(CxlFrontLookup { relaxed: false }),
        Box::new(GpuBottomFwd { launch_gated: false }),
        Box::new(GpuTopMlp),
        Box::new(GpuBottomBwd),
        Box::new(CxlGradFlush),
        Box::new(NdpEmbUpdate { correction: false }),
        Box::new(RedoTailCkpt),
        Box::new(CxlAttribution),
    ];
    let r = analysis::analyze_training_chain(&spec(CkptMode::Redo), "mutant", &chain);
    assert_flags(&r, "ReadWithoutProducer", |v| {
        matches!(v, Violation::ReadWithoutProducer { stage, region }
            if *stage == "gpu-top-mlp" && *region == Region::GpuVectors)
    });
}

#[test]
fn mutant_oversized_mlp_gap_is_flagged() {
    let t = Topology::builder("mutant-gap")
        .near_data()
        .hw_movement()
        .checkpoint(CkptMode::Relaxed)
        .relaxed_lookup()
        .max_mlp_log_gap(analysis::MAX_SAFE_MLP_GAP * 5)
        .build()
        .unwrap();
    let r = analysis::analyze_topology(&t).unwrap();
    assert_flags(&r, "MlpGapOverrun", |v| {
        matches!(v, Violation::MlpGapOverrun { gap, .. }
            if *gap == analysis::MAX_SAFE_MLP_GAP * 5)
    });
}

/// A relaxed-mode MLP log that declares no lag bound.
struct UnboundedMlpLog;

impl Stage for UnboundedMlpLog {
    fn name(&self) -> &'static str {
        "mutant-unbounded-mlp-log"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::MlpLog, Rows::All)
            .mlp(MlpPersist::Unbounded)
            .section(&[Resource::CxlLink])
    }

    fn run(&self, _env: &mut PipelineEnv, _ctx: &mut BatchCtx) {}
}

/// A windowed MLP log whose first snapshot does not seal synchronously.
struct LazyBootstrapMlpLog;

impl Stage for LazyBootstrapMlpLog {
    fn name(&self) -> &'static str {
        "mutant-lazy-bootstrap-mlp-log"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::MlpLog, Rows::All)
            .mlp(MlpPersist::WindowBounded {
                seals_bootstrap: false,
            })
            .section(&[Resource::CxlLink])
    }

    fn run(&self, _env: &mut PipelineEnv, _ctx: &mut BatchCtx) {}
}

/// The relaxed single-GPU chain with its MLP-log tail swapped out.
fn relaxed_chain_with_tail(tail: Box<dyn Stage>) -> Vec<Box<dyn Stage>> {
    vec![
        Box::new(CxlFrontLookup { relaxed: false }),
        Box::new(EmbUndoLog),
        Box::new(DcohFlush),
        Box::new(GpuBottomFwd { launch_gated: false }),
        Box::new(GpuTopMlp),
        Box::new(GpuBottomBwd),
        Box::new(CxlGradFlush),
        Box::new(NdpEmbUpdate { correction: false }),
        tail,
        Box::new(CxlAttribution),
    ]
}

#[test]
fn mutant_unbounded_mlp_lag_is_flagged() {
    let chain = relaxed_chain_with_tail(Box::new(UnboundedMlpLog));
    let r = analysis::analyze_training_chain(&spec(CkptMode::Relaxed), "mutant", &chain);
    assert_flags(&r, "UnboundedMlpLag", |v| {
        matches!(v, Violation::UnboundedMlpLag { stage }
            if *stage == "mutant-unbounded-mlp-log")
    });
}

#[test]
fn mutant_unsealed_bootstrap_snapshot_is_flagged() {
    let chain = relaxed_chain_with_tail(Box::new(LazyBootstrapMlpLog));
    let r = analysis::analyze_training_chain(&spec(CkptMode::Relaxed), "mutant", &chain);
    assert_flags(&r, "UnsealedBootstrapSnapshot", |v| {
        matches!(v, Violation::UnsealedBootstrapSnapshot { stage }
            if *stage == "mutant-lazy-bootstrap-mlp-log")
    });
}

/// A stage that acquires the pool *while holding* a fabric link — the
/// reverse of the canonical pool-before-link nesting every real stage
/// follows (`tier-migrate`, `host-redo-ckpt`).
struct LinkThenPoolStage;

impl Stage for LinkThenPoolStage {
    fn name(&self) -> &'static str {
        "mutant-link-then-pool"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared().section(&[Resource::CxlLink, Resource::PmemPool])
    }

    fn run(&self, _env: &mut PipelineEnv, _ctx: &mut BatchCtx) {}
}

#[test]
fn mutant_cyclic_resource_order_is_flagged() {
    // tier-migrate nests pool -> link; the mutant nests link -> pool in
    // the same world. Two lanes running these concurrently can deadlock.
    let chain: Vec<Box<dyn Stage>> = vec![
        Box::new(TieredEmbLookup { relaxed: false }),
        Box::new(TieredEmbUndoLog),
        Box::new(stage::HotTierFlush),
        Box::new(DcohFlush),
        Box::new(GpuBottomFwd { launch_gated: false }),
        Box::new(GpuTopMlp),
        Box::new(GpuBottomBwd),
        Box::new(CxlGradFlush),
        Box::new(TieredEmbUpdate { correction: false }),
        Box::new(BatchAwareMlpLog),
        Box::new(LinkThenPoolStage),
        Box::new(TierMigrate),
        Box::new(CxlAttribution),
    ];
    let r = analysis::analyze_training_chain(&spec(CkptMode::BatchAware), "mutant", &chain);
    assert_flags(&r, "CyclicResourceOrder", |v| {
        matches!(v, Violation::CyclicResourceOrder { cycle }
            if cycle.contains(&Resource::PmemPool) && cycle.contains(&Resource::CxlLink))
    });
}

/// A serving stage that mutates the embedding table.
struct WritingServeStage;

impl ServeStage for WritingServeStage {
    fn name(&self) -> &'static str {
        "mutant-writing-serve-stage"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::EmbTable, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, _env: &mut PipelineEnv, _ctx: &mut ServeCtx) {}
}

#[test]
fn mutant_write_bearing_serving_stage_is_flagged() {
    let chain: Vec<Box<dyn ServeStage>> = vec![Box::new(WritingServeStage)];
    let r = analysis::analyze_serving_chain("mutant", &chain);
    assert_flags(&r, "WritingServingStage", |v| {
        matches!(v, Violation::WritingServingStage { stage, region }
            if *stage == "mutant-writing-serve-stage" && *region == Region::EmbTable)
    });
}

/// A stage that never declared its effects (trait default).
struct ForgetfulStage;

impl Stage for ForgetfulStage {
    fn name(&self) -> &'static str {
        "mutant-forgetful-stage"
    }

    fn run(&self, _env: &mut PipelineEnv, _ctx: &mut BatchCtx) {}
}

#[test]
fn mutant_undeclared_effects_is_flagged() {
    let chain: Vec<Box<dyn Stage>> = vec![Box::new(ForgetfulStage)];
    let r = analysis::analyze_training_chain(&spec(CkptMode::None), "mutant", &chain);
    assert_flags(&r, "UndeclaredEffects", |v| {
        matches!(v, Violation::UndeclaredEffects { stage }
            if *stage == "mutant-forgetful-stage")
    });
}

// ------------------------------------------------------------ repo gate

#[test]
fn analyze_repo_gate_is_clean() {
    // The exact sweep the `trainingcxl analyze` CI gate runs: every
    // shipped configs/topologies/*.toml (training + serving + tenant
    // worlds) plus the exhaustive builder-family enumeration.
    let root = trainingcxl::repo_root();
    if !root.join("configs/topologies").is_dir() {
        eprintln!("skipping: no configs/topologies under {}", root.display());
        return;
    }
    let reports = analysis::analyze_repo(&root).expect("shipped configs must load");
    assert!(reports.len() > 100, "enumeration unexpectedly small");
    for r in &reports {
        assert!(r.is_clean(), "{r}");
    }
}
