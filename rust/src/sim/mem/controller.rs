//! Request-level memory controller on the discrete-event engine.
//!
//! Models `channels` independent channels, each a FIFO with `queue_depth`
//! in-flight slots. An access occupies a slot for its device latency and
//! the channel data bus for `bytes/bw`; the two overlap across requests up
//! to the queue depth — the same behaviour the closed-form
//! [`super::MediaModel::batch_access`] approximates. Used by
//! `benches/table2_media.rs` and the validation test in `super::tests`.

use super::AccessKind;
use crate::config::device::MediaParams;
use crate::sim::engine::EventQueue;
use crate::sim::{ns, SimTime};

/// One memory request (addresses are only used for channel interleave).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub addr: u64,
    pub bytes: u64,
    pub kind: AccessKind,
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    /// A request completed on `chan`.
    Done { chan: usize },
}

/// Channel-interleaved controller; 256B interleave granularity.
pub struct Controller {
    p: MediaParams,
    /// Per-channel: (bus_free_at, in-flight completion times)
    chans: Vec<ChanState>,
}

#[derive(Clone, Debug, Default)]
struct ChanState {
    bus_free: SimTime,
    inflight: usize,
    pending: std::collections::VecDeque<Request>,
    last_done: SimTime,
}

impl Controller {
    pub fn new(p: MediaParams) -> Self {
        let chans = vec![ChanState::default(); p.channels];
        Controller { p, chans }
    }

    fn service(&self, r: &Request) -> (SimTime, SimTime) {
        let lat = match r.kind {
            AccessKind::Read => self.p.read_ns,
            AccessKind::Write => self.p.write_ns,
        };
        let bw = match r.kind {
            AccessKind::Read => self.p.read_gbps,
            AccessKind::Write => self.p.write_gbps,
        };
        let amp = if r.kind == AccessKind::Write {
            self.p.write_amp.max(1.0)
        } else {
            1.0
        };
        (ns(lat), ns(r.bytes as f64 * amp / bw))
    }

    fn try_issue(&mut self, chan: usize, now: SimTime, q: &mut EventQueue<Ev>) {
        while self.chans[chan].inflight < self.p.queue_depth
            && !self.chans[chan].pending.is_empty()
        {
            let r = self.chans[chan].pending.pop_front().unwrap();
            let (lat, xfer) = self.service(&r);
            let st = &mut self.chans[chan];
            // data bus serialises transfers; device latency overlaps
            let bus_start = st.bus_free.max(now);
            let done = (bus_start + xfer).max(now + lat);
            st.bus_free = bus_start + xfer;
            st.inflight += 1;
            st.last_done = st.last_done.max(done);
            q.schedule(done, Ev::Done { chan });
        }
    }

    /// Simulate a closed batch of requests all arriving at t=0; returns the
    /// makespan.
    pub fn run_batch(&mut self, reqs: &[Request]) -> SimTime {
        for c in &mut self.chans {
            *c = ChanState::default();
        }
        let nchan = self.chans.len();
        for r in reqs {
            let chan = ((r.addr / 256) as usize) % nchan;
            self.chans[chan].pending.push_back(*r);
        }
        let mut q: EventQueue<Ev> = EventQueue::new();
        for chan in 0..nchan {
            self.try_issue(chan, 0, &mut q);
        }
        let mut makespan = 0;
        while let Some((now, Ev::Done { chan })) = q.pop() {
            makespan = makespan.max(now);
            self.chans[chan].inflight -= 1;
            self.try_issue(chan, now, &mut q);
        }
        makespan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::device::DeviceParams;

    #[test]
    fn single_request_costs_latency_or_transfer() {
        let p = DeviceParams::builtin_default();
        let mut c = Controller::new(p.dram.clone());
        let d = c.run_batch(&[Request {
            addr: 0,
            bytes: 64,
            kind: AccessKind::Read,
        }]);
        // one access: bounded below by device latency
        assert!(d >= p.dram.read_ns as SimTime);
        assert!(d < 2 * p.dram.read_ns as SimTime + 64);
    }

    #[test]
    fn channel_parallelism_scales() {
        let p = DeviceParams::builtin_default();
        let reqs: Vec<Request> = (0..4000)
            .map(|i| Request {
                addr: i * 256,
                bytes: 128,
                kind: AccessKind::Read,
            })
            .collect();
        let mut four = Controller::new(p.pmem.clone());
        let d4 = four.run_batch(&reqs);
        let mut one_p = p.pmem.clone();
        one_p.channels = 1;
        let mut one = Controller::new(one_p);
        let d1 = one.run_batch(&reqs);
        let speedup = d1 as f64 / d4 as f64;
        assert!(
            (3.0..=4.5).contains(&speedup),
            "expected ~4x from 4 channels, got {speedup:.2}"
        );
    }

    #[test]
    fn queue_depth_hides_latency() {
        let p = DeviceParams::builtin_default();
        let reqs: Vec<Request> = (0..1000)
            .map(|i| Request {
                addr: i * 256,
                bytes: 64,
                kind: AccessKind::Read,
            })
            .collect();
        let mut deep = Controller::new(p.ssd.clone());
        let dd = deep.run_batch(&reqs);
        let mut shallow_p = p.ssd.clone();
        shallow_p.queue_depth = 1;
        let mut shallow = Controller::new(shallow_p);
        let ds = shallow.run_batch(&reqs);
        assert!(ds as f64 > 4.0 * dd as f64, "QD8 {dd} vs QD1 {ds}");
    }
}
