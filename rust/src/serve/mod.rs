//! Online inference serving over the pooled fabric.
//!
//! Training shares its disaggregated embedding pool with the system that
//! actually faces users: inference servers doing read-heavy, p99-bound
//! lookups against the same tables the trainers update. This module makes
//! serving a first-class workload:
//!
//! * [`arrivals`] — deterministic open-loop arrival generation (Poisson
//!   base rate, diurnal/spike trace shapes);
//! * [`batcher`] — dynamic request batching under an explicit
//!   `max_batch` × `max_wait_us` policy;
//! * [`ServingSim`] — a read-only lookup pipeline composed from the same
//!   device/stage vocabulary as the training chains ([`compose_serving`]),
//!   over any topology family (software, PCIe, pooled CXL, tiered,
//!   sharded). No undo log, no checkpoint stages, no update legs: a
//!   serving batch is lookup → movement → forward-only MLP.
//!
//! Serving tenants co-locate with trainers through
//! [`crate::tenancy::MultiTenantSim`] (`role = "server"` in `[[tenants]]`
//! TOML), contending for the same PMEM pool and switch links — which is
//! where tail amplification (co-located p99 / isolated p99) and staleness
//! (served-embedding age behind the training head) come from.

use crate::analysis::effects::{Region, Resource, Rows, StageEffects};
use crate::config::device::DeviceParams;
use crate::config::ModelConfig;
use crate::devices::CxlGpu;
use crate::sched::pipeline::RunResult;
use crate::sched::stage::PipelineEnv;
use crate::sim::cxl::Proto;
use crate::sim::engine::{Event, EventQueue};
use crate::sim::mem::MediaKind;
use crate::sim::topology::{Topology, TopologyError};
use crate::sim::{Lane, OpKind, SimTime};
use crate::telemetry::trace::{TraceEvent, TraceKind, TraceLog};
use crate::telemetry::{Breakdown, LatencyHistogram, StalenessGauge};
use crate::workload::BatchStats;

pub mod arrivals;
pub mod batcher;

pub use arrivals::{ArrivalProcess, TraceShape};
pub use batcher::{BatchPolicy, Batcher, FormedBatch};

/// Serving knobs of one server tenant (the `role = "server"` TOML keys).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeConfig {
    /// Offered load (requests per second), open-loop.
    pub rate_per_s: f64,
    pub policy: BatchPolicy,
    pub trace: TraceShape,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            rate_per_s: 2000.0,
            policy: BatchPolicy::default(),
            trace: TraceShape::Steady,
        }
    }
}

/// Per-serving-batch timing slots, produced left-to-right by the serve
/// stage chain (the read-only analogue of
/// [`crate::sched::stage::BatchCtx`]).
#[derive(Clone, Copy, Debug)]
pub struct ServeCtx {
    pub batch: u64,
    pub t0: SimTime,
    /// Requests in this dynamic batch.
    pub requests: u64,
    /// Embedding gather completion (all lanes/tiers).
    pub lookup_done: SimTime,
    /// Reduced-vector movement completion (DCOH flush or software copy).
    pub xf_end: SimTime,
    /// Interaction + top-MLP forward window.
    pub tm_start: SimTime,
    /// Batch completion (responses ready).
    pub end: SimTime,
    /// Critical-path attribution (checkpoint stays 0 — read-only).
    pub bd: Breakdown,
}

impl ServeCtx {
    pub fn new(batch: u64, t0: SimTime, requests: u64) -> ServeCtx {
        ServeCtx {
            batch,
            t0,
            requests,
            lookup_done: t0,
            xf_end: t0,
            tm_start: t0,
            end: t0,
            bd: Breakdown::default(),
        }
    }
}

/// One schedulable slice of a serving batch, sharing [`PipelineEnv`] with
/// the training stages so both tenant classes charge the same devices,
/// media, and `pmem_free` serialisation point. `Send + Sync` for the same
/// reason as [`Stage`](crate::sched::stage::Stage): server lanes run on
/// the engine's worker pool.
pub trait ServeStage: Send + Sync {
    fn name(&self) -> &'static str;

    /// Declarative effect summary for the static analyzer
    /// ([`crate::analysis`]); same contract as
    /// [`crate::sched::stage::Stage::effects`]. The write-free check
    /// runs over these declarations, so a serving stage that mutates
    /// recoverable state is caught before it ever runs.
    fn effects(&self) -> StageEffects {
        StageEffects::undeclared()
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut ServeCtx);
}

/// Traffic-accounting label of a medium (the serve-side copy of the
/// private mapping in `sched::stage`).
fn medium_name(kind: MediaKind) -> &'static str {
    match kind {
        MediaKind::Dram => "dram",
        MediaKind::Pmem => "pmem",
        MediaKind::Ssd => "ssd",
    }
}

/// Batch statistics for `requests` served requests: the model's training
/// batch stats rescaled from the training batch size.
fn serve_stats(env: &PipelineEnv, requests: u64) -> BatchStats {
    env.stats.scaled(requests, env.cfg.batch_size as u64)
}

/// Lane `s`'s stripe of the serving stats (aggregate when unsharded).
fn lane_serve_stats(env: &PipelineEnv, s: usize, requests: u64) -> BatchStats {
    let base = if env.topo.gpu_shards > 1 {
        env.shard_stats[s]
    } else {
        env.stats
    };
    base.scaled(requests, env.cfg.batch_size as u64)
}

/// Reduced-vector bytes a serving batch moves to the GPU.
fn serve_reduced_bytes(env: &PipelineEnv, requests: u64) -> u64 {
    requests * (env.cfg.num_tables * env.cfg.feature_dim * 4) as u64
}

// ======================================================= lookup stages

/// Host-CPU gather against the storage tier (software baselines),
/// optionally in front of the host-DRAM vector cache. Read-only: no RAW
/// exposure regardless of co-located updates to *other* rows.
struct HostServeLookup;

impl ServeStage for HostServeLookup {
    fn name(&self) -> &'static str {
        "host-serve-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .read(Region::HostMirror, Rows::Hot)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut ServeCtx) {
        let s = serve_stats(env, ctx.requests);
        let medium = medium_name(env.topo.table_media);
        let cache = if env.topo.dram_vector_cache {
            s.hot_hit_frac
        } else {
            0.0
        };
        let st = env.pmem_free.max(ctx.t0);
        let lk = env
            .host
            .embedding_lookup(st, &mut env.table, &mut env.dram, s.accesses, cache, 0.0);
        let end = st + lk.duration;
        env.pmem_free = end;
        env.traffic.record(medium, lk.media.bytes_read, lk.media.bytes_written);
        env.spans.add(Lane::HostCpu, OpKind::EmbLookup, ctx.batch, st, end);
        env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, st, end);
        env.host_busy += lk.duration;
        ctx.lookup_done = end;
    }
}

/// Near-data gather on the expander's computing logic against the pooled
/// backend, serialised on `pmem_free` like every pool consumer.
struct PooledServeLookup {
    /// PCIe configuration pays a host kernel launch before the gather.
    launch_gated: bool,
}

impl ServeStage for PooledServeLookup {
    fn name(&self) -> &'static str {
        "pooled-serve-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut ServeCtx) {
        let s = serve_stats(env, ctx.requests);
        let gate = if self.launch_gated {
            ctx.t0 + env.host.p.kernel_launch_ns as SimTime
        } else {
            ctx.t0
        };
        let st = env.pmem_free.max(gate);
        let lk = env.mem.embedding_lookup(st, &mut env.table, s.accesses, 0.0);
        let end = st + lk.duration;
        env.pmem_free = end;
        env.traffic.record("pmem", lk.media.bytes_read, lk.media.bytes_written);
        env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, st, end);
        env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, st, end);
        env.logic_busy += lk.duration;
        ctx.lookup_done = end;
    }
}

/// Per-tier gather: the Zipf head reads from the volatile hot tier beside
/// the pool, the cold tail serialises through `pmem_free`. Lane-looping,
/// so it composes with `gpu_shards(n)`.
struct TieredServeLookup;

impl ServeStage for TieredServeLookup {
    fn name(&self) -> &'static str {
        "tiered-serve-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::Cold)
            .read(Region::HotTier, Rows::Hot)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut ServeCtx) {
        for lane in 0..env.topo.gpu_shards {
            let s = lane_serve_stats(env, lane, ctx.requests);
            let cold_acc = s.accesses - s.hot_accesses;
            let mut lane_end = ctx.t0;
            if cold_acc > 0 {
                let st = env.pmem_free.max(ctx.t0);
                let lk = env.mem.embedding_lookup(st, &mut env.table, cold_acc, 0.0);
                let end = st + lk.duration;
                env.pmem_free = end;
                env.traffic.record("pmem", lk.media.bytes_read, lk.media.bytes_written);
                env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, st, end);
                env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, st, end);
                env.logic_busy += lk.duration;
                lane_end = end;
            }
            if s.hot_accesses > 0 {
                let hot = env.hot.as_mut().expect("tiered serve without a hot tier");
                let lk = env.mem.embedding_lookup(ctx.t0, hot, s.hot_accesses, 0.0);
                let medium = medium_name(hot.kind);
                env.traffic.record(medium, lk.media.bytes_read, lk.media.bytes_written);
                env.logic_busy += lk.duration;
                lane_end = lane_end.max(ctx.t0 + lk.duration);
            }
            ctx.lookup_done = ctx.lookup_done.max(lane_end);
        }
    }
}

/// Per-lane gathers of each GPU lane's table stripe against the shared
/// pool (multi-GPU sharded topologies).
struct ShardedServeLookup;

impl ServeStage for ShardedServeLookup {
    fn name(&self) -> &'static str {
        "sharded-serve-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut ServeCtx) {
        for lane in 0..env.topo.gpu_shards {
            let s = lane_serve_stats(env, lane, ctx.requests);
            if s.accesses == 0 {
                continue;
            }
            let st = env.pmem_free.max(ctx.t0);
            let lk = env.mem.embedding_lookup(st, &mut env.table, s.accesses, 0.0);
            let end = st + lk.duration;
            env.pmem_free = end;
            env.traffic.record("pmem", lk.media.bytes_read, lk.media.bytes_written);
            env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, st, end);
            env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, st, end);
            env.logic_busy += lk.duration;
            ctx.lookup_done = ctx.lookup_done.max(end);
        }
    }
}

// ========================================================= data movement

/// Move the gathered reduced vectors to the GPU: DCOH flush over CXL
/// (`hw`) or sync + memcpy + launch over PCIe (software).
struct ServeTransfer {
    hw: bool,
}

impl ServeStage for ServeTransfer {
    fn name(&self) -> &'static str {
        "serve-transfer"
    }

    fn effects(&self) -> StageEffects {
        let link = if self.hw {
            Resource::CxlLink
        } else {
            Resource::PcieLink
        };
        StageEffects::declared()
            .read(Region::ReducedVectors, Rows::All)
            .write(Region::GpuVectors, Rows::All)
            .section(&[link])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut ServeCtx) {
        let bytes = serve_reduced_bytes(env, ctx.requests);
        let start = ctx.lookup_done.max(ctx.t0);
        let end = if self.hw {
            let fl = env.cxl.transfer(bytes, Proto::Cache);
            env.traffic.record_link(fl.bytes);
            env.spans.add(Lane::Link, OpKind::Transfer, ctx.batch, start, start + fl.duration);
            start + fl.duration
        } else {
            let xf = env.host.sw_transfer(&env.pcie, bytes);
            env.traffic.record_link(xf.link_bytes);
            env.spans.add(Lane::HostCpu, OpKind::Transfer, ctx.batch, start, start + xf.duration);
            env.host_busy += xf.duration;
            start + xf.duration
        };
        ctx.xf_end = end;
    }
}

// ====================================================== GPU forward pass

/// Forward-only MLP: bottom MLP overlaps the gather from `t0`, the
/// interaction + top MLP waits for both. Durations scale with the dynamic
/// batch size (the GPU kernels were profiled at the training batch size).
/// Also writes the critical-path attribution — an exact partition of
/// `end - t0` into embedding/transfer/bmlp/tmlp (checkpoint stays 0).
struct ServeGpuForward {
    launch_gated: bool,
}

impl ServeStage for ServeGpuForward {
    fn name(&self) -> &'static str {
        "serve-gpu-forward"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::GpuVectors, Rows::All)
            .section(&[Resource::GpuLane])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut ServeCtx) {
        let requests = ctx.requests;
        let bs = (env.cfg.batch_size as u64).max(1);
        let scale =
            |d: SimTime| ((d as u128 * requests as u128).div_ceil(bs as u128) as SimTime).max(1);
        let bf_start = if self.launch_gated {
            ctx.t0 + env.host.p.kernel_launch_ns as SimTime
        } else {
            ctx.t0
        };
        let bf = scale(env.gpu.bmlp_fwd);
        let bf_end = bf_start + bf;
        env.spans.add(Lane::Gpu, OpKind::BottomMlp, ctx.batch, bf_start, bf_end);
        let tm_start = bf_end.max(ctx.xf_end);
        let tm = scale(env.gpu.tmlp_fwd);
        let tm_end = tm_start + tm;
        env.spans.add(Lane::Gpu, OpKind::TopMlp, ctx.batch, tm_start, tm_end);
        env.gpu_busy += bf + tm;
        ctx.tm_start = tm_start;
        ctx.end = tm_end;
        ctx.bd.embedding = (ctx.lookup_done - ctx.t0) as f64;
        ctx.bd.transfer = (ctx.xf_end - ctx.lookup_done) as f64;
        ctx.bd.bmlp = (tm_start - ctx.xf_end) as f64;
        ctx.bd.tmlp = (tm_end - tm_start) as f64;
    }
}

// ========================================================== composition

/// Select the read-only serving chain for a topology — the same branch
/// structure as [`crate::sched::stage::compose`], minus every mutation
/// and checkpoint stage.
pub fn compose_serving(t: &Topology) -> Result<Vec<Box<dyn ServeStage>>, TopologyError> {
    t.validate()?;
    let mut v: Vec<Box<dyn ServeStage>> = Vec::new();
    if !t.near_data_processing {
        v.push(Box::new(HostServeLookup));
        v.push(Box::new(ServeTransfer { hw: false }));
        v.push(Box::new(ServeGpuForward { launch_gated: true }));
    } else if !t.hw_data_movement {
        v.push(Box::new(PooledServeLookup { launch_gated: true }));
        v.push(Box::new(ServeTransfer { hw: false }));
        v.push(Box::new(ServeGpuForward { launch_gated: true }));
    } else if t.tier_split().is_some() {
        v.push(Box::new(TieredServeLookup));
        v.push(Box::new(ServeTransfer { hw: true }));
        v.push(Box::new(ServeGpuForward {
            launch_gated: false,
        }));
    } else if t.gpu_shards == 1 {
        v.push(Box::new(PooledServeLookup {
            launch_gated: false,
        }));
        v.push(Box::new(ServeTransfer { hw: true }));
        v.push(Box::new(ServeGpuForward {
            launch_gated: false,
        }));
    } else {
        v.push(Box::new(ShardedServeLookup));
        v.push(Box::new(ServeTransfer { hw: true }));
        v.push(Box::new(ServeGpuForward {
            launch_gated: false,
        }));
    }
    Ok(v)
}

// ============================================================ simulator

/// Serving-side counters a run accumulates beside its [`RunResult`].
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub latency: LatencyHistogram,
    pub staleness: StalenessGauge,
    pub requests: u64,
}

/// Result of a standalone serving run.
#[derive(Clone, Debug)]
pub struct ServeRun {
    pub result: RunResult,
    pub stats: ServeStats,
}

/// One serving batch's outcome, returned by [`ServingSim::step_batch`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOutcome {
    /// When processing started (arrival flush or server availability,
    /// whichever is later).
    pub start: SimTime,
    pub end: SimTime,
    pub bd: Breakdown,
    pub requests: u64,
}

/// Open-loop serving simulator for one (model, topology) pair: arrivals
/// feed the dynamic batcher, each flushed batch runs the composed
/// read-only chain, and every request's completion latency (from its
/// arrival timestamp) lands in the histogram.
pub struct ServingSim {
    env: PipelineEnv,
    stages: Vec<Box<dyn ServeStage>>,
    arrivals: ArrivalProcess,
    batcher: Batcher,
    hist: LatencyHistogram,
    staleness: StalenessGauge,
    requests_served: u64,
}

impl ServingSim {
    /// Wrap an instantiated env. The arrival stream is seeded from the
    /// tenant seed, so a fixed seed replays the same offered load.
    pub fn from_env(
        env: PipelineEnv,
        serve: &ServeConfig,
        seed: u64,
    ) -> Result<ServingSim, TopologyError> {
        let stages = compose_serving(&env.topo)?;
        Ok(ServingSim {
            stages,
            arrivals: ArrivalProcess::new(seed, serve.rate_per_s, serve.trace),
            batcher: Batcher::new(serve.policy),
            hist: LatencyHistogram::new(),
            staleness: StalenessGauge::default(),
            requests_served: 0,
            env,
        })
    }

    /// Build the simulator for one `(model, topology)` pair — the serving
    /// mirror of [`crate::sched::PipelineSim::for_model`], sharing its
    /// workload-statistics construction so a server tenant sees the same
    /// table skew its co-located trainer does.
    pub fn for_model(
        root: &std::path::Path,
        model: &str,
        topo: Topology,
        seed: u64,
        serve: &ServeConfig,
    ) -> anyhow::Result<ServingSim> {
        use crate::workload::Generator;
        let cfg = ModelConfig::load(root, model)?;
        let params = DeviceParams::load(root)?;
        let gpu = CxlGpu::from_params(&cfg, &params, root);
        let cache = if topo.dram_vector_cache {
            params.host.dram_cache_rows_frac
        } else {
            0.0
        };
        let shards = topo.gpu_shards;
        let hot_frac = topo.tier_split().map(|t| t.hot_frac).unwrap_or(0.0);
        let stats = Generator::average_stats_tiered(&cfg, seed, 8, cache, hot_frac);
        let mut env = PipelineEnv::new(&cfg, topo, &params, gpu, stats);
        if shards > 1 {
            env.shard_stats =
                Generator::sharded_average_stats_tiered(&cfg, seed, 8, cache, hot_frac, shards);
        }
        let sim = ServingSim::from_env(env, serve, seed)?;
        Ok(sim)
    }

    pub fn stage_names(&self) -> Vec<&'static str> {
        self.stages.iter().map(|s| s.name()).collect()
    }

    pub fn env(&self) -> &PipelineEnv {
        &self.env
    }

    /// Mutable env access for cross-tenant drivers (the tenancy arbiter
    /// charges co-tenant pool occupancy to `pmem_free`).
    pub fn env_mut(&mut self) -> &mut PipelineEnv {
        &mut self.env
    }

    /// Form and serve the next dynamic batch. `now` is when the server
    /// becomes free (previous batch end); processing starts at
    /// `max(now, flush)` — a backlogged server keeps old flush times
    /// waiting, which is exactly how open-loop queueing delay reaches the
    /// latency histogram.
    pub fn step_batch(&mut self, batch: u64, now: SimTime) -> ServeOutcome {
        let arrivals = &mut self.arrivals;
        let formed = self.batcher.form(&mut || arrivals.next_arrival());
        let t0 = now.max(formed.flush);
        let requests = formed.arrivals.len() as u64;
        let mut ctx = ServeCtx::new(batch, t0, requests);
        for s in &self.stages {
            s.run(&mut self.env, &mut ctx);
        }
        debug_assert!(ctx.end > t0, "serving batch must advance time");
        for &a in &formed.arrivals {
            self.hist.record((ctx.end - a).max(1));
        }
        self.requests_served += requests;
        ServeOutcome {
            start: t0,
            end: ctx.end,
            bd: ctx.bd,
            requests,
        }
    }

    /// Record how many training batches behind the head this serving
    /// batch's embeddings were (driven by the tenancy loop; standalone
    /// runs stay at age 0 implicitly).
    pub fn note_staleness(&mut self, age_batches: u64) {
        self.staleness.record(age_batches);
    }

    pub fn latency(&self) -> &LatencyHistogram {
        &self.hist
    }

    /// Assemble the final records — the serving mirror of
    /// [`crate::sched::PipelineSim::finish`].
    pub fn finish(
        self,
        breakdowns: Vec<Breakdown>,
        batch_times: Vec<SimTime>,
        total_time: SimTime,
    ) -> (RunResult, ServeStats) {
        let env = self.env;
        let result = RunResult {
            config: env.topo.system_label(),
            topology: env.topo.name.clone(),
            model: env.cfg.name.clone(),
            spans: env.spans,
            breakdowns,
            batch_times,
            traffic: env.traffic,
            total_time,
            raw_hits: env.raw_hits,
            max_mlp_gap: env.max_mlp_gap,
            gpu_busy: env.gpu_busy,
            host_busy: env.host_busy,
            logic_busy: env.logic_busy,
            trace: TraceLog::default(),
        };
        let stats = ServeStats {
            latency: self.hist,
            staleness: self.staleness,
            requests: self.requests_served,
        };
        (result, stats)
    }

    /// Serve `n` dynamic batches; returns the accumulated run.
    ///
    /// Pumped through the discrete-event engine exactly like
    /// [`PipelineSim::run`](crate::sched::pipeline::PipelineSim::run):
    /// `SlotStart` steps the batch at the lane clock, `SlotDone` fires at
    /// its completion and chains the next slot — bit-identical to the
    /// pre-engine sequential loop (the single-server tenancy pin in
    /// `rust/tests/serving.rs` holds this).
    pub fn run(mut self, n: u64) -> ServeRun {
        let mut breakdowns = Vec::with_capacity(n as usize);
        let mut batch_times = Vec::with_capacity(n as usize);
        let mut q: EventQueue<Event> = EventQueue::new();
        let mut trace = TraceLog::new();
        let root = trace.record(TraceEvent::span(None, Some(0), TraceKind::Run, 0, 0));
        let mut t = 0;
        if n > 0 {
            q.schedule(0, Event::SlotStart { lane: 0, batch: 0 });
        }
        while let Some((at, ev)) = q.pop() {
            match ev {
                Event::SlotStart { batch, .. } => {
                    let out = self.step_batch(batch, at);
                    let kind = TraceKind::slot(batch, out.end - out.start, 0, 0, 0, &out.bd);
                    trace.record(TraceEvent::span(
                        Some(root),
                        Some(0),
                        kind,
                        out.start,
                        out.end,
                    ));
                    breakdowns.push(out.bd);
                    batch_times.push(out.end - out.start);
                    q.schedule(out.end, Event::SlotDone { lane: 0, batch });
                }
                Event::SlotDone { batch, .. } => {
                    t = at;
                    if batch + 1 < n {
                        q.schedule(at, Event::SlotStart { lane: 0, batch: batch + 1 });
                    }
                }
                _ => unreachable!("solo serving lanes only pump slot events"),
            }
        }
        trace.close(root, 0, t);
        let (mut result, stats) = self.finish(breakdowns, batch_times, t);
        result.trace = trace;
        ServeRun { result, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::sysconfig::SystemConfig;
    use crate::repo_root;

    fn serving(model: &str, topo: Topology, seed: u64, cfg: &ServeConfig) -> ServingSim {
        ServingSim::for_model(&repo_root(), model, topo, seed, cfg).unwrap()
    }

    #[test]
    fn composition_tracks_the_topology_family() {
        let names = |t: &Topology| {
            compose_serving(t)
                .unwrap()
                .iter()
                .map(|s| s.name())
                .collect::<Vec<_>>()
        };
        let cxl = names(&Topology::from_system(SystemConfig::Cxl));
        assert_eq!(
            cxl,
            vec!["pooled-serve-lookup", "serve-transfer", "serve-gpu-forward"]
        );
        let ssd = names(&Topology::from_system(SystemConfig::Ssd));
        assert_eq!(ssd[0], "host-serve-lookup");
        let pcie = names(&Topology::from_system(SystemConfig::Pcie));
        assert_eq!(pcie[0], "pooled-serve-lookup");
        let sharded = Topology::builder("s2")
            .near_data()
            .hw_movement()
            .gpu_shards(2)
            .build()
            .unwrap();
        assert_eq!(names(&sharded)[0], "sharded-serve-lookup");
        let tiered = Topology::builder("t")
            .near_data()
            .hw_movement()
            .tiered_media(MediaKind::Dram, 0.3)
            .build()
            .unwrap();
        assert_eq!(names(&tiered)[0], "tiered-serve-lookup");
    }

    #[test]
    fn serving_run_is_deterministic_for_a_fixed_seed() {
        let cfg = ServeConfig::default();
        let run = || {
            serving(
                "rm_mini",
                Topology::from_system(SystemConfig::Cxl),
                42,
                &cfg,
            )
            .run(12)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.result.total_time, b.result.total_time);
        assert_eq!(a.result.batch_times, b.result.batch_times);
        assert_eq!(a.stats.latency, b.stats.latency);
        assert_eq!(a.stats.requests, b.stats.requests);
        assert!(a.stats.requests > 0);
        assert!(a.stats.latency.p999() >= a.stats.latency.p50());
        assert!(a.stats.latency.p50() > 0);
    }

    #[test]
    fn breakdown_partitions_the_service_time_exactly() {
        let run = serving(
            "rm_mini",
            Topology::from_system(SystemConfig::Cxl),
            7,
            &ServeConfig::default(),
        )
        .run(8);
        for (bd, bt) in run.result.breakdowns.iter().zip(&run.result.batch_times) {
            let sum = bd.embedding + bd.transfer + bd.bmlp + bd.tmlp + bd.checkpoint;
            assert!(
                (sum - *bt as f64).abs() < 1.0,
                "breakdown {sum} vs batch {bt}"
            );
            assert_eq!(bd.checkpoint, 0.0, "serving writes no checkpoints");
        }
    }

    #[test]
    fn serving_is_read_only_on_the_pool() {
        let run = serving(
            "rm_mini",
            Topology::from_system(SystemConfig::Cxl),
            42,
            &ServeConfig::default(),
        )
        .run(8);
        assert_eq!(run.result.raw_hits, 0, "read-only lookups see no RAW");
        let (read, written) = run.result.traffic.by_medium["pmem"];
        assert!(read > 0, "lookups must read the pool");
        assert_eq!(written, 0, "serving must not write the pool");
    }

    #[test]
    fn bigger_batches_amortise_into_higher_throughput() {
        let fast = ServeConfig {
            rate_per_s: 200_000.0,
            policy: BatchPolicy {
                max_batch: 64,
                max_wait_us: 2000,
            },
            trace: TraceShape::Steady,
        };
        let tiny = ServeConfig {
            policy: BatchPolicy {
                max_batch: 1,
                max_wait_us: 2000,
            },
            ..fast
        };
        let topo = || Topology::from_system(SystemConfig::Cxl);
        let big = serving("rm_mini", topo(), 42, &fast).run(16);
        let small = serving("rm_mini", topo(), 42, &tiny).run(16);
        let thru = |r: &ServeRun| r.stats.requests as f64 / r.result.total_time as f64;
        assert!(
            thru(&big) > thru(&small),
            "batched {} vs per-request {}",
            thru(&big),
            thru(&small)
        );
    }

    #[test]
    fn all_topology_families_serve() {
        for sys in [SystemConfig::Ssd, SystemConfig::Pcie, SystemConfig::Cxl] {
            let run = serving(
                "rm_mini",
                Topology::from_system(sys),
                42,
                &ServeConfig::default(),
            )
            .run(6);
            assert!(run.stats.latency.p99() > 0, "{sys:?} produced no latencies");
        }
        let tiered = Topology::builder("tiered-serve")
            .near_data()
            .hw_movement()
            .tiered_media(MediaKind::Dram, 0.3)
            .build()
            .unwrap();
        let run = serving("rm_mini", tiered, 42, &ServeConfig::default()).run(6);
        assert!(run.stats.latency.p99() > 0);
        let (dram_read, _) = run.result.traffic.by_medium["dram"];
        assert!(dram_read > 0, "tiered serving must read the hot tier");
        let sharded = Topology::builder("sharded-serve")
            .near_data()
            .hw_movement()
            .gpu_shards(2)
            .expander_pool(2, 1)
            .build()
            .unwrap();
        let run = serving("rm_mini", sharded, 42, &ServeConfig::default()).run(6);
        assert!(run.stats.latency.p99() > 0);
    }
}
