//! CXL-MEM: the PMEM-backed Type-2 memory expander (paper Fig 3b, Fig 10).
//!
//! Frontend: a CXL (3.0) controller exposing MMIO registers, a *computing
//! logic* (adders/multipliers + scratchpad) that performs embedding
//! lookup/update near the data, and a *checkpointing logic* (CXL DMA
//! engine + two counters) that creates embedding/MLP logs. Backend: four
//! memory controllers over PMEM.
//!
//! Methods price one batch-level operation each and return
//! [`AccessCost`]s; the scheduler composes them into the pipeline and the
//! telemetry/energy accounting.

use crate::config::device::{CkptLogicParams, CompLogicParams, DeviceParams};
use crate::config::ModelConfig;
use crate::sim::cxl::{Link, Proto};
use crate::sim::mem::{AccessCost, AccessKind, MediaModel};
use crate::sim::{ns, SimTime};

/// MMIO configuration registers (paper: "the host CPU sets CXL-MEM's MMIO
/// registers with embedding vector length and learning rate ... MLP
/// parameters' memory address and the size of MLP parameters").
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MmioRegs {
    pub vec_len: u32,
    pub lr_bits: u32, // f32 as bits: MMIO registers are untyped words
    pub mlp_addr: u64,
    pub mlp_size: u64,
    /// Sparse-feature window for the *next* batch (batch-aware checkpoint
    /// needs to know which rows will be updated before training completes).
    pub sparse_base: u64,
    pub sparse_len: u64,
}

/// Outcome of a CXL-MEM operation: device time plus media accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemOp {
    pub duration: SimTime,
    pub media: AccessCost,
    /// Bytes that crossed the CXL link (MLP log pulls, flushes).
    pub link_bytes: u64,
    /// Compute time within `duration` spent in the adder tree.
    pub compute_ns: SimTime,
}

/// The CXL-MEM device (timing oracle + MMIO state).
#[derive(Clone, Debug)]
pub struct CxlMem {
    pub regs: MmioRegs,
    comp: CompLogicParams,
    ckpt: CkptLogicParams,
    row_bytes: u64,
    feature_dim: u64,
}

impl CxlMem {
    pub fn new(cfg: &ModelConfig, p: &DeviceParams) -> CxlMem {
        CxlMem {
            regs: MmioRegs {
                vec_len: cfg.feature_dim as u32,
                lr_bits: (cfg.lr as f32).to_bits(),
                mlp_addr: 0x4000_0000,
                mlp_size: cfg.mlp_param_bytes(),
                sparse_base: 0,
                sparse_len: 0,
            },
            comp: p.comp_logic.clone(),
            ckpt: p.ckpt_logic.clone(),
            row_bytes: cfg.row_bytes(),
            feature_dim: cfg.feature_dim as u64,
        }
    }

    /// Host writes the next batch's sparse-feature window (per batch, the
    /// enabler of batch-aware checkpointing).
    pub fn set_sparse_window(&mut self, base: u64, len: u64) {
        self.regs.sparse_base = base;
        self.regs.sparse_len = len;
    }

    /// Embedding lookup + aggregation for one batch: `accesses` row reads
    /// from PMEM overlapped with the adder tree; `raw_frac` of them may be
    /// RAW-exposed (0 under relaxed lookup).
    pub fn embedding_lookup(
        &self,
        start: SimTime,
        pmem: &mut MediaModel,
        accesses: u64,
        raw_frac: f64,
    ) -> MemOp {
        let media = pmem.batch_access(start, accesses, self.row_bytes, AccessKind::Read, raw_frac);
        // one fused-multiply-add lane per element; fully overlapped with
        // the reads except the drain of the last vector
        let flops = accesses * self.feature_dim;
        let compute = ns(flops as f64 / self.comp.flops_per_ns);
        let drain = ns(self.feature_dim as f64 / self.comp.flops_per_ns);
        MemOp {
            duration: media.duration.max(compute) + drain,
            media,
            link_bytes: 0,
            compute_ns: compute,
        }
    }

    /// Embedding (SGD) update: read-modify-write of the touched rows plus
    /// the gradient-apply arithmetic.
    pub fn embedding_update(
        &self,
        start: SimTime,
        pmem: &mut MediaModel,
        unique_rows: u64,
        extra_correction_rows: u64,
    ) -> MemOp {
        // RMW: each row is read and written once per batch (gradients are
        // pre-aggregated per row by the computing logic's scratchpad).
        let rd = pmem.batch_access(start, unique_rows, self.row_bytes, AccessKind::Read, 0.0);
        let wr = pmem.batch_access(
            start + rd.duration,
            unique_rows,
            self.row_bytes,
            AccessKind::Write,
            0.0,
        );
        // relaxed-lookup correction: the deferred delta adds for rows the
        // early lookup touched (commutative-add fixup, Fig 8 bottom)
        let flops = (unique_rows + extra_correction_rows) * self.feature_dim * 2;
        let compute = ns(flops as f64 / self.comp.flops_per_ns);
        let media = AccessCost {
            duration: rd.duration + wr.duration,
            bytes_read: rd.bytes_read,
            bytes_written: wr.bytes_written,
            raw_hits: 0,
        };
        MemOp {
            duration: media.duration.max(compute),
            media,
            link_bytes: 0,
            compute_ns: compute,
        }
    }

    /// Embedding undo-log (Fig 7 steps 1-3): copy the old values of the
    /// rows the coming update will touch from the data region to the log
    /// region, then set the persistent flag.
    pub fn embedding_log(&self, start: SimTime, pmem: &mut MediaModel, unique_rows: u64) -> MemOp {
        let rd = pmem.batch_access(start, unique_rows, self.row_bytes, AccessKind::Read, 0.0);
        // log region writes are sequential (DMA engine streams them)
        let wr = pmem.stream(start + rd.duration, unique_rows * self.row_bytes, AccessKind::Write);
        // +8B persistent flag write, priced as one more line
        let flag = pmem.stream(start + rd.duration + wr.duration, 64, AccessKind::Write);
        MemOp {
            duration: ns(self.ckpt.dma_setup_ns) + rd.duration + wr.duration + flag.duration,
            media: AccessCost {
                duration: rd.duration + wr.duration + flag.duration,
                bytes_read: rd.bytes_read,
                bytes_written: wr.bytes_written + flag.bytes_written,
                raw_hits: 0,
            },
            link_bytes: 0,
            compute_ns: 0,
        }
    }

    /// MLP log: pull `bytes` of MLP parameters from CXL-GPU over CXL.cache
    /// (by `mlp_addr`/`mlp_size` MMIO regs) and stream them into the log
    /// region. `bytes` may be a partial continuation under the relaxed
    /// schedule.
    pub fn mlp_log(
        &self,
        start: SimTime,
        pmem: &mut MediaModel,
        link: &Link,
        bytes: u64,
    ) -> MemOp {
        if bytes == 0 {
            return MemOp::default();
        }
        let xfer = link.transfer(bytes, Proto::Cache);
        // link pull and log-region stream overlap (DMA pipelining); the
        // slower of the two dominates
        let wr = pmem.stream(start, bytes, AccessKind::Write);
        let flag = pmem.stream(start + wr.duration.max(xfer.duration), 64, AccessKind::Write);
        MemOp {
            duration: ns(self.ckpt.dma_setup_ns)
                + wr.duration.max(xfer.duration)
                + flag.duration,
            media: AccessCost {
                duration: wr.duration + flag.duration,
                bytes_read: 0,
                bytes_written: wr.bytes_written + flag.bytes_written,
                raw_hits: 0,
            },
            link_bytes: xfer.bytes,
            compute_ns: 0,
        }
    }

    /// Redo-log checkpoint (baselines / CXL-D): after updates land, stream
    /// the new values of the touched rows + the MLP params into the log
    /// region.
    pub fn redo_log(
        &self,
        start: SimTime,
        pmem: &mut MediaModel,
        unique_rows: u64,
        mlp_bytes: u64,
    ) -> MemOp {
        let rd = pmem.batch_access(start, unique_rows, self.row_bytes, AccessKind::Read, 0.0);
        let wr = pmem.stream(
            start + rd.duration,
            unique_rows * self.row_bytes + mlp_bytes,
            AccessKind::Write,
        );
        MemOp {
            duration: ns(self.ckpt.dma_setup_ns) + rd.duration + wr.duration,
            media: AccessCost {
                duration: rd.duration + wr.duration,
                bytes_read: rd.bytes_read,
                bytes_written: wr.bytes_written,
                raw_hits: 0,
            },
            link_bytes: 0,
            compute_ns: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;
    use crate::sim::mem::MediaKind;

    fn setup() -> (CxlMem, MediaModel, Link, ModelConfig) {
        let root = repo_root();
        let cfg = ModelConfig::load(&root, "rm1").unwrap();
        let p = DeviceParams::builtin_default();
        (
            CxlMem::new(&cfg, &p),
            MediaModel::new(MediaKind::Pmem, p.pmem.clone()),
            Link::new(p.cxl_link.clone()),
            cfg,
        )
    }

    #[test]
    fn mmio_regs_initialised_from_model() {
        let (mem, _, _, cfg) = setup();
        assert_eq!(mem.regs.vec_len, cfg.feature_dim as u32);
        assert_eq!(mem.regs.mlp_size, cfg.mlp_param_bytes());
        assert_eq!(f32::from_bits(mem.regs.lr_bits), cfg.lr as f32);
    }

    #[test]
    fn lookup_is_media_bound_for_embedding_heavy_models() {
        let (mem, mut pmem, _, cfg) = setup();
        let op = mem.embedding_lookup(0, &mut pmem, cfg.lookups_per_batch(), 0.0);
        assert!(op.duration > op.compute_ns, "PMEM should gate, not adders");
        assert_eq!(op.media.bytes_read, cfg.lookups_per_batch() * cfg.row_bytes());
    }

    #[test]
    fn raw_makes_lookup_slower() {
        let (mem, mut pmem, _, cfg) = setup();
        let clean = mem.embedding_lookup(0, &mut pmem, cfg.lookups_per_batch(), 0.0);
        // a write burst just before the lookup
        let up = mem.embedding_update(clean.duration, &mut pmem, 100_000, 0);
        let t0 = clean.duration + up.duration;
        let raw = mem.embedding_lookup(t0, &mut pmem, cfg.lookups_per_batch(), 0.8);
        assert!(raw.duration > clean.duration);
    }

    #[test]
    fn update_costs_rmw() {
        let (mem, mut pmem, _, _) = setup();
        let op = mem.embedding_update(0, &mut pmem, 10_000, 0);
        assert_eq!(op.media.bytes_read, 10_000 * 128);
        assert_eq!(op.media.bytes_written, 10_000 * 128);
    }

    #[test]
    fn mlp_log_pulls_over_link() {
        let (mem, mut pmem, link, cfg) = setup();
        let op = mem.mlp_log(0, &mut pmem, &link, cfg.mlp_param_bytes());
        assert!(op.link_bytes >= cfg.mlp_param_bytes());
        assert!(op.duration > 0);
        // empty continuation is free
        assert_eq!(mem.mlp_log(0, &mut pmem, &link, 0).duration, 0);
    }

    #[test]
    fn undo_log_cheaper_than_redo_with_mlp() {
        let (mem, mut pmem, _, cfg) = setup();
        let undo = mem.embedding_log(0, &mut pmem, 50_000);
        pmem.reset();
        let redo = mem.redo_log(0, &mut pmem, 50_000, cfg.mlp_param_bytes());
        assert!(undo.duration < redo.duration);
    }
}
