//! Composable pipeline stages.
//!
//! The old `PipelineSim` monolith carried one `step_*` method per system
//! configuration; adding a scenario meant editing an 800-line match. Here
//! a batch is a *composition* of [`Stage`]s selected from a
//! [`Topology`](crate::sim::topology::Topology) by [`compose`]: embedding
//! lookup (host / near-data / relaxed-early), MLP forward/backward on the
//! GPU, software or DCOH data movement, embedding update, and the four
//! checkpoint schedules (redo tail, staged redo, batch-aware undo,
//! relaxed). Every stage reads and writes two shared records:
//!
//! * [`PipelineEnv`] — devices, media, links, and run-long state
//!   (PMEM serialisation point, in-flight MLP log, telemetry);
//! * [`BatchCtx`] — the per-batch timing slots (lookup done, flush done,
//!   GPU phase boundaries, update window, batch end) that downstream
//!   stages consume, ending in a critical-path [`Breakdown`].
//!
//! Invalid compositions (the old `unreachable!` arm) are rejected by
//! [`compose`] — and earlier by the topology builder — so a composed
//! pipeline always runs.

use crate::analysis::effects::{MlpPersist, Region, Resource, Rows, StageEffects};
use crate::config::device::DeviceParams;
use crate::config::sysconfig::CkptMode;
use crate::config::ModelConfig;
use crate::devices::{CxlGpu, CxlMem, HostCpu};
use crate::sim::cxl::{Link, Proto};
use crate::sim::mem::{AccessCost, AccessKind, MediaKind, MediaModel};
use crate::sim::topology::{Topology, TopologyError};
use crate::sim::{Lane, OpKind, SimTime};
use crate::telemetry::{Breakdown, SpanLog, TrafficCounters};
use crate::workload::BatchStats;

/// Devices, media, links, and run-long mutable state shared by every
/// stage of a pipeline.
pub struct PipelineEnv {
    pub cfg: ModelConfig,
    pub topo: Topology,
    pub gpu: CxlGpu,
    pub mem: CxlMem,
    pub host: HostCpu,
    pub table: MediaModel,
    pub dram: MediaModel,
    /// Volatile hot tier in front of the pool (tiered-media topologies).
    pub hot: Option<MediaModel>,
    pub cxl: Link,
    pub pcie: Link,
    pub stats: BatchStats,

    // run state
    pub spans: SpanLog,
    pub traffic: TrafficCounters,
    pub raw_hits: u64,
    /// PMEM/SSD backend is a single serialised resource.
    pub pmem_free: SimTime,
    /// Relaxed lookup: completion time of the early lookup for the next
    /// batch (None on the first batch).
    pub early_lookup_done: Option<SimTime>,
    /// Relaxed checkpoint: (snapshot batch, bytes remaining) of the MLP
    /// log in flight.
    pub mlp_inflight: Option<(u64, u64)>,
    /// Differential MLP checkpoint payload per generation (bytes).
    pub mlp_log_bytes: u64,
    pub max_mlp_gap: u64,
    pub gpu_busy: SimTime,
    pub host_busy: SimTime,
    pub logic_busy: SimTime,

    // multi-GPU shard lanes (gpu_shards > 1)
    /// Per-lane batch statistics: the stripe of tables lane `s` owns.
    /// Defaults to an even split of the aggregate stats; the bench/CLI
    /// path replaces it with generator-striped stats
    /// ([`crate::workload::Generator::sharded_average_stats`]).
    pub shard_stats: Vec<BatchStats>,
    /// Per-lane lookup completion, rewritten each batch by the sharded
    /// lookup stage (per-batch shard-stage handoff slots live here
    /// because [`BatchCtx`] is a `Copy` record of scalar times).
    pub shard_lookup_done: Vec<SimTime>,
    /// Per-lane DCOH flush completion of the lane's reduced vectors.
    pub shard_flush_done: Vec<SimTime>,
}

impl PipelineEnv {
    /// Instantiate devices and media for `topo`. The expander pool is
    /// applied here: striping over `k` backends multiplies PMEM channel
    /// parallelism, each extra switch level adds hop latency.
    pub fn new(
        cfg: &ModelConfig,
        topo: Topology,
        params: &DeviceParams,
        gpu: CxlGpu,
        stats: BatchStats,
    ) -> PipelineEnv {
        let shards = topo.gpu_shards;
        let shard_stats = if shards > 1 {
            split_even(stats, shards)
        } else {
            Vec::new()
        };
        let mut table = media_model(topo.table_media, params);
        let mut cxl = Link::new(params.cxl_link.clone());
        table.p.channels *= topo.pool.expanders;
        cxl.p.hops += topo.pool.extra_hops;
        let hot = topo.tier_split().map(|ts| media_model(ts.hot, params));
        PipelineEnv {
            hot,
            mem: CxlMem::new(cfg, params),
            host: HostCpu::new(cfg.row_bytes(), params),
            table,
            dram: MediaModel::new(MediaKind::Dram, params.dram.clone()),
            cxl,
            pcie: Link::new(params.pcie_link.clone()),
            stats,
            spans: SpanLog::default(),
            traffic: TrafficCounters::default(),
            raw_hits: 0,
            pmem_free: 0,
            early_lookup_done: None,
            mlp_inflight: None,
            mlp_log_bytes: (cfg.mlp_param_bytes() as f64 * params.ckpt_logic.mlp_log_frac).ceil()
                as u64,
            max_mlp_gap: 0,
            gpu_busy: 0,
            host_busy: 0,
            logic_busy: 0,
            shard_stats,
            shard_lookup_done: vec![0; shards],
            shard_flush_done: vec![0; shards],
            gpu,
            cfg: cfg.clone(),
            topo,
        }
    }

    fn table_medium_name(&self) -> &'static str {
        medium_name(self.topo.table_media)
    }

    /// Bytes of reduced embedding vectors (and their gradients) that
    /// cross the fabric each batch.
    fn reduced_bytes(&self) -> u64 {
        (self.cfg.batch_size * self.cfg.num_tables * self.cfg.feature_dim * 4) as u64
    }

    fn record_media(&mut self, cost: &AccessCost, medium: &'static str) {
        self.traffic.record(medium, cost.bytes_read, cost.bytes_written);
        self.raw_hits += cost.raw_hits;
    }

    /// Reduced-vector bytes lane `s` produces (its stripe's share of the
    /// batch, proportional to the stripe's accesses).
    fn shard_reduced_bytes(&self, s: usize) -> u64 {
        let total: u64 = self.shard_stats.iter().map(|st| st.accesses).sum();
        if total == 0 {
            return 0;
        }
        self.reduced_bytes() * self.shard_stats[s].accesses / total
    }

    /// Stats stripe lane `s` owns (the aggregate stats when unsharded) —
    /// how the tiered stages loop GPU lanes uniformly.
    fn lane_stats(&self, s: usize) -> BatchStats {
        if self.topo.gpu_shards > 1 {
            self.shard_stats[s]
        } else {
            self.stats
        }
    }

    /// Traffic-accounting name of the hot-tier medium (DRAM by
    /// construction — validate() rejects anything else).
    fn hot_medium_name(&self) -> &'static str {
        let hot = self.hot.as_ref().expect("tiered stage without a hot tier");
        medium_name(hot.kind)
    }

    /// Cold-tier lookup leg: gathers from the pool, serialised on
    /// `pmem_free`, full span/traffic/busy accounting. Returns its end.
    fn cold_lookup(&mut self, b: u64, start: SimTime, acc: u64, raw: f64) -> SimTime {
        let lk = self.mem.embedding_lookup(start, &mut self.table, acc, raw);
        let end = start + lk.duration;
        self.pmem_free = end;
        self.record_media(&lk.media, "pmem");
        self.spans.add(Lane::CompLogic, OpKind::EmbLookup, b, start, end);
        self.spans.add(Lane::Pmem, OpKind::EmbLookup, b, start, end);
        self.logic_busy += lk.duration;
        end
    }

    /// Hot-tier lookup leg: the volatile tier runs beside the pool, so
    /// only traffic and logic-busy time are accounted (no pool clock, no
    /// serial-lane span). Returns its end.
    fn hot_lookup(&mut self, start: SimTime, acc: u64) -> SimTime {
        let hot = self.hot.as_mut().expect("tiered stage without a hot tier");
        let lk = self.mem.embedding_lookup(start, hot, acc, 0.0);
        let medium = self.hot_medium_name();
        self.record_media(&lk.media, medium);
        self.logic_busy += lk.duration;
        start + lk.duration
    }

    /// Cold-tier update leg (RMW through the pool, serialised).
    fn cold_update(&mut self, b: u64, start: SimTime, rows: u64, corr: u64) -> SimTime {
        let up = self.mem.embedding_update(start, &mut self.table, rows, corr);
        let end = start + up.duration;
        self.pmem_free = end;
        self.record_media(&up.media, "pmem");
        self.spans.add(Lane::CompLogic, OpKind::EmbUpdate, b, start, end);
        self.spans.add(Lane::Pmem, OpKind::EmbUpdate, b, start, end);
        self.logic_busy += up.duration;
        end
    }

    /// Hot-tier update leg (RMW in the volatile tier, off the pool).
    fn hot_update(&mut self, start: SimTime, rows: u64, corr: u64) -> SimTime {
        let hot = self.hot.as_mut().expect("tiered stage without a hot tier");
        let up = self.mem.embedding_update(start, hot, rows, corr);
        let medium = self.hot_medium_name();
        self.record_media(&up.media, medium);
        self.logic_busy += up.duration;
        start + up.duration
    }
}

/// RAW-exposed fraction of the cold tail of one lane's accesses: the
/// overlap hits that did NOT land in the hot tier, over the cold
/// accesses (the hot tier is volatile DRAM — no XPBuffer, no RAW).
fn cold_raw_frac(st: &BatchStats) -> f64 {
    let cold_acc = st.accesses - st.hot_accesses;
    if cold_acc == 0 {
        return 0.0;
    }
    let total_ov = st.prev_overlap * st.accesses as f64;
    ((total_ov - st.hot_overlap_hits as f64).max(0.0) / cold_acc as f64).min(1.0)
}

/// Traffic-accounting label of a medium (single source for both the
/// table pool and the hot tier).
fn medium_name(kind: MediaKind) -> &'static str {
    match kind {
        MediaKind::Dram => "dram",
        MediaKind::Pmem => "pmem",
        MediaKind::Ssd => "ssd",
    }
}

/// Instantiate the timing model for one medium (the single source of the
/// `MediaKind -> MediaParams` mapping for both the table pool and the
/// hot tier).
fn media_model(kind: MediaKind, params: &DeviceParams) -> MediaModel {
    match kind {
        MediaKind::Dram => MediaModel::new(MediaKind::Dram, params.dram.clone()),
        MediaKind::Pmem => MediaModel::new(MediaKind::Pmem, params.pmem.clone()),
        MediaKind::Ssd => MediaModel::new(MediaKind::Ssd, params.ssd.clone()),
    }
}

/// Even-split fallback for the per-shard stats when no generator-striped
/// stats are installed (library callers constructing a sharded
/// [`PipelineEnv`] directly).
fn split_even(s: BatchStats, shards: usize) -> Vec<BatchStats> {
    let n = shards as u64;
    let part = |x: u64, i: u64| x / n + u64::from(i < x % n);
    (0..n)
        .map(|i| BatchStats {
            accesses: part(s.accesses, i),
            unique_rows: part(s.unique_rows, i),
            prev_overlap: s.prev_overlap,
            hot_hit_frac: s.hot_hit_frac,
            hot_accesses: part(s.hot_accesses, i),
            hot_unique_rows: part(s.hot_unique_rows, i),
            hot_overlap_hits: part(s.hot_overlap_hits, i),
        })
        .collect()
}

/// Per-batch timing slots, produced left-to-right by the stage chain.
/// Every time field starts at the batch start `t0`.
#[derive(Clone, Copy, Debug)]
pub struct BatchCtx {
    pub batch: u64,
    pub t0: SimTime,
    /// When this batch's reduced vectors are ready (CXL lanes).
    pub lookup_done: SimTime,
    /// End of the (strict) embedding lookup (software/PCIe lanes).
    pub lk_end: SimTime,
    /// Bottom-MLP forward end.
    pub bf_end: SimTime,
    /// Forward transfer/flush end — the interaction inputs' arrival.
    pub xf_end: SimTime,
    /// Interaction + top-MLP window.
    pub tm_start: SimTime,
    pub tm_end: SimTime,
    /// Bottom-MLP backward end (GPU commit point).
    pub bb_end: SimTime,
    /// Gradient transfer/flush end.
    pub gx_end: SimTime,
    /// PCIe MLP-staging end.
    pub stage_end: SimTime,
    /// Embedding undo-log end (batch-aware schedules).
    pub emb_log_end: SimTime,
    /// Embedding update window.
    pub up_start: SimTime,
    pub up_end: SimTime,
    /// Checkpoint time past the natural batch tail (ns).
    pub ck_tail: i64,
    /// Batch end.
    pub end: SimTime,
    /// Critical-path attribution, filled by the terminal stage.
    pub bd: Breakdown,
}

impl BatchCtx {
    pub fn new(batch: u64, t0: SimTime) -> BatchCtx {
        BatchCtx {
            batch,
            t0,
            lookup_done: t0,
            lk_end: t0,
            bf_end: t0,
            xf_end: t0,
            tm_start: t0,
            tm_end: t0,
            bb_end: t0,
            gx_end: t0,
            stage_end: t0,
            emb_log_end: t0,
            up_start: t0,
            up_end: t0,
            ck_tail: 0,
            end: t0,
            bd: Breakdown::default(),
        }
    }
}

/// One schedulable slice of a training batch. Stages communicate only
/// through [`PipelineEnv`] and [`BatchCtx`], so compositions can add,
/// drop, or swap them without touching their neighbours. `Send + Sync`
/// because composed chains ride tenant lanes across the engine's worker
/// pool ([`crate::sim::engine::run_tasks`]); stages are stateless
/// behaviour over `&self`, so the bound costs nothing.
pub trait Stage: Send + Sync {
    fn name(&self) -> &'static str;

    /// Declarative effect summary for the static analyzer
    /// ([`crate::analysis`]): the regions this stage reads and writes,
    /// the backend resources it holds, and its contribution to the
    /// undo/MLP coverage windows. The default is *undeclared* — the
    /// analyzer flags it and the recovery-matrix coverage pin fails on
    /// it, so a new stage cannot ship without stating its effects.
    fn effects(&self) -> StageEffects {
        StageEffects::undeclared()
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx);
}

// ====================================================== embedding lookup

/// Host-CPU embedding lookup against the storage tier (SSD/PMEM
/// baselines), optionally in front of a host-DRAM vector cache.
pub struct HostEmbLookup;

impl Stage for HostEmbLookup {
    fn name(&self) -> &'static str {
        "host-emb-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .read(Region::HostMirror, Rows::Hot)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let medium = env.table_medium_name();
        let raw_frac = if env.topo.table_media == MediaKind::Pmem {
            s.prev_overlap
        } else {
            0.0
        };
        let cache = if env.topo.dram_vector_cache {
            s.hot_hit_frac
        } else {
            0.0
        };
        let lk_start = env.pmem_free.max(ctx.t0);
        let lk = env.host.embedding_lookup(
            lk_start,
            &mut env.table,
            &mut env.dram,
            s.accesses,
            cache,
            raw_frac,
        );
        let lk_end = lk_start + lk.duration;
        env.pmem_free = lk_end;
        env.record_media(&lk.media, medium);
        env.spans.add(Lane::HostCpu, OpKind::EmbLookup, ctx.batch, lk_start, lk_end);
        env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, lk_start, lk_end);
        env.host_busy += lk.duration;
        ctx.lk_end = lk_end;
        ctx.lookup_done = lk_end;
    }
}

/// Near-data embedding lookup on the expander's computing logic, gated by
/// the host's kernel launch (PCIe configuration).
pub struct NdpEmbLookup;

impl Stage for NdpEmbLookup {
    fn name(&self) -> &'static str {
        "ndp-emb-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let lk_start = env
            .pmem_free
            .max(ctx.t0 + env.host.p.kernel_launch_ns as SimTime);
        let lk = env
            .mem
            .embedding_lookup(lk_start, &mut env.table, s.accesses, s.prev_overlap);
        let lk_end = lk_start + lk.duration;
        env.pmem_free = lk_end;
        env.record_media(&lk.media, "pmem");
        env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, lk_start, lk_end);
        env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, lk_start, lk_end);
        env.logic_busy += lk.duration;
        ctx.lk_end = lk_end;
        ctx.lookup_done = lk_end;
    }
}

/// CXL embedding-lane front half. Strict mode: lookup(N) runs first,
/// RAW-exposed to the previous batch's update writes. Relaxed mode: the
/// reduced vectors for THIS batch were produced during the previous batch
/// (Fig 8), so only the cold start (no previous batch) runs a lookup.
pub struct CxlFrontLookup {
    pub relaxed: bool,
}

impl Stage for CxlFrontLookup {
    fn name(&self) -> &'static str {
        "cxl-front-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        if !self.relaxed {
            let st = env.pmem_free.max(ctx.t0);
            let lk = env
                .mem
                .embedding_lookup(st, &mut env.table, s.accesses, s.prev_overlap);
            let end = st + lk.duration;
            env.pmem_free = end;
            env.record_media(&lk.media, "pmem");
            env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, st, end);
            env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, st, end);
            env.logic_busy += lk.duration;
            ctx.lookup_done = end;
        } else if env.early_lookup_done.is_none() {
            // cold start: no early lookup from a previous batch — run one
            let st = env.pmem_free.max(ctx.t0);
            let lk = env.mem.embedding_lookup(st, &mut env.table, s.accesses, 0.0);
            let end = st + lk.duration;
            env.pmem_free = end;
            env.record_media(&lk.media, "pmem");
            env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, st, end);
            env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, st, end);
            env.logic_busy += lk.duration;
            ctx.lookup_done = end;
        }
        // relaxed steady state: vectors ready at t0 (ctx default)
    }
}

/// Relaxed early lookup for the NEXT batch (Fig 8 bottom: lookup(N+1)
/// against the N-th table, before update(N) — commutative-add correction
/// applied at update time).
pub struct RelaxedEarlyLookup;

impl Stage for RelaxedEarlyLookup {
    fn name(&self) -> &'static str {
        "relaxed-early-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let st = env.pmem_free.max(ctx.emb_log_end);
        let lk = env.mem.embedding_lookup(st, &mut env.table, s.accesses, 0.0);
        let end = st + lk.duration;
        env.pmem_free = end;
        env.record_media(&lk.media, "pmem");
        env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, st, end);
        env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, st, end);
        env.logic_busy += lk.duration;
        env.early_lookup_done = Some(end);
    }
}

// ============================================================= GPU lane

/// Bottom-MLP forward. Software paths pay a kernel launch before the GPU
/// starts; the CXL fabric starts at `t0`.
pub struct GpuBottomFwd {
    pub launch_gated: bool,
}

impl Stage for GpuBottomFwd {
    fn name(&self) -> &'static str {
        "gpu-bottom-fwd"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared().section(&[Resource::GpuLane])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let bf_start = if self.launch_gated {
            ctx.t0 + env.host.p.kernel_launch_ns as SimTime
        } else {
            ctx.t0
        };
        let bf_end = bf_start + env.gpu.bmlp_fwd;
        env.spans.add(Lane::Gpu, OpKind::BottomMlp, ctx.batch, bf_start, bf_end);
        ctx.bf_end = bf_end;
    }
}

/// Interaction + top-MLP forward+backward: starts when both the bottom
/// forward and the reduced vectors (transfer or DCOH flush) are in.
pub struct GpuTopMlp;

impl Stage for GpuTopMlp {
    fn name(&self) -> &'static str {
        "gpu-top-mlp"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::GpuVectors, Rows::All)
            .section(&[Resource::GpuLane])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let tm_start = ctx.xf_end.max(ctx.bf_end);
        let tm_end = tm_start + env.gpu.tmlp_total();
        env.spans.add(Lane::Gpu, OpKind::TopMlp, ctx.batch, tm_start, tm_end);
        ctx.tm_start = tm_start;
        ctx.tm_end = tm_end;
    }
}

/// Bottom-MLP backward (weight commit); accounts the whole batch's GPU
/// busy time.
pub struct GpuBottomBwd;

impl Stage for GpuBottomBwd {
    fn name(&self) -> &'static str {
        "gpu-bottom-bwd"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::GpuWeights, Rows::All)
            .section(&[Resource::GpuLane])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let bb_end = ctx.tm_end + env.gpu.bmlp_bwd;
        env.spans.add(Lane::Gpu, OpKind::BottomMlp, ctx.batch, ctx.tm_end, bb_end);
        env.gpu_busy += env.gpu.gpu_busy();
        ctx.bb_end = bb_end;
    }
}

// ======================================================== data movement

/// Software transfer of the reduced vectors to the GPU
/// (sync + memcpy + launch over PCIe, Fig 4a).
pub struct SwUplinkTransfer;

impl Stage for SwUplinkTransfer {
    fn name(&self) -> &'static str {
        "sw-uplink-transfer"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::ReducedVectors, Rows::All)
            .write(Region::GpuVectors, Rows::All)
            .section(&[Resource::PcieLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let xf_start = ctx.lk_end.max(ctx.bf_end);
        let xf = env.host.sw_transfer(&env.pcie, env.reduced_bytes());
        let xf_end = xf_start + xf.duration;
        env.traffic.record_link(xf.link_bytes);
        env.spans.add(Lane::HostCpu, OpKind::Transfer, ctx.batch, xf_start, xf_end);
        env.host_busy += xf.duration;
        ctx.xf_end = xf_end;
    }
}

/// Software copy of the reduced-vector gradients back from the GPU.
pub struct SwGradTransfer;

impl Stage for SwGradTransfer {
    fn name(&self) -> &'static str {
        "sw-grad-transfer"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared().section(&[Resource::PcieLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let gx = env.host.sw_transfer(&env.pcie, env.reduced_bytes());
        let gx_end = ctx.tm_end + gx.duration;
        env.traffic.record_link(gx.link_bytes);
        env.spans.add(Lane::HostCpu, OpKind::Transfer, ctx.batch, ctx.tm_end, gx_end);
        env.host_busy += gx.duration;
        ctx.gx_end = gx_end;
    }
}

/// DCOH flush of the reduced vectors into GPU memory (Fig 5a/b) — the
/// hardware movement that replaces [`SwUplinkTransfer`].
pub struct DcohFlush;

impl Stage for DcohFlush {
    fn name(&self) -> &'static str {
        "dcoh-flush"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::ReducedVectors, Rows::All)
            .write(Region::GpuVectors, Rows::All)
            .section(&[Resource::CxlLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let fl = env.cxl.transfer(env.reduced_bytes(), Proto::Cache);
        let flush_start = ctx.lookup_done.max(ctx.t0);
        let flush_end = flush_start + fl.duration;
        env.traffic.record_link(fl.bytes);
        env.spans.add(Lane::Link, OpKind::Transfer, ctx.batch, flush_start, flush_end);
        ctx.xf_end = flush_end;
    }
}

/// Gradient flush back to CXL-MEM (CXL-GPU's DCOH, Fig 5 BWP).
pub struct CxlGradFlush;

impl Stage for CxlGradFlush {
    fn name(&self) -> &'static str {
        "cxl-grad-flush"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared().section(&[Resource::CxlLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let gfl = env.cxl.transfer(env.reduced_bytes(), Proto::Cache);
        let gfl_end = ctx.tm_end + gfl.duration;
        env.traffic.record_link(gfl.bytes);
        env.spans.add(Lane::Link, OpKind::Transfer, ctx.batch, ctx.tm_end, gfl_end);
        ctx.gx_end = gfl_end;
    }
}

// ====================================================== embedding update

/// Host-side embedding update (software baselines).
pub struct HostEmbUpdate;

impl Stage for HostEmbUpdate {
    fn name(&self) -> &'static str {
        "host-emb-update"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::EmbTable, Rows::All)
            .write(Region::HostMirror, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let medium = env.table_medium_name();
        let up_start = ctx.gx_end.max(env.pmem_free);
        let up = env
            .host
            .embedding_update(up_start, &mut env.table, s.unique_rows);
        let up_end = up_start + up.duration;
        env.pmem_free = up_end;
        env.record_media(&up.media, medium);
        env.spans.add(Lane::HostCpu, OpKind::EmbUpdate, ctx.batch, up_start, up_end);
        env.spans.add(Lane::Pmem, OpKind::EmbUpdate, ctx.batch, up_start, up_end);
        env.host_busy += up.duration;
        ctx.up_start = up_start;
        ctx.up_end = up_end;
    }
}

/// Near-data embedding update on the computing logic. Under the relaxed
/// lookup it also applies the commutative-add correction for rows the
/// early lookup touched; under batch-aware checkpointing it may not start
/// before its rows are undo-logged.
pub struct NdpEmbUpdate {
    pub correction: bool,
}

impl Stage for NdpEmbUpdate {
    fn name(&self) -> &'static str {
        "ndp-emb-update"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::EmbTable, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let correction_rows = if self.correction {
            (s.unique_rows as f64 * s.prev_overlap) as u64
        } else {
            0
        };
        let up_start = ctx.gx_end.max(env.pmem_free).max(ctx.emb_log_end);
        let up = env
            .mem
            .embedding_update(up_start, &mut env.table, s.unique_rows, correction_rows);
        let up_end = up_start + up.duration;
        env.pmem_free = up_end;
        env.record_media(&up.media, "pmem");
        env.spans.add(Lane::CompLogic, OpKind::EmbUpdate, ctx.batch, up_start, up_end);
        env.spans.add(Lane::Pmem, OpKind::EmbUpdate, ctx.batch, up_start, up_end);
        env.logic_busy += up.duration;
        ctx.up_start = up_start;
        ctx.up_end = up_end;
    }
}

// =========================================================== checkpoints

/// Batch-aware undo log of this batch's rows (Fig 6): runs in the CXL-MEM
/// idle window after the lookup; the update must wait on it.
pub struct EmbUndoLog;

impl Stage for EmbUndoLog {
    fn name(&self) -> &'static str {
        "emb-undo-log"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::UndoLog, Rows::All)
            .undo_capture(Rows::All, false)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let st = env.pmem_free.max(ctx.t0);
        let op = env.mem.embedding_log(st, &mut env.table, s.unique_rows);
        let emb_log_end = st + op.duration;
        env.pmem_free = emb_log_end;
        env.record_media(&op.media, "pmem");
        env.spans.add(Lane::CkptLogic, OpKind::CkptEmb, ctx.batch, st, emb_log_end);
        env.spans.add(Lane::Pmem, OpKind::CkptEmb, ctx.batch, st, emb_log_end);
        env.logic_busy += op.duration;
        ctx.emb_log_end = emb_log_end;
    }
}

/// Seal the batch at the natural tail (update vs bottom backward) —
/// the terminal scheduling stage when no checkpoint tail follows.
pub struct BatchEnd;

impl Stage for BatchEnd {
    fn name(&self) -> &'static str {
        "batch-end"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
    }

    fn run(&self, _env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        ctx.end = ctx.up_end.max(ctx.bb_end);
    }
}

/// Host-driven redo-log checkpoint on the critical path (SSD/PMEM
/// baselines, Fig 4a). Composed after [`BatchEnd`].
pub struct HostRedoCkpt;

impl Stage for HostRedoCkpt {
    fn name(&self) -> &'static str {
        "host-redo-ckpt"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::UndoLog, Rows::All)
            .write(Region::MlpLog, Rows::All)
            .undo_capture(Rows::All, true)
            .mlp(MlpPersist::PerBatch)
            .section(&[Resource::PmemPool, Resource::PcieLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let medium = env.table_medium_name();
        let ck_start = ctx.end.max(env.pmem_free);
        let ck = env.host.redo_checkpoint(
            ck_start,
            &mut env.table,
            &env.pcie,
            s.unique_rows,
            env.mlp_log_bytes,
        );
        let ck_end = ck_start + ck.duration;
        env.pmem_free = ck_end;
        env.record_media(&ck.media, medium);
        env.traffic.record_link(ck.link_bytes);
        env.spans.add(Lane::HostCpu, OpKind::CkptEmb, ctx.batch, ck_start, ck_end);
        env.spans.add(Lane::Pmem, OpKind::CkptEmb, ctx.batch, ck_start, ck_end);
        env.host_busy += ck.duration;
        ctx.end = ck_end;
    }
}

/// PCIe near-data redo checkpoint: MLP params staged over PCIe once the
/// bottom backward commits, then the device DMA writes the redo log.
pub struct PcieStagedRedoCkpt;

impl Stage for PcieStagedRedoCkpt {
    fn name(&self) -> &'static str {
        "pcie-staged-redo-ckpt"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::UndoLog, Rows::All)
            .write(Region::MlpLog, Rows::All)
            .undo_capture(Rows::All, true)
            .mlp(MlpPersist::PerBatch)
            .section(&[Resource::PcieLink])
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let stage = env.host.sw_transfer(&env.pcie, env.mlp_log_bytes);
        let stage_end = ctx.bb_end + stage.duration;
        env.traffic.record_link(stage.link_bytes);
        env.spans.add(Lane::HostCpu, OpKind::CkptMlp, ctx.batch, ctx.bb_end, stage_end);
        env.host_busy += stage.duration;
        let ck_start = ctx.up_end.max(stage_end).max(env.pmem_free);
        let ck = env
            .mem
            .redo_log(ck_start, &mut env.table, s.unique_rows, env.mlp_log_bytes);
        let ck_end = ck_start + ck.duration;
        env.pmem_free = ck_end;
        env.record_media(&ck.media, "pmem");
        env.spans.add(Lane::CkptLogic, OpKind::CkptEmb, ctx.batch, ck_start, ck_end);
        env.spans.add(Lane::Pmem, OpKind::CkptEmb, ctx.batch, ck_start, ck_end);
        env.logic_busy += ck.duration;
        ctx.stage_end = stage_end;
        ctx.end = ck_end;
    }
}

/// CXL-D tail: MLP redo log via CXL.cache right after the GPU commits
/// (overlaps the update); embedding redo log after it.
pub struct RedoTailCkpt;

impl Stage for RedoTailCkpt {
    fn name(&self) -> &'static str {
        "redo-tail-ckpt"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::UndoLog, Rows::All)
            .write(Region::MlpLog, Rows::All)
            .undo_capture(Rows::All, true)
            .mlp(MlpPersist::PerBatch)
            .section(&[Resource::CxlLink])
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let s = env.stats;
        let bytes = env.mlp_log_bytes;
        let ml = env.mem.mlp_log(ctx.bb_end, &mut env.table, &env.cxl, bytes);
        let ml_end = ctx.bb_end + ml.duration;
        env.record_media(&ml.media, "pmem");
        env.traffic.record_link(ml.link_bytes);
        env.spans.add(Lane::CkptLogic, OpKind::CkptMlp, ctx.batch, ctx.bb_end, ml_end);
        env.logic_busy += ml.duration;
        let ck_start = ctx.up_end.max(env.pmem_free).max(ml_end);
        let ck = env.mem.redo_log(ck_start, &mut env.table, s.unique_rows, 0);
        let ck_end = ck_start + ck.duration;
        env.pmem_free = ck_end;
        env.record_media(&ck.media, "pmem");
        env.spans.add(Lane::CkptLogic, OpKind::CkptEmb, ctx.batch, ck_start, ck_end);
        env.spans.add(Lane::Pmem, OpKind::CkptEmb, ctx.batch, ck_start, ck_end);
        env.logic_busy += ck.duration;
        ctx.end = ck_end.max(ctx.bb_end);
        ctx.ck_tail = (ctx.end as i64) - (ctx.up_end.max(ctx.bb_end) as i64);
    }
}

/// CXL-B tail: the MLP undo log must capture pre-update params before the
/// GPU commits at `bb_end`; it runs behind the embedding log. If the log
/// outlives the GPU's backward, the commit stalls.
pub struct BatchAwareMlpLog;

impl Stage for BatchAwareMlpLog {
    fn name(&self) -> &'static str {
        "batch-aware-mlp-log"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::MlpLog, Rows::All)
            .mlp(MlpPersist::PerBatch)
            .section(&[Resource::CxlLink])
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let st = ctx.emb_log_end;
        let bytes = env.mlp_log_bytes;
        let ml = env.mem.mlp_log(st, &mut env.table, &env.cxl, bytes);
        let ml_end = st + ml.duration;
        env.record_media(&ml.media, "pmem");
        env.traffic.record_link(ml.link_bytes);
        env.spans.add(Lane::CkptLogic, OpKind::CkptMlp, ctx.batch, st, ml_end);
        env.logic_busy += ml.duration;
        ctx.end = ctx.up_end.max(ctx.bb_end).max(ml_end);
        ctx.ck_tail = (ctx.end as i64) - (ctx.up_end.max(ctx.bb_end) as i64);
    }
}

/// CXL tail: MLP log slices ride the GPU's interaction+top-MLP window
/// only (the GPU answers CXL.cache reads while busy there, Fig 9b); a
/// snapshot that ages past the configured gap is finished synchronously.
pub struct RelaxedMlpLog;

impl Stage for RelaxedMlpLog {
    fn name(&self) -> &'static str {
        "relaxed-mlp-log"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::MlpLog, Rows::All)
            .mlp(MlpPersist::WindowBounded {
                seals_bootstrap: true,
            })
            .section(&[Resource::CxlLink])
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let window = ctx.tm_end.saturating_sub(ctx.tm_start);
        let (snap_batch, mut pending) = env
            .mlp_inflight
            .take()
            .unwrap_or((ctx.batch, env.mlp_log_bytes));
        // bytes that fit the window at the link/log stream rate
        let probe = {
            let mut probe_table = env.table.clone();
            env.mem.mlp_log(ctx.tm_start, &mut probe_table, &env.cxl, pending)
        };
        let bytes_fit = if probe.duration <= window {
            pending
        } else {
            (pending as u128 * window as u128 / probe.duration.max(1) as u128) as u64
        };
        if bytes_fit > 0 {
            let ml = env
                .mem
                .mlp_log(ctx.tm_start, &mut env.table, &env.cxl, bytes_fit);
            env.record_media(&ml.media, "pmem");
            env.traffic.record_link(ml.link_bytes);
            let ml_end = ctx.tm_start + ml.duration.min(window);
            env.spans.add(Lane::CkptLogic, OpKind::CkptMlp, ctx.batch, ctx.tm_start, ml_end);
            env.logic_busy += ml.duration.min(window);
            pending -= bytes_fit;
        }
        ctx.end = ctx.up_end.max(ctx.bb_end);
        if pending == 0 {
            let gap = ctx.batch - snap_batch;
            env.max_mlp_gap = env.max_mlp_gap.max(gap);
            env.mlp_inflight = None; // next batch starts a new snapshot
        } else if ctx.batch - snap_batch >= env.topo.max_mlp_log_gap {
            // business-accuracy bound reached: finish synchronously
            let st = ctx.end.max(env.pmem_free);
            let ml = env.mem.mlp_log(st, &mut env.table, &env.cxl, pending);
            let ml_end = st + ml.duration;
            env.pmem_free = ml_end;
            env.record_media(&ml.media, "pmem");
            env.traffic.record_link(ml.link_bytes);
            env.spans.add(Lane::CkptLogic, OpKind::CkptMlp, ctx.batch, st, ml_end);
            env.logic_busy += ml.duration;
            env.max_mlp_gap = env.max_mlp_gap.max(ctx.batch - snap_batch);
            ctx.ck_tail = (ml_end - ctx.end) as i64;
            ctx.end = ml_end;
        } else {
            env.mlp_inflight = Some((snap_batch, pending));
            env.max_mlp_gap = env.max_mlp_gap.max(ctx.batch - snap_batch);
        }
    }
}

// ================================================== multi-GPU shard lanes
//
// `gpu_shards > 1`: the embedding tables are striped round-robin across
// GPU lanes (one shard stage per lane). The expander pool and its PMEM
// backend stay SHARED — every lane's lookup/log/update serialises through
// `PipelineEnv::pmem_free`, which is exactly the DCOH/pool contention the
// scenario studies — while the per-lane DCOH flushes overlap and the
// cross-lane exchange/reduce legs ride the (hop-aware) switch link.

/// Per-lane embedding lookups against the shared pool. Strict mode runs
/// every lane's stripe RAW-exposed; relaxed mode has the vectors ready at
/// `t0` in steady state (each lane's early lookup ran during the previous
/// batch) and only the cold start pays for lookups.
pub struct ShardedEmbLookup {
    pub relaxed: bool,
}

impl Stage for ShardedEmbLookup {
    fn name(&self) -> &'static str {
        "sharded-emb-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        if self.relaxed && env.early_lookup_done.is_some() {
            // relaxed steady state (Fig 8): every lane's reduced vectors
            // were produced during the previous batch
            env.shard_lookup_done.fill(ctx.t0);
            return; // lookup_done stays at the ctx default (t0)
        }
        for s in 0..env.topo.gpu_shards {
            let st = env.shard_stats[s];
            let raw_frac = if self.relaxed { 0.0 } else { st.prev_overlap };
            let start = env.pmem_free.max(ctx.t0);
            let lk = env
                .mem
                .embedding_lookup(start, &mut env.table, st.accesses, raw_frac);
            let end = start + lk.duration;
            env.pmem_free = end;
            env.record_media(&lk.media, "pmem");
            env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, start, end);
            env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, start, end);
            env.logic_busy += lk.duration;
            env.shard_lookup_done[s] = end;
            ctx.lookup_done = end;
        }
    }
}

/// Per-lane batch-aware undo logs (the per-shard checkpoint tails). Lanes
/// serialise on the shared backend behind the lookups; the update may not
/// start before the last lane's rows are logged, preserving the paper's
/// persistency ordering under the relaxed modes.
pub struct ShardedEmbUndoLog;

impl Stage for ShardedEmbUndoLog {
    fn name(&self) -> &'static str {
        "sharded-emb-undo-log"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::UndoLog, Rows::All)
            .undo_capture(Rows::All, false)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        for s in 0..env.topo.gpu_shards {
            let st = env.shard_stats[s];
            let start = env.pmem_free.max(ctx.t0);
            let op = env.mem.embedding_log(start, &mut env.table, st.unique_rows);
            let end = start + op.duration;
            env.pmem_free = end;
            env.record_media(&op.media, "pmem");
            env.spans.add(Lane::CkptLogic, OpKind::CkptEmb, ctx.batch, start, end);
            env.spans.add(Lane::Pmem, OpKind::CkptEmb, ctx.batch, start, end);
            env.logic_busy += op.duration;
            ctx.emb_log_end = end;
        }
    }
}

/// Per-lane DCOH flush of each lane's reduced-vector stripe into its GPU.
/// A lane flushes as soon as its own lookup lands — lane 0's flush
/// overlaps lane 1's lookup, the pipelining win sharding buys.
pub struct ShardedDcohFlush;

impl Stage for ShardedDcohFlush {
    fn name(&self) -> &'static str {
        "sharded-dcoh-flush"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::ReducedVectors, Rows::All)
            .write(Region::GpuVectors, Rows::All)
            .section(&[Resource::CxlLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        for s in 0..env.topo.gpu_shards {
            let bytes = env.shard_reduced_bytes(s);
            let start = env.shard_lookup_done[s].max(ctx.t0);
            let end = if bytes == 0 {
                start
            } else {
                let fl = env.cxl.transfer(bytes, Proto::Cache);
                env.traffic.record_link(fl.bytes);
                env.spans.add(Lane::Link, OpKind::Transfer, ctx.batch, start, start + fl.duration);
                start + fl.duration
            };
            env.shard_flush_done[s] = end;
        }
    }
}

/// All-to-all exchange of the reduced vectors between GPU lanes over the
/// CXL switch: each lane keeps its own `1/n` stripe and receives the
/// remaining `(n-1)/n` from its peers. Hop-aware — the link carries the
/// pool's extra switch levels ([`crate::sim::topology::ExpanderPool::extra_hops`]).
pub struct ShardAllToAllExchange;

impl Stage for ShardAllToAllExchange {
    fn name(&self) -> &'static str {
        "shard-exchange"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::GpuVectors, Rows::All)
            .write(Region::GpuVectors, Rows::All)
            .section(&[Resource::CxlLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let n = env.topo.gpu_shards as u64;
        let start = env
            .shard_flush_done
            .iter()
            .copied()
            .max()
            .unwrap_or(ctx.t0)
            .max(ctx.t0);
        let xf = env.cxl.transfer(env.reduced_bytes() * (n - 1) / n, Proto::Cache);
        env.traffic.record_link(xf.bytes);
        env.spans.add(Lane::Link, OpKind::Transfer, ctx.batch, start, start + xf.duration);
        ctx.xf_end = start + xf.duration;
    }
}

/// Gradient movement after the top-MLP: each lane's DCOH flushes the
/// reduced-vector gradients back (the single-GPU BWP volume), then the
/// cross-lane legs ride the switch — embedding gradients routed to the
/// owning lane (`(n-1)/n` of the reduced bytes) plus the dense-MLP
/// replica all-reduce (`2*(n-1)/n` of the differential MLP payload).
pub struct ShardedGradReduce;

impl Stage for ShardedGradReduce {
    fn name(&self) -> &'static str {
        "shard-grad-reduce"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared().section(&[Resource::CxlLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let n = env.topo.gpu_shards as u64;
        let local = env.cxl.transfer(env.reduced_bytes(), Proto::Cache);
        let cross_bytes = (env.reduced_bytes() + 2 * env.mlp_log_bytes) * (n - 1) / n;
        let cross = env.cxl.transfer(cross_bytes, Proto::Cache);
        let end = ctx.tm_end + local.duration + cross.duration;
        env.traffic.record_link(local.bytes + cross.bytes);
        env.spans.add(Lane::Link, OpKind::Transfer, ctx.batch, ctx.tm_end, end);
        ctx.gx_end = end;
    }
}

/// Per-lane relaxed early lookups for the NEXT batch, serialised on the
/// shared backend behind this batch's undo logs (Fig 8 bottom, striped).
pub struct ShardedRelaxedEarlyLookup;

impl Stage for ShardedRelaxedEarlyLookup {
    fn name(&self) -> &'static str {
        "sharded-early-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let mut last = ctx.emb_log_end;
        for s in 0..env.topo.gpu_shards {
            let st = env.shard_stats[s];
            let start = env.pmem_free.max(ctx.emb_log_end);
            let lk = env.mem.embedding_lookup(start, &mut env.table, st.accesses, 0.0);
            let end = start + lk.duration;
            env.pmem_free = end;
            env.record_media(&lk.media, "pmem");
            env.spans.add(Lane::CompLogic, OpKind::EmbLookup, ctx.batch, start, end);
            env.spans.add(Lane::Pmem, OpKind::EmbLookup, ctx.batch, start, end);
            env.logic_busy += lk.duration;
            last = end;
        }
        env.early_lookup_done = Some(last);
    }
}

/// Per-lane embedding updates of each lane's stripe, serialised on the
/// shared backend; under the relaxed lookup each lane also applies its
/// stripe's commutative-add correction.
pub struct ShardedEmbUpdate {
    pub correction: bool,
}

impl Stage for ShardedEmbUpdate {
    fn name(&self) -> &'static str {
        "sharded-emb-update"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::EmbTable, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let mut first: Option<SimTime> = None;
        let mut last = ctx.gx_end;
        for s in 0..env.topo.gpu_shards {
            let st = env.shard_stats[s];
            let correction_rows = if self.correction {
                (st.unique_rows as f64 * st.prev_overlap) as u64
            } else {
                0
            };
            let start = ctx.gx_end.max(env.pmem_free).max(ctx.emb_log_end);
            let up = env
                .mem
                .embedding_update(start, &mut env.table, st.unique_rows, correction_rows);
            let end = start + up.duration;
            env.pmem_free = end;
            env.record_media(&up.media, "pmem");
            env.spans.add(Lane::CompLogic, OpKind::EmbUpdate, ctx.batch, start, end);
            env.spans.add(Lane::Pmem, OpKind::EmbUpdate, ctx.batch, start, end);
            env.logic_busy += up.duration;
            first.get_or_insert(start);
            last = end;
        }
        ctx.up_start = first.unwrap_or(ctx.gx_end);
        ctx.up_end = last;
    }
}

// ==================================================== tiered media lanes
//
// `Topology::tiered_media(hot, hot_frac)`: the hottest `hot_frac` Zipf
// ranks of every table are served from a fast volatile tier while the
// durable pool keeps the cold tail AND stays authoritative for every row
// (inclusive tiering). Lookups/updates split per tier; the volatile
// tier's touched rows are captured durably each batch by `hot-tier-flush`
// (they are not covered by the PMEM undo log); a periodic `tier-migrate`
// leg swaps promotion/demotion candidates over the switch. Every stage
// loops the GPU lanes, so the tiered chain composes with `gpu_shards(n)`
// — only the cold legs serialise on the shared `pmem_free` backend.

/// Per-tier embedding lookup: the cold tail (and all of the RAW
/// exposure) stays on the pool, the Zipf head is gathered from the hot
/// tier in parallel. Relaxed mode mirrors [`CxlFrontLookup`]: in steady
/// state both tiers' reduced vectors were produced during the previous
/// batch.
pub struct TieredEmbLookup {
    pub relaxed: bool,
}

impl Stage for TieredEmbLookup {
    fn name(&self) -> &'static str {
        "tiered-emb-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::Cold)
            .read(Region::HotTier, Rows::Hot)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        if self.relaxed {
            if let Some(done) = env.early_lookup_done {
                // Steady state: the vectors were produced during the
                // previous batch. Unlike the untiered chain (where the
                // early lookup is bounded by the pool chain and always
                // lands before the batch tail), the hot-tier leg runs off
                // the pool — a long hot gather can spill past t0, so the
                // flush must wait for it.
                let ready = done.max(ctx.t0);
                env.shard_lookup_done.fill(ready);
                ctx.lookup_done = ready;
                return;
            }
        }
        for s in 0..env.topo.gpu_shards {
            let st = env.lane_stats(s);
            let cold_acc = st.accesses - st.hot_accesses;
            let raw = if self.relaxed { 0.0 } else { cold_raw_frac(&st) };
            let mut lane_end = ctx.t0;
            if cold_acc > 0 {
                let start = env.pmem_free.max(ctx.t0);
                lane_end = env.cold_lookup(ctx.batch, start, cold_acc, raw);
            }
            if st.hot_accesses > 0 {
                lane_end = lane_end.max(env.hot_lookup(ctx.t0, st.hot_accesses));
            }
            if env.topo.gpu_shards > 1 {
                env.shard_lookup_done[s] = lane_end;
            }
            ctx.lookup_done = ctx.lookup_done.max(lane_end);
        }
    }
}

/// Batch-aware undo log of the COLD rows only — the hot tier's rows are
/// captured by [`HotTierFlush`], which completes the same generation.
pub struct TieredEmbUndoLog;

impl Stage for TieredEmbUndoLog {
    fn name(&self) -> &'static str {
        "tiered-emb-undo-log"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::Cold)
            .write(Region::UndoLog, Rows::Cold)
            .undo_capture(Rows::Cold, false)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        for s in 0..env.topo.gpu_shards {
            let st = env.lane_stats(s);
            let rows = st.unique_rows - st.hot_unique_rows;
            if rows == 0 {
                continue;
            }
            let start = env.pmem_free.max(ctx.t0);
            let op = env.mem.embedding_log(start, &mut env.table, rows);
            let end = start + op.duration;
            env.pmem_free = end;
            env.record_media(&op.media, "pmem");
            env.spans.add(Lane::CkptLogic, OpKind::CkptEmb, ctx.batch, start, end);
            env.spans.add(Lane::Pmem, OpKind::CkptEmb, ctx.batch, start, end);
            env.logic_busy += op.duration;
            ctx.emb_log_end = ctx.emb_log_end.max(end);
        }
    }
}

/// Durable capture of the volatile tier: the PMEM undo log cannot cover
/// rows living in DRAM, so each batch the checkpointing logic reads the
/// batch's hot rows from the hot tier and streams them into the PMEM log
/// region (pre-update capture + write-back of the previous hot deltas),
/// completing the undo generation recovery replays. The update may not
/// start before this lands — the same persistency ordering as the cold
/// undo log.
pub struct HotTierFlush;

impl Stage for HotTierFlush {
    fn name(&self) -> &'static str {
        "hot-tier-flush"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::HotTier, Rows::Hot)
            .write(Region::UndoLog, Rows::Hot)
            .undo_capture(Rows::Hot, false)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let hot_medium = env.hot_medium_name();
        let row_bytes = env.cfg.row_bytes();
        for s in 0..env.topo.gpu_shards {
            let st = env.lane_stats(s);
            let rows = st.hot_unique_rows;
            if rows == 0 {
                continue;
            }
            let start = env.pmem_free.max(ctx.t0);
            let hot = env.hot.as_mut().expect("tiered stage without a hot tier");
            let rd = hot.batch_access(start, rows, row_bytes, AccessKind::Read, 0.0);
            let wr_start = start + rd.duration;
            let wbytes = rows * row_bytes;
            let wr = env.table.stream(wr_start, wbytes, AccessKind::Write);
            let fl_start = wr_start + wr.duration;
            let flag = env.table.stream(fl_start, 64, AccessKind::Write);
            let end = fl_start + flag.duration;
            env.pmem_free = end;
            env.record_media(&rd, hot_medium);
            env.record_media(&wr, "pmem");
            env.record_media(&flag, "pmem");
            env.spans.add(Lane::CkptLogic, OpKind::CkptEmb, ctx.batch, start, end);
            env.spans.add(Lane::Pmem, OpKind::CkptEmb, ctx.batch, wr_start, end);
            env.logic_busy += end - start;
            ctx.emb_log_end = ctx.emb_log_end.max(end);
        }
    }
}

/// Per-tier relaxed early lookups for the NEXT batch (Fig 8 bottom): the
/// cold tail serialises on the pool behind this batch's undo generation;
/// the hot tier's leg runs on the volatile medium in parallel.
pub struct TieredRelaxedEarlyLookup;

impl Stage for TieredRelaxedEarlyLookup {
    fn name(&self) -> &'static str {
        "tiered-early-lookup"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::Cold)
            .read(Region::HotTier, Rows::Hot)
            .write(Region::ReducedVectors, Rows::All)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let mut last = ctx.emb_log_end;
        for s in 0..env.topo.gpu_shards {
            let st = env.lane_stats(s);
            let cold_acc = st.accesses - st.hot_accesses;
            if cold_acc > 0 {
                let start = env.pmem_free.max(ctx.emb_log_end);
                last = last.max(env.cold_lookup(ctx.batch, start, cold_acc, 0.0));
            }
            if st.hot_accesses > 0 {
                last = last.max(env.hot_lookup(ctx.emb_log_end, st.hot_accesses));
            }
        }
        env.early_lookup_done = Some(last);
    }
}

/// Per-tier embedding updates: cold rows RMW through the pool (serialised
/// on `pmem_free`, gated on the complete undo generation), hot rows RMW
/// in the volatile tier concurrently. Under the relaxed lookup each tier
/// applies its share of the commutative-add correction.
pub struct TieredEmbUpdate {
    pub correction: bool,
}

impl Stage for TieredEmbUpdate {
    fn name(&self) -> &'static str {
        "tiered-emb-update"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .write(Region::EmbTable, Rows::Cold)
            .write(Region::HotTier, Rows::Hot)
            .section(&[Resource::PmemPool])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let mut first: Option<SimTime> = None;
        let mut last = ctx.gx_end;
        for s in 0..env.topo.gpu_shards {
            let st = env.lane_stats(s);
            let cold_rows = st.unique_rows - st.hot_unique_rows;
            if cold_rows > 0 {
                let corr = if self.correction {
                    (cold_rows as f64 * st.prev_overlap) as u64
                } else {
                    0
                };
                let start = ctx.gx_end.max(env.pmem_free).max(ctx.emb_log_end);
                let end = env.cold_update(ctx.batch, start, cold_rows, corr);
                first = Some(first.map_or(start, |f| f.min(start)));
                last = last.max(end);
            }
            if st.hot_unique_rows > 0 {
                let corr = if self.correction {
                    (st.hot_unique_rows as f64 * st.prev_overlap) as u64
                } else {
                    0
                };
                let start = ctx.gx_end.max(ctx.emb_log_end);
                let end = env.hot_update(start, st.hot_unique_rows, corr);
                first = Some(first.map_or(start, |f| f.min(start)));
                last = last.max(end);
            }
        }
        ctx.up_start = first.unwrap_or(ctx.gx_end);
        ctx.up_end = last;
    }
}

/// Periodic promotion/demotion between the tiers (every
/// `tiers.migrate_every` batches): the DMA engine swaps the promotion
/// candidates' rows over the switch in the post-batch window. Off the
/// batch's critical path, but it occupies the pool — heavy migration
/// back-pressures the next batch's cold legs through `pmem_free`, the
/// cost the `tier-sweep` experiment exposes.
pub struct TierMigrate;

impl Stage for TierMigrate {
    fn name(&self) -> &'static str {
        "tier-migrate"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
            .read(Region::EmbTable, Rows::All)
            .read(Region::HotTier, Rows::All)
            .section(&[Resource::PmemPool, Resource::CxlLink])
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let Some(ts) = env.topo.tier_split() else {
            return;
        };
        if (ctx.batch + 1) % ts.migrate_every.max(1) != 0 {
            return;
        }
        let st = env.stats;
        // promote a quarter of the cold churn; demote a matching set
        let promote = (st.unique_rows - st.hot_unique_rows) / 4;
        if promote == 0 {
            return;
        }
        let row_bytes = env.cfg.row_bytes();
        let start = env.pmem_free.max(ctx.end);
        let rd = env
            .table
            .batch_access(start, promote, row_bytes, AccessKind::Read, 0.0);
        let wr = env
            .table
            .batch_access(start + rd.duration, promote, row_bytes, AccessKind::Write, 0.0);
        let (hrd, hwr) = {
            let hot = env.hot.as_mut().expect("tiered stage without a hot tier");
            let hrd = hot.batch_access(start, promote, row_bytes, AccessKind::Read, 0.0);
            let hstart = start + hrd.duration;
            let hwr = hot.batch_access(hstart, promote, row_bytes, AccessKind::Write, 0.0);
            (hrd, hwr)
        };
        let link = env.cxl.transfer(2 * promote * row_bytes, Proto::Cache);
        let pool_end = start + rd.duration + wr.duration;
        let hot_end = start + hrd.duration + hwr.duration;
        let end = pool_end.max(hot_end).max(start + link.duration);
        env.pmem_free = end;
        let hot_medium = env.hot_medium_name();
        env.record_media(&rd, "pmem");
        env.record_media(&wr, "pmem");
        env.record_media(&hrd, hot_medium);
        env.record_media(&hwr, hot_medium);
        env.traffic.record_link(link.bytes);
        env.spans.add(Lane::CkptLogic, OpKind::Transfer, ctx.batch, start, end);
        env.spans.add(Lane::Pmem, OpKind::Transfer, ctx.batch, start, pool_end);
        env.spans.add(Lane::Link, OpKind::Transfer, ctx.batch, start, start + link.duration);
        env.logic_busy += end - start;
    }
}

// ========================================================== attribution

/// Critical-path attribution for the software pipelines (Fig 11 bars).
pub struct SoftwareAttribution;

impl Stage for SoftwareAttribution {
    fn name(&self) -> &'static str {
        "software-attribution"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let bd = &mut ctx.bd;
        let fwd_ready = ctx.xf_end;
        if ctx.lk_end >= ctx.bf_end {
            bd.embedding += (ctx.lk_end - ctx.t0) as f64;
            bd.transfer += (fwd_ready - ctx.lk_end) as f64;
        } else {
            bd.bmlp += (ctx.bf_end - ctx.t0) as f64;
            bd.transfer += (fwd_ready - ctx.bf_end) as f64;
        }
        bd.tmlp += env.gpu.tmlp_total() as f64;
        // post-tmlp tail
        let tail_end = ctx.up_end.max(ctx.bb_end);
        if ctx.up_end >= ctx.bb_end {
            bd.transfer += (ctx.gx_end - ctx.tm_end) as f64;
            bd.embedding += (ctx.up_end - ctx.gx_end) as f64;
        } else {
            bd.bmlp += (ctx.bb_end - ctx.tm_end) as f64;
        }
        bd.checkpoint += (ctx.end - tail_end) as f64;
    }
}

/// Critical-path attribution for the PCIe pipeline (adds the MLP staging
/// leg to the tail analysis).
pub struct PcieAttribution;

impl Stage for PcieAttribution {
    fn name(&self) -> &'static str {
        "pcie-attribution"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let bd = &mut ctx.bd;
        if ctx.lk_end >= ctx.bf_end {
            bd.embedding += (ctx.lk_end - ctx.t0) as f64;
            bd.transfer += (ctx.xf_end - ctx.lk_end) as f64;
        } else {
            bd.bmlp += (ctx.bf_end - ctx.t0) as f64;
            bd.transfer += (ctx.xf_end - ctx.bf_end) as f64;
        }
        bd.tmlp += env.gpu.tmlp_total() as f64;
        let tail_end = ctx.up_end.max(ctx.bb_end).max(ctx.stage_end);
        if ctx.up_end >= ctx.bb_end.max(ctx.stage_end) {
            bd.transfer += (ctx.gx_end - ctx.tm_end) as f64;
            bd.embedding += (ctx.up_end - ctx.gx_end) as f64;
        } else if ctx.stage_end >= ctx.bb_end {
            bd.bmlp += (ctx.bb_end - ctx.tm_end) as f64;
            bd.checkpoint += (ctx.stage_end - ctx.bb_end) as f64;
        } else {
            bd.bmlp += (ctx.bb_end - ctx.tm_end) as f64;
        }
        bd.checkpoint += (ctx.end - tail_end) as f64;
    }
}

/// Critical-path attribution for the CXL pipelines: splits update waits
/// between checkpoint (undo-log gating, Fig 12b) and embedding work
/// (early lookup holding the PMEM backend).
pub struct CxlAttribution;

impl Stage for CxlAttribution {
    fn name(&self) -> &'static str {
        "cxl-attribution"
    }

    fn effects(&self) -> StageEffects {
        StageEffects::declared()
    }

    fn run(&self, env: &mut PipelineEnv, ctx: &mut BatchCtx) {
        let bd = &mut ctx.bd;
        let flush_end = ctx.xf_end;
        if flush_end > ctx.bf_end {
            // embedding path gated the interaction start
            let lk_seg = ctx.lookup_done.saturating_sub(ctx.t0);
            bd.embedding += lk_seg.min(flush_end - ctx.t0) as f64;
            bd.transfer += (flush_end - ctx.lookup_done.max(ctx.t0)) as f64;
        } else {
            bd.bmlp += env.gpu.bmlp_fwd as f64;
        }
        bd.tmlp += env.gpu.tmlp_total() as f64;
        // post-tmlp tail: whichever chain reaches the natural tail last
        if ctx.up_end >= ctx.bb_end {
            bd.transfer += (ctx.gx_end - ctx.tm_end) as f64;
            // The update may have waited: on the undo log (checkpoint
            // overhead, Fig 12b) or on the early lookup holding the PMEM
            // backend (embedding work, relaxed schedule). Split the wait.
            let wait = ctx.up_start.saturating_sub(ctx.gx_end);
            let ck_wait = ctx.emb_log_end.saturating_sub(ctx.gx_end).min(wait);
            bd.checkpoint += ck_wait as f64;
            bd.embedding += (wait - ck_wait) as f64 + (ctx.up_end - ctx.up_start) as f64;
        } else {
            bd.bmlp += env.gpu.bmlp_bwd as f64;
        }
        bd.checkpoint += ctx.ck_tail.max(0) as f64;
    }
}

// ========================================================== composition

/// Select the stage chain for a topology. Re-runs [`Topology::validate`]
/// (the shared invariant list) so hand-constructed `Topology` values
/// cannot revive the old `unreachable!` path.
pub fn compose(t: &Topology) -> Result<Vec<Box<dyn Stage>>, TopologyError> {
    t.validate()?;

    let mut v: Vec<Box<dyn Stage>> = Vec::new();
    if !t.near_data_processing {
        // SSD / PMEM / DRAM-ideal: host CPU embedding ops + sync/memcpy
        v.push(Box::new(HostEmbLookup));
        v.push(Box::new(GpuBottomFwd { launch_gated: true }));
        v.push(Box::new(SwUplinkTransfer));
        v.push(Box::new(GpuTopMlp));
        v.push(Box::new(SwGradTransfer));
        v.push(Box::new(GpuBottomBwd));
        v.push(Box::new(HostEmbUpdate));
        v.push(Box::new(BatchEnd));
        if t.ckpt == CkptMode::Redo {
            v.push(Box::new(HostRedoCkpt));
        }
        v.push(Box::new(SoftwareAttribution));
    } else if !t.hw_data_movement {
        // PCIe-attached PMEM: near-data embedding ops, software movement
        v.push(Box::new(NdpEmbLookup));
        v.push(Box::new(GpuBottomFwd { launch_gated: true }));
        v.push(Box::new(SwUplinkTransfer));
        v.push(Box::new(GpuTopMlp));
        v.push(Box::new(SwGradTransfer));
        v.push(Box::new(GpuBottomBwd));
        v.push(Box::new(NdpEmbUpdate { correction: false }));
        if t.ckpt == CkptMode::Redo {
            v.push(Box::new(PcieStagedRedoCkpt));
        } else {
            v.push(Box::new(BatchEnd));
        }
        v.push(Box::new(PcieAttribution));
    } else if t.tier_split().is_some() {
        // Tiered hot/cold media over the CXL fabric: per-tier lookup,
        // undo-log + hot-tier-flush checkpoint legs, per-tier update, a
        // periodic migration leg — all lane-looping, so the same chain
        // composes with gpu_shards(n); the movement/exchange stages are
        // the exact objects the untiered chains use. `hot_frac == 0`
        // never reaches this branch (`tier_split` is None), keeping the
        // single-media chain untouched and bit-identical.
        v.push(Box::new(TieredEmbLookup {
            relaxed: t.relaxed_lookup,
        }));
        if matches!(t.ckpt, CkptMode::BatchAware | CkptMode::Relaxed) {
            v.push(Box::new(TieredEmbUndoLog));
            v.push(Box::new(HotTierFlush));
        }
        if t.gpu_shards == 1 {
            v.push(Box::new(DcohFlush));
        } else {
            v.push(Box::new(ShardedDcohFlush));
            v.push(Box::new(ShardAllToAllExchange));
        }
        v.push(Box::new(GpuBottomFwd {
            launch_gated: false,
        }));
        v.push(Box::new(GpuTopMlp));
        v.push(Box::new(GpuBottomBwd));
        if t.gpu_shards == 1 {
            v.push(Box::new(CxlGradFlush));
        } else {
            v.push(Box::new(ShardedGradReduce));
        }
        if t.relaxed_lookup {
            v.push(Box::new(TieredRelaxedEarlyLookup));
        }
        v.push(Box::new(TieredEmbUpdate {
            correction: t.relaxed_lookup,
        }));
        match t.ckpt {
            CkptMode::Redo => v.push(Box::new(RedoTailCkpt)),
            CkptMode::BatchAware => v.push(Box::new(BatchAwareMlpLog)),
            CkptMode::Relaxed => v.push(Box::new(RelaxedMlpLog)),
            CkptMode::None => v.push(Box::new(BatchEnd)),
        }
        v.push(Box::new(TierMigrate));
        v.push(Box::new(CxlAttribution));
    } else if t.gpu_shards == 1 {
        // CXL-D / CXL-B / CXL: automatic data movement; checkpoint mode
        // and lookup relaxation select the remaining stages
        v.push(Box::new(CxlFrontLookup {
            relaxed: t.relaxed_lookup,
        }));
        if matches!(t.ckpt, CkptMode::BatchAware | CkptMode::Relaxed) {
            v.push(Box::new(EmbUndoLog));
        }
        v.push(Box::new(DcohFlush));
        v.push(Box::new(GpuBottomFwd {
            launch_gated: false,
        }));
        v.push(Box::new(GpuTopMlp));
        v.push(Box::new(GpuBottomBwd));
        v.push(Box::new(CxlGradFlush));
        if t.relaxed_lookup {
            v.push(Box::new(RelaxedEarlyLookup));
        }
        v.push(Box::new(NdpEmbUpdate {
            correction: t.relaxed_lookup,
        }));
        match t.ckpt {
            CkptMode::Redo => v.push(Box::new(RedoTailCkpt)),
            CkptMode::BatchAware => v.push(Box::new(BatchAwareMlpLog)),
            CkptMode::Relaxed => v.push(Box::new(RelaxedMlpLog)),
            CkptMode::None => v.push(Box::new(BatchEnd)),
        }
        v.push(Box::new(CxlAttribution));
    } else {
        // Multi-GPU sharded CXL lanes: striped tables, shared DCOH/pool,
        // all-to-all exchange + gradient reduce over the switch. The same
        // GPU phase and checkpoint-tail stages as the single-GPU chain
        // ride on top of the per-lane lookup/flush/update lanes.
        v.push(Box::new(ShardedEmbLookup {
            relaxed: t.relaxed_lookup,
        }));
        if matches!(t.ckpt, CkptMode::BatchAware | CkptMode::Relaxed) {
            v.push(Box::new(ShardedEmbUndoLog));
        }
        v.push(Box::new(ShardedDcohFlush));
        v.push(Box::new(ShardAllToAllExchange));
        v.push(Box::new(GpuBottomFwd {
            launch_gated: false,
        }));
        v.push(Box::new(GpuTopMlp));
        v.push(Box::new(GpuBottomBwd));
        v.push(Box::new(ShardedGradReduce));
        if t.relaxed_lookup {
            v.push(Box::new(ShardedRelaxedEarlyLookup));
        }
        v.push(Box::new(ShardedEmbUpdate {
            correction: t.relaxed_lookup,
        }));
        match t.ckpt {
            CkptMode::Redo => v.push(Box::new(RedoTailCkpt)),
            CkptMode::BatchAware => v.push(Box::new(BatchAwareMlpLog)),
            CkptMode::Relaxed => v.push(Box::new(RelaxedMlpLog)),
            CkptMode::None => v.push(Box::new(BatchEnd)),
        }
        v.push(Box::new(CxlAttribution));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn names(t: &Topology) -> Vec<&'static str> {
        compose(t).unwrap().iter().map(|s| s.name()).collect()
    }

    #[test]
    fn compositions_differ_only_where_capabilities_do() {
        let d = names(&Topology::from_system(SystemConfig::CxlD));
        let b = names(&Topology::from_system(SystemConfig::CxlB));
        let c = names(&Topology::from_system(SystemConfig::Cxl));
        // CXL-B = CXL-D + undo log, batch-aware tail instead of redo tail
        assert!(b.contains(&"emb-undo-log") && !d.contains(&"emb-undo-log"));
        assert!(d.contains(&"redo-tail-ckpt") && b.contains(&"batch-aware-mlp-log"));
        // CXL = CXL-B + early lookup + relaxed tail
        assert!(c.contains(&"relaxed-early-lookup") && !b.contains(&"relaxed-early-lookup"));
        assert!(c.contains(&"relaxed-mlp-log"));
        // software paths share the GPU/transfer spine
        let pmem = names(&Topology::from_system(SystemConfig::Pmem));
        let ssd = names(&Topology::from_system(SystemConfig::Ssd));
        assert_eq!(pmem, ssd);
        assert!(pmem.contains(&"host-redo-ckpt"));
        let dram = names(&Topology::from_system(SystemConfig::Dram));
        assert!(!dram.contains(&"host-redo-ckpt"));
    }

    #[test]
    fn sharded_compositions_swap_in_the_shard_lanes() {
        let sharded = Topology::builder("sharded")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200)
            .gpu_shards(2)
            .build()
            .unwrap();
        let n = names(&sharded);
        for stage in [
            "sharded-emb-lookup",
            "sharded-emb-undo-log",
            "sharded-dcoh-flush",
            "shard-exchange",
            "shard-grad-reduce",
            "sharded-early-lookup",
            "sharded-emb-update",
            "relaxed-mlp-log",
        ] {
            assert!(n.contains(&stage), "missing {stage}: {n:?}");
        }
        assert!(!n.contains(&"cxl-front-lookup") && !n.contains(&"dcoh-flush"));
        // gpu_shards(1) composes the exact single-GPU chain
        let single = Topology::builder("single")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200)
            .gpu_shards(1)
            .build()
            .unwrap();
        assert_eq!(names(&single), names(&Topology::from_system(SystemConfig::Cxl)));
    }

    #[test]
    fn tiered_compositions_swap_in_the_tier_lanes() {
        let flagship = |name: &str| {
            Topology::builder(name)
                .near_data()
                .hw_movement()
                .checkpoint(CkptMode::Relaxed)
                .relaxed_lookup()
                .max_mlp_log_gap(200)
        };
        let tiered = flagship("tiered").tiered_media(MediaKind::Dram, 0.3).build().unwrap();
        let n = names(&tiered);
        for stage in [
            "tiered-emb-lookup",
            "tiered-emb-undo-log",
            "hot-tier-flush",
            "dcoh-flush",
            "tiered-early-lookup",
            "tiered-emb-update",
            "relaxed-mlp-log",
            "tier-migrate",
        ] {
            assert!(n.contains(&stage), "missing {stage}: {n:?}");
        }
        assert!(!n.contains(&"cxl-front-lookup") && !n.contains(&"ndp-emb-update"));
        // hot_frac == 0 degenerates to the untouched single-media chain
        let zero = flagship("zero").tiered_media(MediaKind::Dram, 0.0).build().unwrap();
        assert_eq!(names(&zero), names(&Topology::from_system(SystemConfig::Cxl)));
        // tiers compose with gpu_shards(n): tier lanes + shard legs
        let sharded = flagship("tiered-sharded")
            .tiered_media(MediaKind::Dram, 0.3)
            .gpu_shards(2)
            .build()
            .unwrap();
        let n = names(&sharded);
        for stage in [
            "tiered-emb-lookup",
            "hot-tier-flush",
            "sharded-dcoh-flush",
            "shard-exchange",
            "shard-grad-reduce",
            "tiered-emb-update",
            "tier-migrate",
        ] {
            assert!(n.contains(&stage), "missing {stage}: {n:?}");
        }
        assert!(!n.contains(&"sharded-emb-lookup") && !n.contains(&"dcoh-flush"));
        // the hot-tier flush only exists where an undo generation does
        let redo = Topology::builder("tiered-redo")
            .near_data()
            .hw_movement()
            .tiered_media(MediaKind::Dram, 0.3)
            .build()
            .unwrap();
        let n = names(&redo);
        assert!(!n.contains(&"hot-tier-flush") && n.contains(&"redo-tail-ckpt"));
    }

    #[test]
    fn invalid_hand_built_topologies_rejected() {
        // bypass the builder: hand-construct the old unreachable combo
        let mut t = Topology::from_system(SystemConfig::Pmem);
        t.hw_data_movement = true; // but near_data_processing stays false
        assert_eq!(
            compose(&t).err(),
            Some(TopologyError::HwMovementWithoutNdp)
        );
    }
}
