//! The six evaluated system configurations (paper "Test configurations")
//! plus the DRAM-ideal energy reference, and the checkpointing modes they
//! schedule. A [`SystemConfig`] is now just a *name*: its capability
//! decomposition lives in [`crate::sim::topology::Topology`], which the
//! stage pipeline is composed from ([`Topology::from_system`]).
//!
//! [`Topology::from_system`]: crate::sim::topology::Topology::from_system

use std::fmt;
use std::str::FromStr;

/// Where embedding tables live and who moves/checkpoints data.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SystemConfig {
    /// Embedding tables on SSD, host-CPU embedding ops, host-DRAM vector
    /// cache, redo-log checkpoints to SSD.
    Ssd,
    /// Local Optane PMEM, host-CPU embedding ops, redo-log checkpoints.
    Pmem,
    /// PCIe-attached PMEM with near-data processing but software-managed
    /// movement + redo log.
    Pcie,
    /// TrainingCXL hardware without scheduling support (redo log).
    CxlD,
    /// CXL-D + batch-aware (undo-log, background) checkpoint.
    CxlB,
    /// CXL-B + relaxed embedding lookup + relaxed batch-aware checkpoint.
    Cxl,
    /// Energy-analysis ideal: tables fully in DRAM, no checkpointing.
    Dram,
}

/// Checkpointing scheme (Fig 4/6/9b).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CkptMode {
    /// Synchronous redo log at end of batch (baselines).
    Redo,
    /// Batch-aware undo log in background (CXL-B).
    BatchAware,
    /// Batch-aware + MLP logging spread across batches (CXL).
    Relaxed,
    /// No checkpointing at all (DRAM ideal).
    None,
}

impl SystemConfig {
    pub const ALL: [SystemConfig; 6] = [
        SystemConfig::Ssd,
        SystemConfig::Pmem,
        SystemConfig::Pcie,
        SystemConfig::CxlD,
        SystemConfig::CxlB,
        SystemConfig::Cxl,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SystemConfig::Ssd => "SSD",
            SystemConfig::Pmem => "PMEM",
            SystemConfig::Pcie => "PCIe",
            SystemConfig::CxlD => "CXL-D",
            SystemConfig::CxlB => "CXL-B",
            SystemConfig::Cxl => "CXL",
            SystemConfig::Dram => "DRAM",
        }
    }
}

impl fmt::Display for SystemConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Error of [`SystemConfig::from_str`]: carries the offending input and
/// renders the full valid list, so CLI users see their options instead of
/// a generic failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownConfig(pub String);

impl fmt::Display for UnknownConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unknown system config '{}' (valid:", self.0)?;
        for c in SystemConfig::ALL {
            write!(f, " {}", c.name())?;
        }
        write!(f, " {})", SystemConfig::Dram.name())
    }
}

impl std::error::Error for UnknownConfig {}

impl FromStr for SystemConfig {
    type Err = UnknownConfig;

    /// Case-insensitive; accepts the hyphenated and bare spellings of the
    /// CXL stages ("CXL-D"/"cxld", ...).
    fn from_str(s: &str) -> Result<SystemConfig, UnknownConfig> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "ssd" => SystemConfig::Ssd,
            "pmem" => SystemConfig::Pmem,
            "pcie" => SystemConfig::Pcie,
            "cxl-d" | "cxld" => SystemConfig::CxlD,
            "cxl-b" | "cxlb" => SystemConfig::CxlB,
            "cxl" => SystemConfig::Cxl,
            "dram" => SystemConfig::Dram,
            _ => return Err(UnknownConfig(s.to_string())),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_str_round_trip() {
        for c in SystemConfig::ALL {
            assert_eq!(c.name().parse::<SystemConfig>(), Ok(c));
            assert_eq!(c.name().to_ascii_lowercase().parse::<SystemConfig>(), Ok(c));
        }
        assert_eq!("DRAM".parse::<SystemConfig>(), Ok(SystemConfig::Dram));
        assert_eq!("cxld".parse::<SystemConfig>(), Ok(SystemConfig::CxlD));
    }

    #[test]
    fn unknown_config_lists_valid_names() {
        let err = "bogus".parse::<SystemConfig>().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("bogus"));
        for c in SystemConfig::ALL {
            assert!(msg.contains(c.name()), "error should list {}: {msg}", c.name());
        }
    }

    #[test]
    fn display_matches_name() {
        assert_eq!(SystemConfig::CxlB.to_string(), "CXL-B");
    }
}
