//! Crash recovery from the log region (paper: "Even if a power failure
//! occurs during an embedding update, training can be resumed from that
//! batch if the persistent flag is set").
//!
//! Undo semantics: the embedding log holds the *pre-update* values of the
//! rows batch N touches, so rolling them back restores the tables to the
//! start of batch N. The MLP log holds a snapshot from batch N-g (relaxed
//! logging); recovery resumes training at batch N with MLP parameters that
//! are g batches stale — exactly the state Fig 9a quantifies.

use super::log_region::LogRegion;
use crate::emb::EmbeddingStore;

/// What recovery reconstructed.
#[derive(Clone, Debug, PartialEq)]
pub struct RecoveredState {
    /// Batch to resume from (the embedding log's generation).
    pub resume_batch: u64,
    /// MLP staleness in batches (Fig 9a's x-axis).
    pub mlp_gap: u64,
    pub mlp_params: Vec<Vec<f32>>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum RecoveryError {
    #[error("no persistent embedding log — cannot roll back tables")]
    NoEmbLog,
    #[error("no persistent MLP log — cannot restore model parameters")]
    NoMlpLog,
}

/// Roll the embedding store back to the start of the logged batch and
/// return the restored MLP parameters.
///
/// `store` is the post-crash table image (possibly mid-update garbage in
/// the touched rows — everything else is valid because updates are
/// in-place per row).
pub fn recover(
    store: &mut EmbeddingStore,
    region: &LogRegion,
) -> Result<RecoveredState, RecoveryError> {
    let emb = region.persistent_emb().ok_or(RecoveryError::NoEmbLog)?;
    let mlp = region.persistent_mlp().ok_or(RecoveryError::NoMlpLog)?;
    for e in &emb.entries {
        store.apply_row(e.table, e.row, &e.old);
    }
    Ok(RecoveredState {
        resume_batch: emb.batch,
        mlp_gap: emb.batch.saturating_sub(mlp.batch),
        mlp_params: mlp.params.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::repo_root;

    fn setup() -> (ModelConfig, EmbeddingStore) {
        let cfg = ModelConfig::load(&repo_root(), "rm_mini").unwrap();
        let mut s = EmbeddingStore::zeros(&cfg);
        for t in 0..cfg.num_tables {
            for r in 0..cfg.rows_per_table {
                s.row_mut(t, r).fill((t * 1000 + r) as f32);
            }
        }
        (cfg, s)
    }

    #[test]
    fn rollback_restores_touched_rows_only() {
        let (_, mut store) = setup();
        let clean = store.clone();
        let mut region = LogRegion::new();
        let touched = vec![(0, 3), (2, 9)];
        region.begin_emb_log(5, &store, &touched);
        region.seal_emb_log(5);
        region.begin_mlp_log(5, &[vec![1.0, 2.0]]);
        region.advance_mlp_log(8);
        region.seal_mlp_log();

        // crash mid-update: touched rows are garbage
        store.row_mut(0, 3).fill(f32::NAN);
        store.row_mut(2, 9).fill(777.0);
        let rec = recover(&mut store, &region).unwrap();
        assert_eq!(rec.resume_batch, 5);
        assert_eq!(rec.mlp_gap, 0);
        assert_eq!(store, clean);
    }

    #[test]
    fn stale_mlp_log_reports_gap() {
        let (_, mut store) = setup();
        let mut region = LogRegion::new();
        region.begin_mlp_log(10, &[vec![0.5; 4]]);
        region.advance_mlp_log(16);
        region.seal_mlp_log();
        region.begin_emb_log(130, &store, &[(1, 1)]);
        region.seal_emb_log(130);
        let rec = recover(&mut store, &region).unwrap();
        assert_eq!(rec.resume_batch, 130);
        assert_eq!(rec.mlp_gap, 120);
        assert_eq!(rec.mlp_params, vec![vec![0.5; 4]]);
    }

    #[test]
    fn unsealed_generation_falls_back_to_previous() {
        let (_, mut store) = setup();
        let mut region = LogRegion::new();
        region.begin_emb_log(1, &store, &[(0, 1)]);
        region.seal_emb_log(1);
        region.begin_mlp_log(1, &[vec![1.0]]);
        region.advance_mlp_log(4);
        region.seal_mlp_log();
        // crash while generation-2 logs are mid-flight
        store.row_mut(0, 1).fill(-1.0);
        region.begin_emb_log(2, &store, &[(0, 1)]);
        let rec = recover(&mut store, &region).unwrap();
        assert_eq!(rec.resume_batch, 1);
    }

    #[test]
    fn missing_logs_error() {
        let (_, mut store) = setup();
        let region = LogRegion::new();
        assert_eq!(recover(&mut store, &region), Err(RecoveryError::NoEmbLog));
        let mut r2 = LogRegion::new();
        r2.begin_emb_log(0, &store, &[]);
        r2.seal_emb_log(0);
        assert_eq!(recover(&mut store, &r2), Err(RecoveryError::NoMlpLog));
    }
}
