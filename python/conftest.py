"""Make `compile.*` importable when pytest runs from the repo root."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
