"""L1 Pallas kernels: the compute hot-spots the paper puts in hardware.

embedding.py — CXL-MEM computing-logic kernels (bag lookup, SGD scatter)
mlp.py       — MXU-tiled matmul(+bias) for the bottom/top-MLP
ref.py       — pure-jnp oracles (the correctness ground truth)
"""

from . import embedding, mlp, ref  # noqa: F401
