//! Multi-level CXL 3.0 switch fabric: a *tree* of range-routed switches.
//!
//! CXL 3.0 allows up to 4095 devices per root complex through multi-level
//! switching; the single [`Switch`] models one level. A [`FabricTree`]
//! composes switches into a root + internal + leaf hierarchy with
//! hop-aware routing and per-link byte/occupancy counters — the fabric
//! the multi-tenant pooled-expander scenarios mount their shared PMEM
//! pool on ([`crate::tenancy`]). A tree with only the root node is
//! exactly the depth-1 case: it routes, forwards, and counts like the
//! plain `Switch` it wraps (pinned by `depth1_tree_matches_plain_switch`).
//!
//! Invariants:
//! * every device window is registered at its leaf AND every ancestor up
//!   to the root, so the root sees the whole HPA map — any overlap
//!   between any two windows (even in different subtrees) is rejected at
//!   the root before anything is registered;
//! * a routed path always terminates at a device port (child ports only
//!   exist where a subtree was attached), and its `hops` count is the
//!   number of switches traversed (1 for the depth-1 tree).

use crate::sim::cxl::switch::{PortId, Switch, SwitchError};
use crate::sim::SimTime;
use std::collections::BTreeMap;

/// Index of a switch node inside its [`FabricTree`].
pub type NodeId = usize;

/// The root switch every tree starts with.
pub const ROOT: NodeId = 0;

/// Cumulative counters of one tree edge (a child switch's uplink to its
/// parent): bytes forwarded, occupancy (busy ns), and transfer count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    pub bytes: u64,
    pub busy_ns: SimTime,
    pub transfers: u64,
}

/// One switch in the tree plus its uplink accounting.
#[derive(Debug)]
struct Node {
    name: String,
    parent: Option<NodeId>,
    switch: Switch,
    /// Local ports that lead to a child switch (absent = device port).
    child_of_port: BTreeMap<PortId, NodeId>,
    next_port: u16,
    /// Counters of the uplink to `parent` (unused for the root).
    uplink: LinkStats,
}

#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum FabricError {
    #[error("unknown fabric node {0}")]
    UnknownNode(NodeId),
    #[error("fabric switch '{name}': {err}")]
    Switch { name: String, err: SwitchError },
    #[error("fabric switch '{0}' has no free ports")]
    PortsExhausted(String),
}

/// A resolved path through the tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Route {
    /// The switch owning the terminal device port.
    pub node: NodeId,
    /// The device port on that switch.
    pub port: PortId,
    /// Switches traversed root → device (1 for a depth-1 tree).
    pub hops: usize,
}

/// Root + internal + leaf switches with per-link counters.
#[derive(Debug)]
pub struct FabricTree {
    nodes: Vec<Node>,
}

impl FabricTree {
    /// A tree holding only the root switch — the depth-1 fabric the
    /// paper's single-switch topology uses.
    pub fn new(root_name: &str) -> FabricTree {
        FabricTree {
            nodes: vec![Node {
                name: root_name.to_string(),
                parent: None,
                switch: Switch::new(),
                child_of_port: BTreeMap::new(),
                next_port: 0,
                uplink: LinkStats::default(),
            }],
        }
    }

    fn node(&self, id: NodeId) -> Result<&Node, FabricError> {
        self.nodes.get(id).ok_or(FabricError::UnknownNode(id))
    }

    fn alloc_port(&mut self, id: NodeId) -> Result<PortId, FabricError> {
        let name = self.nodes[id].name.clone();
        let node = &mut self.nodes[id];
        if node.next_port == u16::MAX {
            return Err(FabricError::PortsExhausted(name));
        }
        let p = PortId(node.next_port);
        node.next_port += 1;
        Ok(p)
    }

    /// Add a child switch under `parent`; returns the new node's id.
    pub fn add_switch(&mut self, parent: NodeId, name: &str) -> Result<NodeId, FabricError> {
        self.node(parent)?;
        let port = self.alloc_port(parent)?;
        let id = self.nodes.len();
        self.nodes[parent].child_of_port.insert(port, id);
        self.nodes.push(Node {
            name: name.to_string(),
            parent: Some(parent),
            switch: Switch::new(),
            child_of_port: BTreeMap::new(),
            next_port: 0,
            uplink: LinkStats::default(),
        });
        Ok(id)
    }

    /// The chain of nodes from the root down to `id` (inclusive).
    fn path_to(&self, id: NodeId) -> Vec<NodeId> {
        let mut chain = vec![id];
        let mut cur = id;
        while let Some(p) = self.nodes[cur].parent {
            chain.push(p);
            cur = p;
        }
        chain.reverse();
        chain
    }

    /// Attach a device window `[start, start+len)` at switch `node`,
    /// registering the range at every ancestor so the root can route it.
    ///
    /// Validation happens at the root FIRST: the root holds every window
    /// of the whole tree, so any overlap (even across subtrees), a
    /// zero-length window, or an overflowing range is rejected there
    /// before anything is registered anywhere — no partial attachment.
    pub fn attach_device(
        &mut self,
        node: NodeId,
        name: &str,
        start: u64,
        len: u64,
    ) -> Result<PortId, FabricError> {
        self.node(node)?;
        let chain = self.path_to(node);
        // Resolve (allocating where needed) the port each chain switch
        // routes this range through: the child-subtree port for interior
        // nodes, a fresh device port at the target.
        let mut ports = Vec::with_capacity(chain.len());
        for pair in chain.windows(2) {
            let (parent, child) = (pair[0], pair[1]);
            let existing = self.nodes[parent]
                .child_of_port
                .iter()
                .find(|&(_, &c)| c == child)
                .map(|(&p, _)| p)
                .expect("child switches always hold a port in their parent");
            ports.push((parent, existing, self.nodes[child].name.clone()));
        }
        let dev_port = self.alloc_port(node)?;
        ports.push((node, dev_port, name.to_string()));
        // Root first: its window set is the union of every subtree's, so
        // success there guarantees success at every descendant.
        for (i, (at, port, port_name)) in ports.iter().enumerate() {
            match self.nodes[*at].switch.attach(*port, port_name, start, len) {
                Ok(()) => {}
                Err(err) => {
                    debug_assert!(i == 0, "descendant attach failed after root accepted");
                    return Err(FabricError::Switch {
                        name: self.nodes[*at].name.clone(),
                        err,
                    });
                }
            }
        }
        Ok(dev_port)
    }

    /// Route an HPA from the root down to its device port.
    pub fn route(&self, addr: u64) -> Result<Route, FabricError> {
        let mut node = ROOT;
        let mut hops = 1;
        loop {
            let port = self.nodes[node].switch.route(addr).map_err(|err| {
                FabricError::Switch {
                    name: self.nodes[node].name.clone(),
                    err,
                }
            })?;
            match self.nodes[node].child_of_port.get(&port) {
                Some(&child) => {
                    node = child;
                    hops += 1;
                }
                None => return Ok(Route { node, port, hops }),
            }
        }
    }

    /// Account a transfer of `bytes` to `addr` occupying the path for
    /// `busy_ns`: per-port byte counters at every traversed switch plus
    /// byte/occupancy/transfer counters on every traversed link.
    pub fn forward(
        &mut self,
        addr: u64,
        bytes: u64,
        busy_ns: SimTime,
    ) -> Result<Route, FabricError> {
        let route = self.route(addr)?;
        let mut node = ROOT;
        loop {
            let port = self.nodes[node]
                .switch
                .forward(addr, bytes)
                .expect("route() already resolved this address");
            match self.nodes[node].child_of_port.get(&port).copied() {
                Some(child) => {
                    let l = &mut self.nodes[child].uplink;
                    l.bytes += bytes;
                    l.busy_ns += busy_ns;
                    l.transfers += 1;
                    node = child;
                }
                None => break,
            }
        }
        Ok(route)
    }

    /// Tree depth: 1 for the root-only (classic single-switch) fabric.
    pub fn levels(&self) -> usize {
        (0..self.nodes.len()).map(|n| self.path_to(n).len()).max().unwrap_or(1)
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn node_name(&self, id: NodeId) -> &str {
        self.nodes.get(id).map(|n| n.name.as_str()).unwrap_or("?")
    }

    /// The underlying switch of one node (introspection/tests).
    pub fn switch(&self, id: NodeId) -> Option<&Switch> {
        self.nodes.get(id).map(|n| &n.switch)
    }

    /// Uplink counters of one non-root node.
    pub fn uplink(&self, id: NodeId) -> Option<LinkStats> {
        self.nodes.get(id).filter(|n| n.parent.is_some()).map(|n| n.uplink)
    }

    /// `(link name, stats)` for every tree edge, in node order. Empty for
    /// the depth-1 fabric (no internal links).
    pub fn links(&self) -> Vec<(String, LinkStats)> {
        self.nodes
            .iter()
            .filter(|n| n.parent.is_some())
            .map(|n| (n.name.clone(), n.uplink))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: u64 = 1 << 30;

    #[test]
    fn depth1_tree_matches_plain_switch() {
        // the root-only tree must behave exactly like the single Switch
        // it subsumes: same routing, same per-port byte accounting
        let mut plain = Switch::new();
        let mut tree = FabricTree::new("root");
        let windows = [(0u64, 4 * GB), (4 * GB, 24 * GB), (28 * GB, 16 * GB)];
        for (i, &(start, len)) in windows.iter().enumerate() {
            plain.attach(PortId(i as u16), &format!("dev{i}"), start, len).unwrap();
            let p = tree.attach_device(ROOT, &format!("dev{i}"), start, len).unwrap();
            assert_eq!(p, PortId(i as u16));
        }
        assert_eq!(tree.levels(), 1);
        assert!(tree.links().is_empty(), "depth-1 fabric has no internal links");
        for addr in [0, GB, 5 * GB, 30 * GB, 43 * GB] {
            let r = tree.route(addr).unwrap();
            assert_eq!(r.port, plain.route(addr).unwrap());
            assert_eq!(r.node, ROOT);
            assert_eq!(r.hops, 1);
        }
        // unrouted addresses fail identically
        assert!(plain.route(60 * GB).is_err());
        assert!(matches!(
            tree.route(60 * GB),
            Err(FabricError::Switch {
                err: SwitchError::Unrouted(_),
                ..
            })
        ));
        // forwarding counts the same bytes on the same port
        plain.forward(5 * GB, 4096).unwrap();
        tree.forward(5 * GB, 4096, 100).unwrap();
        assert_eq!(
            tree.switch(ROOT).unwrap().bytes_by_port,
            plain.bytes_by_port
        );
    }

    #[test]
    fn multi_level_routing_is_hop_aware() {
        let mut tree = FabricTree::new("root");
        let leaf_a = tree.add_switch(ROOT, "leaf-a").unwrap();
        let leaf_b = tree.add_switch(ROOT, "leaf-b").unwrap();
        let deep = tree.add_switch(leaf_b, "leaf-b-2").unwrap();
        tree.attach_device(leaf_a, "mem-a", 0, 16 * GB).unwrap();
        tree.attach_device(deep, "mem-b", 16 * GB, 16 * GB).unwrap();
        tree.attach_device(ROOT, "host", 64 * GB, 4 * GB).unwrap();
        assert_eq!(tree.levels(), 3);

        let a = tree.route(GB).unwrap();
        assert_eq!((a.node, a.hops), (leaf_a, 2));
        let b = tree.route(17 * GB).unwrap();
        assert_eq!((b.node, b.hops), (deep, 3));
        let h = tree.route(65 * GB).unwrap();
        assert_eq!((h.node, h.hops), (ROOT, 1));
    }

    #[test]
    fn per_link_bytes_and_occupancy_accounted_on_the_path_only() {
        let mut tree = FabricTree::new("root");
        let leaf_a = tree.add_switch(ROOT, "leaf-a").unwrap();
        let leaf_b = tree.add_switch(ROOT, "leaf-b").unwrap();
        tree.attach_device(leaf_a, "mem-a", 0, 16 * GB).unwrap();
        tree.attach_device(leaf_b, "mem-b", 16 * GB, 16 * GB).unwrap();

        tree.forward(GB, 1024, 50).unwrap();
        tree.forward(GB, 1024, 70).unwrap();
        tree.forward(17 * GB, 4096, 10).unwrap();

        let a = tree.uplink(leaf_a).unwrap();
        assert_eq!((a.bytes, a.busy_ns, a.transfers), (2048, 120, 2));
        let b = tree.uplink(leaf_b).unwrap();
        assert_eq!((b.bytes, b.busy_ns, b.transfers), (4096, 10, 1));
        // the root has no uplink
        assert!(tree.uplink(ROOT).is_none());
        // root switch saw all the traffic, split across its two ports
        let root_bytes: u64 = tree.switch(ROOT).unwrap().bytes_by_port.values().sum();
        assert_eq!(root_bytes, 2048 + 4096);
        let links = tree.links();
        assert_eq!(links.len(), 2);
        assert_eq!(links[0].0, "leaf-a");
    }

    #[test]
    fn cross_subtree_overlap_rejected_atomically() {
        let mut tree = FabricTree::new("root");
        let leaf_a = tree.add_switch(ROOT, "leaf-a").unwrap();
        let leaf_b = tree.add_switch(ROOT, "leaf-b").unwrap();
        tree.attach_device(leaf_a, "mem-a", 0, 16 * GB).unwrap();
        // overlaps mem-a, but lives in a *different* subtree: the leaf
        // switch alone would accept it — the root must reject it
        let err = tree.attach_device(leaf_b, "mem-b", 8 * GB, 16 * GB).unwrap_err();
        assert!(
            matches!(
                err,
                FabricError::Switch {
                    err: SwitchError::Overlap { .. },
                    ..
                }
            ),
            "{err}"
        );
        // nothing was registered at leaf-b: a disjoint retry succeeds and
        // leaf-b still has no stale window from the failed attempt
        assert!(tree.route(9 * GB).is_ok(), "mem-a still routes");
        assert_eq!(tree.route(9 * GB).unwrap().node, leaf_a);
        tree.attach_device(leaf_b, "mem-b", 32 * GB, 16 * GB).unwrap();
        assert_eq!(tree.route(33 * GB).unwrap().node, leaf_b);
    }

    #[test]
    fn zero_length_and_overflow_propagate_from_the_switch() {
        let mut tree = FabricTree::new("root");
        let leaf = tree.add_switch(ROOT, "leaf").unwrap();
        assert!(matches!(
            tree.attach_device(leaf, "z", GB, 0),
            Err(FabricError::Switch {
                err: SwitchError::ZeroLength { .. },
                ..
            })
        ));
        assert!(matches!(
            tree.attach_device(leaf, "w", u64::MAX - 16, 64),
            Err(FabricError::Switch {
                err: SwitchError::Overflow { .. },
                ..
            })
        ));
        assert!(tree.route(GB).is_err(), "rejected windows route nothing");
    }

    #[test]
    fn unknown_nodes_are_errors() {
        let mut tree = FabricTree::new("root");
        assert_eq!(tree.add_switch(99, "x").unwrap_err(), FabricError::UnknownNode(99));
        assert_eq!(
            tree.attach_device(99, "x", 0, GB).unwrap_err(),
            FabricError::UnknownNode(99)
        );
    }
}
