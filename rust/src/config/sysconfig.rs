//! The six evaluated system configurations (paper "Test configurations")
//! plus the DRAM-ideal energy reference, decomposed into orthogonal knobs
//! so ablation benches can flip one dimension at a time.

use crate::sim::mem::MediaKind;

/// Where embedding tables live and who moves/checkpoints data.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SystemConfig {
    /// Embedding tables on SSD, host-CPU embedding ops, host-DRAM vector
    /// cache, redo-log checkpoints to SSD.
    Ssd,
    /// Local Optane PMEM, host-CPU embedding ops, redo-log checkpoints.
    Pmem,
    /// PCIe-attached PMEM with near-data processing but software-managed
    /// movement + redo log.
    Pcie,
    /// TrainingCXL hardware without scheduling support (redo log).
    CxlD,
    /// CXL-D + batch-aware (undo-log, background) checkpoint.
    CxlB,
    /// CXL-B + relaxed embedding lookup + relaxed batch-aware checkpoint.
    Cxl,
    /// Energy-analysis ideal: tables fully in DRAM, no checkpointing.
    Dram,
}

/// Checkpointing scheme (Fig 4/6/9b).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CkptMode {
    /// Synchronous redo log at end of batch (baselines).
    Redo,
    /// Batch-aware undo log in background (CXL-B).
    BatchAware,
    /// Batch-aware + MLP logging spread across batches (CXL).
    Relaxed,
    /// No checkpointing at all (DRAM ideal).
    None,
}

/// Fully decomposed knobs derived from a [`SystemConfig`].
#[derive(Clone, Debug, PartialEq)]
pub struct SystemKnobs {
    pub config: SystemConfig,
    /// Medium holding the embedding tables.
    pub table_media: MediaKind,
    /// Embedding ops run near data (computing logic) instead of host CPU.
    pub near_data_processing: bool,
    /// Data movement by CXL hardware (DCOH flushes) instead of
    /// sync+memcpy software.
    pub hw_data_movement: bool,
    pub ckpt: CkptMode,
    /// Relaxed embedding lookup (RAW elimination, Fig 8).
    pub relaxed_lookup: bool,
    /// Host-DRAM vector cache in front of the table medium (SSD config).
    pub dram_vector_cache: bool,
    /// Max embedding/MLP-log batch gap tolerated by relaxed checkpointing
    /// (Fig 9a: hundreds of batches stay within the 0.01% accuracy budget).
    pub max_mlp_log_gap: u64,
}

impl SystemConfig {
    pub const ALL: [SystemConfig; 6] = [
        SystemConfig::Ssd,
        SystemConfig::Pmem,
        SystemConfig::Pcie,
        SystemConfig::CxlD,
        SystemConfig::CxlB,
        SystemConfig::Cxl,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SystemConfig::Ssd => "SSD",
            SystemConfig::Pmem => "PMEM",
            SystemConfig::Pcie => "PCIe",
            SystemConfig::CxlD => "CXL-D",
            SystemConfig::CxlB => "CXL-B",
            SystemConfig::Cxl => "CXL",
            SystemConfig::Dram => "DRAM",
        }
    }

    pub fn parse(s: &str) -> Option<SystemConfig> {
        Some(match s.to_ascii_lowercase().as_str() {
            "ssd" => SystemConfig::Ssd,
            "pmem" => SystemConfig::Pmem,
            "pcie" => SystemConfig::Pcie,
            "cxl-d" | "cxld" => SystemConfig::CxlD,
            "cxl-b" | "cxlb" => SystemConfig::CxlB,
            "cxl" => SystemConfig::Cxl,
            "dram" => SystemConfig::Dram,
            _ => return None,
        })
    }

    pub fn knobs(&self) -> SystemKnobs {
        let base = SystemKnobs {
            config: *self,
            table_media: MediaKind::Pmem,
            near_data_processing: false,
            hw_data_movement: false,
            ckpt: CkptMode::Redo,
            relaxed_lookup: false,
            dram_vector_cache: false,
            max_mlp_log_gap: 1,
        };
        match self {
            SystemConfig::Ssd => SystemKnobs {
                table_media: MediaKind::Ssd,
                dram_vector_cache: true,
                ..base
            },
            SystemConfig::Pmem => base,
            SystemConfig::Pcie => SystemKnobs {
                near_data_processing: true,
                ..base
            },
            SystemConfig::CxlD => SystemKnobs {
                near_data_processing: true,
                hw_data_movement: true,
                ..base
            },
            SystemConfig::CxlB => SystemKnobs {
                near_data_processing: true,
                hw_data_movement: true,
                ckpt: CkptMode::BatchAware,
                ..base
            },
            SystemConfig::Cxl => SystemKnobs {
                near_data_processing: true,
                hw_data_movement: true,
                ckpt: CkptMode::Relaxed,
                relaxed_lookup: true,
                max_mlp_log_gap: 200,
                ..base
            },
            SystemConfig::Dram => SystemKnobs {
                table_media: MediaKind::Dram,
                near_data_processing: false,
                hw_data_movement: false,
                ckpt: CkptMode::None,
                ..base
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_progression_matches_paper() {
        // each TrainingCXL step adds exactly one capability
        let d = SystemConfig::CxlD.knobs();
        let b = SystemConfig::CxlB.knobs();
        let c = SystemConfig::Cxl.knobs();
        assert!(d.near_data_processing && d.hw_data_movement);
        assert_eq!(d.ckpt, CkptMode::Redo);
        assert_eq!(b.ckpt, CkptMode::BatchAware);
        assert!(!b.relaxed_lookup);
        assert_eq!(c.ckpt, CkptMode::Relaxed);
        assert!(c.relaxed_lookup);
        assert!(c.max_mlp_log_gap > 100); // Fig 9a: hundreds of batches
    }

    #[test]
    fn parse_round_trip() {
        for c in SystemConfig::ALL {
            assert_eq!(SystemConfig::parse(c.name()), Some(c));
        }
        assert_eq!(SystemConfig::parse("DRAM"), Some(SystemConfig::Dram));
        assert_eq!(SystemConfig::parse("bogus"), None);
    }

    #[test]
    fn baselines_use_software_paths() {
        for c in [SystemConfig::Ssd, SystemConfig::Pmem] {
            let k = c.knobs();
            assert!(!k.near_data_processing);
            assert!(!k.hw_data_movement);
            assert_eq!(k.ckpt, CkptMode::Redo);
        }
        assert!(SystemConfig::Pcie.knobs().near_data_processing);
        assert!(!SystemConfig::Pcie.knobs().hw_data_movement);
        assert_eq!(SystemConfig::Ssd.knobs().table_media, MediaKind::Ssd);
    }
}
