//! Adversarial config-parsing matrix.
//!
//! Operator-supplied TOML is the repo's only untrusted input surface:
//! `tomlmini` feeds `Topology::from_doc` and `TenantSet::from_doc`, and a
//! bad file must surface as a typed error naming the offending key (or a
//! logged fallback on the lenient paths) — never a panic and never a
//! silently-wrong fabric. `rust/tests/serving.rs` pins the serving-knob
//! and `[tiers]` rows of this matrix; this file covers the rest: parser
//! edge cases, per-key type confusion in `Topology::from_doc`,
//! cross-field composition conflicts reachable from TOML, and the
//! structural `TenantSet` errors.

use trainingcxl::config::SystemConfig;
use trainingcxl::repo_root;
use trainingcxl::sim::topology::Topology;
use trainingcxl::tenancy::TenantSet;
use trainingcxl::util::tomlmini::Doc;

// ------------------------------------------------------------- tomlmini

#[test]
fn parser_rejects_malformed_lines_without_panicking() {
    // Every input here must come back as Err(TomlError) — the parser has
    // no panicking path for garbage. (Basic shapes are pinned in the
    // tomlmini unit tests; these are the adversarial leftovers.)
    for bad in [
        "x =",                       // empty value
        "x = [1,",                   // unterminated array
        "x = [1, ]",                 // trailing comma -> empty element
        "x = [[1, 2], [3]]",         // nested arrays are out of subset
        "x = \"unterminated",        // unterminated string
        "x = y = z",                 // value with stray '='
        "[[tenants]\nmodel = \"m\"", // mis-closed array header
        "\u{1f4a5} boom",            // unicode garbage, no '='
    ] {
        assert!(Doc::parse(bad).is_err(), "{bad:?} should not parse");
    }
}

#[test]
fn parser_accepts_exotic_but_well_formed_values() {
    // Lenient acceptances the consumers must cope with: these parse, and
    // the typed from_doc layers reject them field-by-field instead.
    // An integer too big for i64 degrades to a float, not a panic.
    let doc = Doc::parse("x = 99999999999999999999999999").unwrap();
    assert!(doc.get("x").unwrap().as_i64().is_none());
    assert!(doc.get("x").unwrap().as_f64().unwrap() > 1e25);
    // Underscore grouping applies to floats too.
    let doc = Doc::parse("x = 1_000.5").unwrap();
    assert_eq!(doc.get("x").unwrap().as_f64(), Some(1000.5));
    // Duplicate keys: last one wins, silently.
    let doc = Doc::parse("x = 1\nx = 2").unwrap();
    assert_eq!(doc.get("x").unwrap().as_i64(), Some(2));
    // A header re-opening a table keeps accumulating keys.
    let doc = Doc::parse("[a]\nx = 1\n[b]\ny = 2\n[a]\nz = 3").unwrap();
    assert_eq!(doc.get("a.x").unwrap().as_i64(), Some(1));
    assert_eq!(doc.get("a.z").unwrap().as_i64(), Some(3));
}

// ------------------------------------------- Topology::from_doc, per key

/// Every wrong-typed or out-of-range scalar key yields a typed error
/// whose message names the key, so an operator can find the bad line.
#[test]
fn topology_from_doc_names_the_offending_key() {
    for (bad, needle) in [
        ("table_media = 3", "table_media"),
        ("table_media = \"l2\"", "table_media"),
        ("checkpoint = true", "checkpoint"),
        ("checkpoint = \"incremental\"", "checkpoint"),
        ("near_data_processing = \"yes\"", "near_data_processing"),
        ("hw_data_movement = 1", "hw_data_movement"),
        ("relaxed_lookup = \"on\"", "relaxed_lookup"),
        ("dram_vector_cache = 0.5", "dram_vector_cache"),
        ("max_mlp_log_gap = -5", "max_mlp_log_gap"),
        ("max_mlp_log_gap = \"big\"", "max_mlp_log_gap"),
        ("[pool]\nexpanders = \"many\"", "pool.expanders"),
        ("[pool]\nexpanders = -1", "pool.expanders"),
        ("[pool]\nextra_hops = 1.5", "pool.extra_hops"),
        ("[gpu]\nshards = -2", "gpu.shards"),
        ("[gpu]\nshards = \"all\"", "gpu.shards"),
        ("[tiers]\nmigrate_every = -1", "tiers.migrate_every"),
        // a [[tenants]] file refused here: it is a set, not a topology
        ("[[tenants]]\nmodel = \"rm_mini\"", "tenants"),
    ] {
        let doc = Doc::parse(bad).unwrap();
        let err = Topology::from_doc("adv", &doc).unwrap_err().to_string();
        assert!(err.contains(needle), "{bad:?} -> {err}");
    }
}

/// Conflicting compositions reachable from a well-typed TOML file are
/// rejected by `validate()`, not silently "fixed".
#[test]
fn topology_from_doc_rejects_conflicting_compositions() {
    for bad in [
        // background checkpointing without hardware movement
        "near_data_processing = true\ncheckpoint = \"batch-aware\"",
        "near_data_processing = true\ncheckpoint = \"relaxed\"",
        // relaxed lookup without hardware movement
        "near_data_processing = true\nrelaxed_lookup = true",
        // hardware movement without near-data processing
        "hw_data_movement = true",
        // sharding without hardware movement
        "near_data_processing = true\n[gpu]\nshards = 2",
        // empty pool / empty shard set
        "[pool]\nexpanders = 0",
        "[gpu]\nshards = 0",
        // tiers over a non-durable cold store
        "table_media = \"ssd\"\nnear_data_processing = true\nhw_data_movement = true\n\
         [tiers]\nhot_media = \"dram\"\nhot_frac = 0.5",
        // migrate cadence of zero
        "near_data_processing = true\nhw_data_movement = true\n\
         [tiers]\nhot_media = \"dram\"\nhot_frac = 0.5\nmigrate_every = 0",
    ] {
        let doc = Doc::parse(bad).unwrap();
        assert!(
            Topology::from_doc("adv", &doc).is_err(),
            "{bad:?} should not compose"
        );
    }
}

#[test]
fn lenient_load_falls_back_for_tenant_set_names() {
    // `Topology::load` handed the name of a *tenant-set* file must not
    // silently simulate a default fabric: from_doc refuses the tenants
    // table and the lenient chain falls back to the flagship preset.
    let root = repo_root();
    if !root.join("configs/topologies/serve-mixed-2.toml").is_file() {
        eprintln!("skipping: shipped tenant sets not present");
        return;
    }
    assert!(Topology::load_strict(&root, "serve-mixed-2").is_err());
    let t = Topology::load(&root, "serve-mixed-2");
    assert_eq!(
        t.name,
        SystemConfig::Cxl.name(),
        "tenant-set names fall back to the flagship"
    );
}

// --------------------------------------------------- TenantSet::from_doc

#[test]
fn tenant_set_structural_errors_are_typed() {
    let root = repo_root();
    // no [[tenants]] at all — with and without other valid tables
    for bad in ["", "name = \"solo\"", "[fabric]\nlevels = 2"] {
        let doc = Doc::parse(bad).unwrap();
        let err = TenantSet::from_doc(&root, "adv", &doc).unwrap_err().to_string();
        assert!(err.contains("at least one"), "{bad:?} -> {err}");
    }
    // per-key confusion above the tenant tables and inside them
    for (bad, needle) in [
        ("[fabric]\nlevels = 0\n[[tenants]]\nmodel = \"m\"", "fabric.levels"),
        (
            "[fabric]\nlevels = \"two\"\n[[tenants]]\nmodel = \"m\"",
            "fabric.levels",
        ),
        (
            "[arbiter]\npolicy = \"round-robin\"\n[[tenants]]\nmodel = \"m\"",
            "arbiter.policy",
        ),
        ("[arbiter]\npolicy = 7\n[[tenants]]\nmodel = \"m\"", "arbiter.policy"),
        ("[[tenants]]\nname = \"a\"", "model"),
        ("[[tenants]]\nmodel = 3", "model"),
        ("[[tenants]]\nmodel = \"m\"\nname = 7", "name"),
        ("[[tenants]]\nmodel = \"m\"\ntopology = 9", "topology"),
        ("[[tenants]]\nmodel = \"m\"\nseed = -1", "seed"),
        ("[[tenants]]\nmodel = \"m\"\nweight = 0", "weight"),
        ("[[tenants]]\nmodel = \"m\"\nweight = \"heavy\"", "weight"),
        // an unknown per-tenant topology is a load error, not a fallback:
        // strict resolution inside a set (unlike the lenient CLI path)
        (
            "[[tenants]]\nmodel = \"m\"\ntopology = \"no-such-fabric\"",
            "no-such-fabric",
        ),
    ] {
        let doc = Doc::parse(bad).unwrap();
        let err = TenantSet::from_doc(&root, "adv", &doc).unwrap_err().to_string();
        assert!(err.contains(needle), "{bad:?} -> {err}");
    }
    // the error for a malformed *later* table still names its index key
    let doc = Doc::parse(
        "[[tenants]]\nmodel = \"m\"\n[[tenants]]\nmodel = \"m\"\nweight = -3\n",
    )
    .unwrap();
    let err = TenantSet::from_doc(&root, "adv", &doc).unwrap_err().to_string();
    assert!(err.contains("tenants.1.weight"), "{err}");
}

/// `[fabric] redundancy` and the `[[faults]]` schedule are operator
/// input too: every malformed row is a typed `BadField` naming the
/// offending key — unknown kinds, dangling tenant names, out-of-range
/// levels, negative times, repair-before-inject — never a panic.
#[test]
fn fault_schedule_keys_are_typed() {
    let root = repo_root();
    // one valid tenant so the only defect is the row under test
    const T: &str = "[[tenants]]\nname = \"a\"\nmodel = \"m\"\n";
    for (bad, needle) in [
        // spare-lane knob: type confusion and out-of-range both name it
        (format!("[fabric]\nredundancy = -1\n{T}"), "fabric.redundancy"),
        (format!("[fabric]\nredundancy = 99\n{T}"), "fabric.redundancy"),
        (format!("[fabric]\nredundancy = \"two\"\n{T}"), "fabric.redundancy"),
        // kind: required, string-typed, closed enum
        (format!("{T}[[faults]]\ntenant = \"a\""), "faults.0.kind"),
        (format!("{T}[[faults]]\nkind = 3\ntenant = \"a\""), "faults.0.kind"),
        (
            format!("{T}[[faults]]\nkind = \"gamma-ray\"\ntenant = \"a\""),
            "unknown fault kind",
        ),
        // tenant: required, and must resolve against the [[tenants]] names
        (format!("{T}[[faults]]\nkind = \"link-down\""), "faults.0.tenant"),
        (
            format!("{T}[[faults]]\nkind = \"link-down\"\ntenant = \"nobody\""),
            "no tenant named 'nobody'",
        ),
        // level: kind-dependent validity against the declared fabric depth
        (
            format!("{T}[[faults]]\nkind = \"expander-lost\"\ntenant = \"a\"\nlevel = 0"),
            "level only applies",
        ),
        (
            format!(
                "[fabric]\nlevels = 2\n{T}[[faults]]\nkind = \"link-down\"\ntenant = \"a\"\nlevel = 5"
            ),
            "link level must be in 1..=1",
        ),
        (
            format!(
                "[fabric]\nlevels = 2\n{T}[[faults]]\nkind = \"switch-down\"\ntenant = \"a\"\nlevel = 9"
            ),
            "switch level must be in 0..=1",
        ),
        (
            format!("{T}[[faults]]\nkind = \"switch-down\"\ntenant = \"a\"\nlevel = -1"),
            "faults.0.level",
        ),
        // rounds: required, non-negative, and repair strictly after inject
        (
            format!("{T}[[faults]]\nkind = \"link-down\"\ntenant = \"a\""),
            "faults.0.inject_round",
        ),
        (
            format!(
                "{T}[[faults]]\nkind = \"link-down\"\ntenant = \"a\"\ninject_round = -1"
            ),
            "faults.0.inject_round",
        ),
        (
            format!(
                "{T}[[faults]]\nkind = \"link-down\"\ntenant = \"a\"\n\
                 inject_round = 2\nrepair_round = 2"
            ),
            "must come after inject round",
        ),
    ] {
        let doc = Doc::parse(&bad).unwrap();
        let err = TenantSet::from_doc(&root, "adv", &doc).unwrap_err().to_string();
        assert!(err.contains(needle), "{bad:?} -> {err}");
    }
    // a malformed *later* fault row still names its own index key
    let doc = Doc::parse(
        "[[tenants]]\nname = \"a\"\nmodel = \"m\"\n\
         [[faults]]\nkind = \"link-down\"\ntenant = \"a\"\ninject_round = 1\nrepair_round = 3\n\
         [[faults]]\nkind = \"switch-down\"\ntenant = \"a\"\ninject_round = 4\nrepair_round = 1\n",
    )
    .unwrap();
    let err = TenantSet::from_doc(&root, "adv", &doc).unwrap_err().to_string();
    assert!(err.contains("faults.1.repair_round"), "{err}");
}
