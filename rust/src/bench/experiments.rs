//! Experiment drivers: one function per paper table/figure (DESIGN.md's
//! experiment index), all returning a typed [`Report`].
//!
//! A [`Report`] carries the rendered figure text (what `trainingcxl bench
//! <exp>` prints — [`Report`] implements `Display`) *and* the key scalars
//! in a typed [`MetricsRegistry`] (counters/gauges/histogram summaries),
//! so tests, benches, and downstream tooling read numbers instead of
//! re-parsing report strings. `Report::to_json` emits the registry's
//! flat scalar view serde-free through [`crate::util::json::Json`] — the
//! same key set the old hand-plumbed metric list carried, so downstream
//! fingerprints and golden fixtures did not move.

use crate::config::device::DeviceParams;
use crate::config::sysconfig::SystemConfig;
use crate::config::{CkptMode, ModelConfig};
use crate::devices::CxlGpu;
use crate::energy::energy_of_run;
use crate::sched::{PipelineSim, RunResult};
use crate::sim::mem::MediaKind;
use crate::sim::topology::Topology;
use crate::telemetry::{BreakdownTable, MetricsRegistry};
use crate::util::json::Json;
use crate::util::stats::geomean;
use crate::world::World;
use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;
use std::path::Path;
use std::str::FromStr;

pub const PAPER_MODELS: [&str; 4] = ["rm1", "rm2", "rm3", "rm4"];

// ============================================================== reports

/// Typed result of one experiment: the rendered figure text plus the key
/// scalars in a [`MetricsRegistry`].
#[derive(Clone, Debug)]
pub struct Report {
    /// Which experiment produced this.
    pub experiment: Experiment,
    /// Rendered, human-readable figure text (what the CLI prints).
    pub body: String,
    /// The experiment's registered metrics — the one export path.
    pub metrics: MetricsRegistry,
}

impl Report {
    fn new(experiment: Experiment) -> Report {
        Report {
            experiment,
            body: String::new(),
            metrics: MetricsRegistry::new(),
        }
    }

    fn push(&mut self, key: impl Into<String>, value: f64, unit: &'static str) {
        self.metrics.gauge(key, value, unit);
    }

    /// Look up a metric's flat scalar by key.
    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics.value(key)
    }

    /// Every metric must be a finite number — the CI bench-smoke gate
    /// (a NaN/inf speedup means an experiment silently divided by zero).
    pub fn ensure_finite(&self) -> anyhow::Result<()> {
        for (key, value) in self.metrics.flat() {
            anyhow::ensure!(
                value.is_finite(),
                "experiment {}: metric '{}' is non-finite ({})",
                self.experiment.name(),
                key,
                value
            );
        }
        Ok(())
    }

    /// Serde-free JSON rendering of the metrics
    /// (`{"experiment": ..., "metrics": {key: value, ...}}`) — the
    /// registry's flat scalar view, which keeps the historic key shape.
    pub fn to_json(&self) -> Json {
        let mut top = BTreeMap::new();
        top.insert(
            "experiment".to_string(),
            Json::Str(self.experiment.name().to_string()),
        );
        top.insert("metrics".to_string(), self.metrics.to_json());
        Json::Obj(top)
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.body)
    }
}

/// The paper experiments, one per table/figure.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Experiment {
    Fig11,
    Fig12,
    Fig13,
    Fig9a,
    Headline,
    AblateMovement,
    AblateRaw,
    Pooling,
    ShardScaling,
    TierSweep,
    TenantInterference,
    ServeLatency,
    EngineThroughput,
    FaultSweep,
}

impl Experiment {
    pub const ALL: [Experiment; 14] = [
        Experiment::Fig11,
        Experiment::Fig12,
        Experiment::Fig13,
        Experiment::Headline,
        Experiment::AblateMovement,
        Experiment::AblateRaw,
        Experiment::Pooling,
        Experiment::ShardScaling,
        Experiment::TierSweep,
        Experiment::TenantInterference,
        Experiment::ServeLatency,
        Experiment::EngineThroughput,
        Experiment::FaultSweep,
        Experiment::Fig9a,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Experiment::Fig11 => "fig11",
            Experiment::Fig12 => "fig12",
            Experiment::Fig13 => "fig13",
            Experiment::Fig9a => "fig9a",
            Experiment::Headline => "headline",
            Experiment::AblateMovement => "ablate-movement",
            Experiment::AblateRaw => "ablate-raw",
            Experiment::Pooling => "pooling",
            Experiment::ShardScaling => "shard-scaling",
            Experiment::TierSweep => "tier-sweep",
            Experiment::TenantInterference => "tenant-interference",
            Experiment::ServeLatency => "serve-latency",
            Experiment::EngineThroughput => "engine-throughput",
            Experiment::FaultSweep => "fault-sweep",
        }
    }

    /// Run this experiment with `opts`; the uniform entry point `main`,
    /// the benches, and the examples share. Every report passes the
    /// finite-metrics gate before it is returned, and the trajectory
    /// experiments (engine-throughput, fault-sweep, tenant-interference)
    /// write their `BENCH_*.json` snapshot at the repo root.
    pub fn run(&self, root: &Path, opts: &RunOpts) -> anyhow::Result<Report> {
        let mut r = match self {
            Experiment::Fig11 => fig11(root, opts.batches),
            Experiment::Fig12 => fig12(root, opts.model.as_deref().unwrap_or("rm1")),
            Experiment::Fig13 => fig13(root, opts.batches),
            Experiment::Fig9a => fig9a(root, &[0, 1, 10, 50, 100, 200]),
            Experiment::Headline => headline(root, opts.batches),
            Experiment::AblateMovement => ablate_movement(root, opts.batches),
            Experiment::AblateRaw => ablate_raw(root, opts.batches),
            Experiment::Pooling => {
                pooling(root, opts.model.as_deref().unwrap_or("rm2"), opts.batches)
            }
            Experiment::ShardScaling => {
                shard_scaling(root, opts.model.as_deref().unwrap_or("rm2"), opts.batches)
            }
            Experiment::TierSweep => {
                tier_sweep(root, opts.model.as_deref().unwrap_or("rm2"), opts.batches)
            }
            Experiment::TenantInterference => {
                tenant_interference(root, opts.model.as_deref().unwrap_or("rm2"), opts.batches)
            }
            Experiment::ServeLatency => {
                serve_latency(root, opts.model.as_deref().unwrap_or("rm2"), opts.batches)
            }
            Experiment::EngineThroughput => engine_throughput(root, opts.batches),
            Experiment::FaultSweep => fault_sweep(root, opts.batches),
        }?;
        anyhow::ensure!(
            !r.metrics.is_empty(),
            "experiment {}: report carries no metrics (the bench-smoke gate \
             rejects empty reports)",
            self.name()
        );
        r.ensure_finite()?;
        match self {
            Experiment::TenantInterference => write_bench_json(&mut r, root, "BENCH_tenancy.json")?,
            Experiment::FaultSweep => write_bench_json(&mut r, root, "BENCH_faults.json")?,
            _ => {}
        }
        Ok(r)
    }
}

/// Write `r`'s JSON rendering to `<root>/<file>` — the repo-root bench
/// trajectory (`BENCH_engine.json` / `BENCH_faults.json` /
/// `BENCH_tenancy.json`, all the same `{"experiment", "metrics"}`
/// shape) — and append a `wrote <path>` line to the body. Only the
/// bench entry points call this; the raw experiment functions stay
/// side-effect free for tests.
fn write_bench_json(r: &mut Report, root: &Path, file: &str) -> anyhow::Result<()> {
    let path = root.join(file);
    std::fs::write(&path, format!("{}\n", r.to_json()))?;
    writeln!(r.body, "wrote {}", path.display())?;
    Ok(())
}

/// Error of [`Experiment::from_str`]: lists the valid experiment names.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownExperiment(pub String);

impl fmt::Display for UnknownExperiment {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unknown experiment '{}' (valid:", self.0)?;
        for e in Experiment::ALL {
            write!(f, " {}", e.name())?;
        }
        write!(f, " all)")
    }
}

impl std::error::Error for UnknownExperiment {}

impl FromStr for Experiment {
    type Err = UnknownExperiment;

    fn from_str(s: &str) -> Result<Experiment, UnknownExperiment> {
        Experiment::ALL
            .iter()
            .copied()
            .find(|e| e.name().eq_ignore_ascii_case(s))
            .ok_or_else(|| UnknownExperiment(s.to_string()))
    }
}

/// Shared experiment knobs.
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub batches: u64,
    pub model: Option<String>,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            batches: 30,
            model: None,
        }
    }
}

// =========================================================== simulation

/// Simulate one (model, config) pair for `batches` batches.
pub fn simulate(
    root: &Path,
    model: &str,
    sys: SystemConfig,
    batches: u64,
) -> anyhow::Result<RunResult> {
    simulate_topology(root, model, Topology::from_system(sys), batches)
}

/// Simulate one (model, topology) pair — the entry point custom scenarios
/// (pooled expanders, sharded lanes, tiered media, TOML-defined fabrics)
/// share with the paper configs. Sharded topologies get generator-striped
/// per-lane stats (table `t` on lane `t % shards`), not an even split;
/// tiered topologies get per-tier access classification from the same
/// generator (`hot_frac == 0` stats are bit-identical to untiered ones).
pub fn simulate_topology(
    root: &Path,
    model: &str,
    topo: Topology,
    batches: u64,
) -> anyhow::Result<RunResult> {
    // A pure graph walk, so debug builds refuse to benchmark a chain the
    // static analyzer would reject.
    debug_assert!(
        crate::analysis::analyze_topology(&topo)
            .map(|r| r.is_clean())
            .unwrap_or(false),
        "statically inconsistent topology reached the bench path: {}",
        topo.name
    );
    Ok(PipelineSim::for_model(root, model, topo, 42)?.run(batches))
}

// ========================================================== experiments

/// E1 / Figure 11: training-time breakdown per model x config.
pub fn fig11(root: &Path, batches: u64) -> anyhow::Result<Report> {
    let mut r = Report::new(Experiment::Fig11);
    writeln!(r.body, "=== Figure 11: training time breakdown (per batch) ===")?;
    for model in PAPER_MODELS {
        let mut table = BreakdownTable::default();
        for sys in SystemConfig::ALL {
            let run = simulate(root, model, sys, batches)?;
            table.push(sys.name(), run.mean_breakdown());
            r.push(
                format!("{model}.{}.batch_ms", sys.name()),
                run.mean_batch_ns() / 1e6,
                "ms",
            );
        }
        writeln!(r.body, "\n[{model}]")?;
        r.body.push_str(&table.render(1e6, "ms"));
    }
    // paper cross-checks
    let mut sp_pcie_vs_cxld = Vec::new();
    let mut sp_cxlb_vs_cxl = Vec::new();
    for model in PAPER_MODELS {
        let pcie = simulate(root, model, SystemConfig::Pcie, batches)?.mean_batch_ns();
        let d = simulate(root, model, SystemConfig::CxlD, batches)?.mean_batch_ns();
        let b = simulate(root, model, SystemConfig::CxlB, batches)?.mean_batch_ns();
        let c = simulate(root, model, SystemConfig::Cxl, batches)?.mean_batch_ns();
        sp_pcie_vs_cxld.push(1.0 - d / pcie);
        sp_cxlb_vs_cxl.push(1.0 - c / b);
    }
    let cxld_red = 100.0 * sp_pcie_vs_cxld.iter().sum::<f64>() / sp_pcie_vs_cxld.len() as f64;
    let cxl_red = 100.0 * sp_cxlb_vs_cxl.iter().sum::<f64>() / sp_cxlb_vs_cxl.len() as f64;
    writeln!(
        r.body,
        "\nCXL-D vs PCIe mean training-time reduction: {cxld_red:.0}% (paper: 23%)"
    )?;
    writeln!(
        r.body,
        "CXL vs CXL-B mean training-time reduction:  {cxl_red:.0}% (paper: 14%)"
    )?;
    r.push("cxld_vs_pcie_reduction_pct", cxld_red, "%");
    r.push("cxl_vs_cxlb_reduction_pct", cxl_red, "%");
    Ok(r)
}

/// E2 / Figure 12: utilization timelines for CXL-D / CXL-B / CXL.
pub fn fig12(root: &Path, model: &str) -> anyhow::Result<Report> {
    let mut r = Report::new(Experiment::Fig12);
    writeln!(r.body, "=== Figure 12: resource utilization timelines [{model}] ===")?;
    for sys in [SystemConfig::CxlD, SystemConfig::CxlB, SystemConfig::Cxl] {
        let run = simulate(root, model, sys, 5)?;
        // steady-state window: batches 2..5
        let t0 = run.batch_times[..2].iter().sum::<u64>();
        let t1 = t0 + run.batch_times[2..].iter().sum::<u64>();
        writeln!(r.body, "\n--- {} (3 steady-state batches) ---", sys.name())?;
        r.body.push_str(&run.spans.render_timeline(t0, t1, 96));
        for lane in [
            crate::sim::Lane::Gpu,
            crate::sim::Lane::CompLogic,
            crate::sim::Lane::CkptLogic,
            crate::sim::Lane::Pmem,
        ] {
            let util = 100.0 * run.spans.utilization(lane, t0, t1);
            writeln!(r.body, "    {:<10} utilization {util:>5.1}%", lane.name())?;
            r.push(format!("{model}.{}.{}_util_pct", sys.name(), lane.name()), util, "%");
        }
    }
    Ok(r)
}

/// E3 / Figure 13: normalized energy per model x {SSD, PMEM, DRAM, CXL}.
pub fn fig13(root: &Path, batches: u64) -> anyhow::Result<Report> {
    let mut r = Report::new(Experiment::Fig13);
    writeln!(r.body, "=== Figure 13: energy (normalized to PMEM) ===")?;
    writeln!(
        r.body,
        "{:<8} {:>8} {:>8} {:>8} {:>8}   (paper shape: CXL lowest everywhere;",
        "model", "SSD", "PMEM", "DRAM", "CXL"
    )?;
    writeln!(
        r.body,
        "{:<8} {:>8} {:>8} {:>8} {:>8}    DRAM>PMEM on RM1/2, PMEM>DRAM on RM3/4)",
        "", "", "", "", ""
    )?;
    let mut cxl_savings = Vec::new();
    for model in PAPER_MODELS {
        let cfg = ModelConfig::load(root, model)?;
        let params = DeviceParams::load(root)?;
        let mut joules = BTreeMap::new();
        for sys in [
            SystemConfig::Ssd,
            SystemConfig::Pmem,
            SystemConfig::Dram,
            SystemConfig::Cxl,
        ] {
            let run = simulate(root, model, sys, batches)?;
            joules.insert(sys.name(), energy_of_run(&cfg, &params, &run).total());
        }
        let pmem = joules["PMEM"];
        writeln!(
            r.body,
            "{:<8} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            model,
            joules["SSD"] / pmem,
            1.0,
            joules["DRAM"] / pmem,
            joules["CXL"] / pmem
        )?;
        for (name, j) in &joules {
            r.push(format!("{model}.{name}.norm_energy"), j / pmem, "x");
        }
        cxl_savings.push(1.0 - joules["CXL"] / pmem);
    }
    let saving = 100.0 * cxl_savings.iter().sum::<f64>() / cxl_savings.len() as f64;
    writeln!(
        r.body,
        "\nCXL mean energy saving vs PMEM: {saving:.0}% (paper: 76%)"
    )?;
    r.push("cxl_energy_saving_pct", saving, "%");
    Ok(r)
}

/// E6 / headline: 5.2x training speedup + 76% energy saving vs PMEM.
pub fn headline(root: &Path, batches: u64) -> anyhow::Result<Report> {
    let mut r = Report::new(Experiment::Headline);
    writeln!(r.body, "=== Headline: CXL vs PMEM-based systems ===")?;
    let mut speedups = Vec::new();
    let mut savings = Vec::new();
    for model in PAPER_MODELS {
        let cfg = ModelConfig::load(root, model)?;
        let params = DeviceParams::load(root)?;
        let pmem = simulate(root, model, SystemConfig::Pmem, batches)?;
        let cxl = simulate(root, model, SystemConfig::Cxl, batches)?;
        let sp = pmem.mean_batch_ns() / cxl.mean_batch_ns();
        let e_pmem = energy_of_run(&cfg, &params, &pmem).total();
        let e_cxl = energy_of_run(&cfg, &params, &cxl).total();
        let saving = 1.0 - e_cxl / e_pmem;
        writeln!(
            r.body,
            "{model}: speedup {sp:.2}x, energy saving {:.0}%",
            100.0 * saving
        )?;
        r.push(format!("{model}.speedup"), sp, "x");
        r.push(format!("{model}.energy_saving_pct"), 100.0 * saving, "%");
        speedups.push(sp);
        savings.push(saving);
    }
    let geo = geomean(&speedups);
    let mean_saving = 100.0 * savings.iter().sum::<f64>() / savings.len() as f64;
    writeln!(
        r.body,
        "\ngeo-mean speedup: {geo:.2}x (paper: 5.2x)\nmean energy saving: {mean_saving:.0}% (paper: 76%)"
    )?;
    r.push("geomean_speedup", geo, "x");
    r.push("mean_energy_saving_pct", mean_saving, "%");
    Ok(r)
}

/// E7 / Fig 4-5 ablation: software vs hardware data movement, isolated.
pub fn ablate_movement(root: &Path, batches: u64) -> anyhow::Result<Report> {
    let mut r = Report::new(Experiment::AblateMovement);
    writeln!(r.body, "=== Ablation: data movement (PCIe=software vs CXL-D=hardware) ===")?;
    for model in PAPER_MODELS {
        let sw = simulate(root, model, SystemConfig::Pcie, batches)?;
        let hw = simulate(root, model, SystemConfig::CxlD, batches)?;
        let sw_bd = sw.mean_breakdown();
        let hw_bd = hw.mean_breakdown();
        let faster = 100.0 * (1.0 - hw.mean_batch_ns() / sw.mean_batch_ns());
        writeln!(
            r.body,
            "{model}: transfer {:>8.1}us -> {:>6.1}us; batch {:>8.1}us -> {:>8.1}us ({faster:.0}% faster)",
            sw_bd.transfer / 1e3,
            hw_bd.transfer / 1e3,
            sw.mean_batch_ns() / 1e3,
            hw.mean_batch_ns() / 1e3,
        )?;
        r.push(format!("{model}.reduction_pct"), faster, "%");
    }
    Ok(r)
}

/// E8 / Fig 8 ablation: RAW stalls with vs without relaxed lookup.
pub fn ablate_raw(root: &Path, batches: u64) -> anyhow::Result<Report> {
    let mut r = Report::new(Experiment::AblateRaw);
    writeln!(r.body, "=== Ablation: RAW (CXL-B dependent vs CXL relaxed lookup) ===")?;
    for model in ["rm1", "rm2", "rm3"] {
        let dep = simulate(root, model, SystemConfig::CxlB, batches)?;
        let rel = simulate(root, model, SystemConfig::Cxl, batches)?;
        writeln!(
            r.body,
            "{model}: raw-hits/batch {:>9.0} -> {:>3}; embedding {:>8.1}us -> {:>8.1}us",
            dep.raw_hits as f64 / batches as f64,
            rel.raw_hits,
            dep.mean_breakdown().embedding / 1e3,
            rel.mean_breakdown().embedding / 1e3,
        )?;
        r.push(
            format!("{model}.raw_hits_per_batch"),
            dep.raw_hits as f64 / batches as f64,
            "",
        );
        r.push(format!("{model}.relaxed_raw_hits"), rel.raw_hits as f64, "");
    }
    Ok(r)
}

/// Extension: multi-expander pooling sweep (CXL 3.0 multi-level
/// switching, paper §Related Work — the scalability edge over
/// RecNMP/TensorDIMM). Each pool size is its own [`Topology`]: tables
/// striped over k pooled CXL-MEM devices, one extra switch level (hop)
/// per doubling.
pub fn pooling(root: &Path, model: &str, batches: u64) -> anyhow::Result<Report> {
    // model/device/calibration/workload inputs are identical across pool
    // sizes: load them once and only swap the topology per run.
    let cfg = ModelConfig::load(root, model)?;
    let params = DeviceParams::load(root)?;
    let gpu = CxlGpu::from_params(&cfg, &params, root);
    let stats = crate::workload::Generator::average_stats(&cfg, 42, 8, 0.0);
    let mut r = Report::new(Experiment::Pooling);
    writeln!(r.body, "=== Extension: CXL-MEM pool scaling [{model}] ===")?;
    writeln!(r.body, "{:<10} {:>12} {:>9}", "expanders", "ms/batch", "speedup")?;
    let mut base = None;
    for k in [1usize, 2, 4, 8] {
        let extra_hops = (k as f64).log2() as usize; // one switch level per doubling
        let topo = Topology::builder(&format!("pooled-cxl-{k}x"))
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200)
            .expander_pool(k, extra_hops)
            .build()?;
        let t = PipelineSim::from_topology(&cfg, topo, &params, gpu, stats)?
            .run(batches)
            .mean_batch_ns();
        let b = *base.get_or_insert(t);
        writeln!(r.body, "{:<10} {:>12.3} {:>8.2}x", k, t / 1e6, b / t)?;
        r.push(format!("batch_ms_k{k}"), t / 1e6, "ms");
        r.push(format!("speedup_k{k}"), b / t, "x");
    }
    writeln!(r.body, "(embedding-bound models scale with the pool until the GPU floor)")?;
    Ok(r)
}

/// Extension: multi-GPU shard scaling sweep. Each lane count `k` stripes
/// the tables over `k` GPU lanes AND `k` pooled expanders (one extra
/// switch level per doubling) — the production recommendation-training
/// shape where shard-parallel lanes contend for the same DCOH and
/// expander pool. Also runs the two shipped sharded TOMLs end-to-end so
/// CI exercises the file-defined path.
pub fn shard_scaling(root: &Path, model: &str, batches: u64) -> anyhow::Result<Report> {
    let mut r = Report::new(Experiment::ShardScaling);
    writeln!(r.body, "=== Extension: multi-GPU shard scaling [{model}] ===")?;
    writeln!(r.body, "{:<8} {:>12} {:>9}", "lanes", "ms/batch", "speedup")?;
    let mut base = None;
    for k in [1usize, 2, 4, 8] {
        let extra_hops = (k as f64).log2() as usize; // one switch level per doubling
        let topo = Topology::builder(&format!("sharded-cxl-{k}x"))
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200)
            .expander_pool(k, extra_hops)
            .gpu_shards(k)
            .build()?;
        // simulate_topology owns the sharded-stats wiring, so the builder
        // leg and the shipped-TOML leg below stay numerically identical
        let t = simulate_topology(root, model, topo, batches)?.mean_batch_ns();
        let b = *base.get_or_insert(t);
        writeln!(r.body, "{:<8} {:>12.3} {:>8.2}x", k, t / 1e6, b / t)?;
        r.push(format!("batch_ms_s{k}"), t / 1e6, "ms");
        r.push(format!("speedup_s{k}"), b / t, "x");
    }
    writeln!(r.body, "\nshipped sharded topologies (configs/topologies/):")?;
    for name in ["sharded-cxl-2x", "sharded-cxl-4x"] {
        let topo = World::resolve(root, name)?.into_solo()?;
        let run = simulate_topology(root, model, topo, batches)?;
        writeln!(
            r.body,
            "{name}: {:.3} ms/batch, max MLP-log gap {}",
            run.mean_batch_ns() / 1e6,
            run.max_mlp_gap
        )?;
        r.push(format!("{name}.batch_ms"), run.mean_batch_ns() / 1e6, "ms");
    }
    writeln!(
        r.body,
        "(lanes split the lookup/update stripes; the exchange/reduce legs ride the switch)"
    )?;
    Ok(r)
}

/// Extension: hot/cold tiered-media sweep. Each `hot_frac` serves that
/// fraction of the hottest Zipf ranks from a volatile DRAM tier in front
/// of the pooled PMEM (docs/topology.md §Tiered media); `0.0` is the
/// untouched flagship schedule and the sweep's baseline. Also runs the
/// two shipped tiered TOMLs end-to-end so CI exercises the file-defined
/// path.
pub fn tier_sweep(root: &Path, model: &str, batches: u64) -> anyhow::Result<Report> {
    let mut r = Report::new(Experiment::TierSweep);
    writeln!(r.body, "=== Extension: hot/cold tiered media sweep [{model}] ===")?;
    writeln!(r.body, "{:<10} {:>12} {:>9}", "hot_frac", "ms/batch", "speedup")?;
    let mut base = None;
    for frac in [0.0, 0.05, 0.1, 0.3, 0.5] {
        let pct = (frac * 100.0).round() as u32;
        let b = Topology::builder(&format!("tiered-cxl-{pct}"))
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200);
        let b = if frac > 0.0 {
            b.tiered_media(MediaKind::Dram, frac).migrate_every(4)
        } else {
            b
        };
        let t = simulate_topology(root, model, b.build()?, batches)?.mean_batch_ns();
        let bse = *base.get_or_insert(t);
        let label = format!("{frac:.2}");
        writeln!(r.body, "{:<10} {:>12.3} {:>8.2}x", label, t / 1e6, bse / t)?;
        r.push(format!("batch_ms_h{pct}"), t / 1e6, "ms");
        r.push(format!("speedup_h{pct}"), bse / t, "x");
    }
    writeln!(r.body, "\nshipped tiered topologies (configs/topologies/):")?;
    for name in ["tiered-cxl-10", "tiered-cxl-30"] {
        let topo = World::resolve(root, name)?.into_solo()?;
        let run = simulate_topology(root, model, topo, batches)?;
        writeln!(
            r.body,
            "{name}: {:.3} ms/batch, max MLP-log gap {}",
            run.mean_batch_ns() / 1e6,
            run.max_mlp_gap
        )?;
        r.push(format!("{name}.batch_ms"), run.mean_batch_ns() / 1e6, "ms");
    }
    writeln!(
        r.body,
        "(the Zipf head moves to the volatile tier; the pool keeps the tail + undo log)"
    )?;
    Ok(r)
}

/// Extension: multi-tenant pool-interference sweep (docs/topology.md
/// §Multi-tenant pooled fabric). Tenant count x arbitration policy over
/// one shared pooled fabric: every tenant runs the flagship relaxed CXL
/// schedule against its own workload seed, interleaved by the
/// [`PoolArbiter`](crate::tenancy::PoolArbiter). Reports per-tenant
/// throughput, the worst p99 pool stall, and Jain's fairness index per
/// cell, then runs the two shipped `multi-tenant-*.toml` sets end-to-end
/// so CI exercises the file-defined path.
pub fn tenant_interference(root: &Path, model: &str, batches: u64) -> anyhow::Result<Report> {
    use crate::tenancy::{
        jain_fairness, MultiTenantRun, MultiTenantSim, QosPolicy, TenantSet, TenantSpec,
    };

    let build_set = |n: usize, policy: QosPolicy| -> TenantSet {
        let tenants = (0..n)
            .map(|i| TenantSpec {
                name: format!("t{i}"),
                model: model.to_string(),
                topology: Topology::from_system(SystemConfig::Cxl),
                seed: 42 + i as u64,
                // weighted cells give tenant 0 the production share
                weight: if i == 0 { 4 } else { 1 },
                serve: None,
            })
            .collect();
        TenantSet {
            name: format!("interf-{n}x-{}", policy.name()),
            // solo runs keep the paper's depth-1 switch; shared runs pay
            // one extra level for the pooling tree
            fabric_levels: if n == 1 { 1 } else { 2 },
            redundancy: 0,
            policy,
            tenants,
            faults: Vec::new(),
        }
    };
    let summarize = |run: &MultiTenantRun| -> (f64, f64, f64) {
        let thr: Vec<f64> = run.tenants.iter().map(|t| t.throughput_batches_per_s()).collect();
        let agg: f64 = thr.iter().sum();
        let fair = jain_fairness(&thr);
        let p99 = run
            .tenants
            .iter()
            .map(|t| t.p99_stall_ns())
            .fold(0.0f64, f64::max);
        (agg, fair, p99)
    };

    let mut r = Report::new(Experiment::TenantInterference);
    writeln!(r.body, "=== Extension: multi-tenant pool interference [{model}] ===")?;
    writeln!(
        r.body,
        "{:<9} {:<16} {:>14} {:>9} {:>14}",
        "tenants", "policy", "agg batches/s", "fairness", "p99 stall (ms)"
    )?;
    for n in [1usize, 2, 4] {
        for policy in [
            QosPolicy::FairShare,
            QosPolicy::Weighted,
            QosPolicy::StrictPriority,
        ] {
            if n == 1 && policy != QosPolicy::FairShare {
                continue; // one tenant: every policy degenerates to solo
            }
            let set = build_set(n, policy);
            let run = MultiTenantSim::new(root, &set)?.run(batches);
            let (agg, fair, p99) = summarize(&run);
            writeln!(
                r.body,
                "{:<9} {:<16} {:>14.2} {:>9.3} {:>14.3}",
                n,
                policy.name(),
                agg,
                fair,
                p99 / 1e6
            )?;
            let cell = format!("t{n}.{}", policy.name());
            r.push(format!("{cell}.agg_batches_per_s"), agg, "1/s");
            r.push(format!("{cell}.fairness"), fair, "");
            r.push(format!("{cell}.p99_stall_ms"), p99 / 1e6, "ms");
            for t in &run.tenants {
                r.push(
                    format!("{cell}.{}.batch_ms", t.name),
                    t.result.mean_batch_ns() / 1e6,
                    "ms",
                );
            }
        }
    }
    writeln!(r.body, "\nshipped tenant sets (configs/topologies/):")?;
    for name in ["multi-tenant-2", "multi-tenant-4"] {
        let set = World::resolve(root, name)?.into_tenants()?;
        let run = MultiTenantSim::new(root, &set)?.run(batches);
        let (agg, fair, p99) = summarize(&run);
        let link_gb: f64 = run.links.iter().map(|(_, l)| l.bytes as f64).sum::<f64>() / 1e9;
        // per-link utilization over the set's wall clock (slowest tenant)
        let wall = run
            .tenants
            .iter()
            .map(|t| t.result.total_time)
            .max()
            .unwrap_or(1)
            .max(1);
        r.metrics.register_links(name, &run.links, wall);
        writeln!(
            r.body,
            "{name}: {} tenants, {} fabric levels, {agg:.2} agg batches/s, \
             fairness {fair:.3}, p99 stall {:.3} ms, {link_gb:.2} GB fabric-link traffic",
            run.tenants.len(),
            run.levels,
            p99 / 1e6
        )?;
        r.push(format!("{name}.agg_batches_per_s"), agg, "1/s");
        r.push(format!("{name}.fairness"), fair, "");
        r.push(format!("{name}.fabric_link_gb"), link_gb, "GB");
        for t in &run.tenants {
            r.push(
                format!("{name}.{}.batch_ms", t.name),
                t.result.mean_batch_ns() / 1e6,
                "ms",
            );
        }
    }
    writeln!(
        r.body,
        "(the pool serialises cross-tenant traffic; the policy shapes who absorbs the stalls)"
    )?;
    Ok(r)
}

/// Extension: online inference serving sweep (docs/topology.md §Online
/// serving). Three legs: (1) standalone open-loop rate x batching-policy
/// sweep over the flagship CXL schedule, reporting p50/p99/p999 request
/// latency; (2) tail amplification — the same server tenant isolated vs
/// co-located with a trainer through the pool arbiter, p99 ratio; (3)
/// the two shipped `serve-mixed-*.toml` mixed-tenancy sets end-to-end
/// with per-link fabric utilization, so CI exercises the file-defined
/// path.
pub fn serve_latency(root: &Path, model: &str, batches: u64) -> anyhow::Result<Report> {
    use crate::serve::{BatchPolicy, ServeConfig, ServingSim, TraceShape};
    use crate::tenancy::{MultiTenantSim, QosPolicy, TenantSet, TenantSpec};

    // serving batches are far shorter than training batches: scale the
    // bench knob up so the percentiles have some mass behind them
    let serve_batches = (batches * 4).max(8);
    let mut r = Report::new(Experiment::ServeLatency);
    writeln!(r.body, "=== Extension: online serving latency [{model}] ===")?;
    writeln!(
        r.body,
        "{:<9} {:<16} {:>9} {:>9} {:>9} {:>12}",
        "rate/s", "batch policy", "p50 ms", "p99 ms", "p999 ms", "req/s served"
    )?;
    for rate in [1_000u64, 4_000, 16_000] {
        for policy in [
            BatchPolicy {
                max_batch: 8,
                max_wait_us: 100,
            },
            BatchPolicy {
                max_batch: 64,
                max_wait_us: 1000,
            },
        ] {
            let sc = ServeConfig {
                rate_per_s: rate as f64,
                policy,
                trace: TraceShape::Steady,
            };
            let topo = Topology::from_system(SystemConfig::Cxl);
            let run = ServingSim::for_model(root, model, topo, 42, &sc)?.run(serve_batches);
            let h = &run.stats.latency;
            let served = run.stats.requests as f64 * 1e9 / run.result.total_time.max(1) as f64;
            let pname = format!("{}x{}us", policy.max_batch, policy.max_wait_us);
            writeln!(
                r.body,
                "{:<9} {:<16} {:>9.3} {:>9.3} {:>9.3} {:>12.0}",
                rate,
                pname,
                h.p50() as f64 / 1e6,
                h.p99() as f64 / 1e6,
                h.p999() as f64 / 1e6,
                served
            )?;
            let cell = format!("r{rate}.b{}w{}", policy.max_batch, policy.max_wait_us);
            r.metrics.register_latency_ms(&cell, h);
            r.push(format!("{cell}.req_per_s"), served, "1/s");
        }
    }

    // tail amplification: the identical server tenant (same seed, same
    // arrival stream) isolated vs sharing the pool with a trainer — the
    // charged trainer pool occupancy can only delay serving batches, so
    // the ratio is >= 1 by construction
    let server = |tenants: Vec<TenantSpec>| TenantSet {
        name: "serve-amp".into(),
        fabric_levels: 1,
        redundancy: 0,
        policy: QosPolicy::FairShare,
        tenants,
        faults: Vec::new(),
    };
    let frontend = TenantSpec {
        name: "frontend".into(),
        model: model.to_string(),
        topology: Topology::from_system(SystemConfig::Cxl),
        seed: 42,
        weight: 1,
        serve: Some(ServeConfig {
            rate_per_s: 4_000.0,
            policy: BatchPolicy::default(),
            trace: TraceShape::Steady,
        }),
    };
    let trainer = TenantSpec {
        name: "trainer".into(),
        model: model.to_string(),
        topology: Topology::from_system(SystemConfig::Cxl),
        seed: 43,
        weight: 1,
        serve: None,
    };
    let iso = MultiTenantSim::new(root, &server(vec![frontend.clone()]))?.run(serve_batches);
    let mix = MultiTenantSim::new(root, &server(vec![frontend, trainer]))?.run(serve_batches);
    let iso_s = iso.tenants[0].serve.as_ref().expect("server tenant");
    let mix_s = mix.tenants[0].serve.as_ref().expect("server tenant");
    let amp = mix_s.latency.p99() as f64 / (iso_s.latency.p99() as f64).max(1.0);
    writeln!(
        r.body,
        "\ntail amplification (p99 co-located with a trainer / p99 isolated, rate 4000/s):\n\
         isolated {:.3} ms -> co-located {:.3} ms = {amp:.2}x; \
         served embeddings {:.1} trainer batches stale on average",
        iso_s.latency.p99() as f64 / 1e6,
        mix_s.latency.p99() as f64 / 1e6,
        mix_s.staleness.mean()
    )?;
    r.push("isolated_p99_ms", iso_s.latency.p99() as f64 / 1e6, "ms");
    r.push("colocated_p99_ms", mix_s.latency.p99() as f64 / 1e6, "ms");
    r.push("tail_amplification", amp, "x");
    r.push("staleness_batches", mix_s.staleness.mean(), "batches");

    writeln!(r.body, "\nshipped mixed-tenancy sets (configs/topologies/):")?;
    for name in ["serve-mixed-2", "serve-mixed-4"] {
        let set = World::resolve(root, name)?.into_tenants()?;
        let run = MultiTenantSim::new(root, &set)?.run(serve_batches);
        let wall = run
            .tenants
            .iter()
            .map(|t| t.result.total_time)
            .max()
            .unwrap_or(1)
            .max(1);
        for t in &run.tenants {
            match &t.serve {
                Some(s) => {
                    let p99 = s.latency.p99() as f64 / 1e6;
                    let served = s.requests as f64 * 1e9 / t.result.total_time.max(1) as f64;
                    writeln!(
                        r.body,
                        "{name}/{}: server, p99 {p99:.3} ms, {served:.0} req/s, \
                         staleness {:.1} batches",
                        t.name,
                        s.staleness.mean()
                    )?;
                    r.push(format!("{name}.{}.p99_ms", t.name), p99, "ms");
                    r.push(format!("{name}.{}.req_per_s", t.name), served, "1/s");
                    r.push(
                        format!("{name}.{}.staleness_batches", t.name),
                        s.staleness.mean(),
                        "batches",
                    );
                }
                None => {
                    writeln!(
                        r.body,
                        "{name}/{}: trainer, {:.3} ms/batch",
                        t.name,
                        t.result.mean_batch_ns() / 1e6
                    )?;
                    r.push(
                        format!("{name}.{}.batch_ms", t.name),
                        t.result.mean_batch_ns() / 1e6,
                        "ms",
                    );
                }
            }
        }
        r.metrics.register_links(name, &run.links, wall);
    }
    writeln!(
        r.body,
        "(open-loop arrivals: a backlogged server pays queueing delay in its own tail)"
    )?;
    Ok(r)
}

/// Extension: discrete-event engine throughput (docs/engine.md). One
/// 64-tenant fleet — every tenant running the 8-way sharded pooled
/// flagship schedule against its own workload seed — simulated to
/// completion at worker counts {1, 2, 4}. Reports wall time and
/// batches-simulated/sec per worker count, *asserts* the engine's
/// determinism contract (identical result fingerprints at every worker
/// count), and writes the report JSON to `BENCH_engine.json` at the
/// repo root for the CI bench-smoke gate.
pub fn engine_throughput(root: &Path, batches: u64) -> anyhow::Result<Report> {
    engine_fleet(root, batches, 64, true)
}

/// [`engine_throughput`] with the fleet size as a knob (tests shrink it)
/// and the `BENCH_engine.json` side effect made optional.
fn engine_fleet(
    root: &Path,
    batches: u64,
    n_tenants: usize,
    write_json: bool,
) -> anyhow::Result<Report> {
    use crate::tenancy::{MultiTenantSim, QosPolicy, TenantSet, TenantSpec};

    const SHARDS: usize = 8;
    let tenants = (0..n_tenants)
        .map(|i| -> anyhow::Result<TenantSpec> {
            Ok(TenantSpec {
                name: format!("t{i}"),
                model: "rm_mini".to_string(),
                // the shard_scaling k=8 shape: one switch level per pool
                // doubling, lanes striped over the pooled expanders
                topology: Topology::builder(&format!("engine-shard-{i}"))
                    .near_data()
                    .hw_movement()
                    .checkpoint(CkptMode::Relaxed)
                    .relaxed_lookup()
                    .max_mlp_log_gap(200)
                    .expander_pool(SHARDS, 3)
                    .gpu_shards(SHARDS)
                    .build()?,
                seed: 42 + i as u64,
                weight: 1,
                serve: None,
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let set = TenantSet {
        name: format!("engine-fleet-{n_tenants}x{SHARDS}"),
        fabric_levels: 3,
        redundancy: 0,
        policy: QosPolicy::FairShare,
        tenants,
        faults: Vec::new(),
    };

    let mut r = Report::new(Experiment::EngineThroughput);
    writeln!(
        r.body,
        "=== Extension: engine throughput ({n_tenants} tenants x {SHARDS} shards) ==="
    )?;
    writeln!(r.body, "{:<9} {:>12} {:>16}", "workers", "wall ms", "batches/s")?;
    r.push("tenants", n_tenants as f64, "");
    r.push("shards", SHARDS as f64, "");
    r.push("batches", batches as f64, "");
    let total_batches = batches as f64 * n_tenants as f64;
    let mut fp_base = None;
    for workers in [1usize, 2, 4] {
        let sim = MultiTenantSim::new(root, &set)?.with_workers(workers);
        let t0 = std::time::Instant::now();
        let run = sim.run(batches);
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        let fp = fingerprint(&run);
        let base = *fp_base.get_or_insert(fp);
        anyhow::ensure!(
            fp == base,
            "engine determinism broken: the {workers}-worker run diverged from \
             the 1-worker run (fingerprint {fp:#018x} != {base:#018x})"
        );
        writeln!(
            r.body,
            "{:<9} {:>12.1} {:>16.0}",
            workers,
            dt * 1e3,
            total_batches / dt
        )?;
        r.push(format!("wall_ms_w{workers}"), dt * 1e3, "ms");
        r.push(format!("batches_per_s_w{workers}"), total_batches / dt, "1/s");
    }
    r.push("determinism_checked", 1.0, "");
    writeln!(
        r.body,
        "(identical result fingerprints at every worker count: the round merge \
         is deterministic)"
    )?;
    if write_json {
        write_bench_json(&mut r, root, "BENCH_engine.json")?;
    }
    Ok(r)
}

/// Extension: fabric fault sweep (docs/fabric-faults.md). Every
/// [`FaultKind`](crate::sim::fabric::FaultKind) x redundancy {0, 1} x
/// checkpoint mode (the CXL-D/CXL-B/CXL ladder) injected into a
/// two-tenant pooled pair, each cell compared against its fault-free
/// twin: degraded-throughput ratio, time-to-recover, and the measured
/// blast radius. Then both shipped `multi-tenant-*.toml` sets take a
/// canonical expander loss end-to-end, with the per-link counters
/// (including the degraded-mode share) rendered into the body.
pub fn fault_sweep(root: &Path, batches: u64) -> anyhow::Result<Report> {
    use crate::sim::fabric::FaultKind;
    use crate::telemetry::render_links;
    use crate::tenancy::{FaultPlan, MultiTenantRun, MultiTenantSim, QosPolicy, TenantSet, TenantSpec};

    // the canonical schedule: strike while round 1 is about to open,
    // repair before round 3 — two full outage rounds, early enough that
    // even the smoke run (`--batches 6`) sees the whole cycle
    let plan_of = |kind: FaultKind| FaultPlan {
        kind,
        tenant: 0,
        level: None,
        inject_round: 1,
        repair_round: 3,
    };
    let pair = |sys: SystemConfig, red: u32, faults: Vec<FaultPlan>| -> TenantSet {
        let tenants = (0..2)
            .map(|i| TenantSpec {
                name: format!("t{i}"),
                model: "rm_mini".to_string(),
                topology: Topology::from_system(sys),
                seed: 42 + i as u64,
                weight: 1,
                serve: None,
            })
            .collect();
        TenantSet {
            name: format!("fault-{}-r{red}", sys.name()),
            fabric_levels: 2,
            redundancy: red,
            policy: QosPolicy::FairShare,
            tenants,
            faults,
        }
    };
    let agg = |run: &MultiTenantRun| -> f64 {
        run.tenants.iter().map(|t| t.throughput_batches_per_s()).sum()
    };
    // ns the set as a whole lost to the fault: degraded-edge penalties,
    // re-entry stalls, and torn-row replay, summed over every tenant
    let ttr_ms = |run: &MultiTenantRun| -> f64 {
        run.tenants
            .iter()
            .map(|t| (t.fault_stall_ns + t.fault_recovery_ns) as f64)
            .sum::<f64>()
            / 1e6
    };

    const LADDER: [SystemConfig; 3] = [SystemConfig::CxlD, SystemConfig::CxlB, SystemConfig::Cxl];
    let ckpt_of = |sys: SystemConfig| match sys {
        SystemConfig::CxlB => "batch-aware",
        SystemConfig::Cxl => "relaxed",
        _ => "redo",
    };
    // spare lanes are invisible until a fault consumes one, so one
    // fault-free twin per checkpoint mode covers every grid cell
    let mut clean_agg = Vec::new();
    for sys in LADDER {
        let clean = MultiTenantSim::new(root, &pair(sys, 0, Vec::new()))?.run(batches);
        clean_agg.push(agg(&clean));
    }

    let mut r = Report::new(Experiment::FaultSweep);
    writeln!(r.body, "=== Extension: fabric fault sweep [rm_mini, 2 tenants] ===")?;
    writeln!(
        r.body,
        "{:<14} {:<5} {:<12} {:>10} {:>14} {:>7}",
        "fault", "red", "ckpt", "thr ratio", "recover (ms)", "blast"
    )?;
    for kind in FaultKind::ALL {
        for red in [0u32, 1] {
            for (si, sys) in LADDER.into_iter().enumerate() {
                let ckpt = ckpt_of(sys);
                let faulted =
                    MultiTenantSim::new(root, &pair(sys, red, vec![plan_of(kind)]))?.run(batches);
                let ratio = agg(&faulted) / clean_agg[si].max(f64::MIN_POSITIVE);
                let ttr = ttr_ms(&faulted);
                let blast = faulted.faults[0].blast.len();
                anyhow::ensure!(
                    ratio > 0.0 && ratio <= 1.0 + 1e-9,
                    "fault-sweep {}/{red}/{ckpt}: a faulted run out-ran its \
                     fault-free twin (ratio {ratio})",
                    kind.name()
                );
                let absorbed = kind == FaultKind::LinkDown && red > 0;
                anyhow::ensure!(
                    if absorbed { blast == 0 } else { blast == 1 },
                    "fault-sweep {}/{red}/{ckpt}: blast radius {blast} (a leaf-path \
                     fault must tear exactly the victim unless spare lanes absorb it)",
                    kind.name()
                );
                if kind.tears_data() {
                    anyhow::ensure!(
                        faulted.tenants[0].fault_recovery_ns > 0,
                        "fault-sweep expander-lost/{red}/{ckpt}: the victim never \
                         replayed its undo slice"
                    );
                }
                writeln!(
                    r.body,
                    "{:<14} {:<5} {:<12} {:>10.4} {:>14.3} {:>7}",
                    kind.name(),
                    red,
                    ckpt,
                    ratio,
                    ttr,
                    blast
                )?;
                let cell = format!("{}.r{red}.{ckpt}", kind.name());
                r.push(format!("{cell}.degraded_throughput_ratio"), ratio, "");
                r.push(format!("{cell}.time_to_recover_ms"), ttr, "ms");
                r.push(format!("{cell}.blast_tenants"), blast as f64, "");
            }
        }
    }

    writeln!(
        r.body,
        "\nshipped tenant sets under a canonical expander loss (configs/topologies/):"
    )?;
    for name in ["multi-tenant-2", "multi-tenant-4"] {
        let clean_set = World::resolve(root, name)?.into_tenants()?;
        let mut faulted_set = World::resolve(root, name)?.into_tenants()?;
        faulted_set.faults.push(plan_of(FaultKind::ExpanderLost));
        let clean = MultiTenantSim::new(root, &clean_set)?.run(batches);
        let faulted = MultiTenantSim::new(root, &faulted_set)?.run(batches);
        let ratio = agg(&faulted) / agg(&clean).max(f64::MIN_POSITIVE);
        let ttr = ttr_ms(&faulted);
        let blast = faulted.faults[0].blast.len();
        writeln!(
            r.body,
            "{name}: expander under '{}' lost rounds 1..3, thr ratio {ratio:.4}, \
             recover {ttr:.3} ms, blast {blast} tenant(s)",
            faulted.tenants[0].name
        )?;
        let wall = faulted
            .tenants
            .iter()
            .map(|t| t.result.total_time)
            .max()
            .unwrap_or(1)
            .max(1);
        r.body.push_str(&render_links(&faulted.links, wall));
        r.metrics.register_links(name, &faulted.links, wall);
        r.push(format!("{name}.degraded_throughput_ratio"), ratio, "");
        r.push(format!("{name}.time_to_recover_ms"), ttr, "ms");
        r.push(format!("{name}.blast_tenants"), blast as f64, "");
    }
    writeln!(
        r.body,
        "(redundant lanes absorb link faults into degraded-mode occupancy; \
         everything else stalls exactly its blast radius until repair)"
    )?;
    Ok(r)
}

/// FNV-1a over every scheduling-visible number a multi-tenant run
/// produces — the equality the engine's determinism contract
/// (docs/engine.md) promises across worker counts.
fn fingerprint(run: &crate::tenancy::MultiTenantRun) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for t in &run.tenants {
        mix(t.result.total_time);
        for &bt in &t.result.batch_times {
            mix(bt);
        }
        for &s in &t.stalls {
            mix(s);
        }
        mix(t.pool_busy_ns);
        mix(t.batches);
        mix(t.recoveries);
        mix(t.stalled_rounds);
        mix(t.fault_stall_ns);
        mix(t.fault_recovery_ns);
    }
    for (name, l) in &run.links {
        mix(name.len() as u64);
        mix(l.bytes);
        mix(l.busy_ns);
        mix(l.degraded_ns);
    }
    for f in &run.faults {
        mix(f.plan.kind as u64);
        mix(f.plan.tenant as u64);
        mix(f.plan.inject_round);
        mix(f.plan.repair_round);
        for &t in &f.blast {
            mix(t as u64);
        }
    }
    h
}

/// E4 / Figure 9a: accuracy vs embedding/MLP-log batch gap (real training).
pub fn fig9a(root: &Path, gaps: &[u64]) -> anyhow::Result<Report> {
    use crate::train::failure;
    let cfg = ModelConfig::load(root, "rm_mini")?;
    let mut r = Report::new(Experiment::Fig9a);
    writeln!(r.body, "=== Figure 9a: accuracy vs MLP-log batch gap (rm_mini, real numerics) ===")?;
    let (base_loss, base_acc) = failure::run_no_crash_baseline(root, &cfg, 7, 400, 16)?;
    writeln!(r.body, "no-crash baseline: loss {base_loss:.4} acc {base_acc:.4}")?;
    r.push("baseline_acc", base_acc, "");
    for &gap in gaps {
        let res = failure::run_gap_experiment(root, &cfg, 7, 200, 200, gap, 16)?;
        writeln!(
            r.body,
            "gap {:>4}: recovered@{:>3} observed-gap {:>3} loss {:.4} acc {:.4} (delta {:+.4})",
            gap,
            res.recovered_from,
            res.mlp_gap_observed,
            res.loss,
            res.accuracy,
            res.accuracy - base_acc
        )?;
        r.push(format!("gap{gap}.acc_delta"), res.accuracy - base_acc, "");
    }
    writeln!(r.body, "(paper: degradation within business tolerance up to gaps of hundreds)")?;
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    #[test]
    fn fig11_report_renders_and_carries_metrics() {
        let root = repo_root();
        let r = fig11(&root, 6).unwrap();
        assert!(r.body.contains("[rm1]") && r.body.contains("[rm4]"));
        assert!(r.body.contains("CXL-D vs PCIe"));
        // typed metrics replace string scraping
        assert!(r.metric("rm1.CXL.batch_ms").unwrap() > 0.0);
        assert!(r.metric("cxld_vs_pcie_reduction_pct").is_some());
        assert!(r.metric("no-such-key").is_none());
    }

    #[test]
    fn fig13_report_has_all_rows() {
        let root = repo_root();
        let r = fig13(&root, 6).unwrap();
        for m in PAPER_MODELS {
            assert!(r.body.contains(m), "missing {m}: {}", r.body);
            assert!((r.metric(&format!("{m}.PMEM.norm_energy")).unwrap() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn experiment_names_round_trip() {
        for e in Experiment::ALL {
            assert_eq!(e.name().parse::<Experiment>(), Ok(e));
        }
        let err = "fig99".parse::<Experiment>().unwrap_err();
        assert!(err.to_string().contains("fig11"), "{err}");
    }

    #[test]
    fn shard_scaling_report_runs_end_to_end() {
        let root = repo_root();
        let r = shard_scaling(&root, "rm_mini", 4).unwrap();
        r.ensure_finite().unwrap();
        assert!(r.metric("batch_ms_s1").unwrap() > 0.0);
        assert!(r.metric("speedup_s4").is_some());
        // the shipped sharded TOMLs run end-to-end through the Report
        assert!(r.metric("sharded-cxl-2x.batch_ms").unwrap() > 0.0);
        assert!(r.metric("sharded-cxl-4x.batch_ms").unwrap() > 0.0);
        assert!(r.body.contains("shard scaling"), "{}", r.body);
    }

    #[test]
    fn tier_sweep_report_runs_end_to_end() {
        let root = repo_root();
        let r = tier_sweep(&root, "rm_mini", 4).unwrap();
        r.ensure_finite().unwrap();
        assert!(r.metric("batch_ms_h0").unwrap() > 0.0);
        assert!(r.metric("batch_ms_h30").unwrap() > 0.0);
        assert!(r.metric("speedup_h50").is_some());
        // the shipped tiered TOMLs run end-to-end through the Report
        assert!(r.metric("tiered-cxl-10.batch_ms").unwrap() > 0.0);
        assert!(r.metric("tiered-cxl-30.batch_ms").unwrap() > 0.0);
        assert!(r.body.contains("tiered media sweep"), "{}", r.body);
    }

    #[test]
    fn tenant_interference_report_runs_end_to_end() {
        let root = repo_root();
        let r = tenant_interference(&root, "rm_mini", 4).unwrap();
        r.ensure_finite().unwrap();
        // the sweep cells
        assert!(r.metric("t1.fair-share.agg_batches_per_s").unwrap() > 0.0);
        assert!(r.metric("t2.weighted.fairness").is_some());
        assert!(r.metric("t4.strict-priority.p99_stall_ms").is_some());
        // one tenant: no co-tenant stall at all
        assert_eq!(r.metric("t1.fair-share.p99_stall_ms").unwrap(), 0.0);
        // sharing the pool (and its deeper fabric) can never be faster
        // than running alone; strictness on an embedding-bound model is
        // pinned by tenancy::tests::co_tenants_contend_for_the_pool
        assert!(
            r.metric("t2.fair-share.t0.batch_ms").unwrap()
                >= r.metric("t1.fair-share.t0.batch_ms").unwrap()
        );
        // strict priority shields tenant 0 at the expense of fairness
        assert!(
            r.metric("t2.strict-priority.fairness").unwrap()
                <= r.metric("t2.fair-share.fairness").unwrap() + 1e-9
        );
        // the shipped tenant sets run end-to-end through the Report
        assert!(r.metric("multi-tenant-2.agg_batches_per_s").unwrap() > 0.0);
        assert!(r.metric("multi-tenant-2.ranker.batch_ms").unwrap() > 0.0);
        assert!(r.metric("multi-tenant-4.fairness").unwrap() > 0.0);
        assert!(r.metric("multi-tenant-4.fabric_link_gb").unwrap() > 0.0);
        // per-link fabric utilization is reported for the shipped sets
        assert!(r.metric("multi-tenant-2.link.ranker-l1.util_pct").unwrap() > 0.0);
        assert!(r.metric("multi-tenant-2.link.ranker-l1.gb").unwrap() > 0.0);
        assert!(r.body.contains("pool interference"), "{}", r.body);
    }

    #[test]
    fn serve_latency_report_runs_end_to_end() {
        let root = repo_root();
        let r = serve_latency(&root, "rm_mini", 4).unwrap();
        r.ensure_finite().unwrap();
        // the standalone rate x policy sweep
        assert!(r.metric("r1000.b8w100.p50_ms").unwrap() > 0.0);
        assert!(r.metric("r16000.b64w1000.p999_ms").unwrap() > 0.0);
        assert!(
            r.metric("r4000.b8w100.p50_ms").unwrap() <= r.metric("r4000.b8w100.p99_ms").unwrap()
        );
        assert!(
            r.metric("r4000.b8w100.p99_ms").unwrap() <= r.metric("r4000.b8w100.p999_ms").unwrap()
        );
        // the acceptance bound: sharing the pool can only lengthen the tail
        assert!(r.metric("tail_amplification").unwrap() >= 1.0);
        // a co-located trainer makes the served embeddings measurably stale
        assert!(r.metric("staleness_batches").unwrap() > 0.0);
        // the shipped mixed sets run end-to-end: servers report latency,
        // trainers report batch time, and the fabric links report util
        assert!(r.metric("serve-mixed-2.frontend.p99_ms").unwrap() > 0.0);
        assert!(r.metric("serve-mixed-2.frontend.req_per_s").unwrap() > 0.0);
        assert!(r.metric("serve-mixed-2.ranker.batch_ms").unwrap() > 0.0);
        assert!(r.metric("serve-mixed-2.link.frontend-l1.util_pct").unwrap() > 0.0);
        assert!(r.metric("serve-mixed-4.mobile.p99_ms").unwrap() > 0.0);
        assert!(r.body.contains("online serving latency"), "{}", r.body);
    }

    #[test]
    fn engine_fleet_is_deterministic_across_worker_counts() {
        let root = repo_root();
        // a shrunk fleet: the in-driver fingerprint ensure! IS the
        // determinism assertion — it runs workers {1, 2, 4} internally
        let r = engine_fleet(&root, 2, 6, false).unwrap();
        r.ensure_finite().unwrap();
        assert_eq!(r.metric("determinism_checked").unwrap(), 1.0);
        assert_eq!(r.metric("tenants").unwrap(), 6.0);
        assert!(r.metric("batches_per_s_w1").unwrap() > 0.0);
        assert!(r.metric("batches_per_s_w4").unwrap() > 0.0);
        assert!(r.metric("wall_ms_w2").unwrap() > 0.0);
        assert!(r.body.contains("engine throughput"), "{}", r.body);
        // no side effect without the bench entry point's write flag
        assert!(!r.body.contains("wrote"), "{}", r.body);
    }

    #[test]
    fn fault_sweep_report_runs_end_to_end() {
        let root = repo_root();
        let r = fault_sweep(&root, 6).unwrap();
        r.ensure_finite().unwrap();
        // the grid: every FaultKind x redundancy x checkpoint mode
        for kind in ["link-down", "switch-down", "expander-lost"] {
            for red in [0, 1] {
                for ckpt in ["redo", "batch-aware", "relaxed"] {
                    let cell = format!("{kind}.r{red}.{ckpt}");
                    let ratio = r
                        .metric(&format!("{cell}.degraded_throughput_ratio"))
                        .unwrap_or_else(|| panic!("missing cell {cell}"));
                    assert!(ratio > 0.0 && ratio <= 1.0 + 1e-9, "{cell}: {ratio}");
                    assert!(r.metric(&format!("{cell}.time_to_recover_ms")).unwrap() >= 0.0);
                }
            }
        }
        // spare lanes absorb a link fault (degraded, no blast); nothing
        // absorbs a switch or expander fault on the victim's leaf path
        assert_eq!(r.metric("link-down.r1.relaxed.blast_tenants").unwrap(), 0.0);
        assert!(r.metric("link-down.r1.relaxed.time_to_recover_ms").unwrap() > 0.0);
        assert_eq!(r.metric("link-down.r0.relaxed.blast_tenants").unwrap(), 1.0);
        assert_eq!(r.metric("switch-down.r1.redo.blast_tenants").unwrap(), 1.0);
        assert_eq!(r.metric("expander-lost.r1.relaxed.blast_tenants").unwrap(), 1.0);
        // a torn victim pays a real replay
        assert!(r.metric("expander-lost.r0.redo.time_to_recover_ms").unwrap() > 0.0);
        // the shipped sets run end-to-end and the body carries the
        // degraded-mode link table
        assert_eq!(r.metric("multi-tenant-2.blast_tenants").unwrap(), 1.0);
        assert!(r.metric("multi-tenant-2.time_to_recover_ms").unwrap() > 0.0);
        assert!(r.metric("multi-tenant-4.degraded_throughput_ratio").unwrap() > 0.0);
        assert!(r.body.contains("fabric fault sweep"), "{}", r.body);
        assert!(r.body.contains("degraded ms"), "{}", r.body);
    }

    #[test]
    fn non_finite_metrics_are_rejected() {
        let mut r = Report::new(Experiment::ShardScaling);
        r.push("ok", 1.0, "x");
        assert!(r.ensure_finite().is_ok());
        r.push("bad_speedup", f64::NAN, "x");
        let err = r.ensure_finite().unwrap_err().to_string();
        assert!(err.contains("bad_speedup"), "{err}");
    }

    #[test]
    fn report_json_is_parseable() {
        let root = repo_root();
        let r = ablate_movement(&root, 4).unwrap();
        let text = r.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("experiment").and_then(|e| e.as_str()),
            Some("ablate-movement")
        );
        assert!(parsed
            .get("metrics")
            .and_then(|m| m.get("rm1.reduction_pct"))
            .and_then(|v| v.as_f64())
            .is_some());
    }
}
