#!/usr/bin/env bash
# Tier-1 verification: rust build+tests, python tests.
# Usage: scripts/check.sh [--rust-only|--python-only]
set -euo pipefail
cd "$(dirname "$0")/.."

want_rust=1
want_python=1
case "${1:-}" in
  --rust-only) want_python=0 ;;
  --python-only) want_rust=0 ;;
  "") ;;
  *) echo "usage: scripts/check.sh [--rust-only|--python-only]" >&2; exit 2 ;;
esac

status=0

# Build the rm_mini AOT artifacts when the python toolchain can (jax
# importable): the rust train::failure / runtime_e2e tests self-skip
# without them, so this is what turns them on in CI. Idempotent — aot.py
# fingerprints its sources and skips up-to-date artifacts. Only worth the
# compile time when the rust tier will actually run (cargo present).
if [ "$want_rust" = 1 ] && command -v cargo >/dev/null 2>&1; then
  if command -v python3 >/dev/null 2>&1 && python3 -c "import jax" >/dev/null 2>&1; then
    echo "== building rm_mini artifacts (python -m compile.aot) =="
    (cd python && python3 -m compile.aot --model rm_mini)
  else
    echo "!! jax not importable: skipping artifact build (artifact-gated rust tests will self-skip)" >&2
  fi
fi

if [ "$want_rust" = 1 ]; then
  if command -v cargo >/dev/null 2>&1; then
    echo "== cargo build --release =="
    cargo build --release
    echo "== cargo test -q =="
    cargo test -q
  else
    echo "!! cargo not found: skipping rust tier (install a rust toolchain)" >&2
    status=0 # informational skip; CI images provide the toolchain
  fi
fi

if [ "$want_python" = 1 ]; then
  if command -v python3 >/dev/null 2>&1; then
    echo "== python -m pytest python/tests -q =="
    python3 -m pytest python/tests -q
  else
    echo "!! python3 not found: skipping python tier" >&2
  fi
fi

exit "$status"
