"""Pallas kernels for the CXL-MEM *computing logic* (paper Fig. 3b/10).

The paper's CXL-MEM frontend contains adders/multipliers plus scratchpad
memory that perform embedding lookup (gather + sum-reduce) and embedding
update (SGD scatter) near the PMEM backend, one table striped per memory
channel. The decomposition here mirrors that hardware exactly:

  * the **memory controllers** move rows between the table and the
    computing logic — expressed as XLA gather/scatter on the (T, R, D)
    table, which the backend executes natively (and which a TPU would
    realise as HBM DMA);
  * the **computing logic** is the Pallas kernels: the adder tree that
    sum-reduces the L gathered rows per bag (`_bag_reduce_kernel`) and the
    multiplier array that forms the -lr-scaled per-row SGD deltas
    (`_sgd_delta_kernel`). One grid step per table <-> one computing-logic
    lane per PMEM channel; BlockSpec carries the channel-local tile
    through VMEM.

This split is also the performance-critical choice for the AOT artifacts:
interpret-mode Pallas materialises every BlockSpec block, so keeping the
(R, D) table *outside* the kernels turns two O(table) block copies per
grid step into O(batch) ones (see EXPERIMENTS.md §Perf — 17x on the
rm_e2e hot path).

Kernels are lowered with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); the grid is sequential in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_reduce_kernel(rows_ref, out_ref):
    """Adder tree: one grid step per table; sum L gathered rows per bag."""
    rows = rows_ref[0]  # (B, L, D) channel-local gathered rows
    out_ref[:, 0, :] = rows.sum(axis=1)


@jax.jit
def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """Sum-reduce embedding lookup. table (T,R,D), indices (T,B,L) -> (B,T,D)."""
    T, R, D = table.shape
    _, B, L = indices.shape
    # memory-controller path: gather the rows for each (table, bag, slot)
    rows = jax.vmap(lambda tbl_t, idx_t: jnp.take(tbl_t, idx_t.reshape(B * L), axis=0))(
        table, indices
    ).reshape(T, B, L, D)
    # computing-logic path: per-channel adder tree
    return pl.pallas_call(
        _bag_reduce_kernel,
        grid=(T,),
        in_specs=[pl.BlockSpec((1, B, L, D), lambda t: (t, 0, 0, 0))],
        out_specs=pl.BlockSpec((B, 1, D), lambda t: (0, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), table.dtype),
        interpret=True,
    )(rows)


@jax.jit
def gather_rows(table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """Memory-controller path only: the raw rows for each (table, bag, slot).

    table (T,R,D), indices (T,B,L) -> (T,B,L,D). No computing-logic
    reduction — this is the row readout the rust trainer uses to maintain
    its host mirror incrementally: after an update it downloads just the
    rows the batch touched, never the full table.
    """
    T, R, D = table.shape
    _, B, L = indices.shape
    return jax.vmap(lambda tbl_t, idx_t: jnp.take(tbl_t, idx_t.reshape(B * L), axis=0))(
        table, indices
    ).reshape(T, B, L, D)


def _sgd_delta_kernel(lr_ref, grad_ref, out_ref):
    """Multiplier array: form the -lr * grad row deltas for one table."""
    out_ref[0] = -lr_ref[0] * grad_ref[:, 0, :]


@jax.jit
def embedding_update(
    table: jnp.ndarray, indices: jnp.ndarray, grad: jnp.ndarray, lr: jnp.ndarray
) -> jnp.ndarray:
    """SGD scatter update. table (T,R,D), indices (T,B,L), grad (B,T,D), lr scalar.

    d(reduced)/d(row) is identity for a sum-bag, so every looked-up row
    receives its bag's gradient; duplicate indices accumulate (segment-sum
    semantics), matching ref.embedding_update.
    """
    T, R, D = table.shape
    _, B, L = indices.shape
    lr = jnp.asarray(lr, table.dtype).reshape(1)
    # computing logic: per-bag deltas
    deltas = pl.pallas_call(
        _sgd_delta_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1,), lambda t: (0,)),
            pl.BlockSpec((B, 1, D), lambda t: (0, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, B, D), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, B, D), table.dtype),
        interpret=True,
    )(lr, grad)
    # memory-controller path: scatter-add each bag's delta into every row
    # slot it looked up (duplicates accumulate)
    updates = jnp.broadcast_to(deltas[:, :, None, :], (T, B, L, D)).reshape(T, B * L, D)
    flat_idx = indices.reshape(T, B * L)
    return jax.vmap(lambda tbl_t, idx_t, upd_t: tbl_t.at[idx_t].add(upd_t))(
        table, flat_idx, updates
    )
