"""Model-zoo config loader.

configs/models/*.toml is the single source of truth shared by the Python
compile path (artifact shapes) and the rust coordinator (simulator +
runtime). Keep field names in sync with rust/src/config/model.rs.
"""

from __future__ import annotations

import dataclasses
import pathlib

try:
    import tomllib
except ModuleNotFoundError:  # Python < 3.11: fall back to the in-tree subset parser
    tomllib = None

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
MODELS_DIR = REPO_ROOT / "configs" / "models"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Shape of one DLRM variant (paper Table 3 row)."""

    name: str
    feature_dim: int
    num_dense: int
    num_tables: int
    rows_per_table: int
    lookups_per_table: int
    bottom_mlp: tuple[int, ...]  # hidden widths; input width = num_dense
    top_mlp: tuple[int, ...]  # hidden widths ending in 1
    batch_size: int
    lr: float

    @property
    def interaction_dim(self) -> int:
        """Width of the top-MLP input: concat(bottom-out, T reduced vectors)."""
        return self.bottom_mlp[-1] + self.num_tables * self.feature_dim

    @property
    def bottom_layers(self) -> list[tuple[int, int]]:
        dims = [self.num_dense, *self.bottom_mlp]
        return list(zip(dims[:-1], dims[1:]))

    @property
    def top_layers(self) -> list[tuple[int, int]]:
        dims = [self.interaction_dim, *self.top_mlp]
        return list(zip(dims[:-1], dims[1:]))

    @property
    def total_rows(self) -> int:
        return self.num_tables * self.rows_per_table

    def param_count(self) -> int:
        n = self.total_rows * self.feature_dim
        for i, o in self.bottom_layers + self.top_layers:
            n += i * o + o
        return n


def _strip_comment(line: str) -> str:
    in_str = False
    for i, c in enumerate(line):
        if c == '"':
            in_str = not in_str
        elif c == "#" and not in_str:
            return line[:i]
    return line


def _parse_value(s: str):
    if s.startswith('"') and s.endswith('"') and len(s) >= 2:
        return s[1:-1]
    if s == "true":
        return True
    if s == "false":
        return False
    if s.startswith("[") and s.endswith("]"):
        inner = s[1:-1].strip()
        if not inner:
            return []
        return [_parse_value(p.strip()) for p in inner.split(",")]
    clean = s.replace("_", "")
    try:
        return int(clean)
    except ValueError:
        return float(clean)


def _parse_mini(text: str) -> dict:
    """Minimal TOML subset parser (mirrors rust/src/util/tomlmini.rs):
    ``[table]`` headers, ``key = value`` with strings/ints/floats/bools and
    flat arrays, ``#`` comments. Enough for configs/**/*.toml."""
    doc: dict = {}
    table = doc
    for raw in text.splitlines():
        line = _strip_comment(raw).strip()
        if not line:
            continue
        if line.startswith("["):
            name = line[1:-1].strip()
            table = doc
            for part in name.split("."):
                table = table.setdefault(part, {})
            continue
        key, _, val = line.partition("=")
        table[key.strip()] = _parse_value(val.strip())
    return doc


def load(name: str) -> ModelConfig:
    path = MODELS_DIR / f"{name}.toml"
    if tomllib is not None:
        with open(path, "rb") as f:
            raw = tomllib.load(f)
    else:
        raw = _parse_mini(path.read_text())
    raw.pop("sim", None)  # simulator-only section, consumed by rust
    raw["bottom_mlp"] = tuple(raw["bottom_mlp"])
    raw["top_mlp"] = tuple(raw["top_mlp"])
    return ModelConfig(**raw)


def available() -> list[str]:
    return sorted(p.stem for p in MODELS_DIR.glob("*.toml"))
