//! CXL-GPU: a Type-2 GPU whose kernel times are *replayed* from real
//! measurements — the same methodology as the paper's prototype, which
//! replays per-batch MLP computation cycles extracted from an RTX 3090
//! into the Vortex GPGPU.
//!
//! Our measurements come from executing the AOT `bottom_mlp` / `top_mlp`
//! artifacts on the PJRT CPU client (`trainingcxl calibrate`), divided by
//! `gpu.speedup_vs_cpu`; a static fallback table ships in
//! `configs/devices/testbed.toml` so simulations run without PJRT.

use crate::config::device::{DeviceParams, MlpTimesUs};
use crate::config::ModelConfig;
use crate::sim::SimTime;

/// Per-batch MLP phase durations in ns.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CxlGpu {
    /// Bottom-MLP forward.
    pub bmlp_fwd: SimTime,
    /// Bottom-MLP backward (incl. weight update commit).
    pub bmlp_bwd: SimTime,
    /// Feature interaction + top-MLP forward.
    pub tmlp_fwd: SimTime,
    /// Top-MLP backward (gradients for interaction inputs).
    pub tmlp_bwd: SimTime,
    /// Bytes of MLP parameters resident on the GPU (the MLP log payload).
    pub mlp_param_bytes: u64,
}

impl CxlGpu {
    pub fn new(cfg: &ModelConfig, times_us: MlpTimesUs) -> CxlGpu {
        let ns = |us: f64| (us * 1000.0).ceil() as SimTime;
        CxlGpu {
            bmlp_fwd: ns(times_us[0]),
            bmlp_bwd: ns(times_us[1]),
            tmlp_fwd: ns(times_us[2]),
            tmlp_bwd: ns(times_us[3]),
            mlp_param_bytes: cfg.mlp_param_bytes(),
        }
    }

    pub fn from_params(cfg: &ModelConfig, p: &DeviceParams, root: &std::path::Path) -> CxlGpu {
        let times = p
            .mlp_times_us(root, &cfg.name)
            .unwrap_or_else(|| panic!("no MLP calibration for model '{}'", cfg.name));
        Self::new(cfg, times)
    }

    /// Interaction + top-MLP fwd+bwd as one GPU occupancy block (the
    /// window the relaxed checkpoint may steal CXL.cache cycles from —
    /// the GPU only answers MLP-log reads while it is busy here).
    pub fn tmlp_total(&self) -> SimTime {
        self.tmlp_fwd + self.tmlp_bwd
    }

    /// Whole-batch GPU busy time.
    pub fn gpu_busy(&self) -> SimTime {
        self.bmlp_fwd + self.bmlp_bwd + self.tmlp_total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    #[test]
    fn replay_times_scale_from_calibration() {
        let root = repo_root();
        let cfg = ModelConfig::load(&root, "rm1").unwrap();
        let p = DeviceParams::builtin_default();
        let gpu = CxlGpu::from_params(&cfg, &p, std::path::Path::new("/nonexistent"));
        assert_eq!(gpu.bmlp_fwd, 240_000); // 240us
        assert_eq!(gpu.tmlp_total(), (180 + 320) * 1000);
        assert_eq!(gpu.mlp_param_bytes, cfg.mlp_param_bytes());
    }

    #[test]
    fn mlp_intensive_models_have_longer_bmlp() {
        let root = repo_root();
        let p = DeviceParams::builtin_default();
        let np = std::path::Path::new("/nonexistent");
        let rm1 = CxlGpu::from_params(&ModelConfig::load(&root, "rm1").unwrap(), &p, np);
        let rm3 = CxlGpu::from_params(&ModelConfig::load(&root, "rm3").unwrap(), &p, np);
        let rm4 = CxlGpu::from_params(&ModelConfig::load(&root, "rm4").unwrap(), &p, np);
        assert!(rm3.bmlp_fwd > rm1.bmlp_fwd);
        assert!(rm4.bmlp_fwd > rm3.bmlp_fwd);
    }
}
