//! Device models: the CXL-MEM Type-2 expander (computing + checkpointing
//! logic, Fig 3b/10), the CXL-GPU (Vortex-style replay of measured MLP
//! times), and the host CPU software path the CXL configs eliminate.
//!
//! Devices are *timing oracles*: they own their parameters and MMIO-style
//! configuration state, and price operations against the media/link models
//! the scheduler passes in. The byte-accurate log regions used for real
//! crash-recovery live in [`crate::checkpoint`].

pub mod cxl_gpu;
pub mod cxl_mem;
pub mod host;

pub use cxl_gpu::CxlGpu;
pub use cxl_mem::{CxlMem, MmioRegs};
pub use host::HostCpu;
