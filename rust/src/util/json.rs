//! Minimal JSON reader for `artifacts/<model>/manifest.json`.
//!
//! Supports the full JSON value grammar minus exotic escapes (\uXXXX is
//! decoded for the BMP). Not a general-purpose serde replacement — just
//! enough to consume what `python/compile/aot.py` writes, with precise
//! error offsets for debugging a corrupted artifact dir.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{:?}", s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{:?}:{v}", k)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // copy raw utf-8 byte(s); input is a &str so it's valid
                    let start = self.pos - 1;
                    let ch_len = utf8_len(c);
                    self.pos = start + ch_len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"model":"rm_mini","config":{"lr":0.05,"bottom_mlp":[32,8]},
                      "exports":{"train_step":{"inputs":[{"shape":[4,128,8],"dtype":"float32"}]}}}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("model").unwrap().as_str(), Some("rm_mini"));
        assert_eq!(
            j.get("config").unwrap().get("lr").unwrap().as_f64(),
            Some(0.05)
        );
        let shape = j
            .get("exports")
            .and_then(|e| e.get("train_step"))
            .and_then(|t| t.get("inputs"))
            .and_then(|i| i.as_arr())
            .unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        let dims: Vec<usize> = shape.iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![4, 128, 8]);
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }
}
