//! The unified entry point for "a thing the simulator can run".
//!
//! Every TOML under `configs/topologies/` describes one of two worlds:
//! a **solo** fabric schedule ([`Topology`]) or a **multi-tenant set**
//! (`[[tenants]]` tables → [`TenantSet`]). Callers used to hand-route
//! between `Topology::from_doc` and `TenantSet::from_doc` by sniffing the
//! document themselves; [`World::load`] owns that dispatch now, and
//! [`World::resolve`] layers the CLI name rules on top (paper
//! system-config names → prebuilt topologies, anything else →
//! `configs/topologies/<name>.toml`). `main.rs` (including the
//! `trainingcxl trace` exporter, which runs either world class and ships
//! its [`TraceLog`](crate::telemetry::TraceLog) to Perfetto), the bench
//! drivers, and `analysis::analyze_repo` all come through here.
//!
//! Errors are typed ([`WorldError`]) so a caller that needs exactly one
//! class — [`World::into_solo`] / [`World::into_tenants`] — can say which
//! world it got instead in the message.

use crate::config::sysconfig::SystemConfig;
use crate::sim::topology::{Topology, TopologyError};
use crate::tenancy::TenantSet;
use crate::util::tomlmini::Doc;
use std::path::{Path, PathBuf};

/// One runnable world: a solo fabric schedule or a tenant set sharing a
/// pooled fabric.
#[derive(Clone, Debug)]
pub enum World {
    Solo(Topology),
    Tenants(TenantSet),
}

#[derive(Debug, thiserror::Error)]
pub enum WorldError {
    #[error("world file {path}: {msg}")]
    Io { path: PathBuf, msg: String },
    /// The document is a solo topology and failed topology validation.
    #[error(transparent)]
    Topology(#[from] TopologyError),
    /// The document declares `[[tenants]]` and failed tenant-set
    /// validation (message wrapped: `TenantSet::from_doc` reports
    /// through `anyhow`).
    #[error("tenant set {path}: {msg}")]
    Tenants { path: PathBuf, msg: String },
    #[error("unknown topology or tenant set '{name}' (available: {available})")]
    Unknown { name: String, available: String },
    #[error(
        "world '{name}' is a multi-tenant set; this entry point needs a solo \
         topology (tenant sets run through `MultiTenantSim` — e.g. `bench \
         tenant-interference`)"
    )]
    NotSolo { name: String },
    #[error(
        "world '{name}' is a solo topology; this entry point needs a \
         `[[tenants]]` set"
    )]
    NotTenants { name: String },
}

impl World {
    /// Load a world from a TOML file: documents with one or more
    /// `[[tenants]]` tables parse as a [`TenantSet`], everything else as
    /// a [`Topology`]. `root` anchors the tenant topologies' own lookups.
    pub fn load(root: &Path, path: &Path) -> Result<World, WorldError> {
        let doc = Doc::load(path).map_err(|e| WorldError::Io {
            path: path.to_path_buf(),
            msg: format!("{e:#}"),
        })?;
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("world")
            .to_string();
        World::from_doc(root, &name, &doc).map_err(|e| match e {
            // re-anchor doc-level tenant errors on the file that held them
            WorldError::Tenants { msg, .. } => WorldError::Tenants {
                path: path.to_path_buf(),
                msg,
            },
            other => other,
        })
    }

    /// [`World::load`] for an already-parsed document.
    pub fn from_doc(root: &Path, name: &str, doc: &Doc) -> Result<World, WorldError> {
        if doc.array_len("tenants") > 0 {
            TenantSet::from_doc(root, name, doc)
                .map(World::Tenants)
                .map_err(|e| WorldError::Tenants {
                    path: PathBuf::from(format!("{name}.toml")),
                    msg: format!("{e:#}"),
                })
        } else {
            Topology::from_doc(name, doc)
                .map(World::Solo)
                .map_err(WorldError::from)
        }
    }

    /// The CLI name rules: paper system-config names (`ssd`, `pmem`,
    /// `pcie`, `cxl-d`, `cxl-b`, `cxl`, `dram`) resolve to the prebuilt
    /// solo topologies; anything else loads
    /// `configs/topologies/<name>.toml` strictly. An unknown name lists
    /// what IS available.
    pub fn resolve(root: &Path, name: &str) -> Result<World, WorldError> {
        if let Ok(sys) = name.parse::<SystemConfig>() {
            return Ok(World::Solo(Topology::from_system(sys)));
        }
        let path = root.join("configs/topologies").join(format!("{name}.toml"));
        if !path.is_file() {
            return Err(WorldError::Unknown {
                name: name.to_string(),
                available: Topology::available(root).join(", "),
            });
        }
        World::load(root, &path)
    }

    pub fn name(&self) -> &str {
        match self {
            World::Solo(t) => &t.name,
            World::Tenants(s) => &s.name,
        }
    }

    pub fn is_tenants(&self) -> bool {
        matches!(self, World::Tenants(_))
    }

    /// Unwrap the solo topology, or say (typed) that this world is a
    /// tenant set.
    pub fn into_solo(self) -> Result<Topology, WorldError> {
        match self {
            World::Solo(t) => Ok(t),
            World::Tenants(s) => Err(WorldError::NotSolo { name: s.name }),
        }
    }

    /// Unwrap the tenant set, or say (typed) that this world is solo.
    pub fn into_tenants(self) -> Result<TenantSet, WorldError> {
        match self {
            World::Tenants(s) => Ok(s),
            World::Solo(t) => Err(WorldError::NotTenants { name: t.name }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    #[test]
    fn resolve_routes_system_names_files_and_unknowns() {
        let root = repo_root();
        // paper names stay prebuilt solo topologies
        let w = World::resolve(&root, "cxl").unwrap();
        assert!(matches!(w, World::Solo(_)));
        assert_eq!(w.name(), "cxl");
        // shipped tenant sets sniff their [[tenants]] tables
        let w = World::resolve(&root, "multi-tenant-2").unwrap();
        assert!(w.is_tenants());
        let set = w.into_tenants().unwrap();
        assert_eq!(set.tenants.len(), 2);
        // unknown names list the catalogue
        let err = World::resolve(&root, "no-such-world").unwrap_err().to_string();
        assert!(err.contains("no-such-world") && err.contains("available"), "{err}");
    }

    #[test]
    fn every_shipped_toml_loads_as_some_world() {
        let root = repo_root();
        let dir = root.join("configs/topologies");
        for name in Topology::available(&root) {
            let w = World::load(&root, &dir.join(format!("{name}.toml")))
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            match w {
                World::Solo(t) => assert_eq!(t.name, name),
                World::Tenants(s) => assert!(!s.tenants.is_empty(), "{name}"),
            }
        }
    }

    #[test]
    fn class_unwraps_report_the_other_world_typed() {
        let root = repo_root();
        let err = World::resolve(&root, "multi-tenant-2")
            .unwrap()
            .into_solo()
            .unwrap_err();
        assert!(matches!(err, WorldError::NotSolo { .. }));
        assert!(err.to_string().contains("multi-tenant set"), "{err}");
        let err = World::resolve(&root, "cxl").unwrap().into_tenants().unwrap_err();
        assert!(matches!(err, WorldError::NotTenants { .. }));
    }

    #[test]
    fn tenant_doc_through_topology_redirects_to_world() {
        // the typed redirect: Topology::from_doc on a [[tenants]] file
        // names this API instead of failing opaquely
        let doc = Doc::parse("[[tenants]]\nmodel = \"rm_mini\"\n").unwrap();
        let err = Topology::from_doc("mt", &doc).unwrap_err();
        assert!(matches!(err, TopologyError::TenantWorld));
        // ...and World::load on the same doc succeeds
        let w = World::from_doc(&repo_root(), "mt", &doc).unwrap();
        assert!(w.is_tenants());
    }
}
