"""Model-zoo config loader.

configs/models/*.toml is the single source of truth shared by the Python
compile path (artifact shapes) and the rust coordinator (simulator +
runtime). Keep field names in sync with rust/src/config/model.rs.
"""

from __future__ import annotations

import dataclasses
import pathlib
import tomllib

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
MODELS_DIR = REPO_ROOT / "configs" / "models"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Shape of one DLRM variant (paper Table 3 row)."""

    name: str
    feature_dim: int
    num_dense: int
    num_tables: int
    rows_per_table: int
    lookups_per_table: int
    bottom_mlp: tuple[int, ...]  # hidden widths; input width = num_dense
    top_mlp: tuple[int, ...]  # hidden widths ending in 1
    batch_size: int
    lr: float

    @property
    def interaction_dim(self) -> int:
        """Width of the top-MLP input: concat(bottom-out, T reduced vectors)."""
        return self.bottom_mlp[-1] + self.num_tables * self.feature_dim

    @property
    def bottom_layers(self) -> list[tuple[int, int]]:
        dims = [self.num_dense, *self.bottom_mlp]
        return list(zip(dims[:-1], dims[1:]))

    @property
    def top_layers(self) -> list[tuple[int, int]]:
        dims = [self.interaction_dim, *self.top_mlp]
        return list(zip(dims[:-1], dims[1:]))

    @property
    def total_rows(self) -> int:
        return self.num_tables * self.rows_per_table

    def param_count(self) -> int:
        n = self.total_rows * self.feature_dim
        for i, o in self.bottom_layers + self.top_layers:
            n += i * o + o
        return n


def load(name: str) -> ModelConfig:
    path = MODELS_DIR / f"{name}.toml"
    with open(path, "rb") as f:
        raw = tomllib.load(f)
    raw.pop("sim", None)  # simulator-only section, consumed by rust
    raw["bottom_mlp"] = tuple(raw["bottom_mlp"])
    raw["top_mlp"] = tuple(raw["top_mlp"])
    return ModelConfig(**raw)


def available() -> list[str]:
    return sorted(p.stem for p in MODELS_DIR.glob("*.toml"))
