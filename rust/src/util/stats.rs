//! Summary statistics used by the bench harness and telemetry.

/// Online mean/min/max/stddev accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        if self.n == 1 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Geometric mean over positive values; the paper's cross-RM speedups are
/// geo-means.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Percentile over a sorted-or-not slice (linear interpolation), p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138).abs() < 1e-3);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }
}
