//! Multi-level CXL 3.0 switch fabric: a *tree* of range-routed switches.
//!
//! CXL 3.0 allows up to 4095 devices per root complex through multi-level
//! switching; the single [`Switch`] models one level. A [`FabricTree`]
//! composes switches into a root + internal + leaf hierarchy with
//! hop-aware routing and per-link byte/occupancy counters — the fabric
//! the multi-tenant pooled-expander scenarios mount their shared PMEM
//! pool on ([`crate::tenancy`]). A tree with only the root node is
//! exactly the depth-1 case: it routes, forwards, and counts like the
//! plain `Switch` it wraps (pinned by `depth1_tree_matches_plain_switch`).
//!
//! Invariants:
//! * every device window is registered at its leaf AND every ancestor up
//!   to the root, so the root sees the whole HPA map — any overlap
//!   between any two windows (even in different subtrees) is rejected at
//!   the root before anything is registered;
//! * a routed path always terminates at a device port (child ports only
//!   exist where a subtree was attached), and its `hops` count is the
//!   number of switches traversed (1 for the depth-1 tree).
//!
//! # Failure domains
//!
//! Every component carries health state ([`faults::FaultKind`] names the
//! classes). Each edge — a child switch's uplink or a device-port link —
//! is `1 + redundancy` physical lanes ([`FabricTree::set_redundancy`]):
//! a [`FabricTree::fail_uplink`] / [`FabricTree::fail_device_port`]
//! takes one lane down, and while survivors remain the edge keeps
//! routing at degraded capacity — [`FabricTree::forward_counted`]
//! inflates the edge's occupancy by `down / surviving` and reports the
//! inflation as a penalty (also accumulated in
//! [`LinkStats::degraded_ns`]). With no surviving lanes, or with the
//! switch itself down ([`FabricTree::fail_switch`]) or the expander
//! lost ([`FabricTree::lose_expander`]), [`FabricTree::route`] returns a
//! typed error for every address behind the dead component — the
//! caller's blast radius is exactly the windows whose root-down path
//! crosses it. Repair restores routing bit-identical to pre-fault:
//! health is the only routing input that changes.

use crate::sim::cxl::switch::{PortId, Switch, SwitchError};
use crate::sim::SimTime;
use std::collections::{BTreeMap, BTreeSet};

pub mod faults;

pub use faults::FaultKind;

/// Index of a switch node inside its [`FabricTree`].
pub type NodeId = usize;

/// The root switch every tree starts with.
pub const ROOT: NodeId = 0;

/// Cumulative counters of one tree edge (a child switch's uplink to its
/// parent): bytes forwarded, occupancy (busy ns), degraded-mode
/// occupancy (the share of `busy_ns` caused by lost lanes), and
/// transfer count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    pub bytes: u64,
    pub busy_ns: SimTime,
    /// Extra occupancy charged while the edge ran on surviving lanes —
    /// always <= `busy_ns`, 0 for a healthy edge.
    pub degraded_ns: SimTime,
    pub transfers: u64,
}

/// One switch in the tree plus its uplink accounting and health state.
#[derive(Debug)]
struct Node {
    name: String,
    parent: Option<NodeId>,
    switch: Switch,
    /// Local ports that lead to a child switch (absent = device port).
    child_of_port: BTreeMap<PortId, NodeId>,
    next_port: u16,
    /// Counters of the uplink to `parent` (unused for the root).
    uplink: LinkStats,
    /// The switch itself is down (SwitchDown fault).
    down: bool,
    /// Lanes of the uplink edge currently down (<= lanes per edge).
    uplink_lanes_down: u32,
    /// Lanes down per local device-port link (absent = healthy).
    port_lanes_down: BTreeMap<PortId, u32>,
    /// Device ports whose expander is lost (ExpanderLost fault).
    lost_ports: BTreeSet<PortId>,
}

impl Node {
    fn new(name: &str, parent: Option<NodeId>) -> Node {
        Node {
            name: name.to_string(),
            parent,
            switch: Switch::new(),
            child_of_port: BTreeMap::new(),
            next_port: 0,
            uplink: LinkStats::default(),
            down: false,
            uplink_lanes_down: 0,
            port_lanes_down: BTreeMap::new(),
            lost_ports: BTreeSet::new(),
        }
    }
}

#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum FabricError {
    #[error("unknown fabric node {0}")]
    UnknownNode(NodeId),
    #[error("fabric switch '{name}': {err}")]
    Switch { name: String, err: SwitchError },
    #[error("fabric switch '{0}' has no free ports")]
    PortsExhausted(String),
    #[error("fabric switch '{0}' is down")]
    NodeDown(String),
    #[error("fabric link '{0}' is down (no surviving lanes)")]
    LinkDown(String),
    #[error("fabric expander '{0}' is lost")]
    ExpanderLost(String),
    #[error("fabric node '{0}' has no uplink (it is the root)")]
    NoUplink(String),
    #[error("fabric switch '{0}' has no device port {1}")]
    NoSuchPort(String, u16),
}

/// A resolved path through the tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Route {
    /// The switch owning the terminal device port.
    pub node: NodeId,
    /// The device port on that switch.
    pub port: PortId,
    /// Switches traversed root → device (1 for a depth-1 tree).
    pub hops: usize,
}

/// Root + internal + leaf switches with per-link counters.
#[derive(Debug)]
pub struct FabricTree {
    nodes: Vec<Node>,
    /// Spare physical lanes per edge: every edge is `1 + redundancy`
    /// lanes, so a single LinkDown degrades instead of severing when
    /// `redundancy >= 1`.
    redundancy: u32,
}

impl FabricTree {
    /// A tree holding only the root switch — the depth-1 fabric the
    /// paper's single-switch topology uses.
    pub fn new(root_name: &str) -> FabricTree {
        FabricTree {
            nodes: vec![Node::new(root_name, None)],
            redundancy: 0,
        }
    }

    /// Configure `spares` redundant lanes per edge (0 = the bare fabric).
    /// Set this before injecting faults: lane counters are interpreted
    /// against the configured width.
    pub fn set_redundancy(&mut self, spares: u32) {
        self.redundancy = spares;
    }

    pub fn redundancy(&self) -> u32 {
        self.redundancy
    }

    /// Physical lanes per edge.
    fn lanes(&self) -> u32 {
        1 + self.redundancy
    }

    fn node(&self, id: NodeId) -> Result<&Node, FabricError> {
        self.nodes.get(id).ok_or(FabricError::UnknownNode(id))
    }

    fn alloc_port(&mut self, id: NodeId) -> Result<PortId, FabricError> {
        let name = self.nodes[id].name.clone();
        let node = &mut self.nodes[id];
        if node.next_port == u16::MAX {
            return Err(FabricError::PortsExhausted(name));
        }
        let p = PortId(node.next_port);
        node.next_port += 1;
        Ok(p)
    }

    /// Add a child switch under `parent`; returns the new node's id.
    pub fn add_switch(&mut self, parent: NodeId, name: &str) -> Result<NodeId, FabricError> {
        self.node(parent)?;
        let port = self.alloc_port(parent)?;
        let id = self.nodes.len();
        self.nodes[parent].child_of_port.insert(port, id);
        self.nodes.push(Node::new(name, Some(parent)));
        Ok(id)
    }

    /// The chain of nodes from the root down to `id` (inclusive).
    fn path_to(&self, id: NodeId) -> Vec<NodeId> {
        let mut chain = vec![id];
        let mut cur = id;
        while let Some(p) = self.nodes[cur].parent {
            chain.push(p);
            cur = p;
        }
        chain.reverse();
        chain
    }

    /// Attach a device window `[start, start+len)` at switch `node`,
    /// registering the range at every ancestor so the root can route it.
    ///
    /// Validation happens at the root FIRST: the root holds every window
    /// of the whole tree, so any overlap (even across subtrees), a
    /// zero-length window, or an overflowing range is rejected there
    /// before anything is registered anywhere — no partial attachment.
    pub fn attach_device(
        &mut self,
        node: NodeId,
        name: &str,
        start: u64,
        len: u64,
    ) -> Result<PortId, FabricError> {
        self.node(node)?;
        let chain = self.path_to(node);
        // Resolve (allocating where needed) the port each chain switch
        // routes this range through: the child-subtree port for interior
        // nodes, a fresh device port at the target.
        let mut ports = Vec::with_capacity(chain.len());
        for pair in chain.windows(2) {
            let (parent, child) = (pair[0], pair[1]);
            let existing = self.nodes[parent]
                .child_of_port
                .iter()
                .find(|&(_, &c)| c == child)
                .map(|(&p, _)| p)
                .expect("child switches always hold a port in their parent");
            ports.push((parent, existing, self.nodes[child].name.clone()));
        }
        let dev_port = self.alloc_port(node)?;
        ports.push((node, dev_port, name.to_string()));
        // Root first: its window set is the union of every subtree's, so
        // success there guarantees success at every descendant.
        for (i, (at, port, port_name)) in ports.iter().enumerate() {
            match self.nodes[*at].switch.attach(*port, port_name, start, len) {
                Ok(()) => {}
                Err(err) => {
                    debug_assert!(i == 0, "descendant attach failed after root accepted");
                    return Err(FabricError::Switch {
                        name: self.nodes[*at].name.clone(),
                        err,
                    });
                }
            }
        }
        Ok(dev_port)
    }

    /// Route an HPA from the root down to its device port, refusing paths
    /// that cross a dead component: a downed switch
    /// ([`FabricError::NodeDown`]), an edge with no surviving lanes
    /// ([`FabricError::LinkDown`]), or a lost expander
    /// ([`FabricError::ExpanderLost`]). Routing is a pure function of the
    /// registered windows and the health state, so repairing every fault
    /// restores routes bit-identical to pre-fault.
    pub fn route(&self, addr: u64) -> Result<Route, FabricError> {
        let lanes = self.lanes();
        let mut node = ROOT;
        let mut hops = 1;
        loop {
            let n = &self.nodes[node];
            if n.down {
                return Err(FabricError::NodeDown(n.name.clone()));
            }
            let port = n.switch.route(addr).map_err(|err| FabricError::Switch {
                name: n.name.clone(),
                err,
            })?;
            match n.child_of_port.get(&port) {
                Some(&child) => {
                    if self.nodes[child].uplink_lanes_down >= lanes {
                        return Err(FabricError::LinkDown(self.nodes[child].name.clone()));
                    }
                    node = child;
                    hops += 1;
                }
                None => {
                    if n.lost_ports.contains(&port) {
                        return Err(FabricError::ExpanderLost(format!("{}:p{}", n.name, port.0)));
                    }
                    if n.port_lanes_down.get(&port).copied().unwrap_or(0) >= lanes {
                        return Err(FabricError::LinkDown(format!("{}:p{}", n.name, port.0)));
                    }
                    return Ok(Route { node, port, hops });
                }
            }
        }
    }

    /// Account a transfer of `bytes` to `addr` occupying the path for
    /// `busy_ns`: per-port byte counters at every traversed switch plus
    /// byte/occupancy/transfer counters on every traversed link.
    ///
    /// Degraded edges (some lanes down, survivors routing) stretch the
    /// transfer: each such edge's occupancy is inflated by
    /// `busy_ns * down / surviving` (half the lanes gone = double the
    /// time), tracked per link in [`LinkStats::degraded_ns`]. The
    /// returned penalty is the total inflation across the path — the
    /// extra nanoseconds the caller should attribute to the fault.
    pub fn forward_counted(
        &mut self,
        addr: u64,
        bytes: u64,
        busy_ns: SimTime,
    ) -> Result<(Route, SimTime), FabricError> {
        let route = self.route(addr)?;
        let lanes = self.lanes() as u64;
        let mut penalty: SimTime = 0;
        let mut node = ROOT;
        loop {
            let port = self.nodes[node]
                .switch
                .forward(addr, bytes)
                .expect("route() already resolved this address");
            match self.nodes[node].child_of_port.get(&port).copied() {
                Some(child) => {
                    let down = self.nodes[child].uplink_lanes_down as u64;
                    let extra = if down > 0 { busy_ns * down / (lanes - down) } else { 0 };
                    let l = &mut self.nodes[child].uplink;
                    l.bytes += bytes;
                    l.busy_ns += busy_ns + extra;
                    l.degraded_ns += extra;
                    l.transfers += 1;
                    penalty += extra;
                    node = child;
                }
                None => {
                    let down =
                        self.nodes[node].port_lanes_down.get(&port).copied().unwrap_or(0) as u64;
                    if down > 0 {
                        penalty += busy_ns * down / (lanes - down);
                    }
                    break;
                }
            }
        }
        Ok((route, penalty))
    }

    /// [`FabricTree::forward_counted`] for callers that don't consume the
    /// degradation penalty.
    pub fn forward(
        &mut self,
        addr: u64,
        bytes: u64,
        busy_ns: SimTime,
    ) -> Result<Route, FabricError> {
        self.forward_counted(addr, bytes, busy_ns).map(|(r, _)| r)
    }

    // ------------------------------------------- fault injection/repair

    fn node_mut(&mut self, id: NodeId) -> Result<&mut Node, FabricError> {
        self.nodes.get_mut(id).ok_or(FabricError::UnknownNode(id))
    }

    /// Check `port` is a device port (allocated, not a child-subtree
    /// port) of `id`.
    fn device_port(&mut self, id: NodeId, port: PortId) -> Result<&mut Node, FabricError> {
        let n = self.node_mut(id)?;
        if port.0 >= n.next_port || n.child_of_port.contains_key(&port) {
            let name = n.name.clone();
            return Err(FabricError::NoSuchPort(name, port.0));
        }
        Ok(n)
    }

    /// Take one lane of `id`'s uplink edge down (saturating at the edge
    /// width). The root has no uplink.
    pub fn fail_uplink(&mut self, id: NodeId) -> Result<(), FabricError> {
        let lanes = self.lanes();
        let n = self.node_mut(id)?;
        if n.parent.is_none() {
            let name = n.name.clone();
            return Err(FabricError::NoUplink(name));
        }
        n.uplink_lanes_down = (n.uplink_lanes_down + 1).min(lanes);
        Ok(())
    }

    /// Bring one lane of `id`'s uplink edge back (no-op when healthy).
    pub fn repair_uplink(&mut self, id: NodeId) -> Result<(), FabricError> {
        let n = self.node_mut(id)?;
        if n.parent.is_none() {
            let name = n.name.clone();
            return Err(FabricError::NoUplink(name));
        }
        n.uplink_lanes_down = n.uplink_lanes_down.saturating_sub(1);
        Ok(())
    }

    /// Take the whole switch down: every address routed through it is
    /// unreachable until [`FabricTree::repair_switch`], spares or not.
    pub fn fail_switch(&mut self, id: NodeId) -> Result<(), FabricError> {
        self.node_mut(id)?.down = true;
        Ok(())
    }

    pub fn repair_switch(&mut self, id: NodeId) -> Result<(), FabricError> {
        self.node_mut(id)?.down = false;
        Ok(())
    }

    /// Take one lane of the device-port link `(id, port)` down.
    pub fn fail_device_port(&mut self, id: NodeId, port: PortId) -> Result<(), FabricError> {
        let lanes = self.lanes();
        let n = self.device_port(id, port)?;
        let d = n.port_lanes_down.entry(port).or_insert(0);
        *d = (*d + 1).min(lanes);
        Ok(())
    }

    pub fn repair_device_port(&mut self, id: NodeId, port: PortId) -> Result<(), FabricError> {
        let n = self.device_port(id, port)?;
        if let Some(d) = n.port_lanes_down.get_mut(&port) {
            *d = d.saturating_sub(1);
            if *d == 0 {
                n.port_lanes_down.remove(&port);
            }
        }
        Ok(())
    }

    /// Lose the expander behind device port `(id, port)`: its windows are
    /// unreachable and their in-flight rows torn until
    /// [`FabricTree::restore_expander`].
    pub fn lose_expander(&mut self, id: NodeId, port: PortId) -> Result<(), FabricError> {
        self.device_port(id, port)?.lost_ports.insert(port);
        Ok(())
    }

    pub fn restore_expander(&mut self, id: NodeId, port: PortId) -> Result<(), FabricError> {
        self.device_port(id, port)?.lost_ports.remove(&port);
        Ok(())
    }

    /// Tree depth: 1 for the root-only (classic single-switch) fabric.
    pub fn levels(&self) -> usize {
        (0..self.nodes.len()).map(|n| self.path_to(n).len()).max().unwrap_or(1)
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn node_name(&self, id: NodeId) -> &str {
        self.nodes.get(id).map(|n| n.name.as_str()).unwrap_or("?")
    }

    /// The underlying switch of one node (introspection/tests).
    pub fn switch(&self, id: NodeId) -> Option<&Switch> {
        self.nodes.get(id).map(|n| &n.switch)
    }

    /// Uplink counters of one non-root node.
    pub fn uplink(&self, id: NodeId) -> Option<LinkStats> {
        self.nodes.get(id).filter(|n| n.parent.is_some()).map(|n| n.uplink)
    }

    /// `(link name, stats)` for every tree edge, in node order. Empty for
    /// the depth-1 fabric (no internal links).
    pub fn links(&self) -> Vec<(String, LinkStats)> {
        self.nodes
            .iter()
            .filter(|n| n.parent.is_some())
            .map(|n| (n.name.clone(), n.uplink))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: u64 = 1 << 30;

    #[test]
    fn depth1_tree_matches_plain_switch() {
        // the root-only tree must behave exactly like the single Switch
        // it subsumes: same routing, same per-port byte accounting
        let mut plain = Switch::new();
        let mut tree = FabricTree::new("root");
        let windows = [(0u64, 4 * GB), (4 * GB, 24 * GB), (28 * GB, 16 * GB)];
        for (i, &(start, len)) in windows.iter().enumerate() {
            plain.attach(PortId(i as u16), &format!("dev{i}"), start, len).unwrap();
            let p = tree.attach_device(ROOT, &format!("dev{i}"), start, len).unwrap();
            assert_eq!(p, PortId(i as u16));
        }
        assert_eq!(tree.levels(), 1);
        assert!(tree.links().is_empty(), "depth-1 fabric has no internal links");
        for addr in [0, GB, 5 * GB, 30 * GB, 43 * GB] {
            let r = tree.route(addr).unwrap();
            assert_eq!(r.port, plain.route(addr).unwrap());
            assert_eq!(r.node, ROOT);
            assert_eq!(r.hops, 1);
        }
        // unrouted addresses fail identically
        assert!(plain.route(60 * GB).is_err());
        assert!(matches!(
            tree.route(60 * GB),
            Err(FabricError::Switch {
                err: SwitchError::Unrouted(_),
                ..
            })
        ));
        // forwarding counts the same bytes on the same port
        plain.forward(5 * GB, 4096).unwrap();
        tree.forward(5 * GB, 4096, 100).unwrap();
        assert_eq!(
            tree.switch(ROOT).unwrap().bytes_by_port,
            plain.bytes_by_port
        );
    }

    #[test]
    fn multi_level_routing_is_hop_aware() {
        let mut tree = FabricTree::new("root");
        let leaf_a = tree.add_switch(ROOT, "leaf-a").unwrap();
        let leaf_b = tree.add_switch(ROOT, "leaf-b").unwrap();
        let deep = tree.add_switch(leaf_b, "leaf-b-2").unwrap();
        tree.attach_device(leaf_a, "mem-a", 0, 16 * GB).unwrap();
        tree.attach_device(deep, "mem-b", 16 * GB, 16 * GB).unwrap();
        tree.attach_device(ROOT, "host", 64 * GB, 4 * GB).unwrap();
        assert_eq!(tree.levels(), 3);

        let a = tree.route(GB).unwrap();
        assert_eq!((a.node, a.hops), (leaf_a, 2));
        let b = tree.route(17 * GB).unwrap();
        assert_eq!((b.node, b.hops), (deep, 3));
        let h = tree.route(65 * GB).unwrap();
        assert_eq!((h.node, h.hops), (ROOT, 1));
    }

    #[test]
    fn per_link_bytes_and_occupancy_accounted_on_the_path_only() {
        let mut tree = FabricTree::new("root");
        let leaf_a = tree.add_switch(ROOT, "leaf-a").unwrap();
        let leaf_b = tree.add_switch(ROOT, "leaf-b").unwrap();
        tree.attach_device(leaf_a, "mem-a", 0, 16 * GB).unwrap();
        tree.attach_device(leaf_b, "mem-b", 16 * GB, 16 * GB).unwrap();

        tree.forward(GB, 1024, 50).unwrap();
        tree.forward(GB, 1024, 70).unwrap();
        tree.forward(17 * GB, 4096, 10).unwrap();

        let a = tree.uplink(leaf_a).unwrap();
        assert_eq!((a.bytes, a.busy_ns, a.transfers), (2048, 120, 2));
        let b = tree.uplink(leaf_b).unwrap();
        assert_eq!((b.bytes, b.busy_ns, b.transfers), (4096, 10, 1));
        // the root has no uplink
        assert!(tree.uplink(ROOT).is_none());
        // root switch saw all the traffic, split across its two ports
        let root_bytes: u64 = tree.switch(ROOT).unwrap().bytes_by_port.values().sum();
        assert_eq!(root_bytes, 2048 + 4096);
        let links = tree.links();
        assert_eq!(links.len(), 2);
        assert_eq!(links[0].0, "leaf-a");
    }

    #[test]
    fn cross_subtree_overlap_rejected_atomically() {
        let mut tree = FabricTree::new("root");
        let leaf_a = tree.add_switch(ROOT, "leaf-a").unwrap();
        let leaf_b = tree.add_switch(ROOT, "leaf-b").unwrap();
        tree.attach_device(leaf_a, "mem-a", 0, 16 * GB).unwrap();
        // overlaps mem-a, but lives in a *different* subtree: the leaf
        // switch alone would accept it — the root must reject it
        let err = tree.attach_device(leaf_b, "mem-b", 8 * GB, 16 * GB).unwrap_err();
        assert!(
            matches!(
                err,
                FabricError::Switch {
                    err: SwitchError::Overlap { .. },
                    ..
                }
            ),
            "{err}"
        );
        // nothing was registered at leaf-b: a disjoint retry succeeds and
        // leaf-b still has no stale window from the failed attempt
        assert!(tree.route(9 * GB).is_ok(), "mem-a still routes");
        assert_eq!(tree.route(9 * GB).unwrap().node, leaf_a);
        tree.attach_device(leaf_b, "mem-b", 32 * GB, 16 * GB).unwrap();
        assert_eq!(tree.route(33 * GB).unwrap().node, leaf_b);
    }

    #[test]
    fn zero_length_and_overflow_propagate_from_the_switch() {
        let mut tree = FabricTree::new("root");
        let leaf = tree.add_switch(ROOT, "leaf").unwrap();
        assert!(matches!(
            tree.attach_device(leaf, "z", GB, 0),
            Err(FabricError::Switch {
                err: SwitchError::ZeroLength { .. },
                ..
            })
        ));
        assert!(matches!(
            tree.attach_device(leaf, "w", u64::MAX - 16, 64),
            Err(FabricError::Switch {
                err: SwitchError::Overflow { .. },
                ..
            })
        ));
        assert!(tree.route(GB).is_err(), "rejected windows route nothing");
    }

    #[test]
    fn unknown_nodes_are_errors() {
        let mut tree = FabricTree::new("root");
        assert_eq!(tree.add_switch(99, "x").unwrap_err(), FabricError::UnknownNode(99));
        assert_eq!(
            tree.attach_device(99, "x", 0, GB).unwrap_err(),
            FabricError::UnknownNode(99)
        );
    }

    /// A two-leaf tree with one 16 GB window per leaf — the shape the
    /// tenancy layer builds for a two-tenant depth-2 fabric.
    fn two_leaf_tree() -> (FabricTree, NodeId, NodeId, PortId, PortId) {
        let mut tree = FabricTree::new("root");
        let leaf_a = tree.add_switch(ROOT, "leaf-a").unwrap();
        let leaf_b = tree.add_switch(ROOT, "leaf-b").unwrap();
        let pa = tree.attach_device(leaf_a, "mem-a", 0, 16 * GB).unwrap();
        let pb = tree.attach_device(leaf_b, "mem-b", 16 * GB, 16 * GB).unwrap();
        (tree, leaf_a, leaf_b, pa, pb)
    }

    #[test]
    fn link_down_consumes_spares_then_severs_the_edge() {
        let (mut tree, leaf_a, _, _, _) = two_leaf_tree();
        tree.set_redundancy(1);
        // one lane down: the edge degrades — routes survive, occupancy
        // doubles (2 lanes -> 1), and the inflation is both returned as a
        // penalty and tracked in degraded_ns
        tree.fail_uplink(leaf_a).unwrap();
        let (r, penalty) = tree.forward_counted(GB, 1024, 100).unwrap();
        assert_eq!(r.node, leaf_a);
        assert_eq!(penalty, 100, "half the lanes = double the time");
        let l = tree.uplink(leaf_a).unwrap();
        assert_eq!((l.busy_ns, l.degraded_ns), (200, 100));
        // the sibling's edge is untouched
        let (_, p2) = tree.forward_counted(17 * GB, 1024, 100).unwrap();
        assert_eq!(p2, 0);
        assert_eq!(tree.uplink(tree.route(17 * GB).unwrap().node).unwrap().degraded_ns, 0);
        // the second lane severs the edge: exactly leaf-a's window dies
        tree.fail_uplink(leaf_a).unwrap();
        assert!(matches!(tree.route(GB), Err(FabricError::LinkDown(n)) if n == "leaf-a"));
        assert!(tree.route(17 * GB).is_ok(), "bystander subtree still routes");
        // repair restores lanes one at a time
        tree.repair_uplink(leaf_a).unwrap();
        let (_, p3) = tree.forward_counted(GB, 1024, 100).unwrap();
        assert_eq!(p3, 100, "one lane still down: still degraded");
        tree.repair_uplink(leaf_a).unwrap();
        let (_, p4) = tree.forward_counted(GB, 1024, 100).unwrap();
        assert_eq!(p4, 0, "fully repaired: no penalty");
        // the root has no uplink to fail
        assert!(matches!(tree.fail_uplink(ROOT), Err(FabricError::NoUplink(_))));
    }

    #[test]
    fn switch_down_blacks_out_the_subtree_and_repair_restores_routes() {
        let (mut tree, leaf_a, _, _, _) = two_leaf_tree();
        tree.set_redundancy(4); // spares cannot help a dead switch
        let before_a = tree.route(GB).unwrap();
        let before_b = tree.route(17 * GB).unwrap();
        tree.fail_switch(leaf_a).unwrap();
        assert!(matches!(tree.route(GB), Err(FabricError::NodeDown(n)) if n == "leaf-a"));
        assert_eq!(tree.route(17 * GB).unwrap(), before_b);
        tree.repair_switch(leaf_a).unwrap();
        assert_eq!(tree.route(GB).unwrap(), before_a, "repair restores the exact route");
        // the root going down blacks out everything
        tree.fail_switch(ROOT).unwrap();
        assert!(tree.route(GB).is_err() && tree.route(17 * GB).is_err());
        tree.repair_switch(ROOT).unwrap();
        assert_eq!(tree.route(GB).unwrap(), before_a);
    }

    #[test]
    fn expander_loss_kills_exactly_its_port() {
        let (mut tree, leaf_a, _, pa, _) = two_leaf_tree();
        // a second device on the same leaf: same switch, different port
        let pa2 = tree.attach_device(leaf_a, "mem-a2", 40 * GB, 4 * GB).unwrap();
        tree.lose_expander(leaf_a, pa).unwrap();
        assert!(matches!(tree.route(GB), Err(FabricError::ExpanderLost(_))));
        assert_eq!(tree.route(41 * GB).unwrap().port, pa2, "sibling expander still routes");
        assert!(tree.route(17 * GB).is_ok());
        tree.restore_expander(leaf_a, pa).unwrap();
        assert_eq!(tree.route(GB).unwrap().port, pa);
        // faulting a child-subtree port or an unallocated port is typed
        assert!(matches!(
            tree.lose_expander(ROOT, PortId(0)),
            Err(FabricError::NoSuchPort(_, 0))
        ));
        assert!(matches!(
            tree.fail_device_port(leaf_a, PortId(9)),
            Err(FabricError::NoSuchPort(_, 9))
        ));
    }

    #[test]
    fn depth1_device_port_faults_stall_without_links() {
        // the paper's single-switch fabric: LinkDown lands on the device
        // port itself (there are no internal links to degrade)
        let mut tree = FabricTree::new("root");
        let p = tree.attach_device(ROOT, "pool", 0, 16 * GB).unwrap();
        tree.fail_device_port(ROOT, p).unwrap();
        assert!(matches!(tree.route(GB), Err(FabricError::LinkDown(_))));
        tree.repair_device_port(ROOT, p).unwrap();
        assert_eq!(tree.route(GB).unwrap().port, p);
        // with a spare lane the port degrades instead: the penalty comes
        // back even though no LinkStats edge exists to record it
        tree.set_redundancy(1);
        tree.fail_device_port(ROOT, p).unwrap();
        let (_, penalty) = tree.forward_counted(GB, 512, 80).unwrap();
        assert_eq!(penalty, 80);
        assert!(tree.links().is_empty());
    }

    #[test]
    fn saturated_link_stats_survive_a_down_up_cycle_without_double_counting() {
        // regression (write-only counters fix): a down/up cycle must not
        // inflate, reset, or re-count an edge's accumulated stats
        let (mut tree, leaf_a, _, _, _) = two_leaf_tree();
        tree.set_redundancy(1);
        for _ in 0..32 {
            tree.forward(GB, 4096, 25).unwrap();
        }
        let saturated = tree.uplink(leaf_a).unwrap();
        assert_eq!(
            (saturated.bytes, saturated.busy_ns, saturated.degraded_ns, saturated.transfers),
            (32 * 4096, 32 * 25, 0, 32)
        );
        // a fault + repair with no traffic in between changes nothing
        tree.fail_uplink(leaf_a).unwrap();
        tree.repair_uplink(leaf_a).unwrap();
        assert_eq!(tree.uplink(leaf_a).unwrap(), saturated);
        // traffic after the cycle accumulates exactly linearly on top
        for _ in 0..32 {
            tree.forward(GB, 4096, 25).unwrap();
        }
        let after = tree.uplink(leaf_a).unwrap();
        assert_eq!(
            (after.bytes, after.busy_ns, after.degraded_ns, after.transfers),
            (64 * 4096, 64 * 25, 0, 64)
        );
        // and degraded traffic is split into busy vs degraded with no
        // double count: total busy == healthy share + degraded share
        tree.fail_uplink(leaf_a).unwrap();
        tree.forward(GB, 4096, 25).unwrap();
        let degraded = tree.uplink(leaf_a).unwrap();
        assert_eq!(degraded.busy_ns - after.busy_ns, 50, "25 base + 25 inflation");
        assert_eq!(degraded.degraded_ns, 25);
        tree.repair_uplink(leaf_a).unwrap();
        assert_eq!(tree.uplink(leaf_a).unwrap(), degraded, "repair never rewrites history");
    }
}
