//! Crash-injection recovery matrix: for every checkpoint mode and every
//! topology family the repo ships a schedule for — including the tiered
//! (hot-tier flush) and sharded (per-lane log stripes) compositions —
//! crash the pipeline DURING every stage of every batch and assert the
//! recovered state is bit-identical to an uncrashed twin resumed at the
//! same batch. Generalises the PR-2 twin-equality test from "crash after
//! step()" to the whole stage chain. The multi-tenant rows
//! (`multi_tenant_rows_isolate_failure_domains`) interleave a co-tenant
//! rig with its own log-region slice and pin that a victim's crash under
//! every mode leaves the co-tenant's whole failure domain untouched.
//!
//! The rig maps the timing pipeline's composed stage names
//! (`stage::compose(&topology)`) onto the byte-accurate state the
//! checkpoint path owns: an [`EmbeddingStore`] (the durable data-region
//! image), a [`LogRegion`] (undo generations + MLP snapshots), and a
//! deterministic MLP parameter vector. "Crash during stage j" means the
//! effects of stages `0..j` are applied and stage j's are not; if stage
//! j IS the embedding update, its rows are torn mid-write (NaN fill) —
//! every other stage either only reads or only mutates the log region,
//! which the region's double-buffered flag protocol already covers.

use trainingcxl::checkpoint::recovery::RecoveryError;
use trainingcxl::checkpoint::{self, LogRegion};
use trainingcxl::config::{CkptMode, ModelConfig, SystemConfig};
use trainingcxl::emb::EmbeddingStore;
use trainingcxl::repo_root;
use trainingcxl::sched::stage;
use trainingcxl::sim::mem::MediaKind;
use trainingcxl::sim::topology::{Topology, TopologyBuilder};
use trainingcxl::workload::Generator;

const SEED: u64 = 0xC4A5;
const TOTAL_BATCHES: u64 = 5;

const UPDATE_STAGES: [&str; 4] = [
    "ndp-emb-update",
    "host-emb-update",
    "sharded-emb-update",
    "tiered-emb-update",
];

/// Deterministic embedding-update delta for (batch, table, row): both
/// the crashed run and the twin replay it bit-identically.
fn delta(batch: u64, table: usize, row: usize) -> f32 {
    (batch as f32 + 1.0) * 0.125 + (table * 131 + row) as f32 * 0.001953125
}

fn initial_params() -> Vec<Vec<f32>> {
    vec![vec![0.5; 6], vec![-0.25; 3]]
}

/// One batch's MLP commit (the `gpu-bottom-bwd` stage's data effect).
fn mlp_step(params: &mut [Vec<f32>], batch: u64) {
    for (i, p) in params.iter_mut().enumerate() {
        for v in p.iter_mut() {
            *v += (batch as f32 + 1.0) * 0.25 + i as f32 * 0.0625;
        }
    }
}

/// MLP parameters at the START of batch `k` (pure replay).
fn params_at(k: u64) -> Vec<Vec<f32>> {
    let mut p = initial_params();
    for b in 0..k {
        mlp_step(&mut p, b);
    }
    p
}

fn initial_store(cfg: &ModelConfig) -> EmbeddingStore {
    let mut s = EmbeddingStore::zeros(cfg);
    for t in 0..cfg.num_tables {
        for r in 0..cfg.rows_per_table {
            s.row_mut(t, r).fill((t * 1000 + r) as f32 * 0.03125);
        }
    }
    s
}

/// Touched rows of every batch, from the real workload generator.
fn batch_rows(cfg: &ModelConfig, batches: u64, seed: u64) -> Vec<Vec<(usize, usize)>> {
    let probe = EmbeddingStore::zeros(cfg);
    let mut g = Generator::new(cfg, seed);
    (0..batches)
        .map(|_| probe.touched_rows(&g.next_batch().indices))
        .collect()
}

/// Static hot/cold partition for the tiered rigs. WHICH rows count as
/// hot is irrelevant to recovery correctness — the split only has to be
/// stable across the crashed run and the twin.
fn is_hot(row: usize) -> bool {
    row % 3 == 0
}

struct Rig {
    stages: Vec<&'static str>,
    tiered: bool,
    shards: usize,
    /// Relaxed-mode MLP streaming window (1 = synchronous).
    window: u64,
    store: EmbeddingStore,
    region: LogRegion,
    params: Vec<Vec<f32>>,
    batches: Vec<Vec<(usize, usize)>>,
    mlp_total: u64,
}

impl Rig {
    fn new(cfg: &ModelConfig, topo: Topology) -> Rig {
        Rig::with_seed(cfg, topo, SEED)
    }

    /// A rig with its own workload seed — one tenant of a multi-tenant
    /// pool (each tenant's touched-row stream is its own).
    fn with_seed(cfg: &ModelConfig, topo: Topology, seed: u64) -> Rig {
        let stages: Vec<&'static str> = stage::compose(&topo)
            .expect("matrix topologies always compose")
            .iter()
            .map(|s| s.name())
            .collect();
        let params = initial_params();
        let mlp_total: u64 = params.iter().map(|p| (p.len() * 4) as u64).sum();
        Rig {
            stages,
            tiered: topo.tier_split().is_some(),
            shards: topo.gpu_shards,
            window: topo.max_mlp_log_gap.max(1),
            store: initial_store(cfg),
            region: LogRegion::new(),
            params,
            batches: batch_rows(cfg, TOTAL_BATCHES, seed),
            mlp_total,
        }
    }

    fn cold_rows(&self, b: usize) -> Vec<(usize, usize)> {
        if !self.tiered {
            return self.batches[b].clone();
        }
        self.batches[b].iter().copied().filter(|&(_, r)| !is_hot(r)).collect()
    }

    fn hot_rows(&self, b: usize) -> Vec<(usize, usize)> {
        if !self.tiered {
            return Vec::new();
        }
        self.batches[b].iter().copied().filter(|&(_, r)| is_hot(r)).collect()
    }

    /// Relaxed MLP logging (mirrors `Trainer::step_with_batch`): begin a
    /// snapshot at each window boundary, stream a per-batch slice, seal
    /// when complete; the bootstrap snapshot seals synchronously; a
    /// predecessor that ran out of window finishes synchronously.
    fn relaxed_mlp(&mut self, b: u64) {
        if b % self.window == 0 {
            if self.region.mlp_cur.as_ref().is_some_and(|l| !l.persistent) {
                self.region.advance_mlp_log(u64::MAX);
                self.region.seal_mlp_log();
            }
            let snap = params_at(b);
            self.region.begin_mlp_log(b, &snap);
        }
        if self.region.mlp_cur.as_ref().is_some_and(|l| !l.persistent) {
            let budget = if self.region.persistent_mlp().is_none() {
                u64::MAX
            } else {
                self.mlp_total.div_ceil(self.window).max(1)
            };
            if self.region.advance_mlp_log(budget) == 0 {
                self.region.seal_mlp_log();
            }
        }
    }

    /// Apply the data effect of stage `name` while processing batch `b`.
    fn stage_effect(&mut self, name: &'static str, b: u64) {
        let bi = b as usize;
        match name {
            "gpu-bottom-bwd" => mlp_step(&mut self.params, b),
            // batch-aware undo generation, begun/sealed atomically
            "emb-undo-log" => {
                let rows = self.batches[bi].clone();
                self.region.begin_emb_log(b, &self.store, &rows);
                self.region.seal_emb_log(b);
            }
            // sharded: one stripe per lane appended to the generation
            "sharded-emb-undo-log" => {
                let all = self.batches[bi].clone();
                let lanes = self.shards.max(1);
                let stripe = |l: usize| {
                    all.iter().copied().filter(|&(t, _)| t % lanes == l).collect::<Vec<_>>()
                };
                self.region.begin_emb_log(b, &self.store, &stripe(0));
                for l in 1..lanes {
                    self.region.extend_emb_log(b, &self.store, &stripe(l));
                }
                self.region.seal_emb_log(b);
            }
            // tiered: the cold leg opens the generation UNSEALED...
            "tiered-emb-undo-log" => {
                let cold = self.cold_rows(bi);
                self.region.begin_emb_log(b, &self.store, &cold);
            }
            // ...and the hot-tier flush completes and seals it
            "hot-tier-flush" => {
                let hot = self.hot_rows(bi);
                self.region.extend_emb_log(b, &self.store, &hot);
                self.region.seal_emb_log(b);
            }
            "ndp-emb-update" | "host-emb-update" | "sharded-emb-update" | "tiered-emb-update" => {
                let rows = self.batches[bi].clone();
                for (t, r) in rows {
                    let d = delta(b, t, r);
                    for v in self.store.row_mut(t, r) {
                        *v += d;
                    }
                }
            }
            // Redo tails run AFTER the update: the checkpoint makes the
            // post-batch state durable. For the undo-shaped log region
            // that means capturing the NEXT batch's touched rows at
            // their current (post-batch-b) values as generation b+1.
            "redo-tail-ckpt" | "host-redo-ckpt" | "pcie-staged-redo-ckpt" => {
                if let Some(next) = self.batches.get(bi + 1) {
                    let next = next.clone();
                    self.region.begin_emb_log(b + 1, &self.store, &next);
                    self.region.seal_emb_log(b + 1);
                    let snap = self.params.clone();
                    self.region.begin_mlp_log(b + 1, &snap);
                    self.region.advance_mlp_log(u64::MAX);
                    self.region.seal_mlp_log();
                }
            }
            // batch-aware MLP undo log: pre-commit params of batch b
            "batch-aware-mlp-log" => {
                let snap = params_at(b);
                self.region.begin_mlp_log(b, &snap);
                self.region.advance_mlp_log(u64::MAX);
                self.region.seal_mlp_log();
            }
            "relaxed-mlp-log" => self.relaxed_mlp(b),
            // lookups, flushes, exchanges, GPU forward phases, migration,
            // attribution: reads or pure timing — no recoverable state
            _ => {}
        }
    }

    /// Run one full batch (all stage effects).
    fn run_batch(&mut self, b: u64) {
        let stages = self.stages.clone();
        for &name in &stages {
            self.stage_effect(name, b);
        }
    }

    /// Run batch `b` until the power fails DURING stage `stage_idx`. If
    /// the in-flight stage is the embedding update, the DMA died
    /// mid-row: the batch's touched rows are torn.
    fn crash_in_batch(&mut self, b: u64, stage_idx: usize) {
        let stages = self.stages.clone();
        for (i, &name) in stages.iter().enumerate() {
            if i == stage_idx {
                if UPDATE_STAGES.contains(&name) {
                    let rows = self.batches[b as usize].clone();
                    for (t, r) in rows {
                        self.store.row_mut(t, r).fill(f32::NAN);
                    }
                }
                return;
            }
            self.stage_effect(name, b);
        }
    }

    /// Run `n` full batches, no crash.
    fn run(&mut self, n: u64) {
        for b in 0..n {
            self.run_batch(b);
        }
    }

    /// Run until the power fails DURING stage `stage_idx` of batch
    /// `crash_batch`.
    fn run_to_crash(&mut self, crash_batch: u64, stage_idx: usize) {
        for b in 0..crash_batch {
            self.run_batch(b);
        }
        self.crash_in_batch(crash_batch, stage_idx);
    }
}

fn matrix_case(cfg: &ModelConfig, topo: &Topology, label: &str) {
    let n_stages = Rig::new(cfg, topo.clone()).stages.len();
    for crash_batch in 0..TOTAL_BATCHES {
        for stage_idx in 0..n_stages {
            let mut rig = Rig::new(cfg, topo.clone());
            rig.run_to_crash(crash_batch, stage_idx);
            let stage_name = rig.stages[stage_idx];
            let at = format!("{label}: crash during '{stage_name}' of batch {crash_batch}");

            let mut recovered = rig.store.clone();
            match checkpoint::recover(&mut recovered, &rig.region) {
                Err(e) => {
                    // Unrecoverable is legal only for the checkpoint-free
                    // fabric, or inside batch 0's bootstrap window (before
                    // the very first generation seals).
                    assert!(
                        topo.ckpt == CkptMode::None || crash_batch == 0,
                        "{at}: unexpected recovery failure: {e}"
                    );
                    // in the bootstrap window either log may be the
                    // missing one (emb seals first, MLP after the update)
                    if topo.ckpt == CkptMode::None {
                        assert_eq!(e, RecoveryError::NoEmbLog, "{at}");
                    }
                }
                Ok(rec) => {
                    assert_ne!(topo.ckpt, CkptMode::None, "{at}: None must never recover");
                    // the twin ran the same pipeline, uncrashed, up to the
                    // recovered batch: tables must agree bit-for-bit
                    let mut twin = Rig::new(cfg, topo.clone());
                    twin.run(rec.resume_batch);
                    assert!(
                        recovered.flat().iter().all(|v| v.is_finite()),
                        "{at}: torn rows not healed"
                    );
                    assert_eq!(recovered, twin.store, "{at}: recovered tables diverge");
                    // the MLP snapshot is the batch-start params from
                    // `mlp_gap` batches before the resume point
                    assert_eq!(
                        rec.mlp_params,
                        params_at(rec.resume_batch - rec.mlp_gap),
                        "{at}: recovered MLP params diverge (gap {})",
                        rec.mlp_gap
                    );
                    // staleness stays within the relaxed bound (2x the
                    // window: a crash mid-stream falls back a generation)
                    assert!(
                        rec.mlp_gap <= 2 * topo.max_mlp_log_gap.max(1),
                        "{at}: gap {} beyond the window",
                        rec.mlp_gap
                    );
                }
            }
        }
    }
}

fn relaxed_base(name: &str) -> TopologyBuilder {
    Topology::builder(name)
        .near_data()
        .hw_movement()
        .checkpoint(CkptMode::Relaxed)
        .relaxed_lookup()
        .max_mlp_log_gap(3)
}

#[test]
fn recovery_matrix_covers_stages_modes_and_topologies() {
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();

    let cases: Vec<(&str, Topology)> = vec![
        ("redo/CXL-D", Topology::from_system(SystemConfig::CxlD)),
        ("redo/PMEM-sw", Topology::from_system(SystemConfig::Pmem)),
        ("batch-aware/CXL-B", Topology::from_system(SystemConfig::CxlB)),
        ("relaxed/CXL", relaxed_base("cxl-gap3").build().unwrap()),
        ("none/DRAM", Topology::from_system(SystemConfig::Dram)),
        (
            "tiered/batch-aware",
            Topology::builder("tiered-b")
                .near_data()
                .hw_movement()
                .checkpoint(CkptMode::BatchAware)
                .tiered_media(MediaKind::Dram, 0.4)
                .build()
                .unwrap(),
        ),
        (
            "tiered/relaxed",
            relaxed_base("tiered-r").tiered_media(MediaKind::Dram, 0.4).build().unwrap(),
        ),
        (
            "sharded/relaxed",
            relaxed_base("sharded-r").gpu_shards(2).build().unwrap(),
        ),
        (
            "tiered+sharded/relaxed",
            relaxed_base("tiered-sharded-r")
                .tiered_media(MediaKind::Dram, 0.4)
                .gpu_shards(2)
                .build()
                .unwrap(),
        ),
    ];
    for (label, topo) in cases {
        matrix_case(&cfg, &topo, label);
    }
}

#[test]
fn multi_tenant_rows_isolate_failure_domains() {
    // The multi-tenant row of the matrix: two tenants share the pool but
    // checkpoint into their own LogRegion slices. Crash the victim tenant
    // during EVERY composed stage of every batch under every CkptMode;
    // the victim must recover bit-identically to its uncrashed twin, and
    // the co-tenant's whole failure domain (tables, log region, MLP
    // params) must be byte-identical to an interference-free run.
    use std::cmp::Ordering;
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    let co_topo = Topology::from_system(SystemConfig::CxlB);
    const CO_SEED: u64 = 0x7E47;

    // the co-tenant's interference-free reference, run once
    let mut solo = Rig::with_seed(&cfg, co_topo.clone(), CO_SEED);
    solo.run(TOTAL_BATCHES);

    // every CkptMode appears as the victim's schedule
    let cases: Vec<(&str, Topology)> = vec![
        ("mt-redo/CXL-D", Topology::from_system(SystemConfig::CxlD)),
        ("mt-batch-aware/CXL-B", Topology::from_system(SystemConfig::CxlB)),
        ("mt-relaxed/CXL", relaxed_base("mt-cxl-gap3").build().unwrap()),
        ("mt-none/DRAM", Topology::from_system(SystemConfig::Dram)),
    ];
    for (label, topo) in cases {
        let n_stages = Rig::with_seed(&cfg, topo.clone(), SEED).stages.len();
        for crash_batch in 0..TOTAL_BATCHES {
            for stage_idx in 0..n_stages {
                let mut victim = Rig::with_seed(&cfg, topo.clone(), SEED);
                let mut bystander = Rig::with_seed(&cfg, co_topo.clone(), CO_SEED);
                // fair-share interleave at batch granularity: the victim
                // stops at its crash, the bystander drains its whole run
                for b in 0..TOTAL_BATCHES {
                    match b.cmp(&crash_batch) {
                        Ordering::Less => victim.run_batch(b),
                        Ordering::Equal => victim.crash_in_batch(b, stage_idx),
                        Ordering::Greater => {}
                    }
                    bystander.run_batch(b);
                }
                let stage_name = victim.stages[stage_idx];
                let at = format!("{label}: crash during '{stage_name}' of batch {crash_batch}");

                // victim recovery from ITS slice, same contract as the
                // single-tenant matrix
                let mut recovered = victim.store.clone();
                match checkpoint::recover(&mut recovered, &victim.region) {
                    Err(e) => {
                        assert!(
                            topo.ckpt == CkptMode::None || crash_batch == 0,
                            "{at}: unexpected recovery failure: {e}"
                        );
                    }
                    Ok(rec) => {
                        assert_ne!(topo.ckpt, CkptMode::None, "{at}: None must never recover");
                        let mut twin = Rig::with_seed(&cfg, topo.clone(), SEED);
                        twin.run(rec.resume_batch);
                        assert!(
                            recovered.flat().iter().all(|v| v.is_finite()),
                            "{at}: torn rows not healed"
                        );
                        assert_eq!(recovered, twin.store, "{at}: recovered tables diverge");
                    }
                }

                // the co-tenant never observes the victim's failure
                assert_eq!(bystander.store, solo.store, "{at}: co-tenant tables perturbed");
                assert_eq!(
                    bystander.region, solo.region,
                    "{at}: co-tenant log region perturbed"
                );
                assert_eq!(bystander.params, solo.params, "{at}: co-tenant params perturbed");
            }
        }
    }
}

// ------------------------------------------------- fabric-fault rows

/// The fabric-fault rows of the matrix (docs/fabric-faults.md): every
/// [`FaultKind`] x every CkptMode x the flat/tiered/sharded families,
/// with a co-tenant riding along as the multi-tenant column.
///
/// * `ExpanderLost` is crash-equivalent at the data plane: the victim's
///   in-flight update rows are torn, and undo-slice recovery must be
///   bit-identical to an uncrashed twin resumed at the same batch.
/// * `LinkDown`/`SwitchDown` are pure stalls: the victim's quanta are
///   deferred, not dropped, so after repair its whole failure domain is
///   byte-identical to a fault-free run.
/// * In every case the bystander — whose pool window lives behind a
///   different leaf — keeps tables, log region, and params untouched.
#[test]
fn fabric_fault_rows_tear_exactly_the_blast_radius() {
    use trainingcxl::sim::fabric::FaultKind;
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    let co_topo = Topology::from_system(SystemConfig::CxlB);
    const CO_SEED: u64 = 0x7E47;
    let mut solo = Rig::with_seed(&cfg, co_topo.clone(), CO_SEED);
    solo.run(TOTAL_BATCHES);

    let cases: Vec<(&str, Topology)> = vec![
        ("ff-redo/CXL-D", Topology::from_system(SystemConfig::CxlD)),
        ("ff-batch-aware/CXL-B", Topology::from_system(SystemConfig::CxlB)),
        ("ff-relaxed/CXL", relaxed_base("ff-cxl").build().unwrap()),
        ("ff-none/DRAM", Topology::from_system(SystemConfig::Dram)),
        (
            "ff-relaxed/tiered",
            relaxed_base("ff-tiered").tiered_media(MediaKind::Dram, 0.4).build().unwrap(),
        ),
        (
            "ff-relaxed/sharded",
            relaxed_base("ff-sharded").gpu_shards(2).build().unwrap(),
        ),
    ];
    for (label, topo) in &cases {
        for kind in FaultKind::ALL {
            for fault_batch in 1..TOTAL_BATCHES {
                let mut victim = Rig::with_seed(&cfg, topo.clone(), SEED);
                let mut bystander = Rig::with_seed(&cfg, co_topo.clone(), CO_SEED);
                for b in 0..fault_batch {
                    victim.run_batch(b);
                }
                if kind.tears_data() {
                    // the expander died mid-DMA: tear the in-flight
                    // update rows exactly as a power failure would
                    let upd = victim
                        .stages
                        .iter()
                        .position(|s| UPDATE_STAGES.contains(s))
                        .expect("every matrix topology has an update stage");
                    victim.crash_in_batch(fault_batch, upd);
                }
                // the bystander's window routes through a different leaf:
                // it never stalls and never defers
                bystander.run(TOTAL_BATCHES);
                let at = format!("{label}: {} at batch {fault_batch}", kind.name());

                if kind.tears_data() {
                    let mut recovered = victim.store.clone();
                    match checkpoint::recover(&mut recovered, &victim.region) {
                        Err(e) => assert_eq!(
                            topo.ckpt,
                            CkptMode::None,
                            "{at}: unexpected recovery failure: {e}"
                        ),
                        Ok(rec) => {
                            assert_ne!(topo.ckpt, CkptMode::None, "{at}: None must never recover");
                            let mut twin = Rig::with_seed(&cfg, topo.clone(), SEED);
                            twin.run(rec.resume_batch);
                            assert!(
                                recovered.flat().iter().all(|v| v.is_finite()),
                                "{at}: torn rows not healed"
                            );
                            assert_eq!(recovered, twin.store, "{at}: recovered tables diverge");
                            assert_eq!(
                                rec.mlp_params,
                                params_at(rec.resume_batch - rec.mlp_gap),
                                "{at}: recovered MLP params diverge"
                            );
                        }
                    }
                } else {
                    // a stall defers the victim's quanta; running them
                    // after the outage must land byte-identical to a
                    // fault-free run — the fault never touches data
                    for b in fault_batch..TOTAL_BATCHES {
                        victim.run_batch(b);
                    }
                    let mut twin = Rig::with_seed(&cfg, topo.clone(), SEED);
                    twin.run(TOTAL_BATCHES);
                    assert_eq!(victim.store, twin.store, "{at}: stall perturbed the tables");
                    assert_eq!(victim.region, twin.region, "{at}: stall perturbed the log");
                    assert_eq!(victim.params, twin.params, "{at}: stall perturbed the params");
                }

                // the blast radius ends at the victim's window
                assert_eq!(bystander.store, solo.store, "{at}: bystander tables perturbed");
                assert_eq!(bystander.region, solo.region, "{at}: bystander log perturbed");
                assert_eq!(bystander.params, solo.params, "{at}: bystander params perturbed");
            }
        }
    }
}

/// The timing half of the fabric-fault rows: every [`FaultKind`] x the
/// checkpoint-mode ladder, simulated at worker counts {1, 2, 4} — the
/// fault/repair events are first-class engine events, so a faulted run
/// must stay bit-identical at any worker-pool size.
#[test]
fn fabric_fault_sim_rows_are_deterministic_at_any_worker_count() {
    use trainingcxl::sim::fabric::FaultKind;
    use trainingcxl::tenancy::{FaultPlan, MultiTenantSim, QosPolicy, TenantSet, TenantSpec};
    const BATCHES: u64 = 6;
    let root = repo_root();
    for sys in [SystemConfig::CxlD, SystemConfig::CxlB, SystemConfig::Cxl] {
        for kind in FaultKind::ALL {
            let tenants = (0..2)
                .map(|i| TenantSpec {
                    name: format!("t{i}"),
                    model: "rm_mini".into(),
                    topology: Topology::from_system(sys),
                    seed: 42 + i as u64,
                    weight: 1,
                    serve: None,
                })
                .collect();
            let set = TenantSet {
                name: format!("ff-sim-{}", sys.name()),
                fabric_levels: 2,
                redundancy: 0,
                policy: QosPolicy::FairShare,
                tenants,
                faults: vec![FaultPlan {
                    kind,
                    tenant: 0,
                    level: None,
                    inject_round: 1,
                    repair_round: 3,
                }],
            };
            let at = format!("{}/{}", sys.name(), kind.name());
            let base = MultiTenantSim::new(&root, &set).unwrap().run(BATCHES);
            assert_eq!(base.faults[0].blast, vec![0], "{at}: wrong blast radius");
            for t in &base.tenants {
                assert_eq!(t.batches, BATCHES, "{at}/{}: short-served", t.name);
            }
            assert_eq!(base.tenants[1].stalled_rounds, 0, "{at}: bystander stalled");
            for workers in [2usize, 4] {
                let run = MultiTenantSim::new(&root, &set)
                    .unwrap()
                    .with_workers(workers)
                    .run(BATCHES);
                assert_eq!(run.faults, base.faults, "{at} w{workers}: fault records");
                assert_eq!(run.links, base.links, "{at} w{workers}: link stats");
                for (x, y) in run.tenants.iter().zip(&base.tenants) {
                    let who = format!("{at} w{workers}/{}", x.name);
                    assert_eq!(x.result.batch_times, y.result.batch_times, "{who}");
                    assert_eq!(x.result.total_time, y.result.total_time, "{who}");
                    assert_eq!(x.stalls, y.stalls, "{who}: stalls");
                    assert_eq!(x.pool_busy_ns, y.pool_busy_ns, "{who}: pool busy");
                    assert_eq!(x.stalled_rounds, y.stalled_rounds, "{who}: stalled rounds");
                    assert_eq!(x.fault_stall_ns, y.fault_stall_ns, "{who}: fault stall");
                    assert_eq!(x.fault_recovery_ns, y.fault_recovery_ns, "{who}: replay");
                }
            }
        }
    }
}

#[test]
fn matrix_covers_every_stateful_stage_name() {
    // If a future composition introduces a new update/log stage the rig
    // does not model, the matrix would silently test nothing for it:
    // pin that every composed stage name is either known-stateless or
    // handled by the rig.
    let known: [&str; 26] = [
        // stateless (reads / movement / GPU fwd / timing-only)
        "host-emb-lookup",
        "ndp-emb-lookup",
        "cxl-front-lookup",
        "sharded-emb-lookup",
        "tiered-emb-lookup",
        "relaxed-early-lookup",
        "sharded-early-lookup",
        "tiered-early-lookup",
        "dcoh-flush",
        "sharded-dcoh-flush",
        "shard-exchange",
        "sw-uplink-transfer",
        "sw-grad-transfer",
        "cxl-grad-flush",
        "shard-grad-reduce",
        "gpu-bottom-fwd",
        "gpu-top-mlp",
        "tier-migrate",
        "batch-end",
        "software-attribution",
        "pcie-attribution",
        "cxl-attribution",
        // stateful, modelled by the rig (plus gpu-bottom-bwd, the undo
        // legs, the updates, and the checkpoint tails listed above)
        "gpu-bottom-bwd",
        "emb-undo-log",
        "sharded-emb-undo-log",
        "tiered-emb-undo-log",
    ];
    let extra: [&str; 6] = [
        "hot-tier-flush",
        "redo-tail-ckpt",
        "host-redo-ckpt",
        "pcie-staged-redo-ckpt",
        "batch-aware-mlp-log",
        "relaxed-mlp-log",
    ];
    let all_known: Vec<&str> = known
        .iter()
        .chain(extra.iter())
        .chain(UPDATE_STAGES.iter())
        .copied()
        .collect();
    // The stages the rig treats as stateless (crashing during them tears
    // nothing): the first 22 entries of `known`. Everything else in the
    // universe must both be modelled by the rig AND declare stateful
    // effects, so the analyzer's effect table cannot drift from the
    // dynamic matrix.
    let rig_stateless: &[&str] = &known[..22];
    let topos = [
        Topology::from_system(SystemConfig::Ssd),
        Topology::from_system(SystemConfig::Pmem),
        Topology::from_system(SystemConfig::Pcie),
        Topology::from_system(SystemConfig::CxlD),
        Topology::from_system(SystemConfig::CxlB),
        Topology::from_system(SystemConfig::Cxl),
        Topology::from_system(SystemConfig::Dram),
        relaxed_base("t").tiered_media(MediaKind::Dram, 0.3).build().unwrap(),
        relaxed_base("s").gpu_shards(2).build().unwrap(),
        relaxed_base("ts").tiered_media(MediaKind::Dram, 0.3).gpu_shards(2).build().unwrap(),
    ];
    for topo in topos {
        for s in stage::compose(&topo).unwrap() {
            assert!(
                all_known.contains(&s.name()),
                "stage '{}' is not modelled by the recovery matrix rig",
                s.name()
            );
            // Cross-check against the static analyzer's effect table:
            // every reachable stage must declare effects(), and its
            // stateful/stateless classification must agree with the rig.
            let fx = s.effects();
            assert!(
                fx.declared,
                "stage '{}' is reachable from compose but declares no effects()",
                s.name()
            );
            assert_eq!(
                fx.is_stateful(),
                !rig_stateless.contains(&s.name()),
                "effect table and recovery rig disagree about '{}'",
                s.name()
            );
        }
    }
}
