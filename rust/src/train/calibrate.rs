//! Calibration: derive the per-batch MLP replay times the simulated
//! CXL-GPU uses (paper methodology: per-batch MLP cycles extracted from an
//! RTX 3090, replayed in the Vortex GPGPU).
//!
//! We have no GPU, and interpret-mode Pallas wallclock on CPU is *not* a
//! GPU proxy (the kernels run as unfused loop nests), so the replay times
//! come from an analytic FLOPs/roofline model:
//!
//! ```text
//! t_fwd = mlp_fwd_flops(batch) / gpu.effective_tflops
//! t_bwd = 1.8 * t_fwd                       (dense-layer fwd:bwd ratio)
//! ```
//!
//! `gpu.effective_tflops` is the achieved throughput of batch-32
//! tall-skinny GEMMs on the paper's RTX 3090 (~13% of 35.6 TFLOP/s peak).
//! `calibrate` also measures the artifacts' real PJRT-CPU latencies and
//! prints them for reference — they validate that the executables run,
//! not the GPU timing.
//!
//! Writes `artifacts/calibration.json`:
//!     { "<model>": [bmlp_fwd_us, bmlp_bwd_us, tmlp_fwd_us, tmlp_bwd_us] }

use crate::config::device::DeviceParams;
use crate::config::ModelConfig;
use crate::runtime::{HostTensor, ModelRuntime};
use std::path::Path;
use std::time::Instant;

/// Effective GEMM throughput of the emulated RTX 3090 on DLRM-shaped
/// batches (fraction of the 35.6 TFLOP/s fp32 peak achieved by batch-32
/// tall-skinny layers).
pub const EFFECTIVE_TFLOPS: f64 = 4.5;

/// Per-layer kernel launch/dispatch overhead on the emulated GPU (us).
pub const KERNEL_OVERHEAD_US: f64 = 20.0;

/// Analytic replay times in microseconds: [bf, bb, tf, tb].
pub fn analytic_times_us(cfg: &ModelConfig) -> [f64; 4] {
    let flops_us = |layers: &[(usize, usize)]| -> f64 {
        let flops: f64 = layers
            .iter()
            .map(|&(i, o)| 2.0 * cfg.batch_size as f64 * i as f64 * o as f64)
            .sum();
        flops / (EFFECTIVE_TFLOPS * 1e12) * 1e6 + layers.len() as f64 * KERNEL_OVERHEAD_US
    };
    let bf = flops_us(&cfg.bottom_layers());
    let tf = flops_us(&cfg.top_layers());
    [bf, 1.8 * bf, tf, 1.8 * tf]
}

/// Measure the real PJRT-CPU latency of one export (sanity report only).
pub fn measure_cpu_us(root: &Path, model: &str, export: &str) -> anyhow::Result<f64> {
    let rt = ModelRuntime::load(root, model, &[export])?;
    let spec = rt.export_spec(export).clone();
    let bufs: Vec<xla::PjRtBuffer> = spec
        .inputs
        .iter()
        .map(|s| {
            let n = s.elements();
            if s.dtype == "int32" {
                rt.to_device(&HostTensor::I32(vec![1; n], s.shape.clone()))
            } else {
                rt.to_device(&HostTensor::F32(vec![0.01; n], s.shape.clone()))
            }
        })
        .collect::<anyhow::Result<_>>()?;
    let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let out = rt.run_b(export, &args)?;
    let _ = rt.to_host_f32(&out[0])?; // warmup + completion barrier
    let mut times = Vec::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        let out = rt.run_b(export, &args)?;
        let _ = rt.to_host_f32(&out[0])?;
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(times[times.len() / 2])
}

/// Calibrate models and write `artifacts/calibration.json`. Set
/// `measure_cpu` to also time the real executables (slow for RM1-4: the
/// interpret-mode Pallas kernels are unfused on CPU).
pub fn calibrate_all(root: &Path, models: &[&str], params: &DeviceParams) -> anyhow::Result<()> {
    let _ = params;
    let mut out = String::from("{\n");
    for (i, m) in models.iter().enumerate() {
        let cfg = ModelConfig::load(root, m)?;
        let t = analytic_times_us(&cfg);
        out.push_str(&format!(
            " \"{m}\": [{:.1}, {:.1}, {:.1}, {:.1}]{}\n",
            t[0],
            t[1],
            t[2],
            t[3],
            if i + 1 < models.len() { "," } else { "" }
        ));
        eprintln!(
            "[calibrate] {m}: bmlp {:.0}us tmlp {:.0}us per batch (roofline @ {:.1} TFLOP/s)",
            t[0], t[2], EFFECTIVE_TFLOPS
        );
    }
    out.push_str("}\n");
    std::fs::write(root.join("artifacts/calibration.json"), out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    #[test]
    fn analytic_times_track_flops() {
        let root = repo_root();
        let rm1 = ModelConfig::load(&root, "rm1").unwrap();
        let rm3 = ModelConfig::load(&root, "rm3").unwrap();
        let t1 = analytic_times_us(&rm1);
        let t3 = analytic_times_us(&rm3);
        // rm3's bottom MLP (13-10240-4096-32) has ~3x rm1's FLOPs
        assert!(t3[0] > 2.0 * t1[0]);
        // bwd ratio fixed
        assert!((t1[1] / t1[0] - 1.8).abs() < 1e-9);
        // same ballpark as the checked-in fallback table (within 3x)
        let p = crate::config::device::DeviceParams::builtin_default();
        let f = p.mlp_times_us(std::path::Path::new("/nonexistent"), "rm1").unwrap();
        assert!(t1[0] > f[0] / 3.0 && t1[0] < f[0] * 3.0, "{t1:?} vs {f:?}");
    }
}
