//! Integration tests: cross-module behaviour of the simulator stack
//! (config -> workload -> devices -> scheduler -> telemetry/energy),
//! pinned to the paper's qualitative claims.

use trainingcxl::bench::experiments;
use trainingcxl::config::{DeviceParams, ModelConfig, SystemConfig};
use trainingcxl::energy::energy_of_run;
use trainingcxl::repo_root;
use trainingcxl::sim::Lane;

#[test]
fn all_models_all_configs_simulate() {
    let root = repo_root();
    for model in ["rm1", "rm2", "rm3", "rm4", "rm_mini"] {
        for sys in SystemConfig::ALL {
            let r = experiments::simulate(&root, model, sys, 5).unwrap();
            assert_eq!(r.batch_times.len(), 5);
            assert!(r.mean_batch_ns() > 0.0, "{model}/{}", sys.name());
            // breakdown accounts for the whole batch
            let bd = r.mean_breakdown();
            let mean = r.mean_batch_ns();
            assert!(
                (bd.total() - mean).abs() <= 0.03 * mean + 10.0,
                "{model}/{}: breakdown {} vs batch {}",
                model,
                bd.total(),
                mean
            );
        }
    }
}

#[test]
fn paper_config_ordering_all_models() {
    // Fig 11: each TrainingCXL stage improves (or at worst matches, when a
    // model is GPU-bound) the previous stage, for every RM; strictly for
    // the embedding-intensive models where the techniques bite.
    let root = repo_root();
    for model in ["rm1", "rm2", "rm3", "rm4"] {
        let times: Vec<f64> = SystemConfig::ALL
            .iter()
            .map(|&s| experiments::simulate(&root, model, s, 10).unwrap().mean_batch_ns())
            .collect();
        let strict = model == "rm1" || model == "rm2";
        for (i, w) in times.windows(2).enumerate() {
            // GPU-bound models may tie between adjacent stages (1% slack)
            let ok = if strict { w[0] > w[1] } else { w[0] >= 0.99 * w[1] };
            assert!(
                ok,
                "{model}: {} !>= {} ({:?})",
                SystemConfig::ALL[i].name(),
                SystemConfig::ALL[i + 1].name(),
                times
            );
        }
        assert!(times[0] > times[5], "{model}: SSD must lose to CXL");
    }
}

#[test]
fn embedding_intensive_models_gain_most() {
    // paper: RM2 (most embedding-intensive) gains more than RM4 (most
    // MLP-intensive) from TrainingCXL
    let root = repo_root();
    let speedup = |m: &str| {
        experiments::simulate(&root, m, SystemConfig::Pmem, 10)
            .unwrap()
            .mean_batch_ns()
            / experiments::simulate(&root, m, SystemConfig::Cxl, 10)
                .unwrap()
                .mean_batch_ns()
    };
    let s2 = speedup("rm2");
    let s4 = speedup("rm4");
    assert!(s2 > s4, "rm2 {s2:.2}x vs rm4 {s4:.2}x");
}

#[test]
fn energy_shape_matches_fig13() {
    let root = repo_root();
    let params = DeviceParams::load(&root).unwrap();
    let energy = |model: &str, sys: SystemConfig| {
        let cfg = ModelConfig::load(&root, model).unwrap();
        let r = experiments::simulate(&root, model, sys, 10).unwrap();
        energy_of_run(&cfg, &params, &r).total()
    };
    for model in ["rm1", "rm2", "rm3", "rm4"] {
        let cxl = energy(model, SystemConfig::Cxl);
        let pmem = energy(model, SystemConfig::Pmem);
        let ssd = energy(model, SystemConfig::Ssd);
        // CXL lowest across all RMs (paper)
        assert!(cxl < pmem && cxl < ssd, "{model}: CXL must be lowest");
    }
    // DRAM > PMEM for embedding-intensive RM2 (module count dominates)...
    assert!(energy("rm2", SystemConfig::Dram) > energy("rm2", SystemConfig::Pmem));
    // ...and PMEM > DRAM for MLP-intensive RM4 (MLP logging dominates)
    assert!(energy("rm4", SystemConfig::Pmem) > energy("rm4", SystemConfig::Dram));
}

#[test]
fn headline_band() {
    // geo-mean CXL-vs-PMEM speedup within a plausible band around 5.2x,
    // energy saving within a band around 76%
    let root = repo_root();
    let report = experiments::headline(&root, 12).unwrap();
    let speedup = report.metric("geomean_speedup").unwrap();
    assert!(
        (2.0..=12.0).contains(&speedup),
        "geo-mean speedup {speedup} outside plausible band\n{report}"
    );
}

#[test]
fn fig12_lanes_behave_like_paper() {
    let root = repo_root();
    // CXL-B: checkpoint logic busy while GPU busy (overlap); CXL-D:
    // checkpoint strictly after update (serial tail)
    let b = experiments::simulate(&root, "rm1", SystemConfig::CxlB, 6).unwrap();
    let end = b.spans.end_time();
    let ckpt_busy = b.spans.busy(Lane::CkptLogic, 0, end);
    assert!(ckpt_busy > 0);
    // utilization improves monotonically D -> B -> CXL for the PMEM lane
    let util = |sys| {
        let r = experiments::simulate(&root, "rm1", sys, 6).unwrap();
        let end = r.spans.end_time();
        r.spans.utilization(Lane::Pmem, 0, end)
    };
    let d = util(SystemConfig::CxlD);
    let c = util(SystemConfig::Cxl);
    assert!(
        c > d,
        "CXL should utilise PMEM better than CXL-D ({c:.2} vs {d:.2})"
    );
}

#[test]
fn reports_render_end_to_end() {
    let root = repo_root();
    for r in [
        experiments::fig11(&root, 4).unwrap(),
        experiments::fig13(&root, 4).unwrap(),
        experiments::fig12(&root, "rm_mini").unwrap(),
        experiments::ablate_movement(&root, 4).unwrap(),
        experiments::ablate_raw(&root, 4).unwrap(),
    ] {
        assert!(r.to_string().len() > 100);
        assert!(!r.metrics.is_empty(), "{}: metrics missing", r.experiment.name());
        // every report is JSON-round-trippable, serde-free
        let json = r.to_json().to_string();
        assert!(trainingcxl::util::json::Json::parse(&json).is_ok(), "{json}");
    }
}

#[test]
fn deterministic_simulation() {
    let root = repo_root();
    let a = experiments::simulate(&root, "rm1", SystemConfig::Cxl, 8).unwrap();
    let b = experiments::simulate(&root, "rm1", SystemConfig::Cxl, 8).unwrap();
    assert_eq!(a.batch_times, b.batch_times);
    assert_eq!(a.raw_hits, b.raw_hits);
    assert_eq!(a.traffic, b.traffic);
}

#[test]
fn expander_pooling_scales_embedding_bound_models() {
    // CXL 3.0 pooling extension: striping RM2's tables over more
    // expanders keeps improving batch time until the GPU floor.
    let root = repo_root();
    let report = experiments::pooling(&root, "rm2", 8).unwrap();
    let times: Vec<f64> = [1, 2, 4, 8]
        .iter()
        .map(|k| report.metric(&format!("batch_ms_k{k}")).unwrap())
        .collect();
    assert!(times[1] < times[0] && times[2] < times[1], "{report}");
    // GPU-bound rm4 must NOT scale much
    let r4 = experiments::pooling(&root, "rm4", 8).unwrap();
    let t4: Vec<f64> = [1, 2, 4, 8]
        .iter()
        .map(|k| r4.metric(&format!("batch_ms_k{k}")).unwrap())
        .collect();
    assert!(t4[3] > 0.8 * t4[0], "rm4 should hit the GPU floor: {r4}");
}
