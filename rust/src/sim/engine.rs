//! Discrete-event simulation core: the scheduler behind every simulator
//! in this crate.
//!
//! Three layers, smallest first:
//!
//! 1. [`EventQueue`] — a time-ordered queue with stable FIFO ordering for
//!    simultaneous events (`schedule` posts a payload at an absolute time,
//!    `pop` drains in (time, insertion-seq) order). Determinism is a hard
//!    contract: a simulation is a pure function of its inputs.
//! 2. [`Event`] + [`ResourceQueue`]/[`ResourceLedger`] — the typed event
//!    vocabulary pumped by `PipelineSim`/`ServingSim` (slot start/finish)
//!    and `MultiTenantSim` (arbiter rounds, injected crashes), plus FIFO
//!    acquisition queues keyed by the same
//!    [`Resource`](crate::analysis::effects::Resource) vocabulary the
//!    static analyzer declares in `StageEffects`.
//! 3. [`run_tasks`] — a bounded worker pool (no `unsafe`; scoped threads
//!    over a shared task deque) with index-keyed result slots, so fanning
//!    lanes out over N workers merges back byte-identical to the
//!    sequential order for any N.
//!
//! Lower-level components (memory controllers, CXL ports) are driven by
//! an owner that holds the state and pumps its own typed events; see
//! [`super::mem::controller`].
//!
//! Observability rides the same rails: every event the queue drains and
//! every ledger grant can be recorded as a typed
//! [`TraceEvent`](crate::telemetry::trace::TraceEvent) — recording
//! happens on the merge thread only (lane workers hand records back
//! with their results), so traces inherit the engine's byte-identical
//! determinism contract. See `telemetry/trace.rs` and
//! `docs/telemetry.md`.

use super::SimTime;
use crate::analysis::effects::Resource;
use crate::telemetry::trace::{TraceEvent, TraceKind, TraceLog};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Mutex;

struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest (at, seq) first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Min-heap event queue over payload type `E`.
///
/// Determinism: ties in `at` are broken by insertion order (`seq`), so a
/// simulation is a pure function of its inputs.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Post `payload` to fire at absolute time `at` (must be >= now).
    pub fn schedule(&mut self, at: SimTime, payload: E) {
        debug_assert!(
            at >= self.now,
            "scheduling into the past: {at} < {}",
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, payload });
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = self.heap.pop()?;
        self.now = ev.at;
        Some((ev.at, ev.payload))
    }
}

/// The typed event vocabulary shared by every simulator in the crate.
///
/// `PipelineSim` and `ServingSim` pump `SlotStart`/`SlotDone` pairs on
/// their private lane clock; `MultiTenantSim` pumps `RoundOpen`/
/// `RoundClose` barriers on the arbiter's round clock and arms crash
/// injection with `CrashInject` (the event-queue form of a
/// [`CrashPlan`](crate::tenancy::CrashPlan)). `lane` is the tenant/lane
/// index, `batch` the lane-local batch number.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// A lane begins stepping `batch` at the event's timestamp.
    SlotStart { lane: usize, batch: u64 },
    /// A lane finished `batch`; fires at the batch's completion time.
    SlotDone { lane: usize, batch: u64 },
    /// An arbiter round opens: every (lane, quantum) pair in the round
    /// runs against the same entry-time resource snapshot.
    RoundOpen { round: usize },
    /// All lanes of the round have merged back deterministically.
    RoundClose { round: usize },
    /// A crash is armed for `lane` at lane-local `batch` — recovery cost
    /// (torn-batch replay over the fabric) lands on the victim only.
    CrashInject { lane: usize, batch: u64 },
    /// A fabric component fails; `fault` indexes the world's fault plan
    /// table ([`FaultPlan`](crate::tenancy::FaultPlan)). Scheduled before
    /// the same-time `RoundOpen`, so the round opens against the already
    /// degraded fabric — deterministically, at any worker count.
    FabricFault { fault: usize },
    /// The component of fault plan `fault` is repaired: lanes deferred by
    /// the outage re-enter (a catch-up round) before the next scheduled
    /// round opens.
    FabricRepair { fault: usize },
}

/// FIFO acquisition queue for one serialised resource.
///
/// `acquire(at, dur)` grants the earliest slot not before `at`: the grant
/// starts at `max(at, free_at)` and occupies the resource for `dur`.
/// Totals (`busy_total`, `grants`) accumulate regardless of the caller's
/// clock, so the queue doubles as a deterministic busy ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResourceQueue {
    free_at: SimTime,
    busy_total: SimTime,
    grants: u64,
}

impl ResourceQueue {
    pub fn new() -> Self {
        ResourceQueue::default()
    }

    /// Grant `dur` of the resource, starting no earlier than `at`.
    /// Returns the granted `(start, end)` window.
    pub fn acquire(&mut self, at: SimTime, dur: SimTime) -> (SimTime, SimTime) {
        let start = self.free_at.max(at);
        let end = start + dur;
        self.free_at = end;
        self.busy_total += dur;
        self.grants += 1;
        (start, end)
    }

    /// Earliest time the next grant can start.
    pub fn free_at(&self) -> SimTime {
        self.free_at
    }

    /// Total busy time granted so far.
    pub fn busy_total(&self) -> SimTime {
        self.busy_total
    }

    /// Number of grants served.
    pub fn grants(&self) -> u64 {
        self.grants
    }
}

/// One [`ResourceQueue`] per [`Resource`] the analyzer knows about
/// (`PmemPool`, `CxlLink`, `PcieLink`, `GpuLane`).
///
/// `MultiTenantSim` charges each lane's per-round busy deltas here at
/// merge time; the `PmemPool` total *is* the global pool-pressure
/// snapshot the stall accounting reads at round entry, so the ledger is
/// load-bearing, not telemetry.
#[derive(Clone, Debug, Default)]
pub struct ResourceLedger {
    queues: [ResourceQueue; Resource::COUNT],
}

impl ResourceLedger {
    pub fn new() -> Self {
        ResourceLedger::default()
    }

    /// Append `dur` of busy time to `r`'s queue (FIFO tally: the grant
    /// starts at the queue's own `free_at`).
    pub fn charge(&mut self, r: Resource, dur: SimTime) -> (SimTime, SimTime) {
        self.queues[r.index()].acquire(0, dur)
    }

    /// [`charge`](Self::charge), recording the grant window as a
    /// [`TraceKind::Grant`] event in `trace`. The window runs on the
    /// queue's own cumulative-busy clock — one gap-free track per
    /// resource in the exported trace. Zero-duration grants charge but
    /// record nothing.
    pub fn charge_traced(
        &mut self,
        r: Resource,
        dur: SimTime,
        trace: &mut TraceLog,
        parent: Option<u32>,
        tenant: Option<u32>,
    ) -> (SimTime, SimTime) {
        let (start, end) = self.charge(r, dur);
        if dur > 0 {
            let mut ev = TraceEvent::span(parent, tenant, TraceKind::Grant, start, end);
            ev.resource = Some(r);
            trace.record(ev);
        }
        (start, end)
    }

    /// Total busy time charged against `r`.
    pub fn busy(&self, r: Resource) -> SimTime {
        self.queues[r.index()].busy_total()
    }

    /// Grants served against `r`.
    pub fn grants(&self, r: Resource) -> u64 {
        self.queues[r.index()].grants()
    }

    /// The queue behind `r`, for callers that need the full record.
    pub fn queue(&self, r: Resource) -> &ResourceQueue {
        &self.queues[r.index()]
    }
}

/// Run `tasks` over a pool of `workers` scoped threads and return the
/// results **in task order**, regardless of which worker ran what.
///
/// Each worker pops `(index, task)` pairs off a shared deque and writes
/// `f(index, task)` into the result slot for that index, so the output is
/// byte-identical for any worker count — including the `workers <= 1`
/// fast path, which runs inline with no threads at all. `f` must be
/// `Sync` (shared by reference across workers) and self-contained per
/// task; cross-task state belongs in the caller's deterministic merge.
pub fn run_tasks<T, R>(tasks: Vec<T>, workers: usize, f: impl Fn(usize, T) -> R + Sync) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = tasks.len();
    if workers <= 1 || n <= 1 {
        return tasks.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let queue: Mutex<VecDeque<(usize, T)>> = Mutex::new(tasks.into_iter().enumerate().collect());
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let next = queue.lock().expect("task queue poisoned").pop_front();
                match next {
                    Some((i, t)) => {
                        let r = f(i, t);
                        slots.lock().expect("result slots poisoned")[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots
        .into_inner()
        .expect("result slots poisoned")
        .into_iter()
        .map(|r| r.expect("every task writes its slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_for_ties_and_time_order_overall() {
        let mut q: EventQueue<&'static str> = EventQueue::new();
        q.schedule(10, "b");
        q.schedule(5, "a");
        q.schedule(10, "c");
        q.schedule(20, "d");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(5, "a"), (10, "b"), (10, "c"), (20, "d")]);
    }

    #[test]
    fn now_advances_monotonically() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(3, 1);
        q.schedule(7, 2);
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 3);
        q.pop();
        assert_eq!(q.now(), 7);
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_schedule_pop() {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.schedule(1, 1);
        let (t, v) = q.pop().unwrap();
        assert_eq!((t, v), (1, 1));
        // rescheduling relative to now
        q.schedule(q.now() + 4, 2);
        q.schedule(q.now() + 2, 3);
        assert_eq!(q.pop().unwrap(), (3, 3));
        assert_eq!(q.pop().unwrap(), (5, 2));
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore)]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_scheduling() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(10, 1);
        q.pop();
        q.schedule(5, 2);
    }

    #[test]
    fn typed_events_drain_in_causal_order() {
        let mut q: EventQueue<Event> = EventQueue::new();
        q.schedule(0, Event::CrashInject { lane: 1, batch: 3 });
        q.schedule(0, Event::FabricFault { fault: 0 });
        q.schedule(2, Event::FabricRepair { fault: 0 });
        q.schedule(0, Event::RoundOpen { round: 0 });
        q.schedule(7, Event::SlotDone { lane: 0, batch: 0 });
        q.schedule(0, Event::SlotStart { lane: 0, batch: 0 });
        q.schedule(2, Event::RoundOpen { round: 2 });
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        // ties drain in insertion order: the injected crash and the
        // fabric fault are armed before the round that might hit them
        // opens, and a repair lands before its same-time round so the
        // deferred lanes re-enter first.
        assert_eq!(
            order,
            vec![
                Event::CrashInject { lane: 1, batch: 3 },
                Event::FabricFault { fault: 0 },
                Event::RoundOpen { round: 0 },
                Event::SlotStart { lane: 0, batch: 0 },
                Event::FabricRepair { fault: 0 },
                Event::RoundOpen { round: 2 },
                Event::SlotDone { lane: 0, batch: 0 },
            ]
        );
    }

    #[test]
    fn resource_queue_serialises_grants_fifo() {
        let mut q = ResourceQueue::new();
        assert_eq!(q.acquire(10, 5), (10, 15)); // idle: starts on request
        assert_eq!(q.acquire(0, 3), (15, 18)); // busy: queued behind grant 1
        assert_eq!(q.acquire(100, 2), (100, 102)); // idle gap: jumps ahead
        assert_eq!(q.free_at(), 102);
        assert_eq!(q.busy_total(), 10);
        assert_eq!(q.grants(), 3);
    }

    #[test]
    fn ledger_keys_by_analyzer_resource() {
        let mut ledger = ResourceLedger::new();
        ledger.charge(Resource::PmemPool, 40);
        ledger.charge(Resource::PmemPool, 2);
        ledger.charge(Resource::GpuLane, 7);
        assert_eq!(ledger.busy(Resource::PmemPool), 42);
        assert_eq!(ledger.grants(Resource::PmemPool), 2);
        assert_eq!(ledger.busy(Resource::GpuLane), 7);
        assert_eq!(ledger.busy(Resource::CxlLink), 0);
        assert_eq!(ledger.queue(Resource::PcieLink).grants(), 0);
    }

    #[test]
    fn run_tasks_preserves_task_order_at_any_worker_count() {
        let tasks: Vec<u64> = (0..97).collect();
        let expect: Vec<u64> = tasks.iter().map(|t| t * t + 1).collect();
        for workers in [0, 1, 2, 4, 16] {
            let got = run_tasks(tasks.clone(), workers, |i, t| {
                assert_eq!(i as u64, t);
                t * t + 1
            });
            assert_eq!(got, expect, "workers={workers}");
        }
    }

    #[test]
    fn run_tasks_handles_degenerate_shapes() {
        let none: Vec<u64> = run_tasks(Vec::new(), 4, |_, t: u64| t);
        assert!(none.is_empty());
        let one = run_tasks(vec![9u64], 4, |_, t| t + 1);
        assert_eq!(one, vec![10]);
    }
}
