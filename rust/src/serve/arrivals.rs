//! Deterministic open-loop arrival generation.
//!
//! Inference load is open-loop: requests arrive on the wall clock whether
//! or not the server keeps up (millions of independent users do not wait
//! for each other), which is what makes tail latency meaningful — a slow
//! server builds backlog instead of slowing the offered load. The base
//! process is Poisson at `rate_per_s`; [`TraceShape`] modulates it with a
//! diurnal swing or a load spike via Lewis-Shedler thinning: candidate
//! arrivals are drawn at the peak rate and accepted with probability
//! `rate(t) / rate_max`, which stays exact for any bounded rate function
//! and deterministic for a fixed seed.

use crate::sim::SimTime;
use crate::util::Rng;

/// Shape of the offered-load curve over simulated time. The non-steady
/// shapes are scaled to simulator time: a "day" of user traffic is
/// compressed into milliseconds so reduced-iteration runs still sweep a
/// full cycle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceShape {
    /// Constant rate.
    Steady,
    /// Sinusoidal swing: `rate * (1 + amplitude * sin(2π t / period))`.
    Diurnal { period_s: f64, amplitude: f64 },
    /// Flash crowd: `rate * factor` inside `[at_s, at_s + dur_s)`.
    Spike { at_s: f64, dur_s: f64, factor: f64 },
}

impl TraceShape {
    /// Parse a TOML-level trace name into its canonical shape.
    pub fn parse(s: &str) -> Option<TraceShape> {
        match s {
            "steady" => Some(TraceShape::Steady),
            "diurnal" => Some(TraceShape::Diurnal {
                period_s: 0.01,
                amplitude: 0.5,
            }),
            "spike" => Some(TraceShape::Spike {
                at_s: 0.002,
                dur_s: 0.002,
                factor: 4.0,
            }),
            _ => None,
        }
    }

    /// Peak-to-base rate ratio — the thinning envelope.
    fn peak_factor(&self) -> f64 {
        match *self {
            TraceShape::Steady => 1.0,
            TraceShape::Diurnal { amplitude, .. } => 1.0 + amplitude.clamp(0.0, 0.999),
            TraceShape::Spike { factor, .. } => factor.max(1.0),
        }
    }
}

/// Seeded open-loop arrival stream: monotonically increasing request
/// timestamps (ns), one simulated stream per server tenant.
pub struct ArrivalProcess {
    rng: Rng,
    base: f64,
    shape: TraceShape,
    /// Simulated clock of the last candidate arrival.
    t: SimTime,
    /// Thinning envelope rate (req/s), >= rate(t) for all t.
    lmax: f64,
}

impl ArrivalProcess {
    /// `rate_per_s` must be finite and positive (the TOML layer rejects
    /// anything else with a typed error); a defensive floor keeps a
    /// hand-constructed bad rate from hanging the thinning loop.
    pub fn new(seed: u64, rate_per_s: f64, shape: TraceShape) -> ArrivalProcess {
        debug_assert!(
            rate_per_s.is_finite() && rate_per_s > 0.0,
            "arrival rate must be finite and positive, got {rate_per_s}"
        );
        let base = if rate_per_s.is_finite() && rate_per_s > 0.0 {
            rate_per_s
        } else {
            1.0
        };
        ArrivalProcess {
            rng: Rng::new(seed ^ 0xA881_7A15_0E5E_87ED),
            base,
            lmax: base * shape.peak_factor(),
            shape,
            t: 0,
        }
    }

    /// Offered rate (req/s) at simulated time `t`.
    fn rate_at(&self, t: SimTime) -> f64 {
        let ts = t as f64 / 1e9;
        match self.shape {
            TraceShape::Steady => self.base,
            TraceShape::Diurnal { period_s, amplitude } => {
                let w = std::f64::consts::TAU * ts / period_s.max(1e-9);
                self.base * (1.0 + amplitude.clamp(0.0, 0.999) * w.sin())
            }
            TraceShape::Spike { at_s, dur_s, factor } => {
                if ts >= at_s && ts < at_s + dur_s {
                    self.base * factor.max(1.0)
                } else {
                    self.base
                }
            }
        }
    }

    /// Timestamp of the next request (ns), strictly after the previous
    /// one.
    pub fn next_arrival(&mut self) -> SimTime {
        loop {
            let u = self.rng.next_f64();
            // exponential inter-arrival at the envelope rate; (1 - u) is
            // in (0, 1] so the log is finite
            let dt_s = -(1.0 - u).ln() / self.lmax;
            let dt = (dt_s * 1e9).ceil() as SimTime;
            self.t += dt.max(1);
            if self.rng.next_f64() * self.lmax <= self.rate_at(self.t) {
                return self.t;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_deterministic_and_monotonic() {
        let draw = |seed| {
            let mut p = ArrivalProcess::new(seed, 10_000.0, TraceShape::Steady);
            (0..200).map(|_| p.next_arrival()).collect::<Vec<_>>()
        };
        let a = draw(7);
        assert_eq!(a, draw(7), "same seed must replay the same stream");
        assert_ne!(a, draw(8), "different seeds must diverge");
        assert!(a.windows(2).all(|w| w[0] < w[1]), "timestamps must increase");
    }

    #[test]
    fn steady_rate_matches_poisson_mean() {
        let rate = 50_000.0;
        let mut p = ArrivalProcess::new(42, rate, TraceShape::Steady);
        let n = 20_000;
        let mut last = 0;
        for _ in 0..n {
            last = p.next_arrival();
        }
        let measured = n as f64 / (last as f64 / 1e9);
        assert!(
            (measured - rate).abs() < 0.05 * rate,
            "measured {measured} vs configured {rate}"
        );
    }

    #[test]
    fn spike_concentrates_arrivals_in_its_window() {
        let shape = TraceShape::Spike {
            at_s: 0.001,
            dur_s: 0.001,
            factor: 8.0,
        };
        let mut p = ArrivalProcess::new(3, 100_000.0, shape);
        let (mut inside, mut before) = (0u64, 0u64);
        loop {
            let t = p.next_arrival();
            if t >= 2_000_000 {
                break;
            }
            if t < 1_000_000 {
                before += 1;
            } else {
                inside += 1;
            }
        }
        assert!(
            inside as f64 > 4.0 * before as f64,
            "spike window {inside} vs baseline {before}"
        );
    }

    #[test]
    fn diurnal_swings_the_rate_around_the_base() {
        let shape = TraceShape::Diurnal {
            period_s: 0.01,
            amplitude: 0.5,
        };
        let p = ArrivalProcess::new(1, 1000.0, shape);
        // quarter period = peak, three quarters = trough
        let peak = p.rate_at(2_500_000);
        let trough = p.rate_at(7_500_000);
        assert!(peak > 1400.0 && peak <= 1500.0, "peak {peak}");
        assert!(trough < 600.0 && trough >= 500.0, "trough {trough}");
    }

    #[test]
    fn trace_names_parse() {
        assert_eq!(TraceShape::parse("steady"), Some(TraceShape::Steady));
        assert!(matches!(
            TraceShape::parse("diurnal"),
            Some(TraceShape::Diurnal { .. })
        ));
        assert!(matches!(
            TraceShape::parse("spike"),
            Some(TraceShape::Spike { .. })
        ));
        assert_eq!(TraceShape::parse("bursty"), None);
    }
}
