//! Artifact manifest (`artifacts/<model>/manifest.json`, written by
//! `python/compile/aot.py`): shapes, dtypes, parameter layout and export
//! table for one compiled model.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor spec: shape + dtype string ("float32" / "int32").
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow::anyhow!("spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<_>>()?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow::anyhow!("spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One exported function (train_step / forward / ...).
#[derive(Clone, Debug)]
pub struct ExportSpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub param_count: usize,
    /// Flat parameter layout: (name, shape), table last.
    pub params: Vec<(String, Vec<usize>)>,
    pub exports: BTreeMap<String, ExportSpec>,
    pub lr: f64,
    pub batch_size: usize,
}

impl Manifest {
    /// Shape of parameter `name`, looked up by name rather than position —
    /// manifest ordering (the python side emits "table last" today) must
    /// never silently bind the wrong shape.
    pub fn param_shape(&self, name: &str) -> anyhow::Result<&[usize]> {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.as_slice())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "manifest for '{}' has no param '{name}' (params: {})",
                    self.model,
                    self.params
                        .iter()
                        .map(|(n, _)| n.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn load(model_dir: &Path) -> anyhow::Result<Manifest> {
        let path = model_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display())
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;

        let model = j
            .get("model")
            .and_then(|m| m.as_str())
            .ok_or_else(|| anyhow::anyhow!("manifest missing model"))?
            .to_string();
        let config = j.get("config").ok_or_else(|| anyhow::anyhow!("missing config"))?;
        let param_count = config
            .get("param_count")
            .and_then(|v| v.as_usize())
            .unwrap_or(0);
        let lr = config.get("lr").and_then(|v| v.as_f64()).unwrap_or(0.01);
        let batch_size = config
            .get("batch_size")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing batch_size"))?;

        let params = j
            .get("params")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing params"))?
            .iter()
            .map(|p| {
                let name = p
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| anyhow::anyhow!("param missing name"))?
                    .to_string();
                let shape = p
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow::anyhow!("param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
                    .collect::<anyhow::Result<Vec<usize>>>()?;
                Ok((name, shape))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;

        let mut exports = BTreeMap::new();
        for (name, e) in j
            .get("exports")
            .and_then(|e| e.as_obj())
            .ok_or_else(|| anyhow::anyhow!("missing exports"))?
        {
            let file = model_dir.join(
                e.get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow::anyhow!("export missing file"))?,
            );
            let inputs = e
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow::anyhow!("export missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<_>>()?;
            let outputs = e
                .get("outputs")
                .and_then(|o| o.as_arr())
                .ok_or_else(|| anyhow::anyhow!("export missing outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<_>>()?;
            exports.insert(
                name.clone(),
                ExportSpec {
                    file,
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest {
            model,
            param_count,
            params,
            exports,
            lr,
            batch_size,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    fn mini_dir() -> PathBuf {
        repo_root().join("artifacts/rm_mini")
    }

    #[test]
    fn param_shape_is_ordering_independent() {
        // a manifest whose table is NOT last (a future python layout
        // change) must still bind the right shapes by name
        let m = Manifest {
            model: "synthetic".into(),
            param_count: 0,
            params: vec![
                ("table".into(), vec![4, 128, 8]),
                ("bot_w0".into(), vec![13, 32]),
                ("bot_b0".into(), vec![32]),
            ],
            exports: BTreeMap::new(),
            lr: 0.01,
            batch_size: 32,
        };
        assert_eq!(m.param_shape("table").unwrap(), &[4, 128, 8]);
        assert_eq!(m.param_shape("bot_w0").unwrap(), &[13, 32]);
        // the old positional assumption would have bound bot_b0's shape
        assert_ne!(m.params.last().unwrap().0, "table");
        let err = m.param_shape("nope").unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("table"), "{err}");
    }

    #[test]
    fn loads_rm_mini_manifest() {
        if !mini_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&mini_dir()).unwrap();
        assert_eq!(m.model, "rm_mini");
        assert_eq!(m.params.last().unwrap().0, "table");
        assert_eq!(m.params.last().unwrap().1, vec![4, 128, 8]);
        for name in [
            "train_step",
            "forward",
            "bottom_mlp",
            "top_mlp",
            "embedding_bag",
            "embedding_update",
        ] {
            let e = &m.exports[name];
            assert!(e.file.exists(), "{name} artifact missing");
            assert!(!e.inputs.is_empty());
            assert!(!e.outputs.is_empty());
        }
        // train_step: inputs = params + dense + indices + labels
        let ts = &m.exports["train_step"];
        assert_eq!(ts.inputs.len(), m.params.len() + 3);
        // outputs = new params + loss
        assert_eq!(ts.outputs.len(), m.params.len() + 1);
        assert_eq!(ts.outputs.last().unwrap().shape, Vec::<usize>::new());
        // layout agreement with the config loader
        let cfg = crate::config::ModelConfig::load(&repo_root(), "rm_mini").unwrap();
        let total: usize = m.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(total, cfg.param_count());
        assert_eq!(m.param_count, cfg.param_count());
    }
}
