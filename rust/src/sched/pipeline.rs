//! The per-configuration batch pipelines (paper Fig 4, 6, 8, 9b, 12).
//!
//! One [`PipelineSim`] simulates `n` training batches of one model under
//! one [`SystemConfig`], producing exact per-lane busy intervals and a
//! critical-path time breakdown per batch. The pipelines:
//!
//! * **SSD / PMEM** (software): host CPU performs embedding ops against
//!   the storage medium; every producer/consumer handoff pays
//!   sync + memcpy + kernel-launch; redo-log checkpoint on the critical
//!   path at the end of each batch (Fig 4a).
//! * **PCIe**: near-data processing on the expander, but movement is still
//!   software and checkpointing still redo.
//! * **CXL-D**: automatic data movement — DCOH flushes replace the
//!   software path (Fig 4b/5); MLP redo-logging overlaps with the
//!   embedding update via CXL.cache; embedding redo-log still serial.
//! * **CXL-B**: + batch-aware *undo* checkpoint in CXL-MEM idle time
//!   (Fig 6/7): embedding log after lookup, MLP log behind it; the update
//!   waits for the embedding log (can't overwrite unlogged rows).
//! * **CXL**: + relaxed embedding lookup (batch N+1's lookup runs in
//!   batch N against the old table — no RAW, off the critical path;
//!   Fig 8) and relaxed batch-aware checkpoint (MLP logging only inside
//!   the GPU's interaction+top-MLP window, spread over batches; Fig 9b).
//!
//! PMEM-backend contention is explicit: every operation touching the
//! expander's PMEM serialises through `pmem_free`, which is how
//! checkpoint overhead becomes visible exactly as in Fig 12b.

use crate::config::device::DeviceParams;
use crate::config::sysconfig::{CkptMode, SystemConfig, SystemKnobs};
use crate::config::ModelConfig;
use crate::devices::{CxlGpu, CxlMem, HostCpu};
use crate::sim::cxl::{Link, Proto};
use crate::sim::mem::{MediaKind, MediaModel};
use crate::sim::{Lane, OpKind, SimTime};
use crate::telemetry::{Breakdown, SpanLog, TrafficCounters};
use crate::workload::BatchStats;

/// Everything a simulated run produced.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub config: SystemConfig,
    pub model: String,
    pub spans: SpanLog,
    /// Critical-path breakdown per batch (ns components).
    pub breakdowns: Vec<Breakdown>,
    pub batch_times: Vec<SimTime>,
    pub traffic: TrafficCounters,
    pub total_time: SimTime,
    /// RAW-penalised accesses observed (ablation metric).
    pub raw_hits: u64,
    /// Largest embedding/MLP-log gap reached (batches).
    pub max_mlp_gap: u64,
    /// GPU busy ns (energy accounting).
    pub gpu_busy: SimTime,
    /// Host CPU busy ns.
    pub host_busy: SimTime,
    /// Computing+checkpointing logic busy ns.
    pub logic_busy: SimTime,
}

impl RunResult {
    /// Mean batch latency over the steady-state window (skips warmup).
    pub fn mean_batch_ns(&self) -> f64 {
        let skip = self.batch_times.len().min(2);
        let xs = &self.batch_times[skip..];
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().map(|&t| t as f64).sum::<f64>() / xs.len() as f64
    }

    /// Mean steady-state breakdown.
    pub fn mean_breakdown(&self) -> Breakdown {
        let skip = self.breakdowns.len().min(2);
        let xs = &self.breakdowns[skip..];
        let mut acc = Breakdown::default();
        for b in xs {
            acc.add(b);
        }
        acc.scale(1.0 / xs.len().max(1) as f64)
    }
}

/// Batch-pipeline simulator for one (model, config) pair.
pub struct PipelineSim {
    cfg: ModelConfig,
    knobs: SystemKnobs,
    gpu: CxlGpu,
    mem: CxlMem,
    host: HostCpu,
    table: MediaModel,
    dram: MediaModel,
    cxl: Link,
    pcie: Link,
    stats: BatchStats,

    // run state
    spans: SpanLog,
    traffic: TrafficCounters,
    raw_hits: u64,
    /// PMEM/SSD backend is a single serialised resource.
    pmem_free: SimTime,
    /// Relaxed lookup: completion time of the early lookup for the next
    /// batch (None on the first batch).
    early_lookup_done: Option<SimTime>,
    /// Relaxed checkpoint: (snapshot batch, bytes remaining) of the MLP
    /// log in flight.
    mlp_inflight: Option<(u64, u64)>,
    /// Differential MLP checkpoint payload per generation (bytes).
    mlp_log_bytes: u64,
    max_mlp_gap: u64,
    gpu_busy: SimTime,
    host_busy: SimTime,
    logic_busy: SimTime,
}

impl PipelineSim {
    /// `stats` should come from [`crate::workload::Generator::average_stats`]
    /// with the config-appropriate cache fraction.
    pub fn new(
        cfg: &ModelConfig,
        config: SystemConfig,
        params: &DeviceParams,
        gpu: CxlGpu,
        stats: BatchStats,
    ) -> PipelineSim {
        let knobs = config.knobs();
        let table_media = match knobs.table_media {
            MediaKind::Dram => MediaModel::new(MediaKind::Dram, params.dram.clone()),
            MediaKind::Pmem => MediaModel::new(MediaKind::Pmem, params.pmem.clone()),
            MediaKind::Ssd => MediaModel::new(MediaKind::Ssd, params.ssd.clone()),
        };
        PipelineSim {
            cfg: cfg.clone(),
            knobs,
            gpu,
            mem: CxlMem::new(cfg, params),
            host: HostCpu::new(cfg.row_bytes(), params),
            table: table_media,
            dram: MediaModel::new(MediaKind::Dram, params.dram.clone()),
            cxl: Link::new(params.cxl_link.clone()),
            pcie: Link::new(params.pcie_link.clone()),
            stats,
            spans: SpanLog::default(),
            traffic: TrafficCounters::default(),
            raw_hits: 0,
            pmem_free: 0,
            early_lookup_done: None,
            mlp_inflight: None,
            mlp_log_bytes: (cfg.mlp_param_bytes() as f64 * params.ckpt_logic.mlp_log_frac).ceil()
                as u64,
            max_mlp_gap: 0,
            gpu_busy: 0,
            host_busy: 0,
            logic_busy: 0,
        }
    }

    fn table_medium_name(&self) -> &'static str {
        match self.knobs.table_media {
            MediaKind::Dram => "dram",
            MediaKind::Pmem => "pmem",
            MediaKind::Ssd => "ssd",
        }
    }

    fn reduced_bytes(&self) -> u64 {
        (self.cfg.batch_size * self.cfg.num_tables * self.cfg.feature_dim * 4) as u64
    }

    fn record_media(&mut self, cost: &crate::sim::mem::AccessCost, medium: &'static str) {
        self.traffic.record(medium, cost.bytes_read, cost.bytes_written);
        self.raw_hits += cost.raw_hits;
    }

    /// Scale the expander pool: `k` CXL-MEM devices behind the switch
    /// (CXL 3.0 multi-level switching, paper §Related Work). Tables are
    /// striped across all pooled backends, multiplying PMEM channel
    /// parallelism; each extra switch level adds hop latency to the link.
    pub fn with_expander_pool(mut self, k: usize, extra_hops: usize) -> Self {
        assert!(k >= 1);
        self.table.p.channels *= k;
        self.cxl.p.hops += extra_hops;
        self
    }

    /// Run `n` batches; returns the accumulated result.
    pub fn run(mut self, n: u64) -> RunResult {
        let mut t = 0;
        let mut breakdowns = Vec::with_capacity(n as usize);
        let mut batch_times = Vec::with_capacity(n as usize);
        for batch in 0..n {
            let (end, bd) = self.step(batch, t);
            debug_assert!(end > t, "batch must advance time");
            breakdowns.push(bd);
            batch_times.push(end - t);
            t = end;
        }
        RunResult {
            config: self.knobs.config,
            model: self.cfg.name.clone(),
            spans: self.spans,
            breakdowns,
            batch_times,
            traffic: self.traffic,
            total_time: t,
            raw_hits: self.raw_hits,
            max_mlp_gap: self.max_mlp_gap,
            gpu_busy: self.gpu_busy,
            host_busy: self.host_busy,
            logic_busy: self.logic_busy,
        }
    }

    /// Simulate one batch starting at `t0`; returns (end time, breakdown).
    fn step(&mut self, batch: u64, t0: SimTime) -> (SimTime, Breakdown) {
        match (
            self.knobs.near_data_processing,
            self.knobs.hw_data_movement,
        ) {
            (false, false) => self.step_software(batch, t0),
            (true, false) => self.step_pcie(batch, t0),
            (true, true) => self.step_cxl(batch, t0),
            (false, true) => unreachable!("hw movement requires NDP"),
        }
    }

    // ---------------------------------------------------------- software

    /// SSD / PMEM / DRAM-ideal: host CPU embedding ops + sync/memcpy.
    fn step_software(&mut self, batch: u64, t0: SimTime) -> (SimTime, Breakdown) {
        let s = self.stats;
        let medium = self.table_medium_name();
        let raw_frac = if self.knobs.table_media == MediaKind::Pmem {
            s.prev_overlap
        } else {
            0.0
        };
        let cache = if self.knobs.dram_vector_cache {
            s.hot_hit_frac
        } else {
            0.0
        };

        // embedding lookup on host, gated by the storage tier
        let lk_start = self.pmem_free.max(t0);
        let lk = self.host.embedding_lookup(
            lk_start,
            &mut self.table,
            &mut self.dram,
            s.accesses,
            cache,
            raw_frac,
        );
        let lk_end = lk_start + lk.duration;
        self.pmem_free = lk_end;
        self.record_media(&lk.media, medium);
        self.spans.add(Lane::HostCpu, OpKind::EmbLookup, batch, lk_start, lk_end);
        self.spans.add(Lane::Pmem, OpKind::EmbLookup, batch, lk_start, lk_end);
        self.host_busy += lk.duration;

        // bottom-MLP forward on GPU (after a kernel launch)
        let bf_start = t0 + self.host.p.kernel_launch_ns as SimTime;
        let bf_end = bf_start + self.gpu.bmlp_fwd;
        self.spans.add(Lane::Gpu, OpKind::BottomMlp, batch, bf_start, bf_end);

        // software transfer of the reduced vectors to the GPU
        let xf_start = lk_end.max(bf_end);
        let xf = self.host.sw_transfer(&self.pcie, self.reduced_bytes());
        let xf_end = xf_start + xf.duration;
        self.traffic.record_link(xf.link_bytes);
        self.spans.add(Lane::HostCpu, OpKind::Transfer, batch, xf_start, xf_end);
        self.host_busy += xf.duration;

        // interaction + top-MLP fwd+bwd
        let tm_end = xf_end + self.gpu.tmlp_total();
        self.spans.add(Lane::Gpu, OpKind::TopMlp, batch, xf_end, tm_end);

        // gradient copy back + bottom-MLP backward in parallel
        let gx = self.host.sw_transfer(&self.pcie, self.reduced_bytes());
        let gx_end = tm_end + gx.duration;
        self.traffic.record_link(gx.link_bytes);
        self.spans.add(Lane::HostCpu, OpKind::Transfer, batch, tm_end, gx_end);
        self.host_busy += gx.duration;
        let bb_end = tm_end + self.gpu.bmlp_bwd;
        self.spans.add(Lane::Gpu, OpKind::BottomMlp, batch, tm_end, bb_end);
        self.gpu_busy += self.gpu.gpu_busy();

        // embedding update on host
        let up_start = gx_end.max(self.pmem_free);
        let up = self
            .host
            .embedding_update(up_start, &mut self.table, s.unique_rows);
        let up_end = up_start + up.duration;
        self.pmem_free = up_end;
        self.record_media(&up.media, medium);
        self.spans.add(Lane::HostCpu, OpKind::EmbUpdate, batch, up_start, up_end);
        self.spans.add(Lane::Pmem, OpKind::EmbUpdate, batch, up_start, up_end);
        self.host_busy += up.duration;

        // redo-log checkpoint on the critical path (skipped by DRAM ideal)
        let mut end = up_end.max(bb_end);
        let mut ck_dur = 0;
        if self.knobs.ckpt == CkptMode::Redo {
            let ck_start = end.max(self.pmem_free);
            let ck = self.host.redo_checkpoint(
                ck_start,
                &mut self.table,
                &self.pcie,
                s.unique_rows,
                self.mlp_log_bytes,
            );
            let ck_end = ck_start + ck.duration;
            self.pmem_free = ck_end;
            self.record_media(&ck.media, medium);
            self.traffic.record_link(ck.link_bytes);
            self.spans.add(Lane::HostCpu, OpKind::CkptEmb, batch, ck_start, ck_end);
            self.spans.add(Lane::Pmem, OpKind::CkptEmb, batch, ck_start, ck_end);
            self.host_busy += ck.duration;
            ck_dur = ck.duration;
            end = ck_end;
        }

        // ---- critical-path attribution
        let mut bd = Breakdown::default();
        let fwd_ready = xf_end;
        if lk_end >= bf_end {
            bd.embedding += (lk_end - t0) as f64;
            bd.transfer += (fwd_ready - lk_end) as f64;
        } else {
            bd.bmlp += (bf_end - t0) as f64;
            bd.transfer += (fwd_ready - bf_end) as f64;
        }
        bd.tmlp += self.gpu.tmlp_total() as f64;
        // post-tmlp tail
        let tail_end = up_end.max(bb_end);
        if up_end >= bb_end {
            bd.transfer += (gx_end - tm_end) as f64;
            bd.embedding += (up_end - gx_end) as f64;
        } else {
            bd.bmlp += (bb_end - tm_end) as f64;
        }
        bd.checkpoint += (end - tail_end) as f64 + 0.0_f64.min(ck_dur as f64);
        (end, bd)
    }

    // -------------------------------------------------------------- pcie

    /// PCIe-attached PMEM: near-data embedding ops, software movement,
    /// device-DMA redo checkpoint.
    fn step_pcie(&mut self, batch: u64, t0: SimTime) -> (SimTime, Breakdown) {
        let s = self.stats;
        let lk_start = self.pmem_free.max(t0 + self.host.p.kernel_launch_ns as SimTime);
        let lk = self
            .mem
            .embedding_lookup(lk_start, &mut self.table, s.accesses, s.prev_overlap);
        let lk_end = lk_start + lk.duration;
        self.pmem_free = lk_end;
        self.record_media(&lk.media, "pmem");
        self.spans.add(Lane::CompLogic, OpKind::EmbLookup, batch, lk_start, lk_end);
        self.spans.add(Lane::Pmem, OpKind::EmbLookup, batch, lk_start, lk_end);
        self.logic_busy += lk.duration;

        let bf_end = t0 + self.host.p.kernel_launch_ns as SimTime + self.gpu.bmlp_fwd;
        self.spans.add(Lane::Gpu, OpKind::BottomMlp, batch, bf_end - self.gpu.bmlp_fwd, bf_end);

        let xf_start = lk_end.max(bf_end);
        let xf = self.host.sw_transfer(&self.pcie, self.reduced_bytes());
        let xf_end = xf_start + xf.duration;
        self.traffic.record_link(xf.link_bytes);
        self.spans.add(Lane::HostCpu, OpKind::Transfer, batch, xf_start, xf_end);
        self.host_busy += xf.duration;

        let tm_end = xf_end + self.gpu.tmlp_total();
        self.spans.add(Lane::Gpu, OpKind::TopMlp, batch, xf_end, tm_end);
        let gx = self.host.sw_transfer(&self.pcie, self.reduced_bytes());
        let gx_end = tm_end + gx.duration;
        self.traffic.record_link(gx.link_bytes);
        self.spans.add(Lane::HostCpu, OpKind::Transfer, batch, tm_end, gx_end);
        self.host_busy += gx.duration;
        let bb_end = tm_end + self.gpu.bmlp_bwd;
        self.spans.add(Lane::Gpu, OpKind::BottomMlp, batch, tm_end, bb_end);
        self.gpu_busy += self.gpu.gpu_busy();

        let up_start = gx_end.max(self.pmem_free);
        let up = self.mem.embedding_update(up_start, &mut self.table, s.unique_rows, 0);
        let up_end = up_start + up.duration;
        self.pmem_free = up_end;
        self.record_media(&up.media, "pmem");
        self.spans.add(Lane::CompLogic, OpKind::EmbUpdate, batch, up_start, up_end);
        self.spans.add(Lane::Pmem, OpKind::EmbUpdate, batch, up_start, up_end);
        self.logic_busy += up.duration;

        // MLP params staged over PCIe once bottom bwd commits, then the
        // device DMA writes the redo log
        let stage = self.host.sw_transfer(&self.pcie, self.mlp_log_bytes);
        let stage_end = bb_end + stage.duration;
        self.traffic.record_link(stage.link_bytes);
        self.spans.add(Lane::HostCpu, OpKind::CkptMlp, batch, bb_end, stage_end);
        self.host_busy += stage.duration;
        let ck_start = up_end.max(stage_end).max(self.pmem_free);
        let ck = self
            .mem
            .redo_log(ck_start, &mut self.table, s.unique_rows, self.mlp_log_bytes);
        let ck_end = ck_start + ck.duration;
        self.pmem_free = ck_end;
        self.record_media(&ck.media, "pmem");
        self.spans.add(Lane::CkptLogic, OpKind::CkptEmb, batch, ck_start, ck_end);
        self.spans.add(Lane::Pmem, OpKind::CkptEmb, batch, ck_start, ck_end);
        self.logic_busy += ck.duration;
        let end = ck_end;

        let mut bd = Breakdown::default();
        if lk_end >= bf_end {
            bd.embedding += (lk_end - t0) as f64;
            bd.transfer += (xf_end - lk_end) as f64;
        } else {
            bd.bmlp += (bf_end - t0) as f64;
            bd.transfer += (xf_end - bf_end) as f64;
        }
        bd.tmlp += self.gpu.tmlp_total() as f64;
        let tail_end = up_end.max(bb_end).max(stage_end);
        if up_end >= bb_end.max(stage_end) {
            bd.transfer += (gx_end - tm_end) as f64;
            bd.embedding += (up_end - gx_end) as f64;
        } else if stage_end >= bb_end {
            bd.bmlp += (bb_end - tm_end) as f64;
            bd.checkpoint += (stage_end - bb_end) as f64;
        } else {
            bd.bmlp += (bb_end - tm_end) as f64;
        }
        bd.checkpoint += (end - tail_end) as f64;
        (end, bd)
    }

    // --------------------------------------------------------------- cxl

    /// CXL-D / CXL-B / CXL: automatic data movement; checkpoint mode and
    /// lookup relaxation from the knobs.
    fn step_cxl(&mut self, batch: u64, t0: SimTime) -> (SimTime, Breakdown) {
        let s = self.stats;
        let relaxed = self.knobs.relaxed_lookup;
        let ckpt = self.knobs.ckpt;

        // ---------------- embedding-lane front half
        //
        // CXL-D / CXL-B: lookup(N) runs first, RAW-exposed to the previous
        // batch's update writes. CXL: the reduced vectors for THIS batch
        // were produced during the previous batch (relaxed lookup), so the
        // lane starts with the undo log instead.
        let mut lookup_done = t0; // when this batch's reduced vectors are ready
        let mut lk_len = 0;
        if !relaxed {
            let st = self.pmem_free.max(t0);
            let lk = self
                .mem
                .embedding_lookup(st, &mut self.table, s.accesses, s.prev_overlap);
            let end = st + lk.duration;
            lk_len = lk.duration;
            self.pmem_free = end;
            self.record_media(&lk.media, "pmem");
            self.spans.add(Lane::CompLogic, OpKind::EmbLookup, batch, st, end);
            self.spans.add(Lane::Pmem, OpKind::EmbLookup, batch, st, end);
            self.logic_busy += lk.duration;
            lookup_done = end;
        } else if self.early_lookup_done.is_none() {
            // cold start: no early lookup from a previous batch — run one
            let st = self.pmem_free.max(t0);
            let lk = self.mem.embedding_lookup(st, &mut self.table, s.accesses, 0.0);
            let end = st + lk.duration;
            self.pmem_free = end;
            self.record_media(&lk.media, "pmem");
            self.spans.add(Lane::CompLogic, OpKind::EmbLookup, batch, st, end);
            self.spans.add(Lane::Pmem, OpKind::EmbLookup, batch, st, end);
            self.logic_busy += lk.duration;
            lookup_done = end;
        }

        // Batch-aware undo log of this batch's rows (Fig 6): runs in the
        // CXL-MEM idle window after the lookup; the update must wait on it.
        let mut emb_log_end = t0;
        if matches!(ckpt, CkptMode::BatchAware | CkptMode::Relaxed) {
            let st = self.pmem_free.max(t0);
            let op = self.mem.embedding_log(st, &mut self.table, s.unique_rows);
            emb_log_end = st + op.duration;
            self.pmem_free = emb_log_end;
            self.record_media(&op.media, "pmem");
            self.spans.add(Lane::CkptLogic, OpKind::CkptEmb, batch, st, emb_log_end);
            self.spans.add(Lane::Pmem, OpKind::CkptEmb, batch, st, emb_log_end);
            self.logic_busy += op.duration;
        }

        // DCOH flush of the reduced vectors into GPU memory (Fig 5a/b)
        let fl = self.cxl.transfer(self.reduced_bytes(), Proto::Cache);
        let flush_start = lookup_done.max(t0);
        let flush_end = flush_start + fl.duration;
        self.traffic.record_link(fl.bytes);
        self.spans.add(Lane::Link, OpKind::Transfer, batch, flush_start, flush_end);

        // ---------------- GPU lane
        let bf_end = t0 + self.gpu.bmlp_fwd;
        self.spans.add(Lane::Gpu, OpKind::BottomMlp, batch, t0, bf_end);
        let tm_start = bf_end.max(flush_end);
        let tm_end = tm_start + self.gpu.tmlp_total();
        self.spans.add(Lane::Gpu, OpKind::TopMlp, batch, tm_start, tm_end);
        let bb_end = tm_end + self.gpu.bmlp_bwd;
        self.spans.add(Lane::Gpu, OpKind::BottomMlp, batch, tm_end, bb_end);
        self.gpu_busy += self.gpu.gpu_busy();

        // gradient flush back to CXL-MEM (CXL-GPU's DCOH, Fig 5 BWP)
        let gfl = self.cxl.transfer(self.reduced_bytes(), Proto::Cache);
        let gfl_end = tm_end + gfl.duration;
        self.traffic.record_link(gfl.bytes);
        self.spans.add(Lane::Link, OpKind::Transfer, batch, tm_end, gfl_end);

        // ---------------- relaxed early lookup for the NEXT batch
        // (Fig 8 bottom: lookup(N+1) against the N-th table, before
        // update(N) — commutative-add correction applied at update time.)
        if relaxed {
            let st = self.pmem_free.max(emb_log_end);
            let lk = self.mem.embedding_lookup(st, &mut self.table, s.accesses, 0.0);
            let end = st + lk.duration;
            self.pmem_free = end;
            self.record_media(&lk.media, "pmem");
            self.spans.add(Lane::CompLogic, OpKind::EmbLookup, batch, st, end);
            self.spans.add(Lane::Pmem, OpKind::EmbLookup, batch, st, end);
            self.logic_busy += lk.duration;
            self.early_lookup_done = Some(end);
        }

        // ---------------- embedding update
        // CXL-B/CXL: may not start before its rows are undo-logged.
        let correction_rows = if relaxed {
            (s.unique_rows as f64 * s.prev_overlap) as u64
        } else {
            0
        };
        let up_start = gfl_end.max(self.pmem_free).max(emb_log_end);
        let up = self
            .mem
            .embedding_update(up_start, &mut self.table, s.unique_rows, correction_rows);
        let up_end = up_start + up.duration;
        self.pmem_free = up_end;
        self.record_media(&up.media, "pmem");
        self.spans.add(Lane::CompLogic, OpKind::EmbUpdate, batch, up_start, up_end);
        self.spans.add(Lane::Pmem, OpKind::EmbUpdate, batch, up_start, up_end);
        self.logic_busy += up.duration;

        // ---------------- MLP logging + batch end
        let mut end;
        let mut ck_tail = 0i64;
        match ckpt {
            CkptMode::Redo => {
                // CXL-D: MLP redo log via CXL.cache right after the GPU
                // commits (overlaps the update); embedding redo after it.
                let ml = self.mem.mlp_log(bb_end, &mut self.table, &self.cxl, self.mlp_log_bytes);
                let ml_end = bb_end + ml.duration;
                self.record_media(&ml.media, "pmem");
                self.traffic.record_link(ml.link_bytes);
                self.spans.add(Lane::CkptLogic, OpKind::CkptMlp, batch, bb_end, ml_end);
                self.logic_busy += ml.duration;
                let ck_start = up_end.max(self.pmem_free).max(ml_end);
                let ck = self.mem.redo_log(ck_start, &mut self.table, s.unique_rows, 0);
                let ck_end = ck_start + ck.duration;
                self.pmem_free = ck_end;
                self.record_media(&ck.media, "pmem");
                self.spans.add(Lane::CkptLogic, OpKind::CkptEmb, batch, ck_start, ck_end);
                self.spans.add(Lane::Pmem, OpKind::CkptEmb, batch, ck_start, ck_end);
                self.logic_busy += ck.duration;
                end = ck_end.max(bb_end);
                ck_tail = (end as i64) - (up_end.max(bb_end) as i64);
            }
            CkptMode::BatchAware => {
                // MLP undo log must capture pre-update params before the
                // GPU commits at bb_end; it runs behind the embedding log.
                let st = emb_log_end;
                let ml = self.mem.mlp_log(st, &mut self.table, &self.cxl, self.mlp_log_bytes);
                let ml_end = st + ml.duration;
                self.record_media(&ml.media, "pmem");
                self.traffic.record_link(ml.link_bytes);
                self.spans.add(Lane::CkptLogic, OpKind::CkptMlp, batch, st, ml_end);
                self.logic_busy += ml.duration;
                // if the log outlives the GPU's backward, the commit stalls
                end = up_end.max(bb_end).max(ml_end);
                ck_tail = (end as i64) - (up_end.max(bb_end) as i64);
            }
            CkptMode::Relaxed => {
                // MLP log slices ride the GPU's interaction+top-MLP window
                // only (the GPU answers CXL.cache reads while busy there).
                let window = tm_end.saturating_sub(tm_start);
                let (snap_batch, mut pending) = self
                    .mlp_inflight
                    .take()
                    .unwrap_or((batch, self.mlp_log_bytes));
                // bytes that fit the window at the link/log stream rate
                let probe = self.mem.mlp_log(tm_start, &mut self.table.clone(), &self.cxl, pending);
                let bytes_fit = if probe.duration as u64 <= window {
                    pending
                } else {
                    (pending as u128 * window as u128 / probe.duration.max(1) as u128) as u64
                };
                if bytes_fit > 0 {
                    let ml = self.mem.mlp_log(tm_start, &mut self.table, &self.cxl, bytes_fit);
                    self.record_media(&ml.media, "pmem");
                    self.traffic.record_link(ml.link_bytes);
                    let ml_end = tm_start + ml.duration.min(window);
                    self.spans.add(Lane::CkptLogic, OpKind::CkptMlp, batch, tm_start, ml_end);
                    self.logic_busy += ml.duration.min(window);
                    pending -= bytes_fit;
                }
                end = up_end.max(bb_end);
                if pending == 0 {
                    let gap = batch - snap_batch;
                    self.max_mlp_gap = self.max_mlp_gap.max(gap);
                    self.mlp_inflight = None; // next batch starts a new snapshot
                } else if batch - snap_batch >= self.knobs.max_mlp_log_gap {
                    // business-accuracy bound reached: finish synchronously
                    let st = end.max(self.pmem_free);
                    let ml = self.mem.mlp_log(st, &mut self.table, &self.cxl, pending);
                    let ml_end = st + ml.duration;
                    self.pmem_free = ml_end;
                    self.record_media(&ml.media, "pmem");
                    self.traffic.record_link(ml.link_bytes);
                    self.spans.add(Lane::CkptLogic, OpKind::CkptMlp, batch, st, ml_end);
                    self.logic_busy += ml.duration;
                    self.max_mlp_gap = self.max_mlp_gap.max(batch - snap_batch);
                    ck_tail = (ml_end - end) as i64;
                    end = ml_end;
                } else {
                    self.mlp_inflight = Some((snap_batch, pending));
                    self.max_mlp_gap = self.max_mlp_gap.max(batch - snap_batch);
                }
            }
            CkptMode::None => {
                end = up_end.max(bb_end);
            }
        }

        // ---------------- critical-path attribution
        let mut bd = Breakdown::default();
        if flush_end > bf_end {
            // embedding path gated the interaction start
            let lk_seg = lookup_done.saturating_sub(t0);
            bd.embedding += lk_seg.min(flush_end - t0) as f64;
            bd.transfer += (flush_end - lookup_done.max(t0)) as f64;
            let _ = lk_len;
        } else {
            bd.bmlp += self.gpu.bmlp_fwd as f64;
        }
        bd.tmlp += self.gpu.tmlp_total() as f64;
        // post-tmlp tail: whichever chain reaches the natural tail last
        if up_end >= bb_end {
            bd.transfer += (gfl_end - tm_end) as f64;
            // The update may have waited: on the undo log (checkpoint
            // overhead, Fig 12b) or on the early lookup holding the PMEM
            // backend (embedding work, relaxed schedule). Split the wait.
            let wait = up_start.saturating_sub(gfl_end);
            let ck_wait = emb_log_end.saturating_sub(gfl_end).min(wait);
            bd.checkpoint += ck_wait as f64;
            bd.embedding += (wait - ck_wait) as f64 + (up_end - up_start) as f64;
        } else {
            bd.bmlp += self.gpu.bmlp_bwd as f64;
        }
        bd.checkpoint += ck_tail.max(0) as f64;
        (end, bd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;
    use crate::workload::Generator;

    fn run_cfg(model: &str, sys: SystemConfig, n: u64) -> RunResult {
        let root = repo_root();
        let cfg = ModelConfig::load(&root, model).unwrap();
        let params = DeviceParams::builtin_default();
        let gpu = CxlGpu::from_params(&cfg, &params, std::path::Path::new("/nonexistent"));
        let cache = if sys == SystemConfig::Ssd {
            params.host.dram_cache_rows_frac
        } else {
            0.0
        };
        let stats = Generator::average_stats(&cfg, 42, 8, cache);
        PipelineSim::new(&cfg, sys, &params, gpu, stats).run(n)
    }

    #[test]
    fn paper_ordering_rm1() {
        // Fig 11, embedding-intensive RM1: SSD >> PMEM > PCIe > CXL-D >
        // CXL-B > CXL in mean batch latency.
        let t: Vec<f64> = SystemConfig::ALL
            .iter()
            .map(|&s| run_cfg("rm1", s, 10).mean_batch_ns())
            .collect();
        for w in t.windows(2) {
            assert!(
                w[0] > w[1],
                "expected strictly improving configs, got {t:?}"
            );
        }
        // SSD is catastrophically slower (paper: PMEM ~949x faster than
        // SSD on training time for embedding-heavy RMs; our SSD keeps a
        // warmer vector cache, so the gap is narrower but still decisive —
        // see EXPERIMENTS.md E1 notes)
        assert!(t[0] > 5.0 * t[1], "SSD {} vs PMEM {}", t[0], t[1]);
    }

    #[test]
    fn cxl_beats_pmem_by_paper_magnitude() {
        // headline: 5.2x speedup vs modern PMEM-based systems (geo-mean
        // over RM1-4); per-model we accept a broad band around it.
        let mut speedups = Vec::new();
        for model in ["rm1", "rm2", "rm3", "rm4"] {
            let pmem = run_cfg(model, SystemConfig::Pmem, 10).mean_batch_ns();
            let cxl = run_cfg(model, SystemConfig::Cxl, 10).mean_batch_ns();
            speedups.push(pmem / cxl);
        }
        let geo = crate::util::stats::geomean(&speedups);
        assert!(
            geo > 2.0 && geo < 20.0,
            "geo-mean speedup {geo:.2} out of plausible band ({speedups:?})"
        );
    }

    #[test]
    fn breakdown_sums_to_batch_time() {
        for sys in SystemConfig::ALL {
            let r = run_cfg("rm1", sys, 6);
            for (i, (bd, bt)) in r.breakdowns.iter().zip(&r.batch_times).enumerate() {
                let sum = bd.total();
                let bt = *bt as f64;
                assert!(
                    (sum - bt).abs() <= 0.02 * bt + 2.0,
                    "{}: batch {i}: breakdown {sum} vs batch {bt}",
                    sys.name()
                );
            }
        }
    }

    #[test]
    fn checkpoint_leaves_critical_path_with_batch_aware() {
        // CXL-B reduces checkpoint-on-critical-path vs CXL-D (Fig 12b);
        // CXL hides nearly all of it (Fig 12c).
        let d = run_cfg("rm1", SystemConfig::CxlD, 10).mean_breakdown();
        let b = run_cfg("rm1", SystemConfig::CxlB, 10).mean_breakdown();
        let c = run_cfg("rm1", SystemConfig::Cxl, 10).mean_breakdown();
        assert!(b.checkpoint < d.checkpoint, "B {} vs D {}", b.checkpoint, d.checkpoint);
        assert!(c.checkpoint < 0.5 * d.checkpoint, "C {} vs D {}", c.checkpoint, d.checkpoint);

        // When the GPU window is long relative to the embedding ops
        // (MLP-intensive RM4), batch-aware checkpointing hides nearly
        // everything — the idle-time-exploitation claim of Fig 6.
        let d4 = run_cfg("rm4", SystemConfig::CxlD, 10).mean_breakdown();
        let b4 = run_cfg("rm4", SystemConfig::CxlB, 10).mean_breakdown();
        assert!(
            b4.checkpoint < 0.5 * d4.checkpoint,
            "B {} vs D {}",
            b4.checkpoint,
            d4.checkpoint
        );
    }

    #[test]
    fn relaxed_lookup_removes_raw_hits() {
        let b = run_cfg("rm1", SystemConfig::CxlB, 10);
        let c = run_cfg("rm1", SystemConfig::Cxl, 10);
        assert!(b.raw_hits > 0, "CXL-B must observe RAW");
        assert_eq!(c.raw_hits, 0, "relaxed lookup must eliminate RAW");
    }

    #[test]
    fn mlp_log_gap_bounded_and_nonzero_under_relaxation() {
        let c = run_cfg("rm2", SystemConfig::Cxl, 30);
        assert!(c.max_mlp_gap <= SystemConfig::Cxl.knobs().max_mlp_log_gap);
    }

    #[test]
    fn timelines_populated_for_fig12_lanes() {
        let r = run_cfg("rm2", SystemConfig::CxlB, 4);
        let end = r.spans.end_time();
        assert!(r.spans.busy(Lane::Gpu, 0, end) > 0);
        assert!(r.spans.busy(Lane::CompLogic, 0, end) > 0);
        assert!(r.spans.busy(Lane::CkptLogic, 0, end) > 0);
        assert!(r.spans.busy(Lane::Pmem, 0, end) > 0);
    }

    #[test]
    fn software_configs_burn_host_cpu_cxl_does_not() {
        let pmem = run_cfg("rm1", SystemConfig::Pmem, 6);
        let cxl = run_cfg("rm1", SystemConfig::Cxl, 6);
        assert!(pmem.host_busy > 0);
        assert_eq!(cxl.host_busy, 0, "CXL removes software from the path");
    }

    #[test]
    fn mlp_intensive_models_gain_less() {
        // paper: NDP acceleration works less well for MLP-intensive models
        let s_rm2 = run_cfg("rm2", SystemConfig::Pmem, 8).mean_batch_ns()
            / run_cfg("rm2", SystemConfig::Cxl, 8).mean_batch_ns();
        let s_rm4 = run_cfg("rm4", SystemConfig::Pmem, 8).mean_batch_ns()
            / run_cfg("rm4", SystemConfig::Cxl, 8).mean_batch_ns();
        assert!(
            s_rm2 > s_rm4,
            "embedding-heavy RM2 ({s_rm2:.2}x) should gain more than MLP-heavy RM4 ({s_rm4:.2}x)"
        );
    }
}
