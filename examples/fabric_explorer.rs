//! Fabric explorer: CXL substrate in isolation.
//!
//! Demonstrates (a) the range-routed switch with a multi-expander pool —
//! the CXL 3.0 scalability argument of the paper's related-work section —
//! (b) DCOH-driven automatic data movement: producing a reduced
//! embedding vector on CXL-MEM and flushing exactly the dirty lines to
//! the GPU, priced by the link model (Fig 5), and (c) a CXL 3.0
//! multi-level switch TREE routing two tenants' pool slices through
//! their own leaf switches with per-link byte/occupancy counters
//! (docs/topology.md §Multi-tenant pooled fabric).
//!
//! Run: `cargo run --release --example fabric_explorer`

use trainingcxl::config::DeviceParams;
use trainingcxl::sim::cxl::dcoh::AgentId;
use trainingcxl::sim::cxl::{Dcoh, Link, PortId, Proto, Switch};
use trainingcxl::sim::fabric::{FabricTree, ROOT};

fn main() -> anyhow::Result<()> {
    let params = DeviceParams::builtin_default();

    // ---- a pooled topology: host + GPU + 4 PMEM expanders
    let mut sw = Switch::new();
    const GB: u64 = 1 << 30;
    sw.attach(PortId(0), "host", 0, 4 * GB)?;
    sw.attach(PortId(1), "cxl-gpu", 4 * GB, 24 * GB)?;
    for i in 0..4u64 {
        sw.attach(
            PortId(2 + i as u16),
            &format!("cxl-mem{i}"),
            (28 + 16 * i) * GB,
            16 * GB,
        )?;
    }
    println!("== HPA routing across the pool ==");
    for addr in [GB, 10 * GB, 30 * GB, 50 * GB, 80 * GB] {
        let port = sw.route(addr)?;
        println!(
            "  HPA {:>5.1} GB -> port {:>2} ({})",
            addr as f64 / GB as f64,
            port.0,
            sw.port_name(port)
        );
    }

    // ---- automatic data movement: CXL-MEM produces, DCOH flushes
    let link = Link::new(params.cxl_link.clone());
    let mut dcoh = Dcoh::new();
    let gpu = AgentId(1);
    let mem = AgentId(2);
    let reduced_bytes = 32 * 20 * 32 * 4; // B x T x D f32 (RM1, batch 32)

    println!("\n== FWP: reduced embedding vector, CXL-MEM -> CXL-GPU (Fig 5a/b) ==");
    let dirty = dcoh.produce_and_flush(mem, 4 * GB, reduced_bytes);
    let t = link.transfer(dirty, Proto::Cache);
    println!(
        "  {} dirty bytes flushed in {} ns ({} flits, zero host software)",
        dirty,
        t.duration,
        dirty / params.cxl_link.flit_bytes
    );

    println!("\n== BWP: embedding gradient, CXL-GPU -> CXL-MEM ==");
    let dirty = dcoh.produce_and_flush(gpu, 30 * GB, reduced_bytes);
    let t_hw = link.transfer(dirty, Proto::Cache);
    println!("  {} dirty bytes flushed in {} ns", dirty, t_hw.duration);
    dcoh.check_invariants().map_err(|e| anyhow::anyhow!(e))?;

    // ---- contrast with the software path the paper eliminates
    let host = params.host;
    let sw_ns = host.sync_ns + host.memcpy_setup_ns + host.kernel_launch_ns;
    let pcie = Link::new(params.pcie_link);
    let t_sw = pcie.transfer(reduced_bytes, Proto::Io);
    println!(
        "\nsoftware path would cost {} ns (sync+memcpy+launch {} ns + PCIe {} ns) vs {} ns — {:.1}x",
        sw_ns as u64 + t_sw.duration,
        sw_ns as u64,
        t_sw.duration,
        t_hw.duration,
        (sw_ns + t_sw.duration as f64) / t_hw.duration as f64
    );
    // ---- a multi-level tree: two tenants, one pool, per-link counters
    println!("\n== CXL 3.0 switch tree: two tenants behind their own leaves ==");
    let mut tree = FabricTree::new("pool-root");
    let leaf_a = tree.add_switch(ROOT, "ranker-leaf")?;
    let leaf_b = tree.add_switch(ROOT, "retrieval-leaf")?;
    tree.attach_device(leaf_a, "ranker-slice", 0, 16 * GB)?;
    tree.attach_device(leaf_b, "retrieval-slice", 16 * GB, 16 * GB)?;
    for (who, addr, bytes) in [("ranker", GB, 1 << 20), ("retrieval", 20 * GB, 4 << 20)] {
        let r = tree.forward(addr, bytes, link.transfer(bytes, Proto::Mem).duration)?;
        println!(
            "  {who:>9}: HPA {:>4.1} GB -> {} (hops {})",
            addr as f64 / GB as f64,
            tree.node_name(r.node),
            r.hops
        );
    }
    for (name, l) in tree.links() {
        println!(
            "  link {name:<15} {:>9} bytes  {:>7} ns busy  {} transfers",
            l.bytes, l.busy_ns, l.transfers
        );
    }

    println!("\nfabric_explorer OK (snoops {}, flushes {})", dcoh.snoops, dcoh.flushes);
    Ok(())
}
