"""AOT compile path: lower every exported L2 function to HLO *text*.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Outputs, per model:
    artifacts/<model>/<export>.hlo.txt
    artifacts/<model>/manifest.json    (shapes/dtypes/param layout for rust)

`python -m compile.aot --all` is what `make artifacts` runs; it is
idempotent and skips models whose manifest is newer than the compile
sources. Python never runs after this step.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model, modelcfg

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
DEFAULT_OUT = REPO_ROOT / "artifacts"

# Models compiled by default (`--all`). rm2/rm4 dominate compile time; all
# four paper RMs are needed for calibration benches, rm_mini for tests,
# rm_e2e for the end-to-end example.
DEFAULT_MODELS = ("rm_mini", "rm_e2e", "rm1", "rm2", "rm3", "rm4")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text.

    return_tuple=False: single-output exports lower to a plain array root
    (required for the rust buffer-execution path — PJRT cannot convert a
    wrapper-tuple buffer back to a literal on this xla_extension build);
    multi-output exports still get a natural tuple root, which the rust
    side downloads and decomposes on the host (they are all small).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def source_fingerprint() -> str:
    """Hash of the compile sources + model configs, for idempotence."""
    h = hashlib.sha256()
    roots = [
        pathlib.Path(__file__).parent,
        modelcfg.MODELS_DIR,
    ]
    for root in roots:
        for p in sorted(root.rglob("*")):
            if p.suffix in (".py", ".toml") and p.is_file():
                h.update(p.name.encode())
                h.update(p.read_bytes())
    return h.hexdigest()


def compile_model(name: str, out_root: pathlib.Path, fingerprint: str) -> bool:
    """Lower all exports of one model. Returns False if already current."""
    cfg = modelcfg.load(name)
    out_dir = out_root / name
    manifest_path = out_dir / "manifest.json"
    if manifest_path.exists():
        try:
            old = json.loads(manifest_path.read_text())
            if old.get("fingerprint") == fingerprint:
                print(f"[aot] {name}: up to date, skipping")
                return False
        except (json.JSONDecodeError, KeyError):
            pass

    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {
        "model": name,
        "fingerprint": fingerprint,
        "config": {
            "feature_dim": cfg.feature_dim,
            "num_dense": cfg.num_dense,
            "num_tables": cfg.num_tables,
            "rows_per_table": cfg.rows_per_table,
            "lookups_per_table": cfg.lookups_per_table,
            "bottom_mlp": list(cfg.bottom_mlp),
            "top_mlp": list(cfg.top_mlp),
            "batch_size": cfg.batch_size,
            "lr": cfg.lr,
            "param_count": cfg.param_count(),
        },
        "params": [
            {"name": n, "shape": list(s)} for n, s in model.param_specs(cfg)
        ],
        "exports": {},
    }
    for what in model.EXPORTS:
        fn = model.export_fn(cfg, what)
        inputs = model.example_inputs(cfg, what)
        lowered = jax.jit(fn).lower(*inputs)
        text = to_hlo_text(lowered)
        rel = f"{what}.hlo.txt"
        (out_dir / rel).write_text(text)
        outs = jax.eval_shape(fn, *inputs)
        manifest["exports"][what] = {
            "file": rel,
            "inputs": [_spec_json(s) for s in inputs],
            "outputs": [_spec_json(s) for s in outs],
        }
        print(f"[aot] {name}/{what}: {len(text)} chars")
    manifest_path.write_text(json.dumps(manifest, indent=1))
    return True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", action="append", help="model name (repeatable)")
    ap.add_argument("--all", action="store_true", help=f"compile {DEFAULT_MODELS}")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    names = list(args.model or [])
    if args.all or not names:
        names = list(DEFAULT_MODELS)
    out_root = pathlib.Path(args.out)
    fp = source_fingerprint()
    for name in names:
        compile_model(name, out_root, fp)
    return 0


if __name__ == "__main__":
    sys.exit(main())
