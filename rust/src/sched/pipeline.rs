//! The batch-pipeline runner (paper Fig 4, 6, 8, 9b, 12).
//!
//! One [`PipelineSim`] simulates `n` training batches of one model under
//! one [`Topology`], producing exact per-lane busy intervals and a
//! critical-path time breakdown per batch. The per-configuration
//! schedules themselves are *compositions* of [`crate::sched::stage`]
//! stages selected by [`stage::compose`]:
//!
//! * **SSD / PMEM** (software): host CPU performs embedding ops against
//!   the storage medium; every producer/consumer handoff pays
//!   sync + memcpy + kernel-launch; redo-log checkpoint on the critical
//!   path at the end of each batch (Fig 4a).
//! * **PCIe**: near-data processing on the expander, but movement is still
//!   software and checkpointing still redo.
//! * **CXL-D**: automatic data movement — DCOH flushes replace the
//!   software path (Fig 4b/5); MLP redo-logging overlaps with the
//!   embedding update via CXL.cache; embedding redo-log still serial.
//! * **CXL-B**: + batch-aware *undo* checkpoint in CXL-MEM idle time
//!   (Fig 6/7): embedding log after lookup, MLP log behind it; the update
//!   waits for the embedding log (can't overwrite unlogged rows).
//! * **CXL**: + relaxed embedding lookup (batch N+1's lookup runs in
//!   batch N against the old table — no RAW, off the critical path;
//!   Fig 8) and relaxed batch-aware checkpoint (MLP logging only inside
//!   the GPU's interaction+top-MLP window, spread over batches; Fig 9b).
//!
//! PMEM-backend contention is explicit: every operation touching the
//! expander's PMEM serialises through `PipelineEnv::pmem_free`, which is
//! how checkpoint overhead becomes visible exactly as in Fig 12b.

use crate::config::device::DeviceParams;
use crate::config::sysconfig::SystemConfig;
use crate::config::ModelConfig;
use crate::devices::CxlGpu;
use crate::sched::stage::{self, BatchCtx, PipelineEnv, Stage};
use crate::sim::engine::{Event, EventQueue};
use crate::sim::topology::{Topology, TopologyError};
use crate::sim::SimTime;
use crate::telemetry::trace::{TraceEvent, TraceKind, TraceLog};
use crate::telemetry::{Breakdown, SpanLog, TrafficCounters};
use crate::workload::BatchStats;

/// Everything a simulated run produced.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Legacy accounting label (energy provisioning) — the nearest paper
    /// config; see [`Topology::system_label`].
    pub config: SystemConfig,
    /// Name of the topology that ran.
    pub topology: String,
    pub model: String,
    pub spans: SpanLog,
    /// Critical-path breakdown per batch (ns components).
    pub breakdowns: Vec<Breakdown>,
    pub batch_times: Vec<SimTime>,
    pub traffic: TrafficCounters,
    pub total_time: SimTime,
    /// RAW-penalised accesses observed (ablation metric).
    pub raw_hits: u64,
    /// Largest embedding/MLP-log gap reached (batches).
    pub max_mlp_gap: u64,
    /// GPU busy ns (energy accounting).
    pub gpu_busy: SimTime,
    /// Host CPU busy ns.
    pub host_busy: SimTime,
    /// Computing+checkpointing logic busy ns.
    pub logic_busy: SimTime,
    /// The run's causal trace ([`PipelineSim::run`] records one slot
    /// span per batch under a root `Run` span). Empty when a driver
    /// assembles the result itself via [`PipelineSim::finish`] — the
    /// tenancy lanes carry their trace on `MultiTenantRun` instead.
    pub trace: TraceLog,
}

impl RunResult {
    /// Mean batch latency over the steady-state window (skips warmup).
    pub fn mean_batch_ns(&self) -> f64 {
        let skip = self.batch_times.len().min(2);
        let xs = &self.batch_times[skip..];
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().map(|&t| t as f64).sum::<f64>() / xs.len() as f64
    }

    /// Mean steady-state breakdown.
    pub fn mean_breakdown(&self) -> Breakdown {
        let skip = self.breakdowns.len().min(2);
        let xs = &self.breakdowns[skip..];
        let mut acc = Breakdown::default();
        for b in xs {
            acc.add(b);
        }
        acc.scale(1.0 / xs.len().max(1) as f64)
    }
}

/// Batch-pipeline simulator for one (model, topology) pair: a
/// [`PipelineEnv`] plus the stage chain composed for the topology.
pub struct PipelineSim {
    env: PipelineEnv,
    stages: Vec<Box<dyn Stage>>,
}

impl PipelineSim {
    /// Simulator for one of the paper's system configurations.
    ///
    /// `stats` should come from [`crate::workload::Generator::average_stats`]
    /// with the config-appropriate cache fraction.
    pub fn new(
        cfg: &ModelConfig,
        config: SystemConfig,
        params: &DeviceParams,
        gpu: CxlGpu,
        stats: BatchStats,
    ) -> PipelineSim {
        Self::from_topology(cfg, Topology::from_system(config), params, gpu, stats)
            .expect("paper system configs always compose")
    }

    /// Simulator for an arbitrary [`Topology`]. Invalid compositions are
    /// rejected here (they cannot arise from [`Topology::builder`], which
    /// validates at build time, but a hand-constructed value could).
    pub fn from_topology(
        cfg: &ModelConfig,
        topo: Topology,
        params: &DeviceParams,
        gpu: CxlGpu,
        stats: BatchStats,
    ) -> Result<PipelineSim, TopologyError> {
        let stages = stage::compose(&topo)?;
        Ok(PipelineSim {
            env: PipelineEnv::new(cfg, topo, params, gpu, stats),
            stages,
        })
    }

    /// Build the simulator for one `(model, topology)` pair with workload
    /// seed `seed`: model/device configs loaded from `root`, the cache
    /// fraction derived from the fabric, tiered access classification,
    /// and generator-striped per-lane shard stats. The single
    /// construction point the bench drivers
    /// ([`crate::bench::experiments::simulate_topology`], seed 42) and
    /// the tenancy lanes share — so they cannot drift apart.
    pub fn for_model(
        root: &std::path::Path,
        model: &str,
        topo: Topology,
        seed: u64,
    ) -> anyhow::Result<PipelineSim> {
        use crate::workload::Generator;
        let cfg = ModelConfig::load(root, model)?;
        let params = DeviceParams::load(root)?;
        let gpu = CxlGpu::from_params(&cfg, &params, root);
        let cache = if topo.dram_vector_cache {
            params.host.dram_cache_rows_frac
        } else {
            0.0
        };
        let shards = topo.gpu_shards;
        let hot_frac = topo.tier_split().map(|t| t.hot_frac).unwrap_or(0.0);
        let stats = Generator::average_stats_tiered(&cfg, seed, 8, cache, hot_frac);
        let mut sim = PipelineSim::from_topology(&cfg, topo, &params, gpu, stats)?;
        if shards > 1 {
            sim = sim.with_shard_stats(Generator::sharded_average_stats_tiered(
                &cfg, seed, 8, cache, hot_frac, shards,
            ));
        }
        Ok(sim)
    }

    /// Names of the composed stages, in execution order (introspection /
    /// docs / tests).
    pub fn stage_names(&self) -> Vec<&'static str> {
        self.stages.iter().map(|s| s.name()).collect()
    }

    /// Install generator-striped per-shard statistics (one element per
    /// GPU lane, from [`crate::workload::Generator::sharded_average_stats`])
    /// in place of the even-split fallback a sharded env starts with.
    pub fn with_shard_stats(mut self, shard_stats: Vec<BatchStats>) -> PipelineSim {
        assert_eq!(
            shard_stats.len(),
            self.env.topo.gpu_shards,
            "one BatchStats per GPU lane"
        );
        self.env.shard_stats = shard_stats;
        self
    }

    /// Run one batch starting at `t` — the exact per-batch loop [`run`]
    /// uses, exposed so multi-run drivers (the tenancy lanes) advance a
    /// simulator batch-by-batch through the same code path.
    ///
    /// [`run`]: PipelineSim::run
    pub fn step_batch(&mut self, batch: u64, t: SimTime) -> BatchCtx {
        let mut ctx = BatchCtx::new(batch, t);
        for s in &self.stages {
            s.run(&mut self.env, &mut ctx);
        }
        debug_assert!(ctx.end > t, "batch must advance time");
        ctx
    }

    pub fn env(&self) -> &PipelineEnv {
        &self.env
    }

    /// Mutable env access for drivers injecting cross-run state (the
    /// tenancy arbiter charges co-tenant pool occupancy to `pmem_free`).
    pub fn env_mut(&mut self) -> &mut PipelineEnv {
        &mut self.env
    }

    /// Assemble the final record from the finished env + the per-batch
    /// series a driver accumulated — the single `RunResult` construction
    /// point [`PipelineSim::run`] and the tenancy lanes share.
    pub fn finish(
        self,
        breakdowns: Vec<Breakdown>,
        batch_times: Vec<SimTime>,
        total_time: SimTime,
    ) -> RunResult {
        let env = self.env;
        RunResult {
            config: env.topo.system_label(),
            topology: env.topo.name.clone(),
            model: env.cfg.name.clone(),
            spans: env.spans,
            breakdowns,
            batch_times,
            traffic: env.traffic,
            total_time,
            raw_hits: env.raw_hits,
            max_mlp_gap: env.max_mlp_gap,
            gpu_busy: env.gpu_busy,
            host_busy: env.host_busy,
            logic_busy: env.logic_busy,
            trace: TraceLog::default(),
        }
    }

    /// Run `n` batches; returns the accumulated result.
    ///
    /// Pumped through the discrete-event engine: each batch is a
    /// [`SlotStart`](Event::SlotStart)/[`SlotDone`](Event::SlotDone) pair
    /// on the lane clock, the `SlotDone` timestamp is the batch's
    /// completion time, and the next `SlotStart` chains off it — the
    /// event trace *is* the old sequential loop, so the numbers are
    /// bit-identical to the pre-engine path.
    pub fn run(mut self, n: u64) -> RunResult {
        let mut breakdowns = Vec::with_capacity(n as usize);
        let mut batch_times = Vec::with_capacity(n as usize);
        let mut q: EventQueue<Event> = EventQueue::new();
        let mut trace = TraceLog::new();
        let root = trace.record(TraceEvent::span(None, Some(0), TraceKind::Run, 0, 0));
        let mut t = 0;
        if n > 0 {
            q.schedule(0, Event::SlotStart { lane: 0, batch: 0 });
        }
        while let Some((at, ev)) = q.pop() {
            match ev {
                Event::SlotStart { batch, .. } => {
                    let ctx = self.step_batch(batch, at);
                    let kind = TraceKind::slot(batch, ctx.end - at, 0, 0, 0, &ctx.bd);
                    trace.record(TraceEvent::span(Some(root), Some(0), kind, at, ctx.end));
                    breakdowns.push(ctx.bd);
                    batch_times.push(ctx.end - at);
                    q.schedule(ctx.end, Event::SlotDone { lane: 0, batch });
                }
                Event::SlotDone { batch, .. } => {
                    t = at;
                    if batch + 1 < n {
                        q.schedule(at, Event::SlotStart { lane: 0, batch: batch + 1 });
                    }
                }
                _ => unreachable!("solo pipeline lanes only pump slot events"),
            }
        }
        trace.close(root, 0, t);
        let mut result = self.finish(breakdowns, batch_times, t);
        result.trace = trace;
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;
    use crate::workload::Generator;

    fn run_cfg(model: &str, sys: SystemConfig, n: u64) -> RunResult {
        let root = repo_root();
        let cfg = ModelConfig::load(&root, model).unwrap();
        let params = DeviceParams::builtin_default();
        let gpu = CxlGpu::from_params(&cfg, &params, std::path::Path::new("/nonexistent"));
        let cache = if sys == SystemConfig::Ssd {
            params.host.dram_cache_rows_frac
        } else {
            0.0
        };
        let stats = Generator::average_stats(&cfg, 42, 8, cache);
        PipelineSim::new(&cfg, sys, &params, gpu, stats).run(n)
    }

    #[test]
    fn paper_ordering_rm1() {
        // Fig 11, embedding-intensive RM1: SSD >> PMEM > PCIe > CXL-D >
        // CXL-B > CXL in mean batch latency.
        let t: Vec<f64> = SystemConfig::ALL
            .iter()
            .map(|&s| run_cfg("rm1", s, 10).mean_batch_ns())
            .collect();
        for w in t.windows(2) {
            assert!(
                w[0] > w[1],
                "expected strictly improving configs, got {t:?}"
            );
        }
        // SSD is catastrophically slower (paper: PMEM ~949x faster than
        // SSD on training time for embedding-heavy RMs; our SSD keeps a
        // warmer vector cache, so the gap is narrower but still decisive —
        // see EXPERIMENTS.md E1 notes)
        assert!(t[0] > 5.0 * t[1], "SSD {} vs PMEM {}", t[0], t[1]);
    }

    #[test]
    fn cxl_beats_pmem_by_paper_magnitude() {
        // headline: 5.2x speedup vs modern PMEM-based systems (geo-mean
        // over RM1-4); per-model we accept a broad band around it.
        let mut speedups = Vec::new();
        for model in ["rm1", "rm2", "rm3", "rm4"] {
            let pmem = run_cfg(model, SystemConfig::Pmem, 10).mean_batch_ns();
            let cxl = run_cfg(model, SystemConfig::Cxl, 10).mean_batch_ns();
            speedups.push(pmem / cxl);
        }
        let geo = crate::util::stats::geomean(&speedups);
        assert!(
            geo > 2.0 && geo < 20.0,
            "geo-mean speedup {geo:.2} out of plausible band ({speedups:?})"
        );
    }

    #[test]
    fn breakdown_sums_to_batch_time() {
        for sys in SystemConfig::ALL {
            let r = run_cfg("rm1", sys, 6);
            for (i, (bd, bt)) in r.breakdowns.iter().zip(&r.batch_times).enumerate() {
                let sum = bd.total();
                let bt = *bt as f64;
                assert!(
                    (sum - bt).abs() <= 0.02 * bt + 2.0,
                    "{}: batch {i}: breakdown {sum} vs batch {bt}",
                    sys.name()
                );
            }
        }
    }

    #[test]
    fn checkpoint_leaves_critical_path_with_batch_aware() {
        // CXL-B reduces checkpoint-on-critical-path vs CXL-D (Fig 12b);
        // CXL hides nearly all of it (Fig 12c).
        let d = run_cfg("rm1", SystemConfig::CxlD, 10).mean_breakdown();
        let b = run_cfg("rm1", SystemConfig::CxlB, 10).mean_breakdown();
        let c = run_cfg("rm1", SystemConfig::Cxl, 10).mean_breakdown();
        assert!(b.checkpoint < d.checkpoint, "B {} vs D {}", b.checkpoint, d.checkpoint);
        assert!(c.checkpoint < 0.5 * d.checkpoint, "C {} vs D {}", c.checkpoint, d.checkpoint);

        // When the GPU window is long relative to the embedding ops
        // (MLP-intensive RM4), batch-aware checkpointing hides nearly
        // everything — the idle-time-exploitation claim of Fig 6.
        let d4 = run_cfg("rm4", SystemConfig::CxlD, 10).mean_breakdown();
        let b4 = run_cfg("rm4", SystemConfig::CxlB, 10).mean_breakdown();
        assert!(
            b4.checkpoint < 0.5 * d4.checkpoint,
            "B {} vs D {}",
            b4.checkpoint,
            d4.checkpoint
        );
    }

    #[test]
    fn relaxed_lookup_removes_raw_hits() {
        let b = run_cfg("rm1", SystemConfig::CxlB, 10);
        let c = run_cfg("rm1", SystemConfig::Cxl, 10);
        assert!(b.raw_hits > 0, "CXL-B must observe RAW");
        assert_eq!(c.raw_hits, 0, "relaxed lookup must eliminate RAW");
    }

    #[test]
    fn mlp_log_gap_bounded_and_nonzero_under_relaxation() {
        let c = run_cfg("rm2", SystemConfig::Cxl, 30);
        assert!(c.max_mlp_gap <= Topology::from_system(SystemConfig::Cxl).max_mlp_log_gap);
    }

    #[test]
    fn timelines_populated_for_fig12_lanes() {
        use crate::sim::Lane;
        let r = run_cfg("rm2", SystemConfig::CxlB, 4);
        let end = r.spans.end_time();
        assert!(r.spans.busy(Lane::Gpu, 0, end) > 0);
        assert!(r.spans.busy(Lane::CompLogic, 0, end) > 0);
        assert!(r.spans.busy(Lane::CkptLogic, 0, end) > 0);
        assert!(r.spans.busy(Lane::Pmem, 0, end) > 0);
    }

    #[test]
    fn software_configs_burn_host_cpu_cxl_does_not() {
        let pmem = run_cfg("rm1", SystemConfig::Pmem, 6);
        let cxl = run_cfg("rm1", SystemConfig::Cxl, 6);
        assert!(pmem.host_busy > 0);
        assert_eq!(cxl.host_busy, 0, "CXL removes software from the path");
    }

    #[test]
    fn mlp_intensive_models_gain_less() {
        // paper: NDP acceleration works less well for MLP-intensive models
        let s_rm2 = run_cfg("rm2", SystemConfig::Pmem, 8).mean_batch_ns()
            / run_cfg("rm2", SystemConfig::Cxl, 8).mean_batch_ns();
        let s_rm4 = run_cfg("rm4", SystemConfig::Pmem, 8).mean_batch_ns()
            / run_cfg("rm4", SystemConfig::Cxl, 8).mean_batch_ns();
        assert!(
            s_rm2 > s_rm4,
            "embedding-heavy RM2 ({s_rm2:.2}x) should gain more than MLP-heavy RM4 ({s_rm4:.2}x)"
        );
    }

    #[test]
    fn sharded_lanes_run_and_keep_checkpoint_semantics() {
        let root = repo_root();
        let cfg = ModelConfig::load(&root, "rm2").unwrap();
        let params = DeviceParams::builtin_default();
        let gpu = CxlGpu::from_params(&cfg, &params, std::path::Path::new("/nonexistent"));
        let run = |shards: usize| {
            let topo = Topology::builder(&format!("sharded-{shards}"))
                .near_data()
                .hw_movement()
                .checkpoint(crate::config::CkptMode::Relaxed)
                .relaxed_lookup()
                .max_mlp_log_gap(200)
                .expander_pool(shards, 1)
                .gpu_shards(shards)
                .build()
                .unwrap();
            let stats = Generator::average_stats(&cfg, 42, 8, 0.0);
            let shard_stats = Generator::sharded_average_stats(&cfg, 42, 8, 0.0, shards);
            PipelineSim::from_topology(&cfg, topo, &params, gpu, stats)
                .unwrap()
                .with_shard_stats(shard_stats)
                .run(8)
        };
        let r2 = run(2);
        assert!(r2.total_time > 0 && r2.batch_times.iter().all(|&t| t > 0));
        // relaxed lookup still removes RAW on the sharded lanes
        assert_eq!(r2.raw_hits, 0);
        // the relaxed MLP-log gap bound still holds
        assert!(r2.max_mlp_gap <= 200);
        // striping the pool+lanes speeds up the embedding-bound model
        let r4 = run(4);
        assert!(
            r4.mean_batch_ns() < r2.mean_batch_ns(),
            "4 lanes {} vs 2 lanes {}",
            r4.mean_batch_ns(),
            r2.mean_batch_ns()
        );
    }

    #[test]
    fn tiered_lanes_run_and_shift_traffic_to_the_hot_tier() {
        use crate::sim::mem::MediaKind;
        let root = repo_root();
        let cfg = ModelConfig::load(&root, "rm2").unwrap();
        let params = DeviceParams::builtin_default();
        let gpu = CxlGpu::from_params(&cfg, &params, std::path::Path::new("/nonexistent"));
        let run = |hot_frac: f64, shards: usize| {
            let mut b = Topology::builder(&format!("tiered-{hot_frac}-{shards}"))
                .near_data()
                .hw_movement()
                .checkpoint(crate::config::CkptMode::Relaxed)
                .relaxed_lookup()
                .max_mlp_log_gap(200)
                .gpu_shards(shards);
            if hot_frac > 0.0 {
                b = b.tiered_media(MediaKind::Dram, hot_frac);
            }
            let stats = Generator::average_stats_tiered(&cfg, 42, 8, 0.0, hot_frac);
            let mut sim =
                PipelineSim::from_topology(&cfg, b.build().unwrap(), &params, gpu, stats).unwrap();
            if shards > 1 {
                sim = sim.with_shard_stats(Generator::sharded_average_stats_tiered(
                    &cfg, 42, 8, 0.0, hot_frac, shards,
                ));
            }
            sim.run(8)
        };
        let cold = run(0.0, 1);
        let hot = run(0.3, 1);
        assert!(hot.total_time > 0 && hot.batch_times.iter().all(|&t| t > 0));
        // the Zipf head now reads from the volatile tier: the hot run
        // must move real DRAM traffic and beat the all-PMEM schedule
        let dram_read = |r: &RunResult| r.traffic.by_medium.get("dram").map_or(0, |t| t.0);
        assert!(dram_read(&hot) > dram_read(&cold), "no hot-tier traffic recorded");
        assert!(
            hot.mean_batch_ns() < cold.mean_batch_ns(),
            "tiered {} vs untiered {}",
            hot.mean_batch_ns(),
            cold.mean_batch_ns()
        );
        // and the tiered chain still runs when striped over GPU lanes
        let sharded = run(0.3, 2);
        assert!(sharded.total_time > 0 && sharded.raw_hits == 0);
        assert!(sharded.max_mlp_gap <= 200);
    }

    #[test]
    fn run_result_carries_topology_name() {
        let r = run_cfg("rm_mini", SystemConfig::CxlB, 3);
        assert_eq!(r.topology, "CXL-B");
        assert_eq!(r.config, SystemConfig::CxlB);
    }
}
