//! Engine-sourced structured tracing: a causally-ordered, append-only
//! record of everything the discrete-event engine scheduled.
//!
//! Every engine event — batch slots, arbiter rounds, crash arming,
//! fabric faults/repairs — and every [`ResourceQueue`] grant becomes a
//! typed [`TraceEvent`] in a [`TraceLog`]. Recording uses sim time only
//! and happens on the round-merge thread (lane workers hand their
//! lane-local slot records back through `QuantumOutcome`), so a trace is
//! **byte-identical at any worker count** — the same contract the
//! results themselves keep (docs/engine.md).
//!
//! Consumers:
//!
//! * [`TraceLog::chrome_trace`] exports Chrome trace-event JSON that
//!   Perfetto loads directly: one track per tenant (slots + recovery),
//!   one per tenant leaf link (fabric transfers), one per resource
//!   queue (ledger grants), plus per-hardware-lane tracks from the
//!   tenant [`SpanLog`]s.
//! * [`TraceLog::attribution`] walks the critical-path tenant's slots
//!   and attributes every nanosecond of the measured critical path to
//!   {GpuLane, CxlLink, PcieLink, PmemPool, co-tenant stall, fault
//!   stall, recovery, idle} — the buckets sum to the critical path
//!   exactly, by construction.
//! * [`TraceLog::validate`] is the structural gate the `trainingcxl
//!   trace` driver runs before exporting: parents must exist (and
//!   precede their children), no span may end before it starts, and
//!   slot/recovery spans must nest inside their round.
//!
//! [`ResourceQueue`]: crate::sim::engine::ResourceQueue

use crate::analysis::effects::Resource;
use crate::sim::{Lane, SimTime};
use crate::telemetry::{Breakdown, SpanLog};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// What a trace event records. Span kinds carry their payload inline so
/// the log is self-contained: attribution and export never need the
/// originating simulator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceKind {
    /// The root of a run; every other event is (transitively) its child.
    Run,
    /// One arbiter round (or, with `catch_up`, the deferred-quantum
    /// round a `FabricRepair` triggers — `round` is then the fault
    /// index). Its span covers its children on the lane clocks.
    Round { round: usize, catch_up: bool },
    /// One batch slot on a tenant lane. The wait/compute decomposition
    /// is computed at record time (see [`TraceKind::slot`]): the failure
    /// components are clamped into the slot, the residual is split
    /// across the lane's resources in proportion to the batch's
    /// [`Breakdown`], and whatever remains is implicit idle — so a
    /// slot's components can never exceed its duration.
    Slot {
        batch: u64,
        /// Co-tenant pool stall absorbed at this slot (clamped to dur).
        stall_ns: u64,
        /// Fabric-fault stall absorbed at this slot (clamped likewise).
        fault_stall_ns: u64,
        /// Crash-recovery cycle charged inside this slot (tail ns).
        recovery_ns: u64,
        /// Residual share attributed to the GPU lane.
        gpu_ns: u64,
        /// Residual share attributed to the lane's movement link.
        link_ns: u64,
        /// Residual share attributed to the shared PMEM pool.
        pool_ns: u64,
    },
    /// Undo-slice replay at quantum entry (torn expander).
    Recovery,
    /// A [`ResourceQueue`](crate::sim::engine::ResourceQueue) grant
    /// window. Runs on the ledger's own cumulative-busy clock, not the
    /// lane clock, so nesting checks skip it.
    Grant,
    /// A fabric transfer forwarded through the tenant's leaf path.
    Transfer { bytes: u64 },
    /// A crash plan armed (instant).
    CrashArm { batch: u64 },
    /// Fault plan `fault` struck the fabric (instant).
    FabricFault { fault: usize },
    /// Fault plan `fault` was repaired (instant).
    FabricRepair { fault: usize },
}

impl TraceKind {
    /// Stable display label (Chrome event name, attribution rows).
    pub fn label(&self) -> &'static str {
        match self {
            TraceKind::Run => "run",
            TraceKind::Round { catch_up: false, .. } => "round",
            TraceKind::Round { catch_up: true, .. } => "catch-up",
            TraceKind::Slot { .. } => "slot",
            TraceKind::Recovery => "recovery",
            TraceKind::Grant => "grant",
            TraceKind::Transfer { .. } => "transfer",
            TraceKind::CrashArm { .. } => "crash-arm",
            TraceKind::FabricFault { .. } => "fabric-fault",
            TraceKind::FabricRepair { .. } => "fabric-repair",
        }
    }

    /// Build a [`TraceKind::Slot`], decomposing a slot of `dur` ns: the
    /// failure components are clamped so they fit inside the slot, then
    /// the residual is split across {gpu, link, pool} proportionally to
    /// the batch's breakdown (B-MLP+T-MLP → gpu, Transfer → link,
    /// Embedding+Checkpoint → pool). Floors guarantee the components
    /// never sum past `dur`; the shortfall is the slot's idle share.
    pub fn slot(
        batch: u64,
        dur: SimTime,
        stall: u64,
        fault_stall: u64,
        recovery: u64,
        bd: &Breakdown,
    ) -> TraceKind {
        let recovery_ns = recovery.min(dur);
        let stall_ns = stall.min(dur - recovery_ns);
        let fault_stall_ns = fault_stall.min(dur - recovery_ns - stall_ns);
        let residual = (dur - recovery_ns - stall_ns - fault_stall_ns) as f64;
        let total = bd.total();
        let share = |part: f64| {
            if total > 0.0 {
                (residual * part / total) as u64
            } else {
                0
            }
        };
        TraceKind::Slot {
            batch,
            stall_ns,
            fault_stall_ns,
            recovery_ns,
            gpu_ns: share(bd.bmlp + bd.tmlp),
            link_ns: share(bd.transfer),
            pool_ns: share(bd.embedding + bd.checkpoint),
        }
    }
}

/// One typed, causally-linked trace record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Position in the log (assigned by [`TraceLog::record`]).
    pub id: u32,
    /// The enclosing event, `None` only for the root.
    pub parent: Option<u32>,
    /// Tenant (lane) index in the arbiter, `None` for engine scope.
    pub tenant: Option<u32>,
    /// Hardware lane the event occupies, when one applies.
    pub lane: Option<Lane>,
    /// Resource queue the event occupies, when one applies.
    pub resource: Option<Resource>,
    pub kind: TraceKind,
    pub t_start: SimTime,
    pub t_end: SimTime,
}

impl TraceEvent {
    /// A span with no lane/resource annotation.
    pub fn span(
        parent: Option<u32>,
        tenant: Option<u32>,
        kind: TraceKind,
        t_start: SimTime,
        t_end: SimTime,
    ) -> TraceEvent {
        TraceEvent {
            id: 0,
            parent,
            tenant,
            lane: None,
            resource: None,
            kind,
            t_start,
            t_end,
        }
    }

    /// A zero-duration event.
    pub fn instant(
        parent: Option<u32>,
        tenant: Option<u32>,
        kind: TraceKind,
        t: SimTime,
    ) -> TraceEvent {
        TraceEvent::span(parent, tenant, kind, t, t)
    }
}

/// Append-only log of [`TraceEvent`]s for one run. Ids are positions, so
/// a child always carries a smaller-id parent — the causal order IS the
/// append order.
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    events: Vec<TraceEvent>,
}

impl TraceLog {
    pub fn new() -> TraceLog {
        TraceLog::default()
    }

    /// Append `ev` (its `id` is overwritten with the log position) and
    /// return the assigned id.
    pub fn record(&mut self, mut ev: TraceEvent) -> u32 {
        let id = self.events.len() as u32;
        ev.id = id;
        self.events.push(ev);
        id
    }

    /// Rewrite the span of an already-recorded barrier event — how the
    /// merge thread closes a `Run`/`Round` once its children's extent is
    /// known. The log stays append-only in event count and causality.
    pub fn close(&mut self, id: u32, t_start: SimTime, t_end: SimTime) {
        let ev = &mut self.events[id as usize];
        ev.t_start = t_start;
        ev.t_end = t_end;
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Structural validation (the `trainingcxl trace` driver gate):
    ///
    /// 1. every event's parent exists and precedes it (causal ids);
    /// 2. no span ends before it starts (no negative durations);
    /// 3. `Slot`/`Recovery`/`Round` spans nest within their parent
    ///    barrier span (`Grant` runs on the ledger clock and `Transfer`
    ///    inside its slot's clock, so only same-clock pairs are checked).
    pub fn validate(&self) -> Result<(), String> {
        for ev in &self.events {
            let id = ev.id;
            if let Some(p) = ev.parent {
                if p >= id {
                    return Err(format!("event {id}: parent {p} does not precede it"));
                }
            }
            if ev.t_end < ev.t_start {
                return Err(format!(
                    "event {id} ({}): negative duration ({} -> {})",
                    ev.kind.label(),
                    ev.t_start,
                    ev.t_end
                ));
            }
            let nests = matches!(
                ev.kind,
                TraceKind::Slot { .. } | TraceKind::Recovery | TraceKind::Round { .. }
            );
            if nests {
                if let Some(p) = ev.parent {
                    let pa = &self.events[p as usize];
                    let barrier = matches!(pa.kind, TraceKind::Run | TraceKind::Round { .. });
                    if barrier && (ev.t_start < pa.t_start || ev.t_end > pa.t_end) {
                        return Err(format!(
                            "event {id} ({}) [{}, {}] escapes its {} parent {} [{}, {}]",
                            ev.kind.label(),
                            ev.t_start,
                            ev.t_end,
                            pa.kind.label(),
                            p,
                            pa.t_start,
                            pa.t_end
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Critical-path attribution: find the tenant whose last slot ends
    /// latest (its timeline IS the measured critical path) and attribute
    /// every nanosecond of it to a resource or wait bucket. The buckets
    /// sum to `total_ns` exactly — the `idle` bucket is defined as the
    /// remainder, and every other component is clamped into its slot at
    /// record time.
    pub fn attribution(&self) -> Attribution {
        let on_path = |ev: &TraceEvent| {
            matches!(ev.kind, TraceKind::Slot { .. } | TraceKind::Recovery)
        };
        let mut ends: BTreeMap<u32, SimTime> = BTreeMap::new();
        for ev in self.events.iter().filter(|e| on_path(e)) {
            if let Some(t) = ev.tenant {
                let e = ends.entry(t).or_insert(0);
                *e = (*e).max(ev.t_end);
            }
        }
        let Some((&tenant, &total_ns)) =
            ends.iter().max_by_key(|&(t, end)| (*end, std::cmp::Reverse(*t)))
        else {
            return Attribution {
                tenant: None,
                total_ns: 0,
                buckets: Attribution::BUCKETS.map(|b| (b, 0)).to_vec(),
            };
        };
        let mut sums: BTreeMap<&'static str, u64> = BTreeMap::new();
        for ev in self.events.iter().filter(|e| e.tenant == Some(tenant)) {
            let mut add = |k: &'static str, v: u64| *sums.entry(k).or_insert(0) += v;
            match ev.kind {
                TraceKind::Slot {
                    stall_ns,
                    fault_stall_ns,
                    recovery_ns,
                    gpu_ns,
                    link_ns,
                    pool_ns,
                    ..
                } => {
                    add("co-tenant-stall", stall_ns);
                    add("fault-stall", fault_stall_ns);
                    add("recovery", recovery_ns);
                    add("gpu-lane", gpu_ns);
                    add("pmem-pool", pool_ns);
                    match ev.resource {
                        Some(Resource::PcieLink) => add("pcie-link", link_ns),
                        _ => add("cxl-link", link_ns),
                    }
                }
                TraceKind::Recovery => add("recovery", ev.t_end - ev.t_start),
                _ => {}
            }
        }
        let covered: u64 = sums.values().sum();
        *sums.entry("idle").or_insert(0) += total_ns.saturating_sub(covered);
        Attribution {
            tenant: Some(tenant as usize),
            total_ns,
            buckets: Attribution::BUCKETS
                .map(|b| (b, sums.get(b).copied().unwrap_or(0)))
                .to_vec(),
        }
    }

    /// Export as Chrome trace-event JSON ("X" complete events + "i"
    /// instants, with `process_name`/`thread_name` metadata), loadable
    /// straight into Perfetto / `chrome://tracing`. Timestamps convert
    /// ns → µs (the format's unit). `tenants` names the tenant tracks;
    /// `spans`, when non-empty, must parallel `tenants` and adds one
    /// thread per hardware lane from each tenant's [`SpanLog`]. Output
    /// is deterministic: event order is log order, object keys are
    /// sorted, arithmetic is exact.
    pub fn chrome_trace(&self, tenants: &[String], spans: &[&SpanLog]) -> Json {
        let us = |t: SimTime| Json::Num(t as f64 / 1000.0);
        let obj = |pairs: Vec<(&str, Json)>| {
            Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
        };
        const PID_ENGINE: f64 = 1.0;
        const PID_RESOURCES: f64 = 2.0;
        let pid_tenant = |t: u32| 10.0 + t as f64;
        let mut out: Vec<Json> = Vec::new();
        let meta = |pid: f64, name: &str| {
            obj(vec![
                ("args", obj(vec![("name", Json::Str(name.to_string()))])),
                ("name", Json::Str("process_name".to_string())),
                ("ph", Json::Str("M".to_string())),
                ("pid", Json::Num(pid)),
            ])
        };
        let tmeta = |pid: f64, tid: f64, name: &str| {
            obj(vec![
                ("args", obj(vec![("name", Json::Str(name.to_string()))])),
                ("name", Json::Str("thread_name".to_string())),
                ("ph", Json::Str("M".to_string())),
                ("pid", Json::Num(pid)),
                ("tid", Json::Num(tid)),
            ])
        };
        out.push(meta(PID_ENGINE, "engine"));
        out.push(tmeta(PID_ENGINE, 0.0, "rounds"));
        out.push(tmeta(PID_ENGINE, 1.0, "events"));
        out.push(meta(PID_RESOURCES, "resource-queues"));
        for i in 0..Resource::COUNT {
            out.push(tmeta(PID_RESOURCES, i as f64, Resource::from_index(i).name()));
        }
        for (t, name) in tenants.iter().enumerate() {
            let pid = pid_tenant(t as u32);
            out.push(meta(pid, name));
            out.push(tmeta(pid, 0.0, "slots"));
            out.push(tmeta(pid, 1.0, "fabric"));
        }
        for ev in &self.events {
            let (pid, tid) = match ev.kind {
                TraceKind::Run | TraceKind::Round { .. } => (PID_ENGINE, 0.0),
                TraceKind::CrashArm { .. }
                | TraceKind::FabricFault { .. }
                | TraceKind::FabricRepair { .. } => (PID_ENGINE, 1.0),
                TraceKind::Grant => (
                    PID_RESOURCES,
                    ev.resource.map(|r| r.index()).unwrap_or(0) as f64,
                ),
                TraceKind::Transfer { .. } => (pid_tenant(ev.tenant.unwrap_or(0)), 1.0),
                _ => (pid_tenant(ev.tenant.unwrap_or(0)), 0.0),
            };
            let mut args: Vec<(&str, Json)> = vec![("id", Json::Num(ev.id as f64))];
            if let Some(p) = ev.parent {
                args.push(("parent", Json::Num(p as f64)));
            }
            if let Some(r) = ev.resource {
                args.push(("resource", Json::Str(r.name().to_string())));
            }
            match ev.kind {
                TraceKind::Round { round, .. } => {
                    args.push(("round", Json::Num(round as f64)));
                }
                TraceKind::Slot {
                    batch,
                    stall_ns,
                    fault_stall_ns,
                    recovery_ns,
                    ..
                } => {
                    args.push(("batch", Json::Num(batch as f64)));
                    args.push(("stall_ns", Json::Num(stall_ns as f64)));
                    args.push(("fault_stall_ns", Json::Num(fault_stall_ns as f64)));
                    args.push(("recovery_ns", Json::Num(recovery_ns as f64)));
                }
                TraceKind::Transfer { bytes } => {
                    args.push(("bytes", Json::Num(bytes as f64)));
                }
                TraceKind::CrashArm { batch } => {
                    args.push(("batch", Json::Num(batch as f64)));
                }
                TraceKind::FabricFault { fault } | TraceKind::FabricRepair { fault } => {
                    args.push(("fault", Json::Num(fault as f64)));
                }
                _ => {}
            }
            let instant = ev.t_end == ev.t_start
                && matches!(
                    ev.kind,
                    TraceKind::CrashArm { .. }
                        | TraceKind::FabricFault { .. }
                        | TraceKind::FabricRepair { .. }
                );
            let mut fields: Vec<(&str, Json)> = vec![
                ("args", obj(args)),
                ("cat", Json::Str("engine".to_string())),
                ("name", Json::Str(ev.kind.label().to_string())),
                ("pid", Json::Num(pid)),
                ("tid", Json::Num(tid)),
                ("ts", us(ev.t_start)),
            ];
            if instant {
                fields.push(("ph", Json::Str("i".to_string())));
                fields.push(("s", Json::Str("t".to_string())));
            } else {
                fields.push(("ph", Json::Str("X".to_string())));
                fields.push(("dur", us(ev.t_end - ev.t_start)));
            }
            out.push(obj(fields));
        }
        // hardware-lane tracks from the tenant span logs: tid 2+lane
        const LANES: [Lane; 6] = [
            Lane::Gpu,
            Lane::CompLogic,
            Lane::CkptLogic,
            Lane::Pmem,
            Lane::HostCpu,
            Lane::Link,
        ];
        for (t, log) in spans.iter().enumerate() {
            let pid = pid_tenant(t as u32);
            for (li, lane) in LANES.iter().enumerate() {
                if log.spans.iter().any(|s| s.lane == *lane) {
                    out.push(tmeta(pid, 2.0 + li as f64, lane.name()));
                }
            }
            for s in &log.spans {
                let li = LANES.iter().position(|l| *l == s.lane).unwrap_or(0);
                out.push(obj(vec![
                    ("args", obj(vec![("batch", Json::Num(s.batch as f64))])),
                    ("cat", Json::Str("lane".to_string())),
                    ("dur", us(s.end - s.start)),
                    ("name", Json::Str(format!("{:?}", s.kind))),
                    ("ph", Json::Str("X".to_string())),
                    ("pid", Json::Num(pid)),
                    ("tid", Json::Num(2.0 + li as f64)),
                    ("ts", us(s.start)),
                ]));
            }
        }
        let mut top = BTreeMap::new();
        top.insert("displayTimeUnit".to_string(), Json::Str("ns".to_string()));
        top.insert("traceEvents".to_string(), Json::Arr(out));
        Json::Obj(top)
    }
}

/// Where the critical path's time went — [`TraceLog::attribution`]'s
/// result. `buckets` always carries every bucket (zeros included), in
/// [`Attribution::BUCKETS`] order, and sums to `total_ns` exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct Attribution {
    /// Index of the critical-path tenant (`None` on an empty trace).
    pub tenant: Option<usize>,
    /// The measured critical path: the tenant's last slot end (ns).
    pub total_ns: u64,
    pub buckets: Vec<(&'static str, u64)>,
}

impl Attribution {
    pub const BUCKETS: [&'static str; 8] = [
        "gpu-lane",
        "cxl-link",
        "pcie-link",
        "pmem-pool",
        "co-tenant-stall",
        "fault-stall",
        "recovery",
        "idle",
    ];

    /// The buckets' sum — equals `total_ns` by construction.
    pub fn sum_ns(&self) -> u64 {
        self.buckets.iter().map(|&(_, v)| v).sum()
    }

    /// Plain-text table (the `trainingcxl trace --summary` body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "critical path: {:.3} ms{}\n",
            self.total_ns as f64 / 1e6,
            match self.tenant {
                Some(t) => format!(" (tenant {t})"),
                None => String::new(),
            }
        ));
        out.push_str(&format!("{:<18} {:>12} {:>7}\n", "bucket", "ms", "%"));
        for &(name, v) in &self.buckets {
            out.push_str(&format!(
                "{:<18} {:>12.3} {:>6.1}%\n",
                name,
                v as f64 / 1e6,
                100.0 * v as f64 / self.total_ns.max(1) as f64
            ));
        }
        out.push_str(&format!(
            "{:<18} {:>12.3} {:>6.1}%\n",
            "TOTAL",
            self.sum_ns() as f64 / 1e6,
            100.0 * self.sum_ns() as f64 / self.total_ns.max(1) as f64
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bd(bmlp: f64, transfer: f64, embedding: f64) -> Breakdown {
        Breakdown {
            bmlp,
            tmlp: 0.0,
            transfer,
            embedding,
            checkpoint: 0.0,
        }
    }

    #[test]
    fn slot_decomposition_never_exceeds_the_slot() {
        let k = TraceKind::slot(0, 100, 30, 20, 10, &bd(2.0, 1.0, 1.0));
        let TraceKind::Slot {
            stall_ns,
            fault_stall_ns,
            recovery_ns,
            gpu_ns,
            link_ns,
            pool_ns,
            ..
        } = k
        else {
            panic!("not a slot")
        };
        assert_eq!((stall_ns, fault_stall_ns, recovery_ns), (30, 20, 10));
        // residual 40 split 2:1:1
        assert_eq!((gpu_ns, link_ns, pool_ns), (20, 10, 10));
        // oversized failure components clamp instead of overflowing
        let k = TraceKind::slot(0, 50, 100, 100, 100, &bd(1.0, 0.0, 0.0));
        let TraceKind::Slot {
            stall_ns,
            fault_stall_ns,
            recovery_ns,
            gpu_ns,
            ..
        } = k
        else {
            panic!("not a slot")
        };
        assert_eq!(recovery_ns, 50);
        assert_eq!(stall_ns + fault_stall_ns + gpu_ns, 0);
    }

    #[test]
    fn validate_rejects_orphans_inversions_and_escapes() {
        let mut log = TraceLog::new();
        let root = log.record(TraceEvent::span(None, None, TraceKind::Run, 0, 100));
        let round = log.record(TraceEvent::span(
            Some(root),
            None,
            TraceKind::Round {
                round: 0,
                catch_up: false,
            },
            0,
            50,
        ));
        log.record(TraceEvent::span(
            Some(round),
            Some(0),
            TraceKind::slot(0, 40, 0, 0, 0, &bd(1.0, 0.0, 0.0)),
            10,
            50,
        ));
        assert!(log.validate().is_ok());

        // a slot escaping its round
        let mut bad = log.clone();
        bad.record(TraceEvent::span(
            Some(round),
            Some(0),
            TraceKind::slot(1, 20, 0, 0, 0, &bd(1.0, 0.0, 0.0)),
            40,
            60,
        ));
        assert!(bad.validate().unwrap_err().contains("escapes"));

        // an inverted span
        let mut bad = log.clone();
        bad.record(TraceEvent::span(Some(root), None, TraceKind::Recovery, 9, 3));
        assert!(bad.validate().unwrap_err().contains("negative duration"));

        // a self/forward parent
        let mut bad = log.clone();
        let id = bad.record(TraceEvent::instant(None, None, TraceKind::CrashArm { batch: 0 }, 0));
        bad.close(id, 0, 0);
        bad.events[id as usize].parent = Some(id);
        assert!(bad.validate().unwrap_err().contains("precede"));
    }

    #[test]
    fn attribution_sums_exactly_and_picks_the_slowest_tenant() {
        let mut log = TraceLog::new();
        let root = log.record(TraceEvent::span(None, None, TraceKind::Run, 0, 1000));
        // tenant 0 ends at 400; tenant 1 at 1000 — tenant 1 is critical
        log.record(TraceEvent::span(
            Some(root),
            Some(0),
            TraceKind::slot(0, 400, 0, 0, 0, &bd(1.0, 0.0, 0.0)),
            0,
            400,
        ));
        let mut ev = TraceEvent::span(
            Some(root),
            Some(1),
            TraceKind::slot(0, 900, 100, 50, 0, &bd(1.0, 1.0, 2.0)),
            100,
            1000,
        );
        ev.resource = Some(Resource::PcieLink);
        log.record(ev);
        let a = log.attribution();
        assert_eq!(a.tenant, Some(1));
        assert_eq!(a.total_ns, 1000);
        assert_eq!(a.sum_ns(), a.total_ns);
        let get = |k: &str| a.buckets.iter().find(|(b, _)| *b == k).unwrap().1;
        assert_eq!(get("co-tenant-stall"), 100);
        assert_eq!(get("fault-stall"), 50);
        // residual 750 split 1:1:2 over gpu/link/pool; link on PCIe
        assert_eq!(get("gpu-lane"), 187);
        assert_eq!(get("pcie-link"), 187);
        assert_eq!(get("cxl-link"), 0);
        assert_eq!(get("pmem-pool"), 375);
        // the 100 ns lead-in gap plus the split's floor shortfall is idle
        assert_eq!(get("idle"), 1000 - 100 - 50 - 187 - 187 - 375);
        assert!(a.render().contains("critical path"));
    }

    #[test]
    fn chrome_export_is_loadable_shaped() {
        let mut log = TraceLog::new();
        let root = log.record(TraceEvent::span(None, None, TraceKind::Run, 0, 100));
        let mut grant = TraceEvent::span(Some(root), Some(0), TraceKind::Grant, 0, 10);
        grant.resource = Some(Resource::PmemPool);
        log.record(grant);
        log.record(TraceEvent::instant(
            Some(root),
            None,
            TraceKind::FabricFault { fault: 0 },
            5,
        ));
        let mut spans = SpanLog::default();
        spans.add(Lane::Gpu, crate::sim::OpKind::BottomMlp, 0, 0, 50);
        let j = log.chrome_trace(&["a".to_string()], &[&spans]);
        let s = j.to_string();
        assert!(s.contains("\"traceEvents\""), "{s}");
        assert!(s.contains("\"process_name\""), "{s}");
        assert!(s.contains("\"pmem-pool\""), "{s}");
        assert!(s.contains("\"fabric-fault\""), "{s}");
        assert!(s.contains("\"BottomMlp\""), "{s}");
        // round-trips through our own parser
        let parsed = crate::util::json::Json::parse(&s).expect("export must parse");
        assert!(parsed.get("traceEvents").and_then(|t| t.as_arr()).is_some());
    }
}
