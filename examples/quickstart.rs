//! Quickstart: the public API in ~80 lines.
//!
//! 1. simulate a few batches of RM1 under the paper's six system configs
//!    and print the Fig-11-style breakdown;
//! 2. build a *custom* fabric topology (pooled expanders) with the
//!    builder API and simulate it through the same stage pipeline;
//! 3. run a handful of *real* training steps (PJRT-executed AOT
//!    artifacts) on the tiny model and watch the loss fall.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use trainingcxl::bench::experiments;
use trainingcxl::config::{CkptMode, ModelConfig, SystemConfig};
use trainingcxl::sim::topology::Topology;
use trainingcxl::telemetry::BreakdownTable;
use trainingcxl::train::Trainer;

fn main() -> anyhow::Result<()> {
    let root = trainingcxl::repo_root();

    // ---- 1. the timing simulator (no artifacts needed)
    println!("== RM1 mean batch latency under each system config ==");
    let mut table = BreakdownTable::default();
    for sys in SystemConfig::ALL {
        let run = experiments::simulate(&root, "rm1", sys, 12)?;
        table.push(sys.name(), run.mean_breakdown());
    }
    print!("{}", table.render(1e6, "ms"));

    let pmem = experiments::simulate(&root, "rm1", SystemConfig::Pmem, 12)?.mean_batch_ns();
    let cxl = experiments::simulate(&root, "rm1", SystemConfig::Cxl, 12)?.mean_batch_ns();
    println!("TrainingCXL speedup over PMEM on RM1: {:.2}x\n", pmem / cxl);

    // ---- 2. a custom scenario through the Topology builder
    // (same stage pipeline the paper configs run through; see
    // docs/topology.md and configs/topologies/ for the TOML route)
    let pooled = Topology::builder("pooled-cxl-4x")
        .near_data()
        .hw_movement()
        .checkpoint(CkptMode::Relaxed)
        .relaxed_lookup()
        .max_mlp_log_gap(200)
        .expander_pool(4, 2)
        .build()?;
    let run = experiments::simulate_topology(&root, "rm2", pooled, 12)?;
    println!(
        "== custom topology [{}] on RM2: {:.3} ms/batch (flagship CXL: {:.3}) ==\n",
        run.topology,
        run.mean_batch_ns() / 1e6,
        experiments::simulate(&root, "rm2", SystemConfig::Cxl, 12)?.mean_batch_ns() / 1e6
    );

    // ---- 3. real training through the PJRT runtime
    if !root.join("artifacts/rm_mini/manifest.json").exists() {
        println!("(skipping live training: run `make artifacts` first)");
        return Ok(());
    }
    // The trainer is constructed from the same Topology the simulator
    // runs: the CXL flagship's CkptMode::Relaxed turns on batch-aware
    // checkpointing with the MLP log streamed across batches.
    println!("== 25 real training steps (rm_mini, PJRT CPU, CXL topology) ==");
    let cfg = ModelConfig::load(&root, "rm_mini")?;
    let mut trainer =
        Trainer::with_topology(&root, &cfg, 7, &Topology::from_system(SystemConfig::Cxl))?;
    let mut first = None;
    let mut last = 0.0;
    for s in 0..25 {
        let out = trainer.step()?;
        first.get_or_insert(out.loss);
        last = out.loss;
        if s % 5 == 0 {
            println!("step {:>3}  loss {:.5}", out.batch, out.loss);
        }
    }
    println!(
        "loss {:.4} -> {:.4} ({}), quickstart OK",
        first.unwrap(),
        last,
        if last < first.unwrap() { "learning" } else { "check your build" }
    );
    Ok(())
}
