//! Host CPU model: the software path the CXL configs eliminate (paper
//! Fig 4a) and the host-side embedding operators the SSD/PMEM baselines
//! use.
//!
//! Costs: `cudaStreamSynchronize` round trips, `cudaMemcpy` staging over
//! PCIe, kernel-launch overhead, and per-vector aggregation on the CPU
//! (the baselines aggregate embedding vectors with scalar code).

use crate::config::device::{DeviceParams, HostParams};
use crate::sim::cxl::{Link, Proto};
use crate::sim::mem::{AccessCost, AccessKind, MediaModel};
use crate::sim::{ns, SimTime};

use super::cxl_mem::MemOp;

#[derive(Clone, Debug)]
pub struct HostCpu {
    pub p: HostParams,
    row_bytes: u64,
}

impl HostCpu {
    pub fn new(row_bytes: u64, p: &DeviceParams) -> HostCpu {
        HostCpu {
            p: p.host.clone(),
            row_bytes,
        }
    }

    /// Host-side embedding lookup: gather `accesses` rows from the table
    /// medium (a fraction `cache_hit_frac` served by the DRAM cache) and
    /// aggregate on the CPU.
    #[allow(clippy::too_many_arguments)]
    pub fn embedding_lookup(
        &self,
        start: SimTime,
        table: &mut MediaModel,
        dram: &mut MediaModel,
        accesses: u64,
        cache_hit_frac: f64,
        raw_frac: f64,
    ) -> MemOp {
        let hits = ((accesses as f64 * cache_hit_frac) as u64).min(accesses);
        let misses = accesses - hits;
        let m = table.batch_access(start, misses, self.row_bytes, AccessKind::Read, raw_frac);
        let h = dram.batch_access(start, hits, self.row_bytes, AccessKind::Read, 0.0);
        // gather streams from both tiers run concurrently; CPU aggregation
        // is serial per vector and usually the DRAM-tier bound
        let aggregate = ns(accesses as f64 * self.p.per_vector_ns);
        MemOp {
            duration: m.duration.max(h.duration).max(aggregate),
            media: AccessCost {
                duration: m.duration + h.duration,
                bytes_read: m.bytes_read + h.bytes_read,
                bytes_written: 0,
                raw_hits: m.raw_hits,
            },
            link_bytes: 0,
            compute_ns: aggregate,
        }
    }

    /// Host-side embedding update (RMW through the cache-miss path).
    pub fn embedding_update(
        &self,
        start: SimTime,
        table: &mut MediaModel,
        unique_rows: u64,
    ) -> MemOp {
        let rd = table.batch_access(start, unique_rows, self.row_bytes, AccessKind::Read, 0.0);
        let wr = table.batch_access(
            start + rd.duration,
            unique_rows,
            self.row_bytes,
            AccessKind::Write,
            0.0,
        );
        let compute = ns(unique_rows as f64 * self.p.per_vector_ns);
        MemOp {
            duration: (rd.duration + wr.duration).max(compute),
            media: AccessCost {
                duration: rd.duration + wr.duration,
                bytes_read: rd.bytes_read,
                bytes_written: wr.bytes_written,
                raw_hits: 0,
            },
            link_bytes: 0,
            compute_ns: compute,
        }
    }

    /// Software transfer (Fig 4a): `cudaStreamSynchronize` + `cudaMemcpy`
    /// of `bytes` over the PCIe link, plus the next kernel launch.
    pub fn sw_transfer(&self, pcie: &Link, bytes: u64) -> MemOp {
        let xfer = pcie.transfer(bytes, Proto::Io);
        MemOp {
            duration: ns(self.p.sync_ns + self.p.memcpy_setup_ns + self.p.kernel_launch_ns)
                + xfer.duration,
            media: AccessCost::default(),
            link_bytes: xfer.bytes,
            compute_ns: 0,
        }
    }

    /// Host-driven redo-log checkpoint for the baselines: read updated
    /// rows from the table medium, write rows + MLP params to the
    /// persistent medium; MLP params first staged from GPU over PCIe.
    pub fn redo_checkpoint(
        &self,
        start: SimTime,
        table: &mut MediaModel,
        pcie: &Link,
        unique_rows: u64,
        mlp_bytes: u64,
    ) -> MemOp {
        let stage = self.sw_transfer(pcie, mlp_bytes);
        let rd = table.batch_access(
            start + stage.duration,
            unique_rows,
            self.row_bytes,
            AccessKind::Read,
            0.0,
        );
        let wr = table.stream(
            start + stage.duration + rd.duration,
            unique_rows * self.row_bytes + mlp_bytes,
            AccessKind::Write,
        );
        MemOp {
            duration: stage.duration + rd.duration + wr.duration,
            media: AccessCost {
                duration: rd.duration + wr.duration,
                bytes_read: rd.bytes_read,
                bytes_written: wr.bytes_written,
                raw_hits: 0,
            },
            link_bytes: stage.link_bytes,
            compute_ns: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::device::DeviceParams;
    use crate::sim::mem::MediaKind;

    fn setup() -> (HostCpu, MediaModel, MediaModel, Link) {
        let p = DeviceParams::builtin_default();
        (
            HostCpu::new(128, &p),
            MediaModel::new(MediaKind::Ssd, p.ssd.clone()),
            MediaModel::new(MediaKind::Dram, p.dram.clone()),
            Link::new(p.pcie_link.clone()),
        )
    }

    #[test]
    fn cache_hits_cut_ssd_lookup_time() {
        let (host, mut ssd, mut dram, _) = setup();
        let cold = host.embedding_lookup(0, &mut ssd, &mut dram, 100_000, 0.0, 0.0);
        ssd.reset();
        let warm = host.embedding_lookup(0, &mut ssd, &mut dram, 100_000, 0.9, 0.0);
        assert!(warm.duration < cold.duration / 5);
    }

    #[test]
    fn sw_transfer_has_fixed_software_floor() {
        let (host, _, _, pcie) = setup();
        let tiny = host.sw_transfer(&pcie, 64);
        let floor = (host.p.sync_ns + host.p.memcpy_setup_ns + host.p.kernel_launch_ns) as SimTime;
        assert!(tiny.duration >= floor);
    }

    #[test]
    fn redo_checkpoint_scales_with_rows() {
        let (host, mut ssd, _, pcie) = setup();
        let small = host.redo_checkpoint(0, &mut ssd, &pcie, 1_000, 1 << 20);
        ssd.reset();
        let big = host.redo_checkpoint(0, &mut ssd, &pcie, 100_000, 1 << 20);
        assert!(big.duration > small.duration);
    }
}
