//! Failure-tolerance management (paper §Failure Tolerance Management).
//!
//! Byte-accurate undo-log checkpointing into a [`LogRegion`] — the
//! CXL-MEM log region of Fig 7 — plus crash recovery. The *timing* of
//! checkpoints is priced by [`crate::devices::cxl_mem`]; this module is
//! the *semantics*: what bytes land where, when the persistent flags flip,
//! and what state is reconstructible after a power failure.
//!
//! Key behaviours reproduced:
//! * embedding log per batch (the tables mutate every batch);
//! * MLP log allowed to lag by a bounded batch gap (Fig 9a shows the
//!   accuracy budget tolerates hundreds of batches);
//! * persistent flags written last; the previous checkpoint is deleted
//!   only after both flags of the current one are set (Fig 7 step 4);
//! * recovery restores the tables to batch N and the MLPs to batch N-g.

pub mod log_region;
pub mod recovery;

pub use log_region::{EmbLogEntry, LogRegion, MlpLog};
pub use recovery::{recover, RecoveredState};
