//! The trainer: owns device-resident state, drives batches through the
//! AOT executables, and (optionally) maintains the byte-accurate
//! batch-aware checkpoint of the paper.

use crate::checkpoint::LogRegion;
use crate::config::ModelConfig;
use crate::emb::EmbeddingStore;
use crate::runtime::{HostTensor, ModelRuntime};
use crate::util::Rng;
use crate::workload::{Batch, Generator};
use std::path::Path;

/// Checkpointing behaviour of the trainer.
#[derive(Clone, Copy, Debug)]
pub struct CkptOptions {
    /// Take an embedding undo-log every batch (the paper's invariant).
    pub emb_every_batch: bool,
    /// MLP snapshot cadence in batches (1 = every batch; Fig 9a sweeps
    /// this gap).
    pub mlp_every: u64,
}

impl Default for CkptOptions {
    fn default() -> Self {
        CkptOptions {
            emb_every_batch: true,
            mlp_every: 1,
        }
    }
}

/// Per-step outputs.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    pub batch: u64,
    pub loss: f32,
}

/// Real trainer over the AOT artifacts.
pub struct Trainer {
    pub cfg: ModelConfig,
    rt: ModelRuntime,
    gen: Generator,
    /// Device-resident embedding table (T, R, D) — never downloaded on the
    /// hot path.
    table: xla::PjRtBuffer,
    /// Small MLP parameters: host copy + device buffers (re-uploaded per
    /// step after SGD).
    mlp_host: Vec<Vec<f32>>,
    mlp_shapes: Vec<Vec<usize>>,
    mlp_bufs: Vec<xla::PjRtBuffer>,
    /// Host mirror of the table, maintained only when checkpointing is on
    /// (recovery experiments run at rm_mini scale where this is cheap).
    pub store: Option<EmbeddingStore>,
    pub log: Option<LogRegion>,
    pub ckpt: CkptOptions,
    step_no: u64,
}

impl Trainer {
    /// Exports the trainer needs compiled.
    pub const EXPORTS: [&'static str; 4] =
        ["embedding_bag", "mlp_step", "embedding_update", "forward"];

    pub fn new(
        root: &Path,
        cfg: &ModelConfig,
        seed: u64,
        ckpt: Option<CkptOptions>,
    ) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::load(root, &cfg.name, &Self::EXPORTS)?;
        let mut rng = Rng::new(seed);

        // Xavier-uniform init, same layout as the manifest's param list.
        let mut mlp_host = Vec::new();
        let mut mlp_shapes = Vec::new();
        let mut table_host: Vec<f32> = Vec::new();
        for (name, shape) in &rt.manifest.params {
            let n: usize = shape.iter().product();
            if name == "table" {
                table_host = (0..n).map(|_| (rng.next_f32() - 0.5) * 0.1).collect();
            } else if name.contains("_w") {
                let limit = (6.0 / (shape[0] + shape[1]) as f32).sqrt();
                mlp_host.push((0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * limit).collect());
                mlp_shapes.push(shape.clone());
            } else {
                mlp_host.push(vec![0.0; n]);
                mlp_shapes.push(shape.clone());
            }
        }
        let table_shape = rt.manifest.params.last().unwrap().1.clone();
        let table = rt.to_device(&HostTensor::F32(table_host.clone(), table_shape))?;
        let mlp_bufs = mlp_host
            .iter()
            .zip(&mlp_shapes)
            .map(|(v, s)| rt.to_device(&HostTensor::F32(v.clone(), s.clone())))
            .collect::<anyhow::Result<Vec<_>>>()?;

        let (store, log) = if ckpt.is_some() {
            (
                Some(EmbeddingStore::from_flat(cfg, table_host)),
                Some(LogRegion::new()),
            )
        } else {
            (None, None)
        };

        Ok(Trainer {
            cfg: cfg.clone(),
            rt,
            gen: Generator::new(cfg, seed ^ 0xBA7C4),
            table,
            mlp_host,
            mlp_shapes,
            mlp_bufs,
            store,
            log,
            ckpt: ckpt.unwrap_or_default(),
            step_no: 0,
        })
    }

    pub fn step_no(&self) -> u64 {
        self.step_no
    }

    pub fn mlp_params(&self) -> &[Vec<f32>] {
        &self.mlp_host
    }

    fn idx_shape(&self) -> Vec<usize> {
        vec![
            self.cfg.num_tables,
            self.cfg.batch_size,
            self.cfg.lookups_per_table,
        ]
    }

    /// Run one training batch; returns the loss.
    pub fn step(&mut self) -> anyhow::Result<StepOutcome> {
        let batch = self.gen.next_batch();
        self.step_with_batch(&batch)
    }

    /// Run one training batch with caller-provided data (replay/recovery).
    pub fn step_with_batch(&mut self, batch: &Batch) -> anyhow::Result<StepOutcome> {
        let b = self.step_no;

        // ---- batch-aware checkpoint: undo-log BEFORE the update lands
        // (the sparse features tell us which rows will change — Fig 6).
        if let (Some(store), Some(log)) = (self.store.as_ref(), self.log.as_mut()) {
            if self.ckpt.emb_every_batch {
                let touched = store.touched_rows(&batch.indices);
                log.begin_emb_log(b, store, &touched);
                log.seal_emb_log(b);
            }
            if b % self.ckpt.mlp_every == 0 {
                log.begin_mlp_log(b, &self.mlp_host);
                let total: u64 = self.mlp_host.iter().map(|p| (p.len() * 4) as u64).sum();
                log.advance_mlp_log(total);
                log.seal_mlp_log();
            }
        }

        // ---- FWP embedding path (CXL-MEM computing logic)
        let idx = self
            .rt
            .to_device(&HostTensor::I32(batch.indices.clone(), self.idx_shape()))?;
        let reduced = self
            .rt
            .run_b("embedding_bag", &[&self.table, &idx])?
            .remove(0);

        // ---- MLP fwd+bwd+SGD (CXL-GPU)
        let dense = self.rt.to_device(&HostTensor::F32(
            batch.dense.clone(),
            vec![self.cfg.batch_size, self.cfg.num_dense],
        ))?;
        let labels = self.rt.to_device(&HostTensor::F32(
            batch.labels.clone(),
            vec![self.cfg.batch_size],
        ))?;
        let mut args: Vec<&xla::PjRtBuffer> = self.mlp_bufs.iter().collect();
        args.push(&reduced);
        args.push(&dense);
        args.push(&labels);
        let mut outs = self.rt.run_to_host("mlp_step", &args)?;
        let loss = outs.pop().unwrap()[0];
        let grad_reduced = outs.pop().unwrap();
        // new MLP params
        for (dst, src) in self.mlp_host.iter_mut().zip(outs) {
            *dst = src;
        }
        self.mlp_bufs = self
            .mlp_host
            .iter()
            .zip(&self.mlp_shapes)
            .map(|(v, s)| self.rt.to_device(&HostTensor::F32(v.clone(), s.clone())))
            .collect::<anyhow::Result<Vec<_>>>()?;

        // ---- BWP embedding path: near-data scatter update
        let grad = self.rt.to_device(&HostTensor::F32(
            grad_reduced.clone(),
            vec![
                self.cfg.batch_size,
                self.cfg.num_tables,
                self.cfg.feature_dim,
            ],
        ))?;
        self.table = self
            .rt
            .run_b("embedding_update", &[&self.table, &idx, &grad])?
            .remove(0);

        // ---- keep the host mirror (data region image) in sync
        if self.store.is_some() {
            let flat = self.rt.to_host_f32(&self.table)?;
            self.store = Some(EmbeddingStore::from_flat(&self.cfg, flat));
        }

        self.step_no += 1;
        Ok(StepOutcome { batch: b, loss })
    }

    /// Mean loss + binary accuracy over `n` held-out batches (seeded apart
    /// from the training stream).
    pub fn evaluate(&self, n: u64, seed: u64) -> anyhow::Result<(f32, f32)> {
        let mut gen = Generator::new(&self.cfg, seed);
        let mut loss_sum = 0.0f64;
        let mut correct = 0u64;
        let mut total = 0u64;
        for _ in 0..n {
            let batch = gen.next_batch();
            let idx = self
                .rt
                .to_device(&HostTensor::I32(batch.indices.clone(), self.idx_shape()))?;
            let dense = self.rt.to_device(&HostTensor::F32(
                batch.dense.clone(),
                vec![self.cfg.batch_size, self.cfg.num_dense],
            ))?;
            let mut args: Vec<&xla::PjRtBuffer> = self.mlp_bufs.iter().collect();
            args.push(&self.table);
            args.push(&dense);
            args.push(&idx);
            let logits = self.rt.to_host_f32(&self.rt.run_b("forward", &args)?[0])?;
            for (lo, la) in logits.iter().zip(&batch.labels) {
                let p = 1.0 / (1.0 + (-lo).exp());
                loss_sum += -(la * p.max(1e-7).ln() + (1.0 - la) * (1.0 - p).max(1e-7).ln()) as f64;
                if (p > 0.5) == (*la > 0.5) {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok((
            (loss_sum / total as f64) as f32,
            correct as f32 / total as f32,
        ))
    }

    /// Simulate a power failure mid-update: the device state is lost; the
    /// touched rows of the in-flight batch are garbage in the host image.
    /// Returns the post-crash (store, log) pair for recovery.
    pub fn crash(mut self) -> (EmbeddingStore, LogRegion, Vec<Vec<usize>>) {
        let store = self.store.take().expect("crash() requires checkpointing");
        let log = self.log.take().expect("crash() requires checkpointing");
        let shapes = self.mlp_shapes.clone();
        (store, log, shapes)
    }

    /// Rebuild a trainer from recovered state (tables rolled back to the
    /// logged batch, MLP params possibly `gap` batches stale).
    pub fn from_recovered(
        root: &Path,
        cfg: &ModelConfig,
        seed: u64,
        store: EmbeddingStore,
        mlp_params: Vec<Vec<f32>>,
        mlp_shapes: Vec<Vec<usize>>,
        resume_batch: u64,
        ckpt: CkptOptions,
    ) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::load(root, &cfg.name, &Self::EXPORTS)?;
        let table_shape = rt.manifest.params.last().unwrap().1.clone();
        let table = rt.to_device(&HostTensor::F32(store.flat().to_vec(), table_shape))?;
        let mlp_bufs = mlp_params
            .iter()
            .zip(&mlp_shapes)
            .map(|(v, s)| rt.to_device(&HostTensor::F32(v.clone(), s.clone())))
            .collect::<anyhow::Result<Vec<_>>>()?;
        // Re-play the generator to the resume point so the data stream
        // continues exactly where the crash happened.
        let mut gen = Generator::new(cfg, seed ^ 0xBA7C4);
        for _ in 0..resume_batch {
            let _ = gen.next_batch();
        }
        Ok(Trainer {
            cfg: cfg.clone(),
            rt,
            gen,
            table,
            mlp_host: mlp_params,
            mlp_shapes,
            mlp_bufs,
            store: Some(store),
            log: Some(LogRegion::new()),
            ckpt,
            step_no: resume_batch,
        })
    }
}
