//! CXL protocol substrate: sub-protocol message types, link timing, a
//! multi-port switch, and the DCOH (device coherency engine) that makes
//! Type-2 automatic data movement possible (paper Fig 2/5).
//!
//! The fabric is modelled at transfer granularity: a [`Link`] prices a
//! message by flit count and hop latency; the [`Switch`] routes between
//! ports (HPA ranges) and accumulates per-port byte counters; [`Dcoh`]
//! tracks cacheline ownership so flushes ("the CXL-MEM's DCOH flushes
//! every cacheline of the reduced embedding vector", Fig 5b) move exactly
//! the dirty lines — the mechanism that replaces cudaMemcpy.

pub mod dcoh;
pub mod switch;

pub use dcoh::{CacheState, Dcoh};
pub use switch::{PortId, Switch};

use super::{ns, SimTime};
use crate::config::device::LinkParams;

/// CXL sub-protocols (Fig 2). Type-2 devices (CXL-MEM, CXL-GPU) implement
/// all three.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Proto {
    /// Discovery/config via MMIO registers.
    Io,
    /// Device-initiated coherent access to HPA (what moves embeddings).
    Cache,
    /// Host-initiated access to device memory.
    Mem,
}

/// A priced fabric transfer.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub proto: Proto,
    pub bytes: u64,
    pub duration: SimTime,
}

/// Point-to-point CXL/PCIe link timing.
#[derive(Clone, Debug)]
pub struct Link {
    pub p: LinkParams,
}

impl Link {
    pub fn new(p: LinkParams) -> Self {
        Link { p }
    }

    /// Duration of moving `bytes` through `hops` switch hops: per-hop
    /// latency plus serialisation at link bandwidth, flit-padded.
    pub fn transfer(&self, bytes: u64, proto: Proto) -> Transfer {
        let flits = bytes.div_ceil(self.p.flit_bytes).max(1);
        let wire_bytes = flits * self.p.flit_bytes;
        let duration = ns(
            self.p.hop_ns * self.p.hops as f64 + wire_bytes as f64 / self.p.gbps,
        );
        Transfer {
            proto,
            bytes: wire_bytes,
            duration,
        }
    }

    /// Latency of a single small message (doorbell, MMIO write, snoop).
    pub fn message(&self) -> SimTime {
        ns(self.p.hop_ns * self.p.hops as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::device::DeviceParams;

    #[test]
    fn transfer_cost_scales_with_bytes() {
        let p = DeviceParams::builtin_default();
        let link = Link::new(p.cxl_link.clone());
        let small = link.transfer(64, Proto::Cache);
        let big = link.transfer(1 << 20, Proto::Cache);
        assert!(big.duration > small.duration);
        // 1 MiB at 64 GB/s ~= 16.4 us plus hops
        assert!((15_000..25_000).contains(&big.duration), "{}", big.duration);
    }

    #[test]
    fn flit_padding_rounds_up() {
        let p = DeviceParams::builtin_default();
        let link = Link::new(p.cxl_link.clone());
        let t = link.transfer(1, Proto::Io);
        assert_eq!(t.bytes, p.cxl_link.flit_bytes);
    }

    #[test]
    fn cxl_beats_pcie_for_small_transfers() {
        // the software-eliminating claim needs the fabric itself to be
        // cheaper per message than a PCIe DMA round trip
        let p = DeviceParams::builtin_default();
        let cxl = Link::new(p.cxl_link.clone());
        let pcie = Link::new(p.pcie_link.clone());
        assert!(
            cxl.transfer(4096, Proto::Cache).duration
                < pcie.transfer(4096, Proto::Cache).duration
        );
    }
}
