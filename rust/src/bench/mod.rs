//! In-tree micro-benchmark harness (criterion is unavailable offline) and
//! the experiment drivers that regenerate every table/figure of the paper.
//!
//! [`harness`] provides warmup + repeated timing with mean/stddev/p50/p99;
//! [`experiments`] produces the figure data (one function per paper
//! artifact), used by both `trainingcxl bench <exp>` and the standalone
//! bench binaries in `rust/benches/`.

pub mod experiments;
pub mod harness;

pub use experiments::{Experiment, Report, RunOpts};
pub use harness::{bench_fn, BenchResult};
