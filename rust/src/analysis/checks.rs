//! The analyzer's invariant checks and typed findings.
//!
//! Five families of checks over an [`EffectGraph`]:
//!
//! 1. **Undo-before-update** — under batch-aware/relaxed checkpointing,
//!    every table mutation must be preceded *in the same batch* by undo
//!    captures covering its row classes.
//! 2. **MLP-log bounds** — the composed chain persists the MLP with the
//!    lag class its checkpoint mode promises, the bootstrap snapshot
//!    seals synchronously, and `max_mlp_log_gap` stays inside the
//!    accuracy budget ([`MAX_SAFE_MLP_GAP`]).
//! 3. **Crash-point coverage** — every recoverable write has *some* log
//!    capture happening-before it (same-batch undo or previous-batch
//!    redo image) in the steady state; no write lands outside every
//!    log's coverage window.
//! 4. **Resource order** — the union of nested resource acquisitions
//!    across every chain in a world is acyclic, so no two lanes/tenants
//!    can deadlock on `pmem_free` and the fabric links.
//! 5. **Serving is read-only** — a serving chain never writes
//!    recoverable state or contributes to a log window.
//!
//! Violations are hard failures (the CLI gate exits non-zero); warnings
//! record configurations that are *legitimately* unrecoverable by design
//! (`CkptMode::None` over durable media) or whose logs cannot survive
//! (volatile table media with checkpointing on).

use std::collections::BTreeSet;

use super::effects::{MlpPersist, Region, Resource};
use super::graph::{EffectGraph, EffectNode};
use crate::config::CkptMode;
use crate::sim::mem::MediaKind;
use crate::sim::topology::Topology;

/// Largest `max_mlp_log_gap` the analyzer accepts for relaxed chains:
/// the paper's Fig 9a shows hundreds of batches of MLP staleness stay
/// within the 0.01% accuracy budget; a window beyond this is outside the
/// evidence and flagged as [`Violation::MlpGapOverrun`].
pub const MAX_SAFE_MLP_GAP: u64 = 1000;

/// A hard crash-consistency or ordering defect in a composed chain.
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum Violation {
    #[error("stage '{stage}' is reachable from compose but declares no effects()")]
    UndeclaredEffects { stage: &'static str },
    #[error(
        "update-before-log: '{stage}' mutates {region:?} before the undo capture that covers it"
    )]
    UpdateBeforeUndoLog { stage: &'static str, region: Region },
    #[error(
        "write outside log coverage: '{stage}' mutates {region:?} with no undo/redo capture \
         happening-before it — a crash at this point has no recovery path"
    )]
    WriteOutsideLogCoverage { stage: &'static str, region: Region },
    #[error("checkpoint mode {ckpt:?} promises MLP persistence but no composed stage provides it")]
    MissingMlpPersist { ckpt: CkptMode },
    #[error("'{stage}' persists the MLP log with unbounded lag")]
    UnboundedMlpLag { stage: &'static str },
    #[error("'{stage}' does not seal the bootstrap MLP snapshot synchronously")]
    UnsealedBootstrapSnapshot { stage: &'static str },
    #[error("max_mlp_log_gap {gap} exceeds the recoverability budget of {bound} batches")]
    MlpGapOverrun { gap: u64, bound: u64 },
    #[error(
        "read-without-producer: '{stage}' consumes {region:?} but no earlier stage in the batch \
         produces it (movement stage dropped from the chain?)"
    )]
    ReadWithoutProducer { stage: &'static str, region: Region },
    #[error("cyclic resource acquisition order: {cycle:?}")]
    CyclicResourceOrder { cycle: Vec<Resource> },
    #[error("serving chain stage '{stage}' writes {region:?} — serving must be read-only")]
    WritingServingStage { stage: &'static str, region: Region },
}

/// A configuration the analyzer accepts but flags for the operator.
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum Warning {
    #[error(
        "'{stage}' writes durable {region:?} with CkptMode::None — a crash here is \
         unrecoverable by design"
    )]
    UnprotectedDurableWrite { stage: &'static str, region: Region },
    #[error("checkpointing is on but the table media is volatile — logs cannot survive a crash")]
    VolatileLogMedia,
}

/// What the checks need to know about the chain's topology: the
/// checkpoint promise, the relaxed window, and whether the table media
/// survives a crash at all.
#[derive(Clone, Copy, Debug)]
pub struct ChainSpec {
    pub ckpt: CkptMode,
    pub max_mlp_log_gap: u64,
    /// The table media keeps its contents across a crash (PMEM/SSD).
    /// Resolves region durability: the undo/MLP logs live in the same
    /// pool, so they are exactly as durable as the table.
    pub durable_table: bool,
}

impl ChainSpec {
    pub fn of(t: &Topology) -> ChainSpec {
        ChainSpec {
            ckpt: t.ckpt,
            max_mlp_log_gap: t.max_mlp_log_gap,
            durable_table: t.table_media != MediaKind::Dram,
        }
    }
}

/// The outcome of analyzing one subject (a chain, a serving chain, or a
/// whole tenant world).
#[derive(Clone, Debug, Default)]
pub struct AnalysisReport {
    pub subject: String,
    pub violations: Vec<Violation>,
    pub warnings: Vec<Warning>,
}

impl AnalysisReport {
    pub fn new(subject: impl Into<String>) -> AnalysisReport {
        AnalysisReport {
            subject: subject.into(),
            violations: Vec::new(),
            warnings: Vec::new(),
        }
    }

    /// No violations (warnings do not fail the gate).
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Fold another report's findings into this one (tenant worlds).
    pub fn absorb(&mut self, other: AnalysisReport) {
        self.violations.extend(other.violations);
        self.warnings.extend(other.warnings);
    }
}

impl std::fmt::Display for AnalysisReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_clean() && self.warnings.is_empty() {
            return write!(f, "{}: ok", self.subject);
        }
        writeln!(
            f,
            "{}: {} violation(s), {} warning(s)",
            self.subject,
            self.violations.len(),
            self.warnings.len()
        )?;
        for v in &self.violations {
            writeln!(f, "  VIOLATION: {v}")?;
        }
        for w in &self.warnings {
            writeln!(f, "  warning: {w}")?;
        }
        Ok(())
    }
}

/// Every stage in the graph must have declared its effects.
pub fn check_declared(g: &EffectGraph, out: &mut AnalysisReport) {
    let mut seen = BTreeSet::new();
    for n in g.batch(0) {
        if !n.fx.declared && seen.insert(n.name) {
            out.violations.push(Violation::UndeclaredEffects { stage: n.name });
        }
    }
}

/// Union of current-batch undo coverage declared by `nodes`.
fn coverage_mask(nodes: &[&EffectNode]) -> u8 {
    let mut mask = 0u8;
    for n in nodes {
        if let Some(u) = n.fx.undo {
            if !u.for_next_batch {
                mask |= u.rows.mask();
            }
        }
    }
    mask
}

/// Check 1 — undo-before-update. Only batch-aware/relaxed modes promise
/// same-batch undo coverage; this check reports the pure *ordering* bug
/// (the covering capture exists in the batch but runs after the write).
/// Entirely absent coverage is check 3's finding.
pub fn check_undo_ordering(spec: &ChainSpec, g: &EffectGraph, out: &mut AnalysisReport) {
    if !matches!(spec.ckpt, CkptMode::BatchAware | CkptMode::Relaxed) {
        return;
    }
    let chain = g.batch(0);
    for (i, n) in chain.iter().enumerate() {
        for &(region, rows) in &n.fx.writes {
            if !matches!(region, Region::EmbTable | Region::HotTier) {
                continue;
            }
            let missing = rows.mask() & !coverage_mask(&chain[..i]);
            if missing == 0 {
                continue;
            }
            // The capture exists later in the same batch: ordering bug.
            if missing & coverage_mask(&chain[i + 1..]) == missing {
                out.violations.push(Violation::UpdateBeforeUndoLog {
                    stage: n.name,
                    region,
                });
            }
        }
    }
}

/// Check 2 — MLP-log bounds per checkpoint mode.
pub fn check_mlp(spec: &ChainSpec, g: &EffectGraph, out: &mut AnalysisReport) {
    let mut persists = Vec::new();
    for n in g.batch(0) {
        if let Some(m) = n.fx.mlp {
            persists.push((n.name, m));
            match m {
                MlpPersist::Unbounded => {
                    out.violations.push(Violation::UnboundedMlpLag { stage: n.name });
                }
                MlpPersist::WindowBounded {
                    seals_bootstrap: false,
                } => {
                    out.violations
                        .push(Violation::UnsealedBootstrapSnapshot { stage: n.name });
                }
                _ => {}
            }
        }
    }
    match spec.ckpt {
        CkptMode::None => {}
        CkptMode::Redo | CkptMode::BatchAware => {
            // Both promise a complete MLP image every batch.
            if !persists
                .iter()
                .any(|(_, m)| matches!(m, MlpPersist::PerBatch))
            {
                out.violations
                    .push(Violation::MissingMlpPersist { ckpt: spec.ckpt });
            }
        }
        CkptMode::Relaxed => {
            if !persists.iter().any(|(_, m)| {
                matches!(m, MlpPersist::PerBatch | MlpPersist::WindowBounded { .. })
            }) {
                out.violations
                    .push(Violation::MissingMlpPersist { ckpt: spec.ckpt });
            }
            if spec.max_mlp_log_gap > MAX_SAFE_MLP_GAP {
                out.violations.push(Violation::MlpGapOverrun {
                    gap: spec.max_mlp_log_gap,
                    bound: MAX_SAFE_MLP_GAP,
                });
            }
        }
    }
}

/// Check 3 — every crash point has a reachable recovery path. Runs on the
/// steady-state (last unrolled) batch: a recoverable write needs either
/// same-batch undo coverage before it or a previous-batch capture taken
/// *for* this batch (redo tails). `CkptMode::None` demotes the finding
/// to a warning — the configuration is unrecoverable by design, exactly
/// like the recovery matrix treats it.
pub fn check_crash_coverage(spec: &ChainSpec, g: &EffectGraph, out: &mut AnalysisReport) {
    let last = g.last_batch();
    if spec.ckpt == CkptMode::None {
        if spec.durable_table {
            let mut seen = BTreeSet::new();
            for n in g.batch(0) {
                for &(region, _) in &n.fx.writes {
                    if region == Region::EmbTable && seen.insert((n.name, region)) {
                        out.warnings.push(Warning::UnprotectedDurableWrite {
                            stage: n.name,
                            region,
                        });
                    }
                }
            }
        }
        return;
    }
    if !spec.durable_table {
        out.warnings.push(Warning::VolatileLogMedia);
    }
    // Coverage carried in from earlier batches: captures taken for the
    // batch after theirs, in the batch right before the steady-state one.
    let mut carried = 0u8;
    for n in &g.nodes {
        if let Some(u) = n.fx.undo {
            if u.for_next_batch && n.batch + 1 == last {
                carried |= u.rows.mask();
            }
        }
    }
    let chain = g.batch(last);
    for (i, n) in chain.iter().enumerate() {
        for &(region, rows) in &n.fx.writes {
            if !matches!(region, Region::EmbTable | Region::HotTier) {
                continue;
            }
            let missing = rows.mask() & !(carried | coverage_mask(&chain[..i]));
            if missing == 0 {
                continue;
            }
            // An ordering bug already reported by check 1 is not
            // re-reported as missing coverage.
            let already = out.violations.iter().any(|v| {
                matches!(v, Violation::UpdateBeforeUndoLog { stage, region: r }
                    if *stage == n.name && *r == region)
            });
            if !already {
                out.violations.push(Violation::WriteOutsideLogCoverage {
                    stage: n.name,
                    region,
                });
            }
        }
    }
}

/// Per-batch dataflow: reduced vectors must be produced before they are
/// consumed. Catches a chain composed without its movement stage.
pub fn check_dataflow(g: &EffectGraph, out: &mut AnalysisReport) {
    let mut produced: BTreeSet<Region> = BTreeSet::new();
    let mut reported = BTreeSet::new();
    for n in g.batch(0) {
        for &(region, _) in &n.fx.reads {
            if region.is_dataflow() && !produced.contains(&region) && reported.insert((n.name, region))
            {
                out.violations.push(Violation::ReadWithoutProducer {
                    stage: n.name,
                    region,
                });
            }
        }
        for &(region, _) in &n.fx.writes {
            produced.insert(region);
        }
    }
}

/// Check 4 — globally consistent resource acquisition order. The union
/// of held-while-acquiring edges across every chain in the world must be
/// acyclic; `graphs` spans all co-resident chains (every tenant's, plus
/// any serving chains) since lanes contend on the same `pmem_free` and
/// links.
pub fn check_resource_order<'a>(
    graphs: impl IntoIterator<Item = &'a EffectGraph>,
    out: &mut AnalysisReport,
) {
    let mut adj = [[false; Resource::COUNT]; Resource::COUNT];
    for g in graphs {
        for node in &g.nodes {
            for section in &node.fx.acquires {
                for w in section.windows(2) {
                    if w[0] != w[1] {
                        adj[w[0].index()][w[1].index()] = true;
                    }
                }
            }
        }
    }
    if let Some(cycle) = find_cycle(&adj) {
        out.violations.push(Violation::CyclicResourceOrder { cycle });
    }
}

fn find_cycle(adj: &[[bool; Resource::COUNT]; Resource::COUNT]) -> Option<Vec<Resource>> {
    fn dfs(
        v: usize,
        adj: &[[bool; Resource::COUNT]; Resource::COUNT],
        color: &mut [u8; Resource::COUNT],
        stack: &mut Vec<usize>,
    ) -> Option<Vec<usize>> {
        color[v] = 1;
        stack.push(v);
        for (u, row) in adj[v].iter().enumerate() {
            if !row {
                continue;
            }
            if color[u] == 1 {
                let pos = stack.iter().position(|&x| x == u).unwrap();
                return Some(stack[pos..].to_vec());
            }
            if color[u] == 0 {
                if let Some(c) = dfs(u, adj, color, stack) {
                    return Some(c);
                }
            }
        }
        stack.pop();
        color[v] = 2;
        None
    }
    let mut color = [0u8; Resource::COUNT];
    for v in 0..Resource::COUNT {
        if color[v] == 0 {
            let mut stack = Vec::new();
            if let Some(c) = dfs(v, adj, &mut color, &mut stack) {
                return Some(c.into_iter().map(Resource::from_index).collect());
            }
        }
    }
    None
}

/// Check 5 — serving chains are write-free: no mutation of recoverable
/// state, no log-window contribution.
pub fn check_serving_read_only(g: &EffectGraph, out: &mut AnalysisReport) {
    let mut seen = BTreeSet::new();
    for n in &g.nodes {
        for &(region, _) in &n.fx.writes {
            if region.is_recoverable_state() && seen.insert((n.name, region)) {
                out.violations.push(Violation::WritingServingStage {
                    stage: n.name,
                    region,
                });
            }
        }
        if n.fx.undo.is_some() && seen.insert((n.name, Region::UndoLog)) {
            out.violations.push(Violation::WritingServingStage {
                stage: n.name,
                region: Region::UndoLog,
            });
        }
        if n.fx.mlp.is_some() && seen.insert((n.name, Region::MlpLog)) {
            out.violations.push(Violation::WritingServingStage {
                stage: n.name,
                region: Region::MlpLog,
            });
        }
    }
}
