//! End-to-end tests over the PJRT runtime: the three layers must compose
//! (Pallas kernels -> JAX DLRM -> rust coordinator) with real numerics.
//! All tests skip gracefully when `make artifacts` has not run.

use trainingcxl::config::{ModelConfig, SystemConfig};
use trainingcxl::repo_root;
use trainingcxl::runtime::{HostTensor, ModelRuntime};
use trainingcxl::sim::topology::Topology;
use trainingcxl::train::Trainer;
use trainingcxl::workload::Generator;

fn topo(sys: SystemConfig) -> Topology {
    Topology::from_system(sys)
}

fn ready() -> Option<(std::path::PathBuf, ModelConfig)> {
    let root = repo_root();
    if !root.join("artifacts/rm_mini/manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some((root.clone(), ModelConfig::load(&root, "rm_mini").unwrap()))
}

#[test]
fn training_reduces_loss() {
    let Some((root, cfg)) = ready() else { return };
    let mut t = Trainer::with_topology(&root, &cfg, 3, &topo(SystemConfig::Dram)).unwrap();
    let mut first10 = 0.0;
    let mut last10 = 0.0;
    for s in 0..60 {
        let out = t.step().unwrap();
        if s < 10 {
            first10 += out.loss / 10.0;
        }
        if s >= 50 {
            last10 += out.loss / 10.0;
        }
    }
    assert!(
        last10 < first10 - 0.005,
        "no learning: {first10:.4} -> {last10:.4}"
    );
}

#[test]
fn split_path_matches_monolithic_train_step() {
    // The device-split hot path (embedding_bag -> mlp_step ->
    // embedding_update) must produce the SAME loss and parameters as the
    // monolithic train_step artifact: the decomposition is an
    // implementation detail, not a semantic change.
    let Some((root, cfg)) = ready() else { return };
    let rt = ModelRuntime::load(&root, "rm_mini", &["train_step"]).unwrap();

    // identical init on both paths
    let mut split = Trainer::with_topology(&root, &cfg, 5, &topo(SystemConfig::Dram)).unwrap();
    let mlp0: Vec<Vec<f32>> = split.mlp_params().to_vec();

    // monolithic inputs with the same init: read the initial table back
    // (off the hot path — download_table is verification tooling)
    let table0 = split.download_table().unwrap();

    let mut gen = Generator::new(&cfg, 5 ^ 0xBA7C4);
    let batch = gen.next_batch();

    // split path: one step
    let split_out = split.step_with_batch(&batch).unwrap();

    // monolithic path
    let spec = rt.export_spec("train_step").clone();
    let mut bufs = Vec::new();
    let nmlp = mlp0.len();
    for (i, p) in mlp0.iter().enumerate() {
        bufs.push(
            rt.to_device(&HostTensor::F32(p.clone(), spec.inputs[i].shape.clone()))
                .unwrap(),
        );
    }
    bufs.push(
        rt.to_device(&HostTensor::F32(table0, spec.inputs[nmlp].shape.clone()))
            .unwrap(),
    );
    bufs.push(
        rt.to_device(&HostTensor::F32(
            batch.dense.clone(),
            spec.inputs[nmlp + 1].shape.clone(),
        ))
        .unwrap(),
    );
    bufs.push(
        rt.to_device(&HostTensor::I32(
            batch.indices.clone(),
            spec.inputs[nmlp + 2].shape.clone(),
        ))
        .unwrap(),
    );
    bufs.push(
        rt.to_device(&HostTensor::F32(
            batch.labels.clone(),
            spec.inputs[nmlp + 3].shape.clone(),
        ))
        .unwrap(),
    );
    let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let outs = rt.run_to_host("train_step", &args).unwrap();
    let mono_loss = outs.last().unwrap()[0];

    assert!(
        (mono_loss - split_out.loss).abs() < 1e-5,
        "split {} vs monolithic {}",
        split_out.loss,
        mono_loss
    );
    // and the updated MLP params agree
    for (i, (a, b)) in outs[..nmlp].iter().zip(split.mlp_params()).enumerate() {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5, "param {i} diverged: {x} vs {y}");
        }
    }
}

#[test]
fn forward_shapes_and_determinism() {
    let Some((root, cfg)) = ready() else { return };
    let t1 = Trainer::with_topology(&root, &cfg, 9, &topo(SystemConfig::Dram)).unwrap();
    let t2 = Trainer::with_topology(&root, &cfg, 9, &topo(SystemConfig::Dram)).unwrap();
    let (l1, a1) = t1.evaluate(3, 123).unwrap();
    let (l2, a2) = t2.evaluate(3, 123).unwrap();
    assert_eq!(l1, l2, "same seed must give identical eval");
    assert_eq!(a1, a2);
    let (l3, _) = t1.evaluate(3, 456).unwrap();
    assert_ne!(l1, l3, "different eval seed must differ");
}

#[test]
fn checkpointed_training_keeps_host_mirror_in_sync() {
    let Some((root, cfg)) = ready() else { return };
    // CXL-B: batch-aware checkpointing, synchronous MLP log
    let mut t = Trainer::with_topology(&root, &cfg, 13, &topo(SystemConfig::CxlB)).unwrap();
    for _ in 0..5 {
        t.step().unwrap();
    }
    // the undo log of the NEXT batch must capture current values: verify
    // by crashing now and recovering — rollback must equal the mirror
    // state at the last completed batch boundary.
    let (mut store, log, _) = t.crash();
    let pre = store.clone();
    let rec = trainingcxl::checkpoint::recover(&mut store, &log).unwrap();
    assert_eq!(rec.resume_batch, 4);
    // rows not in the last batch's touched set are identical
    let touched: std::collections::HashSet<(usize, usize)> = log
        .persistent_emb()
        .unwrap()
        .entries
        .iter()
        .map(|e| (e.table, e.row))
        .collect();
    for t_i in 0..cfg.num_tables {
        for r_i in 0..cfg.rows_per_table {
            if !touched.contains(&(t_i, r_i)) {
                assert_eq!(store.row(t_i, r_i), pre.row(t_i, r_i));
            }
        }
    }
}

#[test]
fn incremental_mirror_matches_full_download() {
    // THE parity pin for the tentpole refactor: N steps of row-wise
    // mirror maintenance must produce a store bit-identical to what the
    // old full-table device->host rebuild produced each step.
    let Some((root, cfg)) = ready() else { return };
    let mut t = Trainer::with_topology(&root, &cfg, 21, &topo(SystemConfig::CxlB)).unwrap();
    for _ in 0..8 {
        t.step().unwrap();
    }
    let full = t.download_table().unwrap();
    assert_eq!(
        t.store.as_ref().unwrap().flat(),
        &full[..],
        "incremental mirror diverged from device table"
    );
}

#[test]
fn relaxed_topology_streams_mlp_log_across_batches() {
    // Relaxed CkptMode: after the bootstrap generation (which seals
    // synchronously so recovery is never impossible), MLP snapshots are
    // advanced in slices across the window (Fig 9b), not begun+sealed in
    // one step.
    let Some((root, cfg)) = ready() else { return };
    let relaxed = trainingcxl::sim::topology::Topology::builder("relaxed-8")
        .near_data()
        .hw_movement()
        .checkpoint(trainingcxl::config::CkptMode::Relaxed)
        .max_mlp_log_gap(8)
        .build()
        .unwrap();
    let mut t = Trainer::with_topology(&root, &cfg, 2, &relaxed).unwrap();
    for _ in 0..11 {
        t.step().unwrap();
    }
    let log = t.log.as_ref().unwrap();
    // bootstrap generation: batch 0, sealed synchronously, now the
    // persistent fallback while the second generation streams
    let prev = log.persistent_mlp().unwrap();
    assert_eq!(prev.batch, 0);
    // second window's snapshot: begun at batch 8, streamed at 8/9/10
    let cur = log.mlp_cur.as_ref().unwrap();
    assert_eq!(cur.batch, 8, "snapshot begun at the window boundary");
    assert!(!cur.persistent, "mid-window snapshot must still be open");
    let budget = cur.bytes_total.div_ceil(8).max(1);
    assert_eq!(
        cur.bytes_done,
        3 * budget,
        "streaming: {} of {} bytes after 3 of 8 batches",
        cur.bytes_done,
        cur.bytes_total
    );
    assert!(cur.bytes_done < cur.bytes_total);
}

#[test]
fn rm1_artifacts_load_and_execute() {
    // one of the real paper models end-to-end at artifact scale
    let root = repo_root();
    if !root.join("artifacts/rm1/manifest.json").exists() {
        eprintln!("skipping: rm1 artifacts not built");
        return;
    }
    let cfg = ModelConfig::load(&root, "rm1").unwrap();
    let mut t = Trainer::with_topology(&root, &cfg, 1, &topo(SystemConfig::Dram)).unwrap();
    let out = t.step().unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
}
