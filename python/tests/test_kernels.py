"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes; fixed cases pin the paper's RM shapes.
This is the CORE correctness signal for the compute hot-spots the paper
puts into CXL-MEM hardware.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not available in this environment"
)
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import embedding, mlp, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rnd(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ------------------------------------------------------------- embedding_bag


@hypothesis.given(
    t=st.integers(1, 6),
    r=st.integers(1, 64),
    d=st.integers(1, 48),
    b=st.integers(1, 32),
    ell=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_bag_matches_ref(t, r, d, b, ell, seed):
    rng = np.random.default_rng(seed)
    table = rnd(rng, (t, r, d))
    idx = jnp.asarray(rng.integers(0, r, size=(t, b, ell)), jnp.int32)
    got = embedding.embedding_bag(table, idx)
    want = ref.embedding_bag(table, idx)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_bag_duplicate_indices_accumulate():
    table = jnp.arange(2 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 3)
    idx = jnp.zeros((2, 1, 5), jnp.int32)  # same row 5 times
    got = embedding.embedding_bag(table, idx)
    np.testing.assert_allclose(got[0, 0], 5 * table[0, 0])
    np.testing.assert_allclose(got[0, 1], 5 * table[1, 0])


def test_bag_single_lookup_is_gather():
    rng = np.random.default_rng(7)
    table = rnd(rng, (3, 16, 4))
    idx = jnp.asarray(rng.integers(0, 16, size=(3, 8, 1)), jnp.int32)
    got = embedding.embedding_bag(table, idx)
    for t in range(3):
        for b in range(8):
            np.testing.assert_allclose(got[b, t], table[t, idx[t, b, 0]])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bag_dtypes(dtype):
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(size=(2, 8, 4)), dtype)
    idx = jnp.asarray(rng.integers(0, 8, size=(2, 4, 3)), jnp.int32)
    got = embedding.embedding_bag(table, idx)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref.embedding_bag(table, idx), np.float32),
        rtol=2e-2,
    )


# ------------------------------------------------------------- gather_rows


def test_gather_rows_is_exact_row_readout():
    """gather_rows[t, b, l] must be bit-identical to table[t, idx[t, b, l]]
    (the rust trainer relies on this for bit-exact mirror maintenance)."""
    rng = np.random.default_rng(11)
    table = rnd(rng, (3, 16, 4))
    idx = jnp.asarray(rng.integers(0, 16, size=(3, 8, 5)), jnp.int32)
    got = np.asarray(embedding.gather_rows(table, idx))
    tab = np.asarray(table)
    i = np.asarray(idx)
    for t in range(3):
        for b in range(8):
            for ell in range(5):
                assert (got[t, b, ell] == tab[t, i[t, b, ell]]).all()


def test_gather_rows_sums_to_bag():
    rng = np.random.default_rng(12)
    table = rnd(rng, (2, 32, 6))
    idx = jnp.asarray(rng.integers(0, 32, size=(2, 4, 3)), jnp.int32)
    rows = embedding.gather_rows(table, idx)  # (T, B, L, D)
    np.testing.assert_allclose(
        rows.sum(axis=2).transpose(1, 0, 2),
        ref.embedding_bag(table, idx),
        rtol=1e-6,
    )


# ---------------------------------------------------------- embedding_update


@hypothesis.given(
    t=st.integers(1, 5),
    r=st.integers(2, 48),
    d=st.integers(1, 32),
    b=st.integers(1, 16),
    ell=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_update_matches_ref(t, r, d, b, ell, seed):
    rng = np.random.default_rng(seed)
    table = rnd(rng, (t, r, d))
    idx = jnp.asarray(rng.integers(0, r, size=(t, b, ell)), jnp.int32)
    grad = rnd(rng, (b, t, d))
    got = embedding.embedding_update(table, idx, grad, 0.1)
    want = ref.embedding_update(table, idx, grad, 0.1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_update_duplicates_accumulate():
    table = jnp.zeros((1, 4, 2), jnp.float32)
    idx = jnp.full((1, 2, 3), 1, jnp.int32)  # row 1 hit 6 times
    grad = jnp.ones((2, 1, 2), jnp.float32)
    got = embedding.embedding_update(table, idx, grad, 1.0)
    np.testing.assert_allclose(got[0, 1], [-6.0, -6.0])
    np.testing.assert_allclose(got[0, 0], [0.0, 0.0])  # untouched rows


def test_update_zero_lr_is_identity():
    rng = np.random.default_rng(0)
    table = rnd(rng, (2, 8, 4))
    idx = jnp.asarray(rng.integers(0, 8, size=(2, 4, 3)), jnp.int32)
    grad = rnd(rng, (4, 2, 4))
    got = embedding.embedding_update(table, idx, grad, 0.0)
    np.testing.assert_allclose(got, table)


def test_lookup_update_commute():
    """The relaxation invariant (paper Fig. 8): for a sum-bag,
    lookup(T) + apply-delta == lookup(update(T)). This is the property the
    relaxed embedding lookup relies on; the rust scheduler has the same
    test against its replayed numerics."""
    rng = np.random.default_rng(11)
    table = rnd(rng, (2, 16, 4))
    idx_n = jnp.asarray(rng.integers(0, 16, size=(2, 8, 3)), jnp.int32)  # batch N
    idx_n1 = jnp.asarray(rng.integers(0, 16, size=(2, 8, 3)), jnp.int32)  # batch N+1
    grad_n = rnd(rng, (8, 2, 4))
    lr = 0.05

    # dependent schedule: update with batch-N grads, then lookup batch N+1
    updated = embedding.embedding_update(table, idx_n, grad_n, lr)
    dependent = embedding.embedding_bag(updated, idx_n1)

    # relaxed schedule: lookup batch N+1 against the OLD table, then add the
    # delta contributed by batch N's update to the rows this bag touched.
    early = embedding.embedding_bag(table, idx_n1)
    delta_tbl = updated - table  # sparse in rows; dense here for the oracle
    correction = ref.embedding_bag(delta_tbl, idx_n1)
    np.testing.assert_allclose(early + correction, dependent, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------------- matmul


@hypothesis.given(
    m=st.integers(1, 200),
    k=st.integers(1, 160),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, (m, k)), rnd(rng, (k, n)), rnd(rng, (n,))
    got = mlp.matmul_bias(x, w, b)
    want = ref.matmul_bias(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(13, 8192, 16), (256, 13, 128), (1, 1, 1)])
def test_matmul_paper_shapes(m, k, n):
    rng = np.random.default_rng(5)
    x, w, b = rnd(rng, (m, k)), rnd(rng, (k, n)), rnd(rng, (n,))
    np.testing.assert_allclose(
        mlp.matmul_bias(x, w, b), ref.matmul_bias(x, w, b), rtol=1e-3, atol=1e-3
    )


def test_matmul_vjp_matches_ref():
    rng = np.random.default_rng(9)
    x, w, b = rnd(rng, (32, 48)), rnd(rng, (48, 24)), rnd(rng, (24,))

    def f_kernel(x, w, b):
        return (mlp.matmul_bias(x, w, b) ** 2).sum()

    def f_ref(x, w, b):
        return (ref.matmul_bias(x, w, b) ** 2).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(a, c, rtol=1e-3, atol=1e-3)


def test_matmul_custom_tiles():
    rng = np.random.default_rng(2)
    x, w = rnd(rng, (100, 70)), rnd(rng, (70, 50))
    got = mlp.matmul(x, w, bm=32, bn=16, bk=8)
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)
