//! Topology-equivalence tests: every legacy [`SystemConfig`] must produce
//! *identical* `RunResult` numbers whether it is routed through the
//! prebuilt topology, assembled step-by-step with the builder API, or
//! loaded from its `configs/topologies/*.toml` — plus a pooled-expander
//! (`k > 1`, extra hops) regression for the CXL 3.0 scaling path.

use trainingcxl::bench::experiments;
use trainingcxl::config::{CkptMode, SystemConfig};
use trainingcxl::repo_root;
use trainingcxl::sched::RunResult;
use trainingcxl::sim::mem::MediaKind;
use trainingcxl::sim::topology::{Topology, TopologyBuilder};

const MODELS: [&str; 4] = ["rm1", "rm2", "rm3", "rm4"];
const BATCHES: u64 = 6;

/// Builder composition mirroring each paper config, written against the
/// public builder API (NOT `from_system`) so the test fails if the
/// builder and the prebuilt path drift apart.
fn built_by_hand(sys: SystemConfig) -> Topology {
    let b: TopologyBuilder = Topology::builder(sys.name());
    let b = match sys {
        SystemConfig::Ssd => b.table_media(MediaKind::Ssd).vector_cache(),
        SystemConfig::Pmem => b,
        SystemConfig::Pcie => b.near_data(),
        SystemConfig::CxlD => b.near_data().hw_movement().checkpoint(CkptMode::Redo),
        SystemConfig::CxlB => b.near_data().hw_movement().checkpoint(CkptMode::BatchAware),
        SystemConfig::Cxl => b
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200),
        SystemConfig::Dram => b.table_media(MediaKind::Dram).checkpoint(CkptMode::None),
    };
    b.build().unwrap()
}

fn assert_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.batch_times, b.batch_times, "{what}: batch times differ");
    assert_eq!(a.total_time, b.total_time, "{what}: total time differs");
    assert_eq!(a.raw_hits, b.raw_hits, "{what}: raw hits differ");
    assert_eq!(a.max_mlp_gap, b.max_mlp_gap, "{what}: mlp gap differs");
    assert_eq!(a.traffic, b.traffic, "{what}: traffic differs");
    assert_eq!(a.gpu_busy, b.gpu_busy, "{what}: gpu busy differs");
    assert_eq!(a.host_busy, b.host_busy, "{what}: host busy differs");
    assert_eq!(a.logic_busy, b.logic_busy, "{what}: logic busy differs");
    assert_eq!(
        a.breakdowns.len(),
        b.breakdowns.len(),
        "{what}: breakdown count differs"
    );
    for (i, (x, y)) in a.breakdowns.iter().zip(&b.breakdowns).enumerate() {
        assert_eq!(x, y, "{what}: breakdown {i} differs");
    }
}

#[test]
fn legacy_configs_equal_builder_compositions() {
    let root = repo_root();
    for model in MODELS {
        for sys in SystemConfig::ALL {
            let legacy = experiments::simulate(&root, model, sys, BATCHES).unwrap();
            let built =
                experiments::simulate_topology(&root, model, built_by_hand(sys), BATCHES).unwrap();
            assert_identical(&legacy, &built, &format!("{model}/{}", sys.name()));
        }
    }
}

#[test]
fn toml_topologies_equal_legacy_configs() {
    let root = repo_root();
    for sys in SystemConfig::ALL {
        let name = sys.name().to_ascii_lowercase();
        let topo = Topology::load_strict(&root, &name).unwrap();
        let legacy = experiments::simulate(&root, "rm1", sys, BATCHES).unwrap();
        let loaded = experiments::simulate_topology(&root, "rm1", topo, BATCHES).unwrap();
        assert_identical(&legacy, &loaded, &format!("toml/{name}"));
    }
}

#[test]
fn dram_ideal_routes_through_topology_too() {
    let root = repo_root();
    let legacy = experiments::simulate(&root, "rm1", SystemConfig::Dram, BATCHES).unwrap();
    let built =
        experiments::simulate_topology(&root, "rm1", built_by_hand(SystemConfig::Dram), BATCHES)
            .unwrap();
    assert_identical(&legacy, &built, "rm1/DRAM");
    assert_eq!(legacy.config, SystemConfig::Dram);
}

#[test]
fn pooled_expanders_regression() {
    // k pooled expanders behind extra switch hops: embedding-bound rm2
    // must get strictly faster with the pool, deterministically.
    let root = repo_root();
    let pool = |k: usize, hops: usize| {
        let topo = Topology::builder(&format!("pool{k}"))
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200)
            .expander_pool(k, hops)
            .build()
            .unwrap();
        experiments::simulate_topology(&root, "rm2", topo, BATCHES).unwrap()
    };
    let k1 = pool(1, 0);
    let k4 = pool(4, 2);
    assert!(
        k4.mean_batch_ns() < k1.mean_batch_ns(),
        "pooling must speed up rm2: k1 {} vs k4 {}",
        k1.mean_batch_ns(),
        k4.mean_batch_ns()
    );
    // k=1 with no extra hops is exactly the flagship CXL topology
    let flagship = experiments::simulate(&root, "rm2", SystemConfig::Cxl, BATCHES).unwrap();
    assert_identical(&k1, &flagship, "rm2/pool1-vs-CXL");
    // determinism of the pooled path
    let k4b = pool(4, 2);
    assert_identical(&k4, &k4b, "rm2/pool4-determinism");
    // the shipped pooled TOML is the same composition
    let toml = Topology::load_strict(&root, "pooled-cxl-4x").unwrap();
    let toml_run = experiments::simulate_topology(&root, "rm2", toml, BATCHES).unwrap();
    assert_identical(&k4, &toml_run, "rm2/pool4-vs-toml");
}

#[test]
fn one_gpu_shard_is_bit_identical_to_the_cxl_topology() {
    // The sharding equivalence pin: an explicit gpu_shards(1) must route
    // through the exact single-GPU composition — identical RunResults to
    // the shipped cxl.toml path and the prebuilt flagship, for every
    // paper model.
    let root = repo_root();
    for model in MODELS {
        let sharded1 = Topology::builder("CXL")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200)
            .gpu_shards(1)
            .build()
            .unwrap();
        let a = experiments::simulate_topology(&root, model, sharded1, BATCHES).unwrap();
        let toml = Topology::load_strict(&root, "cxl").unwrap();
        let b = experiments::simulate_topology(&root, model, toml, BATCHES).unwrap();
        assert_identical(&a, &b, &format!("{model}/shards1-vs-cxl-toml"));
        let legacy = experiments::simulate(&root, model, SystemConfig::Cxl, BATCHES).unwrap();
        assert_identical(&a, &legacy, &format!("{model}/shards1-vs-prebuilt"));
    }
}

#[test]
fn sharded_topologies_run_end_to_end_and_deterministically() {
    let root = repo_root();
    for name in ["sharded-cxl-2x", "sharded-cxl-4x"] {
        let run = || {
            let topo = Topology::load_strict(&root, name).unwrap();
            experiments::simulate_topology(&root, "rm2", topo, BATCHES).unwrap()
        };
        let a = run();
        assert!(a.total_time > 0, "{name}: no simulated time");
        assert!(a.batch_times.iter().all(|&t| t > 0), "{name}");
        assert_eq!(a.raw_hits, 0, "{name}: relaxed lookup must remove RAW");
        assert!(a.mean_batch_ns().is_finite(), "{name}");
        assert_identical(&a, &run(), &format!("{name}/determinism"));
    }
    // lanes + pool must beat the single-GPU flagship on the
    // embedding-bound model (that is the point of the scenario)
    let flagship = experiments::simulate(&root, "rm2", SystemConfig::Cxl, BATCHES).unwrap();
    let topo = Topology::load_strict(&root, "sharded-cxl-4x").unwrap();
    let x4 = experiments::simulate_topology(&root, "rm2", topo, BATCHES).unwrap();
    assert!(
        x4.mean_batch_ns() < flagship.mean_batch_ns(),
        "sharded-cxl-4x {} vs CXL {}",
        x4.mean_batch_ns(),
        flagship.mean_batch_ns()
    );
}

#[test]
fn zero_hot_frac_tier_is_bit_identical_to_the_cxl_topology() {
    // The tiered-media equivalence pin: hot_frac = 0 (and an absent
    // [tiers] table — covered by toml_topologies_equal_legacy_configs)
    // must route through the untouched single-media chain, producing
    // bit-identical RunResults to the shipped cxl.toml path.
    let root = repo_root();
    for model in MODELS {
        let tiered0 = Topology::builder("CXL")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200)
            .tiered_media(MediaKind::Dram, 0.0)
            .build()
            .unwrap();
        let a = experiments::simulate_topology(&root, model, tiered0, BATCHES).unwrap();
        let toml = Topology::load_strict(&root, "cxl").unwrap();
        let b = experiments::simulate_topology(&root, model, toml, BATCHES).unwrap();
        assert_identical(&a, &b, &format!("{model}/tiered0-vs-cxl-toml"));
        let legacy = experiments::simulate(&root, model, SystemConfig::Cxl, BATCHES).unwrap();
        assert_identical(&a, &legacy, &format!("{model}/tiered0-vs-prebuilt"));
    }
}

#[test]
fn tiered_topologies_run_and_beat_the_flagship() {
    let root = repo_root();
    let batches = 8; // enough to cross the shipped migrate_every = 4
    let run = |name: &str| {
        let topo = Topology::load_strict(&root, name).unwrap();
        experiments::simulate_topology(&root, "rm2", topo, batches).unwrap()
    };
    let flagship = experiments::simulate(&root, "rm2", SystemConfig::Cxl, batches).unwrap();
    let mut means = Vec::new();
    for name in ["tiered-cxl-10", "tiered-cxl-30"] {
        let r = run(name);
        assert!(r.total_time > 0, "{name}: no simulated time");
        assert!(r.batch_times.iter().all(|&t| t > 0), "{name}");
        assert_eq!(r.raw_hits, 0, "{name}: relaxed lookup must still remove RAW");
        assert!(r.max_mlp_gap <= 200, "{name}");
        assert!(r.mean_batch_ns().is_finite(), "{name}");
        assert_identical(&r, &run(name), &format!("{name}/determinism"));
        // serving the Zipf head from DRAM must beat the all-PMEM pool on
        // the embedding-bound model (that is the point of the scenario)
        assert!(
            r.mean_batch_ns() < flagship.mean_batch_ns(),
            "{name} {} vs CXL {}",
            r.mean_batch_ns(),
            flagship.mean_batch_ns()
        );
        means.push(r.mean_batch_ns());
    }
    // a bigger hot head moves more of the skew off the pool
    let (t10, t30) = (means[0], means[1]);
    assert!(t30 < t10, "hot 30% {t30} vs hot 10% {t10}");
}

#[test]
fn tiered_composes_with_gpu_shards() {
    let root = repo_root();
    let build = |shards: usize| {
        Topology::builder("tiered-sharded")
            .near_data()
            .hw_movement()
            .checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(200)
            .tiered_media(MediaKind::Dram, 0.3)
            .expander_pool(shards, 1)
            .gpu_shards(shards)
            .build()
            .unwrap()
    };
    let r2 = experiments::simulate_topology(&root, "rm2", build(2), BATCHES).unwrap();
    assert!(r2.total_time > 0 && r2.batch_times.iter().all(|&t| t > 0));
    assert_eq!(r2.raw_hits, 0, "relaxed tiered lanes must stay RAW-free");
    assert!(r2.max_mlp_gap <= 200);
    let r2b = experiments::simulate_topology(&root, "rm2", build(2), BATCHES).unwrap();
    assert_identical(&r2, &r2b, "rm2/tiered-sharded-determinism");
}

#[test]
fn single_tenant_pool_arbiter_is_bit_identical_to_the_cxl_chain() {
    // The tenancy equivalence pin: a PoolArbiter serving ONE tenant over
    // a depth-1 fabric must be the existing cxl.toml stage chain, bit for
    // bit — no stall is ever charged, no hop is ever added, and the env
    // construction mirrors simulate_topology exactly (seed 42 is the
    // solo path's generator seed).
    use trainingcxl::tenancy::{MultiTenantSim, QosPolicy, TenantSet, TenantSpec};
    let root = repo_root();
    for model in MODELS {
        let set = TenantSet {
            name: "solo".into(),
            fabric_levels: 1,
            policy: QosPolicy::FairShare,
            tenants: vec![TenantSpec {
                name: "solo".into(),
                model: model.to_string(),
                topology: Topology::load_strict(&root, "cxl").unwrap(),
                seed: 42,
                weight: 1,
                serve: None,
            }],
        };
        let run = MultiTenantSim::new(&root, &set).unwrap().run(BATCHES);
        assert_eq!(run.tenants.len(), 1);
        assert_eq!(run.tenants[0].total_stall_ns(), 0, "{model}: solo tenant stalled");
        assert!(run.links.is_empty(), "{model}: depth-1 fabric grew links");
        let toml = Topology::load_strict(&root, "cxl").unwrap();
        let solo = experiments::simulate_topology(&root, model, toml, BATCHES).unwrap();
        assert_identical(
            &run.tenants[0].result,
            &solo,
            &format!("{model}/arbiter1-vs-cxl-toml"),
        );
        let legacy = experiments::simulate(&root, model, SystemConfig::Cxl, BATCHES).unwrap();
        assert_identical(
            &run.tenants[0].result,
            &legacy,
            &format!("{model}/arbiter1-vs-prebuilt"),
        );
    }
}

#[test]
fn stage_compositions_expose_their_shape() {
    use trainingcxl::config::{DeviceParams, ModelConfig};
    use trainingcxl::devices::CxlGpu;
    use trainingcxl::sched::PipelineSim;
    use trainingcxl::workload::Generator;

    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    let params = DeviceParams::builtin_default();
    let gpu = CxlGpu::from_params(&cfg, &params, std::path::Path::new("/nonexistent"));
    let stats = Generator::average_stats(&cfg, 42, 4, 0.0);
    let sim = PipelineSim::from_topology(
        &cfg,
        Topology::from_system(SystemConfig::Cxl),
        &params,
        gpu,
        stats,
    )
    .unwrap();
    let names = sim.stage_names();
    assert!(names.contains(&"relaxed-early-lookup"));
    assert!(names.contains(&"relaxed-mlp-log"));
    assert!(names.contains(&"dcoh-flush"));
    assert!(!names.contains(&"sw-uplink-transfer"));
}
