//! Model-zoo configs (paper Table 3), parsed from `configs/models/*.toml`
//! — the same files `python/compile/modelcfg.py` reads, so artifact shapes
//! and simulator workloads can never drift apart.

use crate::util::tomlmini::Doc;
use std::path::Path;

/// One DLRM variant. Field meanings match Table 3 of the paper.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub feature_dim: usize,
    pub num_dense: usize,
    pub num_tables: usize,
    /// Physical rows per table in the AOT artifact (real numerics).
    pub rows_per_table: usize,
    pub lookups_per_table: usize,
    pub bottom_mlp: Vec<usize>,
    pub top_mlp: Vec<usize>,
    pub batch_size: usize,
    pub lr: f64,
    pub sim: SimWorkload,
}

/// Simulator-side workload parameters (`[sim]` table).
#[derive(Clone, Debug, PartialEq)]
pub struct SimWorkload {
    /// Logical rows per table the timing model assumes (paper-scale).
    pub logical_rows_per_table: usize,
    /// Zipf skew of table accesses (Criteo-Kaggle-like).
    pub zipf_alpha: f64,
    /// Fraction of embedding rows re-touched by the next batch
    /// (Kwon & Rhu 2022 report ~80%) — drives the RAW exposure.
    pub consecutive_batch_overlap: f64,
}

impl ModelConfig {
    pub fn load(root: &Path, name: &str) -> anyhow::Result<ModelConfig> {
        let path = root.join("configs/models").join(format!("{name}.toml"));
        let doc = Doc::load(&path)?;
        Ok(ModelConfig {
            name: doc.req_str("name")?.to_string(),
            feature_dim: doc.req_usize("feature_dim")?,
            num_dense: doc.req_usize("num_dense")?,
            num_tables: doc.req_usize("num_tables")?,
            rows_per_table: doc.req_usize("rows_per_table")?,
            lookups_per_table: doc.req_usize("lookups_per_table")?,
            bottom_mlp: doc.req_usize_arr("bottom_mlp")?,
            top_mlp: doc.req_usize_arr("top_mlp")?,
            batch_size: doc.req_usize("batch_size")?,
            lr: doc.req_f64("lr")?,
            sim: SimWorkload {
                logical_rows_per_table: doc.req_usize("sim.logical_rows_per_table")?,
                zipf_alpha: doc.f64_or("sim.zipf_alpha", 1.05),
                consecutive_batch_overlap: doc.f64_or("sim.consecutive_batch_overlap", 0.8),
            },
        })
    }

    pub fn available(root: &Path) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(root.join("configs/models"))
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        let p = e.path();
                        (p.extension()? == "toml")
                            .then(|| p.file_stem().unwrap().to_string_lossy().into_owned())
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }

    /// Width of the top-MLP input: concat(bottom-out, T reduced vectors).
    pub fn interaction_dim(&self) -> usize {
        self.bottom_mlp.last().unwrap() + self.num_tables * self.feature_dim
    }

    /// (fan_in, fan_out) pairs of the bottom MLP.
    pub fn bottom_layers(&self) -> Vec<(usize, usize)> {
        let dims: Vec<usize> = std::iter::once(self.num_dense)
            .chain(self.bottom_mlp.iter().copied())
            .collect();
        dims.windows(2).map(|w| (w[0], w[1])).collect()
    }

    pub fn top_layers(&self) -> Vec<(usize, usize)> {
        let dims: Vec<usize> = std::iter::once(self.interaction_dim())
            .chain(self.top_mlp.iter().copied())
            .collect();
        dims.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// MLP parameter bytes (f32) — the MLP checkpoint log size.
    pub fn mlp_param_bytes(&self) -> u64 {
        let mut n = 0usize;
        for (i, o) in self.bottom_layers().into_iter().chain(self.top_layers()) {
            n += i * o + o;
        }
        (n * 4) as u64
    }

    /// Embedding row bytes (f32).
    pub fn row_bytes(&self) -> u64 {
        (self.feature_dim * 4) as u64
    }

    /// Row accesses per batch: every (table, sample, lookup).
    pub fn lookups_per_batch(&self) -> u64 {
        (self.num_tables * self.batch_size * self.lookups_per_table) as u64
    }

    /// Logical embedding-table bytes the storage tier must provision.
    pub fn logical_table_bytes(&self) -> u64 {
        self.num_tables as u64 * self.sim.logical_rows_per_table as u64 * self.row_bytes()
    }

    /// Total trainable parameters (artifact-scale).
    pub fn param_count(&self) -> usize {
        let mut n = self.num_tables * self.rows_per_table * self.feature_dim;
        for (i, o) in self.bottom_layers().into_iter().chain(self.top_layers()) {
            n += i * o + o;
        }
        n
    }

    /// MLP FLOPs per sample for forward (2*i*o per layer); bwd ~ 2x fwd.
    pub fn mlp_fwd_flops_per_sample(&self) -> u64 {
        self.bottom_layers()
            .into_iter()
            .chain(self.top_layers())
            .map(|(i, o)| 2 * i as u64 * o as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    #[test]
    fn loads_all_paper_models() {
        let root = repo_root();
        for name in ["rm1", "rm2", "rm3", "rm4"] {
            let m = ModelConfig::load(&root, name).unwrap();
            assert_eq!(m.name, name);
            assert_eq!(m.num_dense, 13);
        }
    }

    #[test]
    fn table3_shapes() {
        let root = repo_root();
        let rm1 = ModelConfig::load(&root, "rm1").unwrap();
        assert_eq!((rm1.num_tables, rm1.lookups_per_table), (20, 80));
        assert_eq!(rm1.bottom_mlp, vec![8192, 2048, 32]);
        assert_eq!(rm1.top_mlp, vec![256, 64, 1]);
        let rm2 = ModelConfig::load(&root, "rm2").unwrap();
        assert_eq!(rm2.num_tables, 4 * rm1.num_tables); // "RM2 has 4x many tables"
        let rm4 = ModelConfig::load(&root, "rm4").unwrap();
        assert_eq!((rm4.feature_dim, rm4.lookups_per_table), (16, 1));
    }

    #[test]
    fn derived_quantities() {
        let root = repo_root();
        let m = ModelConfig::load(&root, "rm_mini").unwrap();
        assert_eq!(m.interaction_dim(), 8 + 4 * 8);
        assert_eq!(m.bottom_layers(), vec![(13, 32), (32, 8)]);
        assert_eq!(m.top_layers(), vec![(40, 16), (16, 1)]);
        assert_eq!(m.row_bytes(), 32);
        assert_eq!(m.lookups_per_batch(), (4 * 32 * 4) as u64);
        let nb = 13 * 32 + 32 + 32 * 8 + 8;
        let nt = 40 * 16 + 16 + 16 + 1; // (40x16 w + 16 b) + (16x1 w + 1 b)
        assert_eq!(m.mlp_param_bytes(), ((nb + nt) * 4) as u64);
        assert_eq!(m.param_count(), 4 * 128 * 8 + nb + nt);
    }

    #[test]
    fn e2e_model_is_about_100m_params() {
        let root = repo_root();
        let m = ModelConfig::load(&root, "rm_e2e").unwrap();
        let p = m.param_count();
        assert!((90_000_000..120_000_000).contains(&p), "{p}");
    }
}
