//! Experiment drivers: one function per paper table/figure (DESIGN.md's
//! experiment index). Each returns the rendered report and the raw data;
//! `trainingcxl bench <exp>` prints it, EXPERIMENTS.md records it.

use crate::config::device::DeviceParams;
use crate::config::sysconfig::SystemConfig;
use crate::config::ModelConfig;
use crate::devices::CxlGpu;
use crate::energy::energy_of_run;
use crate::sched::{PipelineSim, RunResult};
use crate::telemetry::BreakdownTable;
use crate::util::stats::geomean;
use crate::workload::Generator;
use std::fmt::Write as _;
use std::path::Path;

pub const PAPER_MODELS: [&str; 4] = ["rm1", "rm2", "rm3", "rm4"];

/// Simulate one (model, config) pair for `batches` batches.
pub fn simulate(
    root: &Path,
    model: &str,
    sys: SystemConfig,
    batches: u64,
) -> anyhow::Result<RunResult> {
    let cfg = ModelConfig::load(root, model)?;
    let params = DeviceParams::load(root)?;
    let gpu = CxlGpu::from_params(&cfg, &params, root);
    let cache = if sys == SystemConfig::Ssd {
        params.host.dram_cache_rows_frac
    } else {
        0.0
    };
    let stats = Generator::average_stats(&cfg, 42, 8, cache);
    Ok(PipelineSim::new(&cfg, sys, &params, gpu, stats).run(batches))
}

/// E1 / Figure 11: training-time breakdown per model x config.
pub fn fig11(root: &Path, batches: u64) -> anyhow::Result<String> {
    let mut out = String::new();
    writeln!(out, "=== Figure 11: training time breakdown (per batch) ===")?;
    for model in PAPER_MODELS {
        let mut table = BreakdownTable::default();
        for sys in SystemConfig::ALL {
            let r = simulate(root, model, sys, batches)?;
            table.push(sys.name(), r.mean_breakdown());
        }
        writeln!(out, "\n[{model}]")?;
        out.push_str(&table.render(1e6, "ms"));
    }
    // paper cross-checks
    let mut sp_pcie_vs_cxld = Vec::new();
    let mut sp_cxlb_vs_cxl = Vec::new();
    for model in PAPER_MODELS {
        let pcie = simulate(root, model, SystemConfig::Pcie, batches)?.mean_batch_ns();
        let d = simulate(root, model, SystemConfig::CxlD, batches)?.mean_batch_ns();
        let b = simulate(root, model, SystemConfig::CxlB, batches)?.mean_batch_ns();
        let c = simulate(root, model, SystemConfig::Cxl, batches)?.mean_batch_ns();
        sp_pcie_vs_cxld.push(1.0 - d / pcie);
        sp_cxlb_vs_cxl.push(1.0 - c / b);
    }
    writeln!(
        out,
        "\nCXL-D vs PCIe mean training-time reduction: {:.0}% (paper: 23%)",
        100.0 * sp_pcie_vs_cxld.iter().sum::<f64>() / sp_pcie_vs_cxld.len() as f64
    )?;
    writeln!(
        out,
        "CXL vs CXL-B mean training-time reduction:  {:.0}% (paper: 14%)",
        100.0 * sp_cxlb_vs_cxl.iter().sum::<f64>() / sp_cxlb_vs_cxl.len() as f64
    )?;
    Ok(out)
}

/// E2 / Figure 12: utilization timelines for CXL-D / CXL-B / CXL.
pub fn fig12(root: &Path, model: &str) -> anyhow::Result<String> {
    let mut out = String::new();
    writeln!(out, "=== Figure 12: resource utilization timelines [{model}] ===")?;
    for sys in [SystemConfig::CxlD, SystemConfig::CxlB, SystemConfig::Cxl] {
        let r = simulate(root, model, sys, 5)?;
        // steady-state window: batches 2..5
        let t0 = r.batch_times[..2].iter().sum::<u64>();
        let t1 = t0 + r.batch_times[2..].iter().sum::<u64>();
        writeln!(out, "\n--- {} (3 steady-state batches) ---", sys.name())?;
        out.push_str(&r.spans.render_timeline(t0, t1, 96));
        for lane in [
            crate::sim::Lane::Gpu,
            crate::sim::Lane::CompLogic,
            crate::sim::Lane::CkptLogic,
            crate::sim::Lane::Pmem,
        ] {
            writeln!(
                out,
                "    {:<10} utilization {:>5.1}%",
                lane.name(),
                100.0 * r.spans.utilization(lane, t0, t1)
            )?;
        }
    }
    Ok(out)
}

/// E3 / Figure 13: normalized energy per model x {SSD, PMEM, DRAM, CXL}.
pub fn fig13(root: &Path, batches: u64) -> anyhow::Result<String> {
    let mut out = String::new();
    writeln!(out, "=== Figure 13: energy (normalized to PMEM) ===")?;
    writeln!(
        out,
        "{:<8} {:>8} {:>8} {:>8} {:>8}   (paper shape: CXL lowest everywhere;",
        "model", "SSD", "PMEM", "DRAM", "CXL"
    )?;
    writeln!(
        out,
        "{:<8} {:>8} {:>8} {:>8} {:>8}    DRAM>PMEM on RM1/2, PMEM>DRAM on RM3/4)",
        "", "", "", "", ""
    )?;
    let mut cxl_savings = Vec::new();
    for model in PAPER_MODELS {
        let cfg = ModelConfig::load(root, model)?;
        let params = DeviceParams::load(root)?;
        let mut joules = std::collections::BTreeMap::new();
        for sys in [
            SystemConfig::Ssd,
            SystemConfig::Pmem,
            SystemConfig::Dram,
            SystemConfig::Cxl,
        ] {
            let r = simulate(root, model, sys, batches)?;
            joules.insert(sys.name(), energy_of_run(&cfg, &params, &r).total());
        }
        let pmem = joules["PMEM"];
        writeln!(
            out,
            "{:<8} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            model,
            joules["SSD"] / pmem,
            1.0,
            joules["DRAM"] / pmem,
            joules["CXL"] / pmem
        )?;
        cxl_savings.push(1.0 - joules["CXL"] / pmem);
    }
    writeln!(
        out,
        "\nCXL mean energy saving vs PMEM: {:.0}% (paper: 76%)",
        100.0 * cxl_savings.iter().sum::<f64>() / cxl_savings.len() as f64
    )?;
    Ok(out)
}

/// E6 / headline: 5.2x training speedup + 76% energy saving vs PMEM.
pub fn headline(root: &Path, batches: u64) -> anyhow::Result<String> {
    let mut out = String::new();
    writeln!(out, "=== Headline: CXL vs PMEM-based systems ===")?;
    let mut speedups = Vec::new();
    let mut savings = Vec::new();
    for model in PAPER_MODELS {
        let cfg = ModelConfig::load(root, model)?;
        let params = DeviceParams::load(root)?;
        let pmem = simulate(root, model, SystemConfig::Pmem, batches)?;
        let cxl = simulate(root, model, SystemConfig::Cxl, batches)?;
        let sp = pmem.mean_batch_ns() / cxl.mean_batch_ns();
        let e_pmem = energy_of_run(&cfg, &params, &pmem).total();
        let e_cxl = energy_of_run(&cfg, &params, &cxl).total();
        writeln!(
            out,
            "{model}: speedup {:.2}x, energy saving {:.0}%",
            sp,
            100.0 * (1.0 - e_cxl / e_pmem)
        )?;
        speedups.push(sp);
        savings.push(1.0 - e_cxl / e_pmem);
    }
    writeln!(
        out,
        "\ngeo-mean speedup: {:.2}x (paper: 5.2x)\nmean energy saving: {:.0}% (paper: 76%)",
        geomean(&speedups),
        100.0 * savings.iter().sum::<f64>() / savings.len() as f64
    )?;
    Ok(out)
}

/// E7 / Fig 4-5 ablation: software vs hardware data movement, isolated.
pub fn ablate_movement(root: &Path, batches: u64) -> anyhow::Result<String> {
    let mut out = String::new();
    writeln!(out, "=== Ablation: data movement (PCIe=software vs CXL-D=hardware) ===")?;
    for model in PAPER_MODELS {
        let sw = simulate(root, model, SystemConfig::Pcie, batches)?;
        let hw = simulate(root, model, SystemConfig::CxlD, batches)?;
        let sw_bd = sw.mean_breakdown();
        let hw_bd = hw.mean_breakdown();
        writeln!(
            out,
            "{model}: transfer {:>8.1}us -> {:>6.1}us; batch {:>8.1}us -> {:>8.1}us ({:.0}% faster)",
            sw_bd.transfer / 1e3,
            hw_bd.transfer / 1e3,
            sw.mean_batch_ns() / 1e3,
            hw.mean_batch_ns() / 1e3,
            100.0 * (1.0 - hw.mean_batch_ns() / sw.mean_batch_ns())
        )?;
    }
    Ok(out)
}

/// E8 / Fig 8 ablation: RAW stalls with vs without relaxed lookup.
pub fn ablate_raw(root: &Path, batches: u64) -> anyhow::Result<String> {
    let mut out = String::new();
    writeln!(out, "=== Ablation: RAW (CXL-B dependent vs CXL relaxed lookup) ===")?;
    for model in ["rm1", "rm2", "rm3"] {
        let dep = simulate(root, model, SystemConfig::CxlB, batches)?;
        let rel = simulate(root, model, SystemConfig::Cxl, batches)?;
        writeln!(
            out,
            "{model}: raw-hits/batch {:>9.0} -> {:>3}; embedding {:>8.1}us -> {:>8.1}us",
            dep.raw_hits as f64 / batches as f64,
            rel.raw_hits,
            dep.mean_breakdown().embedding / 1e3,
            rel.mean_breakdown().embedding / 1e3,
        )?;
    }
    Ok(out)
}

/// Extension: multi-expander pooling sweep (CXL 3.0 multi-level
/// switching, paper §Related Work — the scalability edge over
/// RecNMP/TensorDIMM). Stripes the tables over k pooled CXL-MEM devices;
/// each doubling adds one switch level (extra hop).
pub fn pooling(root: &Path, model: &str, batches: u64) -> anyhow::Result<String> {
    let cfg = ModelConfig::load(root, model)?;
    let params = DeviceParams::load(root)?;
    let gpu = CxlGpu::from_params(&cfg, &params, root);
    let stats = Generator::average_stats(&cfg, 42, 8, 0.0);
    let mut out = String::new();
    writeln!(out, "=== Extension: CXL-MEM pool scaling [{model}] ===")?;
    writeln!(out, "{:<10} {:>12} {:>9}", "expanders", "ms/batch", "speedup")?;
    let mut base = None;
    for k in [1usize, 2, 4, 8] {
        let extra_hops = (k as f64).log2() as usize; // one switch level per doubling
        let r = PipelineSim::new(&cfg, SystemConfig::Cxl, &params, gpu, stats)
            .with_expander_pool(k, extra_hops)
            .run(batches);
        let t = r.mean_batch_ns();
        let b = *base.get_or_insert(t);
        writeln!(out, "{:<10} {:>12.3} {:>8.2}x", k, t / 1e6, b / t)?;
    }
    writeln!(out, "(embedding-bound models scale with the pool until the GPU floor)")?;
    Ok(out)
}

/// E4 / Figure 9a: accuracy vs embedding/MLP-log batch gap (real training).
pub fn fig9a(root: &Path, gaps: &[u64]) -> anyhow::Result<String> {
    use crate::train::failure;
    let cfg = ModelConfig::load(root, "rm_mini")?;
    let mut out = String::new();
    writeln!(out, "=== Figure 9a: accuracy vs MLP-log batch gap (rm_mini, real numerics) ===")?;
    let (base_loss, base_acc) = failure::run_no_crash_baseline(root, &cfg, 7, 400, 16)?;
    writeln!(out, "no-crash baseline: loss {base_loss:.4} acc {base_acc:.4}")?;
    for &gap in gaps {
        let r = failure::run_gap_experiment(root, &cfg, 7, 200, 200, gap, 16)?;
        writeln!(
            out,
            "gap {:>4}: recovered@{:>3} observed-gap {:>3} loss {:.4} acc {:.4} (delta {:+.4})",
            gap,
            r.recovered_from,
            r.mlp_gap_observed,
            r.loss,
            r.accuracy,
            r.accuracy - base_acc
        )?;
    }
    writeln!(out, "(paper: degradation within business tolerance up to gaps of hundreds)")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    #[test]
    fn fig11_report_renders() {
        let root = repo_root();
        let s = fig11(&root, 6).unwrap();
        assert!(s.contains("[rm1]") && s.contains("[rm4]"));
        assert!(s.contains("CXL-D vs PCIe"));
    }

    #[test]
    fn fig13_report_has_all_rows() {
        let root = repo_root();
        let s = fig13(&root, 6).unwrap();
        for m in PAPER_MODELS {
            assert!(s.contains(m), "missing {m}: {s}");
        }
    }
}
