//! Real DLRM training through the PJRT runtime — the system's request
//! path. The embedding tables live in device buffers and never cross the
//! host boundary (the paper's CXL-MEM data region); the small MLP state
//! round-trips per batch (the CXL-GPU side), exchanging only reduced
//! vectors and their gradients — exactly the paper's device split.
//!
//! [`failure`] implements crash injection + recovery on top of the
//! byte-accurate log region, which is how Fig 9a (accuracy vs.
//! embedding/MLP-log gap) is measured with *real* numerics.
//!
//! Construct trainers with [`Trainer::with_topology`]: checkpointing
//! behaviour derives from the fabric's `CkptMode`
//! ([`CkptOptions::from_topology`]), so the real trainer runs the same
//! schedule the simulator models.

pub mod calibrate;
pub mod failure;
pub mod trainer;

pub use trainer::{CkptOptions, StepOutcome, Trainer};
