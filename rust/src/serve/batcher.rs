//! Policy-driven dynamic request batching.
//!
//! The serving latency/throughput trade-off lives in exactly two knobs:
//! `max_batch` (amortise the lookup + forward pass over more requests)
//! and `max_wait_us` (bound how long the first queued request may age
//! before the batch flushes anyway). A batch flushes on whichever bound
//! trips first — the standard dynamic-batching contract of inference
//! servers.

use crate::sim::SimTime;

/// The two batching knobs. Flush when `max_batch` requests are queued OR
/// the oldest queued request has waited `max_wait_us`, whichever first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_wait_us: 200,
        }
    }
}

impl BatchPolicy {
    pub fn max_wait_ns(&self) -> SimTime {
        self.max_wait_us * 1000
    }
}

/// One flushed batch: the arrival timestamps it carries, when it opened
/// (first arrival) and when it flushed (size bound: last arrival; wait
/// bound: `open + max_wait`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FormedBatch {
    pub open: SimTime,
    pub flush: SimTime,
    pub arrivals: Vec<SimTime>,
}

/// Dynamic batcher over a monotone arrival stream. An arrival that trips
/// the wait bound is retained as the seed of the next batch, so no
/// request is ever dropped between batches.
#[derive(Debug, Default)]
pub struct Batcher {
    policy: BatchPolicy,
    pending: Vec<SimTime>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher {
            policy,
            pending: Vec::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Pull arrivals from `next` until a flush bound trips; returns the
    /// flushed batch (always non-empty).
    pub fn form(&mut self, next: &mut dyn FnMut() -> SimTime) -> FormedBatch {
        if self.pending.is_empty() {
            self.pending.push(next());
        }
        let open = self.pending[0];
        let deadline = open + self.policy.max_wait_ns();
        loop {
            if self.pending.len() >= self.policy.max_batch.max(1) {
                let arrivals = std::mem::take(&mut self.pending);
                let flush = *arrivals.last().expect("size-flushed batch is non-empty");
                return FormedBatch {
                    open,
                    flush: flush.min(deadline),
                    arrivals,
                };
            }
            let t = next();
            if t > deadline {
                let arrivals = std::mem::take(&mut self.pending);
                self.pending.push(t); // seed of the next batch
                return FormedBatch {
                    open,
                    flush: deadline,
                    arrivals,
                };
            }
            self.pending.push(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic arrival stream with a fixed inter-arrival gap.
    fn ticker(start: SimTime, gap: SimTime) -> impl FnMut() -> SimTime {
        let mut t = start;
        move || {
            let now = t;
            t += gap;
            now
        }
    }

    #[test]
    fn size_bound_flushes_at_the_last_arrival() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait_us: 1_000_000, // wait bound far away
        });
        let mut next = ticker(100, 10);
        let f = b.form(&mut next);
        assert_eq!(f.arrivals, vec![100, 110, 120, 130]);
        assert_eq!(f.open, 100);
        assert_eq!(f.flush, 130);
        // the stream continues seamlessly into the next batch
        let f2 = b.form(&mut next);
        assert_eq!(f2.arrivals, vec![140, 150, 160, 170]);
    }

    #[test]
    fn wait_bound_flushes_a_partial_batch_and_keeps_the_straggler() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 64,
            max_wait_us: 1, // 1000 ns
        });
        let mut next = ticker(0, 600);
        let f = b.form(&mut next);
        // arrivals 0 and 600 fit in [0, 1000]; 1200 trips the deadline
        assert_eq!(f.arrivals, vec![0, 600]);
        assert_eq!(f.flush, 1000);
        // 1200 seeds the next batch instead of being dropped
        let f2 = b.form(&mut next);
        assert_eq!(f2.open, 1200);
        assert_eq!(f2.arrivals[0], 1200);
    }

    #[test]
    fn max_batch_one_degenerates_to_per_request_dispatch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 1,
            max_wait_us: 200,
        });
        let mut next = ticker(5, 50);
        for want in [5u64, 55, 105] {
            let f = b.form(&mut next);
            assert_eq!(f.arrivals, vec![want]);
            assert_eq!(f.flush, want);
        }
    }

    #[test]
    fn zero_wait_still_makes_progress() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait_us: 0,
        });
        let mut next = ticker(10, 10);
        let f = b.form(&mut next);
        assert_eq!(f.arrivals, vec![10]);
        assert_eq!(f.flush, 10);
    }
}
