//! Online-serving pins: the serving subsystem's contract with the rest
//! of the repo.
//!
//! * **Equivalence** — a single server tenant on a depth-1 fabric run
//!   through the tenancy arbiter is bit-identical to the standalone
//!   `ServingSim`, and deterministic for a fixed seed.
//! * **Tail amplification** — co-locating a trainer can only lengthen
//!   the server's latency tail (the pool serialises them), and ages the
//!   served embeddings behind the training head.
//! * **Robustness** — malformed `[[tenants]]` serving knobs and `[tiers]`
//!   tables surface typed errors (or the documented logged fallback),
//!   never a panic.

use trainingcxl::config::SystemConfig;
use trainingcxl::repo_root;
use trainingcxl::serve::{BatchPolicy, ServeConfig, ServingSim, TraceShape};
use trainingcxl::sim::topology::Topology;
use trainingcxl::tenancy::{MultiTenantSim, QosPolicy, TenantSet, TenantSpec};
use trainingcxl::util::tomlmini::Doc;

const BATCHES: u64 = 8;

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        rate_per_s: 4000.0,
        policy: BatchPolicy {
            max_batch: 32,
            max_wait_us: 200,
        },
        trace: TraceShape::Steady,
    }
}

fn server_spec(name: &str, model: &str, seed: u64) -> TenantSpec {
    TenantSpec {
        name: name.into(),
        model: model.into(),
        topology: Topology::from_system(SystemConfig::Cxl),
        seed,
        weight: 1,
        serve: Some(serve_cfg()),
    }
}

fn trainer_spec(name: &str, model: &str, seed: u64) -> TenantSpec {
    TenantSpec {
        name: name.into(),
        model: model.into(),
        topology: Topology::from_system(SystemConfig::Cxl),
        seed,
        weight: 1,
        serve: None,
    }
}

fn set_of(tenants: Vec<TenantSpec>) -> TenantSet {
    TenantSet {
        name: "serving-test".into(),
        fabric_levels: 1,
        policy: QosPolicy::FairShare,
        tenants,
    }
}

#[test]
fn single_server_tenancy_is_bit_identical_to_standalone_serving() {
    let root = repo_root();
    // the standalone serving simulator...
    let solo = ServingSim::for_model(
        &root,
        "rm_mini",
        Topology::from_system(SystemConfig::Cxl),
        42,
        &serve_cfg(),
    )
    .unwrap()
    .run(BATCHES);
    // ...vs the same server as the only tenant of a depth-1 pooled
    // fabric: no co-tenant, no stall, no extra hop — bit-identical
    let run = || {
        MultiTenantSim::new(&root, &set_of(vec![server_spec("s", "rm_mini", 42)]))
            .unwrap()
            .run(BATCHES)
    };
    let a = run();
    let b = run();
    let sa = a.tenants[0].serve.as_ref().expect("server tenant");
    let sb = b.tenants[0].serve.as_ref().expect("server tenant");
    // deterministic across runs for a fixed seed
    assert_eq!(a.tenants[0].result.batch_times, b.tenants[0].result.batch_times);
    assert_eq!(sa.latency, sb.latency, "latency histogram must replay");
    assert_eq!(sa.requests, sb.requests);
    // and identical to the standalone path, field by field
    let (t, s) = (&a.tenants[0].result, sa);
    assert_eq!(t.batch_times, solo.result.batch_times, "batch times diverge");
    assert_eq!(t.total_time, solo.result.total_time);
    assert_eq!(t.traffic, solo.result.traffic);
    assert_eq!(t.gpu_busy, solo.result.gpu_busy);
    assert_eq!(t.host_busy, solo.result.host_busy);
    assert_eq!(t.logic_busy, solo.result.logic_busy);
    assert_eq!(s.latency, solo.stats.latency, "histograms diverge");
    assert_eq!(s.requests, solo.stats.requests);
    assert_eq!(a.tenants[0].total_stall_ns(), 0, "solo server stalled");
    // serving is read-only: nothing recovered, nothing written back
    assert_eq!(a.tenants[0].recoveries, 0);
    assert_eq!(t.raw_hits, 0, "serving must never take a RAW stall");
}

#[test]
fn colocating_a_trainer_amplifies_the_serving_tail() {
    let root = repo_root();
    let iso = MultiTenantSim::new(&root, &set_of(vec![server_spec("s", "rm_mini", 42)]))
        .unwrap()
        .run(BATCHES);
    let mix = MultiTenantSim::new(
        &root,
        &set_of(vec![
            server_spec("s", "rm_mini", 42),
            trainer_spec("t", "rm_mini", 43),
        ]),
    )
    .unwrap()
    .run(BATCHES);
    let iso_s = iso.tenants[0].serve.as_ref().unwrap();
    let mix_s = mix.tenants[0].serve.as_ref().unwrap();
    // same seed, same arrival stream: the batcher forms identical
    // batches whatever the service times do
    assert_eq!(iso_s.requests, mix_s.requests);
    // the trainer's pool occupancy is charged to the server, which can
    // only push completions (and therefore every percentile) later
    assert!(
        mix_s.latency.p99() >= iso_s.latency.p99(),
        "co-located p99 {} < isolated p99 {}",
        mix_s.latency.p99(),
        iso_s.latency.p99()
    );
    assert!(
        mix_s.latency.p50() >= iso_s.latency.p50(),
        "co-located p50 regressed below isolated"
    );
    // rm_mini is embedding-bound: real contention, not a tie
    assert!(
        mix.tenants[0].total_stall_ns() > 0,
        "the server never absorbed trainer pool time"
    );
}

#[test]
fn staleness_tracks_the_training_head() {
    let root = repo_root();
    let iso = MultiTenantSim::new(&root, &set_of(vec![server_spec("s", "rm_mini", 42)]))
        .unwrap()
        .run(BATCHES);
    let iso_s = iso.tenants[0].serve.as_ref().unwrap();
    assert_eq!(iso_s.staleness.mean(), 0.0, "no trainer, no staleness");
    assert_eq!(iso_s.staleness.max(), 0);

    let mix = MultiTenantSim::new(
        &root,
        &set_of(vec![
            trainer_spec("t", "rm_mini", 43),
            server_spec("s", "rm_mini", 42),
        ]),
    )
    .unwrap()
    .run(BATCHES);
    let mix_s = mix.tenants[1].serve.as_ref().unwrap();
    assert_eq!(mix_s.staleness.samples(), BATCHES);
    assert!(
        mix_s.staleness.mean() > 0.0,
        "trainer commits must age the served embeddings"
    );
    // fair-share interleaves one trainer batch per serving batch, so the
    // served embeddings are exactly one batch behind the head each slot
    assert_eq!(mix_s.staleness.max(), 1);
}

#[test]
fn malformed_serving_and_tier_tables_error_without_panicking() {
    let root = repo_root();
    // [[tenants]] serving knobs: every malformed field is a typed error
    // naming the key (the PR-3 BadField contract, extended to roles)
    for (bad, needle) in [
        ("[[tenants]]\nmodel = \"rm_mini\"\nrole = 3", "role"),
        ("[[tenants]]\nmodel = \"rm_mini\"\nrole = \"proxy\"", "role"),
        (
            "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\nrate_per_s = 0",
            "rate_per_s",
        ),
        (
            "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\nrate_per_s = \"fast\"",
            "rate_per_s",
        ),
        (
            "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\nmax_batch = -2",
            "max_batch",
        ),
        (
            "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\nmax_wait_us = -1",
            "max_wait_us",
        ),
        (
            "[[tenants]]\nmodel = \"rm_mini\"\nrole = \"server\"\ntrace = \"sawtooth\"",
            "trace",
        ),
        // serving knobs on a trainer are a conflict, not silently dropped
        ("[[tenants]]\nmodel = \"rm_mini\"\nrate_per_s = 100", "rate_per_s"),
        ("[[tenants]]\nmodel = \"rm_mini\"\ntrace = \"steady\"", "trace"),
    ] {
        let doc = Doc::parse(bad).unwrap();
        let err = TenantSet::from_doc(&root, "x", &doc).unwrap_err().to_string();
        assert!(err.contains(needle), "{bad:?} -> {err}");
    }
    // truncated TOML fails at the parser, as an Err — never a panic
    assert!(Doc::parse("[[tenants\nmodel = ").is_err());
    // malformed [tiers] tables are Topology-level typed errors
    for bad in [
        "[tiers]\nhot_media = \"l2\"\nhot_frac = 0.1",
        "[tiers]\nhot_media = \"dram\"\nhot_frac = 1.5",
        "[tiers]\nhot_media = \"dram\"",
    ] {
        let doc = Doc::parse(bad).unwrap();
        assert!(
            Topology::from_doc("bad-tiers", &doc).is_err(),
            "{bad:?} should not compose"
        );
    }
    // the lenient loader falls back (with a stderr note) instead of
    // panicking, whatever name it is handed
    let t = Topology::load(&root, "no-such-topology-anywhere");
    assert_eq!(t.name, SystemConfig::Cxl.name(), "unknown names fall back to the flagship");
}

#[test]
fn shipped_serve_mixed_sets_load() {
    let root = repo_root();
    let two = TenantSet::load_strict(&root, "serve-mixed-2").unwrap();
    assert_eq!(two.tenants.len(), 2);
    assert_eq!(two.policy, QosPolicy::FairShare);
    assert!(two.tenants[0].serve.is_none(), "ranker is a trainer");
    let fe = two.tenants[1].serve.expect("frontend is a server");
    assert_eq!(fe.rate_per_s, 4000.0);
    assert_eq!(fe.policy.max_batch, 32);
    assert_eq!(fe.policy.max_wait_us, 200);

    let four = TenantSet::load_strict(&root, "serve-mixed-4").unwrap();
    assert_eq!(four.tenants.len(), 4);
    assert_eq!(four.policy, QosPolicy::Weighted);
    let servers: Vec<_> = four.tenants.iter().filter(|t| t.serve.is_some()).collect();
    assert_eq!(servers.len(), 2, "two of the four tenants serve");
    assert!(matches!(
        servers[1].serve.unwrap().trace,
        TraceShape::Diurnal { .. }
    ));
    // trainers keep the bigger weighted share
    assert!(four.tenants[0].weight > servers[0].weight);
}
