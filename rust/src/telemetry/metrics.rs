//! The typed metrics registry — the crate's one export path for
//! numbers.
//!
//! Every subsystem that used to hand-plumb scalars into `Report`
//! (`SpanLog` utilizations, `LinkStats` counters, latency histograms,
//! per-tenant stall/fault counters) registers into a
//! [`MetricsRegistry`] instead: a sorted map of named
//! [`MetricValue`]s — counters (monotonic `u64`), gauges (`f64`
//! point-in-time), and histogram summaries (count/min/max/p50/p99/p999
//! captured from a [`LatencyHistogram`]). `Report::to_json` serializes
//! the registry as one coherent tree; the flat scalar view
//! ([`MetricsRegistry::flat`]) keeps the exact key set the bench
//! drivers always exported, so downstream fingerprints and golden
//! fixtures do not move.

use crate::sim::fabric::LinkStats;
use crate::sim::{Lane, SimTime};
use crate::telemetry::{LatencyHistogram, SpanLog, StalenessGauge};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One registered metric value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MetricValue {
    /// Monotonic count (events, bytes, transfers).
    Counter(u64),
    /// Point-in-time scalar (ratios, milliseconds, percentages).
    Gauge(f64),
    /// Distribution summary captured from a [`LatencyHistogram`].
    Summary {
        count: u64,
        min: u64,
        max: u64,
        p50: u64,
        p99: u64,
        p999: u64,
    },
}

impl MetricValue {
    /// The scalar a flat export carries for this value: counters cast,
    /// gauges pass through, summaries surface their median.
    pub fn scalar(&self) -> f64 {
        match *self {
            MetricValue::Counter(c) => c as f64,
            MetricValue::Gauge(g) => g,
            MetricValue::Summary { p50, .. } => p50 as f64,
        }
    }
}

/// A value plus its display unit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricEntry {
    pub value: MetricValue,
    pub unit: &'static str,
}

/// Sorted name → entry registry. Keys are dotted paths
/// (`t2.fair-share.agg_batches_per_s`); iteration and serialization
/// order is the sorted key order, so exports are deterministic.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    entries: BTreeMap<String, MetricEntry>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Register a monotonic counter.
    pub fn counter(&mut self, key: impl Into<String>, value: u64, unit: &'static str) {
        self.entries.insert(
            key.into(),
            MetricEntry {
                value: MetricValue::Counter(value),
                unit,
            },
        );
    }

    /// Register a point-in-time gauge.
    pub fn gauge(&mut self, key: impl Into<String>, value: f64, unit: &'static str) {
        self.entries.insert(
            key.into(),
            MetricEntry {
                value: MetricValue::Gauge(value),
                unit,
            },
        );
    }

    /// Register a distribution summary captured from `h` (ns samples).
    pub fn histogram(&mut self, key: impl Into<String>, h: &LatencyHistogram) {
        self.entries.insert(
            key.into(),
            MetricEntry {
                value: MetricValue::Summary {
                    count: h.count(),
                    min: h.min(),
                    max: h.max(),
                    p50: h.p50(),
                    p99: h.p99(),
                    p999: h.p999(),
                },
                unit: "ns",
            },
        );
    }

    /// The flat scalar for `key`, if registered.
    pub fn value(&self, key: &str) -> Option<f64> {
        self.entries.get(key).map(|e| e.value.scalar())
    }

    /// The unit registered for `key`.
    pub fn unit(&self, key: &str) -> Option<&'static str> {
        self.entries.get(key).map(|e| e.unit)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &MetricEntry)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Flat `key → scalar` view. Counters and gauges keep their key;
    /// a summary expands into `.count/.min/.max/.p50/.p99/.p999`
    /// subkeys (all ns), so a summary never hides behind one number.
    pub fn flat(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (k, e) in &self.entries {
            match e.value {
                MetricValue::Counter(_) | MetricValue::Gauge(_) => {
                    out.insert(k.clone(), e.value.scalar());
                }
                MetricValue::Summary {
                    count,
                    min,
                    max,
                    p50,
                    p99,
                    p999,
                } => {
                    out.insert(format!("{k}.count"), count as f64);
                    out.insert(format!("{k}.min"), min as f64);
                    out.insert(format!("{k}.max"), max as f64);
                    out.insert(format!("{k}.p50"), p50 as f64);
                    out.insert(format!("{k}.p99"), p99 as f64);
                    out.insert(format!("{k}.p999"), p999 as f64);
                }
            }
        }
        out
    }

    /// The flat view as a JSON object — what `Report::to_json` embeds.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.flat()
                .into_iter()
                .map(|(k, v)| (k, Json::Num(v)))
                .collect(),
        )
    }

    /// Typed tree: every entry as `{kind, unit, value…}` — the
    /// lossless serialization (summaries keep all six fields).
    pub fn tree_json(&self) -> Json {
        let mut top = BTreeMap::new();
        for (k, e) in &self.entries {
            let mut m = BTreeMap::new();
            m.insert("unit".to_string(), Json::Str(e.unit.to_string()));
            match e.value {
                MetricValue::Counter(c) => {
                    m.insert("kind".to_string(), Json::Str("counter".to_string()));
                    m.insert("value".to_string(), Json::Num(c as f64));
                }
                MetricValue::Gauge(g) => {
                    m.insert("kind".to_string(), Json::Str("gauge".to_string()));
                    m.insert("value".to_string(), Json::Num(g));
                }
                MetricValue::Summary {
                    count,
                    min,
                    max,
                    p50,
                    p99,
                    p999,
                } => {
                    m.insert("kind".to_string(), Json::Str("summary".to_string()));
                    m.insert("count".to_string(), Json::Num(count as f64));
                    m.insert("min".to_string(), Json::Num(min as f64));
                    m.insert("max".to_string(), Json::Num(max as f64));
                    m.insert("p50".to_string(), Json::Num(p50 as f64));
                    m.insert("p99".to_string(), Json::Num(p99 as f64));
                    m.insert("p999".to_string(), Json::Num(p999 as f64));
                }
            }
            top.insert(k.clone(), Json::Obj(m));
        }
        Json::Obj(top)
    }

    /// Plain-text table (the `trainingcxl trace --summary` tail).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:<44} {:>16} {:>6}\n", "metric", "value", "unit"));
        for (k, v) in self.flat() {
            let unit = self
                .unit(k.rsplit_once('.').map_or(k.as_str(), |(p, _)| p))
                .or_else(|| self.unit(&k))
                .unwrap_or("");
            out.push_str(&format!("{k:<44} {v:>16.3} {unit:>6}\n"));
        }
        out
    }

    // ---- registration helpers: the subsystems' one export path ----

    /// Register per-link counters under `{prefix}.link.{name}.*`: the
    /// exact `util_pct` (busy ÷ `wall_ns`) and `gb` scalars the serve /
    /// tenant reports always carried, plus `degraded_ms` and
    /// `transfers`.
    pub fn register_links(
        &mut self,
        prefix: &str,
        links: &[(String, LinkStats)],
        wall_ns: SimTime,
    ) {
        let wall = wall_ns.max(1) as f64;
        for (name, l) in links {
            let base = format!("{prefix}.link.{name}");
            self.gauge(
                format!("{base}.util_pct"),
                100.0 * l.busy_ns as f64 / wall,
                "%",
            );
            self.gauge(format!("{base}.gb"), l.bytes as f64 / 1e9, "GB");
            self.gauge(
                format!("{base}.degraded_ms"),
                l.degraded_ns as f64 / 1e6,
                "ms",
            );
            self.counter(format!("{base}.transfers"), l.transfers, "ops");
        }
    }

    /// Register a latency histogram's tail under the report's historic
    /// key shape: `{prefix}.p50_ms/.p99_ms/.p999_ms` (ns → ms gauges).
    pub fn register_latency_ms(&mut self, prefix: &str, h: &LatencyHistogram) {
        self.gauge(format!("{prefix}.p50_ms"), h.p50() as f64 / 1e6, "ms");
        self.gauge(format!("{prefix}.p99_ms"), h.p99() as f64 / 1e6, "ms");
        self.gauge(format!("{prefix}.p999_ms"), h.p999() as f64 / 1e6, "ms");
    }

    /// Register a staleness gauge under `{prefix}.staleness_*`.
    pub fn register_staleness(&mut self, prefix: &str, g: &StalenessGauge) {
        self.gauge(format!("{prefix}.staleness_mean"), g.mean(), "batches");
        self.counter(format!("{prefix}.staleness_max"), g.max(), "batches");
    }

    /// Register per-lane busy utilization from a span log over
    /// `[from, to)` as `{prefix}.lane.{name}.util_pct` gauges.
    pub fn register_lanes(&mut self, prefix: &str, spans: &SpanLog, from: SimTime, to: SimTime) {
        const LANES: [Lane; 6] = [
            Lane::Gpu,
            Lane::CompLogic,
            Lane::CkptLogic,
            Lane::Pmem,
            Lane::HostCpu,
            Lane::Link,
        ];
        for lane in LANES {
            let busy = spans.busy(lane, from, to);
            if busy == 0 {
                continue;
            }
            self.gauge(
                format!("{prefix}.lane.{}.util_pct", lane.name()),
                100.0 * busy as f64 / (to - from).max(1) as f64,
                "%",
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_expands_summaries_and_sorts_keys() {
        let mut m = MetricsRegistry::new();
        m.gauge("b.ratio", 1.5, "x");
        m.counter("a.events", 7, "ops");
        let mut h = LatencyHistogram::new();
        for v in [10, 20, 30] {
            h.record(v);
        }
        m.histogram("c.lat", &h);
        let flat = m.flat();
        let keys: Vec<&str> = flat.keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            [
                "a.events",
                "b.ratio",
                "c.lat.count",
                "c.lat.max",
                "c.lat.min",
                "c.lat.p50",
                "c.lat.p99",
                "c.lat.p999",
            ]
        );
        assert_eq!(flat["a.events"], 7.0);
        assert_eq!(flat["c.lat.count"], 3.0);
        assert_eq!(m.value("c.lat"), Some(20.0));
        assert_eq!(m.unit("a.events"), Some("ops"));
    }

    #[test]
    fn json_views_are_parseable_and_deterministic() {
        let mut m = MetricsRegistry::new();
        m.gauge("x.g", 0.25, "ms");
        m.counter("x.c", 3, "ops");
        let flat = m.to_json().to_string();
        let tree = m.tree_json().to_string();
        assert_eq!(flat, "{\"x.c\":3,\"x.g\":0.25}");
        assert!(tree.contains("\"kind\":\"gauge\""), "{tree}");
        assert!(Json::parse(&flat).is_ok());
        assert!(Json::parse(&tree).is_ok());
        // same registry, same bytes
        assert_eq!(flat, m.to_json().to_string());
    }

    #[test]
    fn register_links_matches_the_report_key_shape() {
        let mut m = MetricsRegistry::new();
        let links = vec![(
            "t0-l1".to_string(),
            LinkStats {
                bytes: 2_000_000_000,
                busy_ns: 5_000_000,
                degraded_ns: 1_000_000,
                transfers: 4,
            },
        )];
        m.register_links("mt", &links, 10_000_000);
        assert_eq!(m.value("mt.link.t0-l1.util_pct"), Some(50.0));
        assert_eq!(m.value("mt.link.t0-l1.gb"), Some(2.0));
        assert_eq!(m.value("mt.link.t0-l1.degraded_ms"), Some(1.0));
        assert_eq!(m.value("mt.link.t0-l1.transfers"), Some(4.0));
        let r = m.render();
        assert!(r.contains("util_pct"), "{r}");
    }
}
