//! Config system: model zoo (`configs/models/*.toml`, shared with the
//! Python compile path) and device/testbed parameters
//! (`configs/devices/testbed.toml`).

pub mod device;
pub mod model;
pub mod sysconfig;

pub use device::DeviceParams;
pub use model::ModelConfig;
pub use sysconfig::{CkptMode, SystemConfig};
