//! Engine determinism pins (docs/engine.md §Determinism contract):
//!
//! * A multi-tenant world mixing every lane class — tiered trainer,
//!   sharded trainer, flagship trainer, inference server — must produce
//!   **bit-identical** results at every worker-pool size: the round
//!   merge is keyed by task index, never by completion order.
//! * The event queue drains any schedule in (time, insertion-seq) order
//!   — the causal total order every simulator in the crate pumps.
//! * The causal trace is part of the contract: its Chrome trace-event
//!   export must be **byte-identical** at every worker count (all
//!   recording happens on the round-merge thread, in merge order), it
//!   must pass schema validation even under crashes and fabric faults,
//!   and its critical-path attribution must sum exactly.

use trainingcxl::config::{CkptMode, SystemConfig};
use trainingcxl::repo_root;
use trainingcxl::sched::RunResult;
use trainingcxl::serve::{BatchPolicy, ServeConfig, TraceShape};
use trainingcxl::sim::engine::EventQueue;
use trainingcxl::sim::mem::MediaKind;
use trainingcxl::sim::topology::Topology;
use trainingcxl::telemetry::SpanLog;
use trainingcxl::tenancy::{MultiTenantRun, MultiTenantSim, QosPolicy, TenantSet, TenantSpec};
use trainingcxl::util::Rng;

const BATCHES: u64 = 6;

/// A world touching every lane class the engine schedules: a tiered
/// trainer, a 2-way sharded trainer, a flagship trainer, and an
/// inference server, sharing a depth-2 pooled fabric.
fn mixed_world() -> TenantSet {
    let tiered = Topology::builder("det-tiered")
        .near_data()
        .hw_movement()
        .checkpoint(CkptMode::Relaxed)
        .relaxed_lookup()
        .max_mlp_log_gap(200)
        .tiered_media(MediaKind::Dram, 0.1)
        .migrate_every(4)
        .build()
        .expect("tiered member must validate");
    let sharded = Topology::builder("det-sharded")
        .near_data()
        .hw_movement()
        .checkpoint(CkptMode::Relaxed)
        .relaxed_lookup()
        .max_mlp_log_gap(200)
        .expander_pool(2, 1)
        .gpu_shards(2)
        .build()
        .expect("sharded member must validate");
    let spec = |name: &str, topo: Topology, seed, serve| TenantSpec {
        name: name.into(),
        model: "rm_mini".into(),
        topology: topo,
        seed,
        weight: 1,
        serve,
    };
    TenantSet {
        name: "det-mixed".into(),
        fabric_levels: 2,
        redundancy: 0,
        policy: QosPolicy::FairShare,
        tenants: vec![
            spec("tiered", tiered, 42, None),
            spec("sharded", sharded, 43, None),
            spec("flagship", Topology::from_system(SystemConfig::Cxl), 44, None),
            spec(
                "frontend",
                Topology::from_system(SystemConfig::Cxl),
                45,
                Some(ServeConfig {
                    rate_per_s: 4_000.0,
                    policy: BatchPolicy::default(),
                    trace: TraceShape::Steady,
                }),
            ),
        ],
        faults: Vec::new(),
    }
}

fn assert_identical_result(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.batch_times, b.batch_times, "{what}: batch times differ");
    assert_eq!(a.total_time, b.total_time, "{what}: total time differs");
    assert_eq!(a.raw_hits, b.raw_hits, "{what}: raw hits differ");
    assert_eq!(a.max_mlp_gap, b.max_mlp_gap, "{what}: mlp gap differs");
    assert_eq!(a.traffic, b.traffic, "{what}: traffic differs");
    assert_eq!(a.gpu_busy, b.gpu_busy, "{what}: gpu busy differs");
    assert_eq!(a.host_busy, b.host_busy, "{what}: host busy differs");
    assert_eq!(a.logic_busy, b.logic_busy, "{what}: logic busy differs");
    assert_eq!(a.breakdowns, b.breakdowns, "{what}: breakdowns differ");
}

fn assert_identical_run(a: &MultiTenantRun, b: &MultiTenantRun, what: &str) {
    assert_eq!(a.levels, b.levels, "{what}: fabric levels differ");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        let who = format!("{what}/{}", x.name);
        assert_eq!(x.name, y.name, "{who}: order differs");
        assert_identical_result(&x.result, &y.result, &who);
        assert_eq!(x.stalls, y.stalls, "{who}: stalls differ");
        assert_eq!(x.pool_busy_ns, y.pool_busy_ns, "{who}: pool busy differs");
        assert_eq!(x.batches, y.batches, "{who}: batches differ");
        assert_eq!(x.recoveries, y.recoveries, "{who}: recoveries differ");
        assert_eq!(x.stalled_rounds, y.stalled_rounds, "{who}: stalled rounds differ");
        assert_eq!(x.fault_stall_ns, y.fault_stall_ns, "{who}: fault stall differs");
        assert_eq!(
            x.fault_recovery_ns, y.fault_recovery_ns,
            "{who}: fault recovery differs"
        );
        match (&x.serve, &y.serve) {
            (None, None) => {}
            (Some(s), Some(t)) => {
                assert_eq!(s.latency, t.latency, "{who}: latency histogram differs");
                assert_eq!(s.staleness, t.staleness, "{who}: staleness differs");
                assert_eq!(s.requests, t.requests, "{who}: request count differs");
            }
            _ => panic!("{who}: serve role differs"),
        }
    }
    assert_eq!(a.links.len(), b.links.len(), "{what}: link count");
    for ((an, al), (bn, bl)) in a.links.iter().zip(&b.links) {
        assert_eq!(an, bn, "{what}: link order differs");
        assert_eq!(al, bl, "{what}/{an}: link stats differ");
    }
    assert_eq!(a.faults, b.faults, "{what}: fault records differ");
}

#[test]
fn mixed_world_is_bit_identical_at_any_worker_count() {
    let root = repo_root();
    let set = mixed_world();
    let run = |workers: usize| {
        MultiTenantSim::new(&root, &set)
            .expect("mixed world must build")
            .with_workers(workers)
            .run(BATCHES)
    };
    let base = run(1);
    for workers in [2usize, 4] {
        assert_identical_run(&base, &run(workers), &format!("workers={workers}"));
    }
}

#[test]
fn crash_recovery_is_bit_identical_at_any_worker_count() {
    use trainingcxl::tenancy::CrashPlan;
    let root = repo_root();
    let set = mixed_world();
    let crash = CrashPlan {
        tenant: 1,
        batch: 2,
    };
    let run = |workers: usize| {
        MultiTenantSim::new(&root, &set)
            .expect("mixed world must build")
            .with_workers(workers)
            .run_with_crash(BATCHES, Some(crash))
    };
    let base = run(1);
    assert_eq!(base.tenants[1].recoveries, 1, "victim must recover");
    for workers in [2usize, 4] {
        assert_identical_run(&base, &run(workers), &format!("crash workers={workers}"));
    }
}

#[test]
fn fabric_faults_are_bit_identical_at_any_worker_count() {
    use trainingcxl::sim::fabric::FaultKind;
    use trainingcxl::tenancy::FaultPlan;
    let root = repo_root();
    // every fault class in one schedule: a severed link on the tiered
    // tenant, a switch brown-out on the sharded one, and an expander
    // loss tearing the flagship tenant's in-flight rows
    let mut set = mixed_world();
    set.faults = vec![
        FaultPlan {
            kind: FaultKind::LinkDown,
            tenant: 0,
            level: None,
            inject_round: 1,
            repair_round: 2,
        },
        FaultPlan {
            kind: FaultKind::SwitchDown,
            tenant: 1,
            level: None,
            inject_round: 2,
            repair_round: 4,
        },
        FaultPlan {
            kind: FaultKind::ExpanderLost,
            tenant: 2,
            level: None,
            inject_round: 3,
            repair_round: 5,
        },
    ];
    let run = |workers: usize| {
        MultiTenantSim::new(&root, &set)
            .expect("faulted mixed world must build")
            .with_workers(workers)
            .run(BATCHES)
    };
    let base = run(1);
    assert_eq!(base.faults.len(), 3, "every fault must be applied");
    assert!(
        base.faults.iter().all(|f| !f.blast.is_empty()),
        "an unredundant fabric absorbs nothing"
    );
    assert!(base.tenants[2].fault_recovery_ns > 0, "torn tenant must replay");
    for workers in [2usize, 4] {
        assert_identical_run(&base, &run(workers), &format!("faults workers={workers}"));
    }
}

/// The Perfetto export of one trace, as the CLI would write it.
fn export_bytes(run: &MultiTenantRun) -> String {
    run.trace.validate().expect("trace must validate");
    let tenants: Vec<String> = run.tenants.iter().map(|t| t.name.clone()).collect();
    let spans: Vec<&SpanLog> = run.tenants.iter().map(|t| &t.result.spans).collect();
    run.trace.chrome_trace(&tenants, &spans).to_string()
}

#[test]
fn trace_export_is_byte_identical_at_any_worker_count() {
    let root = repo_root();
    let set = mixed_world();
    let export = |workers: usize| {
        export_bytes(
            &MultiTenantSim::new(&root, &set)
                .expect("mixed world must build")
                .with_workers(workers)
                .run(BATCHES),
        )
    };
    let base = export(1);
    assert!(base.contains("\"traceEvents\":["), "export must be trace-event shaped");
    for workers in [2usize, 4] {
        assert_eq!(base, export(workers), "trace bytes differ at workers={workers}");
    }
}

#[test]
fn trace_attribution_sums_exactly_and_tracks_the_critical_path() {
    let root = repo_root();
    let run = MultiTenantSim::new(&root, &mixed_world())
        .expect("mixed world must build")
        .run(BATCHES);
    run.trace.validate().expect("trace must validate");
    let a = run.trace.attribution();
    assert_eq!(a.sum_ns(), a.total_ns, "buckets must cover the path exactly");
    let wall = run
        .tenants
        .iter()
        .map(|t| t.result.total_time)
        .max()
        .expect("tenants exist");
    assert!(a.total_ns > 0 && wall > 0);
    let err = (a.total_ns as f64 - wall as f64).abs() / wall as f64;
    assert!(
        err < 0.01,
        "attribution total {} strays from the measured critical path {wall}",
        a.total_ns
    );
}

#[test]
fn trace_stays_valid_and_marks_crashes_and_fabric_faults() {
    use trainingcxl::sim::fabric::FaultKind;
    use trainingcxl::tenancy::{CrashPlan, FaultPlan};
    let root = repo_root();
    let mut set = mixed_world();
    // an expander loss (tears in-flight rows -> undo replay at re-entry)
    // plus a GPU crash on the sharded tenant, in one run
    set.faults = vec![FaultPlan {
        kind: FaultKind::ExpanderLost,
        tenant: 2,
        level: None,
        inject_round: 2,
        repair_round: 4,
    }];
    let crash = CrashPlan {
        tenant: 1,
        batch: 2,
    };
    let run = MultiTenantSim::new(&root, &set)
        .expect("faulted world must build")
        .run_with_crash(BATCHES, Some(crash));
    run.trace.validate().expect("crash+fault trace must validate");
    let labels: Vec<&str> = run.trace.events().iter().map(|e| e.kind.label()).collect();
    for mark in ["fabric-fault", "fabric-repair", "crash-arm", "recovery", "catch-up"] {
        assert!(labels.contains(&mark), "trace must carry a '{mark}' event");
    }
    // the torn GPU batch carries its whole crash cycle inside the slot
    let crashed_slot = run.trace.events().iter().any(|e| match e.kind {
        trainingcxl::telemetry::TraceKind::Slot { recovery_ns, .. } => recovery_ns > 0,
        _ => false,
    });
    assert!(crashed_slot, "the crashed batch's slot must record its recovery cost");
}

/// Property: whatever schedule is thrown at it, the queue drains in
/// nondecreasing time, and same-time events pop in insertion order.
/// (Hand-rolled proptest: seeded generator, many cases, no dep.)
#[test]
fn event_queue_drains_any_schedule_in_causal_order() {
    const CASES: u64 = 200;
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x9E37_79B9_7F4A_7C15);
        let n = 1 + rng.gen_range(64) as usize;
        let mut q: EventQueue<usize> = EventQueue::new();
        let mut times = Vec::with_capacity(n);
        for i in 0..n {
            // a small time range forces plenty of ties
            let at = rng.gen_range(8);
            times.push(at);
            q.schedule(at, i);
        }
        let mut last: Option<(u64, usize)> = None;
        let mut drained = 0usize;
        while let Some((at, i)) = q.pop() {
            assert_eq!(at, times[i], "case {case}: event {i} popped at wrong time");
            assert_eq!(q.now(), at, "case {case}: clock must follow the pop");
            if let Some((pt, pi)) = last {
                assert!(pt <= at, "case {case}: time went backwards ({pt} -> {at})");
                if pt == at {
                    assert!(pi < i, "case {case}: tie broke insertion order ({pi} -> {i})");
                }
            }
            last = Some((at, i));
            drained += 1;
        }
        assert_eq!(drained, n, "case {case}: queue lost events");
        assert!(q.is_empty());
    }
}
