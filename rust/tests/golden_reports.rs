//! Golden-report regression: the bench-smoke Report JSONs (fig11,
//! shard-scaling, tier-sweep, tenant-interference, serve-latency at the
//! same reduced iteration counts the CI smoke job uses) are compared metric-by-metric
//! against committed fixtures under `rust/tests/golden/`, so metric
//! drift fails CI instead of passing silently.
//!
//! Bootstrap/bless: when a fixture is missing (first run on a fresh
//! checkout) or `GOLDEN_BLESS=1` is set, the test writes the fixture and
//! passes with a notice — commit the generated file to arm the gate.
//! See `rust/tests/golden/README.md`.

use std::collections::BTreeMap;
use trainingcxl::bench::experiments::{self, Report};
use trainingcxl::repo_root;
use trainingcxl::util::json::Json;

/// Relative drift tolerance. The simulator is deterministic, so a real
/// schedule change lands far beyond this; the slack only absorbs
/// deliberate device-parameter nudges small enough to be noise.
const REL_TOL: f64 = 0.02;
/// Absolute floor for metrics near zero (counts that should stay zero).
const ABS_TOL: f64 = 1e-6;

fn metric_map(j: &Json) -> BTreeMap<String, f64> {
    j.get("metrics")
        .and_then(|m| m.as_obj())
        .map(|m| {
            m.iter()
                .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                .collect()
        })
        .unwrap_or_default()
}

fn check_golden(name: &str, report: &Report) {
    let path = repo_root().join("rust/tests/golden").join(format!("{name}.json"));
    let rendered = report.to_json().to_string();
    let bless = std::env::var("GOLDEN_BLESS").ok().as_deref() == Some("1");
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, rendered + "\n").unwrap();
        eprintln!(
            "[golden] blessed {} — commit it to arm the regression gate",
            path.display()
        );
        // A fresh CI checkout would re-bless forever and the gate would
        // never arm; CI sets GOLDEN_STRICT=1 so a missing fixture is a
        // loud failure (commit the file just generated), not a pass.
        assert!(
            bless || std::env::var("GOLDEN_STRICT").ok().as_deref() != Some("1"),
            "{name}: no committed fixture at rust/tests/golden/{name}.json — \
             the drift gate is unarmed; commit the freshly blessed file"
        );
        return;
    }
    let fixture = std::fs::read_to_string(&path).unwrap();
    let want = metric_map(&Json::parse(fixture.trim()).unwrap());
    let got = metric_map(&Json::parse(&rendered).unwrap());
    assert!(!want.is_empty(), "{name}: fixture carries no metrics");
    let mut drift = Vec::new();
    for (k, w) in &want {
        match got.get(k) {
            None => drift.push(format!("missing metric '{k}' (fixture {w})")),
            Some(g) if (g - w).abs() > REL_TOL * w.abs() + ABS_TOL => {
                drift.push(format!("'{k}': {g} vs fixture {w}"));
            }
            Some(_) => {}
        }
    }
    for k in got.keys() {
        if !want.contains_key(k) {
            drift.push(format!("new metric '{k}' missing from the fixture"));
        }
    }
    assert!(
        drift.is_empty(),
        "{name}: metric drift vs rust/tests/golden/{name}.json \
         (intentional? re-bless with GOLDEN_BLESS=1 and commit):\n  {}",
        drift.join("\n  ")
    );
}

#[test]
fn golden_fig11() {
    check_golden("fig11", &experiments::fig11(&repo_root(), 6).unwrap());
}

#[test]
fn golden_shard_scaling() {
    check_golden(
        "shard-scaling",
        &experiments::shard_scaling(&repo_root(), "rm2", 6).unwrap(),
    );
}

#[test]
fn golden_tier_sweep() {
    check_golden("tier-sweep", &experiments::tier_sweep(&repo_root(), "rm2", 6).unwrap());
}

#[test]
fn golden_tenant_interference() {
    check_golden(
        "tenant-interference",
        &experiments::tenant_interference(&repo_root(), "rm2", 6).unwrap(),
    );
}

#[test]
fn golden_serve_latency() {
    check_golden(
        "serve-latency",
        &experiments::serve_latency(&repo_root(), "rm2", 6).unwrap(),
    );
}
