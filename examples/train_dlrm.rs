//! End-to-end driver (DESIGN.md E9): train the ~100M-parameter `rm_e2e`
//! DLRM for a few hundred real steps on synthetic Criteo-like data,
//! entirely through the rust coordinator + PJRT AOT artifacts — Python is
//! not involved. Logs the loss curve and throughput; the run is recorded
//! in EXPERIMENTS.md.
//!
//! The embedding table (~403 MB) stays device-resident across steps; only
//! reduced vectors/gradients and the ~0.6 MB of MLP parameters cross the
//! host boundary — the paper's CXL-MEM/CXL-GPU split.
//!
//! Run: `cargo run --release --example train_dlrm -- [steps] [model]`

use trainingcxl::config::{ModelConfig, SystemConfig};
use trainingcxl::sim::topology::Topology;
use trainingcxl::train::Trainer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = args.get(2).map(|s| s.as_str()).unwrap_or("rm_e2e");

    let root = trainingcxl::repo_root();
    let cfg = ModelConfig::load(&root, model)?;
    println!(
        "[e2e] {model}: {:.1}M parameters ({} tables x {} rows x {}d + {:.2}M MLP), batch {}",
        cfg.param_count() as f64 / 1e6,
        cfg.num_tables,
        cfg.rows_per_table,
        cfg.feature_dim,
        cfg.mlp_param_bytes() as f64 / 4e6,
        cfg.batch_size
    );

    // DRAM-ideal fabric: CkptMode::None, so no host mirror — this driver
    // measures pure training throughput (the recovery walk-through is
    // examples/failure_recovery.rs).
    let t_load = std::time::Instant::now();
    let mut trainer =
        Trainer::with_topology(&root, &cfg, 7, &Topology::from_system(SystemConfig::Dram))?;
    println!("[e2e] runtime + buffers ready in {:.1}s", t_load.elapsed().as_secs_f64());

    let t0 = std::time::Instant::now();
    let mut curve: Vec<(u64, f32)> = Vec::new();
    let mut window = Vec::new();
    for s in 0..steps {
        let out = trainer.step()?;
        window.push(out.loss);
        if s % 20 == 0 || s + 1 == steps {
            let avg = window.iter().sum::<f32>() / window.len() as f32;
            window.clear();
            curve.push((out.batch, avg));
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "step {:>5}  loss {:.5}  ({:.2} steps/s, {:.1} samples/s)",
                out.batch,
                avg,
                (s + 1) as f64 / dt,
                ((s + 1) as usize * cfg.batch_size) as f64 / dt
            );
        }
    }
    let dt = t0.elapsed().as_secs_f64();

    let (eval_loss, acc) = trainer.evaluate(8, 0xE7A1)?;
    println!("\n[e2e] loss curve (batch, mean loss):");
    for (b, l) in &curve {
        println!("  {b:>5} {l:.5}");
    }
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    println!(
        "\n[e2e] {steps} steps in {dt:.1}s = {:.1} ms/step | loss {first:.4} -> {last:.4} | eval loss {eval_loss:.4} acc {acc:.4}",
        1e3 * dt / steps as f64
    );
    anyhow::ensure!(last < first, "loss did not decrease — training broken");
    println!("[e2e] OK: all three layers compose (Pallas kernels -> JAX DLRM -> rust/PJRT)");
    Ok(())
}
