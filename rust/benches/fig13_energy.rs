//! Bench: regenerate paper Figure 13 (normalized energy, RM1-4 x
//! {SSD, PMEM, DRAM, CXL}) and Figure 12 (utilization timelines).
//!
//! Run: `cargo bench --bench fig13_energy`

use trainingcxl::bench::experiments;

fn main() -> anyhow::Result<()> {
    let root = trainingcxl::repo_root();
    println!("{}", experiments::fig13(&root, 30)?);
    println!("{}", experiments::fig12(&root, "rm1")?);
    println!("{}", experiments::fig12(&root, "rm2")?);
    Ok(())
}
