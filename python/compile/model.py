"""L2: DLRM forward/backward/SGD in JAX, composed from the L1 kernels.

Mirrors the paper's Figure 1 split:

  * bottom-MLP over dense features      -> CXL-GPU (mlp.matmul_bias)
  * embedding bag over sparse features  -> CXL-MEM computing logic
                                           (embedding.embedding_bag)
  * feature interaction = concatenation -> CXL-GPU
  * top-MLP + BCE loss                  -> CXL-GPU
  * BWP: MLP grads via autodiff through the custom-VJP matmul kernel;
    embedding update applied by the scatter kernel on the *bag gradient*
    (d reduced / d row = identity), never materialising a dense table
    gradient — exactly the paper's near-memory embedding update.

The embedding bag is a stop_gradient boundary: jax.grad differentiates
w.r.t. the reduced vectors (an activation), and the table update is the
explicit embedding_update kernel. This keeps the MLP path (GPU) and the
embedding path (CXL-MEM) separable, which is what lets the rust scheduler
overlap / relax them.

Params are a flat list in a fixed order (see param_specs) so the rust
runtime can feed PJRT buffers positionally; aot.py records the layout in
manifest.json.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import embedding, mlp
from .modelcfg import ModelConfig


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Flat (name, shape) layout: bottom w/b pairs, top w/b pairs, table."""
    specs: list[tuple[str, tuple[int, ...]]] = []
    for i, (fan_in, fan_out) in enumerate(cfg.bottom_layers):
        specs.append((f"bot_w{i}", (fan_in, fan_out)))
        specs.append((f"bot_b{i}", (fan_out,)))
    for i, (fan_in, fan_out) in enumerate(cfg.top_layers):
        specs.append((f"top_w{i}", (fan_in, fan_out)))
        specs.append((f"top_b{i}", (fan_out,)))
    specs.append(("table", (cfg.num_tables, cfg.rows_per_table, cfg.feature_dim)))
    return specs


def init_params(cfg: ModelConfig, key) -> list[jnp.ndarray]:
    """Xavier-uniform init matching rust/src/train's initializer (same layout)."""
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name == "table":
            params.append(jax.random.uniform(sub, shape, jnp.float32, -0.05, 0.05))
        elif "_w" in name:
            limit = (6.0 / (shape[0] + shape[1])) ** 0.5
            params.append(jax.random.uniform(sub, shape, jnp.float32, -limit, limit))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def split_params(cfg: ModelConfig, flat):
    nb = len(cfg.bottom_layers)
    nt = len(cfg.top_layers)
    bot = [(flat[2 * i], flat[2 * i + 1]) for i in range(nb)]
    top = [(flat[2 * nb + 2 * i], flat[2 * nb + 2 * i + 1]) for i in range(nt)]
    table = flat[2 * nb + 2 * nt]
    return bot, top, table


def _mlp_forward(layers, x, final_relu: bool) -> jnp.ndarray:
    for i, (w, b) in enumerate(layers):
        x = mlp.matmul_bias(x, w, b)
        if i + 1 < len(layers) or final_relu:
            x = jax.nn.relu(x)
    return x


def bottom_mlp(bot, dense: jnp.ndarray) -> jnp.ndarray:
    """Dense-feature encoder; final ReLU keeps it in embedding space (DLRM)."""
    return _mlp_forward(bot, dense, final_relu=True)


def interaction(bottom_out: jnp.ndarray, reduced: jnp.ndarray) -> jnp.ndarray:
    """Paper's feature interaction: concatenation into one vector space."""
    B = bottom_out.shape[0]
    return jnp.concatenate([bottom_out, reduced.reshape(B, -1)], axis=1)


def top_mlp(top, z: jnp.ndarray) -> jnp.ndarray:
    """Click-probability head; returns logits (B,)."""
    return _mlp_forward(top, z, final_relu=False)[:, 0]


def forward(cfg: ModelConfig, flat_params, dense, indices) -> jnp.ndarray:
    bot, top, table = split_params(cfg, flat_params)
    reduced = embedding.embedding_bag(table, indices)
    return top_mlp(top, interaction(bottom_mlp(bot, dense), reduced))


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable binary cross-entropy with logits."""
    return jnp.mean(
        jnp.maximum(logits, 0.0)
        - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def mlp_step(cfg: ModelConfig, mlp_flat, reduced, dense, labels):
    """The CXL-GPU half of a batch: MLP fwd+bwd+SGD given the reduced
    embedding vectors from CXL-MEM. Returns (*new_mlp_flat, grad_reduced,
    loss).

    This split mirrors the paper's hardware: the embedding path
    (embedding_bag / embedding_update, table-resident) and the MLP path
    exchange only the reduced vectors and their gradients — which is also
    what lets the rust runtime keep the (huge) table in a device buffer
    while the (small) MLP state round-trips per batch.
    """
    nb = len(cfg.bottom_layers)
    nt = len(cfg.top_layers)
    bot = [(mlp_flat[2 * i], mlp_flat[2 * i + 1]) for i in range(nb)]
    top = [(mlp_flat[2 * nb + 2 * i], mlp_flat[2 * nb + 2 * i + 1]) for i in range(nt)]

    def loss_fn(mlp_params, reduced_in):
        bot_p, top_p = mlp_params
        z = interaction(bottom_mlp(bot_p, dense), reduced_in)
        return bce_loss(top_mlp(top_p, z), labels)

    loss, (grads_mlp, grad_reduced) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
        (bot, top), reduced
    )
    lr = jnp.float32(cfg.lr)
    new_bot = [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(bot, grads_mlp[0])]
    new_top = [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(top, grads_mlp[1])]
    out = []
    for w, b in new_bot + new_top:
        out.extend([w, b])
    out.append(grad_reduced)
    out.append(loss)
    return tuple(out)


def train_step(cfg: ModelConfig, flat_params, dense, indices, labels):
    """One fused FWP+BWP+SGD batch. Returns (*new_flat_params, loss)."""
    bot, top, table = split_params(cfg, flat_params)
    # FWP embedding path (CXL-MEM computing logic); grad boundary here.
    reduced = jax.lax.stop_gradient(embedding.embedding_bag(table, indices))

    def loss_fn(mlp_params, reduced_in):
        bot_p, top_p = mlp_params
        z = interaction(bottom_mlp(bot_p, dense), reduced_in)
        return bce_loss(top_mlp(top_p, z), labels)

    loss, (grads_mlp, grad_reduced) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
        (bot, top), reduced
    )

    lr = jnp.float32(cfg.lr)
    new_bot = [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(bot, grads_mlp[0])]
    new_top = [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(top, grads_mlp[1])]
    # BWP embedding path: near-memory scatter update on the bag gradient.
    new_table = embedding.embedding_update(table, indices, grad_reduced, lr)

    out = []
    for w, b in new_bot + new_top:
        out.extend([w, b])
    out.append(new_table)
    out.append(loss)
    return tuple(out)


# ---------------------------------------------------------------- exports


def example_inputs(cfg: ModelConfig, what: str):
    """ShapeDtypeStructs for jax.jit(...).lower of each exported function."""
    B, T, L, D = cfg.batch_size, cfg.num_tables, cfg.lookups_per_table, cfg.feature_dim
    f32, i32 = jnp.float32, jnp.int32
    params = [jax.ShapeDtypeStruct(s, f32) for _, s in param_specs(cfg)]
    dense = jax.ShapeDtypeStruct((B, cfg.num_dense), f32)
    indices = jax.ShapeDtypeStruct((T, B, L), i32)
    labels = jax.ShapeDtypeStruct((B,), f32)
    table = params[-1]
    if what == "train_step":
        return [*params, dense, indices, labels]
    if what == "forward":
        return [*params, dense, indices]
    if what == "bottom_mlp":
        return [*params[: 2 * len(cfg.bottom_layers)], dense]
    if what == "top_mlp":
        nb = 2 * len(cfg.bottom_layers)
        z = jax.ShapeDtypeStruct((B, cfg.interaction_dim), f32)
        return [*params[nb : nb + 2 * len(cfg.top_layers)], z]
    if what == "embedding_bag":
        return [table, indices]
    if what == "embedding_update":
        grad = jax.ShapeDtypeStruct((B, T, D), f32)
        return [table, indices, grad]
    if what == "gather_rows":
        return [table, indices]
    if what == "mlp_step":
        nmlp = 2 * (len(cfg.bottom_layers) + len(cfg.top_layers))
        reduced = jax.ShapeDtypeStruct((B, T, D), f32)
        return [*params[:nmlp], reduced, dense, labels]
    raise ValueError(what)


def export_fn(cfg: ModelConfig, what: str):
    """The callable to lower for artifact `what` (positional args only)."""
    nparams = len(param_specs(cfg))

    if what == "train_step":

        def f(*args):
            return train_step(cfg, list(args[:nparams]), *args[nparams:])

    elif what == "forward":

        def f(*args):
            return (forward(cfg, list(args[:nparams]), *args[nparams:]),)

    elif what == "bottom_mlp":
        nb = len(cfg.bottom_layers)

        def f(*args):
            layers = [(args[2 * i], args[2 * i + 1]) for i in range(nb)]
            return (bottom_mlp(layers, args[2 * nb]),)

    elif what == "top_mlp":
        nt = len(cfg.top_layers)

        def f(*args):
            layers = [(args[2 * i], args[2 * i + 1]) for i in range(nt)]
            return (top_mlp(layers, args[2 * nt]),)

    elif what == "embedding_bag":

        def f(table, indices):
            return (embedding.embedding_bag(table, indices),)

    elif what == "embedding_update":

        def f(table, indices, grad):
            return (
                embedding.embedding_update(table, indices, grad, jnp.float32(cfg.lr)),
            )

    elif what == "gather_rows":

        def f(table, indices):
            return (embedding.gather_rows(table, indices),)

    elif what == "mlp_step":
        nmlp = 2 * (len(cfg.bottom_layers) + len(cfg.top_layers))

        def f(*args):
            return mlp_step(cfg, list(args[:nmlp]), *args[nmlp:])

    else:
        raise ValueError(what)
    return f


EXPORTS = (
    "train_step",
    "mlp_step",
    "forward",
    "bottom_mlp",
    "top_mlp",
    "embedding_bag",
    "embedding_update",
    "gather_rows",
)
