//! Failure injection + recovery experiments (paper Fig 9a).
//!
//! Protocol: train for `pre` batches with batch-aware checkpointing where
//! the MLP snapshot lags by `gap` batches; inject a power failure (device
//! state lost, in-flight rows corrupted); recover from the log region
//! (tables at batch N, MLP at batch N-gap); resume for `post` batches;
//! report the final held-out accuracy. The paper's claim: the accuracy
//! degradation stays within the 0.01% business tolerance even when the
//! gap reaches hundreds of batches.
//!
//! The trainer is constructed from a fabric [`Topology`] (the CXL
//! flagship schedule with the gap under test as `max_mlp_log_gap`), so
//! the experiment runs exactly the checkpoint schedule the simulator
//! models — not an ad-hoc option set.

use super::trainer::{CkptOptions, Trainer};
use crate::checkpoint;
use crate::config::sysconfig::CkptMode;
use crate::config::ModelConfig;
use crate::sim::topology::Topology;
use std::path::Path;

/// One Fig-9a measurement.
#[derive(Clone, Copy, Debug)]
pub struct GapResult {
    pub gap: u64,
    pub recovered_from: u64,
    pub mlp_gap_observed: u64,
    pub loss: f32,
    pub accuracy: f32,
}

/// The fabric whose checkpoint schedule a gap experiment runs: the CXL
/// flagship with `max_mlp_log_gap` set to the gap under test (`gap <= 1`
/// degrades to the synchronous CXL-B schedule).
pub fn gap_topology(gap: u64) -> Topology {
    let b = Topology::builder(&format!("cxl-gap-{gap}"))
        .near_data()
        .hw_movement();
    let b = if gap > 1 {
        b.checkpoint(CkptMode::Relaxed)
            .relaxed_lookup()
            .max_mlp_log_gap(gap)
    } else {
        b.checkpoint(CkptMode::BatchAware)
    };
    b.build().expect("gap topologies are always valid")
}

/// Train, crash, recover with an MLP log `gap` batches stale, resume, and
/// evaluate. `gap == 0` means MLP logged every batch (no staleness).
pub fn run_gap_experiment(
    root: &Path,
    cfg: &ModelConfig,
    seed: u64,
    pre: u64,
    post: u64,
    gap: u64,
    eval_batches: u64,
) -> anyhow::Result<GapResult> {
    let topo = gap_topology(gap);
    let ckpt = CkptOptions::from_topology(&topo).expect("gap topologies checkpoint");
    let mut t = Trainer::with_topology(root, cfg, seed, &topo)?;
    for _ in 0..pre {
        t.step()?;
    }

    // ---- power failure: device state gone, in-flight rows torn; roll
    // back from the log region
    let (mut store, log, mlp_shapes) = t.crash();
    let rec = checkpoint::recover(&mut store, &log)
        .map_err(|e| anyhow::anyhow!("recovery failed: {e}"))?;

    let mut t = Trainer::from_recovered(
        root,
        cfg,
        seed,
        store,
        rec.mlp_params.clone(),
        mlp_shapes,
        rec.resume_batch,
        ckpt,
    )?;
    for _ in 0..post {
        t.step()?;
    }
    let (loss, accuracy) = t.evaluate(eval_batches, seed ^ 0xE7A1)?;
    Ok(GapResult {
        gap,
        recovered_from: rec.resume_batch,
        mlp_gap_observed: rec.mlp_gap,
        loss,
        accuracy,
    })
}

/// Baseline: same schedule with no crash (DRAM-ideal fabric: no
/// checkpointing, no mirror).
pub fn run_no_crash_baseline(
    root: &Path,
    cfg: &ModelConfig,
    seed: u64,
    batches: u64,
    eval_batches: u64,
) -> anyhow::Result<(f32, f32)> {
    use crate::config::SystemConfig;
    let topo = Topology::from_system(SystemConfig::Dram);
    let mut t = Trainer::with_topology(root, cfg, seed, &topo)?;
    for _ in 0..batches {
        t.step()?;
    }
    t.evaluate(eval_batches, seed ^ 0xE7A1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    fn ready() -> Option<(std::path::PathBuf, ModelConfig)> {
        let root = repo_root();
        if !root.join("artifacts/rm_mini/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
        Some((root, cfg))
    }

    #[test]
    fn gap_topologies_follow_paper_schedules() {
        // no artifacts needed: the derivation is pure
        let sync = gap_topology(1);
        assert_eq!(sync.ckpt, CkptMode::BatchAware);
        let relaxed = gap_topology(25);
        assert_eq!(relaxed.ckpt, CkptMode::Relaxed);
        assert_eq!(relaxed.max_mlp_log_gap, 25);
        let o = CkptOptions::from_topology(&relaxed).unwrap();
        assert_eq!((o.mlp_every, o.mlp_stream_batches), (25, 25));
    }

    #[test]
    fn crash_recovery_resumes_and_learns() {
        let Some((root, cfg)) = ready() else { return };
        let r = run_gap_experiment(&root, &cfg, 11, 12, 12, 1, 4).unwrap();
        assert_eq!(r.recovered_from, 11); // emb log of the last batch
        assert!(r.mlp_gap_observed <= 1);
        assert!(r.accuracy > 0.5, "acc {}", r.accuracy);
    }

    #[test]
    fn crash_corrupts_inflight_rows_and_rollback_restores_them() {
        let Some((root, cfg)) = ready() else { return };
        let topo = gap_topology(1);
        let mut t = Trainer::with_topology(&root, &cfg, 17, &topo).unwrap();
        for _ in 0..5 {
            t.step().unwrap();
        }
        let (mut store, log, _) = t.crash();
        // the crash tore the in-flight batch's touched rows
        let touched: Vec<(usize, usize)> = log
            .persistent_emb()
            .unwrap()
            .entries
            .iter()
            .map(|e| (e.table, e.row))
            .collect();
        assert!(!touched.is_empty());
        for &(ti, ri) in &touched {
            assert!(
                store.row(ti, ri).iter().all(|v| v.is_nan()),
                "({ti},{ri}) not torn"
            );
        }
        let rec = checkpoint::recover(&mut store, &log).unwrap();
        assert_eq!(rec.resume_batch, 4);
        // rollback must leave no garbage anywhere...
        assert!(store.flat().iter().all(|v| v.is_finite()));
        // ...and restore exactly the state at the start of the in-flight
        // batch: a twin that stopped one batch earlier agrees bit-for-bit
        let mut twin = Trainer::with_topology(&root, &cfg, 17, &topo).unwrap();
        for _ in 0..4 {
            twin.step().unwrap();
        }
        assert_eq!(store, *twin.store.as_ref().unwrap());
    }

    #[test]
    fn recovery_survives_gap_longer_than_run() {
        let Some((root, cfg)) = ready() else { return };
        // window longer than the whole pre phase: only the bootstrap MLP
        // snapshot (batch 0, sealed synchronously) exists at crash time —
        // recovery must still succeed, with the full staleness reported
        let r = run_gap_experiment(&root, &cfg, 11, 6, 6, 50, 4).unwrap();
        assert_eq!(r.recovered_from, 5);
        assert_eq!(r.mlp_gap_observed, 5);
    }

    #[test]
    fn stale_mlp_recovery_close_to_fresh() {
        let Some((root, cfg)) = ready() else { return };
        // longer resume phase lets recovery re-converge (Fig 9a's regime
        // is thousands of batches; rm_mini keeps CI fast)
        let fresh = run_gap_experiment(&root, &cfg, 11, 20, 60, 1, 10).unwrap();
        let stale = run_gap_experiment(&root, &cfg, 11, 20, 60, 10, 10).unwrap();
        assert!(stale.mlp_gap_observed > 0, "gap not exercised");
        // Fig 9a: accuracy degradation is tiny even at large gaps
        assert!(
            (fresh.accuracy - stale.accuracy).abs() < 0.04,
            "fresh {} vs stale {}",
            fresh.accuracy,
            stale.accuracy
        );
    }
}
