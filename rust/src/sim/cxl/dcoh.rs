//! DCOH — the Type-2 *device coherency engine* (paper Fig 2/5).
//!
//! Tracks, per 64B cacheline, which agent holds the line and in what state
//! (MESI without the E optimisation: Invalid / Shared / Modified). The
//! paper's automatic data movement works by having the producer cache the
//! consumer's memory (CXL.cache) and then *flush* the dirty lines, which
//! pushes the data to where it will be used next without any host software.
//!
//! Invariants enforced (and property-tested in `rust/tests/proptests.rs`):
//!   * at most one agent holds a line Modified;
//!   * Modified excludes any other holder (even Shared);
//!   * flush leaves the line uncached and yields exactly the dirty bytes.

use std::collections::BTreeMap;

/// Coherency agent id (host = 0 by convention; devices >= 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AgentId(pub u16);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheState {
    Shared,
    Modified,
}

pub const LINE: u64 = 64;

/// Per-line directory.
#[derive(Debug, Default)]
pub struct Dcoh {
    /// line base address -> holders
    lines: BTreeMap<u64, Vec<(AgentId, CacheState)>>,
    /// protocol message counters (snoops/invalidation traffic)
    pub snoops: u64,
    pub flushes: u64,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CoherenceError {
    #[error("agent {0:?} does not hold line {1:#x}")]
    NotHolder(AgentId, u64),
}

impl Dcoh {
    pub fn new() -> Self {
        Self::default()
    }

    fn line_of(addr: u64) -> u64 {
        addr & !(LINE - 1)
    }

    /// Agent reads a line into its cache (CXL.cache RdShared). Invalidates
    /// nothing; downgrades a remote Modified holder to Shared (snoop +
    /// implicit writeback).
    pub fn read(&mut self, agent: AgentId, addr: u64) {
        let line = Self::line_of(addr);
        let holders = self.lines.entry(line).or_default();
        for (a, st) in holders.iter_mut() {
            if *st == CacheState::Modified && *a != agent {
                *st = CacheState::Shared;
                self.snoops += 1;
            }
        }
        if !holders.iter().any(|(a, _)| *a == agent) {
            holders.push((agent, CacheState::Shared));
        }
    }

    /// Agent writes a line (CXL.cache RdOwn): invalidate all other holders.
    pub fn write(&mut self, agent: AgentId, addr: u64) {
        let line = Self::line_of(addr);
        let holders = self.lines.entry(line).or_default();
        let before = holders.len();
        holders.retain(|(a, _)| *a == agent);
        self.snoops += (before - holders.len()) as u64;
        match holders.iter_mut().find(|(a, _)| *a == agent) {
            Some((_, st)) => *st = CacheState::Modified,
            None => holders.push((agent, CacheState::Modified)),
        }
    }

    /// Flush one line from `agent`'s cache (CXL.cache CleanEvict/DirtyEvict).
    /// Returns the number of dirty bytes pushed to memory (0 or LINE).
    pub fn flush_line(&mut self, agent: AgentId, addr: u64) -> Result<u64, CoherenceError> {
        let line = Self::line_of(addr);
        let holders = self
            .lines
            .get_mut(&line)
            .ok_or(CoherenceError::NotHolder(agent, line))?;
        let idx = holders
            .iter()
            .position(|(a, _)| *a == agent)
            .ok_or(CoherenceError::NotHolder(agent, line))?;
        let (_, st) = holders.swap_remove(idx);
        if holders.is_empty() {
            self.lines.remove(&line);
        }
        self.flushes += 1;
        Ok(match st {
            CacheState::Modified => LINE,
            CacheState::Shared => 0,
        })
    }

    /// Flush an address range; returns total dirty bytes (the transfer the
    /// fabric must price — Fig 5b's "flush every cacheline of the reduced
    /// embedding vector").
    pub fn flush_range(&mut self, agent: AgentId, start: u64, len: u64) -> u64 {
        let mut dirty = 0;
        let mut a = Self::line_of(start);
        while a < start + len {
            if let Ok(b) = self.flush_line(agent, a) {
                dirty += b;
            }
            a += LINE;
        }
        dirty
    }

    /// Write a whole range then flush it — the producer side of automatic
    /// data movement. Returns dirty bytes moved.
    pub fn produce_and_flush(&mut self, agent: AgentId, start: u64, len: u64) -> u64 {
        let mut a = Self::line_of(start);
        while a < start + len {
            self.write(agent, a);
            a += LINE;
        }
        self.flush_range(agent, start, len)
    }

    pub fn state(&self, agent: AgentId, addr: u64) -> Option<CacheState> {
        self.lines
            .get(&Self::line_of(addr))
            .and_then(|h| h.iter().find(|(a, _)| *a == agent).map(|(_, s)| *s))
    }

    pub fn holders(&self, addr: u64) -> usize {
        self.lines
            .get(&Self::line_of(addr))
            .map(|h| h.len())
            .unwrap_or(0)
    }

    /// Check the single-writer invariant for every tracked line.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (line, holders) in &self.lines {
            let modified = holders
                .iter()
                .filter(|(_, s)| *s == CacheState::Modified)
                .count();
            if modified > 1 {
                return Err(format!("line {line:#x}: {modified} Modified holders"));
            }
            if modified == 1 && holders.len() > 1 {
                return Err(format!(
                    "line {line:#x}: Modified coexists with {} other holders",
                    holders.len() - 1
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GPU: AgentId = AgentId(1);
    const MEM: AgentId = AgentId(2);

    #[test]
    fn write_invalidates_other_holders() {
        let mut d = Dcoh::new();
        d.read(GPU, 0x100);
        d.read(MEM, 0x100);
        assert_eq!(d.holders(0x100), 2);
        d.write(MEM, 0x100);
        assert_eq!(d.holders(0x100), 1);
        assert_eq!(d.state(MEM, 0x100), Some(CacheState::Modified));
        assert_eq!(d.state(GPU, 0x100), None);
        d.check_invariants().unwrap();
    }

    #[test]
    fn read_downgrades_modified() {
        let mut d = Dcoh::new();
        d.write(GPU, 0x40);
        d.read(MEM, 0x40);
        assert_eq!(d.state(GPU, 0x40), Some(CacheState::Shared));
        assert_eq!(d.state(MEM, 0x40), Some(CacheState::Shared));
        d.check_invariants().unwrap();
    }

    #[test]
    fn flush_moves_exactly_dirty_bytes() {
        let mut d = Dcoh::new();
        // 300B reduced vector at 0x1000: 5 lines written + flushed
        let dirty = d.produce_and_flush(MEM, 0x1000, 300);
        assert_eq!(dirty, 5 * LINE);
        assert_eq!(d.holders(0x1000), 0);
        // clean lines flush for free
        d.read(GPU, 0x2000);
        assert_eq!(d.flush_line(GPU, 0x2000).unwrap(), 0);
    }

    #[test]
    fn flush_requires_holding() {
        let mut d = Dcoh::new();
        assert!(d.flush_line(GPU, 0x0).is_err());
        d.read(MEM, 0x0);
        assert!(d.flush_line(GPU, 0x0).is_err());
    }

    #[test]
    fn unaligned_ranges_cover_partial_lines() {
        let mut d = Dcoh::new();
        let dirty = d.produce_and_flush(GPU, 0x10, 64); // straddles 2 lines
        assert_eq!(dirty, 2 * LINE);
    }
}
