//! Simulation substrates: the discrete-event engine, the CXL protocol
//! model (links, switch, DCOH), and the memory-media timing models of
//! Table 2.
//!
//! The [`engine`] is the scheduler every simulator in the crate pumps:
//! typed slot/round/crash events over a deterministic (time,
//! insertion-seq) queue, FIFO resource queues keyed by the analyzer's
//! `Resource` vocabulary, and a worker pool with index-keyed merge so
//! multi-tenant rounds parallelize without losing byte-identical
//! determinism (see `docs/engine.md`).
//!
//! Fidelity comes in two levels, deliberately:
//!
//! * **Request level** — [`engine`] + [`mem::controller`] simulate
//!   individual line/vector accesses through channel-interleaved
//!   controllers. Used to *validate* the analytic model against Table 2
//!   (`benches/table2_media.rs`) and for microbenchmarks.
//! * **Batch level** — [`mem::MediaModel::batch_access`] computes closed-form
//!   durations for a batch of accesses (same parameters), which the
//!   [`crate::sched`] pipeline uses so that full Fig-11/12/13 sweeps run in
//!   milliseconds. The request-level engine is the ground truth the
//!   analytic form is tested against (see `sim::mem::tests`).

pub mod cxl;
pub mod engine;
pub mod fabric;
pub mod mem;
pub mod topology;

pub use topology::{Topology, TopologyBuilder, TopologyError};

/// Simulated time in nanoseconds.
pub type SimTime = u64;

/// Convert f64 nanoseconds (from bandwidth math) to SimTime, rounding up.
#[inline]
pub fn ns(t: f64) -> SimTime {
    debug_assert!(t >= 0.0 && t.is_finite(), "bad duration {t}");
    t.ceil() as SimTime
}

/// A half-open busy interval on a named resource; the unit telemetry and
/// Fig-12 timelines are built from.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub lane: Lane,
    pub kind: OpKind,
    pub batch: u64,
    pub start: SimTime,
    pub end: SimTime,
}

/// Hardware resources (Fig 12's rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Lane {
    /// CXL-GPU (bottom/top-MLP, interaction)
    Gpu,
    /// CXL-MEM computing logic (embedding lookup/update)
    CompLogic,
    /// CXL-MEM checkpointing logic (DMA engine)
    CkptLogic,
    /// PMEM backend channels (aggregate)
    Pmem,
    /// Host CPU (software path: embedding ops, sync, memcpy)
    HostCpu,
    /// Interconnect (CXL or PCIe)
    Link,
}

impl Lane {
    pub fn name(&self) -> &'static str {
        match self {
            Lane::Gpu => "CXL-GPU",
            Lane::CompLogic => "CompLogic",
            Lane::CkptLogic => "CkptLogic",
            Lane::Pmem => "PMEM",
            Lane::HostCpu => "HostCPU",
            Lane::Link => "Link",
        }
    }
}

/// Operation categories; Fig 11's stacked-bar segments plus checkpoint
/// sub-kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    BottomMlp,
    TopMlp,
    Transfer,
    EmbLookup,
    EmbUpdate,
    CkptEmb,
    CkptMlp,
    Idle,
}

impl OpKind {
    /// Paper Figure 11 category this op is accounted under.
    pub fn breakdown(&self) -> &'static str {
        match self {
            OpKind::BottomMlp => "B-MLP",
            OpKind::TopMlp => "T-MLP",
            OpKind::Transfer => "Transfer",
            OpKind::EmbLookup | OpKind::EmbUpdate => "Embedding",
            OpKind::CkptEmb | OpKind::CkptMlp => "Checkpoint",
            OpKind::Idle => "Idle",
        }
    }
}
