//! Tail-latency telemetry for the online-serving lanes: a log-bucketed
//! latency histogram (p50/p99/p999 without retaining every sample) and a
//! staleness gauge (served-embedding age behind the training head).
//!
//! The histogram is an HdrHistogram-lite: values below `2^SUB_BITS` ns
//! get exact unit buckets, everything above lands in one of `2^SUB_BITS`
//! linear sub-buckets per power-of-two octave, so relative bucket width
//! is bounded by `2^-SUB_BITS` (6.25%) across the full `u64` range.
//! Recording, percentile queries, and merging are all O(buckets); two
//! histograms merge into exactly what recording the union would have
//! produced (pinned in `tests/proptests.rs`).

/// Linear sub-bucket bits per octave (16 sub-buckets, <= 6.25% width).
const SUB_BITS: u32 = 4;
/// Sub-buckets per octave.
const SUB: usize = 1 << SUB_BITS;
/// Highest index is `(63 - SUB_BITS) * SUB + 2*SUB - 1` = 991.
const BUCKETS: usize = (64 - SUB_BITS as usize) * SUB + SUB;

/// Log-bucketed latency histogram over `u64` nanosecond samples.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    min: u64,
    max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: vec![0; BUCKETS],
            total: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Bucket index of a value (exposed so tests can assert "within one
    /// bucket" without duplicating the bucketing rule).
    pub fn bucket_index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let exp = 63 - v.leading_zeros();
        let m = (v >> (exp - SUB_BITS)) as usize; // in [SUB, 2*SUB)
        (exp - SUB_BITS) as usize * SUB + m
    }

    /// Inclusive `(low, high)` value range of bucket `i`.
    pub fn bucket_bounds(i: usize) -> (u64, u64) {
        if i < SUB {
            return (i as u64, i as u64);
        }
        let shift = (i / SUB - 1) as u32;
        let m = (i - (shift as usize) * SUB) as u64;
        let low = m << shift;
        (low, low + (1u64 << shift) - 1)
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.total += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Value at quantile `q` in (0, 1]: the upper bound of the bucket
    /// holding the rank-`ceil(q * n)` sample (the same nearest-rank rule
    /// the exact sorted computation uses), clamped to the observed max.
    /// 0 on an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_bounds(i).1.min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.percentile(0.999)
    }

    /// Fold `other` into `self`; equivalent to having recorded the union
    /// of both sample sets.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Served-embedding age gauge: how many training batches the embeddings a
/// serving batch read were behind the training head (0 when no trainer is
/// co-located — the server always reads the freshest committed table).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StalenessGauge {
    samples: u64,
    total: u64,
    max: u64,
}

impl StalenessGauge {
    pub fn record(&mut self, age_batches: u64) {
        self.samples += 1;
        self.total += age_batches;
        self.max = self.max.max(age_batches);
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.total as f64 / self.samples as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rules_are_contiguous_and_invertible() {
        // every bucket's bounds map back to its own index, and bucket
        // lows are strictly increasing (no gaps, no overlaps)
        let mut prev_high = None;
        for i in 0..BUCKETS {
            let (lo, hi) = LatencyHistogram::bucket_bounds(i);
            assert!(lo <= hi);
            assert_eq!(LatencyHistogram::bucket_index(lo), i, "low of {i}");
            assert_eq!(LatencyHistogram::bucket_index(hi), i, "high of {i}");
            if let Some(p) = prev_high {
                assert_eq!(lo, p + 1u64, "gap before bucket {i}");
            }
            prev_high = Some(hi);
        }
        assert_eq!(LatencyHistogram::bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn relative_bucket_width_bounded() {
        for v in [100u64, 1_000, 1_000_000, 1_000_000_000, u64::MAX / 2] {
            let (lo, hi) = LatencyHistogram::bucket_bounds(LatencyHistogram::bucket_index(v));
            assert!((hi - lo) as f64 <= lo as f64 / (SUB as f64 - 1.0) + 1.0, "{v}: [{lo},{hi}]");
        }
    }

    #[test]
    fn percentiles_on_known_samples() {
        let mut h = LatencyHistogram::new();
        for v in 1..=100u64 {
            h.record(v * 1000);
        }
        assert_eq!(h.count(), 100);
        // nearest-rank p50 is the 50th sample (50_000 ns); the histogram
        // answers with that sample's bucket upper bound
        let (lo, hi) = LatencyHistogram::bucket_bounds(LatencyHistogram::bucket_index(50_000));
        assert!((lo..=hi).contains(&h.p50()), "{} not in [{lo},{hi}]", h.p50());
        assert!(h.p99() >= h.p50());
        assert!(h.p999() >= h.p99());
        assert!(h.p999() <= h.max());
        assert_eq!(h.min(), 1000);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p999(), 0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(10);
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 1_000_000);
    }

    #[test]
    fn staleness_gauge_tracks_mean_and_max() {
        let mut g = StalenessGauge::default();
        assert_eq!(g.mean(), 0.0);
        g.record(0);
        g.record(4);
        g.record(2);
        assert_eq!(g.samples(), 3);
        assert!((g.mean() - 2.0).abs() < 1e-12);
        assert_eq!(g.max(), 4);
    }
}
