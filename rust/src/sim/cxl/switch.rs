//! CXL switch: routes HPA ranges to ports, counts traffic.
//!
//! CXL 3.0 allows up to 4095 devices per root complex through multi-level
//! switching; we model one level (the paper's topology: host, CXL-GPU,
//! CXL-MEM behind one switch) but the routing table is range-based so
//! multi-expander pools (more CXL-MEM ports) work too — that is what the
//! `fabric_explorer` example sweeps.

use std::collections::BTreeMap;

/// Switch port handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PortId(pub u16);

/// One HPA window claimed by a port.
#[derive(Clone, Copy, Debug)]
struct Window {
    start: u64,
    len: u64,
    port: PortId,
}

/// Range-routed switch with per-port byte counters.
#[derive(Debug, Default)]
pub struct Switch {
    windows: Vec<Window>,
    names: BTreeMap<PortId, String>,
    pub bytes_by_port: BTreeMap<PortId, u64>,
}

#[derive(Clone, Debug, thiserror::Error, PartialEq)]
pub enum SwitchError {
    #[error("HPA window [{start:#x}, +{len:#x}) overlaps an existing window")]
    Overlap { start: u64, len: u64 },
    #[error("address {0:#x} is not claimed by any port")]
    Unrouted(u64),
    #[error("HPA window at {start:#x} has zero length (routes nothing)")]
    ZeroLength { start: u64 },
    #[error("HPA window [{start:#x}, +{len:#x}) overflows the address space")]
    Overflow { start: u64, len: u64 },
}

impl Switch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach a device: claim `[start, start+len)` of HPA for `port`.
    ///
    /// Rejected without registering anything: a zero-length window (it
    /// would route nothing yet still claim a name/counter) and a window
    /// whose end wraps past `u64::MAX` (the old `start + len` overflow
    /// would panic in debug and silently wrap — mis-routing — in release).
    pub fn attach(
        &mut self,
        port: PortId,
        name: &str,
        start: u64,
        len: u64,
    ) -> Result<(), SwitchError> {
        if len == 0 {
            return Err(SwitchError::ZeroLength { start });
        }
        let end = start
            .checked_add(len)
            .ok_or(SwitchError::Overflow { start, len })?;
        for w in &self.windows {
            // attached windows are overflow-checked, so `start + len` on
            // an existing window cannot wrap
            let wend = w.start + w.len;
            if start < wend && w.start < end {
                return Err(SwitchError::Overlap { start, len });
            }
        }
        self.windows.push(Window { start, len, port });
        self.names.insert(port, name.to_string());
        self.bytes_by_port.entry(port).or_insert(0);
        Ok(())
    }

    /// Route an HPA to its owning port.
    pub fn route(&self, addr: u64) -> Result<PortId, SwitchError> {
        self.windows
            .iter()
            .find(|w| addr >= w.start && addr < w.start + w.len)
            .map(|w| w.port)
            .ok_or(SwitchError::Unrouted(addr))
    }

    /// Account a transfer of `bytes` to/from `addr`'s port; returns the port.
    pub fn forward(&mut self, addr: u64, bytes: u64) -> Result<PortId, SwitchError> {
        let port = self.route(addr)?;
        *self.bytes_by_port.get_mut(&port).unwrap() += bytes;
        Ok(port)
    }

    pub fn port_name(&self, port: PortId) -> &str {
        self.names.get(&port).map(|s| s.as_str()).unwrap_or("?")
    }

    pub fn ports(&self) -> impl Iterator<Item = PortId> + '_ {
        self.names.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_by_range() {
        let mut sw = Switch::new();
        sw.attach(PortId(0), "host", 0x0, 0x1000).unwrap();
        sw.attach(PortId(1), "cxl-mem", 0x1000, 0x4000).unwrap();
        sw.attach(PortId(2), "cxl-gpu", 0x5000, 0x1000).unwrap();
        assert_eq!(sw.route(0x10).unwrap(), PortId(0));
        assert_eq!(sw.route(0x1000).unwrap(), PortId(1));
        assert_eq!(sw.route(0x4fff).unwrap(), PortId(1));
        assert_eq!(sw.route(0x5800).unwrap(), PortId(2));
        assert_eq!(sw.route(0x6000), Err(SwitchError::Unrouted(0x6000)));
    }

    #[test]
    fn rejects_overlapping_windows() {
        let mut sw = Switch::new();
        sw.attach(PortId(0), "a", 0x0, 0x2000).unwrap();
        assert!(matches!(
            sw.attach(PortId(1), "b", 0x1000, 0x1000),
            Err(SwitchError::Overlap { .. })
        ));
        // adjacent is fine
        sw.attach(PortId(2), "c", 0x2000, 0x1000).unwrap();
    }

    #[test]
    fn rejects_zero_length_and_overflowing_windows() {
        let mut sw = Switch::new();
        // a zero-length window routes nothing; before the checked-attach
        // fix it was silently accepted and still registered a name/counter
        assert_eq!(
            sw.attach(PortId(0), "empty", 0x1000, 0),
            Err(SwitchError::ZeroLength { start: 0x1000 })
        );
        // `start + len` used to overflow u64 (panic in debug, wrap and
        // mis-route in release)
        assert_eq!(
            sw.attach(PortId(1), "wrap", u64::MAX - 0x10, 0x100),
            Err(SwitchError::Overflow {
                start: u64::MAX - 0x10,
                len: 0x100
            })
        );
        // nothing was registered by the rejected attaches
        assert_eq!(sw.ports().count(), 0);
        assert!(sw.bytes_by_port.is_empty());
        assert_eq!(sw.route(0x1000), Err(SwitchError::Unrouted(0x1000)));
        // a window ending exactly at u64::MAX is still attachable
        sw.attach(PortId(2), "top", u64::MAX - 0x100, 0x100).unwrap();
        assert_eq!(sw.route(u64::MAX - 1).unwrap(), PortId(2));
    }

    #[test]
    fn overlap_check_safe_against_attached_windows() {
        // regression: the overlap scan recomputes `w.start + w.len` for
        // every attached window — after the checked attach that sum can
        // never wrap, so probing near the top of the space is safe
        let mut sw = Switch::new();
        sw.attach(PortId(0), "top", u64::MAX - 0x1000, 0x1000).unwrap();
        assert!(matches!(
            sw.attach(PortId(1), "probe", u64::MAX - 0x800, 0x100),
            Err(SwitchError::Overlap { .. })
        ));
    }

    #[test]
    fn traffic_accounting() {
        let mut sw = Switch::new();
        sw.attach(PortId(1), "cxl-mem", 0x1000, 0x1000).unwrap();
        sw.forward(0x1800, 256).unwrap();
        sw.forward(0x1810, 64).unwrap();
        assert_eq!(sw.bytes_by_port[&PortId(1)], 320);
    }
}
