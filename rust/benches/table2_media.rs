//! Bench: validate Table 2 (device characteristics) — the analytic media
//! model vs the request-level DES controller, plus measured latency and
//! bandwidth ratios vs DRAM.
//!
//! Run: `cargo bench --bench table2_media`

use trainingcxl::config::DeviceParams;
use trainingcxl::sim::mem::controller::{Controller, Request};
use trainingcxl::sim::mem::{AccessKind, MediaKind, MediaModel};

fn main() {
    let p = DeviceParams::builtin_default();
    println!("=== Table 2: device characteristics (measured on the models) ===");
    println!(
        "{:<6} {:>12} {:>12} {:>14} {:>14}",
        "media", "rd lat (vs D)", "wr lat (vs D)", "rd BW (vs D)", "wr BW (vs D)"
    );

    let measure = |kind: MediaKind, mp: &trainingcxl::config::device::MediaParams| {
        // latency: single access; bandwidth: large streaming batch
        let mut m = MediaModel::new(kind, mp.clone());
        let rd1 = m.batch_access(0, 1, 64, AccessKind::Read, 0.0).duration;
        m.reset();
        let wr1 = m.batch_access(0, 1, 64, AccessKind::Write, 0.0).duration;
        m.reset();
        let n = 1_000_000u64;
        let rdn = m.stream(0, n * 64, AccessKind::Read).duration;
        m.reset();
        let wrn = m.stream(0, n * 64, AccessKind::Write).duration;
        (rd1 as f64, wr1 as f64, n as f64 * 64.0 / rdn as f64, n as f64 * 64.0 / wrn as f64)
    };

    let (d_rl, d_wl, d_rb, d_wb) = measure(MediaKind::Dram, &p.dram);
    for (name, kind, mp) in [
        ("DRAM", MediaKind::Dram, &p.dram),
        ("PMEM", MediaKind::Pmem, &p.pmem),
        ("SSD", MediaKind::Ssd, &p.ssd),
    ] {
        let (rl, wl, rb, wb) = measure(kind, mp);
        println!(
            "{:<6} {:>12.1}x {:>12.1}x {:>14.2}x {:>14.2}x",
            name,
            rl / d_rl,
            wl / d_wl,
            rb / d_rb,
            wb / d_wb
        );
    }
    println!("(paper Table 2: PMEM 3x/7x lat, 0.6x/0.1x BW; SSD 165x lat, 0.02x BW)");

    println!("\n=== analytic model vs request-level DES (5000 x 128B random reads) ===");
    for (name, kind, mp) in [
        ("DRAM", MediaKind::Dram, &p.dram),
        ("PMEM", MediaKind::Pmem, &p.pmem),
        ("SSD", MediaKind::Ssd, &p.ssd),
    ] {
        let mut analytic = MediaModel::new(kind, mp.clone());
        let a = analytic.batch_access(0, 5000, 128, AccessKind::Read, 0.0).duration;
        let mut ctrl = Controller::new(mp.clone());
        let reqs: Vec<Request> = (0..5000)
            .map(|i| Request {
                addr: i * 128,
                bytes: 128,
                kind: AccessKind::Read,
            })
            .collect();
        let t0 = std::time::Instant::now();
        let des = ctrl.run_batch(&reqs);
        let wall = t0.elapsed();
        println!(
            "{:<6} analytic {:>12} ns | DES {:>12} ns | ratio {:>5.3} | DES wall {:?} ({:.1}M ev/s)",
            name,
            a,
            des,
            a as f64 / des as f64,
            wall,
            5000.0 / wall.as_secs_f64() / 1e6
        );
    }

    println!("\n=== RAW interference sweep (PMEM; paper §Relaxed Embedding Lookup) ===");
    for frac in [0.0, 0.2, 0.5, 0.8, 1.0] {
        let mut m = MediaModel::new(MediaKind::Pmem, p.pmem.clone());
        let w = m.batch_access(0, 50_000, 128, AccessKind::Write, 0.0);
        let r = m.batch_access(w.duration, 100_000, 128, AccessKind::Read, frac);
        println!(
            "  overlap {:>4.1}: lookup {:>10} ns ({} RAW hits)",
            frac, r.duration, r.raw_hits
        );
    }
}
