//! The CXL-MEM log region (Fig 7): double-buffered embedding undo logs and
//! MLP parameter logs with persistent flags.
//!
//! The region holds at most two generations of each log; a generation's
//! flag is set only after its payload is complete (write-ordering the real
//! hardware enforces with the DMA engine's completion counters). The
//! previous generation is dropped once the *current* one has both flags
//! set — so at any instant a crash finds at least one complete
//! (embedding, MLP) pair.

/// One undo-log row: the pre-update value of (table, row).
#[derive(Clone, Debug, PartialEq)]
pub struct EmbLogEntry {
    pub table: usize,
    pub row: usize,
    pub old: Vec<f32>,
}

/// One embedding-log generation.
#[derive(Clone, Debug, PartialEq)]
pub struct EmbLog {
    pub batch: u64,
    pub entries: Vec<EmbLogEntry>,
    pub persistent: bool,
}

/// One MLP-log generation (full parameter snapshot before batch `batch`).
#[derive(Clone, Debug, PartialEq)]
pub struct MlpLog {
    pub batch: u64,
    pub params: Vec<Vec<f32>>,
    /// Bytes written so far (relaxed logging streams incrementally).
    pub bytes_done: u64,
    pub bytes_total: u64,
    pub persistent: bool,
}

/// The log region: current + previous generation of each log.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LogRegion {
    pub emb_cur: Option<EmbLog>,
    pub emb_prev: Option<EmbLog>,
    pub mlp_cur: Option<MlpLog>,
    pub mlp_prev: Option<MlpLog>,
    /// Total bytes ever written (telemetry / wear accounting).
    pub bytes_written: u64,
}

impl LogRegion {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin the embedding undo log for `batch`: capture the old values of
    /// the rows the coming update will touch (known in advance from the
    /// sparse features — the batch-aware property).
    pub fn begin_emb_log(
        &mut self,
        batch: u64,
        store: &crate::emb::EmbeddingStore,
        touched: &[(usize, usize)],
    ) {
        let entries: Vec<EmbLogEntry> = touched
            .iter()
            .map(|&(t, r)| EmbLogEntry {
                table: t,
                row: r,
                old: store.row(t, r).to_vec(),
            })
            .collect();
        self.bytes_written += entries
            .iter()
            .map(|e| (e.old.len() * 4) as u64)
            .sum::<u64>();
        self.emb_prev = self.emb_cur.take();
        self.emb_cur = Some(EmbLog {
            batch,
            entries,
            persistent: false,
        });
    }

    /// Append more pre-update rows to the in-flight (unsealed) embedding
    /// generation. The tiered topologies build one generation in legs —
    /// the cold undo log captures the PMEM rows, then the hot-tier flush
    /// appends the volatile tier's rows — and seal only once the batch's
    /// whole footprint is durable; the sharded topologies append one
    /// stripe per lane. A crash between the legs leaves the generation
    /// unsealed, so recovery falls back to the previous complete one.
    pub fn extend_emb_log(
        &mut self,
        batch: u64,
        store: &crate::emb::EmbeddingStore,
        touched: &[(usize, usize)],
    ) {
        let log = self.emb_cur.as_mut().expect("no embedding log in flight");
        assert_eq!(log.batch, batch, "extending wrong embedding-log generation");
        assert!(!log.persistent, "extending a sealed embedding log");
        let mut bytes = 0u64;
        for &(t, r) in touched {
            let old = store.row(t, r).to_vec();
            bytes += (old.len() * 4) as u64;
            log.entries.push(EmbLogEntry { table: t, row: r, old });
        }
        self.bytes_written += bytes;
    }

    /// Mark the embedding log persistent (flag written after the payload).
    pub fn seal_emb_log(&mut self, batch: u64) {
        let log = self.emb_cur.as_mut().expect("no embedding log in flight");
        assert_eq!(log.batch, batch, "sealing wrong embedding-log generation");
        log.persistent = true;
        self.bytes_written += 8;
        self.gc();
    }

    /// Begin an MLP log snapshot of the *current* (pre-update) parameters.
    pub fn begin_mlp_log(&mut self, batch: u64, params: &[Vec<f32>]) {
        let total: u64 = params.iter().map(|p| (p.len() * 4) as u64).sum();
        self.mlp_prev = self.mlp_cur.take();
        self.mlp_cur = Some(MlpLog {
            batch,
            params: params.to_vec(),
            bytes_done: 0,
            bytes_total: total,
            persistent: false,
        });
    }

    /// Stream `bytes` of the in-flight MLP log (relaxed logging transfers
    /// in slices while the GPU is busy). Returns the bytes still pending.
    /// Wear telemetry counts only the clamped delta: a caller overshooting
    /// `bytes_total` writes no more media bytes than actually remain.
    pub fn advance_mlp_log(&mut self, bytes: u64) -> u64 {
        let log = self.mlp_cur.as_mut().expect("no MLP log in flight");
        let delta = bytes.min(log.bytes_total - log.bytes_done);
        log.bytes_done += delta;
        self.bytes_written += delta;
        log.bytes_total - log.bytes_done
    }

    /// Seal the MLP log once its completion counter matches the MMIO size.
    pub fn seal_mlp_log(&mut self) {
        let log = self.mlp_cur.as_mut().expect("no MLP log in flight");
        assert_eq!(
            log.bytes_done, log.bytes_total,
            "sealing an incomplete MLP log"
        );
        log.persistent = true;
        self.bytes_written += 8;
        self.gc();
    }

    /// Fig 7 step 4: drop the previous checkpoint only when the current
    /// embedding AND MLP logs are both persistent.
    fn gc(&mut self) {
        let both = self.emb_cur.as_ref().is_some_and(|l| l.persistent)
            && self.mlp_cur.as_ref().is_some_and(|l| l.persistent);
        if both {
            self.emb_prev = None;
            self.mlp_prev = None;
        }
    }

    /// The newest *persistent* embedding log (what recovery may use).
    pub fn persistent_emb(&self) -> Option<&EmbLog> {
        [self.emb_cur.as_ref(), self.emb_prev.as_ref()]
            .into_iter()
            .flatten()
            .find(|l| l.persistent)
    }

    /// The stripe of the newest persistent embedding log belonging to one
    /// GPU lane of a sharded topology (tables striped round-robin:
    /// `table % shards == shard`). Partial recovery of a single failed
    /// lane replays only its stripe instead of the whole log.
    pub fn persistent_emb_for_shard(&self, shard: usize, shards: usize) -> Vec<&EmbLogEntry> {
        assert!(shards > 0 && shard < shards, "shard {shard} of {shards}");
        self.persistent_emb()
            .map(|l| l.entries.iter().filter(|e| e.table % shards == shard).collect())
            .unwrap_or_default()
    }

    /// The newest *persistent* MLP log.
    pub fn persistent_mlp(&self) -> Option<&MlpLog> {
        [self.mlp_cur.as_ref(), self.mlp_prev.as_ref()]
            .into_iter()
            .flatten()
            .find(|l| l.persistent)
    }

    /// Batch gap between embedding and MLP persistent logs (Fig 9a x-axis).
    pub fn log_gap(&self) -> Option<u64> {
        match (self.persistent_emb(), self.persistent_mlp()) {
            (Some(e), Some(m)) => Some(e.batch.saturating_sub(m.batch)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::emb::EmbeddingStore;
    use crate::repo_root;

    fn setup() -> (ModelConfig, EmbeddingStore) {
        let cfg = ModelConfig::load(&repo_root(), "rm_mini").unwrap();
        let mut s = EmbeddingStore::zeros(&cfg);
        for t in 0..cfg.num_tables {
            for r in 0..cfg.rows_per_table {
                s.row_mut(t, r).fill((t * 1000 + r) as f32);
            }
        }
        (cfg, s)
    }

    #[test]
    fn captures_pre_update_values() {
        let (_, store) = setup();
        let mut log = LogRegion::new();
        log.begin_emb_log(3, &store, &[(0, 5), (1, 7)]);
        let cur = log.emb_cur.as_ref().unwrap();
        assert_eq!(cur.entries[0].old, vec![5.0; 8]);
        assert_eq!(cur.entries[1].old, vec![1007.0; 8]);
        assert!(!cur.persistent);
    }

    #[test]
    fn gc_waits_for_both_flags() {
        let (_, store) = setup();
        let mut log = LogRegion::new();
        log.begin_emb_log(0, &store, &[(0, 1)]);
        log.seal_emb_log(0);
        log.begin_mlp_log(0, &[vec![1.0, 2.0]]);
        assert_eq!(log.advance_mlp_log(8), 0);
        log.seal_mlp_log();

        // next generation: prev kept while the current emb log is unsealed
        log.begin_emb_log(1, &store, &[(0, 2)]);
        assert!(log.emb_prev.is_some(), "gen-1 emb log not persistent yet");
        // sealing it allows gc: a persistent MLP log exists (gen 0 — the
        // relaxed scheme intentionally lets the MLP generation lag)
        log.seal_emb_log(1);
        assert!(log.emb_prev.is_none(), "gc once both flags are set");

        // an in-flight (unsealed) MLP log protects its predecessor
        log.begin_mlp_log(1, &[vec![3.0, 4.0]]);
        log.begin_emb_log(2, &store, &[(0, 3)]);
        log.seal_emb_log(2);
        assert!(log.mlp_prev.is_some(), "gen-0 mlp still the recovery source");
        log.advance_mlp_log(8);
        log.seal_mlp_log();
        assert!(log.mlp_prev.is_none());
    }

    #[test]
    fn extend_builds_one_generation_in_legs() {
        let (_, store) = setup();
        let mut log = LogRegion::new();
        // leg 1: cold rows; leg 2: the hot tier's rows; seal after both
        log.begin_emb_log(0, &store, &[(0, 1), (1, 2)]);
        let before = log.bytes_written;
        log.extend_emb_log(0, &store, &[(2, 3), (3, 4)]);
        assert_eq!(log.bytes_written - before, 2 * 8 * 4, "wear counts the extension");
        // unsealed: recovery must not see the partial generation
        assert!(log.persistent_emb().is_none());
        log.seal_emb_log(0);
        let gen = log.persistent_emb().unwrap();
        assert_eq!(gen.entries.len(), 4);
        assert_eq!(gen.entries[3].old, vec![3004.0; 8]);
    }

    #[test]
    #[should_panic(expected = "extending wrong embedding-log generation")]
    fn extend_checks_generation() {
        let (_, store) = setup();
        let mut log = LogRegion::new();
        log.begin_emb_log(0, &store, &[(0, 1)]);
        log.extend_emb_log(1, &store, &[(0, 2)]);
    }

    #[test]
    #[should_panic(expected = "extending a sealed embedding log")]
    fn extend_rejects_sealed_generation() {
        let (_, store) = setup();
        let mut log = LogRegion::new();
        log.begin_emb_log(0, &store, &[(0, 1)]);
        log.seal_emb_log(0);
        log.extend_emb_log(0, &store, &[(0, 2)]);
    }

    #[test]
    fn persistent_lookup_skips_unsealed() {
        let (_, store) = setup();
        let mut log = LogRegion::new();
        log.begin_emb_log(0, &store, &[(0, 1)]);
        log.seal_emb_log(0);
        log.begin_emb_log(1, &store, &[(0, 2)]);
        // gen 1 unsealed: recovery must see gen 0
        assert_eq!(log.persistent_emb().unwrap().batch, 0);
        log.seal_emb_log(1);
        assert_eq!(log.persistent_emb().unwrap().batch, 1);
    }

    #[test]
    fn relaxed_mlp_log_streams_incrementally() {
        let mut log = LogRegion::new();
        log.begin_mlp_log(10, &[vec![0.0; 100]]); // 400 bytes
        assert_eq!(log.advance_mlp_log(150), 250);
        assert_eq!(log.advance_mlp_log(150), 100);
        assert_eq!(log.advance_mlp_log(500), 0); // clamped
        log.seal_mlp_log();
        assert!(log.persistent_mlp().is_some());
    }

    #[test]
    fn wear_accounting_counts_only_clamped_bytes() {
        let mut log = LogRegion::new();
        log.begin_mlp_log(0, &[vec![0.0; 100]]); // 400-byte payload
        let base = log.bytes_written;
        log.advance_mlp_log(150);
        assert_eq!(log.bytes_written - base, 150);
        // overshoot: only the 250 remaining payload bytes hit the media
        log.advance_mlp_log(10_000);
        assert_eq!(log.bytes_written - base, 400);
        // further advances on a complete log write nothing
        log.advance_mlp_log(64);
        assert_eq!(log.bytes_written - base, 400);
        log.seal_mlp_log();
    }

    #[test]
    #[should_panic(expected = "incomplete MLP log")]
    fn cannot_seal_incomplete_mlp_log() {
        let mut log = LogRegion::new();
        log.begin_mlp_log(0, &[vec![0.0; 4]]);
        log.seal_mlp_log();
    }

    #[test]
    fn shard_stripe_partitions_the_persistent_log() {
        let (_, store) = setup();
        let mut log = LogRegion::new();
        // rm_mini has 4 tables: one touched row in each
        log.begin_emb_log(0, &store, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        // unsealed: no persistent generation, every stripe is empty
        assert!(log.persistent_emb_for_shard(0, 2).is_empty());
        log.seal_emb_log(0);
        let s0 = log.persistent_emb_for_shard(0, 2);
        let s1 = log.persistent_emb_for_shard(1, 2);
        assert_eq!(s0.len() + s1.len(), 4);
        assert!(s0.iter().all(|e| e.table % 2 == 0));
        assert!(s1.iter().all(|e| e.table % 2 == 1));
        // a lane's stripe carries the same pre-update values as the log
        assert_eq!(s1[0].old, vec![1002.0; 8]);
        // one lane == the whole log
        assert_eq!(log.persistent_emb_for_shard(0, 1).len(), 4);
    }

    #[test]
    fn log_gap_measures_staleness() {
        let (_, store) = setup();
        let mut log = LogRegion::new();
        log.begin_mlp_log(2, &[vec![0.0]]);
        log.advance_mlp_log(4);
        log.seal_mlp_log();
        log.begin_emb_log(7, &store, &[(0, 0)]);
        log.seal_emb_log(7);
        assert_eq!(log.log_gap(), Some(5));
    }
}
