//! Bench: regenerate paper Figure 11 (training-time breakdown for RM1-4
//! under SSD/PMEM/PCIe/CXL-D/CXL-B/CXL) plus the headline comparison, and
//! time the simulator itself.
//!
//! Run: `cargo bench --bench fig11_breakdown`

use trainingcxl::bench::{bench_fn, experiments};
use trainingcxl::config::SystemConfig;

fn main() -> anyhow::Result<()> {
    let root = trainingcxl::repo_root();

    println!("{}", experiments::fig11(&root, 30)?);
    println!("{}", experiments::headline(&root, 30)?);
    println!("{}", experiments::ablate_movement(&root, 30)?);
    println!("{}", experiments::ablate_raw(&root, 30)?);

    // simulator hot-path timing (L3 perf target: scheduler not the
    // bottleneck — thousands of simulated batches per second)
    println!("=== simulator throughput ===");
    for sys in [SystemConfig::Pmem, SystemConfig::Cxl] {
        let r = bench_fn(
            &format!("pipeline rm1/{} x30 batches", sys.name()),
            2,
            10,
            || {
                experiments::simulate(&root, "rm1", sys, 30).unwrap();
            },
        );
        println!("{}", r.render());
    }
    Ok(())
}
