//! Typed fabric fault vocabulary.
//!
//! A pooled CXL 3.0 pod loses more than media: links drop lanes,
//! switches brown out, whole expanders disappear. [`FaultKind`] names the
//! three component classes a [`super::FabricTree`] can lose; the tree
//! itself holds the per-component health state (lane counters, switch
//! down flags, lost expander ports) and the tenancy layer schedules
//! injection/repair times as first-class engine events
//! ([`crate::sim::engine::Event::FabricFault`] /
//! [`crate::sim::engine::Event::FabricRepair`]).

/// One class of fabric component failure.
///
/// * `LinkDown` — one physical lane of an edge (a switch uplink or a
///   device-port link) goes down. With `[fabric] redundancy` spares the
///   edge keeps routing at degraded capacity; without survivors the
///   subtree behind it is unreachable until repair.
/// * `SwitchDown` — a whole switch browns out. Redundant lanes cannot
///   help: everything routed through it is unreachable until repair.
/// * `ExpanderLost` — the PMEM expander behind a device port is lost.
///   The HPA windows it backs are unreachable until it is restored, and
///   rows in flight at the instant of loss are torn: the owning tenants
///   must replay their undo slices on re-entry (bystanders whose windows
///   live elsewhere are untouched).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    LinkDown,
    SwitchDown,
    ExpanderLost,
}

impl FaultKind {
    pub const ALL: [FaultKind; 3] = [
        FaultKind::LinkDown,
        FaultKind::SwitchDown,
        FaultKind::ExpanderLost,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::LinkDown => "link-down",
            FaultKind::SwitchDown => "switch-down",
            FaultKind::ExpanderLost => "expander-lost",
        }
    }

    /// Parse a `[[faults]]` TOML `kind` value.
    pub fn parse(s: &str) -> Option<FaultKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "link-down" | "link" => FaultKind::LinkDown,
            "switch-down" | "switch" => FaultKind::SwitchDown,
            "expander-lost" | "expander" => FaultKind::ExpanderLost,
            _ => return None,
        })
    }

    /// Whether this fault tears persistent state (forcing undo-slice
    /// recovery) or merely stalls/degrades traffic.
    pub fn tears_data(&self) -> bool {
        matches!(self, FaultKind::ExpanderLost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for k in FaultKind::ALL {
            assert_eq!(FaultKind::parse(k.name()), Some(k));
        }
        assert_eq!(FaultKind::parse("switch"), Some(FaultKind::SwitchDown));
        assert_eq!(FaultKind::parse("fire"), None);
    }

    #[test]
    fn only_expander_loss_tears() {
        assert!(FaultKind::ExpanderLost.tears_data());
        assert!(!FaultKind::LinkDown.tears_data());
        assert!(!FaultKind::SwitchDown.tears_data());
    }
}
